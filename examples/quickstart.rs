//! Quickstart: train a logistic-regression model with FD-SVRG on a small
//! synthetic high-dimensional dataset and print the convergence trace.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! This is the 30-second tour of the public API: build a [`Problem`] from a
//! dataset, pick [`RunParams`], call [`Algorithm::run`], read the trace.

use fdsvrg::algs::{Algorithm, Problem, RunParams};
use fdsvrg::data::{generate, GenSpec};
use fdsvrg::metrics::TextTable;

fn main() {
    // A d > N dataset — the regime the paper targets (d/N = 12.5 here).
    let ds = generate(&GenSpec::new("quickstart", 10_000, 800, 50).with_seed(1));
    let problem = Problem::logistic_l2(ds, 1e-4);
    println!(
        "dataset: d={} features, N={} instances (aspect d/N = {:.1})",
        problem.d(),
        problem.n(),
        problem.d() as f64 / problem.n() as f64
    );

    // q=8 workers, 12 outer epochs, everything else at paper defaults
    // (M = N inner steps, auto step size η = 0.1/L, binomial-tree reduce).
    let params = RunParams { q: 8, outer: 12, ..Default::default() };
    let res = Algorithm::FdSvrg.run(&problem, &params);

    let mut table = TextTable::new(vec!["epoch", "objective", "sim time (s)", "Mscalars"]);
    for p in &res.trace.points {
        table.row(vec![
            format!("{}", p.outer),
            format!("{:.8}", p.objective),
            format!("{:.4}", p.sim_time),
            format!("{:.3}", p.scalars as f64 / 1e6),
        ]);
    }
    println!("{}", table.render());
    println!(
        "final: objective {:.6}, train accuracy {:.2}%, {} scalars moved ({}k per epoch)",
        res.final_objective(),
        100.0 * problem.accuracy(&res.w),
        res.total_scalars,
        res.total_scalars / (res.trace.points.len() as u64 - 1) / 1000,
    );
    println!(
        "note: an instance-distributed method would move ≥ 2qd = {} scalars per epoch —\n\
         FD-SVRG moved {} (the 4qN of §4.5), a {:.1}× reduction on this d/N.",
        2 * params.q * problem.d(),
        res.total_scalars / (res.trace.points.len() as u64 - 1),
        (2 * params.q * problem.d()) as f64
            / (res.total_scalars as f64 / (res.trace.points.len() as f64 - 1.0)),
    );
}
