//! Blocked compute-engine demo — proves the engine layers compose: the
//! rust coordinator drives the [`ComputeEngine`] kernels and reproduces
//! the f64 CSC reference numbers on a dense slab.
//!
//! On the default build this runs the pure-Rust native backend and needs
//! nothing else:
//!
//! ```sh
//! cargo run --release --example xla_engine
//! ```
//!
//! Under `--features xla` the same flow runs through the PJRT CPU client
//! on the AOT-compiled JAX/Pallas artifacts (python runs once at build
//! time, never here):
//!
//! ```sh
//! make artifacts && cargo run --release --features xla --example xla_engine
//! ```
//!
//! The demo runs one FD-SVRG worker's full-gradient phase (Alg. 1 lines
//! 3–5) and a sampled inner batch (lines 9–11) through both paths:
//!   reference : rust CSC kernels (f64)
//!   engine    : the selected ComputeEngine backend (f32)
//! and checks agreement to f32 tolerance.

use fdsvrg::data::{generate, GenSpec};
use fdsvrg::loss::{Logistic, Loss};
use fdsvrg::runtime::{
    build_engine, pad_slab, pad_vec, EngineKind, BLOCK_D, BLOCK_N, BLOCK_U,
};
use fdsvrg::util::Pcg64;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let kind = EngineKind::default_for_build();
    println!("building `{}` engine (artifacts dir: {dir}/) ...", kind.name());
    let engine = build_engine(kind, Path::new(&dir))?;
    println!(
        "engine `{}` ready: {} kernels in the contract",
        engine.name(),
        fdsvrg::runtime::ARTIFACTS.len()
    );

    // One worker's slab: dl ≤ BLOCK_D features of a dense-ish dataset,
    // n ≤ BLOCK_N instances.
    let ds = generate(&GenSpec::new("engine-demo", BLOCK_D, BLOCK_N - 37, 64).with_seed(5));
    let (dl, n) = (ds.d(), ds.n());
    let mut rng = Pcg64::seed_from_u64(9);
    let w: Vec<f64> = (0..dl).map(|_| 0.05 * rng.normal()).collect();

    // densify the slab column-major (dl × n), then pad to the block grid
    let slab = ds.x.dense_slab_f32(0, dl);
    let d_block = pad_slab(&slab, dl, n);
    let w32: Vec<f32> = w.iter().map(|&v| v as f32).collect();
    let w_pad = pad_vec(&w32, BLOCK_D);
    let y32: Vec<f32> = ds.y.iter().map(|&v| v as f32).collect();
    let y_pad = pad_vec(&y32, BLOCK_N);

    // ---- full-gradient phase through the engine ----
    let s = engine.partial_products(&w_pad, &d_block)?;
    let c = engine.logistic_coef(&s, &y_pad)?;
    let inv_n = 1.0 / n as f32;
    let c_scaled: Vec<f32> =
        c.iter().enumerate().map(|(i, &v)| if i < n { v * inv_n } else { 0.0 }).collect();
    let z = engine.coef_matvec(&d_block, &c_scaled)?;

    // ---- same numbers through the f64 reference path ----
    let loss = Logistic;
    let mut s_native = vec![0.0f64; n];
    ds.x.transpose_matvec(&w, &mut s_native);
    let mut z_native = vec![0.0f64; dl];
    for i in 0..n {
        let ci = loss.derivative(s_native[i], ds.y[i]) / n as f64;
        ds.x.col_axpy(i, ci, &mut z_native);
    }

    let err_s = max_abs_err(&s[..n], &s_native);
    let err_z = max_abs_err(&z[..dl], &z_native);
    println!("full-gradient phase: max |Δs| = {err_s:.2e}, max |Δz| = {err_z:.2e}");
    anyhow::ensure!(err_s < 1e-4 && err_z < 1e-5, "engine/reference disagreement");

    // ---- one inner mini-batch through the fused update kernel ----
    let idx: Vec<i32> = (0..BLOCK_U).map(|_| rng.below(n) as i32).collect();
    let dots = engine.batch_dots(&w_pad, &d_block, &idx)?;
    let margins: Vec<f32> = dots;
    let yb: Vec<f32> = idx.iter().map(|&i| y32[i as usize]).collect();
    let c0b: Vec<f32> =
        idx.iter().map(|&i| loss.derivative(s_native[i as usize], ds.y[i as usize]) as f32).collect();
    let (eta, lambda) = (0.05f32, 1e-3f32);
    let w_next = engine.batch_update(
        &w_pad, &z, &d_block, &idx, &margins, &yb, &c0b, eta, lambda,
    )?;

    // reference replica of the same fused update (sequential over the batch)
    let mut w_ref: Vec<f64> = w.clone();
    let z64: Vec<f64> = z_native.clone();
    for (k, &i) in idx.iter().enumerate() {
        let delta = loss.derivative(margins[k] as f64, yb[k] as f64) - c0b[k] as f64;
        for (wv, zv) in w_ref.iter_mut().zip(z64.iter()) {
            *wv = (1.0 - eta as f64 * lambda as f64) * *wv - eta as f64 * zv;
        }
        ds.x.col_axpy(i as usize, -(eta as f64) * delta, &mut w_ref);
    }
    let err_w = max_abs_err(&w_next[..dl], &w_ref);
    println!("fused inner-batch update: max |Δw| = {err_w:.2e}");
    anyhow::ensure!(err_w < 1e-4, "batch_update disagreement");

    println!(
        "OK — coordinator (L3) → `{}` engine kernels compose end-to-end \
         against the f64 reference.",
        engine.name()
    );
    Ok(())
}

fn max_abs_err(a32: &[f32], b64: &[f64]) -> f64 {
    a32.iter()
        .zip(b64.iter())
        .map(|(&a, &b)| (a as f64 - b).abs())
        .fold(0.0, f64::max)
}
