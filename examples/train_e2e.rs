//! End-to-end driver (the EXPERIMENTS.md validation run): trains logistic
//! regression with FD-SVRG on the paper-matched `webspam-sim` profile
//! (d=280k, N=6k) to the paper's 1e-4 gap target, logging the full loss
//! curve, communication counters and the final model quality — then
//! cross-checks the result against serial SVRG and the closed-form
//! communication formula of §4.5.
//!
//! ```sh
//! cargo run --release --example train_e2e [-- <profile> [q]]
//! ```

use fdsvrg::algs::{serial, Algorithm, Problem, RunParams};
use fdsvrg::data::profiles;
use fdsvrg::metrics::TextTable;
use std::path::Path;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let profile = args.first().map(|s| s.as_str()).unwrap_or("webspam-sim");
    let q: usize = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| profiles::paper_worker_count(profile));

    let ds = profiles::load(profile).expect("known dataset profile");
    let problem = Problem::logistic_l2(ds, 1e-4);
    println!(
        "== end-to-end: FD-SVRG on {profile} (d={}, N={}, q={q}, λ=1e-4) ==",
        problem.d(),
        problem.n()
    );

    // reference optimum for the gap axis (cached across runs)
    println!("solving reference optimum (cached under artifacts/optima)...");
    let (w_star, f_opt) = serial::cached_optimum(&problem, Path::new("artifacts/optima"), 60);
    println!("f* = {f_opt:.10}  (‖w*‖ = {:.4})", fdsvrg::linalg::nrm2(&w_star));

    let params = RunParams {
        q,
        outer: 40,
        gap_stop: Some((f_opt, 1e-5)),
        ..Default::default()
    };
    let res = Algorithm::FdSvrg.run(&problem, &params);

    let mut table =
        TextTable::new(vec!["epoch", "gap", "sim time (s)", "wall (s)", "Mscalars", "grads"]);
    for p in &res.trace.points {
        table.row(vec![
            format!("{}", p.outer),
            format!("{:.3e}", p.objective - f_opt),
            format!("{:.4}", p.sim_time),
            format!("{:.2}", p.wall_time),
            format!("{:.2}", p.scalars as f64 / 1e6),
            format!("{}", p.grads),
        ]);
    }
    println!("{}", table.render());

    // ---- validation block ----
    let epochs = res.trace.points.len() - 1;
    let expect_scalars =
        epochs as u64 * (2 * q as u64 * problem.n() as u64) * 2; // full-grad + inner
    println!("validation:");
    println!(
        "  comm counters: measured {} vs §4.5 closed form {} — {}",
        res.total_scalars,
        expect_scalars,
        if res.total_scalars == expect_scalars { "EXACT" } else { "MISMATCH" }
    );
    let t_gap = res.trace.time_to_gap(f_opt, 1e-4);
    println!(
        "  time to gap ≤ 1e-4: {} (sim)  |  total wall {:.2}s",
        t_gap.map(|t| format!("{t:.4}s")).unwrap_or_else(|| "not reached".into()),
        res.total_wall_time
    );
    println!(
        "  final gap {:.3e}, train accuracy {:.2}%",
        res.final_objective() - f_opt,
        100.0 * problem.accuracy(&res.w)
    );

    // distributed-vs-serial equivalence on a subsample of coordinates
    println!("  cross-check vs serial SVRG (same seed, same #epochs)...");
    let (w_serial, _) = serial::svrg(
        &problem,
        params.effective_eta(&problem),
        epochs,
        0,
        params.seed,
        serial::SvrgOption::I,
        None,
    );
    let dist = fdsvrg::linalg::dist2(&res.w, &w_serial);
    // Bitwise equality holds at q=1 (disjoint blocks, same arithmetic); for
    // q>1 the cross-block margin sum reassociates FP addition, so demand
    // agreement to accumulated-roundoff tolerance instead.
    let rel = dist / (1.0 + fdsvrg::linalg::nrm2(&w_serial).powi(2));
    println!(
        "  ‖w_fd − w_serial‖² = {dist:.3e} (relative {rel:.3e}) — {}",
        if dist == 0.0 { "BIT-IDENTICAL (paper §4.3 equivalence)" } else { "FP-reassociation noise only" }
    );
    assert!(rel < 1e-9, "FD-SVRG must reproduce serial SVRG (rel {rel:.3e})");
    if res.final_objective() - f_opt > 1e-4 {
        eprintln!("warning: gap target not reached within epoch budget");
    }
}
