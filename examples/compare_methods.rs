//! Head-to-head of every distributed method the paper evaluates —
//! FD-SVRG vs DSVRG vs SynSVRG vs AsySVRG vs PS-Lite(SGD) — on one
//! profile, reporting the three axes of Figures 6–7: simulated time,
//! communicated scalars, and the objective gap, plus the busiest-node
//! traffic that motivates decentralized designs (§3.2).
//!
//! ```sh
//! cargo run --release --example compare_methods [-- <profile> [q]]
//! ```

use fdsvrg::algs::{serial, Algorithm, Problem, RunParams};
use fdsvrg::data::profiles;
use fdsvrg::exp;
use fdsvrg::metrics::TextTable;
use std::path::Path;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let profile = args.first().map(|s| s.as_str()).unwrap_or("news20-sim");
    let q: usize = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| profiles::paper_worker_count(profile));

    let ds = profiles::load(profile).expect("known dataset profile");
    let problem = Problem::logistic_l2(ds, 1e-4);
    let (_, f_opt) = serial::cached_optimum(&problem, Path::new("artifacts/optima"), 60);
    println!(
        "== method comparison on {profile}: d={}, N={}, q={q}, f*={f_opt:.8} ==",
        problem.d(),
        problem.n()
    );

    let gap_target = 1e-4;
    let mut table = TextTable::new(vec![
        "method",
        "framework",
        "time→1e-4 (s)",
        "scalars→1e-4",
        "busiest node",
        "final gap",
    ]);

    let methods: &[(Algorithm, &str)] = &[
        (Algorithm::FdSvrg, "feature-distributed (tree)"),
        (Algorithm::Dsvrg, "decentralized ring"),
        (Algorithm::SynSvrg, "parameter server (4 srv)"),
        (Algorithm::AsySvrg, "parameter server (8 srv)"),
        (Algorithm::PsLiteSgd, "parameter server (8 srv)"),
    ];

    let mut fd_time = None;
    for &(algo, framework) in methods {
        let mut params = RunParams {
            q,
            outer: exp::default_epochs(algo),
            gap_stop: Some((f_opt, gap_target / 10.0)),
            ..Default::default()
        };
        match algo {
            Algorithm::SynSvrg => params.servers = 4, // paper §5.2
            Algorithm::AsySvrg | Algorithm::PsLiteSgd => params.servers = 8,
            _ => {}
        }
        // cap the SGD baseline the way the paper's Table 3 does (">1000s")
        if algo == Algorithm::PsLiteSgd {
            if let Some(t) = fd_time {
                params.sim_time_cap = Some(f64::max(50.0 * t, 1.0));
            }
        }
        let res = algo.run(&problem, &params);
        let tt = res.trace.time_to_gap(f_opt, gap_target);
        if algo == Algorithm::FdSvrg {
            fd_time = tt;
        }
        table.row(vec![
            algo.name().to_string(),
            framework.to_string(),
            tt.map(|t| format!("{t:.4}")).unwrap_or_else(|| format!(">{:.1}", res.total_sim_time)),
            res.trace
                .comm_to_gap(f_opt, gap_target)
                .map(|c| format!("{c}"))
                .unwrap_or_else(|| format!(">{}", res.total_scalars)),
            format!("{}", res.busiest_node_scalars),
            format!("{:.2e}", res.final_objective() - f_opt),
        ]);
    }
    println!("{}", table.render());
    println!(
        "reading guide: on d≫N profiles FD-SVRG should win both time and comm;\n\
         DSVRG is the strongest baseline (paper Table 2); PS-Lite(SGD) trails by\n\
         orders of magnitude (paper Table 3); the busiest-node column shows the\n\
         tree spreading load vs the PS hub."
    );
}
