//! Worker-scaling study (paper Fig. 9): FD-SVRG speedup vs q, plus the
//! mini-batch ablation of §4.4.1 (same scalar volume, fewer messages →
//! lower latency share) and the tree-vs-star reduce ablation (Fig. 5).
//!
//! ```sh
//! cargo run --release --example scaling [-- <profile>]
//! ```

use fdsvrg::algs::{serial, Algorithm, Problem, RunParams};
use fdsvrg::data::profiles;
use fdsvrg::metrics::TextTable;
use std::path::Path;

fn main() {
    let profile = std::env::args().nth(1).unwrap_or_else(|| "webspam-sim".into());
    let ds = profiles::load(&profile).expect("known dataset profile");
    let problem = Problem::logistic_l2(ds, 1e-4);
    let (_, f_opt) = serial::cached_optimum(&problem, Path::new("artifacts/optima"), 60);
    println!("== scaling study on {profile} (d={}, N={}) ==", problem.d(), problem.n());

    // ---- Fig. 9: speedup vs q ----
    let mut t1 = 0.0;
    let mut table = TextTable::new(vec!["q", "time→1e-4 (s)", "speedup", "ideal", "efficiency"]);
    for q in [1usize, 4, 8, 16] {
        let params = RunParams {
            q,
            outer: 40,
            gap_stop: Some((f_opt, 1e-5)),
            ..Default::default()
        };
        let res = Algorithm::FdSvrg.run(&problem, &params);
        let t = res.trace.time_to_gap(f_opt, 1e-4).unwrap_or(res.total_sim_time);
        if q == 1 {
            t1 = t;
        }
        let s = t1 / t;
        table.row(vec![
            format!("{q}"),
            format!("{t:.4}"),
            format!("{s:.2}×"),
            format!("{q}×"),
            format!("{:.0}%", 100.0 * s / q as f64),
        ]);
    }
    println!("-- Fig. 9: speedup vs worker count --\n{}", table.render());

    // ---- §4.4.1: mini-batch ablation at q=8 ----
    let mut table =
        TextTable::new(vec!["batch u", "messages", "scalars", "bytes", "sim time (s)"]);
    for u in [1usize, 4, 16, 64] {
        let params = RunParams { q: 8, outer: 4, batch: u, ..Default::default() };
        let res = Algorithm::FdSvrg.run(&problem, &params);
        // the wire layer counts messages exactly; the closed-form estimate
        // (2q per allreduce, one N-vector + ceil(M/u) batch reduces per
        // epoch) must agree with it
        debug_assert_eq!(res.total_messages, estimate_messages(problem.n(), 4, 8, u));
        table.row(vec![
            format!("{u}"),
            format!("{}", res.total_messages),
            format!("{}", res.total_scalars),
            format!("{}", res.total_bytes),
            format!("{:.4}", res.total_sim_time),
        ]);
    }
    println!(
        "-- §4.4.1: mini-batch (same volume, fewer messages, less α-latency) --\n{}",
        table.render()
    );

    // ---- Fig. 5 ablation: tree vs star reduce at q=16 ----
    let mut table =
        TextTable::new(vec!["reduce", "sim time (s)", "scalars", "busiest node", "result Δ²"]);
    let base = RunParams { q: 16, outer: 4, ..Default::default() };
    let tree = Algorithm::FdSvrg.run(&problem, &base);
    let star = Algorithm::FdSvrg.run(
        &problem,
        &RunParams { star_reduce: true, ..base.clone() },
    );
    let delta = fdsvrg::linalg::dist2(&tree.w, &star.w);
    for (name, res) in [("tree (Fig. 5)", &tree), ("star (naive)", &star)] {
        table.row(vec![
            name.to_string(),
            format!("{:.4}", res.total_sim_time),
            format!("{}", res.total_scalars),
            format!("{}", res.busiest_node_scalars),
            format!("{delta:.1e}"),
        ]);
    }
    println!("-- Fig. 5: tree vs star global sum (identical numerics, different load) --\n{}", table.render());
}

/// Messages per run: each allreduce over a binomial tree of q workers costs
/// 2q messages; an epoch does one N-vector reduce + ceil(M/u) batch reduces.
fn estimate_messages(n: usize, epochs: usize, q: usize, u: usize) -> u64 {
    let per_epoch = 2 * q as u64 * (1 + n.div_ceil(u)) as u64;
    epochs as u64 * per_epoch
}
