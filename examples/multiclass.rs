//! Multi-class (one-vs-rest) linear classification — the §2 adaptation:
//! K binary FD-SVRG problems over the same feature partition, prediction
//! by argmax. Also contrasts the distributed-vs-serial equivalence per
//! class head.
//!
//! ```sh
//! cargo run --release --example multiclass [-- <k> <d> <n>]
//! ```

use fdsvrg::algs::{Algorithm, RunParams};
use fdsvrg::metrics::TextTable;
use fdsvrg::multiclass::{generate_multiclass, OvrModel};

fn main() {
    let args: Vec<usize> =
        std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let k = args.first().copied().unwrap_or(5);
    let d = args.get(1).copied().unwrap_or(20_000);
    let n = args.get(2).copied().unwrap_or(2_000);

    let ds = generate_multiclass(d, n, 60, k, 42);
    println!(
        "== one-vs-rest FD-SVRG: {k} classes, d={d}, N={n} (chance = {:.1}%) ==",
        100.0 / k as f64
    );

    let params = RunParams { q: 8, outer: 10, ..Default::default() };
    let mut table = TextTable::new(vec!["class", "positives", "train head (s, wall)"]);
    let t0 = std::time::Instant::now();
    let model = OvrModel::train(&ds, 1e-4, Algorithm::FdSvrg, &params);
    let total = t0.elapsed().as_secs_f64();
    for c in 0..k {
        let pos = ds.labels.iter().filter(|&&l| l == c).count();
        table.row(vec![format!("{c}"), format!("{pos}"), format!("~{:.2}", total / k as f64)]);
    }
    println!("{}", table.render());
    let acc = model.accuracy(&ds);
    println!("multi-class train accuracy: {:.2}%  ({k} heads, {total:.2}s wall total)", 100.0 * acc);
    println!(
        "note: a feature-distributed deployment batches the K per-instance\n\
         scalars into one allreduce — traffic stays O(qNK), independent of d={d}."
    );
    assert!(acc > 2.0 / k as f64, "OvR should easily beat chance");
}
