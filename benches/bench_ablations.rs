//! Ablation benches for the design choices DESIGN.md §4 calls out:
//!
//! 1. mini-batch size u (§4.4.1): same scalar volume, fewer messages —
//!    how much simulated time does the α-latency share cost at u=1?
//! 2. tree vs star reduce (Fig. 5): identical numerics and volume,
//!    different busiest-node load and latency depth.
//! 3. SVRG Option I vs Option II (Appendix A): convergence of the variant
//!    Theorem 1 newly proves vs the Johnson–Zhang analyzed one.
//! 4. network sensitivity: SimParams α/β sweep — where does the
//!    tree's log₂(q) depth matter?
//! 5. wire formats (`--wire`): f64 vs f32 vs sparse payload codecs,
//!    objective gap vs bytes on the wire (see `exp::wire_ablation`).
//! 6. network models (`--net`): uniform vs cross-rack/straggler/jitter
//!    scenarios — gap vs simulated time + per-node clock skew
//!    (see `exp::netmodel_ablation`).
//!
//! ```sh
//! cargo bench --bench bench_ablations [-- <filter>]
//! ```

use fdsvrg::algs::{serial, Algorithm, Problem, RunParams};
use fdsvrg::bench::Bench;
use fdsvrg::data::profiles;
use fdsvrg::exp;
use fdsvrg::metrics::TextTable;
use fdsvrg::net::SimParams;
use std::path::Path;

fn main() {
    let mut b = Bench::from_args("ablations");
    std::fs::create_dir_all("results").ok();
    let ds = profiles::load("news20-sim").expect("profile");
    let problem = Problem::logistic_l2(ds, 1e-4);
    let (_, f_opt) = serial::cached_optimum(&problem, Path::new("artifacts/optima"), 60);

    // --- 1. mini-batch sweep ---
    b.once("ablation/minibatch u sweep", || {
        let mut table =
            TextTable::new(vec!["u", "sim time (s)", "scalars", "time→1e-4 (s)"]);
        for u in [1usize, 4, 16, 64, 256] {
            let params = RunParams {
                q: 8,
                outer: 12,
                batch: u,
                gap_stop: Some((f_opt, 1e-5)),
                ..Default::default()
            };
            let res = Algorithm::FdSvrg.run(&problem, &params);
            table.row(vec![
                format!("{u}"),
                format!("{:.4}", res.total_sim_time),
                format!("{}", res.total_scalars),
                res.trace
                    .time_to_gap(f_opt, 1e-4)
                    .map(|t| format!("{t:.4}"))
                    .unwrap_or_else(|| ">cap".into()),
            ]);
        }
        println!("== ablation: mini-batch size (same volume, fewer messages) ==\n{}", table.render());
    });

    // --- 2. tree vs star ---
    b.once("ablation/tree vs star reduce", || {
        let mut table = TextTable::new(vec![
            "reduce", "q", "sim time (s)", "busiest node", "total scalars",
        ]);
        for q in [4usize, 8, 16] {
            for star in [false, true] {
                let params = RunParams {
                    q,
                    outer: 6,
                    star_reduce: star,
                    gap_stop: Some((f_opt, 1e-5)),
                    ..Default::default()
                };
                let res = Algorithm::FdSvrg.run(&problem, &params);
                table.row(vec![
                    (if star { "star" } else { "tree" }).to_string(),
                    format!("{q}"),
                    format!("{:.4}", res.total_sim_time),
                    format!("{}", res.busiest_node_scalars),
                    format!("{}", res.total_scalars),
                ]);
            }
        }
        println!("== ablation: Fig.-5 tree vs naive star ==\n{}", table.render());
    });

    // --- 3. Option I vs Option II ---
    b.once("ablation/svrg option I vs II", || {
        let eta = problem.default_eta();
        let mut table = TextTable::new(vec!["option", "epoch", "gap"]);
        for (name, opt) in
            [("I (Thm 1)", serial::SvrgOption::I), ("II (J&Z)", serial::SvrgOption::II)]
        {
            let (_, trace) = serial::svrg(&problem, eta, 8, 0, 42, opt, None);
            for p in trace.points.iter().step_by(2) {
                table.row(vec![
                    name.to_string(),
                    format!("{}", p.outer),
                    format!("{:.3e}", p.objective - f_opt),
                ]);
            }
        }
        println!("== ablation: SVRG snapshot rule (both converge linearly) ==\n{}", table.render());
    });

    // --- FD family: SVRG vs SAGA vs SGD on the same feature partition ---
    b.once("ablation/fd family svrg-saga-sgd", || {
        let mut table = TextTable::new(vec![
            "variant", "epochs", "final gap", "sim time (s)", "scalars/epoch",
        ]);
        for algo in [Algorithm::FdSvrg, Algorithm::FdSaga, Algorithm::FdSgd] {
            let params = RunParams {
                q: 8,
                outer: 30,
                batch: 100,
                gap_stop: Some((f_opt, 1e-5)),
                ..Default::default()
            };
            let res = algo.run(&problem, &params);
            let epochs = (res.trace.points.len() - 1).max(1);
            table.row(vec![
                algo.name().to_string(),
                format!("{epochs}"),
                format!("{:.2e}", res.final_objective() - f_opt),
                format!("{:.4}", res.total_sim_time),
                format!("{}", res.total_scalars / epochs as u64),
            ]);
        }
        println!(
            "== ablation: feature-distributed family (SAGA halves the volume,\n\
             SGD stalls at a loose gap — the §1 'other variants' claim) ==\n{}",
            table.render()
        );
    });

    // --- §Perf: lazy vs naive inner loop (wall time of the real compute) ---
    b.once("ablation/lazy vs naive inner loop", || {
        let mut table =
            TextTable::new(vec!["inner loop", "wall (s)", "sim (s)", "final gap"]);
        for lazy in [false, true] {
            let params = RunParams {
                q: 8,
                outer: 6,
                lazy,
                gap_stop: Some((f_opt, 1e-6)),
                ..Default::default()
            };
            let res = Algorithm::FdSvrg.run(&problem, &params);
            table.row(vec![
                (if lazy { "lazy αv+γz (§Perf)" } else { "naive O(d_l)/step" }).to_string(),
                format!("{:.3}", res.total_wall_time),
                format!("{:.4}", res.total_sim_time),
                format!("{:.2e}", res.final_objective() - f_opt),
            ]);
        }
        println!("== §Perf ablation: FD-SVRG inner-loop implementation ==\n{}", table.render());
    });

    // --- 4. network-parameter sensitivity ---
    b.once("ablation/network alpha-beta sweep", || {
        let mut table = TextTable::new(vec![
            "α (µs)", "GB/s", "tree time (s)", "star time (s)", "tree/star",
        ]);
        for (alpha_us, gbps) in [(5.0, 40.0), (40.0, 10.0), (500.0, 1.0)] {
            let sim = SimParams {
                latency: alpha_us * 1e-6,
                sec_per_byte: 8.0 / (gbps * 1e9), // gbps link, charged per byte
                ..SimParams::default()
            };
            let mut t = [0.0f64; 2];
            for (k, star) in [false, true].iter().enumerate() {
                let params = RunParams {
                    q: 16,
                    outer: 4,
                    star_reduce: *star,
                    sim,
                    ..Default::default()
                };
                t[k] = Algorithm::FdSvrg.run(&problem, &params).total_sim_time;
            }
            table.row(vec![
                format!("{alpha_us}"),
                format!("{gbps}"),
                format!("{:.4}", t[0]),
                format!("{:.4}", t[1]),
                format!("{:.2}", t[0] / t[1]),
            ]);
        }
        println!("== ablation: network cost model sensitivity ==\n{}", table.render());
    });

    // --- 5. wire formats: payload codec sweep on url-sim/news20-sim ---
    b.once("ablation/wire formats", || {
        let ctx = exp::Ctx::bench(Path::new("results"));
        exp::wire_ablation(&ctx).expect("wire ablation run");
    });

    // --- 6. network models: FD-SVRG vs the PS baselines under uniform /
    // cross-rack / straggler / jitter scenarios (see exp::netmodel_ablation)
    b.once("ablation/network models", || {
        let ctx = exp::Ctx::bench(Path::new("results"));
        exp::netmodel_ablation(&ctx).expect("netmodel ablation run");
    });

    b.finish();
}
