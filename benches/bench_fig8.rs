//! Regenerates paper Figure 8: webspam with λ ∈ {1e-3, 1e-5} — the
//! regularization-sensitivity check (FD-SVRG must stay the fastest under
//! both better and worse conditioning).
//!
//! ```sh
//! cargo bench --bench bench_fig8
//! ```

use fdsvrg::bench::Bench;
use fdsvrg::exp;
use std::path::Path;

fn main() {
    let mut b = Bench::from_args("fig8");
    let ctx = exp::Ctx::bench(Path::new("results"));
    std::fs::create_dir_all("results").ok();
    b.once("fig8/webspam lambda sweep", || {
        exp::fig8(&ctx).expect("fig8 run");
    });
    b.finish();
}
