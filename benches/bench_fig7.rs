//! Regenerates paper Figure 7: objective gap vs communicated scalars for
//! the same grid as Figure 6. The trace CSVs carry both axes, so this
//! bench re-runs the grid and reports the communication crossings (the
//! quantity Figure 7 plots on x).
//!
//! ```sh
//! cargo bench --bench bench_fig7 [-- <dataset-filter>]
//! ```

use fdsvrg::algs::Algorithm;
use fdsvrg::bench::Bench;
use fdsvrg::exp;
use fdsvrg::metrics::TextTable;
use std::path::Path;

fn main() {
    let mut b = Bench::from_args("fig7");
    let ctx = exp::Ctx::bench(Path::new("results"));
    std::fs::create_dir_all("results").ok();
    for (profile, q) in exp::paper_grid() {
        b.once(&format!("fig7/{profile}"), || {
            let problem = ctx.problem(profile, ctx.cfg.lambda).expect("profile");
            let (_, f_opt) = ctx.optimum(&problem);
            let mut table =
                TextTable::new(vec!["algorithm", "scalars→1e-3", "scalars→1e-4", "total scalars"]);
            for algo in Algorithm::ALL_DISTRIBUTED {
                let mut params = ctx.cfg.run_params();
                params.q = q;
                let ps = matches!(algo, Algorithm::SynSvrg | Algorithm::AsySvrg);
                params.outer = if ps {
                    ((exp::default_epochs(algo) as f64) * ctx.ps_scale).round() as usize
                } else {
                    exp::default_epochs(algo)
                };
                params.gap_stop = Some((f_opt, ctx.cfg.gap_target / 10.0));
                let res = algo.run(&problem, &params);
                res.trace
                    .write_csv(
                        Path::new("results").join(format!("fig7_{profile}_{}.csv", algo.name())),
                        f_opt,
                    )
                    .ok();
                let fmt = |c: Option<u64>, total: u64| {
                    c.map(|c| format!("{c}")).unwrap_or_else(|| format!(">{total}"))
                };
                table.row(vec![
                    algo.name().to_string(),
                    fmt(res.trace.comm_to_gap(f_opt, 1e-3), res.total_scalars),
                    fmt(res.trace.comm_to_gap(f_opt, 1e-4), res.total_scalars),
                    format!("{}", res.total_scalars),
                ]);
            }
            println!("== Fig 7 :: {profile} (q={q}) — gap vs scalars ==\n{}", table.render());
        });
    }
    b.finish();
}
