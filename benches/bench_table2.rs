//! Regenerates paper Table 2: time-to-gap ≤ 1e-4 for DSVRG vs FD-SVRG on
//! all four dataset profiles, with the speedup row. Expected shape:
//! FD-SVRG wins everywhere, with the largest factors on the biggest /
//! most feature-heavy profiles (paper: 4.16× → 29.9×).
//!
//! ```sh
//! cargo bench --bench bench_table2
//! ```

use fdsvrg::bench::Bench;
use fdsvrg::exp;
use std::path::Path;

fn main() {
    let mut b = Bench::from_args("table2");
    let ctx = exp::Ctx::bench(Path::new("results"));
    std::fs::create_dir_all("results").ok();
    b.once("table2/dsvrg vs fdsvrg", || {
        let rows = exp::table2(&ctx).expect("table2 run");
        for (ds, t_dsvrg, t_fd) in &rows {
            assert!(
                t_fd < t_dsvrg,
                "{ds}: FD-SVRG ({t_fd:.3}s) must beat DSVRG ({t_dsvrg:.3}s)"
            );
        }
    });
    b.finish();
}
