//! Regenerates paper Table 3: time-to-gap ≤ 1e-4 for PS-Lite-style
//! asynchronous SGD vs FD-SVRG. Expected shape: SGD either needs orders of
//! magnitude longer or never reaches the target within the cap (the
//! paper's ">1000s" rows) — speedups in the 10²–10³ range.
//!
//! ```sh
//! cargo bench --bench bench_table3
//! ```

use fdsvrg::bench::Bench;
use fdsvrg::exp;
use std::path::Path;

fn main() {
    let mut b = Bench::from_args("table3");
    let ctx = exp::Ctx::bench(Path::new("results"));
    std::fs::create_dir_all("results").ok();
    b.once("table3/pslite-sgd vs fdsvrg", || {
        let rows = exp::table3(&ctx).expect("table3 run");
        for (ds, t_sgd, t_fd) in &rows {
            // SGD must be at least an order of magnitude slower (or capped)
            if let Some(t) = t_sgd {
                assert!(
                    *t > 10.0 * t_fd,
                    "{ds}: PS-Lite(SGD) {t:.3}s should trail FD-SVRG {t_fd:.3}s by ≥10×"
                );
            }
        }
    });
    b.finish();
}
