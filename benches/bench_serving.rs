//! Serving-plane microbenchmarks: the predict batch-path churn pair
//! (fresh allocation per call vs `Workspace` reuse — satellite of the
//! sharded inference plane), the shard partial-margin kernel on the exact
//! f64 slab vs the f32-quantized snapshot, and two single-shot closed-loop
//! serving sims pinning that batching beats batch=1 on simulated
//! throughput.
//!
//! A full (unfiltered) run writes `BENCH_serving_micro.json` in the
//! working directory — a different file from the `exp serving` report
//! (`BENCH_serving.json`), which carries the latency/throughput grid.
//!
//! ```text
//! cargo bench --bench bench_serving             # full sweep + JSON
//! cargo bench --bench bench_serving -- churn    # predict pair (CI smoke)
//! ```

use fdsvrg::bench::Bench;
use fdsvrg::config::ExperimentConfig;
use fdsvrg::data::profiles;
use fdsvrg::serve::{
    dense_margins, simulate, ArrivalMode, BatchPolicy, QuerySource, ServeSpec, ShardServer,
};
use fdsvrg::util::Pcg64;

fn main() {
    let mut b = Bench::from_args("bench_serving");
    let ds = profiles::load("tiny").expect("tiny profile");
    let (d, n) = (ds.d(), ds.x.cols());
    let mut rng = Pcg64::seed_from_u64(9);
    let w: Vec<f64> = (0..d).map(|_| rng.normal()).collect();

    // predict batch path: margins with a fresh allocation per call vs the
    // reused Workspace buffer — same arithmetic, bit-equal outputs
    let mut before = Vec::new();
    b.bench("churn predict alloc-per-call (before)", || {
        let mut margins = vec![0.0f64; n];
        for (i, m) in margins.iter_mut().enumerate() {
            *m = ds.x.col_dot(i, &w);
        }
        std::hint::black_box(&margins);
        before = margins;
    });
    let mut buf = Vec::new();
    b.bench("churn predict workspace-reuse (after)", || {
        let margins = dense_margins(&ds.x, &w, &mut buf);
        std::hint::black_box(margins);
    });
    if b.enabled("churn predict alloc-per-call (before)")
        && b.enabled("churn predict workspace-reuse (after)")
    {
        let after = dense_margins(&ds.x, &w, &mut buf);
        assert_eq!(before.len(), after.len());
        for (x, y) in before.iter().zip(after) {
            assert_eq!(x.to_bits(), y.to_bits(), "reused path diverged from alloc path");
        }
    }

    // shard partial-margin kernel: exact f64 slab vs f32-quantized snapshot
    let exact = ShardServer::from_snapshot(&w, 0, d, false);
    let quant = ShardServer::from_snapshot(&w, 0, d, true);
    let qidx: Vec<u32> = (0..d as u32).step_by(3).collect();
    let qval: Vec<f64> = qidx.iter().map(|_| rng.normal()).collect();
    b.bench("shard partial f64", || {
        std::hint::black_box(exact.partial_margin(&qidx, &qval));
    });
    b.bench("shard partial f32-quantized", || {
        std::hint::black_box(quant.partial_margin(&qidx, &qval));
    });

    // closed-loop serving sims (single-shot: each drives 2000 queries
    // through a 5-node sim cluster); the in-sim throughput ordering is a
    // correctness pin, not just a number
    let cfg = ExperimentConfig::default();
    let model = cfg.net_spec_for("uniform").unwrap().resolve(cfg.sim_params());
    let source = QuerySource::Synthetic { d, nnz: 8 };
    let sim = |max_batch: usize| {
        simulate(&ServeSpec {
            w: &w,
            bounds: vec![(0, d / 2), (d / 2, d)],
            model: model.clone(),
            wire: fdsvrg::net::WireFmt::F64,
            policy: BatchPolicy { max_batch, max_delay: 200e-6 },
            queries: 2_000,
            mode: ArrivalMode::Closed { concurrency: 64 },
            seed: 5,
            source: source.clone(),
            collect_margins: false,
            robust: Default::default(),
        })
        .expect("serve sim")
        .report
    };
    let mut qps = (0.0f64, 0.0f64);
    b.once("serve sim batch=1", || {
        qps.0 = sim(1).qps;
    });
    b.once("serve sim batch=32", || {
        qps.1 = sim(32).qps;
    });
    if b.enabled("serve sim batch=1") && b.enabled("serve sim batch=32") {
        assert!(
            qps.1 > qps.0,
            "batch=32 ({:.0} qps) should beat batch=1 ({:.0} qps) in-sim",
            qps.1,
            qps.0
        );
        println!("in-sim qps: batch=1 {:.0}, batch=32 {:.0} ({:.2}x)", qps.0, qps.1, qps.1 / qps.0);
    }

    if !b.is_filtered() {
        let note = "serving-plane microbench baseline; regenerate from the repo \
                    root with `cargo bench --bench bench_serving`";
        let path = b.json_path().unwrap_or("BENCH_serving_micro.json");
        b.write_json(path, note).unwrap_or_else(|e| panic!("write {path}: {e}"));
        println!("baseline written to {path}");
    } else if let Some(path) = b.json_path() {
        let note = "partial (filtered) bench_serving run";
        b.write_json(path, note).unwrap_or_else(|e| panic!("write {path}: {e}"));
        println!("filtered report written to {path}");
    }
    b.finish();
}
