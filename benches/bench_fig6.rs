//! Regenerates paper Figure 6: objective gap vs wall-clock (simulated
//! cluster) time for {FD-SVRG, DSVRG, SynSVRG, AsySVRG} on the four
//! dataset profiles, λ = 1e-4. Series CSVs land in `results/`.
//!
//! ```sh
//! cargo bench --bench bench_fig6            # all four datasets
//! cargo bench --bench bench_fig6 -- news20  # one dataset
//! ```

use fdsvrg::bench::Bench;
use fdsvrg::exp;
use std::path::Path;

fn main() {
    let mut b = Bench::from_args("fig6");
    let ctx = exp::Ctx::bench(Path::new("results"));
    std::fs::create_dir_all("results").ok();
    for (profile, q) in exp::paper_grid() {
        b.once(&format!("fig6/{profile}"), || {
            exp::fig6_fig7(&ctx, &[(profile, q)]).expect("fig6 run");
        });
    }
    b.finish();
}
