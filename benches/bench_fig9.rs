//! Regenerates paper Figure 9: FD-SVRG speedup vs worker count
//! q ∈ {1, 4, 8, 16} on webspam-sim, measured at the 1e-4 gap target.
//! Expected shape: near-ideal (the paper reports close-to-linear scaling).
//!
//! ```sh
//! cargo bench --bench bench_fig9
//! ```

use fdsvrg::bench::Bench;
use fdsvrg::exp;
use std::path::Path;

fn main() {
    let mut b = Bench::from_args("fig9");
    let ctx = exp::Ctx::bench(Path::new("results"));
    std::fs::create_dir_all("results").ok();
    b.once("fig9/speedup q in {1,4,8,16}", || {
        let speedups = exp::fig9(&ctx).expect("fig9 run");
        // sanity: speedup must grow with q
        for w in speedups.windows(2) {
            assert!(
                w[1].1 > w[0].1 * 0.9,
                "speedup should not collapse: {:?}",
                speedups
            );
        }
    });
    b.finish();
}
