//! Micro-benchmarks of the L3 hot path primitives: CSC column kernels,
//! dense axpy/axpby, the tree allreduce, and one FD-SVRG inner epoch.
//! This is the §Perf profiling anchor — run `cargo bench micro` before and
//! after every hot-path change.

use fdsvrg::algs::{Algorithm, Problem, RunParams};
use fdsvrg::bench::Bench;
use fdsvrg::data::{generate, GenSpec};
use fdsvrg::net::collectives;
use fdsvrg::net::topology::tree_allreduce;
use fdsvrg::net::{build, tags, Endpoint, NodeId, SimParams, WireFmt};
use fdsvrg::util::Pcg64;

/// The pre-payload broadcast: one deep copy of the full vector per child
/// send (what `tree_broadcast` did before `Arc` payloads). Kept here as
/// the baseline half of the zero-copy before/after comparison.
fn clone_per_hop_broadcast(ep: &mut Endpoint, group: &[NodeId], data: &mut Vec<f64>) {
    let rank = group.iter().position(|&n| n == ep.id()).expect("node not in group");
    let q = group.len();
    let mut mask = 1usize;
    while mask < q {
        mask <<= 1;
    }
    mask >>= 1;
    let mut received = rank == 0;
    while mask >= 1 {
        if rank & (mask - 1) == 0 {
            if !received && rank & mask != 0 {
                let msg = ep.recv_from(group[rank - mask], tags::BCAST);
                msg.payload.decode_resize(data);
                received = true;
            } else if received && rank & mask == 0 && rank + mask < q {
                // fresh encode per child — the per-hop O(d) deep copy
                ep.send(group[rank + mask], tags::BCAST, WireFmt::F64.encode(data));
            }
        }
        if mask == 1 {
            break;
        }
        mask >>= 1;
    }
}

fn clone_per_hop_allreduce(ep: &mut Endpoint, group: &[NodeId], data: &mut Vec<f64>) {
    collectives::tree_reduce(ep, group, data, WireFmt::F64);
    clone_per_hop_broadcast(ep, group, data);
}

fn main() {
    let mut b = Bench::from_args("micro").with_iters(3, 10);

    // --- sparse kernels on a webspam-sim-like slab ---
    let ds = generate(&GenSpec::new("micro", 50_000, 2_000, 200).with_seed(2));
    let x = &ds.x;
    let mut rng = Pcg64::seed_from_u64(1);
    let w: Vec<f64> = (0..ds.d()).map(|_| rng.normal()).collect();
    let mut out_n = vec![0.0f64; ds.n()];
    let mut out_d = vec![0.0f64; ds.d()];

    b.bench("csc/transpose_matvec (Dᵀw, 2k inst × 200nnz)", || {
        x.transpose_matvec(&w, &mut out_n);
        std::hint::black_box(&out_n);
    });
    b.bench("csc/col_dot x2000", || {
        let mut acc = 0.0;
        for i in 0..ds.n() {
            acc += x.col_dot(i, &w);
        }
        std::hint::black_box(acc);
    });
    b.bench("csc/col_axpy x2000 (gradient scatter)", || {
        out_d.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..ds.n() {
            x.col_axpy(i, 1e-3, &mut out_d);
        }
        std::hint::black_box(&out_d);
    });

    // --- dense inner-loop update: the w̃ ← (1-ηλ)w̃ − ηz step ---
    let z: Vec<f64> = (0..ds.d()).map(|_| rng.normal()).collect();
    let mut wd = w.clone();
    b.bench("linalg/axpby 50k (dense SVRG step)", || {
        fdsvrg::linalg::axpby(-1e-3, &z, 1.0 - 1e-7, &mut wd);
        std::hint::black_box(&wd);
    });

    // --- tree allreduce of 1 scalar and of an N-vector, q=16 ---
    for (tag, len) in [("scalar", 1usize), ("N-vector(2k)", 2_000)] {
        b.bench(&format!("net/tree_allreduce q=16 {tag}"), || {
            let (mut eps, _) = build(17, SimParams::free());
            let group: Vec<usize> = (0..17).collect();
            std::thread::scope(|s| {
                for ep in eps.iter_mut() {
                    let group = group.clone();
                    s.spawn(move || {
                        let mut data = vec![1.0f64; len];
                        tree_allreduce(ep, &group, &mut data);
                        std::hint::black_box(&data);
                    });
                }
            });
        });
    }

    // --- zero-copy broadcast before/after: d = 1M allreduce, q ∈ {8, 32}.
    // "clone-per-hop" re-encodes the 8 MB payload for every child send
    // (the pre-payload wire); "zero-copy" is the production path — the
    // root encodes once and every hop forwards the same Arc buffer.
    for q in [8usize, 32] {
        let d = 1_000_000usize;
        b.bench(&format!("net/allreduce d=1M q={q} clone-per-hop (before)"), || {
            let (mut eps, _) = build(q + 1, SimParams::free());
            let group: Vec<usize> = (0..=q).collect();
            std::thread::scope(|s| {
                for ep in eps.iter_mut() {
                    let group = group.clone();
                    s.spawn(move || {
                        let mut data = vec![1.0f64; d];
                        clone_per_hop_allreduce(ep, &group, &mut data);
                        std::hint::black_box(&data);
                    });
                }
            });
        });
        b.bench(&format!("net/allreduce d=1M q={q} zero-copy (after)"), || {
            let (mut eps, _) = build(q + 1, SimParams::free());
            let group: Vec<usize> = (0..=q).collect();
            std::thread::scope(|s| {
                for ep in eps.iter_mut() {
                    let group = group.clone();
                    s.spawn(move || {
                        let mut data = vec![1.0f64; d];
                        tree_allreduce(ep, &group, &mut data);
                        std::hint::black_box(&data);
                    });
                }
            });
        });
    }

    // --- endpoint send/recv hot path: uniform-model dispatch pin ---
    // PR 4 hoisted all time-charging into net::model::LinkView; under the
    // default uniform model this adds one per-peer table lookup to every
    // send/recv. This 1-scalar ping-pong isolates the per-message endpoint
    // overhead so any dispatch regression shows up here (the d=1M
    // zero-copy cases above pin the bandwidth path — together they are the
    // before/after guard for the PR 2 zero-copy numbers).
    b.bench("net/endpoint ping-pong 1-scalar x1000 (uniform model)", || {
        let (mut eps, _) = build(2, SimParams::default());
        let mut b1 = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        std::thread::scope(|s| {
            s.spawn(move || {
                for _ in 0..1000 {
                    a.send(1, tags::PUSH, vec![1.0]);
                    a.recv_from(1, tags::PULL_RESP);
                }
            });
            s.spawn(move || {
                for _ in 0..1000 {
                    let m = b1.recv_from(0, tags::PUSH);
                    b1.send(0, tags::PULL_RESP, m.to_vec(1));
                }
            });
        });
    });

    // --- one full FD-SVRG epoch, wall-clock (q=8, tiny) ---
    let ds = generate(&GenSpec::new("epoch", 20_000, 1_000, 100).with_seed(3));
    let problem = Problem::logistic_l2(ds, 1e-4);
    b.bench("fdsvrg/one epoch (d=20k, N=1k, q=8)", || {
        let params = RunParams { q: 8, outer: 1, sim: SimParams::free(), ..Default::default() };
        let res = Algorithm::FdSvrg.run(&problem, &params);
        std::hint::black_box(res.total_scalars);
    });

    b.finish();
}
