//! Sparse-kernel engine benchmark: the threads sweep for the two
//! wall-clock-dominant kernels (`Dᵀw` partial products, `Dc` gradient
//! aggregation) at d ∈ {100k, 1M}, plus the epoch-buffer allocation-churn
//! before/after pair.
//!
//! A full (unfiltered) run rewrites `BENCH_kernels.json` in the working
//! directory — commit it from the repo root to refresh the perf-trajectory
//! baseline. Every timed case is also checked bit-identical against the
//! serial kernel, so a correctness regression cannot hide behind a good
//! number.
//!
//! ```text
//! cargo bench --bench bench_kernels             # full sweep + JSON
//! cargo bench --bench bench_kernels -- churn    # smallest case (CI smoke)
//! ```

use fdsvrg::algs::Workspace;
use fdsvrg::bench::Bench;
use fdsvrg::data::{generate, GenSpec};
use fdsvrg::sparse::CscMatrix;
use fdsvrg::util::{Pcg64, Pool};

const THREADS: [usize; 4] = [1, 2, 4, 8];

/// Would any kernel entry of this matrix run under the active filter?
/// (Guards the expensive dataset generation + reference passes when the
/// bench is invoked filtered, e.g. the CI churn smoke.)
fn tag_enabled(b: &Bench, tag: &str) -> bool {
    THREADS
        .iter()
        .any(|k| b.enabled(&format!("DTw {tag} k={k}")) || b.enabled(&format!("Dc {tag} k={k}")))
}

fn bench_matrix(b: &mut Bench, tag: &str, x: &CscMatrix) {
    let d = x.rows();
    let n = x.cols();
    let mut rng = Pcg64::seed_from_u64(3);
    let w: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let c: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let inv_n = 1.0 / n as f64;

    // serial references (bit-exactness oracle for every pool width)
    let mut dtw_ref = vec![0.0f64; n];
    x.transpose_matvec(&w, &mut dtw_ref);
    let mut dc_ref = vec![0.0f64; d];
    x.matvec_accumulate_scaled(&c, inv_n, &mut dc_ref);
    x.ensure_mirror(); // off the timed path, as the drivers do

    for k in THREADS {
        let pool = Pool::new(k);
        let mut out_n = vec![0.0f64; n];
        b.bench(&format!("DTw {tag} k={k}"), || {
            x.transpose_matvec_pool(&w, &mut out_n, &pool);
            std::hint::black_box(&out_n);
        });
        // the closure only ran if the entry passed the filter — never
        // compare a buffer a skipped entry left untouched
        if b.enabled(&format!("DTw {tag} k={k}")) {
            assert_eq!(out_n, dtw_ref, "DTw {tag} k={k} diverged from serial");
        }

        let mut out_d = vec![0.0f64; d];
        b.bench(&format!("Dc {tag} k={k}"), || {
            out_d.iter_mut().for_each(|v| *v = 0.0);
            x.matvec_accumulate_scaled_pool(&c, inv_n, &mut out_d, &pool);
            std::hint::black_box(&out_d);
        });
        if b.enabled(&format!("Dc {tag} k={k}")) {
            assert_eq!(out_d, dc_ref, "Dc {tag} k={k} diverged from serial");
        }
    }
}

fn main() {
    let mut b = Bench::from_args("kernels").with_iters(2, 7);

    // d = 100k: ~200k nnz (2k instances x ~100 nnz)
    if tag_enabled(&b, "d=100k") {
        let small = generate(&GenSpec::new("k100k", 100_000, 2_000, 100).with_seed(11));
        bench_matrix(&mut b, "d=100k", &small.x);
    }

    // d = 1M: ~800k nnz (4k instances x ~200 nnz) — the acceptance case:
    // DTw at k=4 must come in >= 2x faster than k=1
    if tag_enabled(&b, "d=1M") {
        let big = generate(&GenSpec::new("k1m", 1_000_000, 4_000, 200).with_seed(12));
        bench_matrix(&mut b, "d=1M", &big.x);
    }

    // epoch-buffer allocation churn: what every epoch loop used to do
    // (fresh margins vector + a fresh partial vector per inner batch)
    // vs the Workspace reuse all drivers run now
    let n = 50_000usize;
    let batches = 200usize;
    let u = 100usize;
    b.bench("churn alloc-per-epoch (before)", || {
        let mut margins = vec![0.0f64; n];
        margins[7] = 1.0;
        std::hint::black_box(&margins);
        for _ in 0..batches {
            let mut partial = vec![0.0f64; u];
            partial[3] = 1.0;
            std::hint::black_box(&partial);
        }
    });
    let mut ws = Workspace::new(1);
    b.bench("churn workspace-reuse (after)", || {
        Workspace::reset(&mut ws.margins, n);
        ws.margins[7] = 1.0;
        std::hint::black_box(&ws.margins);
        for _ in 0..batches {
            Workspace::reset(&mut ws.partial, u);
            ws.partial[3] = 1.0;
            std::hint::black_box(&ws.partial);
        }
    });

    // speedup readout + baseline persistence (full runs only: a filtered
    // run must not overwrite the committed baseline with a partial one)
    if !b.is_filtered() {
        let mean = |name: &str| {
            b.samples()
                .iter()
                .find(|s| s.name == name)
                .map(|s| s.mean_s)
                .expect("entry present in a full run")
        };
        for tag in ["d=100k", "d=1M"] {
            for kernel in ["DTw", "Dc"] {
                let s1 = mean(&format!("{kernel} {tag} k=1"));
                let s4 = mean(&format!("{kernel} {tag} k=4"));
                println!("{kernel} {tag}: k=4 speedup {:.2}x", s1 / s4);
            }
        }
        let note = "sparse-kernel engine baseline; regenerate from the repo root \
                    with `cargo bench --bench bench_kernels`";
        b.write_json("BENCH_kernels.json", note).expect("write BENCH_kernels.json");
        println!("baseline written to BENCH_kernels.json");
    }
    b.finish();
}
