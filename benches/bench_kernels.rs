//! Sparse-kernel engine benchmark: the threads sweep for the two
//! wall-clock-dominant kernels (`Dᵀw` partial products, `Dc` gradient
//! aggregation) at d ∈ {100k, 1M} — exact serial-chain and `--simd`
//! multi-lane variants side by side — plus the mixed-precision engine's
//! error-vs-speed pair and the epoch-buffer allocation-churn pair.
//!
//! A full (unfiltered) run rewrites `BENCH_kernels.json` in the working
//! directory — commit it from the repo root to refresh the perf-trajectory
//! baseline; `-- --json <path>` redirects the report (any run, filtered or
//! not) without touching the committed file. Every timed case is also
//! checked against the serial kernel — bit-identical for the exact pool
//! kernels, documented tolerance for the reassociating simd lanes — so a
//! correctness regression cannot hide behind a good number.
//!
//! ```text
//! cargo bench --bench bench_kernels             # full sweep + JSON
//! cargo bench --bench bench_kernels -- churn    # smallest case (CI smoke)
//! cargo bench --bench bench_kernels -- simd     # multi-lane kernels only
//! ```

use fdsvrg::algs::Workspace;
use fdsvrg::bench::Bench;
use fdsvrg::data::{generate, GenSpec};
use fdsvrg::runtime::{ComputeEngine, MixedEngine, BLOCK_D, BLOCK_N};
use fdsvrg::sparse::CscMatrix;
use fdsvrg::util::{Pcg64, Pool};

const THREADS: [usize; 4] = [1, 2, 4, 8];

/// Would any kernel entry of this matrix run under the active filter?
/// (Guards the expensive dataset generation + reference passes when the
/// bench is invoked filtered, e.g. the CI churn smoke.)
fn tag_enabled(b: &Bench, tag: &str) -> bool {
    THREADS.iter().any(|k| {
        b.enabled(&format!("DTw {tag} k={k}"))
            || b.enabled(&format!("Dc {tag} k={k}"))
            || b.enabled(&format!("DTw simd {tag} k={k}"))
            || b.enabled(&format!("Dc simd {tag} k={k}"))
    })
}

fn bench_matrix(b: &mut Bench, tag: &str, x: &CscMatrix) {
    let d = x.rows();
    let n = x.cols();
    let mut rng = Pcg64::seed_from_u64(3);
    let w: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let c: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let inv_n = 1.0 / n as f64;

    // serial references (bit-exactness oracle for every pool width)
    let mut dtw_ref = vec![0.0f64; n];
    x.transpose_matvec(&w, &mut dtw_ref);
    let mut dc_ref = vec![0.0f64; d];
    x.matvec_accumulate_scaled(&c, inv_n, &mut dc_ref);
    x.ensure_mirror(); // off the timed path, as the drivers do

    for k in THREADS {
        let pool = Pool::new(k);
        let mut out_n = vec![0.0f64; n];
        b.bench(&format!("DTw {tag} k={k}"), || {
            x.transpose_matvec_pool(&w, &mut out_n, &pool);
            std::hint::black_box(&out_n);
        });
        // the closure only ran if the entry passed the filter — never
        // compare a buffer a skipped entry left untouched
        if b.enabled(&format!("DTw {tag} k={k}")) {
            assert_eq!(out_n, dtw_ref, "DTw {tag} k={k} diverged from serial");
        }

        let mut out_d = vec![0.0f64; d];
        b.bench(&format!("Dc {tag} k={k}"), || {
            out_d.iter_mut().for_each(|v| *v = 0.0);
            x.matvec_accumulate_scaled_pool(&c, inv_n, &mut out_d, &pool);
            std::hint::black_box(&out_d);
        });
        if b.enabled(&format!("Dc {tag} k={k}")) {
            assert_eq!(out_d, dc_ref, "Dc {tag} k={k} diverged from serial");
        }

        // --simd variants: reassociated sums, so the check is the same
        // tolerance contract tests/kernel_exactness.rs pins
        let close = |got: f64, want: f64| (got - want).abs() <= 1e-10 * (1.0 + want.abs());
        let mut out_n = vec![0.0f64; n];
        b.bench(&format!("DTw simd {tag} k={k}"), || {
            x.transpose_matvec_pool_simd(&w, &mut out_n, &pool);
            std::hint::black_box(&out_n);
        });
        if b.enabled(&format!("DTw simd {tag} k={k}")) {
            for j in 0..n {
                assert!(close(out_n[j], dtw_ref[j]), "DTw simd {tag} k={k} col {j}");
            }
        }
        let mut out_d = vec![0.0f64; d];
        b.bench(&format!("Dc simd {tag} k={k}"), || {
            out_d.iter_mut().for_each(|v| *v = 0.0);
            x.matvec_accumulate_scaled_pool_simd(&c, inv_n, &mut out_d, &pool);
            std::hint::black_box(&out_d);
        });
        if b.enabled(&format!("Dc simd {tag} k={k}")) {
            for r in 0..d {
                assert!(close(out_d[r], dc_ref[r]), "Dc simd {tag} k={k} row {r}");
            }
        }
    }
}

/// Mixed-precision engine: time the f32 `partial_products` kernel against
/// an f64 scalar evaluation of the same padded tile, and report the max
/// absolute error the precision drop costs (the "error vs speed" row).
fn bench_mixed(b: &mut Bench) {
    let names = ["mixed f32 partial_products", "mixed f64 reference"];
    if !names.iter().any(|n| b.enabled(n)) {
        return;
    }
    let mut rng = Pcg64::seed_from_u64(29);
    let w32: Vec<f32> = (0..BLOCK_D).map(|_| rng.normal() as f32).collect();
    let tile32: Vec<f32> = (0..BLOCK_D * BLOCK_N)
        .map(|_| if rng.next_f64() < 0.1 { rng.normal() as f32 } else { 0.0 })
        .collect();
    let w64: Vec<f64> = w32.iter().map(|&v| v as f64).collect();
    let tile64: Vec<f64> = tile32.iter().map(|&v| v as f64).collect();
    let engine = MixedEngine::new();
    let mut s32 = vec![0f32; BLOCK_N];
    b.bench(names[0], || {
        s32 = engine.partial_products(&w32, &tile32).expect("kernel healthy");
        std::hint::black_box(&s32);
    });
    let mut s64 = vec![0f64; BLOCK_N];
    b.bench(names[1], || {
        for (j, sv) in s64.iter_mut().enumerate() {
            let col = &tile64[j * BLOCK_D..(j + 1) * BLOCK_D];
            *sv = col.iter().zip(w64.iter()).map(|(&dv, &wv)| dv * wv).sum();
        }
        std::hint::black_box(&s64);
    });
    if names.iter().all(|n| b.enabled(n)) {
        let max_err = s32
            .iter()
            .zip(s64.iter())
            .map(|(&a, &bv)| (a as f64 - bv).abs())
            .fold(0.0f64, f64::max);
        println!("mixed partial_products: max |f32 - f64| = {max_err:.3e}");
        assert!(max_err < 1e-3, "f32 kernel error blew past f32 rounding scale");
    }
}

fn main() {
    let mut b = Bench::from_args("kernels").with_iters(2, 7);

    // d = 100k: ~200k nnz (2k instances x ~100 nnz)
    if tag_enabled(&b, "d=100k") {
        let small = generate(&GenSpec::new("k100k", 100_000, 2_000, 100).with_seed(11));
        bench_matrix(&mut b, "d=100k", &small.x);
    }

    // d = 1M: ~800k nnz (4k instances x ~200 nnz) — the acceptance case:
    // DTw at k=4 must come in >= 2x faster than k=1
    if tag_enabled(&b, "d=1M") {
        let big = generate(&GenSpec::new("k1m", 1_000_000, 4_000, 200).with_seed(12));
        bench_matrix(&mut b, "d=1M", &big.x);
    }

    // mixed-precision engine: f32 kernel speed next to the f64 scalar cost
    // + the measured precision gap
    bench_mixed(&mut b);

    // epoch-buffer allocation churn: what every epoch loop used to do
    // (fresh margins vector + a fresh partial vector per inner batch)
    // vs the Workspace reuse all drivers run now
    let n = 50_000usize;
    let batches = 200usize;
    let u = 100usize;
    b.bench("churn alloc-per-epoch (before)", || {
        let mut margins = vec![0.0f64; n];
        margins[7] = 1.0;
        std::hint::black_box(&margins);
        for _ in 0..batches {
            let mut partial = vec![0.0f64; u];
            partial[3] = 1.0;
            std::hint::black_box(&partial);
        }
    });
    let mut ws = Workspace::new(1);
    b.bench("churn workspace-reuse (after)", || {
        Workspace::reset(&mut ws.margins, n);
        ws.margins[7] = 1.0;
        std::hint::black_box(&ws.margins);
        for _ in 0..batches {
            Workspace::reset(&mut ws.partial, u);
            ws.partial[3] = 1.0;
            std::hint::black_box(&ws.partial);
        }
    });

    // speedup readout + baseline persistence (full runs only: a filtered
    // run must not overwrite the committed baseline with a partial one)
    if !b.is_filtered() {
        let mean = |name: &str| {
            b.samples()
                .iter()
                .find(|s| s.name == name)
                .map(|s| s.mean_s)
                .expect("entry present in a full run")
        };
        for tag in ["d=100k", "d=1M"] {
            for kernel in ["DTw", "Dc"] {
                let s1 = mean(&format!("{kernel} {tag} k=1"));
                let s4 = mean(&format!("{kernel} {tag} k=4"));
                println!("{kernel} {tag}: k=4 speedup {:.2}x", s1 / s4);
                let lanes = mean(&format!("{kernel} simd {tag} k=1"));
                println!("{kernel} {tag}: simd lanes at k=1 {:.2}x vs serial chain", s1 / lanes);
            }
        }
        let note = "sparse-kernel engine baseline; regenerate from the repo root \
                    with `cargo bench --bench bench_kernels`";
        let path = b.json_path().unwrap_or("BENCH_kernels.json");
        b.write_json(path, note).unwrap_or_else(|e| panic!("write {path}: {e}"));
        println!("baseline written to {path}");
    } else if let Some(path) = b.json_path() {
        // filtered runs never touch the committed baseline, but an explicit
        // --json destination still gets the partial report
        let note = "partial (filtered) bench_kernels run";
        b.write_json(path, note).unwrap_or_else(|e| panic!("write {path}: {e}"));
        println!("filtered report written to {path}");
    }
    b.finish();
}
