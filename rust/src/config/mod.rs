//! Experiment configuration: a typed view over a TOML-subset parser
//! (`serde`/`toml` are unavailable offline).
//!
//! Supported syntax — everything the experiment configs need:
//!
//! ```toml
//! # comment
//! [section]
//! int = 3
//! float = 1e-4
//! string = "webspam-sim"
//! flag = true
//! list = [1, 4, 8, 16]
//! ```
//!
//! Keys are addressed as `"section.key"`. [`ExperimentConfig`] is the typed
//! experiment schema with defaults matching the paper's §5 setup.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A parsed config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Str(String),
    Bool(bool),
    List(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

fn parse_scalar(tok: &str) -> Result<Value> {
    let tok = tok.trim();
    if tok == "true" {
        return Ok(Value::Bool(true));
    }
    if tok == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(s) = tok.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
        return Ok(Value::Str(s.to_string()));
    }
    if !tok.contains(['.', 'e', 'E']) {
        if let Ok(i) = tok.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = tok.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {tok:?}")
}

/// Flat `section.key -> Value` config document.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut section = String::new();
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                // only strip comments outside strings (strings here never
                // contain '#' in our configs; keep the parser simple)
                Some(pos) if !raw[..pos].contains('"') || raw[..pos].matches('"').count() % 2 == 0 => {
                    &raw[..pos]
                }
                _ => raw,
            };
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            let full_key =
                if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            let val = val.trim();
            let value = if let Some(inner) =
                val.strip_prefix('[').and_then(|s| s.strip_suffix(']'))
            {
                let items: Result<Vec<Value>> = inner
                    .split(',')
                    .filter(|s| !s.trim().is_empty())
                    .map(parse_scalar)
                    .collect();
                Value::List(items.with_context(|| format!("line {}", lineno + 1))?)
            } else {
                parse_scalar(val).with_context(|| format!("line {}", lineno + 1))?
            };
            values.insert(full_key, value);
        }
        Ok(Config { values })
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {}", path.as_ref().display()))?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn usize_list(&self, key: &str) -> Option<Vec<usize>> {
        match self.get(key)? {
            Value::List(items) => items.iter().map(Value::as_usize).collect(),
            _ => None,
        }
    }
}

/// Typed experiment schema; defaults reproduce the paper's §5 setup.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub dataset: String,
    pub algo: String,
    pub lambda: f64,
    pub eta: f64,
    pub outer: usize,
    pub q: usize,
    pub servers: usize,
    pub batch: usize,
    pub seed: u64,
    pub gap_target: f64,
    pub latency: f64,
    pub per_msg: f64,
    pub bandwidth_gbps: f64,
    /// Wire format for counted payloads (`run.wire = "f64"|"f32"|"sparse"`).
    pub wire: crate::net::WireFmt,
    /// Gradient sparsification on counted sends (`run.compress =
    /// "none"|"topk:<k>"|"thresh:<t>"`, CLI `--compress`).
    pub compress: crate::net::Compression,
    /// FD-SVRG lazy inner loop (§Perf).
    pub lazy: bool,
    /// Host threads per node for the sparse compute kernels
    /// (`run.threads`, CLI `--threads`); 1 = serial (default). Bit-exact
    /// at any width — changes host wall-clock only.
    pub threads: usize,
    /// SIMD sparse kernels (`run.simd`, CLI `--simd`); opt-in because the
    /// reduction kernels reassociate sums (tolerance, not bit-exactness).
    pub simd: bool,
    /// Network scenario kind (`net.model = "uniform"|"hetero"|"straggler"|
    /// "jitter"`, CLI `--net`); resolved with the `net.*` scenario table
    /// below by [`ExperimentConfig::net_spec`].
    pub net_model: String,
    /// Hetero: nodes per rack (`net.rack_size`).
    pub rack_size: usize,
    /// Hetero: cross-rack wire latency, seconds (`net.cross_latency`).
    pub cross_latency: f64,
    /// Hetero: cross-rack per-message overhead (`net.cross_per_msg`).
    pub cross_per_msg: f64,
    /// Hetero: cross-rack bandwidth (`net.cross_bandwidth_gbps`).
    pub cross_bandwidth_gbps: f64,
    /// Straggler: how many (highest-id) nodes run slow (`net.slow`).
    pub slow: usize,
    /// Straggler: compute + NIC slowdown factor (`net.factor`).
    pub slow_factor: f64,
    /// Jitter: per-message latency-noise amplitude, seconds
    /// (`net.jitter_amp`).
    pub jitter_amp: f64,
    /// Jitter: dedicated noise-stream seed (`net.jitter_seed`),
    /// independent of the sampling seed so noise and sampling decouple.
    pub jitter_seed: u64,
    /// Message-plane backing (`run.transport = "sim"|"tcp"`, CLI
    /// `--transport`): in-memory mailboxes (default) or localhost sockets
    /// with one OS process per node.
    pub transport: String,
    /// Seeded fault-injection spec (`run.faults`, CLI `--faults`):
    /// comma-separated clauses `crash:<node>@<t>`, `drop:<p>`, `dup:<p>`,
    /// `reorder:<p>`, `partition:<a>+<b>@<t1>-<t2>`, `seed:<u64>`. Empty
    /// (the default) or `"none"` disables the fault plane entirely — a
    /// provable identity.
    pub faults: String,
    /// TCP rendezvous deadline, seconds (`run.rendezvous_timeout`, CLI
    /// `--rendezvous-timeout`): how long the monitor waits for all worker
    /// processes to dial in before failing the launch.
    pub rendezvous_timeout: f64,
    /// Serving plane: batch-close size (`serve.batch`, CLI
    /// `--serve-batch`) — a batch dispatches when it holds this many
    /// queries or when the delay window expires.
    pub serve_batch: usize,
    /// Serving plane: batch-close delay window, seconds (`serve.delay`,
    /// CLI `--serve-delay`).
    pub serve_delay: f64,
    /// Serving plane: total queries the load generator drives
    /// (`serve.queries`, CLI `--queries`).
    pub serve_queries: usize,
    /// Serving plane, closed mode: client-pool size (`serve.concurrency`,
    /// CLI `--concurrency`).
    pub serve_concurrency: usize,
    /// Serving plane arrival discipline (`serve.mode = "closed"|"open"`,
    /// CLI `--mode`).
    pub serve_mode: String,
    /// Serving plane, open mode: Poisson arrival rate, queries/second
    /// (`serve.rate`, CLI `--rate`).
    pub serve_rate: f64,
    /// Serving plane: copies of each feature shard (`serve.replicas`, CLI
    /// `--replicas`) — the cluster becomes `q·r + 1` nodes and the router
    /// fails over between copies.
    pub serve_replicas: usize,
    /// Serving plane: per-batch service deadline, modeled seconds
    /// (`serve.deadline`, CLI `--serve-deadline`); 0 disables. Missed
    /// batches still answer but count `late`.
    pub serve_deadline: f64,
    /// Serving plane: hedge delay, modeled seconds (`serve.hedge`, CLI
    /// `--hedge`) — each batch also races a second replica. Negative
    /// (the default) disables hedging.
    pub serve_hedge: f64,
    /// Serving plane, open mode: admission-queue bound (`serve.queue_cap`,
    /// CLI `--queue-cap`); arrivals past it are shed. 0 = unbounded.
    pub serve_queue_cap: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dataset: "webspam-sim".into(),
            algo: "fdsvrg".into(),
            lambda: 1e-4, // paper §5.3
            eta: 0.0,     // 0 = auto (0.1/L)
            outer: 30,
            q: 16,      // paper §5.1
            servers: 8, // paper §5.2 (AsySVRG)
            // §4.4.1 mini-batch: same total scalars, u× fewer allreduce
            // rounds. Without it every inner step pays a full tree-latency
            // round trip (M = N of them per epoch) and the latency term
            // swamps the bandwidth win the paper measures — the authors'
            // implementation batches for exactly this reason.
            batch: 100,
            seed: 42,
            gap_target: 1e-4, // paper Tables 2–3
            latency: 40e-6,
            per_msg: 10e-6,
            bandwidth_gbps: 10.0, // paper §5: 10GbE
            wire: crate::net::WireFmt::F64,
            compress: crate::net::Compression::None,
            lazy: false,
            threads: 1,
            simd: false,
            net_model: "uniform".into(),
            rack_size: 4,
            // cross-rack defaults: an oversubscribed spine — >10× the
            // latency, 1/10 the bandwidth of the 10GbE rack links
            cross_latency: 500e-6,
            cross_per_msg: 10e-6,
            cross_bandwidth_gbps: 1.0,
            slow: 1,
            slow_factor: 4.0,
            // jitter default: 5× the base latency, a visibly noisy switch
            jitter_amp: 200e-6,
            jitter_seed: 20177,
            transport: "sim".into(),
            faults: String::new(),
            rendezvous_timeout: crate::net::transport::tcp::DEFAULT_RENDEZVOUS_SECS,
            serve_batch: 32,
            // 5× the base wire latency: long enough to fill batches under
            // load, short enough to stay invisible at p50 when idle
            serve_delay: 200e-6,
            serve_queries: 10_000,
            serve_concurrency: 64,
            serve_mode: "closed".into(),
            serve_rate: 50_000.0,
            serve_replicas: 1,
            serve_deadline: 0.0,
            serve_hedge: -1.0,
            serve_queue_cap: 0,
        }
    }
}

/// Private selector for [`ExperimentConfig::net_spec_for`]: the scenario
/// *kind*, before this config's `net.*` table parameterizes it into a
/// full [`crate::net::NetSpec`].
#[derive(Clone, Copy)]
enum NetKind {
    Uniform,
    Hetero,
    Straggler,
    Jitter,
}

const NET_KIND_TABLE: [(&str, NetKind); 5] = [
    ("uniform", NetKind::Uniform),
    ("hetero", NetKind::Hetero),
    ("heterogeneous", NetKind::Hetero),
    ("straggler", NetKind::Straggler),
    ("jitter", NetKind::Jitter),
];

impl ExperimentConfig {
    pub fn from_config(cfg: &Config) -> ExperimentConfig {
        let d = ExperimentConfig::default();
        ExperimentConfig {
            dataset: cfg.str_or("run.dataset", &d.dataset).to_string(),
            algo: cfg.str_or("run.algo", &d.algo).to_string(),
            lambda: cfg.f64_or("run.lambda", d.lambda),
            eta: cfg.f64_or("run.eta", d.eta),
            outer: cfg.usize_or("run.outer", d.outer),
            q: cfg.usize_or("run.q", d.q),
            servers: cfg.usize_or("run.servers", d.servers),
            batch: cfg.usize_or("run.batch", d.batch),
            seed: cfg.usize_or("run.seed", d.seed as usize) as u64,
            gap_target: cfg.f64_or("run.gap_target", d.gap_target),
            latency: cfg.f64_or("net.latency", d.latency),
            per_msg: cfg.f64_or("net.per_msg", d.per_msg),
            bandwidth_gbps: cfg.f64_or("net.bandwidth_gbps", d.bandwidth_gbps),
            wire: {
                let s = cfg.str_or("run.wire", d.wire.name());
                crate::net::WireFmt::parse_or_err(s).unwrap_or_else(|e| panic!("run.wire: {e}"))
            },
            compress: {
                let s = cfg.str_or("run.compress", &d.compress.spec()).to_string();
                crate::net::Compression::parse_or_err(&s)
                    .unwrap_or_else(|e| panic!("run.compress: {e}"))
            },
            lazy: cfg.bool_or("run.lazy", d.lazy),
            threads: cfg.usize_or("run.threads", d.threads).max(1),
            simd: cfg.bool_or("run.simd", d.simd),
            net_model: cfg.str_or("net.model", &d.net_model).to_string(),
            rack_size: cfg.usize_or("net.rack_size", d.rack_size),
            cross_latency: cfg.f64_or("net.cross_latency", d.cross_latency),
            cross_per_msg: cfg.f64_or("net.cross_per_msg", d.cross_per_msg),
            cross_bandwidth_gbps: cfg.f64_or("net.cross_bandwidth_gbps", d.cross_bandwidth_gbps),
            slow: cfg.usize_or("net.slow", d.slow),
            slow_factor: cfg.f64_or("net.factor", d.slow_factor),
            jitter_amp: cfg.f64_or("net.jitter_amp", d.jitter_amp),
            jitter_seed: cfg.usize_or("net.jitter_seed", d.jitter_seed as usize) as u64,
            transport: cfg.str_or("run.transport", &d.transport).to_string(),
            faults: cfg.str_or("run.faults", &d.faults).to_string(),
            rendezvous_timeout: cfg.f64_or("run.rendezvous_timeout", d.rendezvous_timeout),
            serve_batch: cfg.usize_or("serve.batch", d.serve_batch).max(1),
            serve_delay: cfg.f64_or("serve.delay", d.serve_delay),
            serve_queries: cfg.usize_or("serve.queries", d.serve_queries),
            serve_concurrency: cfg.usize_or("serve.concurrency", d.serve_concurrency).max(1),
            serve_mode: cfg.str_or("serve.mode", &d.serve_mode).to_string(),
            serve_rate: cfg.f64_or("serve.rate", d.serve_rate),
            serve_replicas: cfg.usize_or("serve.replicas", d.serve_replicas).max(1),
            serve_deadline: cfg.f64_or("serve.deadline", d.serve_deadline),
            serve_hedge: cfg.f64_or("serve.hedge", d.serve_hedge),
            serve_queue_cap: cfg.usize_or("serve.queue_cap", d.serve_queue_cap),
        }
    }

    /// The [`NetSpec`] for a named scenario kind (case-insensitive),
    /// parameterized by this config's `net.*` scenario table. The error
    /// lists every valid kind (the `parse_or_err` convention).
    pub fn net_spec_for(&self, kind: &str) -> Result<crate::net::NetSpec, String> {
        use crate::net::{LinkProfile, NetSpec};
        let k = crate::util::parse_enum_or_err(
            kind,
            "network model",
            "models (case-insensitive)",
            &NetSpec::KINDS,
            &NET_KIND_TABLE,
        )?;
        Ok(match k {
            NetKind::Uniform => NetSpec::Uniform,
            NetKind::Hetero => NetSpec::Hetero {
                cross: LinkProfile {
                    latency: self.cross_latency,
                    per_msg: self.cross_per_msg,
                    sec_per_byte: 8.0 / (self.cross_bandwidth_gbps * 1e9),
                },
                rack_size: self.rack_size.max(1),
            },
            NetKind::Straggler => NetSpec::Straggler { slow: self.slow, factor: self.slow_factor },
            NetKind::Jitter => NetSpec::Jitter { amp: self.jitter_amp, seed: self.jitter_seed },
        })
    }

    /// This config's network scenario (`net.model` / CLI `--net`).
    pub fn net_spec(&self) -> Result<crate::net::NetSpec, String> {
        self.net_spec_for(&self.net_model)
    }

    /// The serving plane's arrival discipline (`serve.mode` / CLI
    /// `--mode`), parameterized by this config's concurrency/rate knobs.
    pub fn serve_arrival_mode(&self) -> Result<crate::serve::ArrivalMode, String> {
        match self.serve_mode.to_ascii_lowercase().as_str() {
            "closed" => {
                Ok(crate::serve::ArrivalMode::Closed { concurrency: self.serve_concurrency })
            }
            "open" => Ok(crate::serve::ArrivalMode::Open { rate: self.serve_rate }),
            other => Err(format!(
                "unknown serve mode {other:?}; modes (case-insensitive): closed, open"
            )),
        }
    }

    pub fn sim_params(&self) -> crate::net::SimParams {
        crate::net::SimParams {
            latency: self.latency,
            per_msg: self.per_msg,
            // bandwidth is bits/s; the simulator charges per payload byte
            sec_per_byte: 8.0 / (self.bandwidth_gbps * 1e9),
        }
    }

    pub fn run_params(&self) -> crate::algs::RunParams {
        crate::algs::RunParams {
            eta: self.eta,
            outer: self.outer,
            m_inner: 0,
            batch: self.batch,
            q: self.q,
            servers: self.servers,
            seed: self.seed,
            sim: self.sim_params(),
            net: self.net_spec().unwrap_or_else(|e| panic!("net.model: {e}")),
            gap_stop: None,
            sim_time_cap: None,
            star_reduce: false,
            wire: self.wire,
            compress: self.compress,
            lazy: self.lazy,
            threads: self.threads,
            simd: self.simd,
            transport: crate::net::TransportKind::parse_or_err(&self.transport)
                .unwrap_or_else(|e| panic!("run.transport: {e}")),
            worker_spec: None,
            faults: crate::net::fault::FaultPlan::parse(&self.faults, self.seed)
                .unwrap_or_else(|e| panic!("run.faults: {e}")),
            rendezvous_secs: self.rendezvous_timeout,
        }
    }

    /// Serialize this config — plus the CLI extras that live outside the
    /// schema (`--test-frac`, `--star`, `--lazy`) — into the Config text a
    /// `--transport tcp` worker process parses to rebuild the identical
    /// problem and run parameters. `{}` float formatting is Rust's
    /// shortest-round-trip form, so every value survives the text hop
    /// bit-exactly. `run.transport` is deliberately omitted: a worker
    /// always runs its single node over the socket mesh it was handed.
    pub fn worker_spec(&self, test_frac: f64, star: bool, lazy: bool) -> String {
        let lines = [
            "[run]".to_string(),
            format!("dataset = \"{}\"", self.dataset),
            format!("algo = \"{}\"", self.algo),
            format!("lambda = {}", self.lambda),
            format!("eta = {}", self.eta),
            format!("outer = {}", self.outer),
            format!("q = {}", self.q),
            format!("servers = {}", self.servers),
            format!("batch = {}", self.batch),
            format!("seed = {}", self.seed),
            format!("gap_target = {}", self.gap_target),
            format!("wire = \"{}\"", self.wire.name()),
            format!("compress = \"{}\"", self.compress.spec()),
            format!("lazy = {}", self.lazy || lazy),
            format!("threads = {}", self.threads),
            format!("simd = {}", self.simd),
            format!("test_frac = {test_frac}"),
            format!("star = {star}"),
            format!("rendezvous_timeout = {}", self.rendezvous_timeout),
            "[net]".to_string(),
            format!("latency = {}", self.latency),
            format!("per_msg = {}", self.per_msg),
            format!("bandwidth_gbps = {}", self.bandwidth_gbps),
            format!("model = \"{}\"", self.net_model),
            format!("rack_size = {}", self.rack_size),
            format!("cross_latency = {}", self.cross_latency),
            format!("cross_per_msg = {}", self.cross_per_msg),
            format!("cross_bandwidth_gbps = {}", self.cross_bandwidth_gbps),
            format!("slow = {}", self.slow),
            format!("factor = {}", self.slow_factor),
            format!("jitter_amp = {}", self.jitter_amp),
            format!("jitter_seed = {}", self.jitter_seed),
        ];
        let mut spec = lines.join("\n");
        spec.push('\n');
        spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
[run]
dataset = "news20-sim"
lambda = 1e-3
outer = 12
q = 8
star = false
sweep = [1, 4, 8, 16]

[net]
latency = 5e-5
"#;

    #[test]
    fn parse_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("run.dataset", ""), "news20-sim");
        assert_eq!(c.f64_or("run.lambda", 0.0), 1e-3);
        assert_eq!(c.usize_or("run.outer", 0), 12);
        assert!(!c.bool_or("run.star", true));
        assert_eq!(c.usize_list("run.sweep"), Some(vec![1, 4, 8, 16]));
        assert_eq!(c.f64_or("net.latency", 0.0), 5e-5);
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("run.q", 16), 16);
        let e = ExperimentConfig::from_config(&c);
        assert_eq!(e.q, 16);
        assert_eq!(e.lambda, 1e-4);
    }

    #[test]
    fn experiment_config_overrides() {
        let c = Config::parse(SAMPLE).unwrap();
        let e = ExperimentConfig::from_config(&c);
        assert_eq!(e.dataset, "news20-sim");
        assert_eq!(e.q, 8);
        assert_eq!(e.lambda, 1e-3);
        assert_eq!(e.latency, 5e-5);
        // untouched keys keep paper defaults
        assert_eq!(e.gap_target, 1e-4);
    }

    #[test]
    fn sim_params_from_bandwidth() {
        let e = ExperimentConfig::default();
        let sp = e.sim_params();
        // 10 Gb/s ⇒ 0.8 ns per byte (an 8-byte f64 scalar keeps its 6.4 ns)
        assert!((sp.sec_per_byte - 0.8e-9).abs() < 1e-13);
    }

    #[test]
    fn wire_format_parses_from_config() {
        let c = Config::parse("[run]\nwire = \"f32\"\n").unwrap();
        let e = ExperimentConfig::from_config(&c);
        assert_eq!(e.wire, crate::net::WireFmt::F32);
        assert_eq!(e.run_params().wire, crate::net::WireFmt::F32);
        // default stays bit-exact f64
        let e = ExperimentConfig::from_config(&Config::parse("").unwrap());
        assert_eq!(e.wire, crate::net::WireFmt::F64);
    }

    #[test]
    fn threads_parse_from_config_and_default_to_serial() {
        let c = Config::parse("[run]\nthreads = 4\n").unwrap();
        let e = ExperimentConfig::from_config(&c);
        assert_eq!(e.threads, 4);
        assert_eq!(e.run_params().threads, 4);
        let e = ExperimentConfig::from_config(&Config::parse("").unwrap());
        assert_eq!(e.threads, 1, "default stays the serial loops");
        // 0 is clamped: a pool always has at least the caller thread
        let c = Config::parse("[run]\nthreads = 0\n").unwrap();
        assert_eq!(ExperimentConfig::from_config(&c).threads, 1);
    }

    #[test]
    fn compress_and_simd_parse_from_config_and_default_off() {
        use crate::net::Compression;
        let c = Config::parse("[run]\ncompress = \"topk:64\"\nsimd = true\n").unwrap();
        let e = ExperimentConfig::from_config(&c);
        assert_eq!(e.compress, Compression::TopK(64));
        assert!(e.simd);
        let p = e.run_params();
        assert_eq!(p.compress, Compression::TopK(64));
        assert!(p.simd);
        // defaults: no sparsification, serial kernels — the bit-exact paths
        let e = ExperimentConfig::from_config(&Config::parse("").unwrap());
        assert_eq!(e.compress, Compression::None);
        assert!(!e.simd);
        let c = Config::parse("[run]\ncompress = \"thresh:1e-4\"\n").unwrap();
        assert_eq!(ExperimentConfig::from_config(&c).compress, Compression::Threshold(1e-4));
    }

    #[test]
    fn net_model_parses_from_config() {
        use crate::net::NetSpec;
        let c = Config::parse("[net]\nmodel = \"straggler\"\nslow = 3\nfactor = 6.5\n").unwrap();
        let e = ExperimentConfig::from_config(&c);
        assert_eq!(e.net_spec().unwrap(), NetSpec::Straggler { slow: 3, factor: 6.5 });
        assert_eq!(e.run_params().net, NetSpec::Straggler { slow: 3, factor: 6.5 });
        // default stays the legacy uniform network
        let e = ExperimentConfig::from_config(&Config::parse("").unwrap());
        assert_eq!(e.net_spec().unwrap(), NetSpec::Uniform);
        assert_eq!(e.run_params().net, NetSpec::Uniform);
    }

    #[test]
    fn net_spec_kinds_are_case_insensitive_and_errors_list_all() {
        let e = ExperimentConfig::default();
        assert_eq!(e.net_spec_for("UNIFORM").unwrap(), crate::net::NetSpec::Uniform);
        assert!(matches!(
            e.net_spec_for("Jitter").unwrap(),
            crate::net::NetSpec::Jitter { .. }
        ));
        let err = e.net_spec_for("mesh").unwrap_err();
        for kind in crate::net::NetSpec::KINDS {
            assert!(err.contains(kind), "error must list {kind:?}: {err}");
        }
    }

    #[test]
    fn hetero_spec_builds_cross_profile_from_the_net_table() {
        let c = Config::parse(
            "[net]\nmodel = \"hetero\"\nrack_size = 2\ncross_latency = 1e-3\ncross_bandwidth_gbps = 2.0\n",
        )
        .unwrap();
        let e = ExperimentConfig::from_config(&c);
        match e.net_spec().unwrap() {
            crate::net::NetSpec::Hetero { cross, rack_size } => {
                assert_eq!(rack_size, 2);
                assert_eq!(cross.latency, 1e-3);
                assert!((cross.sec_per_byte - 8.0 / 2e9).abs() < 1e-15);
            }
            other => panic!("expected hetero, got {other:?}"),
        }
    }

    #[test]
    fn transport_parses_from_config_and_defaults_to_sim() {
        use crate::net::TransportKind;
        let e = ExperimentConfig::from_config(&Config::parse("").unwrap());
        assert_eq!(e.transport, "sim");
        assert_eq!(e.run_params().transport, TransportKind::Sim);
        assert_eq!(e.run_params().worker_spec, None);
        let c = Config::parse("[run]\ntransport = \"tcp\"\n").unwrap();
        let e = ExperimentConfig::from_config(&c);
        assert_eq!(e.run_params().transport, TransportKind::Tcp);
    }

    #[test]
    fn worker_spec_round_trips_every_field() {
        let e = ExperimentConfig {
            dataset: "news20-sim".into(),
            algo: "dsvrg".into(),
            lambda: 3e-7,
            eta: 0.125,
            outer: 7,
            q: 3,
            seed: 99,
            wire: crate::net::WireFmt::Sparse,
            compress: crate::net::Compression::TopK(37),
            simd: true,
            net_model: "straggler".into(),
            slow_factor: 6.5,
            latency: 40e-6,
            ..ExperimentConfig::default()
        };
        let spec = e.worker_spec(0.25, true, true);
        let c = Config::parse(&spec).unwrap();
        let back = ExperimentConfig::from_config(&c);
        assert_eq!(back.dataset, e.dataset);
        assert_eq!(back.algo, e.algo);
        assert_eq!(back.lambda, e.lambda, "floats must round-trip exactly");
        assert_eq!(back.eta, e.eta);
        assert_eq!(back.outer, e.outer);
        assert_eq!(back.q, e.q);
        assert_eq!(back.seed, e.seed);
        assert_eq!(back.wire, e.wire);
        assert_eq!(back.compress, e.compress);
        assert!(back.simd, "simd flag must cross");
        assert_eq!(back.net_model, e.net_model);
        assert_eq!(back.slow_factor, e.slow_factor);
        assert_eq!(back.latency, e.latency);
        assert!(back.lazy, "merged lazy flag must cross");
        // the out-of-schema extras ride along as plain config keys
        assert_eq!(c.f64_or("run.test_frac", -1.0), 0.25);
        assert!(c.bool_or("run.star", false));
        // a worker never re-enters the process launcher
        assert_eq!(back.transport, "sim");
    }

    #[test]
    fn faults_and_rendezvous_parse_from_config_and_default_off() {
        let e = ExperimentConfig::from_config(&Config::parse("").unwrap());
        assert!(e.faults.is_empty(), "fault plane defaults off");
        assert!(e.run_params().faults.is_none(), "empty spec must build no plan");
        assert_eq!(e.rendezvous_timeout, crate::net::transport::tcp::DEFAULT_RENDEZVOUS_SECS);
        let c = Config::parse(
            "[run]\nfaults = \"drop:0.1,crash:2@0.5\"\nrendezvous_timeout = 7.5\n",
        )
        .unwrap();
        let e = ExperimentConfig::from_config(&c);
        assert_eq!(e.faults, "drop:0.1,crash:2@0.5");
        assert_eq!(e.rendezvous_timeout, 7.5);
        let p = e.run_params();
        assert_eq!(p.rendezvous_secs, 7.5);
        let plan = p.faults.expect("spec with clauses must build a plan");
        assert_eq!(plan.spec(), "drop:0.1,crash:2@0.5");
        assert_eq!(plan.crashes().len(), 1);
    }

    #[test]
    fn bad_syntax_errors() {
        assert!(Config::parse("just words\n").is_err());
        assert!(Config::parse("[run]\nkey = @!?\n").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let c = Config::parse("# hi\n\nx = 1 # trailing\n").unwrap();
        assert_eq!(c.usize_or("x", 0), 1);
    }
}
