//! Command-line argument parser substrate (`clap` is unavailable offline).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches
//! and positional arguments, with generated usage text. Declarative enough
//! for the launcher and the examples:
//!
//! ```
//! use fdsvrg::cli::Args;
//! let args = Args::parse_from(["train", "--algo", "fdsvrg", "-q", "8", "--star"]);
//! assert_eq!(args.subcommand(), Some("train"));
//! assert_eq!(args.get("algo"), Some("fdsvrg"));
//! assert_eq!(args.get_or("q", 4usize), 8);
//! assert!(args.flag("star"));
//! ```

use std::collections::BTreeMap;
use std::str::FromStr;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    subcommand: Option<String>,
    options: BTreeMap<String, String>,
    switches: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse process arguments (skipping argv[0]).
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    /// Parse from an explicit token stream. The first non-flag token is the
    /// subcommand; `--key value`, `--key=value` and `-k value` become
    /// options; `--key` followed by another flag (or nothing) is a switch.
    pub fn parse_from<I, S>(tokens: I) -> Args
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let toks: Vec<String> = tokens.into_iter().map(Into::into).collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(stripped) = t.strip_prefix("--").or_else(|| t.strip_prefix('-')) {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with('-') {
                    args.options.insert(stripped.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    args.switches.push(stripped.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.subcommand.as_deref()
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Raw option value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn get_or<T: FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Debug,
    {
        match self.options.get(key) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{key}={v}: parse error {e:?}")),
            None => default,
        }
    }

    /// Typed option, `None` when absent.
    pub fn get_opt<T: FromStr>(&self, key: &str) -> Option<T>
    where
        T::Err: std::fmt::Debug,
    {
        self.options
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|e| panic!("--{key}={v}: parse error {e:?}")))
    }

    /// Boolean switch (present without value).
    pub fn flag(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subcommand_and_options() {
        let a = Args::parse_from(["train", "--algo", "fdsvrg", "--q=8", "extra"]);
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.get("algo"), Some("fdsvrg"));
        assert_eq!(a.get_or("q", 0usize), 8);
        assert_eq!(a.positional(), &["extra".to_string()]);
    }

    #[test]
    fn switches_vs_options() {
        let a = Args::parse_from(["x", "--verbose", "--eta", "0.5", "--star"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("star"));
        assert!(!a.flag("eta"));
        assert_eq!(a.get_or("eta", 0.0f64), 0.5);
    }

    #[test]
    fn short_flags() {
        let a = Args::parse_from(["run", "-q", "16"]);
        assert_eq!(a.get_or("q", 0usize), 16);
    }

    #[test]
    fn negative_number_values_need_equals() {
        let a = Args::parse_from(["run", "--eta=-0.5"]);
        assert_eq!(a.get_or("eta", 0.0f64), -0.5);
    }

    #[test]
    fn typed_default_on_missing() {
        let a = Args::parse_from(["run"]);
        assert_eq!(a.get_or("missing", 7i32), 7);
        assert_eq!(a.get_opt::<f64>("missing"), None);
    }

    #[test]
    #[should_panic]
    fn bad_parse_panics() {
        let a = Args::parse_from(["run", "--q", "abc"]);
        let _: usize = a.get_or("q", 0);
    }

    #[test]
    fn empty_input() {
        let a = Args::parse_from(Vec::<String>::new());
        assert_eq!(a.subcommand(), None);
    }
}
