//! Property-testing mini-framework (`proptest` is unavailable offline).
//!
//! [`check`] runs a property over `cases` seeded inputs; on failure it
//! reports the failing seed so the case can be replayed deterministically:
//!
//! ```
//! use fdsvrg::testkit::{check, Gen};
//! check("sum is commutative", 64, |g| {
//!     let a = g.f64_in(-10.0, 10.0);
//!     let b = g.f64_in(-10.0, 10.0);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Set `FDSVRG_PROP_SEED=<n>` to replay one particular case and
//! `FDSVRG_PROP_CASES=<n>` to crank the case count in long CI runs.

use crate::util::Pcg64;

/// Random input generator handed to properties.
pub struct Gen {
    rng: Pcg64,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Pcg64::seed_from_u64(seed), seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        assert!(hi_inclusive >= lo);
        lo + self.rng.below(hi_inclusive - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    /// A random sparse matrix (rows × cols CSC with ~`nnz` entries).
    pub fn sparse(&mut self, rows: usize, cols: usize, nnz: usize) -> crate::sparse::CscMatrix {
        let mut b = crate::sparse::CooBuilder::new(rows, cols);
        for _ in 0..nnz {
            b.push(self.rng.below(rows), self.rng.below(cols), self.f64_in(-2.0, 2.0));
        }
        b.to_csc()
    }
}

/// Run `prop` over `default_cases` generated cases (override with
/// `FDSVRG_PROP_CASES`; pin one case with `FDSVRG_PROP_SEED`).
pub fn check<F: Fn(&mut Gen)>(name: &str, default_cases: usize, prop: F) {
    if let Ok(seed) = std::env::var("FDSVRG_PROP_SEED") {
        let seed: u64 = seed.parse().expect("FDSVRG_PROP_SEED must be a u64");
        let mut g = Gen::new(seed);
        prop(&mut g);
        return;
    }
    let cases = std::env::var("FDSVRG_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_cases);
    for case in 0..cases {
        // derive per-case seeds from the property name so adding properties
        // doesn't shift existing ones
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV offset
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        let seed = h ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| e.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property {name:?} failed on case {case} (replay with \
                 FDSVRG_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 16, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always-fails", 4, |_| panic!("intentional"));
        });
        let msg = match r {
            Err(e) => e.downcast_ref::<String>().cloned().unwrap(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("FDSVRG_PROP_SEED="), "{msg}");
        assert!(msg.contains("intentional"), "{msg}");
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(9);
        let mut b = Gen::new(9);
        assert_eq!(a.vec_f64(8, -1.0, 1.0), b.vec_f64(8, -1.0, 1.0));
        assert_eq!(a.usize_in(3, 17), b.usize_in(3, 17));
    }

    #[test]
    fn sparse_gen_valid() {
        let mut g = Gen::new(4);
        let m = g.sparse(20, 10, 50);
        assert_eq!(m.rows(), 20);
        assert_eq!(m.cols(), 10);
        assert!(m.nnz() <= 50);
    }
}
