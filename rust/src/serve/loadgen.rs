//! Seeded load generation and latency accounting for the serving plane.
//!
//! [`LoadGen`] is the traffic source: a [`Pcg64`]-seeded stream of sparse
//! queries drawn from a [`QuerySource`] (real dataset instances, a
//! synthetic power-law generator, or a fixed list for tests), issued under
//! one of two arrival disciplines ([`ArrivalMode`]): *closed* — a fixed
//! pool of clients, each re-issuing the moment its response lands — or
//! *open* — a Poisson process at a target rate, independent of completions.
//!
//! [`LatencyHistogram`] is the sink: log-spaced buckets (1 µs base,
//! 2^(1/8) growth) so p50/p99 over millions of samples cost O(buckets)
//! memory, with exact min/max/mean kept on the side. Every number either
//! side produces is a pure function of the seed and the simulated
//! timeline, which is what makes the serving reports bit-stable across
//! reruns (see the determinism contract in DESIGN.md).
//!
//! The robust router (see [`super::RobustSpec`]) leans on one extra
//! property: the k-th query is drawn from the stream *before* any
//! admission decision is made, so the identity of each arrival is
//! invariant under the `--queue-cap` shed policy — capping the queue
//! changes which queries are answered, never which query the k-th
//! arrival *is*.

use super::Query;
use crate::sparse::CscMatrix;
use crate::util::Pcg64;
use std::sync::Arc;

/// Arrival discipline of the generated traffic.
#[derive(Clone, Copy, Debug)]
pub enum ArrivalMode {
    /// `concurrency` clients, each with exactly one query outstanding:
    /// all issue at t=0, and every completion re-issues immediately. The
    /// canonical throughput-probing loop (offered load tracks capacity).
    Closed { concurrency: usize },
    /// Poisson arrivals at `rate` queries/second, independent of
    /// completions — the overload/latency-probing mode (queues grow when
    /// the offered rate beats the plane's capacity).
    Open { rate: f64 },
}

impl ArrivalMode {
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalMode::Closed { .. } => "closed",
            ArrivalMode::Open { .. } => "open",
        }
    }
}

/// Where query payloads come from.
#[derive(Clone)]
pub enum QuerySource {
    /// Sample real instances: each query is a uniformly drawn column of
    /// the dataset's design matrix (realistic sparsity and index
    /// distribution for the profile being served).
    Columns(Arc<CscMatrix>),
    /// Synthetic text-like queries: `nnz` distinct features drawn from a
    /// zipf(1.05) power law over `[0, d)`, values standard normal.
    Synthetic { d: usize, nnz: usize },
    /// A fixed list, issued round-robin — property tests pin the sharded
    /// margins against a local reference on exactly these queries.
    Fixed(Arc<Vec<Query>>),
}

/// Seeded query stream. Deterministic: the k-th query is a pure function
/// of `(seed, source)`, independent of arrival timing or batching.
pub struct LoadGen {
    rng: Pcg64,
    source: QuerySource,
    issued: usize,
}

impl LoadGen {
    pub fn new(seed: u64, source: QuerySource) -> LoadGen {
        // Domain-separated from the training streams (same seed flag on
        // the CLI must not correlate serving traffic with minibatch order).
        LoadGen { rng: Pcg64::seed_from_u64(seed ^ 0x5e54_11a6), source, issued: 0 }
    }

    /// Next query in the stream.
    pub fn next_query(&mut self) -> Query {
        let k = self.issued;
        self.issued += 1;
        match &self.source {
            QuerySource::Columns(x) => {
                let j = self.rng.below(x.cols());
                let (idx, val) = x.col(j);
                Query { idx: idx.to_vec(), val: val.to_vec() }
            }
            QuerySource::Synthetic { d, nnz } => {
                let want = (*nnz).min(*d).max(1);
                let mut idx: Vec<u32> = Vec::with_capacity(want);
                // rejection-sample distinct features; the power law makes
                // low indices hot, like real text features
                while idx.len() < want {
                    let i = self.rng.zipf(*d, 1.05) as u32;
                    if !idx.contains(&i) {
                        idx.push(i);
                    }
                }
                idx.sort_unstable();
                let val: Vec<f64> = (0..want).map(|_| self.rng.normal()).collect();
                Query { idx, val }
            }
            QuerySource::Fixed(qs) => qs[k % qs.len()].clone(),
        }
    }

    /// Exponential inter-arrival gap for [`ArrivalMode::Open`] at `rate`
    /// arrivals/second (inverse-CDF on the same seeded stream).
    pub fn exp_gap(&mut self, rate: f64) -> f64 {
        let u = self.rng.next_f64();
        -(1.0 - u).max(f64::MIN_POSITIVE).ln() / rate.max(1e-9)
    }
}

/// Log-bucketed latency histogram: bucket `i` covers
/// `[BASE·G^i, BASE·G^(i+1))` with `BASE` = 1 µs and `G` = 2^(1/8)
/// (~9% resolution), plus exact min/max/mean. Quantiles interpolate
/// geometrically inside the winning bucket — a deterministic pure
/// function of the recorded counts.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const BASE_S: f64 = 1e-6;
/// Buckets per octave: G = 2^(1/8).
const PER_OCTAVE: f64 = 8.0;

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            buckets: Vec::new(),
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }

    fn bucket_of(latency_s: f64) -> usize {
        if latency_s <= BASE_S {
            return 0;
        }
        ((latency_s / BASE_S).log2() * PER_OCTAVE).floor() as usize
    }

    /// Lower edge of bucket `i`, seconds.
    fn edge(i: usize) -> f64 {
        BASE_S * (2.0f64).powf(i as f64 / PER_OCTAVE)
    }

    pub fn record(&mut self, latency_s: f64) {
        let b = Self::bucket_of(latency_s);
        if b >= self.buckets.len() {
            self.buckets.resize(b + 1, 0);
        }
        self.buckets[b] += 1;
        self.count += 1;
        self.sum += latency_s;
        self.min = self.min.min(latency_s);
        self.max = self.max.max(latency_s);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Quantile `p` in `[0, 1]`, seconds: find the bucket holding the
    /// `⌈p·count⌉`-th sample, interpolate geometrically by its position
    /// inside the bucket, clamp to the exact observed min/max.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c >= rank {
                let frac = (rank - cum) as f64 / c as f64;
                let lo = Self::edge(i);
                let hi = Self::edge(i + 1);
                let v = lo * (hi / lo).powf(frac);
                return v.clamp(self.min, self.max);
            }
            cum += c;
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let mut h = LatencyHistogram::new();
        for k in 1..=1000 {
            h.record(k as f64 * 1e-6); // 1µs .. 1ms uniform
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 > 3e-4 && p50 < 7e-4, "p50 {p50}");
        assert!(p99 > 8.5e-4 && p99 <= 1e-3, "p99 {p99}");
        assert!(h.quantile(1.0) == h.max());
        assert!(h.mean() > 4.5e-4 && h.mean() < 5.5e-4);
    }

    #[test]
    fn loadgen_streams_are_reproducible() {
        let src = QuerySource::Synthetic { d: 500, nnz: 12 };
        let mut a = LoadGen::new(7, src.clone());
        let mut b = LoadGen::new(7, src);
        for _ in 0..50 {
            let (qa, qb) = (a.next_query(), b.next_query());
            assert_eq!(qa.idx, qb.idx);
            assert_eq!(qa.val, qb.val);
            assert!(qa.idx.windows(2).all(|w| w[0] < w[1]), "ascending, distinct");
        }
        // the exponential gaps ride the same stream deterministically
        assert_eq!(a.exp_gap(1e4), b.exp_gap(1e4));
    }
}
