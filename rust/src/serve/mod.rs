//! Sharded inference plane: batched margin-merge serving over the
//! feature-distributed layout, with replication, failover, hedging, and
//! load shedding under the injected fault plane.
//!
//! Training ends, the layout stays: a d-dimensional linear model trained
//! feature-distributed is *served* feature-distributed. Node 0 is the
//! router front-end; shard `s` of `q` holds one contiguous feature range
//! of the weight vector (the same nnz-balanced partition
//! [`crate::sparse::partition::by_features`] gives the trainer) as a
//! [`ShardServer`]. Under `--replicas r` each shard runs `r` identical
//! copies — replica `c` of shard `s` is node `1 + c·q + s`, so the
//! replica-0 set is nodes `1..=q`, exactly the unreplicated layout — and
//! the cluster is `q·r + 1` nodes. A query's margin factors over shards
//! exactly like the trainer's partial products:
//!
//! ```text
//!   wᵀx = Σ_l  w^(l)ᵀ x^(l)
//! ```
//!
//! so serving one batch is: the router fans the encoded batch to one
//! live replica per shard ([`crate::net::tags::QUERY`]), each replica
//! computes its partial margins against a read-optimized weight snapshot
//! ([`ShardWeights`]: exact `f64` or an `f32`-quantized slab riding the
//! `--wire f32` machinery), and the partials come straight back on
//! [`crate::net::tags::SERVE_RESP`] — a star gather the router merges in
//! ascending shard order (a plain left-to-right chain starting at 0.0,
//! the association [`reference_margins`] replays). The star carries the
//! same q messages of `take` scalars the old reduce tree did; it exists
//! because failover needs a per-replica conversation, not a fixed tree.
//!
//! **Batching policy** ([`BatchPolicy`]): a batch closes when it reaches
//! `max_batch` queries or `max_delay` seconds after its first admitted
//! query, whichever comes first; the router dispatches one batch at a
//! time. Batching is where the throughput comes from — the per-message
//! overhead (`per_msg`, wire latency, one gather round-trip) amortizes
//! over the whole batch.
//!
//! **Robustness** ([`RobustSpec`]): the serving plane composes with the
//! PR 8 fault plane (`--faults` crash/drop/dup/reorder/partition specs)
//! in *cooperative crash* mode — a scheduled crash makes the replica's
//! loop return cleanly at its next protocol boundary, so peers observe
//! [`crate::net::Arrival::Gone`] instead of a torn-down cluster. The
//! router reacts with the failover state machine documented on
//! [`run_router`]: primaries per shard, bounded retry with linear
//! backoff against the next live replica, optional hedged dispatch
//! (`--hedge`), a per-batch service deadline (`--serve-deadline`), a
//! bounded open-loop admission queue (`--queue-cap`), and degraded
//! answers carrying a missing-shard bitmask when a feature range has no
//! live replica left. Every query lands in exactly one of four buckets —
//! `ok`, `degraded`, `late`, `shed` — and they sum to the offered count.
//!
//! **Determinism contract**: the simulation runs on
//! [`Endpoint::set_modeled_time`] — the clock moves only on model charges
//! (message occupancy, explicit [`cost`] constants via
//! [`Endpoint::charge_modeled`]) — and all traffic comes from a seeded
//! [`LoadGen`]. Failure handling preserves this: the router never
//! branches on passively-observed death flags (sends are
//! [`Endpoint::send_lossy`] — always charged, delivery failure ignored),
//! and truth about a peer resolves only at the paired
//! [`Endpoint::recv_from_failable`], whose outcome per-link FIFO makes
//! host-race independent. Hedged answers are drained in a fixed order
//! and ranked by their *modeled* arrival stamps, not by which host
//! thread ran first. Every reported number (p50/p99/QPS/availability/
//! margin checksum) is therefore a pure function of `(spec, seed)`:
//! bit-identical across reruns and `--threads K`.

mod loadgen;

pub use loadgen::{ArrivalMode, LatencyHistogram, LoadGen, QuerySource};

use crate::cluster::run_cluster_model;
use crate::net::fault::{FaultPlan, LinkFaults};
use crate::net::{tags, Endpoint, Msg, NetModel, NodeId, Payload, WireFmt};
use crate::sparse::CscMatrix;
use std::collections::VecDeque;
use std::sync::Arc;

/// The front-end node id (shard replicas are `1..=q·r`).
pub const ROUTER: NodeId = 0;

/// Deterministic modeled compute costs (seconds of serial work) charged
/// through [`Endpoint::charge_modeled`]. These replace measured thread CPU
/// on the serving plane — the clock must be a pure function of the spec —
/// and sit in one place so the model is auditable. Scenario compute
/// scales (the straggler factor) still multiply them.
pub mod cost {
    /// Shard: one in-range nonzero product against the exact f64 shard.
    pub const SHARD_PER_NZ_F64: f64 = 2.0e-9;
    /// Shard: one in-range nonzero product against the f32-quantized
    /// slab (half the memory traffic of the f64 path).
    pub const SHARD_PER_NZ_F32: f64 = 1.2e-9;
    /// Shard: per-query overhead (batch walk, bounds filter).
    pub const SHARD_PER_QUERY: f64 = 60.0e-9;
    /// Shard: per-batch overhead (decode, partial buffer reset).
    pub const SHARD_PER_BATCH: f64 = 2.0e-6;
    /// Router: per-query admission (validation + batch encode share).
    pub const ROUTER_PER_QUERY: f64 = 120.0e-9;
    /// Router: per-batch overhead (close decision, fan-out setup).
    pub const ROUTER_PER_BATCH: f64 = 1.5e-6;
    /// Router: base backoff before re-dispatching a batch to the next
    /// replica after a failover (attempt `k` waits `k` times this).
    pub const RETRY_BACKOFF: f64 = 100.0e-6;
}

/// One sparse query: feature indices (strictly ascending) and values.
#[derive(Clone, Debug, PartialEq)]
pub struct Query {
    pub idx: Vec<u32>,
    pub val: Vec<f64>,
}

impl Query {
    /// Build from unordered pairs (sorts by index; duplicates survive and
    /// are caught by [`Query::validate`]).
    pub fn from_pairs(mut pairs: Vec<(u32, f64)>) -> Query {
        pairs.sort_by_key(|p| p.0);
        Query {
            idx: pairs.iter().map(|p| p.0).collect(),
            val: pairs.iter().map(|p| p.1).collect(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Admission check against a `d`-feature model. Empty queries are
    /// fine (margin 0); duplicate, descending, or out-of-range indices
    /// are rejected with enough context to debug the client.
    pub fn validate(&self, d: usize) -> Result<(), String> {
        if self.idx.len() != self.val.len() {
            return Err(format!(
                "query index/value length mismatch: {} indices vs {} values",
                self.idx.len(),
                self.val.len()
            ));
        }
        for (k, &i) in self.idx.iter().enumerate() {
            if i as usize >= d {
                return Err(format!(
                    "query feature index {i} out of range for a d={d} model"
                ));
            }
            if k > 0 {
                if i == self.idx[k - 1] {
                    return Err(format!("duplicate feature index {i} in query"));
                }
                if i < self.idx[k - 1] {
                    return Err(format!(
                        "query indices must be ascending: {} after {}",
                        i,
                        self.idx[k - 1]
                    ));
                }
            }
        }
        Ok(())
    }
}

/// Batch close policy: dispatch at `max_batch` queries or `max_delay`
/// seconds after the first admitted query, whichever comes first.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_delay: f64,
}

/// Robustness knobs for one serving run (the `--replicas` /
/// `--serve-deadline` / `--hedge` / `--queue-cap` / `--faults` flags).
/// The default is the failure-free PR 9 plane: one replica, no deadline,
/// no hedging, unbounded queue, no faults.
#[derive(Clone)]
pub struct RobustSpec {
    /// Copies of each shard (`r ≥ 1`); the cluster is `q·r + 1` nodes.
    pub replicas: usize,
    /// Per-batch service deadline in modeled seconds, measured from batch
    /// close to merge completion; `0` disables. Missed batches still
    /// answer, but every query in them counts `late` instead of `ok`.
    pub deadline: f64,
    /// Hedge delay in modeled seconds: each batch is also dispatched to a
    /// second live replica, and the hedge's answer wins if its modeled
    /// arrival plus this delay beats the primary's. Negative disables.
    pub hedge: f64,
    /// Open-mode admission queue bound; an arrival that finds the queue
    /// full is shed (counted, never served). `0` = unbounded. Ignored in
    /// closed mode (the concurrency cap already bounds admissions).
    pub queue_cap: usize,
    /// Seeded fault plan (crash/drop/dup/reorder/partition), installed in
    /// cooperative-crash mode on every node. The router (node 0) is
    /// uncrashable; a passive plan is a bit-exact identity.
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for RobustSpec {
    fn default() -> Self {
        RobustSpec { replicas: 1, deadline: 0.0, hedge: -1.0, queue_cap: 0, faults: None }
    }
}

/// A shard's read-optimized weight snapshot: the exact f64 reference, or
/// the f32-quantized slab (the serving twin of the `--wire f32` codec and
/// the trainer's `dense_slab_f32` mirrors — half the bytes, ~2× the scan
/// rate, one rounding per weight).
pub enum ShardWeights {
    Exact(Vec<f64>),
    Quantized(Vec<f32>),
}

impl ShardWeights {
    pub fn new(w: &[f64], lo: usize, hi: usize, quantize: bool) -> ShardWeights {
        if quantize {
            ShardWeights::Quantized(w[lo..hi].iter().map(|&v| v as f32).collect())
        } else {
            ShardWeights::Exact(w[lo..hi].to_vec())
        }
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self, ShardWeights::Quantized(_))
    }

    /// Snapshot bytes held by this shard.
    pub fn bytes(&self) -> usize {
        match self {
            ShardWeights::Exact(v) => 8 * v.len(),
            ShardWeights::Quantized(v) => 4 * v.len(),
        }
    }

    fn per_nz_cost(&self) -> f64 {
        match self {
            ShardWeights::Exact(_) => cost::SHARD_PER_NZ_F64,
            ShardWeights::Quantized(_) => cost::SHARD_PER_NZ_F32,
        }
    }
}

/// One shard server: feature range `[lo, hi)` plus its weight snapshot.
/// Replicas of the same shard are bit-identical, so any live replica's
/// answer is interchangeable — the property failover and hedging rest on.
pub struct ShardServer {
    pub lo: usize,
    pub hi: usize,
    pub weights: ShardWeights,
}

impl ShardServer {
    pub fn from_snapshot(w: &[f64], lo: usize, hi: usize, quantize: bool) -> ShardServer {
        ShardServer { lo, hi, weights: ShardWeights::new(w, lo, hi, quantize) }
    }

    /// Partial margin of one query restricted to this shard's range: a
    /// serial ascending-index chain, f64 accumulation in both weight
    /// forms (only the stored weights are quantized).
    pub fn partial_margin(&self, idx: &[u32], val: &[f64]) -> f64 {
        let (lo, hi) = (self.lo as u32, self.hi as u32);
        let mut acc = 0.0f64;
        match &self.weights {
            ShardWeights::Exact(w) => {
                for (k, &i) in idx.iter().enumerate() {
                    if (lo..hi).contains(&i) {
                        acc += val[k] * w[(i - lo) as usize];
                    }
                }
            }
            ShardWeights::Quantized(w) => {
                for (k, &i) in idx.iter().enumerate() {
                    if (lo..hi).contains(&i) {
                        acc += val[k] * w[(i - lo) as usize] as f64;
                    }
                }
            }
        }
        acc
    }

    /// Decode a flat query batch (see [`encode_batch`]; `flat[0]` is the
    /// batch id, skipped here) and write one partial margin per query
    /// into `out`. Returns the number of in-range nonzeros actually
    /// multiplied (the modeled-cost driver).
    pub fn batch_partials(&self, flat: &[f64], out: &mut Vec<f64>) -> usize {
        let nq = flat[1] as usize;
        out.clear();
        out.reserve(nq);
        let (lo, hi) = (self.lo as u32, self.hi as u32);
        let mut scanned = 0usize;
        let mut pos = 2usize;
        for _ in 0..nq {
            let nnz = flat[pos] as usize;
            let idx = &flat[pos + 1..pos + 1 + nnz];
            let val = &flat[pos + 1 + nnz..pos + 1 + 2 * nnz];
            let mut acc = 0.0f64;
            match &self.weights {
                ShardWeights::Exact(w) => {
                    for (iv, &v) in idx.iter().zip(val) {
                        let i = *iv as u32;
                        if (lo..hi).contains(&i) {
                            acc += v * w[(i - lo) as usize];
                            scanned += 1;
                        }
                    }
                }
                ShardWeights::Quantized(w) => {
                    for (iv, &v) in idx.iter().zip(val) {
                        let i = *iv as u32;
                        if (lo..hi).contains(&i) {
                            acc += v * w[(i - lo) as usize] as f64;
                            scanned += 1;
                        }
                    }
                }
            }
            out.push(acc);
            pos += 1 + 2 * nnz;
        }
        scanned
    }

    /// Modeled serial cost of one decoded batch.
    pub fn batch_cost(&self, nq: usize, scanned_nz: usize) -> f64 {
        cost::SHARD_PER_BATCH
            + cost::SHARD_PER_QUERY * nq as f64
            + self.weights.per_nz_cost() * scanned_nz as f64
    }
}

/// Flat wire layout of a query batch (always exact f64 — quantizing
/// *queries* would corrupt indices):
/// `[bid, nq, nnz_1, idx_1.., val_1.., nnz_2, ...]` — u32 indices and the
/// batch id are exact as f64. The leading batch id lets retried and
/// hedged dispatches be matched to their answers by value instead of by
/// arrival order.
pub fn encode_batch(bid: u64, queries: &[Query]) -> Vec<f64> {
    let scalars = 2 + queries.iter().map(|q| 1 + 2 * q.nnz()).sum::<usize>();
    let mut flat = Vec::with_capacity(scalars);
    flat.push(bid as f64);
    flat.push(queries.len() as f64);
    for q in queries {
        flat.push(q.nnz() as f64);
        flat.extend(q.idx.iter().map(|&i| i as f64));
        flat.extend_from_slice(&q.val);
    }
    flat
}

/// Everything one serving simulation needs. `bounds` is the per-shard
/// feature partition (`[lo, hi)` per shard, covering `[0, d)` in order) —
/// take it from [`crate::sparse::partition::by_features`] to serve the
/// training layout.
pub struct ServeSpec<'a> {
    pub w: &'a [f64],
    pub bounds: Vec<(usize, usize)>,
    pub model: NetModel,
    pub wire: WireFmt,
    pub policy: BatchPolicy,
    pub queries: usize,
    pub mode: ArrivalMode,
    pub seed: u64,
    pub source: QuerySource,
    /// Keep every merged margin (+ missing-shard mask) in issue order —
    /// tests pin them against [`reference_margins`]; off for load runs
    /// (O(total) memory).
    pub collect_margins: bool,
    /// Replication/failover/hedging/shedding knobs (default: the
    /// failure-free single-replica plane).
    pub robust: RobustSpec,
}

/// What one simulation reports: the latency distribution, throughput,
/// availability accounting, and enough configuration echo to be a
/// self-describing JSON row. The accounting invariant (pinned by tests):
/// `queries = ok + degraded + late + shed`, with per-query precedence
/// late > degraded > ok.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub scenario: &'static str,
    pub wire: &'static str,
    pub q: usize,
    pub replicas: usize,
    pub max_batch: usize,
    pub max_delay_us: f64,
    pub deadline_us: f64,
    /// Hedge delay in µs; `-1` when hedging is off.
    pub hedge_us: f64,
    pub queue_cap: usize,
    /// Canonical `--faults` spec, `"none"` without a plan.
    pub faults: String,
    pub mode: &'static str,
    pub concurrency: usize,
    pub rate: f64,
    /// Offered queries (the full seeded stream).
    pub queries: usize,
    /// Queries that got an answer (`ok + degraded + late`).
    pub answered: usize,
    /// Answered in time with every shard contributing.
    pub ok: usize,
    /// Answered with at least one shard's range missing (no live replica).
    pub degraded: usize,
    /// Answered after the per-batch service deadline.
    pub late: usize,
    /// Rejected at admission (open-mode queue cap).
    pub shed: usize,
    /// `100 · ok / queries`.
    pub availability_pct: f64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_us: f64,
    pub p90_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    pub mean_us: f64,
    /// Answered queries per simulated second.
    pub qps: f64,
    /// `ok` queries per simulated second — throughput that met the SLO.
    pub goodput_qps: f64,
    pub sim_time_s: f64,
    pub wire_bytes: u64,
    pub bytes_per_query: f64,
    /// Primary replicas observed dead by the router (each moves the
    /// shard's primary to the next live replica).
    pub failovers: u64,
    /// Re-dispatches after a failover (each charged a linear backoff).
    pub retries: u64,
    /// Hedge copies dispatched.
    pub hedged: u64,
    /// Batches where the hedge's answer won (faster modeled arrival or
    /// the primary died).
    pub hedge_wins: u64,
    /// Scheduled crashes that actually fired (an idle replica whose
    /// clock never reaches its crash time dies only at shutdown).
    pub crashes: u64,
    /// Σ of all merged margins in issue order — a one-number bit-stability
    /// witness for the whole numeric path.
    pub margin_checksum: f64,
}

impl ServeReport {
    /// One hand-rolled JSON object (no trailing comma/newline) — shared
    /// by `serve --out` and the `exp serving`/`exp serving-faults` report
    /// writers. Deliberately separate from the golden-pinned
    /// [`crate::metrics::json::run_result_to_json`] layout.
    pub fn to_json_row(&self) -> String {
        format!(
            "{{\"scenario\": \"{}\", \"wire\": \"{}\", \"q\": {}, \
             \"replicas\": {}, \"max_batch\": {}, \"max_delay_us\": {}, \
             \"deadline_us\": {}, \"hedge_us\": {}, \"queue_cap\": {}, \
             \"faults\": \"{}\", \"mode\": \"{}\", \
             \"concurrency\": {}, \"rate\": {}, \"queries\": {}, \
             \"answered\": {}, \"ok\": {}, \"degraded\": {}, \
             \"late\": {}, \"shed\": {}, \"availability_pct\": {}, \
             \"batches\": {}, \"mean_batch\": {}, \"p50_us\": {}, \
             \"p90_us\": {}, \"p99_us\": {}, \"max_us\": {}, \
             \"mean_us\": {}, \"qps\": {}, \"goodput_qps\": {}, \
             \"sim_time_s\": {}, \"wire_bytes\": {}, \
             \"bytes_per_query\": {}, \"failovers\": {}, \"retries\": {}, \
             \"hedged\": {}, \"hedge_wins\": {}, \"crashes\": {}, \
             \"margin_checksum\": {}}}",
            self.scenario,
            self.wire,
            self.q,
            self.replicas,
            self.max_batch,
            self.max_delay_us,
            self.deadline_us,
            self.hedge_us,
            self.queue_cap,
            self.faults,
            self.mode,
            self.concurrency,
            self.rate,
            self.queries,
            self.answered,
            self.ok,
            self.degraded,
            self.late,
            self.shed,
            self.availability_pct,
            self.batches,
            self.mean_batch,
            self.p50_us,
            self.p90_us,
            self.p99_us,
            self.max_us,
            self.mean_us,
            self.qps,
            self.goodput_qps,
            self.sim_time_s,
            self.wire_bytes,
            self.bytes_per_query,
            self.failovers,
            self.retries,
            self.hedged,
            self.hedge_wins,
            self.crashes,
            self.margin_checksum,
        )
    }
}

/// A full simulation's outputs.
pub struct ServeOutcome {
    pub report: ServeReport,
    /// Merged margins in issue order (only when
    /// [`ServeSpec::collect_margins`]).
    pub margins: Option<Vec<f64>>,
    /// Missing-shard bitmask per answered query, parallel to `margins`
    /// (bit `s` set ⇔ shard `s` had no live replica when that query's
    /// batch was merged). All-zero on failure-free runs.
    pub masks: Option<Vec<u64>>,
}

/// Router-side replica bookkeeping. Replica `c` of shard `s` is node
/// `1 + c·q + s`; `alive` is the router's *observed* view (a replica is
/// marked dead only on a failed receive — never revived), and `primary`
/// is the replica currently fielding each shard's traffic.
struct Fleet {
    q: usize,
    r: usize,
    alive: Vec<bool>,
    primary: Vec<usize>,
}

impl Fleet {
    fn new(q: usize, r: usize) -> Fleet {
        Fleet { q, r, alive: vec![true; q * r], primary: vec![0; q] }
    }

    fn node(&self, s: usize, c: usize) -> NodeId {
        1 + c * self.q + s
    }

    fn is_alive(&self, s: usize, c: usize) -> bool {
        self.alive[s * self.r + c]
    }

    fn kill(&mut self, s: usize, c: usize) {
        self.alive[s * self.r + c] = false;
    }

    /// The shard's primary if still believed alive, else fail over to the
    /// lowest live replica (sticky: the choice persists across batches).
    fn pick_primary(&mut self, s: usize) -> Option<usize> {
        if self.is_alive(s, self.primary[s]) {
            return Some(self.primary[s]);
        }
        for c in 0..self.r {
            if self.is_alive(s, c) {
                self.primary[s] = c;
                return Some(c);
            }
        }
        None
    }

    /// Lowest live replica other than `not` — the hedge target.
    fn other_alive(&self, s: usize, not: usize) -> Option<usize> {
        (0..self.r).find(|&c| c != not && self.is_alive(s, c))
    }
}

#[derive(Default)]
struct RobustCounters {
    failovers: u64,
    retries: u64,
    hedged: u64,
    hedge_wins: u64,
}

struct RouterOut {
    hist: LatencyHistogram,
    batches: u64,
    last_done: f64,
    checksum: f64,
    margins: Option<Vec<f64>>,
    masks: Option<Vec<u64>>,
    answered: usize,
    ok: usize,
    degraded: usize,
    late: usize,
    shed: usize,
    counters: RobustCounters,
}

/// Run one serving simulation: `q = bounds.len()` shards × `r` replicas
/// plus the router on `q·r + 1` sim nodes under `spec.model`, driven by
/// the seeded load generator until every offered query is answered or
/// shed. Entry errors (bad shapes, incompatible robustness knobs, fault
/// plans targeting the router) surface as `Err` with context instead of
/// panics.
pub fn simulate(spec: &ServeSpec) -> Result<ServeOutcome, String> {
    let q = spec.bounds.len();
    let rs = &spec.robust;
    if q == 0 {
        return Err("serve: need at least one shard (empty feature partition)".to_string());
    }
    if spec.policy.max_batch == 0 {
        return Err("serve: max_batch must be ≥ 1".to_string());
    }
    if spec.queries == 0 {
        return Err("serve: need at least one query".to_string());
    }
    if rs.replicas == 0 {
        return Err("serve: --replicas must be ≥ 1".to_string());
    }
    if rs.hedge >= 0.0 && rs.replicas < 2 {
        return Err(
            "serve: --hedge races a second replica per shard; it needs --replicas ≥ 2"
                .to_string(),
        );
    }
    let n_nodes = 1 + q * rs.replicas;
    if let Some(plan) = &rs.faults {
        plan.validate(n_nodes).map_err(|e| format!("serve: {e}"))?;
        if plan.crashes().iter().any(|c| c.node == ROUTER) {
            return Err(format!(
                "serve: the router (node 0) is uncrashable — schedule crashes on shard \
                 nodes 1..={}",
                n_nodes - 1
            ));
        }
        if q > 64 {
            return Err(format!(
                "serve: degraded-answer masks track at most 64 shards under --faults \
                 (got q={q})"
            ));
        }
    }
    let d = spec.bounds.last().unwrap().1;
    let quantize = spec.wire == WireFmt::F32;
    let run = run_cluster_model(n_nodes, &spec.model, |mut ep| {
        ep.set_modeled_time(true);
        if let Some(plan) = &rs.faults {
            ep.install_faults_cooperative(LinkFaults::new(plan.clone(), ep.id()));
        }
        if ep.id() == ROUTER {
            Some(run_router(&mut ep, spec, d))
        } else {
            // Replica c of shard s is node 1 + c·q + s.
            let s = (ep.id() - 1) % q;
            let (lo, hi) = spec.bounds[s];
            run_shard(&mut ep, ShardServer::from_snapshot(spec.w, lo, hi, quantize), spec.wire);
            None
        }
    });
    let out = run
        .results
        .into_iter()
        .flatten()
        .next()
        .ok_or_else(|| "serve: router produced no report".to_string())?;
    let wire_bytes = run.stats.total_bytes();
    let (concurrency, rate) = match spec.mode {
        ArrivalMode::Closed { concurrency } => (concurrency, 0.0),
        ArrivalMode::Open { rate } => (0, rate),
    };
    let offered = spec.queries;
    debug_assert_eq!(out.ok + out.degraded + out.late, out.answered);
    debug_assert_eq!(out.answered + out.shed, offered);
    let report = ServeReport {
        scenario: spec.model.name(),
        wire: spec.wire.name(),
        q,
        replicas: rs.replicas,
        max_batch: spec.policy.max_batch,
        max_delay_us: spec.policy.max_delay * 1e6,
        deadline_us: rs.deadline * 1e6,
        hedge_us: if rs.hedge >= 0.0 { rs.hedge * 1e6 } else { -1.0 },
        queue_cap: rs.queue_cap,
        faults: rs.faults.as_ref().map_or_else(|| "none".to_string(), |p| p.spec().to_string()),
        mode: spec.mode.name(),
        concurrency,
        rate,
        queries: offered,
        answered: out.answered,
        ok: out.ok,
        degraded: out.degraded,
        late: out.late,
        shed: out.shed,
        availability_pct: 100.0 * out.ok as f64 / offered as f64,
        batches: out.batches,
        mean_batch: out.answered as f64 / out.batches.max(1) as f64,
        p50_us: out.hist.quantile(0.50) * 1e6,
        p90_us: out.hist.quantile(0.90) * 1e6,
        p99_us: out.hist.quantile(0.99) * 1e6,
        max_us: out.hist.max() * 1e6,
        mean_us: out.hist.mean() * 1e6,
        qps: out.answered as f64 / out.last_done.max(1e-12),
        goodput_qps: out.ok as f64 / out.last_done.max(1e-12),
        sim_time_s: out.last_done,
        wire_bytes,
        bytes_per_query: wire_bytes as f64 / out.answered.max(1) as f64,
        failovers: out.counters.failovers,
        retries: out.counters.retries,
        hedged: out.counters.hedged,
        hedge_wins: out.counters.hedge_wins,
        crashes: rs.faults.as_ref().map_or(0, |p| p.stats().crashes),
        margin_checksum: out.checksum,
    };
    Ok(ServeOutcome { report, margins: out.margins, masks: out.masks })
}

/// The shard main loop: receive a frame from the router, compute
/// partials, charge the modeled cost, send them straight back on
/// [`tags::SERVE_RESP`]. Shutdown is an explicit [`tags::SERVE_CTRL`]
/// frame — never a magic query payload, so faulty/reordered delivery
/// can't fake it. Scheduled crashes are polled cooperatively at the loop
/// top and again between compute and reply, so a replica can die holding
/// a batch (the case the router's failover path exists for). A dead
/// router means no one is left to serve: log it loudly and shut down.
fn run_shard(ep: &mut Endpoint, shard: ShardServer, wire: WireFmt) {
    let mut partial: Vec<f64> = Vec::new();
    loop {
        if let Some(at) = ep.take_injected_crash() {
            crate::warn_!(
                "serve: shard node {} crashing on schedule (t={at:.6}s)",
                ep.id()
            );
            return;
        }
        let msg = match ep.recv_from_any_failable(ROUTER) {
            Ok(m) => m,
            Err(dead) => {
                crate::warn_!(
                    "serve: shard node {} lost the router (node {dead} disconnected); \
                     shutting down",
                    ep.id()
                );
                return;
            }
        };
        match msg.tag {
            tags::SERVE_CTRL => return,
            tags::QUERY => {}
            other => panic!(
                "serve: shard node {} got unexpected tag {other} from the router",
                ep.id()
            ),
        }
        let flat: &[f64] = match &msg.payload {
            Payload::DenseF64(v) => v,
            other => panic!("serve: query batches travel as exact f64, got {other:?}"),
        };
        let bid = flat[0];
        let nq = flat[1] as usize;
        let scanned = shard.batch_partials(flat, &mut partial);
        ep.charge_modeled(shard.batch_cost(nq, scanned));
        drop(msg);
        // A crash scheduled during the compute fires *before* the reply:
        // the router observes the death while the batch is outstanding
        // and fails over.
        if let Some(at) = ep.take_injected_crash() {
            crate::warn_!(
                "serve: shard node {} crashing on schedule (t={at:.6}s) with a batch in hand",
                ep.id()
            );
            return;
        }
        let mut resp = Vec::with_capacity(1 + partial.len());
        resp.push(bid);
        resp.extend_from_slice(&partial);
        ep.send_lossy(ROUTER, tags::SERVE_RESP, wire.encode(&resp));
    }
}

/// Admit one generated query at time `t`. The query is always drawn (and
/// the seeded stream advanced) *before* the cap check, so the k-th
/// arrival is the same query at any `--queue-cap` — shedding changes who
/// gets served, never who asks. `cap = 0` disables shedding.
fn admit_query(
    pending: &mut VecDeque<(f64, Query)>,
    gen: &mut LoadGen,
    d: usize,
    cap: usize,
    t: f64,
    shed: &mut usize,
) {
    let query = gen.next_query();
    if let Err(e) = query.validate(d) {
        panic!("serve: load generator produced an invalid query: {e}");
    }
    if cap > 0 && pending.len() >= cap {
        *shed += 1;
    } else {
        pending.push_back((t, query));
    }
}

/// Decode one shard response (`[bid, partial_0..partial_{take-1}]`) and
/// check its batch id — per-replica request/response is strictly
/// sequential, so a mismatch is an internal invariant violation, not a
/// network condition.
fn decode_resp(msg: &Msg, bid: u64, take: usize) -> Vec<f64> {
    let flat = msg.to_vec(take + 1);
    assert!(
        flat[0] == bid as f64,
        "serve: internal error: node {} answered batch {} while the router awaited batch {bid}",
        msg.from,
        flat[0]
    );
    flat[1..].to_vec()
}

/// Dispatch one encoded batch to one live replica per shard (plus an
/// optional hedge copy) and merge the answers in ascending shard order.
/// Returns the merged margins and the missing-shard bitmask (bit `s` set
/// ⇔ shard `s` had no live replica left).
///
/// The failover state machine, per shard: send to the primary (and the
/// hedge target when enabled); drain the primary's answer, then the
/// hedge's, in that fixed order — a failed receive kills the replica in
/// the router's view. If neither answered, retry against the next live
/// replica with a linear backoff (`cost::RETRY_BACKOFF · attempt`) until
/// one answers or the shard is out of replicas. Hedge wins are decided
/// by *modeled* arrival stamps (`Endpoint::wire_arrival` + the hedge
/// delay), so the count is deterministic; the drain itself still waits
/// on a slow-but-alive primary — in this blocking modeled-time design
/// hedging pays off against dead or partitioned replicas, not pure
/// stragglers (see DESIGN.md).
fn collect_batch(
    ep: &mut Endpoint,
    fleet: &mut Fleet,
    spec: &ServeSpec,
    payload: &Payload,
    bid: u64,
    take: usize,
    counters: &mut RobustCounters,
) -> (Vec<f64>, u64) {
    let q = fleet.q;
    let rs = &spec.robust;
    // Dispatch: one copy to each shard's primary, plus a hedge copy when
    // enabled and a second live replica exists. Sends are lossy-on-dead
    // and always charged — the router's counters and clock never depend
    // on the host race between a replica's death and this send.
    let mut primary_c: Vec<Option<usize>> = Vec::with_capacity(q);
    let mut hedge_c: Vec<Option<usize>> = vec![None; q];
    for s in 0..q {
        let c = fleet.pick_primary(s);
        if let Some(c) = c {
            ep.send_lossy(fleet.node(s, c), tags::QUERY, payload.clone());
            if rs.hedge >= 0.0 {
                if let Some(h) = fleet.other_alive(s, c) {
                    ep.send_lossy(fleet.node(s, h), tags::QUERY, payload.clone());
                    hedge_c[s] = Some(h);
                    counters.hedged += 1;
                }
            }
        }
        primary_c.push(c);
    }
    // Collect in ascending shard order — the deterministic drain that
    // fixes both the merge association and the clock trajectory.
    let mut merged = vec![0.0f64; take];
    let mut mask = 0u64;
    for s in 0..q {
        // (partials, modeled arrival) of the best answer so far.
        let mut winner: Option<(Vec<f64>, f64)> = None;
        if let Some(c0) = primary_c[s] {
            match ep.recv_from_failable(fleet.node(s, c0), tags::SERVE_RESP) {
                Ok(msg) => {
                    let arr = ep.wire_arrival(&msg);
                    winner = Some((decode_resp(&msg, bid, take), arr));
                }
                Err(dead) => {
                    fleet.kill(s, c0);
                    counters.failovers += 1;
                    crate::warn_!(
                        "serve: shard {s} primary (node {dead}) died; failing over"
                    );
                }
            }
        }
        // The hedge copy is always drained when sent — the mailbox must
        // not leak answers into the next batch.
        if let Some(h) = hedge_c[s] {
            match ep.recv_from_failable(fleet.node(s, h), tags::SERVE_RESP) {
                Ok(msg) => {
                    let arr = ep.wire_arrival(&msg) + rs.hedge;
                    let wins = match &winner {
                        Some((_, primary_arr)) => arr < *primary_arr,
                        // Primary dead: the hedge covered the batch — a
                        // real latency win (no resend round-trip).
                        None => true,
                    };
                    if wins {
                        counters.hedge_wins += 1;
                        winner = Some((decode_resp(&msg, bid, take), arr));
                    }
                }
                Err(dead) => {
                    fleet.kill(s, h);
                    crate::warn_!("serve: shard {s} hedge replica (node {dead}) died");
                }
            }
        }
        // Bounded retry: re-dispatch to the next live replica with a
        // linear backoff until one answers or the shard is exhausted.
        let mut attempt = 0u64;
        while winner.is_none() {
            let Some(c) = fleet.pick_primary(s) else { break };
            attempt += 1;
            counters.retries += 1;
            ep.charge_modeled(cost::RETRY_BACKOFF * attempt as f64);
            ep.send_lossy(fleet.node(s, c), tags::QUERY, payload.clone());
            match ep.recv_from_failable(fleet.node(s, c), tags::SERVE_RESP) {
                Ok(msg) => {
                    let arr = ep.wire_arrival(&msg);
                    winner = Some((decode_resp(&msg, bid, take), arr));
                }
                Err(dead) => {
                    fleet.kill(s, c);
                    counters.failovers += 1;
                    crate::warn_!(
                        "serve: shard {s} replica (node {dead}) died on retry {attempt}"
                    );
                }
            }
        }
        match winner {
            Some((partials, _)) => {
                for k in 0..take {
                    merged[k] += partials[k];
                }
            }
            None => {
                mask |= 1u64 << s;
                let (lo, hi) = spec.bounds[s];
                crate::warn_!(
                    "serve: shard {s} has no live replica; answers degrade over \
                     features [{lo}, {hi})"
                );
            }
        }
    }
    (merged, mask)
}

/// The router main loop: admit seeded traffic (shedding past the queue
/// cap in open mode), close batches under the policy, dispatch through
/// [`collect_batch`]'s failover machinery, classify each answer
/// (late > degraded > ok), record latency, and (closed mode) re-issue.
/// Shutdown is an explicit [`tags::SERVE_CTRL`] to every replica still
/// believed alive.
fn run_router(ep: &mut Endpoint, spec: &ServeSpec, d: usize) -> RouterOut {
    let q = spec.bounds.len();
    let rs = &spec.robust;
    let total = spec.queries;
    let cap = match spec.mode {
        ArrivalMode::Open { .. } => rs.queue_cap,
        ArrivalMode::Closed { .. } => 0,
    };
    let mut fleet = Fleet::new(q, rs.replicas);
    let mut counters = RobustCounters::default();
    let mut gen = LoadGen::new(spec.seed, spec.source.clone());
    let mut hist = LatencyHistogram::new();
    let mut margins_out = spec.collect_margins.then(|| Vec::with_capacity(total));
    let mut masks_out = spec.collect_margins.then(|| Vec::with_capacity(total));
    let mut pending: VecDeque<(f64, Query)> = VecDeque::new();
    let mut issued = 0usize;
    let mut answered = 0usize;
    let mut ok = 0usize;
    let mut degraded = 0usize;
    let mut late = 0usize;
    let mut shed = 0usize;
    let mut batches = 0u64;
    let mut checksum = 0.0f64;
    let mut last_done = 0.0f64;
    // open-mode arrival horizon: simulated time of the next arrival that
    // has not yet been admitted to `pending`
    let mut next_arrival = 0.0f64;

    match spec.mode {
        ArrivalMode::Closed { concurrency } => {
            for _ in 0..concurrency.max(1).min(total) {
                admit_query(&mut pending, &mut gen, d, cap, 0.0, &mut shed);
                issued += 1;
            }
        }
        ArrivalMode::Open { .. } => {}
    }

    while answered + shed < total {
        let t_free = ep.now();
        // Open mode: admit everything that has arrived by the time the
        // router went idle; if nothing survived admission, sleep to the
        // next arrival.
        if let ArrivalMode::Open { rate } = spec.mode {
            while issued < total && next_arrival <= t_free {
                admit_query(&mut pending, &mut gen, d, cap, next_arrival, &mut shed);
                issued += 1;
                next_arrival += gen.exp_gap(rate);
            }
            if answered + shed >= total {
                // The tail of the offered stream was shed at admission.
                break;
            }
            if pending.is_empty() {
                // issued == answered + shed < total here, and the cap
                // can't trigger on an empty queue.
                admit_query(&mut pending, &mut gen, d, cap, next_arrival, &mut shed);
                issued += 1;
                let t = next_arrival;
                next_arrival += gen.exp_gap(rate);
                ep.advance_to(t);
            }
        }
        debug_assert!(!pending.is_empty(), "closed-loop refill keeps the queue nonempty");
        let t0 = pending.front().expect("nonempty").0;
        let open_t = t_free.max(t0);
        // Batch close: full at `open_t`, or wait the delay window (open
        // mode admits what arrives inside it), or the window expires.
        let close_t = if pending.len() >= spec.policy.max_batch {
            open_t
        } else {
            let deadline = (t0 + spec.policy.max_delay).max(open_t);
            let mut closed_at = deadline;
            if let ArrivalMode::Open { rate } = spec.mode {
                while pending.len() < spec.policy.max_batch
                    && issued < total
                    && next_arrival <= deadline
                {
                    let t = next_arrival;
                    admit_query(&mut pending, &mut gen, d, cap, t, &mut shed);
                    issued += 1;
                    next_arrival += gen.exp_gap(rate);
                    if pending.len() == spec.policy.max_batch {
                        closed_at = t.max(open_t);
                    }
                }
            }
            closed_at
        };
        let take = pending.len().min(spec.policy.max_batch);
        let mut arrivals: Vec<f64> = Vec::with_capacity(take);
        let mut batch: Vec<Query> = Vec::with_capacity(take);
        for _ in 0..take {
            let (t, query) = pending.pop_front().expect("sized above");
            arrivals.push(t);
            batch.push(query);
        }
        ep.advance_to(close_t);
        ep.charge_modeled(cost::ROUTER_PER_BATCH + cost::ROUTER_PER_QUERY * take as f64);
        // One encode, one Arc clone per copy sent — the same zero-copy
        // fan-out the training collectives use.
        let bid = batches;
        let payload = Payload::from(encode_batch(bid, &batch));
        let (merged, mask) =
            collect_batch(ep, &mut fleet, spec, &payload, bid, take, &mut counters);
        let t_done = ep.now();
        batches += 1;
        last_done = t_done;
        // Service deadline, post hoc: a batch that merged after
        // `close_t + deadline` still answers, but every query in it
        // counts `late` (precedence: late > degraded > ok).
        let batch_late = rs.deadline > 0.0 && t_done - close_t > rs.deadline;
        for (k, &t_arr) in arrivals.iter().enumerate() {
            hist.record(t_done - t_arr);
            checksum += merged[k];
            if let Some(ms) = margins_out.as_mut() {
                ms.push(merged[k]);
            }
            if let Some(mk) = masks_out.as_mut() {
                mk.push(mask);
            }
            if batch_late {
                late += 1;
            } else if mask != 0 {
                degraded += 1;
            } else {
                ok += 1;
            }
        }
        answered += take;
        if let ArrivalMode::Closed { .. } = spec.mode {
            for _ in 0..take {
                if issued < total {
                    admit_query(&mut pending, &mut gen, d, cap, t_done, &mut shed);
                    issued += 1;
                }
            }
        }
    }
    // Shutdown: an explicit control frame to every replica still believed
    // alive. Lossy on purpose — a replica that crashed after its last
    // reply is already gone, and that must not unwind the router.
    for s in 0..q {
        for c in 0..rs.replicas {
            if fleet.is_alive(s, c) {
                ep.send_lossy(fleet.node(s, c), tags::SERVE_CTRL, vec![0.0f64]);
            }
        }
    }
    RouterOut {
        hist,
        batches,
        last_done,
        checksum,
        margins: margins_out,
        masks: masks_out,
        answered,
        ok,
        degraded,
        late,
        shed,
        counters,
    }
}

/// Local (single-process, no network) replica of what the sharded plane
/// computes for `queries` on the exact f64 path: per-shard partials as
/// ascending-index chains, merged with the *same* plain left-to-right
/// chain (starting at the router's 0.0) that [`run_router`]'s
/// ascending-shard star gather uses. Against this reference the f64
/// sharded sim is bit-exact — including across failovers and hedging,
/// because replicas of a shard hold bit-identical snapshots. At `q = 1`
/// the merge degenerates to the plain serial chain, i.e. the unsharded
/// dense predict.
pub fn reference_margins(w: &[f64], bounds: &[(usize, usize)], queries: &[Query]) -> Vec<f64> {
    let shards: Vec<ShardServer> = bounds
        .iter()
        .map(|&(lo, hi)| ShardServer::from_snapshot(w, lo, hi, false))
        .collect();
    queries
        .iter()
        .map(|query| {
            let mut acc = 0.0f64;
            for s in &shards {
                acc += s.partial_margin(&query.idx, &query.val);
            }
            acc
        })
        .collect()
}

/// All `n` margins `wᵀx_i` of a design matrix into a reused scratch
/// buffer — the allocation-free batch-predict path (`predict --ckpt`):
/// repeated calls reuse capacity instead of allocating per batch.
pub fn dense_margins<'a>(x: &CscMatrix, w: &[f64], buf: &'a mut Vec<f64>) -> &'a [f64] {
    let n = x.cols();
    let out = crate::algs::Workspace::reset(buf, n);
    for (i, m) in out.iter_mut().enumerate() {
        *m = x.col_dot(i, w);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_batch_roundtrips_through_shard_decode() {
        let queries = vec![
            Query { idx: vec![0, 3, 7], val: vec![1.0, -2.0, 0.5] },
            Query { idx: vec![], val: vec![] },
            Query { idx: vec![2], val: vec![4.0] },
        ];
        let flat = encode_batch(42, &queries);
        assert_eq!(flat[0], 42.0);
        assert_eq!(flat[1], 3.0);
        let w: Vec<f64> = (0..8).map(|i| i as f64 + 1.0).collect();
        let shard = ShardServer::from_snapshot(&w, 0, 8, false);
        let mut out = Vec::new();
        let scanned = shard.batch_partials(&flat, &mut out);
        assert_eq!(scanned, 4);
        assert_eq!(out, vec![1.0 * 1.0 - 2.0 * 4.0 + 0.5 * 8.0, 0.0, 4.0 * 3.0]);
    }

    #[test]
    fn partial_margins_respect_shard_bounds() {
        let w: Vec<f64> = vec![1.0; 10];
        let a = ShardServer::from_snapshot(&w, 0, 5, false);
        let b = ShardServer::from_snapshot(&w, 5, 10, false);
        let q = Query { idx: vec![1, 4, 5, 9], val: vec![1.0, 1.0, 1.0, 1.0] };
        assert_eq!(a.partial_margin(&q.idx, &q.val), 2.0);
        assert_eq!(b.partial_margin(&q.idx, &q.val), 2.0);
    }

    #[test]
    fn reference_merge_is_plain_chain_at_q1() {
        let w: Vec<f64> = vec![0.1, 0.2, 0.3, 0.4];
        let q = Query { idx: vec![0, 1, 2, 3], val: vec![1.0, 2.0, 3.0, 4.0] };
        let r = reference_margins(&w, &[(0, 4)], std::slice::from_ref(&q));
        let mut chain = 0.0f64;
        for (&i, &v) in q.idx.iter().zip(&q.val) {
            chain += v * w[i as usize];
        }
        // the router starts at 0.0 and absorbs the single shard
        assert_eq!(r[0].to_bits(), (0.0 + chain).to_bits());
    }

    #[test]
    fn fleet_maps_replicas_to_the_documented_nodes() {
        // q=3, r=2: replica-0 set is nodes 1..=3 (the unreplicated
        // layout), replica-1 set is nodes 4..=6.
        let mut fleet = Fleet::new(3, 2);
        assert_eq!(fleet.node(0, 0), 1);
        assert_eq!(fleet.node(2, 0), 3);
        assert_eq!(fleet.node(0, 1), 4);
        assert_eq!(fleet.node(2, 1), 6);
        assert_eq!(fleet.pick_primary(1), Some(0));
        fleet.kill(1, 0);
        assert_eq!(fleet.pick_primary(1), Some(1), "failover to the next live replica");
        assert_eq!(fleet.other_alive(1, 1), None, "no second live replica left");
        fleet.kill(1, 1);
        assert_eq!(fleet.pick_primary(1), None, "shard exhausted");
        // untouched shard keeps its primary
        assert_eq!(fleet.pick_primary(2), Some(0));
        assert_eq!(fleet.other_alive(2, 0), Some(1));
    }

    #[test]
    fn quantized_snapshot_halves_bytes() {
        let w = vec![0.1f64; 100];
        let exact = ShardWeights::new(&w, 0, 100, false);
        let quant = ShardWeights::new(&w, 0, 100, true);
        assert_eq!(exact.bytes(), 800);
        assert_eq!(quant.bytes(), 400);
        assert!(quant.is_quantized());
    }

    #[test]
    fn query_validation_rejects_bad_indices() {
        assert!(Query { idx: vec![], val: vec![] }.validate(10).is_ok());
        let dup = Query::from_pairs(vec![(3, 1.0), (3, 2.0)]);
        let e = dup.validate(10).unwrap_err();
        assert!(e.contains("duplicate") && e.contains('3'), "{e}");
        let oob = Query { idx: vec![10], val: vec![1.0] };
        let e = oob.validate(10).unwrap_err();
        assert!(e.contains("out of range") && e.contains("d=10"), "{e}");
        let desc = Query { idx: vec![5, 2], val: vec![1.0, 1.0] };
        assert!(desc.validate(10).unwrap_err().contains("ascending"));
        let mismatch = Query { idx: vec![1], val: vec![] };
        assert!(mismatch.validate(10).unwrap_err().contains("mismatch"));
    }

    #[test]
    fn simulate_rejects_bad_entry_shapes_with_context() {
        let w = vec![1.0f64; 4];
        let base = |bounds: Vec<(usize, usize)>, queries: usize, robust: RobustSpec| ServeSpec {
            w: &w,
            bounds,
            model: NetModel::Uniform(crate::net::SimParams::default()),
            wire: WireFmt::F64,
            policy: BatchPolicy { max_batch: 4, max_delay: 1e-4 },
            queries,
            mode: ArrivalMode::Closed { concurrency: 2 },
            seed: 1,
            source: QuerySource::Synthetic { d: 4, nnz: 2 },
            collect_margins: false,
            robust,
        };
        let e = simulate(&base(vec![], 10, RobustSpec::default())).unwrap_err();
        assert!(e.contains("at least one shard"), "{e}");
        let e = simulate(&base(vec![(0, 4)], 0, RobustSpec::default())).unwrap_err();
        assert!(e.contains("at least one query"), "{e}");
        let e = simulate(&base(
            vec![(0, 4)],
            10,
            RobustSpec { replicas: 0, ..Default::default() },
        ))
        .unwrap_err();
        assert!(e.contains("--replicas"), "{e}");
        let e = simulate(&base(
            vec![(0, 4)],
            10,
            RobustSpec { hedge: 1e-4, ..Default::default() },
        ))
        .unwrap_err();
        assert!(e.contains("--hedge") && e.contains("--replicas"), "{e}");
    }
}
