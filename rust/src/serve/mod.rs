//! Sharded inference plane: batched margin-merge serving over the
//! feature-distributed layout.
//!
//! Training ends, the layout stays: a d-dimensional linear model trained
//! feature-distributed is *served* feature-distributed. Node 0 is the
//! [`Router`] front-end; nodes `1..=q` each hold one contiguous feature
//! shard of the weight vector (the same nnz-balanced partition
//! [`crate::sparse::partition::by_features`] gives the trainer) as a
//! [`ShardServer`]. A query's margin factors over shards exactly like the
//! trainer's partial products:
//!
//! ```text
//!   wᵀx = Σ_l  w^(l)ᵀ x^(l)
//! ```
//!
//! so serving one batch is: router fans the encoded batch to all shards
//! ([`crate::net::tags::QUERY`]), each shard computes its partial margins
//! against a read-optimized weight snapshot ([`ShardWeights`]: exact `f64`
//! or an `f32`-quantized slab riding the `--wire f32` machinery), and the
//! partials merge back with the Fig.-5 binomial
//! [`crate::net::collectives::tree_reduce`] rooted at the router.
//!
//! **Batching policy** ([`BatchPolicy`]): a batch closes when it reaches
//! `max_batch` queries or `max_delay` seconds after its first admitted
//! query, whichever comes first; the router dispatches one batch at a
//! time. Batching is where the throughput comes from — the per-message
//! overhead (`per_msg`, wire latency, one reduce round-trip) amortizes
//! over the whole batch.
//!
//! **Determinism contract**: the simulation runs on
//! [`Endpoint::set_modeled_time`] — the clock moves only on model charges
//! (message occupancy, explicit [`cost`] constants via
//! [`Endpoint::charge_modeled`]) — and all traffic comes from a seeded
//! [`LoadGen`]. Every reported number (p50/p99/QPS/bytes/margin checksum)
//! is therefore a pure function of `(spec, seed)`: bit-identical across
//! reruns and `--threads K`.

mod loadgen;

pub use loadgen::{ArrivalMode, LatencyHistogram, LoadGen, QuerySource};

use crate::cluster::run_cluster_model;
use crate::net::collectives::tree_reduce;
use crate::net::{tags, Endpoint, NetModel, NodeId, Payload, WireFmt};
use crate::sparse::CscMatrix;
use std::collections::VecDeque;

/// The front-end node id (shards are `1..=q`).
pub const ROUTER: NodeId = 0;

/// Deterministic modeled compute costs (seconds of serial work) charged
/// through [`Endpoint::charge_modeled`]. These replace measured thread CPU
/// on the serving plane — the clock must be a pure function of the spec —
/// and sit in one place so the model is auditable. Scenario compute
/// scales (the straggler factor) still multiply them.
pub mod cost {
    /// Shard: one in-range nonzero product against the exact f64 shard.
    pub const SHARD_PER_NZ_F64: f64 = 2.0e-9;
    /// Shard: one in-range nonzero product against the f32-quantized
    /// slab (half the memory traffic of the f64 path).
    pub const SHARD_PER_NZ_F32: f64 = 1.2e-9;
    /// Shard: per-query overhead (batch walk, bounds filter).
    pub const SHARD_PER_QUERY: f64 = 60.0e-9;
    /// Shard: per-batch overhead (decode, partial buffer reset).
    pub const SHARD_PER_BATCH: f64 = 2.0e-6;
    /// Router: per-query admission (validation + batch encode share).
    pub const ROUTER_PER_QUERY: f64 = 120.0e-9;
    /// Router: per-batch overhead (close decision, fan-out setup).
    pub const ROUTER_PER_BATCH: f64 = 1.5e-6;
}

/// One sparse query: feature indices (strictly ascending) and values.
#[derive(Clone, Debug, PartialEq)]
pub struct Query {
    pub idx: Vec<u32>,
    pub val: Vec<f64>,
}

impl Query {
    /// Build from unordered pairs (sorts by index; duplicates survive and
    /// are caught by [`Query::validate`]).
    pub fn from_pairs(mut pairs: Vec<(u32, f64)>) -> Query {
        pairs.sort_by_key(|p| p.0);
        Query {
            idx: pairs.iter().map(|p| p.0).collect(),
            val: pairs.iter().map(|p| p.1).collect(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Admission check against a `d`-feature model. Empty queries are
    /// fine (margin 0); duplicate, descending, or out-of-range indices
    /// are rejected with enough context to debug the client.
    pub fn validate(&self, d: usize) -> Result<(), String> {
        if self.idx.len() != self.val.len() {
            return Err(format!(
                "query index/value length mismatch: {} indices vs {} values",
                self.idx.len(),
                self.val.len()
            ));
        }
        for (k, &i) in self.idx.iter().enumerate() {
            if i as usize >= d {
                return Err(format!(
                    "query feature index {i} out of range for a d={d} model"
                ));
            }
            if k > 0 {
                if i == self.idx[k - 1] {
                    return Err(format!("duplicate feature index {i} in query"));
                }
                if i < self.idx[k - 1] {
                    return Err(format!(
                        "query indices must be ascending: {} after {}",
                        i,
                        self.idx[k - 1]
                    ));
                }
            }
        }
        Ok(())
    }
}

/// Batch close policy: dispatch at `max_batch` queries or `max_delay`
/// seconds after the first admitted query, whichever comes first.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_delay: f64,
}

/// A shard's read-optimized weight snapshot: the exact f64 reference, or
/// the f32-quantized slab (the serving twin of the `--wire f32` codec and
/// the trainer's `dense_slab_f32` mirrors — half the bytes, ~2× the scan
/// rate, one rounding per weight).
pub enum ShardWeights {
    Exact(Vec<f64>),
    Quantized(Vec<f32>),
}

impl ShardWeights {
    pub fn new(w: &[f64], lo: usize, hi: usize, quantize: bool) -> ShardWeights {
        if quantize {
            ShardWeights::Quantized(w[lo..hi].iter().map(|&v| v as f32).collect())
        } else {
            ShardWeights::Exact(w[lo..hi].to_vec())
        }
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self, ShardWeights::Quantized(_))
    }

    /// Snapshot bytes held by this shard.
    pub fn bytes(&self) -> usize {
        match self {
            ShardWeights::Exact(v) => 8 * v.len(),
            ShardWeights::Quantized(v) => 4 * v.len(),
        }
    }

    fn per_nz_cost(&self) -> f64 {
        match self {
            ShardWeights::Exact(_) => cost::SHARD_PER_NZ_F64,
            ShardWeights::Quantized(_) => cost::SHARD_PER_NZ_F32,
        }
    }
}

/// One shard server: feature range `[lo, hi)` plus its weight snapshot.
pub struct ShardServer {
    pub lo: usize,
    pub hi: usize,
    pub weights: ShardWeights,
}

impl ShardServer {
    pub fn from_snapshot(w: &[f64], lo: usize, hi: usize, quantize: bool) -> ShardServer {
        ShardServer { lo, hi, weights: ShardWeights::new(w, lo, hi, quantize) }
    }

    /// Partial margin of one query restricted to this shard's range: a
    /// serial ascending-index chain, f64 accumulation in both weight
    /// forms (only the stored weights are quantized).
    pub fn partial_margin(&self, idx: &[u32], val: &[f64]) -> f64 {
        let (lo, hi) = (self.lo as u32, self.hi as u32);
        let mut acc = 0.0f64;
        match &self.weights {
            ShardWeights::Exact(w) => {
                for (k, &i) in idx.iter().enumerate() {
                    if (lo..hi).contains(&i) {
                        acc += val[k] * w[(i - lo) as usize];
                    }
                }
            }
            ShardWeights::Quantized(w) => {
                for (k, &i) in idx.iter().enumerate() {
                    if (lo..hi).contains(&i) {
                        acc += val[k] * w[(i - lo) as usize] as f64;
                    }
                }
            }
        }
        acc
    }

    /// Decode a flat query batch (see [`encode_batch`]) and write one
    /// partial margin per query into `out`. Returns the number of
    /// in-range nonzeros actually multiplied (the modeled-cost driver).
    pub fn batch_partials(&self, flat: &[f64], out: &mut Vec<f64>) -> usize {
        let nq = flat[0] as usize;
        out.clear();
        out.reserve(nq);
        let (lo, hi) = (self.lo as u32, self.hi as u32);
        let mut scanned = 0usize;
        let mut pos = 1usize;
        for _ in 0..nq {
            let nnz = flat[pos] as usize;
            let idx = &flat[pos + 1..pos + 1 + nnz];
            let val = &flat[pos + 1 + nnz..pos + 1 + 2 * nnz];
            let mut acc = 0.0f64;
            match &self.weights {
                ShardWeights::Exact(w) => {
                    for (iv, &v) in idx.iter().zip(val) {
                        let i = *iv as u32;
                        if (lo..hi).contains(&i) {
                            acc += v * w[(i - lo) as usize];
                            scanned += 1;
                        }
                    }
                }
                ShardWeights::Quantized(w) => {
                    for (iv, &v) in idx.iter().zip(val) {
                        let i = *iv as u32;
                        if (lo..hi).contains(&i) {
                            acc += v * w[(i - lo) as usize] as f64;
                            scanned += 1;
                        }
                    }
                }
            }
            out.push(acc);
            pos += 1 + 2 * nnz;
        }
        scanned
    }

    /// Modeled serial cost of one decoded batch.
    pub fn batch_cost(&self, nq: usize, scanned_nz: usize) -> f64 {
        cost::SHARD_PER_BATCH
            + cost::SHARD_PER_QUERY * nq as f64
            + self.weights.per_nz_cost() * scanned_nz as f64
    }
}

/// Flat wire layout of a query batch (always exact f64 — quantizing
/// *queries* would corrupt indices):
/// `[nq, nnz_1, idx_1.., val_1.., nnz_2, ...]` — u32 indices are exact
/// as f64.
pub fn encode_batch(queries: &[Query]) -> Vec<f64> {
    let scalars = 1 + queries.iter().map(|q| 1 + 2 * q.nnz()).sum::<usize>();
    let mut flat = Vec::with_capacity(scalars);
    flat.push(queries.len() as f64);
    for q in queries {
        flat.push(q.nnz() as f64);
        flat.extend(q.idx.iter().map(|&i| i as f64));
        flat.extend_from_slice(&q.val);
    }
    flat
}

/// Everything one serving simulation needs. `bounds` is the per-shard
/// feature partition (`[lo, hi)` per shard, covering `[0, d)` in order) —
/// take it from [`crate::sparse::partition::by_features`] to serve the
/// training layout.
pub struct ServeSpec<'a> {
    pub w: &'a [f64],
    pub bounds: Vec<(usize, usize)>,
    pub model: NetModel,
    pub wire: WireFmt,
    pub policy: BatchPolicy,
    pub queries: usize,
    pub mode: ArrivalMode,
    pub seed: u64,
    pub source: QuerySource,
    /// Keep every merged margin (issue order) — tests pin them against
    /// [`reference_margins`]; off for load runs (O(total) memory).
    pub collect_margins: bool,
}

/// What one simulation reports: the latency distribution, throughput, and
/// enough configuration echo to be a self-describing JSON row.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub scenario: &'static str,
    pub wire: &'static str,
    pub q: usize,
    pub max_batch: usize,
    pub max_delay_us: f64,
    pub mode: &'static str,
    pub concurrency: usize,
    pub rate: f64,
    pub queries: usize,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_us: f64,
    pub p90_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    pub mean_us: f64,
    pub qps: f64,
    pub sim_time_s: f64,
    pub wire_bytes: u64,
    pub bytes_per_query: f64,
    /// Σ of all merged margins in issue order — a one-number bit-stability
    /// witness for the whole numeric path.
    pub margin_checksum: f64,
}

impl ServeReport {
    /// One hand-rolled JSON object (no trailing comma/newline) — shared
    /// by `serve --out` and the `exp serving` report writer. Deliberately
    /// separate from the golden-pinned
    /// [`crate::metrics::json::run_result_to_json`] layout.
    pub fn to_json_row(&self) -> String {
        format!(
            "{{\"scenario\": \"{}\", \"wire\": \"{}\", \"q\": {}, \
             \"max_batch\": {}, \"max_delay_us\": {}, \"mode\": \"{}\", \
             \"concurrency\": {}, \"rate\": {}, \"queries\": {}, \
             \"batches\": {}, \"mean_batch\": {}, \"p50_us\": {}, \
             \"p90_us\": {}, \"p99_us\": {}, \"max_us\": {}, \
             \"mean_us\": {}, \"qps\": {}, \"sim_time_s\": {}, \
             \"wire_bytes\": {}, \"bytes_per_query\": {}, \
             \"margin_checksum\": {}}}",
            self.scenario,
            self.wire,
            self.q,
            self.max_batch,
            self.max_delay_us,
            self.mode,
            self.concurrency,
            self.rate,
            self.queries,
            self.batches,
            self.mean_batch,
            self.p50_us,
            self.p90_us,
            self.p99_us,
            self.max_us,
            self.mean_us,
            self.qps,
            self.sim_time_s,
            self.wire_bytes,
            self.bytes_per_query,
            self.margin_checksum,
        )
    }
}

/// A full simulation's outputs.
pub struct ServeOutcome {
    pub report: ServeReport,
    /// Merged margins in issue order (only when
    /// [`ServeSpec::collect_margins`]).
    pub margins: Option<Vec<f64>>,
}

struct RouterOut {
    hist: LatencyHistogram,
    batches: u64,
    last_done: f64,
    checksum: f64,
    margins: Option<Vec<f64>>,
}

/// Run one serving simulation: `q = bounds.len()` shard servers plus the
/// router on `q+1` sim nodes under `spec.model`, driven by the seeded
/// load generator until `spec.queries` have completed.
pub fn simulate(spec: &ServeSpec) -> ServeOutcome {
    let q = spec.bounds.len();
    assert!(q > 0, "serve: need at least one shard");
    assert!(spec.policy.max_batch > 0, "serve: max_batch must be ≥ 1");
    assert!(spec.queries > 0, "serve: need at least one query");
    let d = spec.bounds.last().unwrap().1;
    let quantize = spec.wire == WireFmt::F32;
    let run = run_cluster_model(q + 1, &spec.model, |mut ep| {
        ep.set_modeled_time(true);
        if ep.id() == ROUTER {
            Some(run_router(&mut ep, spec, d))
        } else {
            let (lo, hi) = spec.bounds[ep.id() - 1];
            run_shard(&mut ep, ShardServer::from_snapshot(spec.w, lo, hi, quantize), spec.wire);
            None
        }
    });
    let out = run
        .results
        .into_iter()
        .flatten()
        .next()
        .expect("serve: router produced no report");
    let wire_bytes = run.stats.total_bytes();
    let (concurrency, rate) = match spec.mode {
        ArrivalMode::Closed { concurrency } => (concurrency, 0.0),
        ArrivalMode::Open { rate } => (0, rate),
    };
    let report = ServeReport {
        scenario: spec.model.name(),
        wire: spec.wire.name(),
        q,
        max_batch: spec.policy.max_batch,
        max_delay_us: spec.policy.max_delay * 1e6,
        mode: spec.mode.name(),
        concurrency,
        rate,
        queries: spec.queries,
        batches: out.batches,
        mean_batch: spec.queries as f64 / out.batches.max(1) as f64,
        p50_us: out.hist.quantile(0.50) * 1e6,
        p90_us: out.hist.quantile(0.90) * 1e6,
        p99_us: out.hist.quantile(0.99) * 1e6,
        max_us: out.hist.max() * 1e6,
        mean_us: out.hist.mean() * 1e6,
        qps: spec.queries as f64 / out.last_done.max(1e-12),
        sim_time_s: out.last_done,
        wire_bytes,
        bytes_per_query: wire_bytes as f64 / spec.queries as f64,
        margin_checksum: out.checksum,
    };
    ServeOutcome { report, margins: out.margins }
}

/// The shard main loop: receive a batch, compute partials, charge the
/// modeled cost, merge up the reduce tree. An empty batch (`nq = 0`) is
/// the shutdown signal.
fn run_shard(ep: &mut Endpoint, shard: ShardServer, wire: WireFmt) {
    let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
    let mut partial: Vec<f64> = Vec::new();
    loop {
        let msg = ep.recv_from(ROUTER, tags::QUERY);
        let flat: &[f64] = match &msg.payload {
            Payload::DenseF64(v) => v,
            other => panic!("serve: query batches travel as exact f64, got {other:?}"),
        };
        if flat[0] == 0.0 {
            break;
        }
        let nq = flat[0] as usize;
        let scanned = shard.batch_partials(flat, &mut partial);
        ep.charge_modeled(shard.batch_cost(nq, scanned));
        drop(msg);
        tree_reduce(ep, &group, &mut partial, wire);
    }
}

/// The router main loop: admit seeded traffic, close batches under the
/// policy, fan out, merge, record latency, and (closed mode) re-issue.
fn run_router(ep: &mut Endpoint, spec: &ServeSpec, d: usize) -> RouterOut {
    let q = spec.bounds.len();
    let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
    let total = spec.queries;
    let mut gen = LoadGen::new(spec.seed, spec.source.clone());
    let mut hist = LatencyHistogram::new();
    let mut margins_out = spec.collect_margins.then(|| Vec::with_capacity(total));
    let mut pending: VecDeque<(f64, Query)> = VecDeque::new();
    let mut issued = 0usize;
    let mut completed = 0usize;
    let mut batches = 0u64;
    let mut checksum = 0.0f64;
    let mut last_done = 0.0f64;
    // open-mode arrival horizon: simulated time of the next arrival that
    // has not yet been admitted to `pending`
    let mut next_arrival = 0.0f64;

    let admit = |pending: &mut VecDeque<(f64, Query)>, gen: &mut LoadGen, t: f64| {
        let query = gen.next_query();
        if let Err(e) = query.validate(d) {
            panic!("serve: load generator produced an invalid query: {e}");
        }
        pending.push_back((t, query));
    };

    match spec.mode {
        ArrivalMode::Closed { concurrency } => {
            for _ in 0..concurrency.max(1).min(total) {
                admit(&mut pending, &mut gen, 0.0);
                issued += 1;
            }
        }
        ArrivalMode::Open { .. } => {}
    }

    while completed < total {
        let t_free = ep.now();
        // Open mode: admit everything that has arrived by the time the
        // router went idle; if nothing is waiting, sleep to the next
        // arrival.
        if let ArrivalMode::Open { rate } = spec.mode {
            while issued < total && next_arrival <= t_free {
                admit(&mut pending, &mut gen, next_arrival);
                issued += 1;
                next_arrival += gen.exp_gap(rate);
            }
            if pending.is_empty() {
                admit(&mut pending, &mut gen, next_arrival);
                issued += 1;
                let t = next_arrival;
                next_arrival += gen.exp_gap(rate);
                ep.advance_to(t);
            }
        }
        debug_assert!(!pending.is_empty(), "closed-loop refill keeps the queue nonempty");
        let t0 = pending.front().expect("nonempty").0;
        let open_t = t_free.max(t0);
        // Batch close: full at `open_t`, or wait the delay window (open
        // mode admits what arrives inside it), or the window expires.
        let close_t = if pending.len() >= spec.policy.max_batch {
            open_t
        } else {
            let deadline = (t0 + spec.policy.max_delay).max(open_t);
            let mut closed_at = deadline;
            if let ArrivalMode::Open { rate } = spec.mode {
                while pending.len() < spec.policy.max_batch
                    && issued < total
                    && next_arrival <= deadline
                {
                    let t = next_arrival;
                    admit(&mut pending, &mut gen, t);
                    issued += 1;
                    next_arrival += gen.exp_gap(rate);
                    if pending.len() == spec.policy.max_batch {
                        closed_at = t.max(open_t);
                    }
                }
            }
            closed_at
        };
        let take = pending.len().min(spec.policy.max_batch);
        let mut arrivals: Vec<f64> = Vec::with_capacity(take);
        let mut batch: Vec<Query> = Vec::with_capacity(take);
        for _ in 0..take {
            let (t, query) = pending.pop_front().expect("sized above");
            arrivals.push(t);
            batch.push(query);
        }
        ep.advance_to(close_t);
        ep.charge_modeled(cost::ROUTER_PER_BATCH + cost::ROUTER_PER_QUERY * take as f64);
        // One encode, q Arc clones — the same zero-copy fan-out the
        // training collectives use.
        let payload = Payload::from(encode_batch(&batch));
        for shard in 1..=q {
            ep.send(shard, tags::QUERY, payload.clone());
        }
        // Merge: router contributes zeros, the sum lands here (rank 0).
        let mut merged = vec![0.0f64; take];
        tree_reduce(ep, &group, &mut merged, spec.wire);
        let t_done = ep.now();
        batches += 1;
        last_done = t_done;
        for (k, &t_arr) in arrivals.iter().enumerate() {
            hist.record(t_done - t_arr);
            checksum += merged[k];
            if let Some(ms) = margins_out.as_mut() {
                ms.push(merged[k]);
            }
        }
        completed += take;
        if let ArrivalMode::Closed { .. } = spec.mode {
            for _ in 0..take {
                if issued < total {
                    admit(&mut pending, &mut gen, t_done);
                    issued += 1;
                }
            }
        }
    }
    // Shutdown: an empty batch to every shard.
    let stop = Payload::from(vec![0.0f64]);
    for shard in 1..=q {
        ep.send(shard, tags::QUERY, stop.clone());
    }
    RouterOut { hist, batches, last_done, checksum, margins: margins_out }
}

/// Local (single-process, no network) replica of what the sharded plane
/// computes for `queries` on the exact f64 path: per-shard partials as
/// ascending-index chains, merged with the *same* binomial-tree
/// association [`tree_reduce`] uses over the `q+1`-node serving group
/// (rank 0 = router contributes zeros). Against this reference the f64
/// sharded sim is bit-exact — the property the serving tests pin. At
/// `q = 1` the merge degenerates to the plain serial chain, i.e. the
/// unsharded dense predict.
pub fn reference_margins(w: &[f64], bounds: &[(usize, usize)], queries: &[Query]) -> Vec<f64> {
    let shards: Vec<ShardServer> = bounds
        .iter()
        .map(|&(lo, hi)| ShardServer::from_snapshot(w, lo, hi, false))
        .collect();
    queries
        .iter()
        .map(|query| {
            // vals[rank] for the serving group: rank 0 is the router
            let mut vals: Vec<f64> = std::iter::once(0.0)
                .chain(shards.iter().map(|s| s.partial_margin(&query.idx, &query.val)))
                .collect();
            let n = vals.len();
            let mut mask = 1usize;
            while mask < n {
                let mut r = 0usize;
                while r + mask < n {
                    // receiver ranks have all `mask`-low bits zero; each
                    // absorbs its `r + mask` child exactly like
                    // tree_reduce's add_into
                    vals[r] += vals[r + mask];
                    r += mask << 1;
                }
                mask <<= 1;
            }
            vals[0]
        })
        .collect()
}

/// All `n` margins `wᵀx_i` of a design matrix into a reused scratch
/// buffer — the allocation-free batch-predict path (`predict --ckpt`):
/// repeated calls reuse capacity instead of allocating per batch.
pub fn dense_margins<'a>(x: &CscMatrix, w: &[f64], buf: &'a mut Vec<f64>) -> &'a [f64] {
    let n = x.cols();
    let out = crate::algs::Workspace::reset(buf, n);
    for (i, m) in out.iter_mut().enumerate() {
        *m = x.col_dot(i, w);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_batch_roundtrips_through_shard_decode() {
        let queries = vec![
            Query { idx: vec![0, 3, 7], val: vec![1.0, -2.0, 0.5] },
            Query { idx: vec![], val: vec![] },
            Query { idx: vec![2], val: vec![4.0] },
        ];
        let flat = encode_batch(&queries);
        assert_eq!(flat[0], 3.0);
        let w: Vec<f64> = (0..8).map(|i| i as f64 + 1.0).collect();
        let shard = ShardServer::from_snapshot(&w, 0, 8, false);
        let mut out = Vec::new();
        let scanned = shard.batch_partials(&flat, &mut out);
        assert_eq!(scanned, 4);
        assert_eq!(out, vec![1.0 * 1.0 - 2.0 * 4.0 + 0.5 * 8.0, 0.0, 4.0 * 3.0]);
    }

    #[test]
    fn partial_margins_respect_shard_bounds() {
        let w: Vec<f64> = vec![1.0; 10];
        let a = ShardServer::from_snapshot(&w, 0, 5, false);
        let b = ShardServer::from_snapshot(&w, 5, 10, false);
        let q = Query { idx: vec![1, 4, 5, 9], val: vec![1.0, 1.0, 1.0, 1.0] };
        assert_eq!(a.partial_margin(&q.idx, &q.val), 2.0);
        assert_eq!(b.partial_margin(&q.idx, &q.val), 2.0);
    }

    #[test]
    fn reference_merge_is_plain_chain_at_q1() {
        let w: Vec<f64> = vec![0.1, 0.2, 0.3, 0.4];
        let q = Query { idx: vec![0, 1, 2, 3], val: vec![1.0, 2.0, 3.0, 4.0] };
        let r = reference_margins(&w, &[(0, 4)], std::slice::from_ref(&q));
        let mut chain = 0.0f64;
        for (&i, &v) in q.idx.iter().zip(&q.val) {
            chain += v * w[i as usize];
        }
        // rank0 starts at 0.0 and absorbs the single shard: 0.0 + chain
        assert_eq!(r[0].to_bits(), (0.0 + chain).to_bits());
    }

    #[test]
    fn quantized_snapshot_halves_bytes() {
        let w = vec![0.1f64; 100];
        let exact = ShardWeights::new(&w, 0, 100, false);
        let quant = ShardWeights::new(&w, 0, 100, true);
        assert_eq!(exact.bytes(), 800);
        assert_eq!(quant.bytes(), 400);
        assert!(quant.is_quantized());
    }

    #[test]
    fn query_validation_rejects_bad_indices() {
        assert!(Query { idx: vec![], val: vec![] }.validate(10).is_ok());
        let dup = Query::from_pairs(vec![(3, 1.0), (3, 2.0)]);
        let e = dup.validate(10).unwrap_err();
        assert!(e.contains("duplicate") && e.contains('3'), "{e}");
        let oob = Query { idx: vec![10], val: vec![1.0] };
        let e = oob.validate(10).unwrap_err();
        assert!(e.contains("out of range") && e.contains("d=10"), "{e}");
        let desc = Query { idx: vec![5, 2], val: vec![1.0, 1.0] };
        assert!(desc.validate(10).unwrap_err().contains("ascending"));
        let mismatch = Query { idx: vec![1], val: vec![] };
        assert!(mismatch.validate(10).unwrap_err().contains("mismatch"));
    }
}
