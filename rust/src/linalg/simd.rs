//! Explicit AVX2 lanes for the elementwise dense kernels.
//!
//! Only the *elementwise* kernels dispatch here automatically: a 4-lane
//! `y[i] = beta·y[i] + alpha·x[i]` performs exactly the same multiply and
//! add per element as the scalar loop — one `vmulpd` plus one `vaddpd`,
//! never a fused multiply-add — so the results are IEEE bit-identical and
//! the runtime dispatch cannot move any pinned trajectory. Reduction
//! kernels (dots, sparse gathers) must NOT route here implicitly: multiple
//! accumulator lanes reassociate the sum, so they get explicit `_simd`
//! entry points behind `RunParams::simd` instead (see
//! [`crate::sparse::csc::CscMatrix`]).
//!
//! Everything is `x86_64`-gated with scalar fallbacks, and the feature
//! check (`is_x86_feature_detected!("avx2")`) is cached after the first
//! call; off x86_64 the prefix helpers report zero elements handled and
//! the callers run their scalar bodies over the whole slice.

/// Whether the AVX2 paths are usable on this machine (always false off
/// x86_64). Cached after the first query.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        use std::sync::OnceLock;
        static AVX2: OnceLock<bool> = OnceLock::new();
        *AVX2.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// AVX2 `y += alpha·x` over the largest multiple-of-4 prefix; returns how
/// many elements were handled (0 when AVX2 is unavailable) so the caller
/// finishes the tail — or everything — in scalar. Bit-identical to the
/// scalar loop per element.
#[allow(unused_variables)]
pub(crate) fn axpy_prefix(alpha: f64, x: &[f64], y: &mut [f64]) -> usize {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: AVX2 presence just checked; the kernel stays within
        // the slices' common length.
        return unsafe { axpy_avx2(alpha, x, y) };
    }
    0
}

/// AVX2 `y = beta·y + alpha·x` over the multiple-of-4 prefix; same
/// contract as [`axpy_prefix`].
#[allow(unused_variables)]
pub(crate) fn axpby_prefix(alpha: f64, x: &[f64], beta: f64, y: &mut [f64]) -> usize {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: AVX2 presence just checked; the kernel stays within
        // the slices' common length.
        return unsafe { axpby_avx2(alpha, x, beta, y) };
    }
    0
}

/// # Safety
/// Caller must ensure AVX2 is available; `x` and `y` must have equal
/// lengths (debug-asserted by the dispatchers).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(alpha: f64, x: &[f64], y: &mut [f64]) -> usize {
    use std::arch::x86_64::*;
    let n = x.len() / 4 * 4;
    let a = _mm256_set1_pd(alpha);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let mut i = 0usize;
    while i < n {
        let xv = _mm256_loadu_pd(xp.add(i));
        let yv = _mm256_loadu_pd(yp.add(i));
        // separate mul + add (no FMA): the exact ops of the scalar loop
        _mm256_storeu_pd(yp.add(i), _mm256_add_pd(yv, _mm256_mul_pd(a, xv)));
        i += 4;
    }
    n
}

/// # Safety
/// Same contract as [`axpy_avx2`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpby_avx2(alpha: f64, x: &[f64], beta: f64, y: &mut [f64]) -> usize {
    use std::arch::x86_64::*;
    let n = x.len() / 4 * 4;
    let a = _mm256_set1_pd(alpha);
    let b = _mm256_set1_pd(beta);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let mut i = 0usize;
    while i < n {
        let xv = _mm256_loadu_pd(xp.add(i));
        let yv = _mm256_loadu_pd(yp.add(i));
        let by = _mm256_mul_pd(b, yv);
        let ax = _mm256_mul_pd(a, xv);
        _mm256_storeu_pd(yp.add(i), _mm256_add_pd(by, ax));
        i += 4;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn avx2_prefix_is_bit_identical_to_scalar_axpy() {
        let mut rng = crate::util::Pcg64::seed_from_u64(61);
        for len in [0usize, 1, 3, 4, 7, 8, 33, 100] {
            let x: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let y0: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let alpha = rng.normal();
            let mut fast = y0.clone();
            let done = axpy_prefix(alpha, &x, &mut fast);
            assert!(done <= len && done % 4 == 0, "len={len}: done={done}");
            for i in done..len {
                fast[i] += alpha * x[i];
            }
            let mut scalar = y0.clone();
            for i in 0..len {
                scalar[i] += alpha * x[i];
            }
            assert_eq!(bits(&fast), bits(&scalar), "axpy len={len}");
        }
    }

    #[test]
    fn avx2_prefix_is_bit_identical_to_scalar_axpby() {
        let mut rng = crate::util::Pcg64::seed_from_u64(62);
        for len in [0usize, 2, 4, 9, 64, 101] {
            let x: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let y0: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let (alpha, beta) = (rng.normal(), 1.0 - 1e-4 * rng.normal().abs());
            let mut fast = y0.clone();
            let done = axpby_prefix(alpha, &x, beta, &mut fast);
            for i in done..len {
                fast[i] = beta * fast[i] + alpha * x[i];
            }
            let mut scalar = y0.clone();
            for v in scalar.iter_mut().zip(x.iter()) {
                *v.0 = beta * *v.0 + alpha * *v.1;
            }
            assert_eq!(bits(&fast), bits(&scalar), "axpby len={len}");
        }
    }
}
