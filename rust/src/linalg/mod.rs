//! Dense vector kernels used on every algorithm's hot path.
//!
//! Everything operates on `f64` slices; the unrolled-by-4 bodies give the
//! compiler clean autovectorization targets without unsafe code. These
//! kernels are deliberately allocation-free — the inner loops of SVRG call
//! them millions of times.
//!
//! The elementwise kernels ([`axpy`], [`axpby`]) additionally dispatch to
//! explicit AVX2 lanes at runtime ([`simd`]): per-element the vector path
//! performs the identical multiply and add (no FMA contraction), so the
//! dispatch is invisible to every pinned trajectory and needs no opt-in.
//! Reduction kernels keep their fixed summation order here; the
//! reassociating multi-lane variants live on the sparse matrix behind
//! `--simd`.

pub mod simd;

/// `y += alpha * x` — AVX2 over the 4-multiple prefix when available
/// (bit-identical per element, see [`simd`]), then a 4-way unrolled scalar
/// body that gives LLVM a clean bounds-check-free vectorization target on
/// the remainder (or everything, off x86_64).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    let done = simd::axpy_prefix(alpha, x, y);
    let (x, y) = (&x[done..], &mut y[done..]);
    let mut yc = y.chunks_exact_mut(4);
    let mut xc = x.chunks_exact(4);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        yb[0] += alpha * xb[0];
        yb[1] += alpha * xb[1];
        yb[2] += alpha * xb[2];
        yb[3] += alpha * xb[3];
    }
    for (yi, xi) in yc.into_remainder().iter_mut().zip(xc.remainder().iter()) {
        *yi += alpha * *xi;
    }
}

/// Dot product.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    // 4 independent accumulators: breaks the FP dependency chain so LLVM can
    // vectorize without -ffast-math.
    let mut acc = [0.0f64; 4];
    let chunks = x.len() / 4;
    for c in 0..chunks {
        let i = 4 * c;
        acc[0] += x[i] * y[i];
        acc[1] += x[i + 1] * y[i + 1];
        acc[2] += x[i + 2] * y[i + 2];
        acc[3] += x[i + 3] * y[i + 3];
    }
    let mut tail = 0.0;
    for i in 4 * chunks..x.len() {
        tail += x[i] * y[i];
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// `x *= alpha`
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// `out = x - y`
#[inline]
pub fn sub(x: &[f64], y: &[f64], out: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    assert_eq!(x.len(), out.len());
    for i in 0..x.len() {
        out[i] = x[i] - y[i];
    }
}

/// `||x - y||_2`
#[inline]
pub fn dist2(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut s = 0.0;
    for i in 0..x.len() {
        let d = x[i] - y[i];
        s += d * d;
    }
    s.sqrt()
}

/// `y = beta*y + alpha*x` (general update used by the SVRG dense step) —
/// the O(d)-per-inner-step hot loop of every naive SVRG path; AVX2 prefix
/// + unrolled scalar remainder like [`axpy`] (elementwise, bit-identical
/// to the scalar loop).
#[inline]
pub fn axpby(alpha: f64, x: &[f64], beta: f64, y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    let done = simd::axpby_prefix(alpha, x, beta, y);
    let (x, y) = (&x[done..], &mut y[done..]);
    let mut yc = y.chunks_exact_mut(4);
    let mut xc = x.chunks_exact(4);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        yb[0] = beta * yb[0] + alpha * xb[0];
        yb[1] = beta * yb[1] + alpha * xb[1];
        yb[2] = beta * yb[2] + alpha * xb[2];
        yb[3] = beta * yb[3] + alpha * xb[3];
    }
    for (yi, xi) in yc.into_remainder().iter_mut().zip(xc.remainder().iter()) {
        *yi = beta * *yi + alpha * *xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..101).map(|i| i as f64 * 0.25).collect();
        let y: Vec<f64> = (0..101).map(|i| (i as f64).sin()).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-9 * naive.abs().max(1.0));
    }

    #[test]
    fn dot_empty() {
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn axpby_basic() {
        let x = [1.0, 1.0];
        let mut y = [2.0, 4.0];
        axpby(3.0, &x, 0.5, &mut y);
        assert_eq!(y, [4.0, 5.0]);
    }

    #[test]
    fn nrm2_345() {
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn dist2_symmetry() {
        let x = [1.0, 2.0, 3.0];
        let y = [4.0, 6.0, 3.0];
        assert!((dist2(&x, &y) - 5.0).abs() < 1e-12);
        assert_eq!(dist2(&x, &y), dist2(&y, &x));
    }

    #[test]
    fn scale_zero() {
        let mut x = [1.0, -2.0];
        scale(0.0, &mut x);
        assert_eq!(x, [0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn dot_length_mismatch_panics() {
        dot(&[1.0], &[1.0, 2.0]);
    }
}
