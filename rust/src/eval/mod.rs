//! Model evaluation: train/test splits and classification metrics.
//!
//! The paper reports objective-gap curves; a framework user also wants
//! held-out quality. This module provides deterministic splits and the
//! standard binary metrics (accuracy, precision/recall/F1, ROC-AUC)
//! computed from margins `wᵀx`.

use crate::sparse::libsvm::Dataset;
use crate::util::Pcg64;

/// Deterministic shuffled split: `test_frac` of instances go to the test
/// set, the rest to train. Instances keep their column order within each
/// side.
pub fn train_test_split(ds: &Dataset, test_frac: f64, seed: u64) -> (Dataset, Dataset) {
    assert!((0.0..1.0).contains(&test_frac));
    let n = ds.n();
    let n_test = ((n as f64) * test_frac).round() as usize;
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Pcg64::seed_from_u64(seed);
    // Fisher–Yates
    for i in (1..n).rev() {
        let j = rng.below(i + 1);
        idx.swap(i, j);
    }
    let (test_idx, train_idx) = idx.split_at(n_test);
    let mut test_idx = test_idx.to_vec();
    let mut train_idx = train_idx.to_vec();
    test_idx.sort_unstable();
    train_idx.sort_unstable();
    let subset = |name: &str, which: &[usize]| Dataset {
        name: format!("{}_{name}", ds.name),
        x: ds.x.select_columns(which),
        y: which.iter().map(|&i| ds.y[i]).collect(),
    };
    (subset("train", &train_idx), subset("test", &test_idx))
}

/// Binary classification metrics at threshold 0.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Metrics {
    pub accuracy: f64,
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
    pub auc: f64,
    pub n: usize,
}

/// Compute metrics of `sign(wᵀx)` (and AUC of the margin ranking) on `ds`.
pub fn evaluate(ds: &Dataset, w: &[f64]) -> Metrics {
    let n = ds.n();
    let margins: Vec<f64> = (0..n).map(|i| ds.x.col_dot(i, w)).collect();
    let (mut tp, mut fp, mut tn, mut fn_) = (0usize, 0usize, 0usize, 0usize);
    for i in 0..n {
        let pred_pos = margins[i] >= 0.0;
        let is_pos = ds.y[i] > 0.0;
        match (pred_pos, is_pos) {
            (true, true) => tp += 1,
            (true, false) => fp += 1,
            (false, false) => tn += 1,
            (false, true) => fn_ += 1,
        }
    }
    let div = |a: usize, b: usize| if b == 0 { 0.0 } else { a as f64 / b as f64 };
    let precision = div(tp, tp + fp);
    let recall = div(tp, tp + fn_);
    let f1 = if precision + recall == 0.0 {
        0.0
    } else {
        2.0 * precision * recall / (precision + recall)
    };
    Metrics {
        accuracy: div(tp + tn, n),
        precision,
        recall,
        f1,
        auc: auc(&margins, &ds.y),
        n,
    }
}

/// ROC-AUC by the rank statistic (ties get the midrank).
pub fn auc(scores: &[f64], labels: &[f64]) -> f64 {
    let n = scores.len();
    assert_eq!(labels.len(), n);
    let n_pos = labels.iter().filter(|&&y| y > 0.0).count();
    let n_neg = n - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    // midrank assignment over tie groups
    let mut rank = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        let mid = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            rank[order[k]] = mid;
        }
        i = j + 1;
    }
    let rank_sum_pos: f64 =
        (0..n).filter(|&i| labels[i] > 0.0).map(|i| rank[i]).sum();
    let u = rank_sum_pos - (n_pos as f64) * (n_pos as f64 + 1.0) / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, GenSpec};

    fn ds() -> Dataset {
        generate(&GenSpec::new("eval", 300, 200, 15).with_seed(13))
    }

    #[test]
    fn split_covers_and_is_disjoint() {
        let d = ds();
        let (train, test) = train_test_split(&d, 0.25, 1);
        assert_eq!(train.n() + test.n(), d.n());
        assert_eq!(test.n(), 50);
        assert_eq!(train.x.nnz() + test.x.nnz(), d.x.nnz());
    }

    #[test]
    fn split_deterministic_per_seed() {
        let d = ds();
        let (a, _) = train_test_split(&d, 0.3, 7);
        let (b, _) = train_test_split(&d, 0.3, 7);
        assert_eq!(a.y, b.y);
        let (c, _) = train_test_split(&d, 0.3, 8);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn perfect_classifier_metrics() {
        let d = ds();
        // build w that classifies via the labels themselves: w = Σ y_i x_i
        // scaled (works because instances are near-orthogonal in high dim)
        let mut w = vec![0.0; d.d()];
        for i in 0..d.n() {
            d.x.col_axpy(i, d.y[i], &mut w);
        }
        let m = evaluate(&d, &w);
        // power-law features are heavily shared across instances, so the
        // prototype classifier is good but not perfect
        assert!(m.accuracy > 0.8, "{m:?}");
        assert!(m.auc > 0.95, "{m:?}");
        assert!(m.f1 > 0.8, "{m:?}");
    }

    #[test]
    fn random_scores_auc_half() {
        let mut rng = crate::util::Pcg64::seed_from_u64(3);
        let scores: Vec<f64> = (0..4000).map(|_| rng.normal()).collect();
        let labels: Vec<f64> =
            (0..4000).map(|_| if rng.next_f64() < 0.5 { 1.0 } else { -1.0 }).collect();
        let a = auc(&scores, &labels);
        assert!((a - 0.5).abs() < 0.03, "auc {a}");
    }

    #[test]
    fn auc_handles_ties_and_degenerate_labels() {
        // all-equal scores → midranks → AUC exactly 0.5
        let scores = vec![1.0; 10];
        let labels = vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0];
        assert_eq!(auc(&scores, &labels), 0.5);
        // single-class labels → defined as 0.5
        assert_eq!(auc(&[0.3, 0.7], &[1.0, 1.0]), 0.5);
    }

    #[test]
    fn inverted_classifier_auc_below_half() {
        let d = ds();
        let mut w = vec![0.0; d.d()];
        for i in 0..d.n() {
            d.x.col_axpy(i, -d.y[i], &mut w); // anti-signal
        }
        let m = evaluate(&d, &w);
        assert!(m.auc < 0.2, "{m:?}");
    }

    #[test]
    fn metrics_consistency() {
        let d = ds();
        let w = vec![0.0; d.d()]; // all margins 0 → everything predicted +
        let m = evaluate(&d, &w);
        let pos_frac = d.y.iter().filter(|&&v| v > 0.0).count() as f64 / d.n() as f64;
        assert!((m.accuracy - pos_frac).abs() < 1e-12);
        assert!((m.recall - 1.0).abs() < 1e-12); // all positives caught
    }
}
