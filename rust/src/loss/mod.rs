//! Loss functions and regularizers for the linear-classification objective
//! (paper eq. 1–2):
//!
//! ```text
//! min_w f(w) = (1/N) Σ_i φ_i(wᵀx_i, y_i) + g(w)
//! ```
//!
//! All losses are exposed through their scalar margin form: the algorithms
//! only ever need `φ(z, y)` and `∂φ/∂z` at `z = wᵀx_i`, which is exactly why
//! feature distribution works — the cross-worker coupling is one scalar.

/// Scalar loss `φ(z, y)` with `z = wᵀx`, `y ∈ {-1, +1}`.
pub trait Loss: Send + Sync {
    fn name(&self) -> &'static str;
    /// Loss value.
    fn value(&self, z: f64, y: f64) -> f64;
    /// Derivative w.r.t. the margin input `z`.
    fn derivative(&self, z: f64, y: f64) -> f64;
    /// Upper bound on `φ''` w.r.t. `z` — enters the smoothness constant
    /// `L ≤ φ''_max · max_i ‖x_i‖² + λ` used for step-size selection and the
    /// Theorem-1 bound check.
    fn curvature_bound(&self) -> f64;
}

/// Logistic loss `log(1 + e^{-y z})` — the paper's experimental choice.
#[derive(Clone, Copy, Debug, Default)]
pub struct Logistic;

impl Loss for Logistic {
    fn name(&self) -> &'static str {
        "logistic"
    }

    fn value(&self, z: f64, y: f64) -> f64 {
        let m = y * z;
        // numerically stable log(1 + e^{-m})
        if m > 0.0 {
            (-m).exp().ln_1p()
        } else {
            -m + m.exp().ln_1p()
        }
    }

    fn derivative(&self, z: f64, y: f64) -> f64 {
        let m = y * z;
        // -y σ(-m) computed stably
        let s = if m > 0.0 { (-m).exp() / (1.0 + (-m).exp()) } else { 1.0 / (1.0 + m.exp()) };
        -y * s
    }

    fn curvature_bound(&self) -> f64 {
        0.25
    }
}

/// Smoothed (quadratically-smoothed) hinge, the L-smooth stand-in for the
/// linear SVM loss `max{0, 1 − yz}` the paper mentions in §2. The plain
/// hinge is not L-smooth, so SVRG theory (and Theorem 1) needs this form:
///
/// ```text
/// φ(z,y) = 0                    if yz ≥ 1
///        = (1 − yz)² / (2γ)     if 1 − γ < yz < 1
///        = 1 − yz − γ/2         otherwise
/// ```
#[derive(Clone, Copy, Debug)]
pub struct SmoothedHinge {
    pub gamma: f64,
}

impl Default for SmoothedHinge {
    fn default() -> Self {
        SmoothedHinge { gamma: 1.0 }
    }
}

impl Loss for SmoothedHinge {
    fn name(&self) -> &'static str {
        "smoothed_hinge"
    }

    fn value(&self, z: f64, y: f64) -> f64 {
        let m = y * z;
        if m >= 1.0 {
            0.0
        } else if m > 1.0 - self.gamma {
            (1.0 - m) * (1.0 - m) / (2.0 * self.gamma)
        } else {
            1.0 - m - self.gamma / 2.0
        }
    }

    fn derivative(&self, z: f64, y: f64) -> f64 {
        let m = y * z;
        if m >= 1.0 {
            0.0
        } else if m > 1.0 - self.gamma {
            -y * (1.0 - m) / self.gamma
        } else {
            -y
        }
    }

    fn curvature_bound(&self) -> f64 {
        1.0 / self.gamma
    }
}

/// Squared loss `(z − y)²/2` — makes the objective a ridge regression;
/// used by tests because its optimum is available in closed form on tiny
/// problems.
#[derive(Clone, Copy, Debug, Default)]
pub struct Squared;

impl Loss for Squared {
    fn name(&self) -> &'static str {
        "squared"
    }

    fn value(&self, z: f64, y: f64) -> f64 {
        0.5 * (z - y) * (z - y)
    }

    fn derivative(&self, z: f64, y: f64) -> f64 {
        z - y
    }

    fn curvature_bound(&self) -> f64 {
        1.0
    }
}

/// Regularizer `g(w)`. The paper's experiments use L2; L1 is supported via
/// subgradient (the paper's framework statement allows both — §4.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Regularizer {
    None,
    L2 { lambda: f64 },
    L1 { lambda: f64 },
}

impl Regularizer {
    pub fn value(&self, w: &[f64]) -> f64 {
        match *self {
            Regularizer::None => 0.0,
            Regularizer::L2 { lambda } => 0.5 * lambda * crate::linalg::dot(w, w),
            Regularizer::L1 { lambda } => lambda * w.iter().map(|x| x.abs()).sum::<f64>(),
        }
    }

    /// Gradient (or subgradient) contribution for coordinate value `wi`.
    #[inline]
    pub fn grad_coord(&self, wi: f64) -> f64 {
        match *self {
            Regularizer::None => 0.0,
            Regularizer::L2 { lambda } => lambda * wi,
            Regularizer::L1 { lambda } => lambda * wi.signum() * if wi == 0.0 { 0.0 } else { 1.0 },
        }
    }

    /// Add ∇g(w) into `out`.
    pub fn add_grad(&self, w: &[f64], out: &mut [f64]) {
        match *self {
            Regularizer::None => {}
            Regularizer::L2 { lambda } => crate::linalg::axpy(lambda, w, out),
            Regularizer::L1 { lambda } => {
                for (o, &wi) in out.iter_mut().zip(w.iter()) {
                    if wi != 0.0 {
                        *o += lambda * wi.signum();
                    }
                }
            }
        }
    }

    /// Strong-convexity modulus contributed by the regularizer.
    pub fn strong_convexity(&self) -> f64 {
        match *self {
            Regularizer::L2 { lambda } => lambda,
            _ => 0.0,
        }
    }

    pub fn lambda(&self) -> f64 {
        match *self {
            Regularizer::None => 0.0,
            Regularizer::L2 { lambda } | Regularizer::L1 { lambda } => lambda,
        }
    }
}

/// Which loss to instantiate — config-level enum.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossKind {
    Logistic,
    SmoothedHinge,
    Squared,
}

impl LossKind {
    pub fn build(self) -> Box<dyn Loss> {
        match self {
            LossKind::Logistic => Box::new(Logistic),
            LossKind::SmoothedHinge => Box::new(SmoothedHinge::default()),
            LossKind::Squared => Box::new(Squared),
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "logistic" | "lr" => Some(LossKind::Logistic),
            "hinge" | "svm" | "smoothed_hinge" => Some(LossKind::SmoothedHinge),
            "squared" | "ridge" => Some(LossKind::Squared),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_derivative(loss: &dyn Loss, z: f64, y: f64) {
        let h = 1e-6;
        let num = (loss.value(z + h, y) - loss.value(z - h, y)) / (2.0 * h);
        let ana = loss.derivative(z, y);
        assert!(
            (num - ana).abs() < 1e-5 * (1.0 + ana.abs()),
            "{}: z={z} y={y}: numeric {num} vs analytic {ana}",
            loss.name()
        );
    }

    #[test]
    fn logistic_derivative_matches_numeric() {
        for &z in &[-30.0, -2.0, -0.1, 0.0, 0.1, 2.0, 30.0] {
            for &y in &[-1.0, 1.0] {
                check_derivative(&Logistic, z, y);
            }
        }
    }

    #[test]
    fn logistic_extreme_margins_stable() {
        let l = Logistic;
        assert!(l.value(1000.0, 1.0).is_finite());
        assert!(l.value(-1000.0, 1.0).is_finite());
        assert!((l.value(-1000.0, 1.0) - 1000.0).abs() < 1e-9);
        assert!(l.derivative(1000.0, 1.0).abs() < 1e-12);
        assert!((l.derivative(-1000.0, 1.0) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn logistic_value_at_zero() {
        assert!((Logistic.value(0.0, 1.0) - std::f64::consts::LN_2).abs() < 1e-12);
    }

    #[test]
    fn smoothed_hinge_regions_and_derivative() {
        let h = SmoothedHinge { gamma: 0.5 };
        assert_eq!(h.value(2.0, 1.0), 0.0);
        assert!(h.value(0.0, 1.0) > 0.0);
        for &z in &[-2.0, 0.2, 0.6, 0.74, 0.9, 1.5] {
            for &y in &[-1.0, 1.0] {
                check_derivative(&h, z, y);
            }
        }
    }

    #[test]
    fn squared_derivative() {
        for &z in &[-3.0, 0.0, 2.0] {
            check_derivative(&Squared, z, 1.0);
        }
    }

    #[test]
    fn l2_regularizer_grad_and_value() {
        let r = Regularizer::L2 { lambda: 0.1 };
        let w = [1.0, -2.0, 0.0];
        assert!((r.value(&w) - 0.05 * 5.0).abs() < 1e-12);
        let mut g = vec![0.0; 3];
        r.add_grad(&w, &mut g);
        assert_eq!(g, vec![0.1, -0.2, 0.0]);
        assert_eq!(r.strong_convexity(), 0.1);
    }

    #[test]
    fn l1_regularizer_subgradient() {
        let r = Regularizer::L1 { lambda: 2.0 };
        let w = [3.0, -1.0, 0.0];
        assert_eq!(r.value(&w), 8.0);
        let mut g = vec![0.0; 3];
        r.add_grad(&w, &mut g);
        assert_eq!(g, vec![2.0, -2.0, 0.0]);
        assert_eq!(r.strong_convexity(), 0.0);
    }

    #[test]
    fn loss_kind_parse() {
        assert_eq!(LossKind::parse("logistic"), Some(LossKind::Logistic));
        assert_eq!(LossKind::parse("svm"), Some(LossKind::SmoothedHinge));
        assert_eq!(LossKind::parse("ridge"), Some(LossKind::Squared));
        assert_eq!(LossKind::parse("bogus"), None);
    }
}
