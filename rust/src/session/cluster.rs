//! Generic [`Driver`] over the thread-per-node cluster simulator.
//!
//! The simulator runs every node to completion on its own OS thread, so a
//! steppable API needs the control flow inverted at epoch boundaries: the
//! monitor node (coordinator / center / server 0 / ring leader) ends each
//! epoch by sending an [`EpochReport`] through an [`EpochGate`] and
//! blocking until the session answers with a [`Directive`]. `Continue`
//! resumes the cluster for one more epoch (via the algorithms' existing
//! uncounted CTRL flags to the other nodes); `Stop` winds it down. The
//! gate rides plain channels, so it adds **zero** counted traffic and no
//! simulated time — trajectories and counters are bit-identical to the
//! old fire-and-forget loops.
//!
//! Every epoch report carries the full per-node resume state, so
//! [`Driver::state`] works at *any* boundary without extra protocol. The
//! copies this costs (uncounted, in-process) scale with the algorithm's
//! state: O(q·d) for D-PSGD (each node's local `d`-vector), O(q·N + d)
//! for FD-SAGA (every worker's copy of the `N`-scalar table), O(d) for
//! the rest — paid per epoch, against the epoch's own O(N·nnz) compute,
//! whether or not a checkpoint is ever taken. If a profile shows this,
//! the CTRL reply has room for a "state wanted" flag to make shipping
//! lazy. The assembled parameter itself is *not* re-copied: the monitor
//! moves it into the report's `Arc<Vec<f64>>`, which the session's
//! objective evaluation, this driver's boundary state and any checkpoint
//! all share.
//!
//! The cluster itself runs on one background runner thread (which hosts
//! the scoped per-node threads), spawned lazily on the first
//! [`Driver::step`] so a session stopped before any epoch never starts
//! the cluster at all. Checkpoint/resume restarts the cluster from a
//! [`ResumeState`]: comm counters are preloaded into [`CommStats`], each
//! node's simulated clock (+ NIC horizons) and net-model jitter stream
//! are restored before its thread starts, and the per-node
//! [`NodeState`]s (RNG words + algorithm extras) are handed to the
//! algorithm's node function.

use super::{Driver, EpochReport, FinishOut, NodeState, ResumeState};
use crate::cluster::run_endpoints;
use crate::metrics::CommTotals;
use crate::net::fault::{FaultPlan, LinkFaults};
use crate::net::transport::{tcp, Transport};
use crate::net::{build_with_model, CommStats, Endpoint, NetModel, NodeComm};
use anyhow::{ensure, Result};
use std::process::Child;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Session → cluster control word, answered to every epoch report.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Directive {
    Continue,
    Stop,
}

/// The monitor node's handle on the session: report an epoch boundary,
/// block for the verdict. A disconnected session (dropped mid-run) reads
/// as `Stop`, so the cluster always winds down cleanly.
pub struct EpochGate {
    tx: Sender<EpochReport>,
    rx: Receiver<Directive>,
}

impl EpochGate {
    pub fn exchange(&self, report: EpochReport) -> Directive {
        if self.tx.send(report).is_err() {
            return Directive::Stop;
        }
        self.rx.recv().unwrap_or(Directive::Stop)
    }
}

/// Per-node context the generic runner hands to an algorithm's node
/// function: the gate (taken once, by the monitor node) and the resume
/// state (shared; nodes index [`ResumeState::nodes`] by their id).
pub struct ClusterCtx {
    gate: Mutex<Option<EpochGate>>,
    pub resume: Option<Arc<ResumeState>>,
}

impl ClusterCtx {
    /// Claim the gate — exactly one node (the monitor) may call this.
    pub fn take_gate(&self) -> EpochGate {
        self.gate.lock().unwrap().take().expect("epoch gate already taken by another node")
    }

    /// This node's resumable state, if resuming.
    pub fn node_state(&self, id: usize) -> Option<&NodeState> {
        self.resume.as_deref().and_then(|r| r.nodes.get(id))
    }
}

/// The node function an algorithm registers: dispatches on `ep.id()` to
/// its monitor/worker/server roles.
pub type NodeFn = Arc<dyn Fn(Endpoint, &ClusterCtx) + Send + Sync>;

struct Running {
    reports: Receiver<EpochReport>,
    directives: Sender<Directive>,
    handle: JoinHandle<()>,
}

/// How the cluster's nodes are hosted (`--transport sim|tcp`).
#[derive(Clone)]
enum Launch {
    /// Every node on its own thread, in this process (the simulator).
    Threads,
    /// One OS process per worker node over localhost TCP; the monitor
    /// node runs in this process. `spec` is the serialized experiment
    /// config handed to each `fdsvrg worker` child.
    Processes { spec: Arc<String> },
}

/// Generic cluster-backed [`Driver`]: owns the runner thread, the gate
/// channels and the boundary state. Algorithm modules construct one via
/// [`ClusterDriver::new`] with their node function; everything else
/// (spawn, step protocol, state export, teardown) is shared.
pub struct ClusterDriver {
    name: String,
    dataset: String,
    n_nodes: usize,
    model: NetModel,
    node_fn: NodeFn,
    resume: Option<Arc<ResumeState>>,
    /// Training state at the last epoch boundary (starts as the resume
    /// state, or fresh).
    last: ResumeState,
    stats: Option<Arc<CommStats>>,
    running: Option<Running>,
    launch: Launch,
    /// Worker processes (tcp launch only): waited in `finish`, killed on
    /// drop so an aborted session never leaks children.
    children: Vec<(usize, Child)>,
    /// Seeded fault plan (`--faults`): installed on every endpoint at
    /// spawn; its latched crashes drive the automatic recovery in `step`.
    faults: Option<Arc<FaultPlan>>,
    /// Recovery policy: asynchronous algorithms (AsySVRG, PS-Lite) absorb
    /// a crashed worker by restarting from the *latest* epoch boundary
    /// (minimal rollback); synchronous ones barrier-and-restart from the
    /// newest durable snapshot, paying the restart penalty.
    async_recovery: bool,
    /// TCP rendezvous deadline, seconds (`--rendezvous-timeout`).
    rendezvous_secs: f64,
}

impl ClusterDriver {
    /// `d` is the problem dimension (for the fresh initial `w`). When
    /// resuming, the resume state must describe exactly this cluster
    /// shape.
    pub fn new(
        name: &str,
        dataset: &str,
        n_nodes: usize,
        d: usize,
        model: NetModel,
        resume: Option<ResumeState>,
        node_fn: NodeFn,
    ) -> Result<ClusterDriver> {
        let (resume, last) = match resume {
            Some(r) if !r.is_fresh() => {
                ensure!(
                    r.nodes.len() == n_nodes,
                    "checkpoint describes a {}-node cluster, run requests {n_nodes} \
                     (resume needs the original q/servers shape)",
                    r.nodes.len()
                );
                ensure!(r.w.len() == d, "checkpoint dim {} != problem dim {d}", r.w.len());
                // The net scenario is not persisted in the checkpoint, but a
                // jitter mismatch is detectable (the per-node stream words
                // are) and silently dropping or re-seeding the noise stream
                // would break the bit-exact-resume guarantee — fail loudly.
                let model_jitter = matches!(model, crate::net::NetModel::Jitter { .. });
                let ckpt_jitter = r.nodes.iter().any(|n| n.jitter.is_some());
                ensure!(
                    model_jitter == ckpt_jitter,
                    "checkpoint {} a jitter noise stream but this run's --net model {}; \
                     resume under the original --net scenario",
                    if ckpt_jitter { "carries" } else { "does not carry" },
                    if model_jitter { "expects one" } else { "does not use one" }
                );
                let last = r.clone();
                (Some(Arc::new(r)), last)
            }
            _ => (None, ResumeState::fresh(d, n_nodes)),
        };
        Ok(ClusterDriver {
            name: name.to_string(),
            dataset: dataset.to_string(),
            n_nodes,
            model,
            node_fn,
            resume,
            last,
            stats: None,
            running: None,
            launch: Launch::Threads,
            children: Vec::new(),
            faults: None,
            async_recovery: false,
            rendezvous_secs: tcp::DEFAULT_RENDEZVOUS_SECS,
        })
    }

    /// Attach a seeded fault plan (`--faults`). `async_recovery` selects
    /// the rollback policy a crash recovery uses (latest boundary for the
    /// asynchronous algorithms, newest durable snapshot otherwise).
    pub fn with_faults(
        mut self,
        plan: Option<Arc<FaultPlan>>,
        async_recovery: bool,
    ) -> Result<ClusterDriver> {
        if let Some(p) = &plan {
            ensure!(
                matches!(self.launch, Launch::Threads),
                "--faults requires the sim transport (fault injection over tcp is not wired yet)"
            );
            p.validate(self.n_nodes).map_err(anyhow::Error::msg)?;
        }
        self.faults = plan;
        self.async_recovery = async_recovery;
        Ok(self)
    }

    /// Switch to process-per-node launch (`--transport tcp`): the q
    /// worker nodes run as child processes of the current executable
    /// (the internal `fdsvrg worker` entrypoint), each rebuilding the
    /// experiment from `spec`; the monitor node stays in this process.
    /// `rendezvous_secs` bounds every rendezvous wait
    /// (`--rendezvous-timeout`).
    pub fn processes(mut self, spec: Arc<String>, rendezvous_secs: f64) -> ClusterDriver {
        self.launch = Launch::Processes { spec };
        self.rendezvous_secs = rendezvous_secs;
        self
    }

    /// Run a single node of this cluster over an established transport —
    /// the worker-process entrypoint. The epoch gate stays with the
    /// monitor process, so this node gets a gateless context (worker
    /// roles never claim it).
    pub fn run_node(self, id: usize, transport: Box<dyn Transport>) {
        let stats = CommStats::new(self.n_nodes);
        let ep = Endpoint::with_transport(id, self.n_nodes, transport, &self.model, stats);
        let ctx = ClusterCtx { gate: Mutex::new(None), resume: None };
        (self.node_fn)(ep, &ctx);
    }

    fn spawn(&mut self) {
        let (tx_rep, rx_rep) = channel::<EpochReport>();
        let (tx_dir, rx_dir) = channel::<Directive>();
        let ctx = Arc::new(ClusterCtx {
            gate: Mutex::new(Some(EpochGate { tx: tx_rep, rx: rx_dir })),
            resume: self.resume.clone(),
        });
        let node_fn = self.node_fn.clone();
        let spec = match &self.launch {
            Launch::Threads => None,
            Launch::Processes { spec } => Some(spec.clone()),
        };
        let handle = match spec {
            None => {
                let (mut eps, stats) = build_with_model(self.n_nodes, &self.model);
                if let Some(r) = self.resume.as_deref() {
                    stats.preload(&r.comm);
                    for ep in eps.iter_mut() {
                        let ns = &r.nodes[ep.id()];
                        ep.restore_clock_state(ns.clock);
                        ep.restore_jitter(ns.jitter);
                    }
                }
                if let Some(plan) = &self.faults {
                    for ep in eps.iter_mut() {
                        ep.install_faults(LinkFaults::new(plan.clone(), ep.id()));
                    }
                }
                self.stats = Some(stats);
                std::thread::Builder::new()
                    .name(format!("session-{}", self.name))
                    .spawn(move || {
                        run_endpoints(eps, move |ep| node_fn(ep, &ctx));
                    })
                    .expect("spawn cluster runner thread")
            }
            Some(spec) => {
                assert!(
                    self.resume.is_none(),
                    "resume is not supported over --transport tcp (CLI rejects it)"
                );
                let transport = self.rendezvous(&spec);
                let stats = CommStats::new(self.n_nodes);
                let ep0 = Endpoint::with_transport(
                    0,
                    self.n_nodes,
                    Box::new(transport),
                    &self.model,
                    stats.clone(),
                );
                self.stats = Some(stats);
                std::thread::Builder::new()
                    .name(format!("session-{}", self.name))
                    .spawn(move || node_fn(ep0, &ctx))
                    .expect("spawn monitor thread")
            }
        };
        self.running = Some(Running { reports: rx_rep, directives: tx_dir, handle });
    }

    /// Spawn the q worker processes and complete the TCP rendezvous,
    /// leaving the children registered for teardown. Failures here are
    /// launch failures, not algorithm failures — panic with the cause
    /// (the session layer surfaces it like any cluster failure).
    fn rendezvous(&mut self, spec: &Arc<String>) -> tcp::TcpTransport {
        let (listener, port) =
            tcp::listen().unwrap_or_else(|e| panic!("tcp rendezvous failed: {e:#}"));
        let exe = std::env::current_exe().expect("locate own executable");
        let mut children: Vec<(usize, Child)> = Vec::new();
        for id in 1..self.n_nodes {
            let child = std::process::Command::new(&exe)
                .arg("worker")
                .env(tcp::ENV_SPEC, spec.as_str())
                .env(tcp::ENV_ID, id.to_string())
                .env(tcp::ENV_NODES, self.n_nodes.to_string())
                .env(tcp::ENV_PORT, port.to_string())
                .spawn()
                .unwrap_or_else(|e| panic!("spawn worker process for node {id}: {e}"));
            children.push((id, child));
        }
        let accepted =
            tcp::accept_workers(&listener, self.n_nodes, self.rendezvous_secs, |streams| {
                tcp::check_children(&mut children, streams)
            });
        self.children = children;
        accepted.unwrap_or_else(|e| panic!("tcp rendezvous failed: {e:#}"))
    }

    /// Re-raise a cluster panic on the session thread with the runner's
    /// payload (preserving the "node panicked: ..." message).
    fn raise_cluster_failure(&mut self) -> ! {
        if let Some(r) = self.running.take() {
            match r.handle.join() {
                Err(payload) => std::panic::resume_unwind(payload),
                Ok(()) => panic!("cluster runner exited without reporting an epoch"),
            }
        }
        panic!("cluster is not running");
    }

    /// Automatic crash recovery. When the cluster dies while the fault
    /// plan holds a latched (injected) crash, this absorbs the cascade
    /// panic, rolls the boundary state back and respawns the cluster —
    /// the recovery half of the fault plane:
    ///
    /// 1. detect — the node went dark mid-epoch; its peers saw `Gone`
    ///    and unwound, so the report channel errored. The *plan's* latch
    ///    (set before the injected panic) is the detection signal —
    ///    panic payloads are never parsed.
    /// 2. roll back — synchronous algorithms restart from the newest
    ///    durable snapshot in the attached [`crate::checkpoint::CheckpointStore`]
    ///    (falling back to the monitor-resident boundary state);
    ///    asynchronous ones absorb the loss by restarting from the latest
    ///    epoch boundary.
    /// 3. respawn — the normal resume path: counters preloaded, per-node
    ///    clocks/jitter restored, shards replayed by the node functions.
    ///
    /// Returns false when the failure was not an injected crash — the
    /// caller re-raises it like any cluster failure.
    fn try_recover(&mut self) -> bool {
        let Some(plan) = self.faults.clone() else { return false };
        let Some(crash_t) = plan.take_pending_recovery() else { return false };
        if let Some(r) = self.running.take() {
            // The runner unwound with the injected panic plus the peers'
            // cascade panics — absorb them; this is the scheduled fault,
            // not an algorithm failure.
            let _ = r.handle.join();
        }
        let resume = if self.async_recovery {
            self.last.clone()
        } else {
            plan.store()
                .and_then(|s| s.latest())
                .map(|ck| ck.state.resume)
                .unwrap_or_else(|| self.last.clone())
        };
        let resumed_clock =
            resume.nodes.iter().map(|n| n.clock.clock).fold(0.0f64, f64::max);
        plan.record_recovery(crash_t - resumed_clock);
        crate::util::logger::log(
            crate::util::logger::Level::Warn,
            format_args!(
                "fault plane: injected crash at sim-time {crash_t:.4}s; respawning {} \
                 from epoch {} ({:.4}s of simulated work rolled back)",
                self.name,
                resume.epoch,
                (crash_t - resumed_clock).max(0.0)
            ),
        );
        self.resume = if resume.is_fresh() { None } else { Some(Arc::new(resume.clone())) };
        self.last = resume;
        true
    }
}

impl Driver for ClusterDriver {
    fn name(&self) -> &str {
        &self.name
    }

    fn dataset(&self) -> &str {
        &self.dataset
    }

    fn step(&mut self) -> EpochReport {
        loop {
            if self.running.is_none() {
                self.spawn(); // nodes start their first epoch immediately
            } else if self.running.as_ref().unwrap().directives.send(Directive::Continue).is_err()
            {
                // the cluster died between boundaries — injected crash?
                if self.try_recover() {
                    continue;
                }
                self.raise_cluster_failure();
            }
            match self.running.as_ref().unwrap().reports.recv() {
                Ok(report) => {
                    self.last = ResumeState {
                        epoch: report.epoch,
                        grads: report.grads,
                        w: report.w.clone(),
                        comm: report.comm.clone(),
                        nodes: report.nodes.clone(),
                    };
                    return report;
                }
                Err(_) => {
                    if self.try_recover() {
                        continue;
                    }
                    self.raise_cluster_failure();
                }
            }
        }
    }

    fn state(&self) -> ResumeState {
        self.last.clone()
    }

    fn finish(mut self: Box<Self>) -> FinishOut {
        if let Some(r) = self.running.take() {
            let _ = r.directives.send(Directive::Stop);
            if let Err(payload) = r.handle.join() {
                std::panic::resume_unwind(payload);
            }
        }
        // tcp launch: the monitor has told every worker to stop, so the
        // children are exiting — reap them, loudly if one failed. (If the
        // monitor itself panicked we never get here; Drop kills them.)
        for (id, mut child) in self.children.drain(..) {
            match child.wait() {
                Ok(status) if status.success() => {}
                Ok(status) => panic!("worker process for node {id} exited with {status}"),
                Err(e) => panic!("wait for worker process {id}: {e}"),
            }
        }
        let totals = match &self.stats {
            Some(st) => CommTotals::from_stats(st),
            // never spawned: the counters are whatever the resume carried
            None => CommTotals::from_node_comm(self.last.comm.clone()),
        };
        // the final boundary buffer is usually uniquely held by now (the
        // cluster has wound down) — unwrap the Arc without copying, and
        // fall back to one clone if a checkpoint still shares it
        let w = Arc::try_unwrap(std::mem::take(&mut self.last.w))
            .unwrap_or_else(|shared| (*shared).clone());
        FinishOut { w, totals }
    }
}

impl Drop for ClusterDriver {
    fn drop(&mut self) {
        // Session dropped without finish(): wind the cluster down rather
        // than leaking node threads blocked on the gate.
        if let Some(r) = self.running.take() {
            let _ = r.directives.send(Directive::Stop);
            let _ = r.handle.join(); // swallow panics — we're already unwinding
        }
        // …and never leak worker processes (tcp launch, aborted run).
        for (_id, child) in self.children.iter_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
        self.children.clear();
    }
}

/// Assemble this node's resumable [`NodeState`]: the algorithm-owned RNG
/// words and extras plus the network-plane state the endpoint owns (the
/// simulated clock and, under a `--net jitter` model, the per-message
/// noise stream's PCG words). Every algorithm builds its node states
/// through this helper so no scenario state is ever dropped from a
/// checkpoint.
pub fn net_node_state(ep: &mut Endpoint, rng: Option<[u64; 4]>, extra: Vec<f64>) -> NodeState {
    NodeState { rng, jitter: ep.jitter_words(), clock: ep.clock_state(), extra }
}

/// Helper the monitor nodes share: assemble the per-node state vector from
/// the STATE eval messages of `peers` (own state goes at `own_id`).
///
/// On a remote (tcp) transport each STATE payload arrives with the
/// sender's comm-counter envelope prepended (see [`send_node_state`]);
/// the counters are absorbed into the monitor's [`CommStats`] — stored
/// absolutely, since they are totals the worker counted itself — and
/// stripped before the node state is unpacked.
pub fn collect_node_states(
    ep: &mut Endpoint,
    own_id: usize,
    own: NodeState,
    peers: impl IntoIterator<Item = usize>,
    n_nodes: usize,
) -> Vec<NodeState> {
    let remote = ep.is_remote();
    let mut nodes = vec![NodeState::default(); n_nodes];
    nodes[own_id] = own;
    for peer in peers {
        let msg = ep.recv_eval_from(peer, crate::net::tags::STATE);
        let buf = msg.to_vec(msg.scalars());
        let body = if remote {
            let nc = NodeComm {
                scalars: buf[0].to_bits(),
                bytes: buf[1].to_bits(),
                messages: buf[2].to_bits(),
            };
            ep.stats().set_node(peer, nc);
            ep.stats().set_node_socket(peer, buf[3].to_bits());
            &buf[4..]
        } else {
            &buf[..]
        };
        nodes[peer] = NodeState::unpack(body);
    }
    nodes
}

/// Helper the non-monitor nodes share: ship this node's resumable state to
/// the monitor over the uncounted evaluation plane.
///
/// On a remote (tcp) transport the monitor cannot see this process's
/// counters, so the payload is prefixed with `[scalars, bytes, messages,
/// socket_bytes]`, each `u64` bit-cast into an `f64` lane for exact
/// transfer over the scalar wire.
pub fn send_node_state(ep: &mut Endpoint, monitor: usize, state: &NodeState) {
    let packed = state.pack();
    if ep.is_remote() {
        let id = ep.id();
        let stats = ep.stats().clone();
        let mut v = Vec::with_capacity(4 + packed.len());
        v.push(f64::from_bits(stats.node_scalars(id)));
        v.push(f64::from_bits(stats.node_bytes(id)));
        v.push(f64::from_bits(stats.node_messages(id)));
        v.push(f64::from_bits(ep.socket_bytes()));
        v.extend_from_slice(&packed);
        ep.send_eval(monitor, crate::net::tags::STATE, v);
    } else {
        ep.send_eval(monitor, crate::net::tags::STATE, packed);
    }
}

/// Snapshot helper for the monitor's report. Folds the monitor's own
/// real socket-byte count into the stats first (workers' counts arrive
/// via the [`send_node_state`] envelopes; a no-op 0 under sim).
pub fn comm_snapshot(ep: &Endpoint) -> (u64, u64, Vec<NodeComm>) {
    let stats = ep.stats();
    stats.set_node_socket(ep.id(), ep.socket_bytes());
    (stats.total_scalars(), stats.total_bytes(), stats.per_node())
}
