//! Session layer — the steppable execution API every algorithm runs under.
//!
//! The paper's headline claims are *trajectories* (objective gap vs
//! communication and vs time), and the ROADMAP's production north-star
//! needs long-running jobs that can be observed, preempted and resumed.
//! Both want the same thing from the algorithm layer: the outer epoch as
//! the unit of work. This module provides it:
//!
//! * [`Driver`] — one outer epoch per [`Driver::step`], with
//!   [`Driver::state`] exporting a full mid-run snapshot at the epoch
//!   boundary. Implemented by all ten algorithms (the cluster ones through
//!   [`cluster::ClusterDriver`], the serial pair in [`serial`], the
//!   blocked dense trainer in [`crate::runtime::trainer`]).
//! * [`Session`] / [`SessionBuilder`] — the shared outer loop: computes
//!   the objective off the simulated clock, appends [`crate::metrics::Trace`]
//!   points, notifies [`Observer`]s, and evaluates composable
//!   [`StopPolicy`] values. This is the *single* copy of the per-epoch
//!   trace/stop logic that used to be duplicated inside every algorithm.
//! * [`SessionState`] — the durable snapshot (trace so far + per-node RNG
//!   words, simulated clocks, comm counters, algorithm state) serialized
//!   as the version-2 checkpoint format
//!   ([`crate::checkpoint::SessionCheckpoint`]); a killed run restored
//!   from it continues on the identical trajectory (bit-exact `w`, trace
//!   and per-sender byte counters for the deterministic algorithms).
//!
//! `Algorithm::run` survives as a thin compatibility wrapper over
//! [`Session::run_to_completion`], so the equivalence/convergence suites
//! pin the refactor bit-exactly.

pub mod cluster;
pub mod serial;

use crate::algs::{Algorithm, Problem, RunParams};
use crate::metrics::{CommTotals, RunResult, Trace, TracePoint};
use crate::net::{ClockState, NodeComm, WireFmt};
use crate::util::time::Stopwatch;
use anyhow::{ensure, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// What a completed epoch looked like — the typed payload every
/// [`Observer`] receives and every [`StopPolicy`] is evaluated against.
#[derive(Clone, Debug)]
pub struct StepEvent {
    /// Absolute outer-epoch index of the epoch that just completed
    /// (continues across checkpoint/resume; first fresh epoch is 1).
    pub epoch: usize,
    /// Objective `f(w)` at the epoch boundary (computed off the simulated
    /// clock by the session, not the cluster).
    pub objective: f64,
    /// Simulated cluster time at the monitor node, seconds.
    pub sim_time: f64,
    /// Per-node clock skew at the epoch boundary (max − min simulated node
    /// time, seconds; 0 for single-node drivers) — the straggler
    /// observability metric.
    pub skew: f64,
    /// Host wall-clock of this session, seconds (contention-polluted).
    pub wall_time: f64,
    /// Cumulative stochastic-gradient evaluations.
    pub grads: u64,
    /// Cumulative communicated scalars (derived §4.5 view of `bytes`).
    pub scalars: u64,
    /// Cumulative wire bytes (canonical unit).
    pub bytes: u64,
    /// Per-sender counter snapshot ([`NodeComm`] per node id).
    pub comm: Vec<NodeComm>,
}

/// One node's resumable state inside a [`ResumeState`]: the RNG stream (if
/// the node owns one), the simulated clock, and whatever algorithm-specific
/// payload the node needs beyond the shared parameter vector (SAGA's
/// coefficient table, D-PSGD's local parameter copy, PS-Lite's step
/// counter, ...).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NodeState {
    pub rng: Option<[u64; 4]>,
    /// The net-model jitter stream's PCG words (runs under
    /// `--net jitter`; `None` on jitter-free models) — restored before the
    /// node thread starts so a resume replays the exact noise tail.
    pub jitter: Option<[u64; 4]>,
    pub clock: ClockState,
    pub extra: Vec<f64>,
}

impl NodeState {
    /// Flatten for the evaluation plane (uncounted, exact `f64`): layout
    /// `[has_rng, rng0..rng3 (bit-cast), has_jitter, j0..j3 (bit-cast),
    /// clock, nic_out, nic_in, extra...]`.
    pub(crate) fn pack(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(13 + self.extra.len());
        for words in [self.rng, self.jitter] {
            match words {
                Some(w) => {
                    v.push(1.0);
                    v.extend(w.iter().map(|&x| f64::from_bits(x)));
                }
                None => v.extend([0.0; 5]),
            }
        }
        v.push(self.clock.clock);
        v.push(self.clock.nic_out);
        v.push(self.clock.nic_in);
        v.extend_from_slice(&self.extra);
        v
    }

    pub(crate) fn unpack(v: &[f64]) -> NodeState {
        assert!(v.len() >= 13, "node state payload too short ({})", v.len());
        let words_at = |at: usize| -> Option<[u64; 4]> {
            if v[at] != 0.0 {
                let w = [v[at + 1], v[at + 2], v[at + 3], v[at + 4]];
                Some(w.map(f64::to_bits))
            } else {
                None
            }
        };
        NodeState {
            rng: words_at(0),
            jitter: words_at(5),
            clock: ClockState { clock: v[10], nic_out: v[11], nic_in: v[12] },
            extra: v[13..].to_vec(),
        }
    }
}

/// The training-state half of a session snapshot: everything a driver
/// needs to continue a run at an epoch boundary. `nodes` is indexed by
/// simulated node id; an empty `nodes` with `epoch == 0` means "fresh
/// start".
#[derive(Clone, Debug, Default)]
pub struct ResumeState {
    pub epoch: usize,
    pub grads: u64,
    /// Full assembled parameter vector at the boundary. Behind `Arc`: the
    /// driver's boundary copy, the epoch report and any checkpoint all
    /// share one buffer instead of re-cloning a `d`-vector per epoch.
    pub w: Arc<Vec<f64>>,
    /// Per-sender communication counters at the boundary.
    pub comm: Vec<NodeComm>,
    pub nodes: Vec<NodeState>,
}

impl ResumeState {
    /// A fresh (never-stepped) state for a `d`-dimensional problem on an
    /// `n_nodes` cluster.
    pub fn fresh(d: usize, n_nodes: usize) -> ResumeState {
        ResumeState {
            epoch: 0,
            grads: 0,
            w: Arc::new(vec![0.0; d]),
            comm: vec![NodeComm::default(); n_nodes],
            nodes: Vec::new(),
        }
    }

    pub fn is_fresh(&self) -> bool {
        self.nodes.is_empty() && self.epoch == 0
    }
}

/// A full mid-run session snapshot — what [`Session::state`] exports and
/// the version-2 checkpoint format serializes.
#[derive(Clone, Debug)]
pub struct SessionState {
    pub algorithm: String,
    pub dataset: String,
    pub lambda: f64,
    /// Wire format of the run; a resume must use the same codec or the
    /// byte counters (and f32/sparse trajectories) would diverge.
    pub wire: WireFmt,
    /// Trace accumulated so far (includes the epoch-0 point).
    pub trace: Trace,
    pub resume: ResumeState,
}

/// Raw per-epoch report a [`Driver`] returns from [`Driver::step`]: the
/// session turns it into a [`StepEvent`] (adding the objective and wall
/// time) and a trace point.
#[derive(Clone, Debug)]
pub struct EpochReport {
    pub epoch: usize,
    /// Assembled parameter at the boundary, shared (`Arc`) with the
    /// driver's resume copy — the monitor hands the buffer over instead of
    /// the historical d-length clone per epoch.
    pub w: Arc<Vec<f64>>,
    pub grads: u64,
    pub sim_time: f64,
    pub scalars: u64,
    pub bytes: u64,
    pub comm: Vec<NodeComm>,
    pub nodes: Vec<NodeState>,
}

/// Final accounting a [`Driver`] hands back when the run is finished.
pub struct FinishOut {
    pub w: Vec<f64>,
    pub totals: CommTotals,
}

/// Per-node clock skew of an epoch boundary: max − min simulated node
/// time over the report's node states (0 for single-node or clock-free
/// drivers). This is what makes straggler runs measurable.
fn clock_skew(nodes: &[NodeState]) -> f64 {
    if nodes.len() < 2 {
        return 0.0;
    }
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for n in nodes {
        lo = lo.min(n.clock.clock);
        hi = hi.max(n.clock.clock);
    }
    hi - lo
}

/// A steppable algorithm execution: one outer epoch per [`Driver::step`].
///
/// Restoration is by construction: [`SessionBuilder::resume`] routes a
/// [`SessionState`] into the algorithm's driver constructor, which rebuilds
/// every node from its [`NodeState`] (RNG stream, simulated clock,
/// algorithm extras) and the shared [`ResumeState`] (`w`, epoch, counters).
pub trait Driver {
    /// Algorithm name as reported in results (e.g. `"fdsvrg"`).
    fn name(&self) -> &str;
    /// Dataset name as reported in results.
    fn dataset(&self) -> &str;
    /// Advance exactly one outer epoch and report the boundary.
    fn step(&mut self) -> EpochReport;
    /// Export the resumable training state at the last epoch boundary.
    fn state(&self) -> ResumeState;
    /// Stop the run (terminating any cluster nodes) and return the final
    /// parameter vector plus communication totals.
    fn finish(self: Box<Self>) -> FinishOut;
}

/// Composable stopping rules, evaluated by the session after every epoch.
/// These subsume the old ad-hoc `gap_stop`/`sim_time_cap` fields of
/// [`RunParams`] (which are still translated into the equivalent policies
/// for compatibility).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StopPolicy {
    /// Stop once the absolute epoch index reaches `max` (a resumed run
    /// counts the epochs done before the checkpoint).
    MaxEpochs(usize),
    /// Stop once `objective − f_opt ≤ target` (paper's gap-based stop).
    GapReached { f_opt: f64, target: f64 },
    /// Stop once the simulated clock passes this many seconds (the
    /// ">1000s" rows of the paper's Table 3).
    SimTimeCap(f64),
    /// Stop after `epochs` consecutive epochs without improving the best
    /// objective by at least `min_delta` (plateau detection).
    Patience { epochs: usize, min_delta: f64 },
}

/// Runtime wrapper pairing a policy with its evaluation state (only
/// `Patience` carries any).
struct PolicyRt {
    spec: StopPolicy,
    best: f64,
    since: usize,
}

impl PolicyRt {
    fn new(spec: StopPolicy) -> PolicyRt {
        PolicyRt { spec, best: f64::INFINITY, since: 0 }
    }

    /// Evaluate against a completed epoch; returns true to stop.
    fn fires(&mut self, ev: &StepEvent) -> bool {
        match self.spec {
            StopPolicy::MaxEpochs(max) => ev.epoch >= max,
            StopPolicy::GapReached { f_opt, target } => ev.objective - f_opt <= target,
            StopPolicy::SimTimeCap(cap) => ev.sim_time >= cap,
            StopPolicy::Patience { epochs, min_delta } => {
                if ev.objective < self.best - min_delta {
                    self.best = ev.objective;
                    self.since = 0;
                } else {
                    self.since += 1;
                }
                self.since >= epochs
            }
        }
    }
}

/// Read-only view of the running session handed to observers, with enough
/// access to export a full checkpoint ([`SessionView::state`]).
pub struct SessionView<'a> {
    driver: &'a dyn Driver,
    trace: &'a Trace,
    lambda: f64,
    wire: WireFmt,
}

impl SessionView<'_> {
    pub fn trace(&self) -> &Trace {
        self.trace
    }

    /// Export the full session snapshot at the current epoch boundary.
    pub fn state(&self) -> SessionState {
        SessionState {
            algorithm: self.driver.name().to_string(),
            dataset: self.driver.dataset().to_string(),
            lambda: self.lambda,
            wire: self.wire,
            trace: self.trace.clone(),
            resume: self.driver.state(),
        }
    }
}

/// Typed per-epoch callback. Observers see every completed epoch exactly
/// once, in order, after the trace point is appended and before stop
/// policies are evaluated.
pub trait Observer {
    fn on_epoch(&mut self, ev: &StepEvent, session: &SessionView<'_>);
}

/// Adapter so plain closures work as observers (ignoring the view):
/// `builder.observe(FnObserver(|ev| ...))`.
pub struct FnObserver<F: FnMut(&StepEvent)>(pub F);

impl<F: FnMut(&StepEvent)> Observer for FnObserver<F> {
    fn on_epoch(&mut self, ev: &StepEvent, _session: &SessionView<'_>) {
        (self.0)(ev)
    }
}

/// Where a [`CheckpointObserver`] persists its snapshots: one
/// overwrite-in-place file (the classic `--ckpt` path) or a rolling
/// last-k [`crate::checkpoint::CheckpointStore`] directory (what crash
/// recovery reads back).
enum CheckpointTarget {
    File(PathBuf),
    Store(Arc<crate::checkpoint::CheckpointStore>),
}

/// Observer that writes a version-2 session checkpoint every `every`
/// epochs. Epochs that are not multiples of `every` are skipped — callers
/// that need the final state on disk regardless (the CLI does) write one
/// more checkpoint from [`Session::state`] after the run ends.
pub struct CheckpointObserver {
    target: CheckpointTarget,
    every: usize,
}

impl CheckpointObserver {
    /// Overwrite one checkpoint file in place every `every` epochs.
    pub fn new(path: impl Into<PathBuf>, every: usize) -> CheckpointObserver {
        CheckpointObserver { target: CheckpointTarget::File(path.into()), every: every.max(1) }
    }

    /// Append to a rolling last-k snapshot store every `every` epochs
    /// (crash recovery respawns from the newest snapshot that verifies).
    pub fn rotating(
        store: Arc<crate::checkpoint::CheckpointStore>,
        every: usize,
    ) -> CheckpointObserver {
        CheckpointObserver { target: CheckpointTarget::Store(store), every: every.max(1) }
    }
}

impl Observer for CheckpointObserver {
    fn on_epoch(&mut self, ev: &StepEvent, session: &SessionView<'_>) {
        if ev.epoch % self.every != 0 {
            return;
        }
        let ckpt = crate::checkpoint::SessionCheckpoint::new(session.state());
        let wrote = match &self.target {
            CheckpointTarget::File(path) => ckpt.save(path),
            CheckpointTarget::Store(store) => store.save(&ckpt).map(|_| ()),
        };
        if let Err(e) = wrote {
            crate::util::logger::log(
                crate::util::logger::Level::Warn,
                format_args!("checkpoint write failed at epoch {}: {e:#}", ev.epoch),
            );
        }
    }
}

/// Builder for a [`Session`]: problem + params + observers + stop policies
/// (+ optional resume state). This replaces direct `run()` calls as the
/// way algorithm executions are configured.
pub struct SessionBuilder<'d> {
    algo: Option<Algorithm>,
    driver: Option<Box<dyn Driver + 'd>>,
    problem: Problem,
    params: RunParams,
    observers: Vec<Box<dyn Observer + 'd>>,
    policies: Vec<StopPolicy>,
    default_policies: bool,
    resume: Option<SessionState>,
}

impl<'d> SessionBuilder<'d> {
    /// Session for one of the named algorithms.
    pub fn new(algo: Algorithm, problem: &Problem, params: RunParams) -> SessionBuilder<'d> {
        SessionBuilder {
            algo: Some(algo),
            driver: None,
            problem: problem.clone(),
            params,
            observers: Vec::new(),
            policies: Vec::new(),
            default_policies: true,
            resume: None,
        }
    }

    /// Session over a caller-provided driver (the blocked dense trainer
    /// uses this to ride the same runner).
    pub fn from_driver(
        driver: Box<dyn Driver + 'd>,
        problem: &Problem,
        params: RunParams,
    ) -> SessionBuilder<'d> {
        SessionBuilder {
            algo: None,
            driver: Some(driver),
            problem: problem.clone(),
            params,
            observers: Vec::new(),
            policies: Vec::new(),
            default_policies: true,
            resume: None,
        }
    }

    /// Attach a per-epoch observer.
    pub fn observe(mut self, o: impl Observer + 'd) -> Self {
        self.observers.push(Box::new(o));
        self
    }

    /// Add a stop policy (composable; the run stops when *any* fires).
    pub fn stop_when(mut self, p: StopPolicy) -> Self {
        self.policies.push(p);
        self
    }

    /// Drop the policies derived from `RunParams` (`MaxEpochs(outer)`,
    /// `gap_stop`, `sim_time_cap`) — the caller provides all of them.
    pub fn explicit_policies_only(mut self) -> Self {
        self.default_policies = false;
        self
    }

    /// Resume from a mid-run snapshot (a version-2 checkpoint). The
    /// session continues the trace and counters; `MaxEpochs` counts
    /// absolute epochs, so `outer` means "total epochs including the ones
    /// before the checkpoint".
    pub fn resume(mut self, state: SessionState) -> Self {
        self.resume = Some(state);
        self
    }

    /// Validate and construct the session. Fresh sessions cannot fail;
    /// resumes are validated against the problem and params.
    pub fn build(self) -> Result<Session<'d>> {
        let SessionBuilder {
            algo,
            driver,
            problem,
            params,
            observers,
            policies,
            default_policies,
            resume,
        } = self;
        let d = problem.d();
        let (resume_state, mut trace) = match resume {
            Some(st) => {
                if let Some(a) = algo {
                    ensure!(
                        st.algorithm == a.name(),
                        "checkpoint is for algorithm {:?}, not {:?}",
                        st.algorithm,
                        a.name()
                    );
                }
                ensure!(
                    st.dataset == problem.ds.name,
                    "checkpoint is for dataset {:?}, not {:?}",
                    st.dataset,
                    problem.ds.name
                );
                ensure!(
                    st.resume.w.len() == d,
                    "checkpoint dim {} does not match problem dim {d}",
                    st.resume.w.len()
                );
                ensure!(
                    st.wire == params.wire,
                    "checkpoint was taken under the {} wire, run requests {}",
                    st.wire.name(),
                    params.wire.name()
                );
                ensure!(!st.trace.points.is_empty(), "checkpoint carries an empty trace");
                let last = st.trace.points.last().unwrap();
                ensure!(
                    last.outer == st.resume.epoch,
                    "checkpoint trace ends at epoch {} but state is at epoch {}",
                    last.outer,
                    st.resume.epoch
                );
                (Some(st.resume), st.trace)
            }
            None => (None, Trace::default()),
        };

        let driver: Box<dyn Driver + 'd> = match driver {
            Some(dr) => dr,
            None => {
                let a = algo.expect("builder has either an algorithm or a driver");
                a.make_driver(&problem, &params, resume_state)?
            }
        };

        // Fresh sessions record the epoch-0 point (objective at the
        // initial parameter) exactly like every algorithm used to.
        if trace.points.is_empty() {
            let w0 = driver.state().w;
            trace.push(TracePoint {
                outer: 0,
                sim_time: 0.0,
                skew: 0.0,
                wall_time: 0.0,
                scalars: 0,
                bytes: 0,
                grads: 0,
                objective: problem.objective(&w0),
            });
        }

        let mut all_policies: Vec<PolicyRt> = Vec::new();
        if default_policies {
            all_policies.push(PolicyRt::new(StopPolicy::MaxEpochs(params.outer)));
            if let Some((f_opt, target)) = params.gap_stop {
                all_policies.push(PolicyRt::new(StopPolicy::GapReached { f_opt, target }));
            }
            if let Some(cap) = params.sim_time_cap {
                all_policies.push(PolicyRt::new(StopPolicy::SimTimeCap(cap)));
            }
        }
        all_policies.extend(policies.into_iter().map(PolicyRt::new));

        let lambda = problem.reg.lambda();
        Ok(Session {
            driver,
            problem,
            observers,
            policies: all_policies,
            trace,
            lambda,
            wire: params.wire,
            wall: Stopwatch::start(),
            stop_requested: false,
        })
    }
}

/// A running (steppable) algorithm execution. Construct with
/// [`SessionBuilder`]; drive with [`Session::step`] or
/// [`Session::run_to_completion`].
pub struct Session<'d> {
    driver: Box<dyn Driver + 'd>,
    problem: Problem,
    observers: Vec<Box<dyn Observer + 'd>>,
    policies: Vec<PolicyRt>,
    trace: Trace,
    lambda: f64,
    wire: WireFmt,
    wall: Stopwatch,
    stop_requested: bool,
}

impl<'d> Session<'d> {
    /// Completed-epoch count so far (absolute; includes pre-resume epochs).
    pub fn epoch(&self) -> usize {
        self.trace.points.last().map(|p| p.outer).unwrap_or(0)
    }

    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// Whether a stop policy has fired (or `MaxEpochs` is already met).
    /// `run_to_completion` steps until this returns true.
    pub fn should_stop(&self) -> bool {
        if self.stop_requested {
            return true;
        }
        // MaxEpochs can be satisfied before any step (outer = 0, or a
        // resume at/past the target) — evaluate it against the trace tail.
        let epoch = self.epoch();
        self.policies.iter().any(|p| matches!(p.spec, StopPolicy::MaxEpochs(max) if epoch >= max))
    }

    /// Advance exactly one outer epoch: runs the driver, appends the trace
    /// point, notifies observers (each epoch exactly once), and evaluates
    /// stop policies.
    pub fn step(&mut self) -> StepEvent {
        let report = self.driver.step();
        let objective = self.problem.objective(&report.w);
        let ev = StepEvent {
            epoch: report.epoch,
            objective,
            sim_time: report.sim_time,
            skew: clock_skew(&report.nodes),
            wall_time: self.wall.seconds(),
            grads: report.grads,
            scalars: report.scalars,
            bytes: report.bytes,
            comm: report.comm,
        };
        self.trace.push(TracePoint {
            outer: ev.epoch,
            sim_time: ev.sim_time,
            skew: ev.skew,
            wall_time: ev.wall_time,
            scalars: ev.scalars,
            bytes: ev.bytes,
            grads: ev.grads,
            objective: ev.objective,
        });
        let Session { driver, trace, observers, lambda, wire, .. } = self;
        let view = SessionView { driver: driver.as_ref(), trace, lambda: *lambda, wire: *wire };
        for o in observers.iter_mut() {
            o.on_epoch(&ev, &view);
        }
        // evaluate every policy (no short-circuit: Patience must see each
        // epoch to track its plateau counter)
        let mut stop = false;
        for p in self.policies.iter_mut() {
            stop |= p.fires(&ev);
        }
        if stop {
            self.stop_requested = true;
        }
        ev
    }

    /// Export the full session snapshot at the current epoch boundary.
    pub fn state(&self) -> SessionState {
        SessionState {
            algorithm: self.driver.name().to_string(),
            dataset: self.driver.dataset().to_string(),
            lambda: self.lambda,
            wire: self.wire,
            trace: self.trace.clone(),
            resume: self.driver.state(),
        }
    }

    /// Stop the run and assemble the final [`RunResult`].
    pub fn finish(self) -> RunResult {
        let Session { driver, trace, wall, .. } = self;
        let name = driver.name().to_string();
        let dataset = driver.dataset().to_string();
        let out = driver.finish();
        let total_sim_time = trace.points.last().map(|p| p.sim_time).unwrap_or(0.0);
        let wall_s = wall.seconds();
        RunResult::from_totals(&name, &dataset, out.w, trace, total_sim_time, wall_s, out.totals)
    }

    /// The fire-and-forget path `Algorithm::run` wraps: step until a stop
    /// policy fires, then finish.
    pub fn run_to_completion(mut self) -> RunResult {
        while !self.should_stop() {
            self.step();
        }
        self.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, GenSpec};
    use crate::net::SimParams;

    fn tiny_problem() -> Problem {
        let ds = generate(&GenSpec::new("t", 150, 60, 10).with_seed(17));
        Problem::logistic_l2(ds, 1e-2)
    }

    fn fast_params(q: usize, outer: usize) -> RunParams {
        RunParams { q, outer, sim: SimParams::free(), ..Default::default() }
    }

    #[test]
    fn session_runs_are_deterministic_across_invocations() {
        // Two independently built sessions over the same seed/params must
        // agree bit-for-bit (thread scheduling of the cluster must not
        // leak into the numerics). The session-vs-historical-loop pinning
        // itself lives in the equivalence/convergence/comm-accounting
        // suites, whose expectations predate the session layer.
        let p = tiny_problem();
        let params = fast_params(3, 4);
        let a = Algorithm::FdSvrg.run(&p, &params);
        let b = SessionBuilder::new(Algorithm::FdSvrg, &p, params)
            .build()
            .unwrap()
            .run_to_completion();
        assert_eq!(a.w, b.w);
        assert_eq!(a.total_scalars, b.total_scalars);
        assert_eq!(a.trace.points.len(), b.trace.points.len());
    }

    #[test]
    fn observers_see_every_epoch_exactly_once() {
        let p = tiny_problem();
        let outer = 5;
        let seen = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        struct Collect(std::rc::Rc<std::cell::RefCell<Vec<usize>>>);
        impl Observer for Collect {
            fn on_epoch(&mut self, ev: &StepEvent, _s: &SessionView<'_>) {
                self.0.borrow_mut().push(ev.epoch);
            }
        }
        let session = SessionBuilder::new(Algorithm::FdSvrg, &p, fast_params(2, outer))
            .observe(Collect(seen.clone()))
            .build()
            .unwrap();
        let _ = session.run_to_completion();
        assert_eq!(*seen.borrow(), (1..=outer).collect::<Vec<_>>());
    }

    #[test]
    fn patience_fires_on_plateau() {
        // Serial SGD with a big min_delta plateaus immediately: Patience
        // must end the run before MaxEpochs.
        let p = tiny_problem();
        let res = SessionBuilder::new(Algorithm::SerialSgd, &p, fast_params(1, 50))
            .stop_when(StopPolicy::Patience { epochs: 3, min_delta: 10.0 })
            .build()
            .unwrap()
            .run_to_completion();
        // epoch 0 point + 3 patience epochs
        assert_eq!(res.trace.points.len(), 4, "{:?}", res.trace.points.len());
    }

    #[test]
    fn max_epochs_zero_runs_no_epochs() {
        let p = tiny_problem();
        let res = SessionBuilder::new(Algorithm::FdSvrg, &p, fast_params(2, 0))
            .build()
            .unwrap()
            .run_to_completion();
        assert_eq!(res.trace.points.len(), 1); // the epoch-0 point only
        assert_eq!(res.total_scalars, 0);
    }

    #[test]
    fn manual_stepping_exposes_state() {
        let p = tiny_problem();
        let mut session =
            SessionBuilder::new(Algorithm::FdSvrg, &p, fast_params(2, 10)).build().unwrap();
        let e1 = session.step();
        assert_eq!(e1.epoch, 1);
        let st = session.state();
        assert_eq!(st.resume.epoch, 1);
        assert_eq!(st.resume.w.len(), p.d());
        assert_eq!(st.algorithm, "fdsvrg");
        let e2 = session.step();
        assert_eq!(e2.epoch, 2);
        assert!(e2.scalars > e1.scalars);
        let res = session.finish();
        assert_eq!(res.trace.points.len(), 3);
    }

    #[test]
    fn node_state_pack_round_trips() {
        let st = NodeState {
            rng: Some([1, u64::MAX, 0x8000_0000_0000_0000, 42]),
            jitter: Some([7, 0, u64::MAX, 3]),
            clock: ClockState { clock: 1.5, nic_out: 2.5, nic_in: 0.25 },
            extra: vec![3.0, -4.0],
        };
        assert_eq!(NodeState::unpack(&st.pack()), st);
        let none =
            NodeState { rng: None, jitter: None, clock: ClockState::default(), extra: vec![] };
        assert_eq!(NodeState::unpack(&none.pack()), none);
        // mixed: jitter without an algorithm RNG (a monitor node under --net jitter)
        let mixed = NodeState {
            rng: None,
            jitter: Some([1, 2, 3, 4]),
            clock: ClockState::default(),
            extra: vec![9.0],
        };
        assert_eq!(NodeState::unpack(&mixed.pack()), mixed);
    }
}
