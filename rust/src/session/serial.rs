//! [`Driver`] implementations for the serial baselines (single node, no
//! cluster): one outer iteration per [`Driver::step`], resumable from the
//! checkpointed RNG words + parameter vector.

use super::{Driver, EpochReport, FinishOut, NodeState, ResumeState};
use crate::algs::serial::{sgd_epoch, svrg_epoch, SgdState, SvrgOption, SvrgState};
use crate::algs::{Problem, RunParams};
use crate::metrics::CommTotals;
use anyhow::{ensure, Result};
use std::sync::Arc;

/// Serial SVRG (Option I — the `Algorithm::SerialSvrg` dispatch) as a
/// steppable driver.
pub struct SerialSvrgDriver {
    problem: Problem,
    eta: f64,
    m_inner: usize,
    option: SvrgOption,
    st: SvrgState,
    epoch: usize,
    grads: u64,
}

impl SerialSvrgDriver {
    pub fn new(
        problem: &Problem,
        params: &RunParams,
        resume: Option<ResumeState>,
    ) -> Result<SerialSvrgDriver> {
        let eta = params.effective_eta(problem);
        let (st, epoch, grads) = match resume {
            Some(r) if !r.is_fresh() => {
                ensure!(r.nodes.len() == 1, "serial checkpoint must carry exactly one node");
                let node = &r.nodes[0];
                let sample = node.rng.ok_or_else(|| anyhow::anyhow!("missing RNG state"))?;
                ensure!(node.extra.len() == 4, "serial-svrg node extra must hold the option RNG");
                let option = [
                    node.extra[0].to_bits(),
                    node.extra[1].to_bits(),
                    node.extra[2].to_bits(),
                    node.extra[3].to_bits(),
                ];
                (SvrgState::restore(problem, r.w.to_vec(), sample, option), r.epoch, r.grads)
            }
            _ => (SvrgState::fresh(problem, params.seed), 0, 0),
        };
        let st = st.with_threads(params.threads);
        // build the CSR mirror at construction time (like the cluster
        // drivers' partition-time prewarm) so the one-time O(nnz)
        // transpose never lands inside the first timed epoch
        if params.threads > 1 {
            problem.ds.x.ensure_mirror();
        }
        Ok(SerialSvrgDriver {
            problem: problem.clone(),
            eta,
            m_inner: params.m_inner,
            option: SvrgOption::I,
            st,
            epoch,
            grads,
        })
    }
}

impl Driver for SerialSvrgDriver {
    fn name(&self) -> &str {
        "serial-svrg"
    }

    fn dataset(&self) -> &str {
        &self.problem.ds.name
    }

    fn step(&mut self) -> EpochReport {
        self.grads += svrg_epoch(&self.problem, self.eta, self.m_inner, self.option, &mut self.st);
        self.epoch += 1;
        EpochReport {
            epoch: self.epoch,
            w: Arc::new(self.st.w.clone()),
            grads: self.grads,
            sim_time: 0.0,
            scalars: 0,
            bytes: 0,
            comm: Vec::new(),
            nodes: vec![self.node_state()],
        }
    }

    fn state(&self) -> ResumeState {
        ResumeState {
            epoch: self.epoch,
            grads: self.grads,
            w: Arc::new(self.st.w.clone()),
            comm: Vec::new(),
            nodes: vec![self.node_state()],
        }
    }

    fn finish(self: Box<Self>) -> FinishOut {
        FinishOut { w: self.st.w, totals: CommTotals::default() }
    }
}

impl SerialSvrgDriver {
    fn node_state(&self) -> NodeState {
        NodeState {
            rng: Some(self.st.sample_rng.state_words()),
            jitter: None,
            clock: Default::default(),
            extra: self.st.option_rng.state_words().iter().map(|&w| f64::from_bits(w)).collect(),
        }
    }
}

/// Serial SGD (with the `Algorithm::run` decay `1/N`) as a steppable
/// driver.
pub struct SerialSgdDriver {
    problem: Problem,
    eta0: f64,
    decay: f64,
    st: SgdState,
    epoch: usize,
}

impl SerialSgdDriver {
    pub fn new(
        problem: &Problem,
        params: &RunParams,
        resume: Option<ResumeState>,
    ) -> Result<SerialSgdDriver> {
        let eta0 = params.effective_eta(problem);
        let decay = 1.0 / problem.n() as f64;
        let (st, epoch) = match resume {
            Some(r) if !r.is_fresh() => {
                ensure!(r.nodes.len() == 1, "serial checkpoint must carry exactly one node");
                let node = &r.nodes[0];
                let rng = node.rng.ok_or_else(|| anyhow::anyhow!("missing RNG state"))?;
                ensure!(node.extra.len() == 1, "serial-sgd node extra must hold the step counter");
                (SgdState::restore(r.w.to_vec(), rng, node.extra[0] as u64), r.epoch)
            }
            _ => (SgdState::fresh(problem, params.seed), 0),
        };
        Ok(SerialSgdDriver { problem: problem.clone(), eta0, decay, st, epoch })
    }

    fn node_state(&self) -> NodeState {
        NodeState {
            rng: Some(self.st.rng.state_words()),
            jitter: None,
            clock: Default::default(),
            extra: vec![self.st.step as f64],
        }
    }
}

impl Driver for SerialSgdDriver {
    fn name(&self) -> &str {
        "serial-sgd"
    }

    fn dataset(&self) -> &str {
        &self.problem.ds.name
    }

    fn step(&mut self) -> EpochReport {
        sgd_epoch(&self.problem, self.eta0, self.decay, &mut self.st);
        self.epoch += 1;
        EpochReport {
            epoch: self.epoch,
            w: Arc::new(self.st.w.clone()),
            grads: self.st.step,
            sim_time: 0.0,
            scalars: 0,
            bytes: 0,
            comm: Vec::new(),
            nodes: vec![self.node_state()],
        }
    }

    fn state(&self) -> ResumeState {
        ResumeState {
            epoch: self.epoch,
            grads: self.st.step,
            w: Arc::new(self.st.w.clone()),
            comm: Vec::new(),
            nodes: vec![self.node_state()],
        }
    }

    fn finish(self: Box<Self>) -> FinishOut {
        FinishOut { w: self.st.w, totals: CommTotals::default() }
    }
}
