//! Deterministic PRNG substrate (the `rand` crate is unavailable offline).
//!
//! [`Pcg64`] is the PCG-XSL-RR 128/64 generator (O'Neill 2014): 128-bit LCG
//! state, 64-bit xorshift-rotate output. It is fast, statistically solid for
//! simulation workloads, and — critically for FD-SVRG — *seed-reproducible*:
//! the coordinator and all workers derive the identical instance-sampling
//! sequence from a shared seed, which is what makes the distributed update
//! rule exactly equal to serial SVRG (paper §4.3).

/// SplitMix64: used to expand a single `u64` seed into PCG's 128-bit state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSL-RR 128/64.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Seed from a single `u64` (expanded via SplitMix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let s1 = splitmix64(&mut sm);
        let i0 = splitmix64(&mut sm);
        let i1 = splitmix64(&mut sm);
        let state = ((s0 as u128) << 64) | s1 as u128;
        // stream/increment must be odd
        let inc = ((((i0 as u128) << 64) | i1 as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_add(state);
        rng.next_u64(); // advance away from the seeding artifacts
        rng
    }

    /// Export the raw generator state as four words (`state` high/low,
    /// `inc` high/low) — the checkpointable representation used by the
    /// session layer's mid-run snapshots.
    pub fn state_words(&self) -> [u64; 4] {
        [
            (self.state >> 64) as u64,
            self.state as u64,
            (self.inc >> 64) as u64,
            self.inc as u64,
        ]
    }

    /// Rebuild a generator from [`Pcg64::state_words`] output. The restored
    /// stream continues bit-exactly where the exported one stopped.
    pub fn from_state_words(words: [u64; 4]) -> Pcg64 {
        Pcg64 {
            state: ((words[0] as u128) << 64) | words[1] as u128,
            inc: ((words[2] as u128) << 64) | words[3] as u128,
        }
    }

    /// Derive an independent child stream (for per-worker RNGs that must not
    /// correlate with the shared sampling stream).
    pub fn child(&mut self, tag: u64) -> Pcg64 {
        let a = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Pcg64::seed_from_u64(a)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire's rejection method).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (polar form avoided; trig is fine here).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Zipf-like power-law sample over `[0, n)` with exponent `s` using
    /// inverse-CDF on the continuous approximation. Used by the synthetic
    /// text-like dataset generator (feature frequencies in news20/webspam
    /// follow a power law).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        if s <= 0.0 {
            return self.below(n);
        }
        let u = self.next_f64();
        if (s - 1.0).abs() < 1e-9 {
            // CDF ∝ ln(1 + x)
            let x = ((1.0 + n as f64).powf(u) - 1.0).floor() as usize;
            return x.min(n - 1);
        }
        let p = 1.0 - s;
        let x = ((u * ((n as f64 + 1.0).powf(p) - 1.0) + 1.0).powf(1.0 / p) - 1.0).floor() as usize;
        x.min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproducible_from_seed() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg64::seed_from_u64(3);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            let expected = n / 10;
            assert!(
                (c as i64 - expected as i64).unsigned_abs() < (expected / 10) as u64,
                "bucket count {c} too far from {expected}"
            );
        }
    }

    #[test]
    fn below_covers_bound_edges() {
        let mut r = Pcg64::seed_from_u64(11);
        for _ in 0..1000 {
            assert_eq!(r.below(1), 0);
        }
        let mut seen = [false; 3];
        for _ in 0..1000 {
            seen[r.below(3)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed_from_u64(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed_from_u64(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::seed_from_u64(6);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn zipf_head_heavier_than_tail() {
        let mut r = Pcg64::seed_from_u64(8);
        let mut head = 0;
        let mut tail = 0;
        for _ in 0..50_000 {
            let x = r.zipf(1000, 1.1);
            if x < 10 {
                head += 1;
            }
            if x >= 500 {
                tail += 1;
            }
        }
        assert!(head > tail * 3, "head={head} tail={tail}");
    }

    #[test]
    fn zipf_within_range() {
        let mut r = Pcg64::seed_from_u64(10);
        for _ in 0..10_000 {
            assert!(r.zipf(17, 1.2) < 17);
        }
    }

    #[test]
    fn state_words_round_trip_continues_stream() {
        let mut a = Pcg64::seed_from_u64(99);
        for _ in 0..37 {
            a.below(1000);
        }
        let mut b = Pcg64::from_state_words(a.state_words());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
            assert_eq!(a.below(17), b.below(17));
        }
    }

    #[test]
    fn child_streams_are_independent() {
        let mut parent = Pcg64::seed_from_u64(1);
        let mut c1 = parent.child(1);
        let mut c2 = parent.child(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}
