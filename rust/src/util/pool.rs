//! Deterministic compute pool — the multi-core engine of the sparse
//! kernels (`std::thread` only; the crate keeps `anyhow` as its sole
//! dependency).
//!
//! ## Determinism contract
//!
//! A parallel region splits an output slice into **fixed contiguous
//! chunks** — chunk `t` of `k` over `n` elements is exactly
//! `[t·n/k, (t+1)·n/k)` — and every element of every chunk is computed by
//! the same scalar code the serial kernel runs. No element is ever touched
//! by two workers and no reduction crosses a chunk boundary, so the result
//! is a pure function of `(input, n, k)`: bit-identical across runs,
//! thread-scheduling, and — for the column/row-parallel kernels built on
//! top ([`crate::sparse::CscMatrix`]) — across every thread count `k`.
//!
//! ## Execution model
//!
//! Workers are *scoped* threads spawned per region (`std::thread::scope`),
//! not persistent: the regions this pool serves are the O(nnz) kernels
//! `Dᵀw` and `Dc`, against which a few short-lived spawns are noise, and
//! scoped borrows keep the API free of `unsafe` lifetime laundering. A
//! pool of width 1 (the default) runs the region inline on the caller —
//! the exact serial code path, zero overhead.
//!
//! ## Simulated-time invariance
//!
//! The cluster simulator charges each node the CPU time of *its own
//! thread* ([`crate::util::time::ThreadCpuTimer`]). Work farmed out to
//! pool workers would silently vanish from that clock — `--threads 8`
//! would look 8× faster on the *simulated* cluster, conflating host
//! parallelism with the modeled hardware. Instead every region measures
//! its workers' thread-CPU time and credits the total to a thread-local
//! accumulator on the caller ([`take_foreign_cpu`]); the network
//! endpoint drains it into the simulated clock on its next `tick`. The
//! modeled compute cost is therefore the *serial* CPU regardless of `k`
//! (up to measurement noise, which the host clock carries anyway), and
//! `NetModel::charge_compute` needs no change.

use crate::util::time::ThreadCpuTimer;
use std::cell::Cell;
use std::ops::Range;

thread_local! {
    /// CPU seconds burned by pool workers on behalf of this thread since
    /// the last [`take_foreign_cpu`] drain.
    static FOREIGN_CPU: Cell<f64> = const { Cell::new(0.0) };
}

/// Drain the calling thread's foreign-CPU accumulator: seconds of worker
/// thread-CPU time spent in pool regions this thread dispatched since the
/// last drain. The simulator's `Endpoint::tick` adds this to the node's
/// own lap so `--threads K` leaves the simulated clock's meaning intact.
pub fn take_foreign_cpu() -> f64 {
    FOREIGN_CPU.with(|c| c.replace(0.0))
}

fn credit_foreign_cpu(seconds: f64) {
    if seconds > 0.0 {
        FOREIGN_CPU.with(|c| c.set(c.get() + seconds));
    }
}

/// The fixed contiguous chunk grid: `k` ranges covering `[0, n)` with
/// `ranges[t] = t·n/k .. (t+1)·n/k`. Chunk sizes differ by at most one
/// element and depend only on `(n, k)` — never on scheduling.
pub fn chunk_ranges(n: usize, k: usize) -> Vec<Range<usize>> {
    let k = k.max(1);
    (0..k).map(|t| (t * n / k)..((t + 1) * n / k)).collect()
}

/// Deterministic data-parallel executor over fixed contiguous chunks.
#[derive(Clone, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// A pool of `threads` workers (clamped to ≥ 1). Width 1 executes
    /// every region inline on the caller — today's serial behavior.
    pub fn new(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// The inline (single-thread) pool.
    pub fn serial() -> Pool {
        Pool { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(start, chunk)` over the fixed contiguous chunks of `out`
    /// (`start` is the chunk's offset into `out`). The caller thread
    /// executes chunk 0; scoped workers execute the rest; worker CPU time
    /// is credited to the caller's foreign-CPU accumulator (see the
    /// module docs). Panics in a worker propagate to the caller.
    pub fn for_each_chunk<F>(&self, out: &mut [f64], f: F)
    where
        F: Fn(usize, &mut [f64]) + Sync,
    {
        if out.is_empty() {
            return;
        }
        let k = self.threads.min(out.len());
        if k <= 1 {
            f(0, out);
            return;
        }
        // carve `out` into the fixed grid up front: disjoint &mut chunks
        let ranges = chunk_ranges(out.len(), k);
        let mut parts: Vec<(usize, &mut [f64])> = Vec::with_capacity(k);
        let mut rest: &mut [f64] = out;
        let mut at = 0usize;
        for r in &ranges {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(r.end - at);
            parts.push((at, head));
            at = r.end;
            rest = tail;
        }
        debug_assert!(rest.is_empty(), "chunk grid must consume the whole slice");
        let f = &f;
        std::thread::scope(|s| {
            let mut parts = parts.into_iter();
            let (start0, chunk0) = parts.next().expect("k >= 1 chunks");
            let handles: Vec<_> = parts
                .map(|(start, chunk)| {
                    s.spawn(move || {
                        let mut cpu = ThreadCpuTimer::start();
                        f(start, chunk);
                        cpu.lap()
                    })
                })
                .collect();
            f(start0, chunk0);
            let foreign: f64 = handles
                .into_iter()
                .map(|h| h.join().expect("pool worker panicked"))
                .sum();
            credit_foreign_cpu(foreign);
        });
    }
}

impl Default for Pool {
    fn default() -> Pool {
        Pool::serial()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_grid_is_contiguous_and_total() {
        for n in [0usize, 1, 2, 7, 100, 101] {
            for k in [1usize, 2, 3, 8, 150] {
                let rs = chunk_ranges(n, k);
                assert_eq!(rs.len(), k);
                assert_eq!(rs[0].start, 0);
                assert_eq!(rs[k - 1].end, n);
                for w in rs.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
                let sizes: Vec<usize> = rs.iter().map(|r| r.end - r.start).collect();
                let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(hi - lo <= 1, "n={n} k={k}: uneven grid {sizes:?}");
            }
        }
    }

    #[test]
    fn for_each_chunk_covers_every_element_once() {
        for threads in [1usize, 2, 3, 8] {
            let pool = Pool::new(threads);
            let mut out = vec![0.0f64; 103];
            pool.for_each_chunk(&mut out, |start, chunk| {
                for (j, o) in chunk.iter_mut().enumerate() {
                    *o += (start + j) as f64 + 1.0;
                }
            });
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i as f64 + 1.0, "element {i} at k={threads}");
            }
        }
    }

    #[test]
    fn results_are_identical_across_thread_counts() {
        let compute = |threads: usize| -> Vec<f64> {
            let mut out = vec![0.0f64; 67];
            Pool::new(threads).for_each_chunk(&mut out, |start, chunk| {
                for (j, o) in chunk.iter_mut().enumerate() {
                    let i = (start + j) as f64;
                    *o = (i * 0.1).sin() * (i + 0.3).sqrt();
                }
            });
            out
        };
        let serial = compute(1);
        for k in [2usize, 3, 8, 100] {
            assert_eq!(serial, compute(k), "k={k} must be bit-identical");
        }
    }

    #[test]
    fn empty_and_tiny_slices_work() {
        let pool = Pool::new(8);
        let mut empty: Vec<f64> = vec![];
        pool.for_each_chunk(&mut empty, |_, _| panic!("no chunks for empty output"));
        let mut one = vec![0.0f64];
        pool.for_each_chunk(&mut one, |start, chunk| {
            assert_eq!(start, 0);
            chunk[0] = 7.0;
        });
        assert_eq!(one, vec![7.0]);
    }

    #[test]
    fn foreign_cpu_accumulates_and_drains() {
        let _ = take_foreign_cpu(); // clean slate
        let pool = Pool::new(4);
        let mut out = vec![0.0f64; 4_000];
        pool.for_each_chunk(&mut out, |start, chunk| {
            for (j, o) in chunk.iter_mut().enumerate() {
                let mut acc = 0.0f64;
                for i in 0..2_000 {
                    acc += ((start + j + i) as f64).sqrt();
                }
                *o = acc;
            }
        });
        let foreign = take_foreign_cpu();
        assert!(foreign >= 0.0);
        assert_eq!(take_foreign_cpu(), 0.0, "drain must reset the accumulator");
    }

    #[test]
    fn serial_pool_never_credits_foreign_cpu() {
        let _ = take_foreign_cpu();
        let mut out = vec![0.0f64; 1_000];
        Pool::serial().for_each_chunk(&mut out, |start, chunk| {
            for (j, o) in chunk.iter_mut().enumerate() {
                *o = (start + j) as f64;
            }
        });
        assert_eq!(take_foreign_cpu(), 0.0, "inline execution is the caller's own CPU");
    }
}
