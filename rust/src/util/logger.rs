//! Minimal leveled logger (no `log`/`env_logger` runtime wiring needed).
//!
//! Level is taken from `FDSVRG_LOG` (`error|warn|info|debug|trace`,
//! default `info`). Output goes to stderr so result tables on stdout stay
//! machine-readable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: OnceLock<()> = OnceLock::new();

pub fn init() {
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("FDSVRG_LOG") {
            let lvl = match v.to_ascii_lowercase().as_str() {
                "error" => Level::Error,
                "warn" => Level::Warn,
                "info" => Level::Info,
                "debug" => Level::Debug,
                "trace" => Level::Trace,
                _ => Level::Info,
            };
            LEVEL.store(lvl as u8, Ordering::Relaxed);
        }
    });
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

#[inline]
pub fn enabled(l: Level) -> bool {
    init();
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{:5}] {}", format!("{l:?}").to_ascii_uppercase(), args);
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
