//! Clocks.
//!
//! The cluster simulator charges each node for *its own* compute using the
//! per-thread CPU clock (`CLOCK_THREAD_CPUTIME_ID`), not wall time: all
//! simulated nodes share one physical machine, so wall time would include
//! scheduler contention from the *other* nodes and corrupt the simulated
//! schedule. Thread CPU time is what this node would have spent had it run
//! alone, which is exactly the quantity the simulated cluster clock needs.

use std::time::Instant;

/// Seconds of CPU time consumed by the calling thread.
pub fn thread_cpu_now() -> f64 {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: plain syscall writing into a stack-allocated timespec.
    let rc = unsafe { libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    assert_eq!(rc, 0, "clock_gettime(CLOCK_THREAD_CPUTIME_ID) failed");
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// Incremental thread-CPU-time meter: `lap()` returns seconds since the
/// previous lap (or construction) on this thread's CPU clock.
pub struct ThreadCpuTimer {
    last: f64,
}

impl ThreadCpuTimer {
    pub fn start() -> Self {
        ThreadCpuTimer { last: thread_cpu_now() }
    }

    /// Seconds of thread CPU time since the last lap; resets the mark.
    pub fn lap(&mut self) -> f64 {
        let now = thread_cpu_now();
        let dt = (now - self.last).max(0.0);
        self.last = now;
        dt
    }
}

/// Wall-clock stopwatch (for end-to-end timings reported next to the
/// simulated clock).
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { t0: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_cpu_monotone() {
        let a = thread_cpu_now();
        // burn a little CPU
        let mut acc = 0u64;
        for i in 0..200_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        let b = thread_cpu_now();
        assert!(b >= a);
    }

    #[test]
    fn timer_laps_positive_under_work() {
        let mut t = ThreadCpuTimer::start();
        let mut acc = 0f64;
        for i in 0..500_000 {
            acc += (i as f64).sqrt();
        }
        std::hint::black_box(acc);
        assert!(t.lap() >= 0.0);
        // second lap with no work should be ~0 (allow scheduling noise)
        assert!(t.lap() < 0.05);
    }

    #[test]
    fn cpu_time_excludes_sleep() {
        let mut t = ThreadCpuTimer::start();
        std::thread::sleep(std::time::Duration::from_millis(30));
        let dt = t.lap();
        assert!(dt < 0.02, "sleep leaked into thread CPU time: {dt}");
    }
}
