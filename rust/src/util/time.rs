//! Clocks.
//!
//! The cluster simulator charges each node for *its own* compute using the
//! per-thread CPU clock (`CLOCK_THREAD_CPUTIME_ID`), not wall time: all
//! simulated nodes share one physical machine, so wall time would include
//! scheduler contention from the *other* nodes and corrupt the simulated
//! schedule. Thread CPU time is what this node would have spent had it run
//! alone, which is exactly the quantity the simulated cluster clock needs.

use std::time::Instant;

/// Minimal `clock_gettime` FFI — the crate keeps `anyhow` as its only
/// dependency, so the `libc` crate is not available; `clock_gettime`
/// itself is in the C library these targets already link. Scoped to the
/// platforms whose clock id and `timespec` layout we actually know
/// (64-bit Linux and macOS); everything else takes the wall-clock
/// fallback below rather than guessing ABI constants.
#[cfg(all(
    any(target_os = "linux", target_os = "macos"),
    target_pointer_width = "64"
))]
mod sys {
    // 64-bit linux-gnu/musl and macOS: { time_t: i64, long: i64 }
    #[repr(C)]
    pub struct Timespec {
        pub tv_sec: i64,
        pub tv_nsec: i64,
    }

    #[cfg(target_os = "linux")]
    pub const CLOCK_THREAD_CPUTIME_ID: i32 = 3;
    #[cfg(target_os = "macos")]
    pub const CLOCK_THREAD_CPUTIME_ID: i32 = 16;

    extern "C" {
        pub fn clock_gettime(clock_id: i32, tp: *mut Timespec) -> i32;
    }
}

/// Seconds of CPU time consumed by the calling thread.
#[cfg(all(
    any(target_os = "linux", target_os = "macos"),
    target_pointer_width = "64"
))]
pub fn thread_cpu_now() -> f64 {
    let mut ts = sys::Timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: plain syscall writing into a stack-allocated timespec.
    let rc = unsafe { sys::clock_gettime(sys::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    assert_eq!(rc, 0, "clock_gettime(CLOCK_THREAD_CPUTIME_ID) failed");
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// Fallback for targets without the FFI above: a process-wide wall
/// clock. The simulated schedule loses its contention immunity there,
/// but the build stays portable.
#[cfg(not(all(
    any(target_os = "linux", target_os = "macos"),
    target_pointer_width = "64"
)))]
pub fn thread_cpu_now() -> f64 {
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Incremental thread-CPU-time meter: `lap()` returns seconds since the
/// previous lap (or construction) on this thread's CPU clock.
pub struct ThreadCpuTimer {
    last: f64,
}

impl ThreadCpuTimer {
    pub fn start() -> Self {
        ThreadCpuTimer { last: thread_cpu_now() }
    }

    /// Seconds of thread CPU time since the last lap; resets the mark.
    pub fn lap(&mut self) -> f64 {
        let now = thread_cpu_now();
        let dt = (now - self.last).max(0.0);
        self.last = now;
        dt
    }
}

/// Wall-clock stopwatch (for end-to-end timings reported next to the
/// simulated clock).
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { t0: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_cpu_monotone() {
        let a = thread_cpu_now();
        // burn a little CPU
        let mut acc = 0u64;
        for i in 0..200_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        let b = thread_cpu_now();
        assert!(b >= a);
    }

    #[test]
    fn timer_laps_positive_under_work() {
        let mut t = ThreadCpuTimer::start();
        let mut acc = 0f64;
        for i in 0..500_000 {
            acc += (i as f64).sqrt();
        }
        std::hint::black_box(acc);
        assert!(t.lap() >= 0.0);
        // second lap with no work should be ~0 (allow scheduling noise)
        assert!(t.lap() < 0.05);
    }

    // only meaningful where the thread-CPU FFI (not the wall-clock
    // fallback) is compiled in
    #[cfg(all(
        any(target_os = "linux", target_os = "macos"),
        target_pointer_width = "64"
    ))]
    #[test]
    fn cpu_time_excludes_sleep() {
        let mut t = ThreadCpuTimer::start();
        std::thread::sleep(std::time::Duration::from_millis(30));
        let dt = t.lap();
        assert!(dt < 0.02, "sleep leaked into thread CPU time: {dt}");
    }
}
