//! Small shared utilities: PRNG, thread CPU-time clocks, logging, and the
//! deterministic compute pool ([`pool`]).

pub mod logger;
pub mod pool;
pub mod rng;
pub mod time;

pub use pool::Pool;
pub use rng::Pcg64;
pub use time::ThreadCpuTimer;

/// Case-insensitive enum-name lookup shared by every CLI/config parser
/// (`Algorithm`, `WireFmt`, `EngineKind`, `TransportKind`, the `--net`
/// scenario names): trims the input, lowercases it, folds `_` to `-`,
/// then matches it against `table` (whose keys must be lowercase).
pub fn parse_enum<T: Clone>(s: &str, table: &[(&str, T)]) -> Option<T> {
    let key = s.trim().to_ascii_lowercase().replace('_', "-");
    table.iter().find(|(name, _)| *name == key).map(|(_, v)| v.clone())
}

/// [`parse_enum`] with the uniform CLI error shape:
/// `unknown {what} {input:?}; valid {note}: a, b, c`.
pub fn parse_enum_or_err<T: Clone>(
    s: &str,
    what: &str,
    note: &str,
    names: &[&str],
    table: &[(&str, T)],
) -> Result<T, String> {
    parse_enum(s, table)
        .ok_or_else(|| format!("unknown {what} {s:?}; valid {note}: {}", names.join(", ")))
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation of a slice.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (of a copy; input untouched).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn mean_basic() {
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn parse_enum_is_case_and_underscore_tolerant() {
        let table = [("fd-svrg", 1u8), ("sim", 2u8)];
        assert_eq!(parse_enum(" FD_SVRG ", &table), Some(1));
        assert_eq!(parse_enum("fd-svrg", &table), Some(1));
        assert_eq!(parse_enum("Sim", &table), Some(2));
        assert_eq!(parse_enum("bogus", &table), None);
    }

    #[test]
    fn parse_enum_or_err_lists_valid_values() {
        let table = [("sim", 0u8), ("tcp", 1u8)];
        let err = parse_enum_or_err(
            "udp",
            "transport",
            "transports (case-insensitive)",
            &["sim", "tcp"],
            &table,
        )
        .unwrap_err();
        assert!(err.contains("unknown transport"), "{err}");
        assert!(err.contains("\"udp\""), "{err}");
        assert!(err.contains("sim, tcp"), "{err}");
    }
}
