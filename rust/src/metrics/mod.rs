//! Convergence traces, counters and result writers.
//!
//! Every algorithm run produces a [`Trace`]: one [`TracePoint`] per outer
//! iteration carrying the axes the paper plots — simulated cluster time
//! (Fig. 6/8/9), communication (Fig. 7; bytes on the wire are the
//! canonical unit, with scalars kept as the derived §4.5 view) and the
//! objective gap. Writers emit CSV that the experiment drivers collect
//! into `results/`.

pub mod json;
pub mod plot;

use crate::net::{CommStats, NodeComm};
use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;

/// One sampled point of a run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TracePoint {
    /// Outer-loop (epoch) index, 0 = before the first epoch.
    pub outer: usize,
    /// Simulated cluster time, seconds.
    pub sim_time: f64,
    /// Per-node clock skew at this epoch boundary (max − min simulated
    /// node time, seconds; 0 for single-node runs) — what makes straggler
    /// and heterogeneous-network runs measurable.
    pub skew: f64,
    /// Real wall-clock of the host process, seconds (reported alongside;
    /// contention-polluted, not used for figures).
    pub wall_time: f64,
    /// Total scalars communicated so far (all links) — the derived §4.5
    /// view of `bytes`.
    pub scalars: u64,
    /// Total wire bytes communicated so far (all links), the canonical
    /// communication unit.
    pub bytes: u64,
    /// Stochastic gradient evaluations so far (N per full-gradient pass +
    /// 1 per inner step), the paper's §4.5 normalization.
    pub grads: u64,
    /// Objective value f(w).
    pub objective: f64,
}

/// A full run trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub points: Vec<TracePoint>,
}

impl Trace {
    pub fn push(&mut self, p: TracePoint) {
        self.points.push(p);
    }

    pub fn last_objective(&self) -> Option<f64> {
        self.points.last().map(|p| p.objective)
    }

    /// First simulated time at which the gap `f(w) − f_opt` drops below
    /// `target` (linear interpolation between trace points, like reading a
    /// convergence plot). `None` if never reached.
    pub fn time_to_gap(&self, f_opt: f64, target: f64) -> Option<f64> {
        self.crossing(f_opt, target).map(|(_, t)| t)
    }

    /// Scalars communicated when the gap first drops below `target`.
    pub fn comm_to_gap(&self, f_opt: f64, target: f64) -> Option<u64> {
        self.crossing(f_opt, target).map(|(i, _)| self.points[i].scalars)
    }

    /// Wire bytes communicated when the gap first drops below `target`.
    pub fn bytes_to_gap(&self, f_opt: f64, target: f64) -> Option<u64> {
        self.crossing(f_opt, target).map(|(i, _)| self.points[i].bytes)
    }

    fn crossing(&self, f_opt: f64, target: f64) -> Option<(usize, f64)> {
        for (i, p) in self.points.iter().enumerate() {
            let gap = p.objective - f_opt;
            if gap <= target {
                if i == 0 {
                    return Some((0, p.sim_time));
                }
                let prev = &self.points[i - 1];
                let g0 = prev.objective - f_opt;
                let g1 = gap;
                // log-linear interpolation on the gap (convergence is
                // roughly geometric, so interpolate in log space)
                let frac = if g0 > 0.0 && g1 > 0.0 && g0 != g1 {
                    ((g0.ln() - target.max(1e-300).ln()) / (g0.ln() - g1.ln())).clamp(0.0, 1.0)
                } else {
                    1.0
                };
                return Some((i, prev.sim_time + frac * (p.sim_time - prev.sim_time)));
            }
        }
        None
    }

    /// Write `outer,sim_time,skew,wall_time,scalars,bytes,grads,objective,gap`
    /// CSV (`skew` = per-node clock skew at the epoch boundary).
    pub fn write_csv<P: AsRef<Path>>(&self, path: P, f_opt: f64) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        writeln!(f, "outer,sim_time,skew,wall_time,scalars,bytes,grads,objective,gap")?;
        for p in &self.points {
            writeln!(
                f,
                "{},{:.6},{:.6},{:.6},{},{},{},{:.12},{:.6e}",
                p.outer,
                p.sim_time,
                p.skew,
                p.wall_time,
                p.scalars,
                p.bytes,
                p.grads,
                p.objective,
                p.objective - f_opt
            )?;
        }
        Ok(())
    }
}

/// Final communication accounting of a run, as a value (the session layer
/// assembles it from live [`CommStats`], a resume snapshot, or a closed
/// form, depending on the driver).
#[derive(Clone, Debug, Default)]
pub struct CommTotals {
    pub total_scalars: u64,
    pub busiest_node_scalars: u64,
    pub total_bytes: u64,
    pub busiest_node_bytes: u64,
    pub total_messages: u64,
    /// Real bytes written to sockets for counted traffic, framing
    /// included (`--transport tcp`; 0 on the in-memory sim transport) —
    /// the measurement `exp calibrate` holds against `total_bytes`.
    pub total_socket_bytes: u64,
    pub node_comm: Vec<NodeComm>,
}

impl CommTotals {
    /// Totals derived from a per-sender snapshot (resume path; the
    /// snapshot predates the tcp transport, so socket bytes read 0).
    pub fn from_node_comm(node_comm: Vec<NodeComm>) -> CommTotals {
        CommTotals {
            total_scalars: node_comm.iter().map(|n| n.scalars).sum(),
            busiest_node_scalars: node_comm.iter().map(|n| n.scalars).max().unwrap_or(0),
            total_bytes: node_comm.iter().map(|n| n.bytes).sum(),
            busiest_node_bytes: node_comm.iter().map(|n| n.bytes).max().unwrap_or(0),
            total_messages: node_comm.iter().map(|n| n.messages).sum(),
            total_socket_bytes: 0,
            node_comm,
        }
    }

    /// Live totals of a finished cluster run.
    pub fn from_stats(stats: &CommStats) -> CommTotals {
        CommTotals {
            total_scalars: stats.total_scalars(),
            busiest_node_scalars: stats.busiest_node_scalars(),
            total_bytes: stats.total_bytes(),
            busiest_node_bytes: stats.busiest_node_bytes(),
            total_messages: stats.total_messages(),
            total_socket_bytes: stats.total_socket_bytes(),
            node_comm: stats.per_node(),
        }
    }
}

/// Result of a complete algorithm run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub algorithm: String,
    pub dataset: String,
    pub w: Vec<f64>,
    pub trace: Trace,
    pub total_sim_time: f64,
    /// Final per-node clock skew (max − min simulated node time at the
    /// last epoch boundary; 0 for single-node runs).
    pub clock_skew: f64,
    pub total_wall_time: f64,
    /// Derived scalar view of the traffic (§4.5 pins: under the `f64`
    /// wire format `total_bytes == 8 * total_scalars`).
    pub total_scalars: u64,
    pub busiest_node_scalars: u64,
    /// Canonical wire accounting: bytes and messages, totalled and for
    /// the busiest single sender.
    pub total_bytes: u64,
    pub busiest_node_bytes: u64,
    pub total_messages: u64,
    /// Real socket bytes for counted traffic, framing included
    /// (`--transport tcp`; 0 under the sim transport).
    pub total_socket_bytes: u64,
    /// Per-sender counters (scalars, bytes, messages), indexed by node id.
    pub node_comm: Vec<NodeComm>,
}

impl RunResult {
    /// Assemble a result from the session layer's pieces: the trace it
    /// accumulated plus the driver's final weights and comm totals.
    pub fn from_totals(
        algorithm: &str,
        dataset: &str,
        w: Vec<f64>,
        trace: Trace,
        total_sim_time: f64,
        total_wall_time: f64,
        totals: CommTotals,
    ) -> RunResult {
        let clock_skew = trace.points.last().map(|p| p.skew).unwrap_or(0.0);
        RunResult {
            algorithm: algorithm.into(),
            dataset: dataset.into(),
            w,
            trace,
            total_sim_time,
            clock_skew,
            total_wall_time,
            total_scalars: totals.total_scalars,
            busiest_node_scalars: totals.busiest_node_scalars,
            total_bytes: totals.total_bytes,
            busiest_node_bytes: totals.busiest_node_bytes,
            total_messages: totals.total_messages,
            total_socket_bytes: totals.total_socket_bytes,
            node_comm: totals.node_comm,
        }
    }

    pub fn final_objective(&self) -> f64 {
        self.trace.last_objective().unwrap_or(f64::NAN)
    }
}

/// Simple aligned-text table writer for the CLI/bench reports.
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        TextTable { header: header.into_iter().map(|s| s.into()).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(|s| s.into()).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for c in 0..ncol {
            width[c] = self.header[c].len();
            for r in &self.rows {
                width[c] = width[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$}  ", cell, w = width[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace_with_gaps(gaps: &[f64]) -> Trace {
        let mut t = Trace::default();
        for (i, &g) in gaps.iter().enumerate() {
            t.push(TracePoint {
                outer: i,
                sim_time: i as f64,
                skew: 0.25 * i as f64,
                wall_time: i as f64 * 2.0,
                scalars: (i as u64) * 100,
                bytes: (i as u64) * 800,
                grads: (i as u64) * 10,
                objective: 1.0 + g, // f_opt = 1.0
            });
        }
        t
    }

    #[test]
    fn time_to_gap_interpolates() {
        let t = trace_with_gaps(&[1.0, 0.1, 0.001]);
        let hit = t.time_to_gap(1.0, 0.01).unwrap();
        assert!(hit > 1.0 && hit <= 2.0, "{hit}");
        // exact hit at a point
        let hit = t.time_to_gap(1.0, 0.1).unwrap();
        assert!((hit - 1.0).abs() < 1e-9);
    }

    #[test]
    fn time_to_gap_unreached() {
        let t = trace_with_gaps(&[1.0, 0.5]);
        assert!(t.time_to_gap(1.0, 1e-4).is_none());
    }

    #[test]
    fn comm_to_gap_reads_scalars() {
        let t = trace_with_gaps(&[1.0, 0.1, 0.001]);
        assert_eq!(t.comm_to_gap(1.0, 0.01), Some(200));
        assert_eq!(t.bytes_to_gap(1.0, 0.01), Some(1600));
    }

    #[test]
    fn csv_round_shape() {
        let t = trace_with_gaps(&[1.0, 0.1]);
        let dir = std::env::temp_dir().join("fdsvrg_test_csv");
        let path = dir.join("t.csv");
        t.write_csv(&path, 1.0).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("outer,"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn csv_includes_clock_skew_column() {
        let t = trace_with_gaps(&[1.0, 0.1]);
        let dir = std::env::temp_dir().join("fdsvrg_test_csv_skew");
        let path = dir.join("t.csv");
        t.write_csv(&path, 1.0).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert!(lines[0].contains(",skew,"), "header must name the skew column: {}", lines[0]);
        assert!(lines[2].contains(",0.250000,"), "point 1 skew must serialize: {}", lines[2]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn run_result_clock_skew_reads_the_last_trace_point() {
        let t = trace_with_gaps(&[1.0, 0.1, 0.01]);
        let r = RunResult::from_totals("a", "d", vec![], t, 2.0, 2.0, CommTotals::default());
        assert_eq!(r.clock_skew, 0.5);
    }

    #[test]
    fn text_table_alignment() {
        let mut t = TextTable::new(vec!["a", "long_header"]);
        t.row(vec!["xxxxx", "1"]);
        let s = t.render();
        assert!(s.contains("a      long_header"));
        assert!(s.lines().count() == 3);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = TextTable::new(vec!["a"]);
        t.row(vec!["1", "2"]);
    }
}
