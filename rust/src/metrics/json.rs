//! Minimal JSON writer for run results (`serde` is unavailable offline).
//!
//! Emits one self-describing document per run — enough for downstream
//! notebooks to ingest `results/*.json` without parsing our CSV dialect.

use super::RunResult;
use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;

/// Escape a string for a JSON literal (shared with [`crate::bench`]'s
/// baseline writer).
pub(crate) fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON-safe float (JSON has no NaN/Inf).
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Serialize a [`RunResult`] (trace included) as JSON.
pub fn run_result_to_json(res: &RunResult, f_opt: Option<f64>) -> String {
    let mut s = String::with_capacity(4096);
    s.push_str("{\n");
    s.push_str(&format!("  \"algorithm\": \"{}\",\n", esc(&res.algorithm)));
    s.push_str(&format!("  \"dataset\": \"{}\",\n", esc(&res.dataset)));
    s.push_str(&format!("  \"total_sim_time\": {},\n", num(res.total_sim_time)));
    s.push_str(&format!("  \"total_wall_time\": {},\n", num(res.total_wall_time)));
    s.push_str(&format!("  \"total_scalars\": {},\n", res.total_scalars));
    s.push_str(&format!(
        "  \"busiest_node_scalars\": {},\n",
        res.busiest_node_scalars
    ));
    s.push_str(&format!("  \"total_bytes\": {},\n", res.total_bytes));
    s.push_str(&format!("  \"busiest_node_bytes\": {},\n", res.busiest_node_bytes));
    s.push_str(&format!("  \"total_messages\": {},\n", res.total_messages));
    s.push_str(&format!("  \"total_socket_bytes\": {},\n", res.total_socket_bytes));
    s.push_str(&format!("  \"clock_skew\": {},\n", num(res.clock_skew)));
    s.push_str(&format!(
        "  \"f_opt\": {},\n",
        f_opt.map(num).unwrap_or_else(|| "null".into())
    ));
    s.push_str(&format!("  \"dim\": {},\n", res.w.len()));
    s.push_str("  \"trace\": [\n");
    for (i, p) in res.trace.points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"outer\": {}, \"sim_time\": {}, \"skew\": {}, \"wall_time\": {}, \
             \"scalars\": {}, \"bytes\": {}, \"grads\": {}, \"objective\": {}{}}}{}\n",
            p.outer,
            num(p.sim_time),
            num(p.skew),
            num(p.wall_time),
            p.scalars,
            p.bytes,
            p.grads,
            num(p.objective),
            f_opt
                .map(|f| format!(", \"gap\": {}", num(p.objective - f)))
                .unwrap_or_default(),
            if i + 1 == res.trace.points.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Write a run result as `<dir>/<tag>.json`.
pub fn write_json<P: AsRef<Path>>(res: &RunResult, f_opt: Option<f64>, path: P) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    f.write_all(run_result_to_json(res, f_opt).as_bytes())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{Trace, TracePoint};

    fn demo() -> RunResult {
        let mut trace = Trace::default();
        trace.push(TracePoint {
            outer: 0,
            sim_time: 0.0,
            skew: 0.0,
            wall_time: 0.0,
            scalars: 0,
            bytes: 0,
            grads: 0,
            objective: 0.7,
        });
        trace.push(TracePoint {
            outer: 1,
            sim_time: 0.5,
            skew: 0.25,
            wall_time: 1.0,
            scalars: 640,
            bytes: 5120,
            grads: 80,
            objective: 0.3,
        });
        RunResult {
            algorithm: "fdsvrg".into(),
            dataset: "tiny \"quoted\"".into(),
            w: vec![0.0; 4],
            trace,
            total_sim_time: 0.5,
            clock_skew: 0.25,
            total_wall_time: 1.0,
            total_scalars: 640,
            busiest_node_scalars: 160,
            total_bytes: 5120,
            busiest_node_bytes: 1280,
            total_messages: 32,
            total_socket_bytes: 0,
            node_comm: Vec::new(),
        }
    }

    #[test]
    fn json_shape_and_escaping() {
        let j = run_result_to_json(&demo(), Some(0.25));
        assert!(j.contains("\"algorithm\": \"fdsvrg\""));
        assert!(j.contains("tiny \\\"quoted\\\""));
        assert!(j.contains("\"gap\": 0.04999999999999999") || j.contains("\"gap\": 0.05"));
        assert!(j.contains("\"total_bytes\": 5120"));
        assert!(j.contains("\"busiest_node_bytes\": 1280"));
        assert!(j.contains("\"total_messages\": 32"));
        assert!(j.contains("\"total_socket_bytes\": 0"));
        assert!(j.contains("\"clock_skew\": 0.25"));
        assert!(j.contains("\"skew\": 0.25"));
        assert!(j.contains("\"bytes\": 5120"));
        // structurally: balanced braces/brackets
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    /// Golden-file round trip: the serialized document for a fixed run
    /// must match `rust/tests/golden/run_result.golden.json` byte for
    /// byte. Regenerate the file from this fixture when the schema
    /// deliberately changes.
    #[test]
    fn golden_file_round_trip() {
        fn golden() -> RunResult {
            let mut trace = Trace::default();
            trace.push(TracePoint {
                outer: 0,
                sim_time: 0.0,
                skew: 0.0,
                wall_time: 0.0,
                scalars: 0,
                bytes: 0,
                grads: 0,
                objective: 0.75,
            });
            trace.push(TracePoint {
                outer: 1,
                sim_time: 0.5,
                skew: 0.125,
                wall_time: 1.0,
                scalars: 640,
                bytes: 5120,
                grads: 80,
                objective: 0.5,
            });
            RunResult {
                algorithm: "fdsvrg".into(),
                dataset: "golden-sim".into(),
                w: vec![0.0; 4],
                trace,
                total_sim_time: 0.5,
                clock_skew: 0.125,
                total_wall_time: 1.0,
                total_scalars: 640,
                busiest_node_scalars: 160,
                total_bytes: 5120,
                busiest_node_bytes: 1280,
                total_messages: 32,
                total_socket_bytes: 0,
                node_comm: Vec::new(),
            }
        }
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("rust/tests/golden/run_result.golden.json");
        let want = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read golden file {}: {e}", path.display()));
        let got = run_result_to_json(&golden(), Some(0.25));
        assert_eq!(
            got, want,
            "RunResult JSON drifted from the golden file; if the schema change \
             is intentional, regenerate {} from this fixture",
            path.display()
        );
    }

    #[test]
    fn json_without_fopt_has_no_gap() {
        let j = run_result_to_json(&demo(), None);
        assert!(j.contains("\"f_opt\": null"));
        assert!(!j.contains("\"gap\""));
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(num(f64::NAN), "null");
        assert_eq!(num(f64::INFINITY), "null");
    }

    #[test]
    fn write_json_creates_file() {
        let dir = std::env::temp_dir().join("fdsvrg_json_test");
        let path = dir.join("run.json");
        write_json(&demo(), Some(0.2), &path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with('{'));
        std::fs::remove_dir_all(dir).ok();
    }
}
