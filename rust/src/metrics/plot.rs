//! ASCII convergence plots — terminal renderings of the paper's figures.
//!
//! The experiment drivers print one [`AsciiPlot`] per figure panel so the
//! "who wins, by what factor, where curves cross" shape is visible
//! directly in CI logs and EXPERIMENTS.md without a plotting stack. The
//! y axis is the objective gap on a log₁₀ scale (as in the paper's
//! Figures 6–8); the x axis is time or communicated scalars.

use super::Trace;

/// One labelled series: (x, gap) points, gap > 0.
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    /// Build a time-axis series from a trace (Fig. 6/8/9 style).
    pub fn gap_vs_time(label: &str, trace: &Trace, f_opt: f64) -> Series {
        Series {
            label: label.to_string(),
            points: trace
                .points
                .iter()
                .filter(|p| p.objective - f_opt > 0.0)
                .map(|p| (p.sim_time, p.objective - f_opt))
                .collect(),
        }
    }

    /// Build a communication-axis series (Fig. 7 style). The comm axis is
    /// labelled in wire **bytes** — the canonical unit, which keeps the
    /// wire-format ablations comparable (the scalar view stays available
    /// in the CSV/JSON outputs).
    pub fn gap_vs_comm(label: &str, trace: &Trace, f_opt: f64) -> Series {
        Series {
            label: label.to_string(),
            points: trace
                .points
                .iter()
                .filter(|p| p.objective - f_opt > 0.0)
                .map(|p| (p.bytes as f64, p.objective - f_opt))
                .collect(),
        }
    }
}

/// Log-y scatter plot rendered with one glyph per series.
pub struct AsciiPlot {
    pub title: String,
    pub x_label: String,
    pub width: usize,
    pub height: usize,
    series: Vec<Series>,
}

const GLYPHS: [char; 6] = ['*', 'o', '+', 'x', '#', '@'];

impl AsciiPlot {
    pub fn new(title: &str, x_label: &str) -> AsciiPlot {
        AsciiPlot {
            title: title.to_string(),
            x_label: x_label.to_string(),
            width: 72,
            height: 20,
            series: Vec::new(),
        }
    }

    pub fn add(&mut self, s: Series) {
        if !s.points.is_empty() {
            self.series.push(s);
        }
    }

    /// Render the plot. Returns an empty string when no series has points.
    pub fn render(&self) -> String {
        if self.series.is_empty() {
            return String::new();
        }
        let (mut x_min, mut x_max) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut g_min, mut g_max) = (f64::INFINITY, f64::NEG_INFINITY);
        for s in &self.series {
            for &(x, g) in &s.points {
                x_min = x_min.min(x);
                x_max = x_max.max(x);
                g_min = g_min.min(g);
                g_max = g_max.max(g);
            }
        }
        if !(x_max > x_min) {
            x_max = x_min + 1.0;
        }
        let (ly_min, mut ly_max) = (g_min.log10(), g_max.log10());
        if !(ly_max > ly_min) {
            ly_max = ly_min + 1.0;
        }

        let mut grid = vec![vec![' '; self.width]; self.height];
        for (si, s) in self.series.iter().enumerate() {
            let glyph = GLYPHS[si % GLYPHS.len()];
            for &(x, g) in &s.points {
                let cx = ((x - x_min) / (x_max - x_min) * (self.width - 1) as f64).round()
                    as usize;
                let cy = ((ly_max - g.log10()) / (ly_max - ly_min)
                    * (self.height - 1) as f64)
                    .round() as usize;
                grid[cy.min(self.height - 1)][cx.min(self.width - 1)] = glyph;
            }
        }

        let mut out = String::new();
        out.push_str(&format!("  {}\n", self.title));
        for (r, row) in grid.iter().enumerate() {
            let ly = ly_max - (ly_max - ly_min) * r as f64 / (self.height - 1) as f64;
            let label = if r % 4 == 0 { format!("1e{ly:+.0}") } else { String::new() };
            out.push_str(&format!("{label:>7} |{}|\n", row.iter().collect::<String>()));
        }
        out.push_str(&format!(
            "{:>7}  {:<w$.3}{:>12.3}\n",
            "",
            x_min,
            x_max,
            w = self.width - 10
        ));
        out.push_str(&format!("{:>9}gap vs {}   ", "", self.x_label));
        for (si, s) in self.series.iter().enumerate() {
            out.push_str(&format!("[{}] {}  ", GLYPHS[si % GLYPHS.len()], s.label));
        }
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::TracePoint;

    fn demo_trace(rate: f64) -> Trace {
        let mut t = Trace::default();
        for i in 0..10 {
            t.push(TracePoint {
                outer: i,
                sim_time: i as f64,
                skew: 0.0,
                wall_time: i as f64,
                scalars: 100 * i as u64,
                bytes: 800 * i as u64,
                grads: 10 * i as u64,
                objective: 1.0 + rate.powi(i as i32),
            });
        }
        t
    }

    #[test]
    fn plot_renders_all_series() {
        let mut plot = AsciiPlot::new("demo", "time (s)");
        plot.add(Series::gap_vs_time("fast", &demo_trace(0.3), 1.0));
        plot.add(Series::gap_vs_time("slow", &demo_trace(0.8), 1.0));
        let s = plot.render();
        assert!(s.contains("demo"));
        assert!(s.contains("[*] fast"));
        assert!(s.contains("[o] slow"));
        assert!(s.contains('*'), "{s}");
        assert!(s.lines().count() > 20);
    }

    #[test]
    fn empty_plot_renders_empty() {
        let plot = AsciiPlot::new("empty", "x");
        assert!(plot.render().is_empty());
    }

    #[test]
    fn comm_axis_uses_wire_bytes() {
        let s = Series::gap_vs_comm("c", &demo_trace(0.5), 1.0);
        assert_eq!(s.points[1].0, 800.0);
    }

    #[test]
    fn zero_gap_points_are_dropped() {
        // the final point may hit f_opt exactly; log scale must not panic
        let mut t = demo_trace(0.5);
        let last = t.points.last_mut().unwrap();
        last.objective = 1.0;
        let s = Series::gap_vs_time("z", &t, 1.0);
        assert_eq!(s.points.len(), 9);
        let mut plot = AsciiPlot::new("t", "x");
        plot.add(s);
        assert!(!plot.render().is_empty());
    }

    #[test]
    fn single_point_series_renders() {
        let mut t = Trace::default();
        t.push(TracePoint {
            outer: 0,
            sim_time: 0.0,
            skew: 0.0,
            wall_time: 0.0,
            scalars: 0,
            bytes: 0,
            grads: 0,
            objective: 2.0,
        });
        let mut plot = AsciiPlot::new("one", "x");
        plot.add(Series::gap_vs_time("p", &t, 1.0));
        assert!(!plot.render().is_empty());
    }
}
