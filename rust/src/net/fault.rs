//! Fault plane: seeded, deterministic failure injection for the cluster
//! simulator.
//!
//! A [`FaultPlan`] is parsed from `--faults` / `run.faults` and composes
//! with any [`crate::net::NetModel`]: it never touches payloads, counters
//! or the algorithm RNG streams — faults reshape **time** (and, for
//! crashes, *which work has to be redone*), so the numerics stay exactly
//! the failure-free numerics. The plan is resolved once per run and every
//! decision is a pure function of `(fault seed, sender id, per-sender send
//! index)` — never of host scheduling — which makes fault runs bit-stable
//! across reruns and across `--threads K` (pinned by
//! `rust/tests/fault_recovery.rs`).
//!
//! Spec grammar (comma-separated clauses):
//!
//! * `drop:<p>` — with probability `p` per counted message, the first
//!   copy is lost on the wire. The sender already paid the NIC for it
//!   (accounting runs before the transport seam), waits out a
//!   retransmission timeout of two wire latencies, then pays the NIC
//!   again for the copy that arrives. Delivery is therefore delayed,
//!   never lost — the reliable-link model every algorithm here assumes.
//! * `dup:<p>` — with probability `p`, a duplicate frame occupies the
//!   sender's NIC a second time; the receiver's reliable layer discards
//!   it, so only the sender's outgoing horizon moves.
//! * `reorder:<p>` — with probability `p`, the message takes a slow path
//!   and arrives one extra wire latency late, letting a later-sent
//!   message overtake it; the endpoints' selective-receive stash absorbs
//!   the logical reordering.
//! * `crash:<node>@<t>` — node `<node>` (a worker; node 0 is the
//!   monitor) goes dark the first time its simulated clock reaches `t`
//!   seconds: its thread unwinds, its endpoint drops, and every peer
//!   observes `Gone`. Fires once; the session layer's recovery protocol
//!   (see [`crate::session::cluster::ClusterDriver`]) respawns the
//!   cluster from the last snapshot.
//! * `partition:<a>+<b>+…@<t1>-<t2>` — between sim-times `t1` and `t2`
//!   the listed nodes are cut off from the rest; messages crossing the
//!   cut are buffered and delivered when the partition heals at `t2`
//!   (TCP riding out a short partition), charged as extra wire latency.
//! * `seed:<u64>` — override the fault-plane seed (defaults to the run
//!   seed, salted).
//!
//! **The empty plan is an identity.** With no plan installed (or a plan
//! whose probabilities are all zero and whose schedules are empty) no
//! stream is consumed and no charge is made — all pinned equivalence /
//! resume / comm-accounting suites run bit-exact with the fault plane
//! compiled in.

use super::NodeId;
use crate::util::Pcg64;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Salt folded into the run seed so the fault streams never alias the
/// algorithm sampling streams or the `--net jitter` noise streams.
const FAULT_SEED_SALT: u64 = 0xFA17_0D0D_5EED_0001;

/// One scheduled crash: the node goes dark the first time its simulated
/// clock reaches `at` (fires at most once per run, tracked in
/// [`FaultPlan::fired`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Crash {
    pub node: NodeId,
    pub at: f64,
}

/// One scheduled partition: `group` vs everyone else over `[from, until)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Partition {
    pub group: Vec<NodeId>,
    pub from: f64,
    pub until: f64,
}

/// Counters the injection points bump (and the recovery protocol reads
/// back) — all interior-mutable so one plan can be shared across every
/// node thread.
#[derive(Debug, Default)]
struct FaultCounters {
    drops: AtomicU64,
    dups: AtomicU64,
    reorders: AtomicU64,
    partition_holds: AtomicU64,
    crashes: AtomicU64,
    recoveries: AtomicU64,
    /// Simulated seconds of work rolled back by crash recoveries (crash
    /// time minus the snapshot clock the cluster respawned from).
    lost_sim_time: Mutex<f64>,
}

/// A read-only snapshot of the fault-plane counters after (or during) a
/// run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultStats {
    pub drops: u64,
    pub dups: u64,
    pub reorders: u64,
    pub partition_holds: u64,
    pub crashes: u64,
    pub recoveries: u64,
    pub lost_sim_time: f64,
}

/// The resolved, seeded fault plan for one run. Shared (`Arc`) between
/// the session driver (crash recovery), every endpoint (per-link
/// injection) and the caller (stats readout).
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    drop_p: f64,
    dup_p: f64,
    reorder_p: f64,
    crashes: Vec<Crash>,
    partitions: Vec<Partition>,
    /// Per-crash one-shot latches (same order as `crashes`).
    fired: Vec<AtomicBool>,
    /// Crash awaiting recovery: set by the crashing node, consumed by the
    /// cluster driver's recovery path. Stores the crash's scheduled time
    /// as bits (NaN bits = empty).
    pending: AtomicU64,
    counters: FaultCounters,
    /// Last-k snapshot store the recovery path respawns from (attached by
    /// the launcher when durable snapshots are configured; recovery falls
    /// back to the monitor-resident epoch state otherwise).
    store: Mutex<Option<Arc<crate::checkpoint::CheckpointStore>>>,
    /// Canonical spec string (for logs, JSON reports and `Debug`).
    spec: String,
}

const PENDING_EMPTY: u64 = u64::MAX;

impl FaultPlan {
    /// Parse a `--faults` spec against the run seed. Empty / `none` specs
    /// resolve to `None` — the caller installs nothing and the fault
    /// plane stays a provable identity.
    pub fn parse(spec: &str, run_seed: u64) -> Result<Option<Arc<FaultPlan>>, String> {
        let spec = spec.trim();
        if spec.is_empty() || spec.eq_ignore_ascii_case("none") {
            return Ok(None);
        }
        let mut plan = FaultPlan {
            seed: run_seed ^ FAULT_SEED_SALT,
            drop_p: 0.0,
            dup_p: 0.0,
            reorder_p: 0.0,
            crashes: Vec::new(),
            partitions: Vec::new(),
            fired: Vec::new(),
            pending: AtomicU64::new(PENDING_EMPTY),
            counters: FaultCounters::default(),
            store: Mutex::new(None),
            spec: spec.to_string(),
        };
        for clause in spec.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (kind, rest) = clause.split_once(':').ok_or_else(|| {
                format!(
                    "fault clause {clause:?} needs a value; valid clauses: \
                     drop:<p>, dup:<p>, reorder:<p>, crash:<node>@<t>, \
                     partition:<a>+<b>+..@<t1>-<t2>, seed:<u64>"
                )
            })?;
            match kind.trim().to_ascii_lowercase().as_str() {
                "drop" => plan.drop_p = parse_prob("drop", rest)?,
                "dup" => plan.dup_p = parse_prob("dup", rest)?,
                "reorder" => plan.reorder_p = parse_prob("reorder", rest)?,
                "seed" => {
                    plan.seed = rest
                        .trim()
                        .parse::<u64>()
                        .map_err(|e| format!("fault seed {rest:?}: {e}"))?;
                }
                "crash" => {
                    let (node, at) = rest.split_once('@').ok_or_else(|| {
                        format!("crash spec {rest:?} must be <node>@<sim-time>, e.g. crash:2@1.5")
                    })?;
                    let node: NodeId = node
                        .trim()
                        .parse()
                        .map_err(|e| format!("crash node {node:?}: {e}"))?;
                    if node == 0 {
                        return Err(
                            "crash:0 is invalid: node 0 is the monitor/coordinator; \
                             crash a worker node instead"
                                .to_string(),
                        );
                    }
                    let at: f64 =
                        at.trim().parse().map_err(|e| format!("crash time {at:?}: {e}"))?;
                    if !(at.is_finite() && at >= 0.0) {
                        return Err(format!("crash time {at} must be finite and >= 0"));
                    }
                    plan.crashes.push(Crash { node, at });
                }
                "partition" => {
                    let (nodes, window) = rest.split_once('@').ok_or_else(|| {
                        format!(
                            "partition spec {rest:?} must be <a>+<b>+..@<t1>-<t2>, \
                             e.g. partition:1+2@0.5-1.0"
                        )
                    })?;
                    let group = nodes
                        .split('+')
                        .map(|n| {
                            n.trim()
                                .parse::<NodeId>()
                                .map_err(|e| format!("partition node {n:?}: {e}"))
                        })
                        .collect::<Result<Vec<_>, _>>()?;
                    if group.is_empty() {
                        return Err(format!("partition {rest:?} lists no nodes"));
                    }
                    let (from, until) = window.split_once('-').ok_or_else(|| {
                        format!("partition window {window:?} must be <t1>-<t2>")
                    })?;
                    let from: f64 = from
                        .trim()
                        .parse()
                        .map_err(|e| format!("partition start {from:?}: {e}"))?;
                    let until: f64 = until
                        .trim()
                        .parse()
                        .map_err(|e| format!("partition end {until:?}: {e}"))?;
                    if !(from.is_finite() && until.is_finite() && from >= 0.0 && until > from) {
                        return Err(format!(
                            "partition window [{from}, {until}) must be finite with t2 > t1 >= 0"
                        ));
                    }
                    plan.partitions.push(Partition { group, from, until });
                }
                other => {
                    return Err(format!(
                        "unknown fault clause {other:?}; valid clauses: drop, dup, reorder, \
                         crash, partition, seed"
                    ));
                }
            }
        }
        plan.fired = plan.crashes.iter().map(|_| AtomicBool::new(false)).collect();
        Ok(Some(Arc::new(plan)))
    }

    /// The canonical spec this plan was parsed from.
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// The resolved fault-plane seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Scheduled crashes (recovery-bearing runs).
    pub fn crashes(&self) -> &[Crash] {
        &self.crashes
    }

    /// True when any clause draws from the per-node random streams.
    fn rand_active(&self) -> bool {
        self.drop_p > 0.0 || self.dup_p > 0.0 || self.reorder_p > 0.0
    }

    /// Validate the plan against a concrete cluster shape.
    pub fn validate(&self, n_nodes: usize) -> Result<(), String> {
        for c in &self.crashes {
            if c.node >= n_nodes {
                return Err(format!(
                    "crash:{}@{} names a node outside this {}-node cluster",
                    c.node, c.at, n_nodes
                ));
            }
        }
        for p in &self.partitions {
            for &n in &p.group {
                if n >= n_nodes {
                    return Err(format!(
                        "partition names node {n} outside this {n_nodes}-node cluster"
                    ));
                }
            }
        }
        Ok(())
    }

    /// If `node`'s clock has crossed an unfired crash, latch it (exactly
    /// once) and return its scheduled time; the caller unwinds the node.
    pub fn crash_due(&self, node: NodeId, clock: f64) -> Option<f64> {
        for (i, c) in self.crashes.iter().enumerate() {
            if c.node == node
                && clock >= c.at
                && self.fired[i]
                    .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
            {
                self.counters.crashes.fetch_add(1, Ordering::Relaxed);
                self.pending.store(c.at.to_bits(), Ordering::SeqCst);
                return Some(c.at);
            }
        }
        None
    }

    /// Consume a crash awaiting recovery (cluster-driver side): returns
    /// the crash's scheduled sim-time, at most once per fired crash.
    pub fn take_pending_recovery(&self) -> Option<f64> {
        let bits = self.pending.swap(PENDING_EMPTY, Ordering::SeqCst);
        if bits == PENDING_EMPTY {
            None
        } else {
            Some(f64::from_bits(bits))
        }
    }

    /// Record one completed recovery and the sim-time it rolled back.
    pub fn record_recovery(&self, lost_sim_time: f64) {
        self.counters.recoveries.fetch_add(1, Ordering::Relaxed);
        *self.counters.lost_sim_time.lock().unwrap() += lost_sim_time.max(0.0);
    }

    /// If a message from `from` to `to` sent at `send_time` crosses an
    /// active partition cut, return the heal time its delivery is
    /// deferred to.
    fn partition_hold(&self, from: NodeId, to: NodeId, send_time: f64) -> Option<f64> {
        for p in &self.partitions {
            if send_time >= p.from && send_time < p.until {
                let a = p.group.contains(&from);
                let b = p.group.contains(&to);
                if a != b {
                    return Some(p.until);
                }
            }
        }
        None
    }

    /// Attach the durable snapshot store the recovery path prefers over
    /// the monitor-resident epoch state.
    pub fn attach_store(&self, store: Arc<crate::checkpoint::CheckpointStore>) {
        *self.store.lock().unwrap() = Some(store);
    }

    /// The attached snapshot store, if any.
    pub fn store(&self) -> Option<Arc<crate::checkpoint::CheckpointStore>> {
        self.store.lock().unwrap().clone()
    }

    /// Snapshot of the injection/recovery counters.
    pub fn stats(&self) -> FaultStats {
        FaultStats {
            drops: self.counters.drops.load(Ordering::Relaxed),
            dups: self.counters.dups.load(Ordering::Relaxed),
            reorders: self.counters.reorders.load(Ordering::Relaxed),
            partition_holds: self.counters.partition_holds.load(Ordering::Relaxed),
            crashes: self.counters.crashes.load(Ordering::Relaxed),
            recoveries: self.counters.recoveries.load(Ordering::Relaxed),
            lost_sim_time: *self.counters.lost_sim_time.lock().unwrap(),
        }
    }
}

fn parse_prob(what: &str, s: &str) -> Result<f64, String> {
    let p: f64 = s.trim().parse().map_err(|e| format!("{what} probability {s:?}: {e}"))?;
    if (0.0..=1.0).contains(&p) {
        Ok(p)
    } else {
        Err(format!("{what} probability {p} must be in [0, 1]"))
    }
}

/// What the fault plane does to one counted send (consumed by
/// [`crate::net::Endpoint::send`], which owns the link profiles and
/// charges the resulting time).
#[derive(Clone, Copy, Debug, Default)]
pub struct SendEffects {
    /// First copy lost; sender retransmits (NIC paid again, delivery
    /// delayed by the retransmission timeout).
    pub dropped: bool,
    /// Duplicate frame occupies the sender NIC once more.
    pub duplicated: bool,
    /// Message takes the slow path: one extra wire latency on delivery.
    pub reordered: bool,
    /// Partition cut: delivery deferred to this heal time.
    pub hold_until: Option<f64>,
}

/// One node's handle on the shared plan: the plan plus this node's
/// seeded decision stream. Decisions are drawn in this node's program
/// order (one fixed triple per counted send while any probability clause
/// is active), so they are independent of `--threads` and of how sibling
/// nodes are scheduled.
#[derive(Debug)]
pub struct LinkFaults {
    plan: Arc<FaultPlan>,
    id: NodeId,
    stream: Pcg64,
}

impl LinkFaults {
    pub fn new(plan: Arc<FaultPlan>, id: NodeId) -> LinkFaults {
        // Same per-node splitmix idiom as `model::node_stream`, against
        // the fault-plane seed.
        let stream = Pcg64::seed_from_u64(
            plan.seed ^ (id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        LinkFaults { plan, id, stream }
    }

    /// The shared plan (recovery bookkeeping lives there).
    pub fn plan(&self) -> &Arc<FaultPlan> {
        &self.plan
    }

    /// Crash check at an injection point (see [`FaultPlan::crash_due`]).
    pub fn crash_due(&self, clock: f64) -> Option<f64> {
        self.plan.crash_due(self.id, clock)
    }

    /// Decide this send's fate. Draws exactly three uniforms per counted
    /// send while any probability clause is active (none otherwise), so
    /// the stream position is a pure function of the send index.
    pub fn on_send(&mut self, to: NodeId, send_time: f64) -> SendEffects {
        let mut eff = SendEffects::default();
        if self.plan.rand_active() {
            let d = self.stream.next_f64();
            let u = self.stream.next_f64();
            let r = self.stream.next_f64();
            if d < self.plan.drop_p {
                eff.dropped = true;
                self.plan.counters.drops.fetch_add(1, Ordering::Relaxed);
            }
            if u < self.plan.dup_p {
                eff.duplicated = true;
                self.plan.counters.dups.fetch_add(1, Ordering::Relaxed);
            }
            if r < self.plan.reorder_p {
                eff.reordered = true;
                self.plan.counters.reorders.fetch_add(1, Ordering::Relaxed);
            }
        }
        if let Some(heal) = self.plan.partition_hold(self.id, to, send_time) {
            eff.hold_until = Some(heal);
            self.plan.counters.partition_holds.fetch_add(1, Ordering::Relaxed);
        }
        eff
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(spec: &str) -> Arc<FaultPlan> {
        FaultPlan::parse(spec, 42).unwrap().expect("non-empty plan")
    }

    #[test]
    fn empty_and_none_specs_resolve_to_no_plan() {
        assert!(FaultPlan::parse("", 1).unwrap().is_none());
        assert!(FaultPlan::parse("  ", 1).unwrap().is_none());
        assert!(FaultPlan::parse("none", 1).unwrap().is_none());
        assert!(FaultPlan::parse("NONE", 1).unwrap().is_none());
    }

    #[test]
    fn parses_combined_clauses() {
        let p = plan("drop:0.1,dup:0.05,reorder:0.2,crash:2@1.5,partition:1+3@0.5-1.0");
        assert_eq!(p.drop_p, 0.1);
        assert_eq!(p.dup_p, 0.05);
        assert_eq!(p.reorder_p, 0.2);
        assert_eq!(p.crashes(), &[Crash { node: 2, at: 1.5 }]);
        assert_eq!(
            p.partitions,
            vec![Partition { group: vec![1, 3], from: 0.5, until: 1.0 }]
        );
        assert!(p.validate(4).is_ok());
        assert!(p.validate(3).is_err(), "partition node 3 outside a 3-node cluster");
    }

    #[test]
    fn rejects_malformed_clauses() {
        for bad in [
            "drop",            // no value
            "drop:1.5",        // out of range
            "drop:-0.1",       // negative
            "crash:2",         // no time
            "crash:0@1.0",     // monitor crash
            "crash:2@-1.0",    // negative time
            "partition:@1-2",  // no nodes
            "partition:1+2@2-1", // inverted window
            "blorp:0.1",       // unknown clause
        ] {
            let got = FaultPlan::parse(bad, 7);
            assert!(got.is_err(), "{bad:?} should be rejected, got {got:?}");
        }
    }

    #[test]
    fn decision_stream_is_a_pure_function_of_seed_and_node() {
        let decide = |seed: u64, id: NodeId| -> Vec<(bool, bool, bool)> {
            let p = FaultPlan::parse("drop:0.3,dup:0.3,reorder:0.3", seed).unwrap().unwrap();
            let mut lf = LinkFaults::new(p, id);
            (0..64)
                .map(|i| {
                    let e = lf.on_send(1 + (i % 3), 0.0);
                    (e.dropped, e.duplicated, e.reordered)
                })
                .collect()
        };
        assert_eq!(decide(9, 1), decide(9, 1), "same seed+node replays identically");
        assert_ne!(decide(9, 1), decide(9, 2), "sibling nodes draw independent streams");
        assert_ne!(decide(9, 1), decide(10, 1), "the seed matters");
    }

    #[test]
    fn crash_fires_exactly_once_and_hands_recovery_the_time() {
        let p = plan("crash:2@1.5");
        assert_eq!(p.crash_due(2, 1.0), None, "before the schedule");
        assert_eq!(p.crash_due(1, 2.0), None, "wrong node");
        assert_eq!(p.crash_due(2, 1.5), Some(1.5));
        assert_eq!(p.crash_due(2, 9.0), None, "one-shot");
        assert_eq!(p.take_pending_recovery(), Some(1.5));
        assert_eq!(p.take_pending_recovery(), None, "consumed");
        assert_eq!(p.stats().crashes, 1);
    }

    #[test]
    fn partition_holds_only_cut_crossing_messages_inside_the_window() {
        let p = plan("partition:1+2@0.5-1.0");
        assert_eq!(p.partition_hold(1, 0, 0.7), Some(1.0), "inside the window, across");
        assert_eq!(p.partition_hold(0, 2, 0.5), Some(1.0), "boundary start is inside");
        assert_eq!(p.partition_hold(1, 2, 0.7), None, "both in the group");
        assert_eq!(p.partition_hold(0, 3, 0.7), None, "both outside the group");
        assert_eq!(p.partition_hold(1, 0, 0.4), None, "before the window");
        assert_eq!(p.partition_hold(1, 0, 1.0), None, "healed at t2");
    }

    #[test]
    fn passive_plan_consumes_no_randomness() {
        // all probabilities zero: on_send must not draw, so two handles
        // built from the same seed stay bit-identical however often one
        // of them is consulted
        let p = plan("crash:2@1e9");
        let mut a = LinkFaults::new(p.clone(), 1);
        for _ in 0..100 {
            let e = a.on_send(0, 0.0);
            assert!(!e.dropped && !e.duplicated && !e.reordered && e.hold_until.is_none());
        }
        let b = LinkFaults::new(p, 1);
        assert_eq!(a.stream.state_words(), b.stream.state_words());
    }

    #[test]
    fn stats_snapshot_counts_decisions() {
        let p = FaultPlan::parse("drop:1.0,dup:1.0,reorder:1.0", 3).unwrap().unwrap();
        let mut lf = LinkFaults::new(p.clone(), 1);
        for _ in 0..5 {
            lf.on_send(0, 0.0);
        }
        let st = p.stats();
        assert_eq!((st.drops, st.dups, st.reorders), (5, 5, 5));
        p.record_recovery(2.5);
        p.record_recovery(1.0);
        let st = p.stats();
        assert_eq!(st.recoveries, 2);
        assert!((st.lost_sim_time - 3.5).abs() < 1e-12);
    }

    #[test]
    fn seed_clause_overrides_the_run_seed() {
        let a = FaultPlan::parse("drop:0.5,seed:123", 1).unwrap().unwrap();
        let b = FaultPlan::parse("drop:0.5,seed:123", 2).unwrap().unwrap();
        assert_eq!(a.seed(), b.seed(), "explicit seed wins over the run seed");
    }
}
