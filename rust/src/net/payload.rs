//! Typed wire payloads and codecs.
//!
//! Everything that crosses a counted link is a [`Payload`]: a reference-
//! counted, immutable buffer in one of three wire formats. Two things fall
//! out of this representation:
//!
//! 1. **Byte-accurate accounting.** Each variant knows its own wire size
//!    ([`Payload::wire_bytes`]), so [`crate::net::CommStats`] can count
//!    bytes — the canonical unit — while the logical scalar count
//!    ([`Payload::scalars`]) survives as a derived view for the paper's
//!    §4.5 `2qN`/`2q` pins.
//! 2. **Zero-copy fan-out.** `Arc` buffers make forwarding free in-process:
//!    a tree broadcast clones a pointer per hop instead of a `d`-length
//!    vector (see [`crate::net::collectives`]).
//!
//! [`WireFmt`] is the codec selector threaded from the CLI (`--wire`)
//! through [`crate::algs::RunParams`]: `f64` is the bit-exact default,
//! `f32` halves the bytes of every dense payload, and `sparse` sends only
//! the nonzero coordinates as `(u32 index, f32 value)` pairs.

use std::sync::Arc;

/// Wire-format selector (`--wire f64|f32|sparse`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WireFmt {
    /// 8 bytes per scalar; bit-exact (the default — equivalence suites pin
    /// this path against serial references).
    #[default]
    F64,
    /// 4 bytes per scalar; rounds every payload value to `f32` on the wire.
    F32,
    /// `(u32, f32)` pairs for the nonzeros only — 8 bytes per *nonzero*.
    /// Wins when payloads are sparser than 50%.
    Sparse,
}

impl WireFmt {
    pub const ALL: [WireFmt; 3] = [WireFmt::F64, WireFmt::F32, WireFmt::Sparse];

    const TABLE: [(&'static str, WireFmt); 3] =
        [("f64", WireFmt::F64), ("f32", WireFmt::F32), ("sparse", WireFmt::Sparse)];
    const NAMES: [&'static str; 3] = ["f64", "f32", "sparse"];

    /// Parse a wire-format name, case-insensitively (`F64`, `f64`, …).
    pub fn parse(s: &str) -> Option<WireFmt> {
        crate::util::parse_enum(s, &Self::TABLE)
    }

    /// [`WireFmt::parse`] with a CLI-grade error: the failure message
    /// lists every valid format instead of a bare "unknown wire format".
    pub fn parse_or_err(s: &str) -> Result<WireFmt, String> {
        crate::util::parse_enum_or_err(
            s,
            "wire format",
            "formats (case-insensitive)",
            &Self::NAMES,
            &Self::TABLE,
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            WireFmt::F64 => "f64",
            WireFmt::F32 => "f32",
            WireFmt::Sparse => "sparse",
        }
    }

    /// Wire bytes per scalar of a fully-dense payload — what closed-form
    /// accounting charges when it models traffic instead of counting real
    /// payloads: 8 for `f64`, 4 for `f32`, and 8 for `sparse` (one
    /// `(u32, f32)` pair per scalar, since a dense payload is all
    /// nonzeros).
    pub fn dense_bytes_per_scalar(self) -> u64 {
        match self {
            WireFmt::F64 | WireFmt::Sparse => 8,
            WireFmt::F32 => 4,
        }
    }

    /// Encode a dense vector for the wire.
    pub fn encode(self, data: &[f64]) -> Payload {
        match self {
            WireFmt::F64 => Payload::DenseF64(data.into()),
            WireFmt::F32 => {
                Payload::DenseF32(data.iter().map(|&v| v as f32).collect::<Vec<f32>>().into())
            }
            WireFmt::Sparse => {
                let mut idx = Vec::new();
                let mut val = Vec::new();
                for (i, &v) in data.iter().enumerate() {
                    if v != 0.0 {
                        idx.push(i as u32);
                        val.push(v as f32);
                    }
                }
                Payload::Sparse { idx: idx.into(), val: val.into() }
            }
        }
    }
}

/// One wire payload. Buffers are `Arc`s so clones (tree fan-out, star
/// broadcast) share the allocation instead of deep-copying it.
#[derive(Clone, Debug)]
pub enum Payload {
    DenseF64(Arc<[f64]>),
    DenseF32(Arc<[f32]>),
    /// Nonzero coordinates only; `idx` is strictly ascending.
    Sparse { idx: Arc<[u32]>, val: Arc<[f32]> },
}

impl From<Vec<f64>> for Payload {
    fn from(v: Vec<f64>) -> Payload {
        Payload::DenseF64(v.into())
    }
}

impl Payload {
    /// Logical scalar count — the §4.5 "communicated scalars" view
    /// (dense: length; sparse: number of nonzeros).
    pub fn scalars(&self) -> usize {
        match self {
            Payload::DenseF64(v) => v.len(),
            Payload::DenseF32(v) => v.len(),
            Payload::Sparse { val, .. } => val.len(),
        }
    }

    /// Exact bytes on the wire — the canonical unit the simulator charges
    /// for (counters and NIC occupancy).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::DenseF64(v) => 8 * v.len(),
            Payload::DenseF32(v) => 4 * v.len(),
            Payload::Sparse { idx, val } => 4 * idx.len() + 4 * val.len(),
        }
    }

    /// Decode into a caller-sized buffer. Dense payload lengths must match
    /// `out.len()`; a sparse payload zeroes `out` and scatters its
    /// nonzeros.
    pub fn decode_into(&self, out: &mut [f64]) {
        match self {
            Payload::DenseF64(v) => {
                assert_eq!(v.len(), out.len(), "dense f64 payload length mismatch");
                out.copy_from_slice(v);
            }
            Payload::DenseF32(v) => {
                assert_eq!(v.len(), out.len(), "dense f32 payload length mismatch");
                for (o, &x) in out.iter_mut().zip(v.iter()) {
                    *o = x as f64;
                }
            }
            Payload::Sparse { idx, val } => {
                out.iter_mut().for_each(|o| *o = 0.0);
                for (&i, &x) in idx.iter().zip(val.iter()) {
                    out[i as usize] = x as f64;
                }
            }
        }
    }

    /// Decode into a fresh vector of logical length `len`.
    pub fn to_vec(&self, len: usize) -> Vec<f64> {
        let mut out = vec![0.0f64; len];
        self.decode_into(&mut out);
        out
    }

    /// Elementwise-add the decoded payload into `out` (reduce step; for
    /// the `f64` format this is the exact same additions as a raw
    /// `Vec<f64>` reduce, in the same order). Dense payload lengths must
    /// match `out.len()` — a mismatch is a protocol bug, not something to
    /// truncate silently.
    pub fn add_into(&self, out: &mut [f64]) {
        match self {
            Payload::DenseF64(v) => {
                assert_eq!(v.len(), out.len(), "dense f64 payload length mismatch");
                for (o, &x) in out.iter_mut().zip(v.iter()) {
                    *o += x;
                }
            }
            Payload::DenseF32(v) => {
                assert_eq!(v.len(), out.len(), "dense f32 payload length mismatch");
                for (o, &x) in out.iter_mut().zip(v.iter()) {
                    *o += x as f64;
                }
            }
            Payload::Sparse { idx, val } => {
                for (&i, &x) in idx.iter().zip(val.iter()) {
                    out[i as usize] += x as f64;
                }
            }
        }
    }

    /// Decode replacing `data`, resizing to the payload's dense length.
    /// Sparse payloads carry no length, so `data` must already be sized.
    pub fn decode_resize(&self, data: &mut Vec<f64>) {
        match self {
            Payload::DenseF64(v) => {
                data.clear();
                data.extend_from_slice(v);
            }
            Payload::DenseF32(v) => {
                data.clear();
                data.extend(v.iter().map(|&x| x as f64));
            }
            Payload::Sparse { .. } => self.decode_into(data),
        }
    }

    /// Borrow an exact `f64` payload in place (the structured payloads
    /// built by `Comm::send_exact`); `None` for codec-compressed
    /// variants. Lets protocol hot loops read without a decode copy.
    pub fn as_f64(&self) -> Option<&[f64]> {
        match self {
            Payload::DenseF64(v) => Some(v),
            _ => None,
        }
    }

    /// Read one logical coordinate (control flags and the like).
    pub fn value(&self, i: usize) -> f64 {
        match self {
            Payload::DenseF64(v) => v[i],
            Payload::DenseF32(v) => v[i] as f64,
            Payload::Sparse { idx, val } => match idx.binary_search(&(i as u32)) {
                Ok(p) => val[p] as f64,
                Err(_) => 0.0,
            },
        }
    }

    /// Serialize for the TCP transport's frame body: `[kind u8]`
    /// `[count u32 LE]` `[data…]`, where kind 0 = dense f64 (8 bytes per
    /// element), 1 = dense f32 (4 bytes per element) and 2 = sparse
    /// (`count` = nnz, then `4·nnz` index bytes followed by `4·nnz` value
    /// bytes). All integers and floats are little-endian.
    pub fn write_bytes(&self, out: &mut Vec<u8>) {
        match self {
            Payload::DenseF64(v) => {
                out.push(0);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for &x in v.iter() {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Payload::DenseF32(v) => {
                out.push(1);
                out.extend_from_slice(&(v.len() as u32).to_le_bytes());
                for &x in v.iter() {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Payload::Sparse { idx, val } => {
                out.push(2);
                out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
                for &i in idx.iter() {
                    out.extend_from_slice(&i.to_le_bytes());
                }
                for &x in val.iter() {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }

    /// Decode a [`Payload::write_bytes`] encoding from the front of `buf`,
    /// returning the payload and the number of bytes consumed.
    ///
    /// The input is untrusted (TCP framing feeds this sockets bytes): the
    /// declared size is computed with checked arithmetic and validated
    /// against `buf` *before* anything is allocated, truncated input
    /// errors instead of panicking, and no byte past the declared size is
    /// ever read.
    pub fn read_bytes(buf: &[u8]) -> Result<(Payload, usize), String> {
        if buf.len() < 5 {
            return Err(format!("payload header truncated: {} bytes, need 5", buf.len()));
        }
        let kind = buf[0];
        let count = u32::from_le_bytes([buf[1], buf[2], buf[3], buf[4]]) as usize;
        let elem_bytes: usize = match kind {
            0 => 8,
            1 => 4,
            2 => 8, // 4 index + 4 value bytes per nonzero
            k => return Err(format!("unknown payload kind {k}")),
        };
        let data_bytes = count
            .checked_mul(elem_bytes)
            .ok_or_else(|| format!("payload element count {count} overflows"))?;
        let total = 5usize
            .checked_add(data_bytes)
            .ok_or_else(|| format!("payload element count {count} overflows"))?;
        if buf.len() < total {
            return Err(format!(
                "payload truncated: {} bytes, need {total} for kind {kind} count {count}",
                buf.len()
            ));
        }
        let body = &buf[5..total];
        let payload = match kind {
            0 => {
                let mut v = Vec::with_capacity(count);
                for c in body.chunks_exact(8) {
                    v.push(f64::from_le_bytes(c.try_into().unwrap()));
                }
                Payload::DenseF64(v.into())
            }
            1 => {
                let mut v = Vec::with_capacity(count);
                for c in body.chunks_exact(4) {
                    v.push(f32::from_le_bytes(c.try_into().unwrap()));
                }
                Payload::DenseF32(v.into())
            }
            _ => {
                let (ib, vb) = body.split_at(4 * count);
                let mut idx = Vec::with_capacity(count);
                for c in ib.chunks_exact(4) {
                    idx.push(u32::from_le_bytes(c.try_into().unwrap()));
                }
                let mut val = Vec::with_capacity(count);
                for c in vb.chunks_exact(4) {
                    val.push(f32::from_le_bytes(c.try_into().unwrap()));
                }
                Payload::Sparse { idx: idx.into(), val: val.into() }
            }
        };
        Ok((payload, total))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_round_trips_bit_exactly() {
        let data = vec![0.1, -2.5, 0.0, 1e300, f64::MIN_POSITIVE];
        let p = WireFmt::F64.encode(&data);
        assert_eq!(p.to_vec(5), data);
        assert_eq!(p.scalars(), 5);
        assert_eq!(p.wire_bytes(), 40);
    }

    #[test]
    fn f32_halves_bytes_and_rounds() {
        let data = vec![1.0, 0.1, -3.0, 0.0];
        let p = WireFmt::F32.encode(&data);
        assert_eq!(p.scalars(), 4);
        assert_eq!(p.wire_bytes(), 16);
        let back = p.to_vec(4);
        assert_eq!(back[0], 1.0);
        assert_eq!(back[2], -3.0);
        assert!((back[1] - 0.1).abs() < 1e-7 && back[1] != 0.1, "0.1 must round through f32");
    }

    #[test]
    fn sparse_keeps_only_nonzeros() {
        let data = vec![0.0, 2.0, 0.0, 0.0, -1.0];
        let p = WireFmt::Sparse.encode(&data);
        assert_eq!(p.scalars(), 2);
        assert_eq!(p.wire_bytes(), 16); // 2 × (u32 + f32)
        assert_eq!(p.to_vec(5), data);
        assert_eq!(p.value(1), 2.0);
        assert_eq!(p.value(3), 0.0);
    }

    #[test]
    fn add_into_matches_decode_then_add() {
        let data = vec![1.0, 0.0, 3.0];
        for fmt in WireFmt::ALL {
            let p = fmt.encode(&data);
            let mut acc = vec![10.0, 20.0, 30.0];
            p.add_into(&mut acc);
            let mut want = vec![10.0, 20.0, 30.0];
            for (w, v) in want.iter_mut().zip(p.to_vec(3)) {
                *w += v;
            }
            assert_eq!(acc, want, "{}", fmt.name());
        }
    }

    #[test]
    fn decode_resize_adopts_dense_length() {
        let p = WireFmt::F64.encode(&[1.0, 2.0, 3.0]);
        let mut data = vec![0.0; 7];
        p.decode_resize(&mut data);
        assert_eq!(data, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn clones_share_the_buffer() {
        let p = WireFmt::F64.encode(&[1.0; 1000]);
        let q = p.clone();
        match (&p, &q) {
            (Payload::DenseF64(a), Payload::DenseF64(b)) => {
                assert!(Arc::ptr_eq(a, b), "clone must not deep-copy");
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn dense_bytes_per_scalar_matches_encode() {
        let dense = [1.0, -2.0, 3.5, 4.0, 0.25];
        for fmt in WireFmt::ALL {
            assert_eq!(
                fmt.encode(&dense).wire_bytes() as u64,
                dense.len() as u64 * fmt.dense_bytes_per_scalar(),
                "{}",
                fmt.name()
            );
        }
    }

    #[test]
    fn parse_round_trip() {
        for fmt in WireFmt::ALL {
            assert_eq!(WireFmt::parse(fmt.name()), Some(fmt));
        }
        assert_eq!(WireFmt::parse("f16"), None);
        assert_eq!(WireFmt::default(), WireFmt::F64);
    }

    #[test]
    fn parse_is_case_insensitive() {
        assert_eq!(WireFmt::parse("F64"), Some(WireFmt::F64));
        assert_eq!(WireFmt::parse("  Sparse "), Some(WireFmt::Sparse));
        assert_eq!(WireFmt::parse("F32"), Some(WireFmt::F32));
    }

    #[test]
    fn parse_error_lists_valid_formats() {
        let err = WireFmt::parse_or_err("f16").unwrap_err();
        for fmt in WireFmt::ALL {
            assert!(err.contains(fmt.name()), "error must list {:?}: {err}", fmt.name());
        }
        assert_eq!(WireFmt::parse_or_err("SPARSE"), Ok(WireFmt::Sparse));
    }

    #[test]
    fn byte_codec_round_trips_all_formats() {
        crate::testkit::check("payload byte round-trip", 24, |g| {
            let n = g.usize_in(0, 40);
            let data = g.vec_f64(n, -3.0, 3.0);
            for fmt in WireFmt::ALL {
                let p = fmt.encode(&data);
                let mut buf = Vec::new();
                p.write_bytes(&mut buf);
                let (back, used) = Payload::read_bytes(&buf).unwrap();
                assert_eq!(used, buf.len(), "{}", fmt.name());
                assert_eq!(back.to_vec(n), p.to_vec(n), "{}", fmt.name());
                assert_eq!(back.wire_bytes(), p.wire_bytes(), "{}", fmt.name());
                assert_eq!(back.scalars(), p.scalars(), "{}", fmt.name());
            }
        });
    }

    #[test]
    fn byte_codec_round_trips_empty_payloads() {
        // zero-length dense payloads in every format …
        for fmt in WireFmt::ALL {
            let mut buf = Vec::new();
            fmt.encode(&[]).write_bytes(&mut buf);
            let (back, used) = Payload::read_bytes(&buf).unwrap();
            assert_eq!(used, 5, "{}", fmt.name());
            assert_eq!(back.scalars(), 0, "{}", fmt.name());
            assert_eq!(back.to_vec(0), Vec::<f64>::new(), "{}", fmt.name());
        }
        // … and an all-zero vector, which Sparse encodes as an empty payload
        let p = WireFmt::Sparse.encode(&[0.0, 0.0, 0.0]);
        assert_eq!(p.scalars(), 0);
        let mut buf = Vec::new();
        p.write_bytes(&mut buf);
        let (back, _) = Payload::read_bytes(&buf).unwrap();
        assert_eq!(back.to_vec(3), vec![0.0; 3]);
    }

    #[test]
    fn topk_outputs_round_trip_the_byte_codec() {
        // Adversarial inputs for the compression stage: mostly-zero vectors
        // with denormals, signed zeros, and magnitude ties. Whatever
        // Payload::Sparse the encoder produces must survive the TCP byte
        // codec with indices strictly ascending and duplicate-free.
        use crate::net::Compression;
        crate::testkit::check("top-k sparse round-trip", 32, |g| {
            let n = g.usize_in(0, 50);
            let mut data = vec![0.0f64; n];
            for v in data.iter_mut() {
                *v = match g.usize_in(0, 5) {
                    0 => 0.0,
                    1 => -0.0,
                    2 => f64::MIN_POSITIVE / 2.0, // subnormal, nonzero
                    3 => g.normal(),
                    // magnitude ties: ±1 forces the index tie-break
                    _ => if g.bool() { 1.0 } else { -1.0 },
                };
            }
            let k = g.usize_in(0, n + 2); // includes k = 0 edge and k ≥ nnz
            let modes = [
                Compression::TopK(k.max(1)),
                Compression::Threshold(g.f64_in(1e-6, 2.0)),
                Compression::None,
            ];
            for mode in modes {
                let p = mode.encode(&data);
                let (idx, val) = match &p {
                    Payload::Sparse { idx, val } => (idx, val),
                    _ => panic!("compression must encode sparse"),
                };
                assert_eq!(idx.len(), val.len());
                assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices must strictly ascend");
                assert!(idx.iter().all(|&i| (i as usize) < n), "index out of range");
                assert_eq!(p.wire_bytes(), 8 * p.scalars());
                let mut buf = Vec::new();
                p.write_bytes(&mut buf);
                let (back, used) = Payload::read_bytes(&buf).unwrap();
                assert_eq!(used, buf.len());
                assert_eq!(back.to_vec(n), p.to_vec(n), "byte codec must be lossless");
                // every surviving coordinate is the f32 rounding of the
                // original — compression selects, it never rewrites
                let dec = p.to_vec(n);
                for (&i, &v) in idx.iter().zip(val.iter()) {
                    assert_eq!(dec[i as usize], v as f64);
                    assert_eq!(v, data[i as usize] as f32);
                    assert!(data[i as usize] != 0.0, "a zero must never be selected");
                }
            }
        });
    }

    #[test]
    fn empty_topk_selection_round_trips() {
        // an all-zero vector compresses to the empty sparse payload, which
        // must survive the byte codec and decode back to zeros
        use crate::net::Compression;
        for mode in [Compression::TopK(4), Compression::Threshold(0.5)] {
            let p = mode.encode(&[0.0, -0.0, 0.0]);
            assert_eq!(p.scalars(), 0);
            assert_eq!(p.wire_bytes(), 0);
            let mut buf = Vec::new();
            p.write_bytes(&mut buf);
            let (back, used) = Payload::read_bytes(&buf).unwrap();
            assert_eq!(used, 5);
            assert_eq!(back.to_vec(3), vec![0.0; 3]);
        }
    }

    #[test]
    fn truncated_byte_streams_error_cleanly() {
        crate::testkit::check("payload truncation errors", 16, |g| {
            let n = g.usize_in(0, 20);
            let data = g.vec_f64(n, -2.0, 2.0);
            for fmt in WireFmt::ALL {
                let mut buf = Vec::new();
                fmt.encode(&data).write_bytes(&mut buf);
                for cut in 0..buf.len() {
                    assert!(
                        Payload::read_bytes(&buf[..cut]).is_err(),
                        "{}: prefix of {cut}/{} bytes must error, not decode",
                        fmt.name(),
                        buf.len()
                    );
                }
            }
        });
    }

    #[test]
    fn adversarial_bytes_never_panic_or_over_read() {
        crate::testkit::check("payload adversarial decode", 32, |g| {
            let len = g.usize_in(0, 64);
            let mut buf: Vec<u8> = (0..len).map(|_| g.usize_in(0, 255) as u8).collect();
            if !buf.is_empty() {
                // bias the kind byte so valid headers are actually exercised
                buf[0] = g.usize_in(0, 3) as u8;
            }
            match Payload::read_bytes(&buf) {
                Ok((p, used)) => {
                    assert!(used <= buf.len(), "decoder must never over-read");
                    assert!(p.wire_bytes() <= used, "decoded size must fit the input");
                }
                Err(e) => assert!(!e.is_empty()),
            }
        });
    }

    #[test]
    fn huge_declared_count_errors_without_allocating() {
        for kind in [0u8, 1, 2] {
            let mut buf = vec![kind];
            buf.extend_from_slice(&u32::MAX.to_le_bytes());
            assert!(Payload::read_bytes(&buf).is_err(), "kind {kind}");
        }
    }
}
