//! Shared collectives over typed payloads — the one place algorithms talk
//! to the wire.
//!
//! The tree collectives implement the paper's Fig.-5 binomial tree rooted
//! at `group[0]`; [`star_allreduce`] is the naive hub ablation. Both are
//! generic over the [`WireFmt`] codec: under the default `f64` format the
//! arithmetic (and therefore the §4.5 scalar counters) is identical to a
//! raw `Vec<f64>` implementation, while `f32`/`sparse` trade precision or
//! zeros for wire bytes.
//!
//! Broadcast fan-out is **zero-copy in-process**: the root encodes its
//! buffer into an `Arc` payload once, and every hop forwards `Arc` clones
//! instead of deep-copying a `d`-length vector per child (the old
//! O(d·log q) allocation hot path of every collective).
//!
//! Algorithms do not call the free functions directly; they hold a
//! [`Comm`] (built by [`crate::algs::RunParams::comm`]) that carries the
//! run's wire format and tree/star choice, so *every counted send* goes
//! through one codec path.

use super::compress::Compression;
use super::payload::{Payload, WireFmt};
use super::{tags, Endpoint, NodeId, Tag};

/// A run's communication policy: which codec encodes counted payloads,
/// whether allreduces use the Fig.-5 tree or the star ablation, and the
/// optional gradient-sparsification stage applied before the codec.
#[derive(Clone, Copy, Debug, Default)]
pub struct Comm {
    pub wire: WireFmt,
    pub star: bool,
    /// Opt-in sparsification of counted payloads (`--compress`). When
    /// active it supersedes `wire` on vector sends: selected coordinates
    /// travel as a [`Payload::Sparse`] regardless of the wire format.
    pub compress: Compression,
}

impl Comm {
    pub fn new(wire: WireFmt, star: bool) -> Comm {
        Comm { wire, star, compress: Compression::None }
    }

    /// Same policy with a sparsification stage attached.
    pub fn with_compress(self, compress: Compression) -> Comm {
        Comm { compress, ..self }
    }

    /// Encode one counted vector: sparsify if compression is on, else the
    /// run's wire codec.
    fn encode(&self, data: &[f64]) -> Payload {
        if self.compress.is_none() {
            self.wire.encode(data)
        } else {
            self.compress.encode(data)
        }
    }

    /// Whether encode→decode can change values: a lossy codec or any
    /// sparsifier. Drives the root/hub self-decode that keeps every node
    /// identical after a collective.
    fn lossy(&self) -> bool {
        self.wire != WireFmt::F64 || !self.compress.is_none()
    }

    /// Allreduce (elementwise sum) over `group`; tree by default, star
    /// under the ablation flag.
    pub fn allreduce(&self, ep: &mut Endpoint, group: &[NodeId], data: &mut Vec<f64>) {
        let enc = |d: &[f64]| self.encode(d);
        if self.star {
            star_allreduce_enc(ep, group, data, &enc, self.lossy());
        } else {
            tree_reduce_enc(ep, group, data, &enc);
            tree_broadcast_enc(ep, group, data, &enc, self.lossy());
        }
    }

    /// Encode and send one counted vector.
    pub fn send(&self, ep: &mut Endpoint, to: NodeId, tag: Tag, data: &[f64]) {
        ep.send(to, tag, self.encode(data));
    }

    /// Encode once, then fan the same `Arc` payload out to every peer
    /// (zero-copy: one encode regardless of the peer count).
    pub fn send_all(
        &self,
        ep: &mut Endpoint,
        to: impl IntoIterator<Item = NodeId>,
        tag: Tag,
        data: &[f64],
    ) {
        let payload = self.encode(data);
        for peer in to {
            ep.send(peer, tag, payload.clone());
        }
    }

    /// Structured payloads — key/value pairs, request tokens, step-size
    /// headers — whose layout is itself the message format. These always
    /// travel as exact `f64` (8 B/scalar): re-encoding them would corrupt
    /// keys or drop structurally-meaningful zeros.
    pub fn send_exact(&self, ep: &mut Endpoint, to: NodeId, tag: Tag, data: Vec<f64>) {
        ep.send(to, tag, Payload::from(data));
    }

    /// Receive from `from` and decode into a caller-sized buffer.
    pub fn recv_into(&self, ep: &mut Endpoint, from: NodeId, tag: Tag, out: &mut [f64]) {
        ep.recv_from(from, tag).decode_into(out);
    }

    /// Receive from `from` and decode into a fresh vector of logical
    /// length `len`.
    pub fn recv_vec(&self, ep: &mut Endpoint, from: NodeId, tag: Tag, len: usize) -> Vec<f64> {
        ep.recv_from(from, tag).to_vec(len)
    }
}

/// Reduce (elementwise sum) of `data` from all nodes in `group` to
/// `group[0]` along the binomial tree. Every node calls this with its own
/// contribution; on return `group[0]`'s buffer holds the sum (other
/// buffers hold partial sums).
pub fn tree_reduce(ep: &mut Endpoint, group: &[NodeId], data: &mut [f64], wire: WireFmt) {
    tree_reduce_enc(ep, group, data, &|d| wire.encode(d));
}

/// [`tree_reduce`] generalized over the payload encoder (wire codec or
/// sparsifier); internal — the public entry points fix the encoder.
fn tree_reduce_enc(
    ep: &mut Endpoint,
    group: &[NodeId],
    data: &mut [f64],
    enc: &dyn Fn(&[f64]) -> Payload,
) {
    let rank = group.iter().position(|&n| n == ep.id()).expect("node not in group");
    let q = group.len();
    let mut mask = 1usize;
    while mask < q {
        if rank & (mask - 1) == 0 {
            if rank & mask != 0 {
                // sender: pass partial sum down to (rank - mask), then leave
                ep.send(group[rank - mask], tags::REDUCE, enc(data));
                break;
            } else if rank + mask < q {
                let msg = ep.recv_from(group[rank + mask], tags::REDUCE);
                msg.add_into(data);
            }
        }
        mask <<= 1;
    }
}

/// Broadcast `data` from `group[0]` to all of `group` along the reverse
/// binomial tree. The root encodes once; interior nodes forward the
/// received `Arc` payload (pointer clones, no per-hop deep copy) and only
/// decode into their own buffer at the end. On non-root nodes `data` is
/// overwritten.
pub fn tree_broadcast(ep: &mut Endpoint, group: &[NodeId], data: &mut Vec<f64>, wire: WireFmt) {
    tree_broadcast_enc(ep, group, data, &|d| wire.encode(d), wire != WireFmt::F64);
}

/// [`tree_broadcast`] generalized over the payload encoder. `lossy` marks
/// encoders whose decode differs from the root's buffer (non-f64 codec or
/// any sparsifier): the root then adopts its own encoding so all nodes
/// exit identical.
fn tree_broadcast_enc(
    ep: &mut Endpoint,
    group: &[NodeId],
    data: &mut Vec<f64>,
    enc: &dyn Fn(&[f64]) -> Payload,
    lossy: bool,
) {
    let rank = group.iter().position(|&n| n == ep.id()).expect("node not in group");
    let q = group.len();
    let mut mask = 1usize;
    while mask < q {
        mask <<= 1;
    }
    mask >>= 1;
    // receive once from the parent, then forward to children in reverse order
    let mut payload: Option<Payload> = if rank == 0 { Some(enc(data)) } else { None };
    while mask >= 1 {
        if rank & (mask - 1) == 0 {
            if payload.is_none() && rank & mask != 0 {
                payload = Some(ep.recv_from(group[rank - mask], tags::BCAST).payload);
            } else if rank & mask == 0 && rank + mask < q {
                // a node only reaches a forwarding round after its own
                // receive (its low bits are all zero here), so the payload
                // is present — forward the Arc, no deep copy
                let p = payload.as_ref().expect("tree broadcast: forward before receive");
                ep.send(group[rank + mask], tags::BCAST, p.clone());
            }
        }
        if mask == 1 {
            break;
        }
        mask >>= 1;
    }
    // Non-root nodes adopt the received payload. Under a lossy codec the
    // root does the same with its own encoding, so every node — root
    // included — exits holding identical (codec-rounded) values; on the
    // exact f64 path the root's buffer is already bit-identical and the
    // copy is skipped.
    let payload = payload.expect("tree broadcast: payload not received");
    if rank != 0 || lossy {
        payload.decode_resize(data);
    }
}

/// Allreduce = tree reduce to `group[0]` + reverse-tree broadcast.
/// After return every node holds the elementwise sum.
pub fn tree_allreduce(ep: &mut Endpoint, group: &[NodeId], data: &mut Vec<f64>, wire: WireFmt) {
    tree_reduce(ep, group, data, wire);
    tree_broadcast(ep, group, data, wire);
}

/// Naive star allreduce (ablation baseline): all nodes send to `group[0]`,
/// which sums and fans the result back out. Same scalar/byte volume as the
/// tree but `2(q−1)` sequential rounds at the hub and a hub hot-spot. The
/// fan-out encodes once and clones the `Arc` payload per peer.
pub fn star_allreduce(ep: &mut Endpoint, group: &[NodeId], data: &mut Vec<f64>, wire: WireFmt) {
    star_allreduce_enc(ep, group, data, &|d| wire.encode(d), wire != WireFmt::F64);
}

/// [`star_allreduce`] generalized over the payload encoder; see
/// [`tree_broadcast_enc`] for the `lossy` contract.
fn star_allreduce_enc(
    ep: &mut Endpoint,
    group: &[NodeId],
    data: &mut Vec<f64>,
    enc: &dyn Fn(&[f64]) -> Payload,
    lossy: bool,
) {
    let rank = group.iter().position(|&n| n == ep.id()).expect("node not in group");
    if rank == 0 {
        for &peer in &group[1..] {
            let msg = ep.recv_from(peer, tags::REDUCE);
            msg.add_into(data);
        }
        let payload = enc(data);
        for &peer in &group[1..] {
            ep.send(peer, tags::BCAST, payload.clone());
        }
        // lossy codec: the hub keeps the same rounded values it fanned out
        if lossy {
            payload.decode_resize(data);
        }
    } else {
        ep.send(group[0], tags::REDUCE, enc(data));
        let msg = ep.recv_from(group[0], tags::BCAST);
        msg.payload.decode_resize(data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{build, SimParams};
    use std::thread;

    /// Run `f(endpoint, rank)` on `n` nodes, return per-rank results.
    fn run_group<T: Send + 'static>(
        n: usize,
        f: impl Fn(&mut Endpoint, usize) -> T + Send + Sync + Copy + 'static,
    ) -> (Vec<T>, std::sync::Arc<crate::net::CommStats>) {
        let (eps, stats) = build(n, SimParams::free());
        let mut handles = Vec::new();
        for (rank, mut ep) in eps.into_iter().enumerate() {
            handles.push(thread::spawn(move || f(&mut ep, rank)));
        }
        (handles.into_iter().map(|h| h.join().unwrap()).collect(), stats)
    }

    #[test]
    fn allreduce_sums_under_every_wire_format() {
        for fmt in WireFmt::ALL {
            for n in [1usize, 2, 3, 5, 8, 9] {
                let (results, _) = run_group(n, move |ep, rank| {
                    let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
                    let mut data = vec![rank as f64, 1.0, 0.0];
                    tree_allreduce(ep, &group, &mut data, fmt);
                    data
                });
                let want = vec![(0..n).sum::<usize>() as f64, n as f64, 0.0];
                for (rank, r) in results.iter().enumerate() {
                    assert_eq!(r, &want, "{} n={n} rank={rank}", fmt.name());
                }
            }
        }
    }

    #[test]
    fn star_agrees_with_tree_under_every_wire_format() {
        for fmt in WireFmt::ALL {
            let (results, _) = run_group(6, move |ep, rank| {
                let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
                let mut data = vec![(rank + 1) as f64, 0.0];
                star_allreduce(ep, &group, &mut data, fmt);
                data
            });
            for r in &results {
                assert_eq!(r, &vec![21.0, 0.0], "{}", fmt.name());
            }
        }
    }

    #[test]
    fn lossy_allreduce_leaves_all_nodes_identical() {
        // 0.1·(rank+1) is not f32-representable: without the root's
        // self-decode the hub would keep exact f64 sums while workers
        // hold rounded ones
        for star in [false, true] {
            let (results, _) = run_group(5, move |ep, rank| {
                let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
                let mut data = vec![0.1 * (rank as f64 + 1.0); 3];
                if star {
                    star_allreduce(ep, &group, &mut data, WireFmt::F32);
                } else {
                    tree_allreduce(ep, &group, &mut data, WireFmt::F32);
                }
                data
            });
            for (rank, r) in results.iter().enumerate() {
                assert_eq!(
                    r, &results[0],
                    "star={star} rank={rank}: every node must hold the same rounded sum"
                );
            }
        }
    }

    #[test]
    fn f32_wire_halves_collective_bytes() {
        let run = |fmt: WireFmt| {
            let (_, stats) = run_group(5, move |ep, rank| {
                let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
                let mut data = vec![rank as f64 + 1.0; 32];
                tree_allreduce(ep, &group, &mut data, fmt);
            });
            (stats.total_scalars(), stats.total_bytes())
        };
        let (s64, b64) = run(WireFmt::F64);
        let (s32, b32) = run(WireFmt::F32);
        assert_eq!(s64, s32, "scalar view must not depend on the codec");
        assert_eq!(b64, 2 * b32, "f32 wire must halve the bytes");
        assert_eq!(b64, 8 * s64, "f64 wire: 8 bytes per scalar");
    }

    #[test]
    fn sparse_wire_counts_nonzeros_only() {
        // broadcast a 1%-dense vector: sparse moves ~1% of the f64 bytes
        let (_, dense_stats) = run_group(4, |ep, rank| {
            let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
            let mut data = vec![0.0f64; 1000];
            if rank == 0 {
                data[7] = 1.0;
                data[700] = -2.0;
            }
            tree_broadcast(ep, &group, &mut data, WireFmt::F64);
            data
        });
        let (results, sparse_stats) = run_group(4, |ep, rank| {
            let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
            let mut data = vec![0.0f64; 1000];
            if rank == 0 {
                data[7] = 1.0;
                data[700] = -2.0;
            }
            tree_broadcast(ep, &group, &mut data, WireFmt::Sparse);
            data
        });
        for r in &results {
            assert_eq!(r[7], 1.0);
            assert_eq!(r[700], -2.0);
            assert_eq!(r.iter().filter(|v| **v != 0.0).count(), 2);
        }
        assert!(
            sparse_stats.total_bytes() * 100 < dense_stats.total_bytes(),
            "sparse {} bytes vs dense {}",
            sparse_stats.total_bytes(),
            dense_stats.total_bytes()
        );
    }

    #[test]
    fn compressed_allreduce_drops_bytes_and_leaves_nodes_identical() {
        // dense 64-vectors, top-8 compression: the reduce keeps summing
        // whatever survives each hop, and every node (hub included) exits
        // with the same sparsified result
        for star in [false, true] {
            let run = move |compress: Compression| {
                let (results, stats) = run_group(5, move |ep, rank| {
                    let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
                    let comm = Comm::new(WireFmt::F64, star).with_compress(compress);
                    let mut data: Vec<f64> =
                        (0..64).map(|j| ((rank * 64 + j) % 13) as f64 - 6.0).collect();
                    comm.allreduce(ep, &group, &mut data);
                    data
                });
                (results, stats.total_bytes())
            };
            let (dense, dense_bytes) = run(Compression::None);
            let (topk, topk_bytes) = run(Compression::TopK(8));
            for (rank, r) in topk.iter().enumerate() {
                assert_eq!(
                    r, &topk[0],
                    "star={star} rank={rank}: all nodes must agree under top-k"
                );
                assert!(
                    r.iter().filter(|v| **v != 0.0).count() <= 8,
                    "star={star}: final vector keeps at most k coordinates"
                );
            }
            // Compression::None rides the sparse codec (f32 values, only
            // nonzeros), so compare against a fully dense f64 run instead.
            assert_eq!(dense.len(), 5);
            assert!(dense_bytes > 0);
            assert!(
                topk_bytes * 2 < dense_bytes,
                "star={star}: top-8 of 64 must cut wire bytes well below half \
                 ({topk_bytes} vs {dense_bytes})"
            );
        }
    }

    #[test]
    fn comm_send_with_compression_counts_kept_coordinates_only() {
        let comm = Comm::new(WireFmt::F64, false).with_compress(Compression::TopK(2));
        let (eps, stats) = build(2, SimParams::free());
        let mut it = eps.into_iter();
        let mut a = it.next().unwrap();
        let mut b = it.next().unwrap();
        let h = thread::spawn(move || {
            comm.send(&mut a, 1, tags::PUSH, &[0.0, 5.0, 1.0, -7.0, 0.5]);
        });
        let msg = b.recv_from(0, tags::PUSH);
        h.join().unwrap();
        assert_eq!(msg.to_vec(5), vec![0.0, 5.0, 0.0, -7.0, 0.0]);
        assert_eq!(stats.total_scalars(), 2, "only the kept coordinates are counted");
        assert_eq!(stats.total_bytes(), 16, "8 wire bytes per kept coordinate");
    }

    #[test]
    fn comm_send_exact_ignores_wire_format() {
        let comm = Comm::new(WireFmt::Sparse, false);
        let (eps, stats) = build(2, SimParams::free());
        let mut it = eps.into_iter();
        let mut a = it.next().unwrap();
        let mut b = it.next().unwrap();
        let h = thread::spawn(move || {
            // structured payload full of zeros — must not be compressed away
            comm.send_exact(&mut a, 1, tags::PUSH, vec![0.0, 3.0, 0.0]);
        });
        let msg = b.recv_from(0, tags::PUSH);
        h.join().unwrap();
        assert_eq!(msg.to_vec(3), vec![0.0, 3.0, 0.0]);
        assert_eq!(stats.total_scalars(), 3);
        assert_eq!(stats.total_bytes(), 24);
    }

    #[test]
    fn comm_send_all_encodes_once() {
        let comm = Comm::new(WireFmt::F64, false);
        let (eps, stats) = build(3, SimParams::free());
        let mut it = eps.into_iter();
        let mut a = it.next().unwrap();
        let mut b = it.next().unwrap();
        let mut c = it.next().unwrap();
        let h = thread::spawn(move || {
            comm.send_all(&mut a, 1..3, tags::BCAST, &[5.0, 6.0]);
        });
        let mb = b.recv_from(0, tags::BCAST);
        let mc = c.recv_from(0, tags::BCAST);
        h.join().unwrap();
        assert_eq!(mb.to_vec(2), vec![5.0, 6.0]);
        // both receivers share the same Arc buffer — fan-out was zero-copy
        match (&mb.payload, &mc.payload) {
            (Payload::DenseF64(x), Payload::DenseF64(y)) => {
                assert!(std::sync::Arc::ptr_eq(x, y));
            }
            _ => unreachable!(),
        }
    }
}
