//! In-process cluster network simulator.
//!
//! Stands in for the paper's testbed (machines on 10GbE). Every simulated
//! node runs on its own OS thread and owns an [`Endpoint`]; endpoints
//! exchange [`Msg`]s over channels. Two things make this a *simulator*
//! rather than just a thread pool:
//!
//! 1. **Exact communication accounting.** Every payload is a typed
//!    [`Payload`] that knows its wire size, so [`CommStats`] counts
//!    **bytes and messages** per sender — the canonical units — plus the
//!    logical scalar count as a derived view (a `d`-vector costs `d`
//!    scalars, matching the paper's Fig. 7 axis; under the default `f64`
//!    wire format bytes are exactly 8× scalars). The counters are what
//!    Figure 7 and the §4.5 complexity table read out, and they are
//!    independent of how the simulation is scheduled.
//! 2. **A simulated clock.** Each node accumulates (a) its own compute,
//!    measured on the per-thread CPU clock so co-scheduled sibling nodes
//!    don't pollute it, and (b) message delays `α + bytes·β` (latency +
//!    per-byte transfer time). A receive advances the receiver to
//!    `max(own_clock, sender_send_time + delay)` — the standard
//!    happens-before rule of a distributed-event simulation. Reported
//!    times are therefore the schedule a real cluster would follow, even
//!    though all nodes share one machine.
//!
//! Evaluation traffic (objective snapshots) uses the `send_eval`/`recv_eval`
//! pair which bypasses both the counters and the clock.
//!
//! All time-charging is owned by the pluggable [`model`] layer: a
//! [`NetModel`] (uniform / heterogeneous racks / stragglers / seeded
//! jitter) hands each endpoint a [`model::LinkView`] and the endpoint
//! routes every compute tick, send and receive through it. [`build`]
//! keeps the legacy flat-[`SimParams`] signature (a [`NetModel::Uniform`]
//! network, bit-exact with the pre-model charging); scenario clusters go
//! through [`build_with_model`].
//!
//! Collectives (tree/star allreduce, zero-copy broadcast) live in
//! [`collectives`]; the codec layer ([`WireFmt`]/[`Payload`]) in
//! [`payload`].
//!
//! Seeded failure injection (message loss/duplication/reorder under a
//! reliable-link model, scheduled crashes and healing partitions) is the
//! [`fault`] plane's job: a [`fault::FaultPlan`] installs a per-node
//! [`fault::LinkFaults`] hook on each endpoint and every counted send
//! consults it *after* the model has charged the wire — faults reshape
//! time, never payloads or counters. With no plan installed the hook is
//! absent and every code path below is byte-for-byte the failure-free
//! one.
//!
//! How a message physically travels is the [`transport`] seam's job:
//! every [`Endpoint`] delegates moving bytes to a [`Transport`] — the
//! in-memory [`transport::SimTransport`] mailboxes (default, bit-exact
//! with the pre-seam plane) or real localhost sockets with one OS
//! process per node ([`transport::tcp::TcpTransport`], `--transport
//! tcp`). All simulator semantics (clock charging, counting, selective
//! receive) live here and apply identically over either transport; on
//! TCP the simulated clock keeps running alongside wall-clock, which is
//! exactly what lets `exp calibrate` compare predictions to
//! measurements.

pub mod collectives;
pub mod compress;
pub mod fault;
pub mod model;
pub mod payload;
pub mod topology;
pub mod transport;

pub use compress::Compression;
pub use model::{LinkProfile, NetModel, NetSpec};
pub use payload::{Payload, WireFmt};
pub use transport::{Transport, TransportKind};

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use transport::Arrival;

use crate::util::time::ThreadCpuTimer;

pub type NodeId = usize;

/// Message tags: algorithm phases use distinct tags so selective receive
/// can't mismatch messages that race on the same link.
pub type Tag = u32;

pub mod tags {
    use super::Tag;
    pub const REDUCE: Tag = 1;
    pub const BCAST: Tag = 2;
    pub const PULL_REQ: Tag = 3;
    pub const PULL_RESP: Tag = 4;
    pub const PUSH: Tag = 5;
    pub const CTRL: Tag = 6;
    pub const RING: Tag = 7;
    /// Serving plane: a batched query fan-out from the router to one
    /// replica per feature shard (responses ride [`SERVE_RESP`]).
    pub const QUERY: Tag = 8;
    /// Serving plane: explicit shutdown control frame from the router to
    /// a shard server. A dedicated tag — never a sentinel value inside a
    /// query frame — so shutdown cannot be confused with a query batch
    /// under faulty or reordered delivery.
    pub const SERVE_CTRL: Tag = 9;
    /// Serving plane: one shard replica's partial-margin response for a
    /// query batch, sent straight back to the router (which merges the
    /// per-shard responses in fixed shard order).
    pub const SERVE_RESP: Tag = 10;
    pub const EVAL: Tag = 100;
    /// Session-layer state snapshots (evaluation plane, uncounted): each
    /// node ships its resumable state to the monitor at epoch boundaries.
    pub const STATE: Tag = 101;
}

/// Network cost model (LogP-flavoured):
///
/// * `latency` — wire/switch latency; parallel across links (two messages
///   on different links overlap fully).
/// * `per_msg` — per-message *endpoint* overhead (NIC + kernel stack);
///   serializes at each node, once on send and once on receive. This is
///   what makes a star hub a hot-spot and the paper's Fig.-5 tree faster:
///   the hub must process `q` messages one after another while tree nodes
///   each handle `O(log q)`.
/// * `sec_per_byte` — transfer time per payload **byte** over the link
///   bandwidth; serializes with `per_msg` at the endpoints. Bytes are the
///   canonical unit so compressed wire formats (`f32`, `sparse`) speed the
///   simulated transfer exactly in proportion to the bytes they save.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimParams {
    /// Wire latency in seconds. Default 40 µs (10GbE switch + propagation).
    pub latency: f64,
    /// Per-message endpoint processing. Default 10 µs.
    pub per_msg: f64,
    /// Seconds per payload byte. Default: 10 Gb/s (an 8-byte f64 scalar
    /// costs the same 6.4 ns it did when this field was seconds-per-scalar).
    pub sec_per_byte: f64,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams { latency: 40e-6, per_msg: 10e-6, sec_per_byte: 8.0 / 10e9 }
    }
}

impl SimParams {
    /// Endpoint occupancy of one message (applied on both ends).
    pub fn occupancy(&self, bytes: usize) -> f64 {
        self.per_msg + bytes as f64 * self.sec_per_byte
    }

    /// An idealized zero-cost network (used by equivalence tests where only
    /// the numerics matter).
    pub fn free() -> Self {
        SimParams { latency: 0.0, per_msg: 0.0, sec_per_byte: 0.0 }
    }
}

/// One sender's counters: the canonical byte/message counts plus the
/// derived scalar view.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NodeComm {
    pub scalars: u64,
    pub bytes: u64,
    pub messages: u64,
}

/// One node's simulated-clock state — everything the scheduler needs to
/// resume a node exactly where a previous run left it: the clock itself
/// plus the NIC occupancy horizons that future sends/receives serialize
/// against.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ClockState {
    pub clock: f64,
    pub nic_out: f64,
    pub nic_in: f64,
}

/// Global communication counters (wire bytes, messages and the derived
/// scalar view, per sending node).
#[derive(Debug)]
pub struct CommStats {
    scalars: Vec<AtomicU64>,
    bytes: Vec<AtomicU64>,
    messages: Vec<AtomicU64>,
    /// Real socket bytes per node (counted frames incl. framing; stays 0
    /// on the in-memory transport).
    socket: Vec<AtomicU64>,
}

impl CommStats {
    pub fn new(n_nodes: usize) -> Arc<Self> {
        Arc::new(CommStats {
            scalars: (0..n_nodes).map(|_| AtomicU64::new(0)).collect(),
            bytes: (0..n_nodes).map(|_| AtomicU64::new(0)).collect(),
            messages: (0..n_nodes).map(|_| AtomicU64::new(0)).collect(),
            socket: (0..n_nodes).map(|_| AtomicU64::new(0)).collect(),
        })
    }

    pub fn total_scalars(&self) -> u64 {
        self.scalars.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    pub fn total_messages(&self) -> u64 {
        self.messages.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    pub fn node_scalars(&self, id: NodeId) -> u64 {
        self.scalars[id].load(Ordering::Relaxed)
    }

    pub fn node_bytes(&self, id: NodeId) -> u64 {
        self.bytes[id].load(Ordering::Relaxed)
    }

    pub fn node_messages(&self, id: NodeId) -> u64 {
        self.messages[id].load(Ordering::Relaxed)
    }

    /// Scalars sent by the busiest single node — the paper's argument
    /// against centralized frameworks is about exactly this number.
    pub fn busiest_node_scalars(&self) -> u64 {
        self.scalars.iter().map(|a| a.load(Ordering::Relaxed)).max().unwrap_or(0)
    }

    /// Wire bytes sent by the busiest single node.
    pub fn busiest_node_bytes(&self) -> u64 {
        self.bytes.iter().map(|a| a.load(Ordering::Relaxed)).max().unwrap_or(0)
    }

    /// Per-sender snapshot of all three counters.
    pub fn per_node(&self) -> Vec<NodeComm> {
        (0..self.scalars.len())
            .map(|id| NodeComm {
                scalars: self.node_scalars(id),
                bytes: self.node_bytes(id),
                messages: self.node_messages(id),
            })
            .collect()
    }

    /// Seed the counters from a previous run's per-sender snapshot so a
    /// resumed session's accounting continues exactly where the
    /// checkpointed one stopped. Entries beyond this cluster's node count
    /// are ignored; missing entries stay zero.
    pub fn preload(&self, base: &[NodeComm]) {
        for (i, nc) in base.iter().enumerate().take(self.scalars.len()) {
            self.scalars[i].store(nc.scalars, Ordering::Relaxed);
            self.bytes[i].store(nc.bytes, Ordering::Relaxed);
            self.messages[i].store(nc.messages, Ordering::Relaxed);
        }
    }

    /// Real socket bytes written across all nodes (0 under the sim
    /// transport) — what `exp calibrate` holds against the simulated
    /// byte counters.
    pub fn total_socket_bytes(&self) -> u64 {
        self.socket.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// Store one node's counters absolutely. The TCP path uses this:
    /// each worker process counts its own sends locally and ships the
    /// totals to the monitor at epoch boundaries, so the monitor
    /// overwrites its slot rather than accumulating.
    pub fn set_node(&self, id: NodeId, nc: NodeComm) {
        self.scalars[id].store(nc.scalars, Ordering::Relaxed);
        self.bytes[id].store(nc.bytes, Ordering::Relaxed);
        self.messages[id].store(nc.messages, Ordering::Relaxed);
    }

    /// Store one node's real socket-byte count absolutely (see
    /// [`CommStats::set_node`]).
    pub fn set_node_socket(&self, id: NodeId, socket_bytes: u64) {
        self.socket[id].store(socket_bytes, Ordering::Relaxed);
    }

    fn record(&self, from: NodeId, scalars: usize, bytes: usize) {
        self.scalars[from].fetch_add(scalars as u64, Ordering::Relaxed);
        self.bytes[from].fetch_add(bytes as u64, Ordering::Relaxed);
        self.messages[from].fetch_add(1, Ordering::Relaxed);
    }
}

/// A network message. `send_time` is the sender's simulated clock at the
/// moment of sending; `counted=false` marks evaluation traffic.
pub struct Msg {
    pub from: NodeId,
    pub tag: Tag,
    pub payload: Payload,
    pub send_time: f64,
    /// Sender-drawn extra wire latency (a [`NetModel::Jitter`] network;
    /// exactly 0.0 otherwise), applied at delivery.
    jitter: f64,
    counted: bool,
}

impl Msg {
    /// The seeded extra wire latency charged to this message by a
    /// [`NetModel::Jitter`] network (0 otherwise) — exposed so determinism
    /// tests can pin the noise stream message by message.
    pub fn wire_jitter(&self) -> f64 {
        self.jitter
    }

    /// Logical scalar count of the payload.
    pub fn scalars(&self) -> usize {
        self.payload.scalars()
    }

    /// Decode into a caller-sized buffer (see [`Payload::decode_into`]).
    pub fn decode_into(&self, out: &mut [f64]) {
        self.payload.decode_into(out);
    }

    /// Decode into a fresh vector of logical length `len`.
    pub fn to_vec(&self, len: usize) -> Vec<f64> {
        self.payload.to_vec(len)
    }

    /// Elementwise-add the decoded payload into `out`.
    pub fn add_into(&self, out: &mut [f64]) {
        self.payload.add_into(out);
    }

    /// Read one logical coordinate (control flags and the like).
    pub fn value(&self, i: usize) -> f64 {
        self.payload.value(i)
    }
}

/// One node's handle on the network.
pub struct Endpoint {
    id: NodeId,
    n_nodes: usize,
    /// Where messages physically travel: in-memory mailboxes (sim) or
    /// localhost sockets (tcp). All semantics above this line are
    /// transport-independent.
    transport: Box<dyn Transport>,
    stash: VecDeque<Msg>,
    /// Peers whose link has closed ([`Arrival::Gone`] observed): a
    /// selective receive waiting on one of these fails fast instead of
    /// blocking forever, because per-link FIFO means nothing from a gone
    /// peer can still be in flight (only, possibly, in the stash).
    gone: Vec<bool>,
    /// Simulated clock + NIC occupancy horizons; every mutation goes
    /// through the model layer's charging rules.
    cs: ClockState,
    cpu: ThreadCpuTimer,
    /// This node's charging rules (per-peer links, straggler scales,
    /// jitter stream) — the [`model`] layer's per-node view.
    net: model::LinkView,
    stats: Arc<CommStats>,
    /// Failure-injection hook (the [`fault`] plane). `None` — the
    /// default — short-circuits every fault check, keeping the
    /// failure-free paths bit-exact.
    fault: Option<fault::LinkFaults>,
    /// Modeled-time mode (the serving plane): [`Endpoint::tick`] discards
    /// measured thread CPU instead of charging it, so the simulated clock
    /// moves *only* on deterministic model charges — send/receive
    /// occupancy, [`Endpoint::advance_to`], and explicit
    /// [`Endpoint::charge_modeled`] costs. Training keeps the default
    /// (measured) charging.
    modeled_time: bool,
    /// Cooperative crash mode (the serving plane): injected crashes are
    /// *not* raised as panics inside send/recv; instead the node loop
    /// polls [`Endpoint::take_injected_crash`] at its own protocol
    /// boundaries and exits cleanly, so peers observe an orderly
    /// [`Arrival::Gone`] rather than a whole-cluster unwind.
    fault_cooperative: bool,
}

impl Endpoint {
    /// Build one endpoint over an arbitrary transport. The sim cluster
    /// builds all of its endpoints at once ([`build_with_model`]); a TCP
    /// worker process builds exactly one, over its socket mesh.
    pub fn with_transport(
        id: NodeId,
        n_nodes: usize,
        transport: Box<dyn Transport>,
        model: &NetModel,
        stats: Arc<CommStats>,
    ) -> Endpoint {
        Endpoint {
            id,
            n_nodes,
            transport,
            stash: VecDeque::new(),
            gone: vec![false; n_nodes],
            cs: ClockState::default(),
            cpu: ThreadCpuTimer::start(),
            net: model.node_view(id, n_nodes),
            stats,
            fault: None,
            modeled_time: false,
            fault_cooperative: false,
        }
    }

    /// Install this node's handle on a shared [`fault::FaultPlan`]. Every
    /// counted send/receive from here on consults the plan; endpoints
    /// without a hook stay on the failure-free fast path.
    pub fn install_faults(&mut self, hook: fault::LinkFaults) {
        self.fault = Some(hook);
    }

    /// Install a fault hook in **cooperative crash** mode (the serving
    /// plane). Link faults (drop/dup/reorder/partition) behave exactly as
    /// under [`Endpoint::install_faults`], but a scheduled crash no
    /// longer panics the node from inside send/recv: the node loop polls
    /// [`Endpoint::take_injected_crash`] at its own protocol boundaries
    /// (e.g. between serving batches) and returns cleanly, dropping the
    /// endpoint so peers observe [`Arrival::Gone`] and can fail over.
    /// Crashes therefore latch at the *next polled boundary* after the
    /// scheduled sim-time — deterministic, since the modeled clock is.
    pub fn install_faults_cooperative(&mut self, hook: fault::LinkFaults) {
        self.fault = Some(hook);
        self.fault_cooperative = true;
    }

    /// Cooperative-mode crash poll: if this node's simulated clock has
    /// crossed a scheduled (and still unfired) crash, latch it exactly
    /// once (see [`fault::FaultPlan::crash_due`]) and return its
    /// scheduled time. The caller is expected to stop using the endpoint
    /// and return from its node closure.
    pub fn take_injected_crash(&mut self) -> Option<f64> {
        match self.fault.as_ref() {
            Some(hook) => hook.crash_due(self.cs.clock),
            None => None,
        }
    }

    pub fn id(&self) -> NodeId {
        self.id
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    /// The model's base link parameters (the uniform/rack-local profile).
    pub fn params(&self) -> SimParams {
        self.net.base()
    }

    /// This node's charging view (scenario tests read link profiles and
    /// straggler scales through it).
    pub fn net(&self) -> &model::LinkView {
        &self.net
    }

    pub fn stats(&self) -> &Arc<CommStats> {
        &self.stats
    }

    /// True when peers live in other OS processes (the TCP transport) —
    /// the session layer ships comm counters over the wire in that case.
    pub fn is_remote(&self) -> bool {
        self.transport.is_remote()
    }

    /// Real bytes this node has written to sockets for counted frames,
    /// framing included (0 on the sim transport).
    pub fn socket_bytes(&self) -> u64 {
        self.transport.socket_bytes()
    }

    /// Charge the thread CPU time burned since the last network operation
    /// to this node's simulated clock (through the model — stragglers run
    /// their compute at `factor×`). The lap includes the *foreign* CPU the
    /// deterministic compute pool burned on worker threads on this node's
    /// behalf ([`crate::util::pool::take_foreign_cpu`]): the simulated
    /// clock charges the serial cost of the kernels regardless of
    /// `--threads`, so host parallelism never masquerades as faster
    /// simulated hardware.
    #[inline]
    pub fn tick(&mut self) {
        let lap = self.cpu.lap() + crate::util::pool::take_foreign_cpu();
        if self.modeled_time {
            // Modeled-time mode: host CPU never reaches the simulated
            // clock, so a rerun (or a different `--threads`) produces
            // bit-identical timestamps. The lap is still drained so a
            // later switch back to measured charging starts clean.
            return;
        }
        self.net.charge_compute(&mut self.cs, lap);
    }

    /// Switch this endpoint to modeled time: from here on the simulated
    /// clock is a pure function of model charges (message occupancy,
    /// [`Endpoint::advance_to`], [`Endpoint::charge_modeled`]) — measured
    /// thread CPU is discarded at every [`Endpoint::tick`]. The serving
    /// plane runs in this mode so its latency report is bit-stable across
    /// reruns and host thread counts.
    pub fn set_modeled_time(&mut self, on: bool) {
        self.discard_cpu();
        self.modeled_time = on;
    }

    /// Charge an explicit modeled compute cost (seconds of *serial* work)
    /// through this node's link view, so scenario compute scales (e.g. the
    /// straggler factor) still apply. The deterministic companion of
    /// [`Endpoint::tick`]'s measured charging.
    pub fn charge_modeled(&mut self, secs: f64) {
        self.net.charge_compute(&mut self.cs, secs);
    }

    /// Discard CPU time burned since the last network op (evaluation /
    /// bookkeeping that a real deployment would do off the critical path),
    /// including any pool-worker CPU accumulated in the same window.
    pub fn discard_cpu(&mut self) {
        let _ = self.cpu.lap();
        let _ = crate::util::pool::take_foreign_cpu();
    }

    /// Current simulated time at this node.
    pub fn now(&mut self) -> f64 {
        self.tick();
        self.cs.clock
    }

    /// Force the clock forward (barrier synchronization).
    pub fn advance_to(&mut self, t: f64) {
        if t > self.cs.clock {
            self.cs.clock = t;
        }
    }

    /// Snapshot the full clock state (clock + NIC horizons) for a session
    /// checkpoint. CPU time burned since the last network op is discarded
    /// (snapshots happen on the uncounted evaluation plane).
    pub fn clock_state(&mut self) -> ClockState {
        self.discard_cpu();
        self.cs
    }

    /// Restore a clock state exported by [`Endpoint::clock_state`] so a
    /// resumed node's schedule continues where the checkpointed one
    /// stopped.
    pub fn restore_clock_state(&mut self, cs: ClockState) {
        self.cs = cs;
    }

    /// The jitter stream's PCG state words (None unless the run uses a
    /// [`NetModel::Jitter`] network) — these join the session checkpoint's
    /// per-node records.
    pub fn jitter_words(&self) -> Option<[u64; 4]> {
        self.net.jitter_words()
    }

    /// Restore a checkpointed jitter stream (no-op on jitter-free models
    /// or a `None` snapshot).
    pub fn restore_jitter(&mut self, words: Option<[u64; 4]>) {
        self.net.restore_jitter(words);
    }

    /// Send a payload to node `to`; counts scalars/bytes/messages,
    /// serializes on this node's outgoing NIC (through the model's link
    /// profile to `to`) and stamps the on-the-wire time. `Vec<f64>`
    /// converts implicitly to an exact `f64` payload; codec-encoded
    /// traffic goes through [`collectives::Comm`].
    pub fn send(&mut self, to: NodeId, tag: Tag, payload: impl Into<Payload>) {
        self.tick();
        self.check_injected_crash();
        let payload = payload.into();
        let bytes = payload.wire_bytes();
        self.stats.record(self.id, payload.scalars(), bytes);
        let (mut wire_time, mut jitter) = self.net.charge_send(&mut self.cs, to, bytes);
        if let Some(hook) = self.fault.as_mut() {
            let eff = hook.on_send(to, wire_time);
            let link_latency = self.net.link(to).latency;
            if eff.dropped {
                // The first copy was lost on the wire *after* the NIC was
                // paid ("the sender paid the NIC"). Under the reliable-link
                // model the sender waits out a retransmission timeout of
                // one unacknowledged round trip, then pays the NIC again
                // for the copy that actually arrives.
                let (wt2, j2) = self.net.charge_send(&mut self.cs, to, bytes);
                wire_time = wt2 + 2.0 * link_latency;
                jitter = j2;
            }
            if eff.duplicated {
                // A spurious duplicate occupies the sender's NIC once
                // more; the receiver's reliable layer discards it, so only
                // the sender's outgoing horizon moves.
                let _ = self.net.charge_send(&mut self.cs, to, bytes);
            }
            if eff.reordered {
                // Slow-path routing: one extra wire latency on delivery,
                // enough for a later-sent message to overtake this one.
                // The selective-receive stash absorbs the logical reorder.
                jitter += link_latency;
            }
            if let Some(heal) = eff.hold_until {
                // Partition cut: TCP rides it out — delivery is deferred
                // to the heal time, charged as extra wire latency.
                if heal > wire_time {
                    jitter += heal - wire_time;
                }
            }
        }
        let msg = Msg { from: self.id, tag, payload, send_time: wire_time, jitter, counted: true };
        // A down link means the run is being torn down (e.g. a worker
        // panicked); panicking here unwinds this node too.
        if self.gone[to] || self.transport.send(to, msg).is_err() {
            panic!("node {}: peer {to} disconnected on send (tag {tag})", self.id);
        }
    }

    /// Best-effort counted send for planes that survive peer death (the
    /// serving plane). Identical to [`Endpoint::send`] — counters, NIC
    /// charging, fault-hook effects — except that a dead destination is
    /// *not* a panic: the frame is charged as if transmitted (a real
    /// router pays its NIC before learning the peer is gone) and silently
    /// lost. Crucially the charge/count happens whether or not the peer's
    /// endpoint has physically dropped yet, so the outcome is independent
    /// of host scheduling; the truth about the peer is resolved by the
    /// paired [`Endpoint::recv_from_failable`], which observes
    /// [`Arrival::Gone`] deterministically.
    pub fn send_lossy(&mut self, to: NodeId, tag: Tag, payload: impl Into<Payload>) {
        self.tick();
        self.check_injected_crash();
        let payload = payload.into();
        let bytes = payload.wire_bytes();
        self.stats.record(self.id, payload.scalars(), bytes);
        let (mut wire_time, mut jitter) = self.net.charge_send(&mut self.cs, to, bytes);
        if let Some(hook) = self.fault.as_mut() {
            let eff = hook.on_send(to, wire_time);
            let link_latency = self.net.link(to).latency;
            if eff.dropped {
                let (wt2, j2) = self.net.charge_send(&mut self.cs, to, bytes);
                wire_time = wt2 + 2.0 * link_latency;
                jitter = j2;
            }
            if eff.duplicated {
                let _ = self.net.charge_send(&mut self.cs, to, bytes);
            }
            if eff.reordered {
                jitter += link_latency;
            }
            if let Some(heal) = eff.hold_until {
                if heal > wire_time {
                    jitter += heal - wire_time;
                }
            }
        }
        let msg = Msg { from: self.id, tag, payload, send_time: wire_time, jitter, counted: true };
        // Best-effort delivery: a closed link loses the frame, it does
        // not unwind the sender.
        let _ = self.transport.send(to, msg);
    }

    /// Blocking selective receive that reports a dead peer as a value
    /// instead of a panic: `Err(from)` when `from`'s link has closed and
    /// nothing from it remains in the stash (per-link FIFO guarantees any
    /// message it sent before dying was pulled into the stash before its
    /// [`Arrival::Gone`] was observed). Other peers' deaths are recorded
    /// and tolerated. The serving router's failover path is built on
    /// this.
    pub fn recv_from_failable(&mut self, from: NodeId, tag: Tag) -> Result<Msg, NodeId> {
        self.tick();
        self.check_injected_crash();
        if let Some(pos) = self.stash.iter().position(|m| m.from == from && m.tag == tag) {
            let msg = self.stash.remove(pos).unwrap();
            self.deliver(&msg);
            return Ok(msg);
        }
        if self.gone[from] {
            return Err(from);
        }
        loop {
            match self.transport.recv() {
                // Every link closed at once means the run is tearing down
                // (or every peer died): report the awaited peer as gone
                // rather than unwinding the survivor.
                None => return Err(from),
                Some(Arrival::Gone(peer)) => {
                    self.gone[peer] = true;
                    if peer == from {
                        return Err(from);
                    }
                }
                Some(Arrival::Msg(msg)) => {
                    if msg.from == from && msg.tag == tag {
                        self.deliver(&msg);
                        return Ok(msg);
                    }
                    self.stash.push_back(msg);
                }
            }
        }
    }

    /// Blocking receive of the next message from `from` with **any** tag,
    /// with the same dead-peer-as-value semantics as
    /// [`Endpoint::recv_from_failable`]. Shard servers use this to wait
    /// on the router (queries and control frames share one upstream link)
    /// while tolerating sibling replicas' deaths.
    pub fn recv_from_any_failable(&mut self, from: NodeId) -> Result<Msg, NodeId> {
        self.tick();
        self.check_injected_crash();
        if let Some(pos) = self.stash.iter().position(|m| m.from == from) {
            let msg = self.stash.remove(pos).unwrap();
            self.deliver(&msg);
            return Ok(msg);
        }
        if self.gone[from] {
            return Err(from);
        }
        loop {
            match self.transport.recv() {
                None => return Err(from),
                Some(Arrival::Gone(peer)) => {
                    self.gone[peer] = true;
                    if peer == from {
                        return Err(from);
                    }
                }
                Some(Arrival::Msg(msg)) => {
                    if msg.from == from {
                        self.deliver(&msg);
                        return Ok(msg);
                    }
                    self.stash.push_back(msg);
                }
            }
        }
    }

    /// Modeled wire-arrival time of a received message at this node:
    /// sender's on-the-wire stamp + this link's latency + any seeded or
    /// fault-injected extra latency the sender attached. Independent of
    /// the order this node drained its mailbox in — the serving router
    /// uses it to rank a hedged pair's answers deterministically.
    pub fn wire_arrival(&self, msg: &Msg) -> f64 {
        msg.send_time + self.net.link(msg.from).latency + msg.jitter
    }

    /// Evaluation-plane send: not counted, no clock effect on either side.
    pub fn send_eval(&mut self, to: NodeId, tag: Tag, payload: impl Into<Payload>) {
        self.discard_cpu();
        let msg = Msg {
            from: self.id,
            tag,
            payload: payload.into(),
            send_time: 0.0,
            jitter: 0.0,
            counted: false,
        };
        if self.gone[to] || self.transport.send(to, msg).is_err() {
            panic!("node {}: peer {to} disconnected on eval send (tag {tag})", self.id);
        }
    }

    /// Fault-plane crash check: if this node's simulated clock has crossed
    /// a scheduled (and still unfired) crash, unwind the node. The plan
    /// latches the crash *before* the panic, so the session layer's
    /// recovery path can tell an injected crash from a genuine failure
    /// without parsing panic payloads.
    #[inline]
    fn check_injected_crash(&mut self) {
        if self.fault_cooperative {
            // Serving plane: crashes fire only at the node loop's own
            // `take_injected_crash` polls, never from inside send/recv.
            return;
        }
        if let Some(hook) = self.fault.as_ref() {
            if let Some(t) = hook.crash_due(self.cs.clock) {
                panic!(
                    "node {}: [fault] injected crash at sim-time {t:.6} (clock {:.6})",
                    self.id, self.cs.clock
                );
            }
        }
    }

    /// Names the peers already observed dead, for "all peers disconnected"
    /// panics — so a surviving node's error identifies *who* died even
    /// when it wasn't selectively waiting on them.
    fn dead_peer_note(&self) -> String {
        let dead: Vec<String> = self
            .gone
            .iter()
            .enumerate()
            .filter(|(_, &g)| g)
            .map(|(i, _)| i.to_string())
            .collect();
        if dead.is_empty() {
            String::new()
        } else {
            format!("; dead peers: [{}]", dead.join(", "))
        }
    }

    fn deliver(&mut self, msg: &Msg) {
        if msg.counted {
            self.net.charge_recv(
                &mut self.cs,
                msg.from,
                msg.payload.wire_bytes(),
                msg.send_time,
                msg.jitter,
            );
        }
    }

    /// Record a closed link; panics (unwinding this node) if it belongs
    /// to the peer a selective receive is blocked on — nothing from a
    /// gone peer can still be in flight, so waiting on would hang.
    fn peer_gone(&mut self, peer: NodeId, waiting_on: Option<NodeId>, tag: Tag) {
        self.gone[peer] = true;
        if waiting_on == Some(peer) {
            panic!("node {}: peer {peer} disconnected while receiving (tag {tag})", self.id);
        }
    }

    /// Blocking selective receive: first message matching `from` and `tag`.
    pub fn recv_from(&mut self, from: NodeId, tag: Tag) -> Msg {
        self.tick();
        self.check_injected_crash();
        if let Some(pos) = self.stash.iter().position(|m| m.from == from && m.tag == tag) {
            let msg = self.stash.remove(pos).unwrap();
            self.deliver(&msg);
            return msg;
        }
        if self.gone[from] {
            panic!("node {}: peer {from} disconnected while receiving (tag {tag})", self.id);
        }
        loop {
            match self.transport.recv() {
                None => panic!(
                    "node {}: all peers disconnected while receiving (expected peer {from}, tag {tag}){}",
                    self.id,
                    self.dead_peer_note()
                ),
                Some(Arrival::Gone(peer)) => self.peer_gone(peer, Some(from), tag),
                Some(Arrival::Msg(msg)) => {
                    if msg.from == from && msg.tag == tag {
                        self.deliver(&msg);
                        return msg;
                    }
                    self.stash.push_back(msg);
                }
            }
        }
    }

    /// Blocking receive of any message with the given tag.
    pub fn recv_tag(&mut self, tag: Tag) -> Msg {
        self.tick();
        self.check_injected_crash();
        if let Some(pos) = self.stash.iter().position(|m| m.tag == tag) {
            let msg = self.stash.remove(pos).unwrap();
            self.deliver(&msg);
            return msg;
        }
        loop {
            match self.transport.recv() {
                None => panic!(
                    "node {}: all peers disconnected while receiving (any peer, tag {tag}){}",
                    self.id,
                    self.dead_peer_note()
                ),
                // An any-peer wait may be waiting on exactly the peer that
                // died (a star hub collecting q reduces cannot finish with
                // q−1): fail fast naming the dead node rather than hang.
                Some(Arrival::Gone(peer)) => {
                    self.gone[peer] = true;
                    panic!(
                        "node {}: peer {peer} disconnected while receiving (tag {tag})",
                        self.id
                    );
                }
                Some(Arrival::Msg(msg)) => {
                    if msg.tag == tag {
                        self.deliver(&msg);
                        return msg;
                    }
                    self.stash.push_back(msg);
                }
            }
        }
    }

    /// Blocking receive of any message at all (parameter-server event
    /// loop).
    ///
    /// **Redelivery order guarantee:** the stash is served FIFO, and
    /// *before* any fresh mailbox message — a message returned via
    /// [`Endpoint::stash_back`] is re-observed by the next `recv_any`
    /// ahead of everything that arrived after it. TCP event loops rely
    /// on this: out-of-band traffic parked during an epoch drain must be
    /// reprocessed before new traffic can be misordered past it (pinned
    /// by the `stash_back_redelivers_before_fresh_messages` test).
    pub fn recv_any(&mut self) -> Msg {
        self.tick();
        self.check_injected_crash();
        if let Some(msg) = self.stash.pop_front() {
            self.deliver(&msg);
            return msg;
        }
        loop {
            match self.transport.recv() {
                None => panic!(
                    "node {}: all peers disconnected while receiving (any peer, any tag){}",
                    self.id,
                    self.dead_peer_note()
                ),
                // Event loops (parameter servers) block here for worker
                // traffic that a dead worker can never send — treat the
                // death as fatal, naming the node, instead of hanging
                // (peers never exit mid-epoch in a healthy run: teardown
                // is flagged over the eval plane first).
                Some(Arrival::Gone(peer)) => {
                    self.gone[peer] = true;
                    panic!(
                        "node {}: peer {peer} disconnected while receiving (any peer, any tag)",
                        self.id
                    );
                }
                Some(Arrival::Msg(msg)) => {
                    self.deliver(&msg);
                    return msg;
                }
            }
        }
    }

    /// Return a message to the stash so a later *selective* receive can
    /// claim it. Event loops built on [`Endpoint::recv_any`] use this for
    /// out-of-band traffic (e.g. the session layer's `STATE` snapshots,
    /// which arrive on the evaluation plane while a server is still
    /// draining its epoch): `deliver` already ran, but eval messages are
    /// clock-free, so stashing is side-effect-free.
    ///
    /// Call this only **after** the `recv_any` loop has finished:
    /// `recv_any` serves the stash before the channel, so stashing a
    /// message back while still looping hands the same message straight
    /// back (livelock). Park out-of-band messages in a local buffer for
    /// the duration of the loop instead.
    pub fn stash_back(&mut self, msg: Msg) {
        self.stash.push_back(msg);
    }

    /// Evaluation-plane receive (no clock effect).
    pub fn recv_eval_from(&mut self, from: NodeId, tag: Tag) -> Msg {
        self.discard_cpu();
        if let Some(pos) = self.stash.iter().position(|m| m.from == from && m.tag == tag) {
            return self.stash.remove(pos).unwrap();
        }
        if self.gone[from] {
            panic!("node {}: peer {from} disconnected while receiving (eval tag {tag})", self.id);
        }
        loop {
            match self.transport.recv() {
                None => panic!(
                    "node {}: all peers disconnected while receiving (expected peer {from}, eval tag {tag})",
                    self.id
                ),
                Some(Arrival::Gone(peer)) => {
                    self.gone[peer] = true;
                    if peer == from {
                        panic!(
                            "node {}: peer {from} disconnected while receiving (eval tag {tag})",
                            self.id
                        );
                    }
                }
                Some(Arrival::Msg(msg)) => {
                    if msg.from == from && msg.tag == tag {
                        return msg;
                    }
                    self.stash.push_back(msg);
                }
            }
        }
    }
}

/// Build a fully-connected network of `n_nodes` endpoints under the legacy
/// flat [`SimParams`] — a [`NetModel::Uniform`] network, bit-exact with
/// the pre-model charging.
pub fn build(n_nodes: usize, params: SimParams) -> (Vec<Endpoint>, Arc<CommStats>) {
    build_with_model(n_nodes, &NetModel::Uniform(params))
}

/// Build a fully-connected network of `n_nodes` endpoints, each charging
/// time through its [`model::LinkView`] of `model`, over the in-memory
/// [`transport::SimTransport`] mesh.
pub fn build_with_model(n_nodes: usize, model: &NetModel) -> (Vec<Endpoint>, Arc<CommStats>) {
    let stats = CommStats::new(n_nodes);
    let endpoints = transport::SimTransport::mesh(n_nodes)
        .into_iter()
        .enumerate()
        .map(|(id, t)| Endpoint::with_transport(id, n_nodes, Box::new(t), model, stats.clone()))
        .collect();
    (endpoints, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn send_recv_counts_scalars_bytes_messages() {
        let (mut eps, stats) = build(2, SimParams::default());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let h = thread::spawn(move || {
            a.send(1, tags::CTRL, vec![1.0, 2.0, 3.0]);
        });
        let msg = b.recv_from(0, tags::CTRL);
        h.join().unwrap();
        assert_eq!(msg.to_vec(3), vec![1.0, 2.0, 3.0]);
        assert_eq!(stats.total_scalars(), 3);
        assert_eq!(stats.total_bytes(), 24, "f64 wire: 8 bytes per scalar");
        assert_eq!(stats.total_messages(), 1);
        assert_eq!(stats.node_scalars(0), 3);
        assert_eq!(stats.node_bytes(0), 24);
        assert_eq!(stats.node_messages(0), 1);
        assert_eq!(stats.node_scalars(1), 0);
        let per_node = stats.per_node();
        assert_eq!(per_node[0], NodeComm { scalars: 3, bytes: 24, messages: 1 });
        assert_eq!(per_node[1], NodeComm::default());
    }

    #[test]
    fn compressed_payload_counts_fewer_bytes() {
        let (mut eps, stats) = build(2, SimParams::default());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let h = thread::spawn(move || {
            a.send(1, tags::CTRL, WireFmt::F32.encode(&[1.0, 2.0, 3.0, 4.0]));
        });
        let msg = b.recv_from(0, tags::CTRL);
        h.join().unwrap();
        assert_eq!(msg.to_vec(4), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(stats.total_scalars(), 4, "scalar view is codec-independent");
        assert_eq!(stats.total_bytes(), 16, "f32 wire: 4 bytes per scalar");
    }

    #[test]
    fn receive_applies_latency_and_bandwidth() {
        // 4 f64 scalars = 32 bytes; 0.0625 s/B ⇒ 2 s occupancy per endpoint
        let params = SimParams { latency: 1.0, per_msg: 0.0, sec_per_byte: 0.0625 };
        let (mut eps, _) = build(2, params);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let h = thread::spawn(move || {
            // sender occupancy 32·0.0625=2, wire latency 1, receiver occupancy 2
            a.send(1, tags::CTRL, vec![0.0; 4]);
        });
        b.recv_from(0, tags::CTRL);
        h.join().unwrap();
        let t = b.now();
        assert!(t >= 5.0, "receiver clock {t} should be >= 5.0");
        assert!(t < 5.5, "receiver clock {t} should not include wall noise");
    }

    #[test]
    fn eval_plane_is_free() {
        let (mut eps, stats) =
            build(2, SimParams { latency: 1.0, per_msg: 1.0, sec_per_byte: 1.0 });
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let h = thread::spawn(move || {
            a.send_eval(1, tags::EVAL, vec![0.0; 100]);
        });
        b.recv_eval_from(0, tags::EVAL);
        h.join().unwrap();
        assert_eq!(stats.total_scalars(), 0);
        assert_eq!(stats.total_bytes(), 0);
        assert!(b.now() < 0.5);
    }

    #[test]
    fn selective_receive_reorders() {
        let (mut eps, _) = build(2, SimParams::free());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let h = thread::spawn(move || {
            a.send(1, tags::PUSH, vec![1.0]);
            a.send(1, tags::REDUCE, vec![2.0]);
        });
        // ask for the REDUCE first even though PUSH arrives first
        let m2 = b.recv_from(0, tags::REDUCE);
        let m1 = b.recv_from(0, tags::PUSH);
        h.join().unwrap();
        assert_eq!(m2.to_vec(1), vec![2.0]);
        assert_eq!(m1.to_vec(1), vec![1.0]);
    }

    #[test]
    fn clock_happens_before_chain() {
        // a -> b -> c: c's clock must reflect both hops' latency
        let params = SimParams { latency: 1.0, per_msg: 0.0, sec_per_byte: 0.0 };
        let (eps, _) = build(3, params);
        let mut it = eps.into_iter();
        let mut a = it.next().unwrap();
        let mut b = it.next().unwrap();
        let mut c = it.next().unwrap();
        let ha = thread::spawn(move || a.send(1, tags::CTRL, vec![1.0]));
        let hb = thread::spawn(move || {
            let m = b.recv_from(0, tags::CTRL);
            b.send(2, tags::CTRL, m.to_vec(1));
        });
        let m = c.recv_from(1, tags::CTRL);
        ha.join().unwrap();
        hb.join().unwrap();
        assert_eq!(m.to_vec(1), vec![1.0]);
        assert!(c.now() >= 2.0, "two hops of 1s latency");
    }

    #[test]
    fn busiest_node_tracking() {
        let (mut eps, stats) = build(3, SimParams::free());
        let mut c = eps.pop().unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let h1 = thread::spawn(move || {
            a.send(2, tags::CTRL, vec![0.0; 10]);
            a.send(2, tags::CTRL, vec![0.0; 10]);
        });
        let h2 = thread::spawn(move || b.send(2, tags::CTRL, vec![0.0; 5]));
        for _ in 0..3 {
            c.recv_tag(tags::CTRL);
        }
        h1.join().unwrap();
        h2.join().unwrap();
        assert_eq!(stats.busiest_node_scalars(), 20);
        assert_eq!(stats.busiest_node_bytes(), 160);
        assert_eq!(stats.total_scalars(), 25);
        assert_eq!(stats.total_bytes(), 200);
    }

    #[test]
    fn straggler_nic_slows_the_slow_nodes_messages() {
        // per_msg = 1 s, factor = 4: a send from the straggler costs 4 s of
        // outgoing-NIC occupancy; the (fast) receiver adds its own 1 s.
        let model = NetModel::Straggler {
            base: SimParams { latency: 0.0, per_msg: 1.0, sec_per_byte: 0.0 },
            slow: 1,
            factor: 4.0,
        };
        let (mut eps, _) = build_with_model(2, &model);
        let mut slow = eps.pop().unwrap(); // node 1 = straggler
        let mut fast = eps.pop().unwrap();
        let h = thread::spawn(move || {
            slow.send(0, tags::CTRL, vec![1.0]);
        });
        fast.recv_from(1, tags::CTRL);
        h.join().unwrap();
        let t = fast.now();
        assert!(t >= 5.0, "4 s straggler send + 1 s receive, got {t}");
        assert!(t < 5.5, "no extra charges expected, got {t}");
    }

    #[test]
    fn jitter_messages_carry_seeded_noise() {
        let model = NetModel::Jitter { base: SimParams::free(), amp: 3.0, seed: 21 };
        let collect = || -> Vec<f64> {
            let (mut eps, _) = build_with_model(2, &model);
            let mut b = eps.pop().unwrap();
            let mut a = eps.pop().unwrap();
            let h = thread::spawn(move || {
                for _ in 0..8 {
                    a.send(1, tags::CTRL, vec![1.0]);
                }
            });
            let jits: Vec<f64> = (0..8).map(|_| b.recv_from(0, tags::CTRL).wire_jitter()).collect();
            h.join().unwrap();
            // the noise is charged as wire latency: the receiver clock must
            // cover at least the largest single jitter seen
            let t = b.now();
            let max = jits.iter().cloned().fold(0.0f64, f64::max);
            assert!(t >= max, "clock {t} must include the {max} jitter");
            jits
        };
        let a = collect();
        let b = collect();
        assert_eq!(a, b, "same seed must replay the same noise sequence");
        assert!(a.iter().all(|&j| (0.0..3.0).contains(&j)));
        assert!(a.iter().any(|&j| j > 0.0), "amp 3.0 must actually draw noise");
    }

    #[test]
    fn hetero_cross_rack_latency_applies_per_link() {
        // rack_size 1 ⇒ every pair is cross-rack (1 s latency); the local
        // profile is free, so the whole delay is the cross link's.
        let model = NetModel::Heterogeneous {
            local: SimParams::free(),
            cross: LinkProfile { latency: 1.0, per_msg: 0.0, sec_per_byte: 0.0 },
            rack_size: 1,
        };
        let (mut eps, _) = build_with_model(2, &model);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let h = thread::spawn(move || a.send(1, tags::CTRL, vec![1.0]));
        b.recv_from(0, tags::CTRL);
        h.join().unwrap();
        let t = b.now();
        assert!((1.0..1.5).contains(&t), "one cross-rack hop of 1 s, got {t}");
        // same model, rack_size 2 ⇒ the pair shares a rack, link is free
        let model = NetModel::Heterogeneous {
            local: SimParams::free(),
            cross: LinkProfile { latency: 1.0, per_msg: 0.0, sec_per_byte: 0.0 },
            rack_size: 2,
        };
        let (mut eps, _) = build_with_model(2, &model);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let h = thread::spawn(move || a.send(1, tags::CTRL, vec![1.0]));
        b.recv_from(0, tags::CTRL);
        h.join().unwrap();
        assert!(b.now() < 0.5, "rack-local link must be free");
    }

    #[test]
    fn recv_panic_names_node_peer_and_tag() {
        let (mut eps, _) = build(2, SimParams::free());
        let mut b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        drop(a); // peer 0 goes away before sending anything
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            b.recv_from(0, tags::REDUCE);
        }))
        .unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .expect("panic payload should be a formatted String");
        assert!(
            msg.contains("node 1") && msg.contains("peer 0") && msg.contains("tag 1"),
            "panic message must name receiver, expected peer and tag: {msg}"
        );
    }

    #[test]
    fn send_panic_names_node_peer_and_tag() {
        let (mut eps, _) = build(2, SimParams::free());
        let b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        drop(b);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            a.send(1, tags::PUSH, vec![1.0]);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().expect("formatted String payload");
        assert!(
            msg.contains("node 0") && msg.contains("peer 1") && msg.contains("tag 5"),
            "panic message must name sender, peer and tag: {msg}"
        );
    }

    #[test]
    fn stash_back_redelivers_before_fresh_messages() {
        let (mut eps, _) = build(2, SimParams::free());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let h = thread::spawn(move || {
            a.send(1, tags::PUSH, vec![1.0]);
            a.send(1, tags::PUSH, vec![2.0]);
        });
        let first = b.recv_any();
        assert_eq!(first.value(0), 1.0, "per-sender FIFO");
        b.stash_back(first);
        let again = b.recv_any();
        assert_eq!(again.value(0), 1.0, "stashed message must be re-observed before fresh ones");
        let second = b.recv_any();
        assert_eq!(second.value(0), 2.0);
        h.join().unwrap();
    }

    #[test]
    fn receive_from_early_exited_peer_panics_naming_the_peer() {
        // Node 1 *returns* (no panic) while node 2 still expects its
        // message: the waiter must fail fast with the peer's name, not
        // hang — node 0 is alive, so the mailbox never closes on its own.
        let (mut eps, _) = build(3, SimParams::free());
        let mut c = eps.pop().unwrap();
        let b = eps.pop().unwrap();
        let _a = eps.remove(0);
        drop(b); // node 1 finishes early
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            c.recv_from(1, tags::REDUCE);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().expect("formatted String payload");
        assert!(
            msg.contains("node 2") && msg.contains("peer 1"),
            "panic must name the early-exited peer: {msg}"
        );
    }

    #[test]
    fn unrelated_peer_exit_does_not_disturb_selective_receive() {
        let (mut eps, _) = build(3, SimParams::free());
        let mut c = eps.pop().unwrap();
        let mut b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        drop(a); // node 0 exits; node 2 still expects node 1's message
        let h = thread::spawn(move || b.send(2, tags::REDUCE, vec![5.0]));
        let m = c.recv_from(1, tags::REDUCE);
        assert_eq!(m.to_vec(1), vec![5.0]);
        h.join().unwrap();
    }

    #[test]
    fn sim_transport_reports_zero_socket_bytes() {
        let (mut eps, stats) = build(2, SimParams::free());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        assert!(!a.is_remote());
        let h = thread::spawn(move || a.send(1, tags::CTRL, vec![1.0; 16]));
        b.recv_from(0, tags::CTRL);
        h.join().unwrap();
        assert_eq!(b.socket_bytes(), 0);
        assert_eq!(stats.total_socket_bytes(), 0);
    }
}
