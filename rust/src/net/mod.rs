//! In-process cluster network simulator.
//!
//! Stands in for the paper's testbed (machines on 10GbE). Every simulated
//! node runs on its own OS thread and owns an [`Endpoint`]; endpoints
//! exchange [`Msg`]s over channels. Two things make this a *simulator*
//! rather than just a thread pool:
//!
//! 1. **Exact communication accounting.** Every payload scalar is counted
//!    (a `d`-vector costs `d`, matching the paper's Fig. 7 axis), per
//!    sender, in [`CommStats`]. The counters are what Figure 7 and the
//!    §4.5 complexity table read out, and they are independent of how the
//!    simulation is scheduled.
//! 2. **A simulated clock.** Each node accumulates (a) its own compute,
//!    measured on the per-thread CPU clock so co-scheduled sibling nodes
//!    don't pollute it, and (b) message delays `α + len·β` (latency +
//!    scalar transfer time). A receive advances the receiver to
//!    `max(own_clock, sender_send_time + delay)` — the standard
//!    happens-before rule of a distributed-event simulation. Reported
//!    times are therefore the schedule a real cluster would follow, even
//!    though all nodes share one machine.
//!
//! Evaluation traffic (objective snapshots) uses the `send_eval`/`recv_eval`
//! pair which bypasses both the counters and the clock.

pub mod topology;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::util::time::ThreadCpuTimer;

pub type NodeId = usize;

/// Message tags: algorithm phases use distinct tags so selective receive
/// can't mismatch messages that race on the same link.
pub type Tag = u32;

pub mod tags {
    use super::Tag;
    pub const REDUCE: Tag = 1;
    pub const BCAST: Tag = 2;
    pub const PULL_REQ: Tag = 3;
    pub const PULL_RESP: Tag = 4;
    pub const PUSH: Tag = 5;
    pub const CTRL: Tag = 6;
    pub const RING: Tag = 7;
    pub const EVAL: Tag = 100;
}

/// Network cost model (LogP-flavoured):
///
/// * `latency` — wire/switch latency; parallel across links (two messages
///   on different links overlap fully).
/// * `per_msg` — per-message *endpoint* overhead (NIC + kernel stack);
///   serializes at each node, once on send and once on receive. This is
///   what makes a star hub a hot-spot and the paper's Fig.-5 tree faster:
///   the hub must process `q` messages one after another while tree nodes
///   each handle `O(log q)`.
/// * `sec_per_scalar` — transfer time per payload scalar (8-byte f64 over
///   the link bandwidth); serializes with `per_msg` at the endpoints.
#[derive(Clone, Copy, Debug)]
pub struct SimParams {
    /// Wire latency in seconds. Default 40 µs (10GbE switch + propagation).
    pub latency: f64,
    /// Per-message endpoint processing. Default 10 µs.
    pub per_msg: f64,
    /// Seconds per payload scalar. Default: 8 bytes over 10 Gb/s.
    pub sec_per_scalar: f64,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams { latency: 40e-6, per_msg: 10e-6, sec_per_scalar: 8.0 * 8.0 / 10e9 }
    }
}

impl SimParams {
    /// Endpoint occupancy of one message (applied on both ends).
    pub fn occupancy(&self, scalars: usize) -> f64 {
        self.per_msg + scalars as f64 * self.sec_per_scalar
    }

    /// An idealized zero-cost network (used by equivalence tests where only
    /// the numerics matter).
    pub fn free() -> Self {
        SimParams { latency: 0.0, per_msg: 0.0, sec_per_scalar: 0.0 }
    }
}

/// Global communication counters (scalars & messages per sending node).
#[derive(Debug)]
pub struct CommStats {
    scalars: Vec<AtomicU64>,
    messages: Vec<AtomicU64>,
}

impl CommStats {
    pub fn new(n_nodes: usize) -> Arc<Self> {
        Arc::new(CommStats {
            scalars: (0..n_nodes).map(|_| AtomicU64::new(0)).collect(),
            messages: (0..n_nodes).map(|_| AtomicU64::new(0)).collect(),
        })
    }

    pub fn total_scalars(&self) -> u64 {
        self.scalars.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    pub fn total_messages(&self) -> u64 {
        self.messages.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    pub fn node_scalars(&self, id: NodeId) -> u64 {
        self.scalars[id].load(Ordering::Relaxed)
    }

    /// Scalars sent by the busiest single node — the paper's argument
    /// against centralized frameworks is about exactly this number.
    pub fn busiest_node_scalars(&self) -> u64 {
        self.scalars.iter().map(|a| a.load(Ordering::Relaxed)).max().unwrap_or(0)
    }

    fn record(&self, from: NodeId, scalars: usize) {
        self.scalars[from].fetch_add(scalars as u64, Ordering::Relaxed);
        self.messages[from].fetch_add(1, Ordering::Relaxed);
    }
}

/// A network message. `send_time` is the sender's simulated clock at the
/// moment of sending; `counted=false` marks evaluation traffic.
pub struct Msg {
    pub from: NodeId,
    pub tag: Tag,
    pub data: Vec<f64>,
    pub send_time: f64,
    counted: bool,
}

/// One node's handle on the network.
pub struct Endpoint {
    id: NodeId,
    n_nodes: usize,
    senders: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    stash: VecDeque<Msg>,
    clock: f64,
    /// NIC occupancy horizons: outgoing/incoming messages serialize here.
    nic_out: f64,
    nic_in: f64,
    cpu: ThreadCpuTimer,
    params: SimParams,
    stats: Arc<CommStats>,
}

impl Endpoint {
    pub fn id(&self) -> NodeId {
        self.id
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    pub fn params(&self) -> SimParams {
        self.params
    }

    pub fn stats(&self) -> &Arc<CommStats> {
        &self.stats
    }

    /// Charge the thread CPU time burned since the last network operation
    /// to this node's simulated clock.
    #[inline]
    pub fn tick(&mut self) {
        self.clock += self.cpu.lap();
    }

    /// Discard CPU time burned since the last network op (evaluation /
    /// bookkeeping that a real deployment would do off the critical path).
    pub fn discard_cpu(&mut self) {
        let _ = self.cpu.lap();
    }

    /// Current simulated time at this node.
    pub fn now(&mut self) -> f64 {
        self.tick();
        self.clock
    }

    /// Force the clock forward (barrier synchronization).
    pub fn advance_to(&mut self, t: f64) {
        if t > self.clock {
            self.clock = t;
        }
    }

    /// Send `data` to node `to`; counts scalars, serializes on this node's
    /// outgoing NIC and stamps the on-the-wire time.
    pub fn send(&mut self, to: NodeId, tag: Tag, data: Vec<f64>) {
        self.tick();
        self.stats.record(self.id, data.len());
        let wire_time = self.clock.max(self.nic_out) + self.params.occupancy(data.len());
        self.nic_out = wire_time;
        let msg = Msg { from: self.id, tag, data, send_time: wire_time, counted: true };
        // A disconnected peer means the run is being torn down (e.g. a
        // worker panicked); panicking here unwinds this node too.
        self.senders[to].send(msg).expect("peer endpoint disconnected");
    }

    /// Evaluation-plane send: not counted, no clock effect on either side.
    pub fn send_eval(&mut self, to: NodeId, tag: Tag, data: Vec<f64>) {
        self.discard_cpu();
        let msg = Msg { from: self.id, tag, data, send_time: 0.0, counted: false };
        self.senders[to].send(msg).expect("peer endpoint disconnected");
    }

    fn deliver(&mut self, msg: &Msg) {
        if msg.counted {
            let at_nic = msg.send_time + self.params.latency;
            let done = at_nic.max(self.nic_in) + self.params.occupancy(msg.data.len());
            self.nic_in = done;
            if done > self.clock {
                self.clock = done;
            }
        }
    }

    /// Blocking selective receive: first message matching `from` and `tag`.
    pub fn recv_from(&mut self, from: NodeId, tag: Tag) -> Msg {
        self.tick();
        if let Some(pos) = self.stash.iter().position(|m| m.from == from && m.tag == tag) {
            let msg = self.stash.remove(pos).unwrap();
            self.deliver(&msg);
            return msg;
        }
        loop {
            let msg = self.rx.recv().expect("all peers disconnected while receiving");
            if msg.from == from && msg.tag == tag {
                self.deliver(&msg);
                return msg;
            }
            self.stash.push_back(msg);
        }
    }

    /// Blocking receive of any message with the given tag.
    pub fn recv_tag(&mut self, tag: Tag) -> Msg {
        self.tick();
        if let Some(pos) = self.stash.iter().position(|m| m.tag == tag) {
            let msg = self.stash.remove(pos).unwrap();
            self.deliver(&msg);
            return msg;
        }
        loop {
            let msg = self.rx.recv().expect("all peers disconnected while receiving");
            if msg.tag == tag {
                self.deliver(&msg);
                return msg;
            }
            self.stash.push_back(msg);
        }
    }

    /// Blocking receive of any message at all (parameter-server event loop).
    pub fn recv_any(&mut self) -> Msg {
        self.tick();
        if let Some(msg) = self.stash.pop_front() {
            self.deliver(&msg);
            return msg;
        }
        let msg = self.rx.recv().expect("all peers disconnected while receiving");
        self.deliver(&msg);
        msg
    }

    /// Evaluation-plane receive (no clock effect).
    pub fn recv_eval_from(&mut self, from: NodeId, tag: Tag) -> Msg {
        self.discard_cpu();
        if let Some(pos) = self.stash.iter().position(|m| m.from == from && m.tag == tag) {
            return self.stash.remove(pos).unwrap();
        }
        loop {
            let msg = self.rx.recv().expect("all peers disconnected while receiving");
            if msg.from == from && msg.tag == tag {
                return msg;
            }
            self.stash.push_back(msg);
        }
    }
}

/// Build a fully-connected network of `n_nodes` endpoints.
pub fn build(n_nodes: usize, params: SimParams) -> (Vec<Endpoint>, Arc<CommStats>) {
    let stats = CommStats::new(n_nodes);
    let mut txs = Vec::with_capacity(n_nodes);
    let mut rxs = Vec::with_capacity(n_nodes);
    for _ in 0..n_nodes {
        let (tx, rx) = channel::<Msg>();
        txs.push(tx);
        rxs.push(rx);
    }
    let endpoints = rxs
        .into_iter()
        .enumerate()
        .map(|(id, rx)| {
            let mut senders = txs.clone();
            // Replace the self-sender with a disconnected one: nodes never
            // send to themselves, and holding a live self-sender would keep
            // a node's own receive channel open forever — turning a peer
            // panic into a deadlock instead of a clean cascade failure.
            let (dead_tx, _) = channel::<Msg>();
            senders[id] = dead_tx;
            Endpoint {
                id,
                n_nodes,
                senders,
                rx,
                stash: VecDeque::new(),
                clock: 0.0,
                nic_out: 0.0,
                nic_in: 0.0,
                cpu: ThreadCpuTimer::start(),
                params,
                stats: stats.clone(),
            }
        })
        .collect();
    (endpoints, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn send_recv_counts_scalars() {
        let (mut eps, stats) = build(2, SimParams::default());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let h = thread::spawn(move || {
            a.send(1, tags::CTRL, vec![1.0, 2.0, 3.0]);
        });
        let msg = b.recv_from(0, tags::CTRL);
        h.join().unwrap();
        assert_eq!(msg.data, vec![1.0, 2.0, 3.0]);
        assert_eq!(stats.total_scalars(), 3);
        assert_eq!(stats.total_messages(), 1);
        assert_eq!(stats.node_scalars(0), 3);
        assert_eq!(stats.node_scalars(1), 0);
    }

    #[test]
    fn receive_applies_latency_and_bandwidth() {
        let params = SimParams { latency: 1.0, per_msg: 0.0, sec_per_scalar: 0.5 };
        let (mut eps, _) = build(2, params);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let h = thread::spawn(move || {
            // sender occupancy 4*0.5=2, wire latency 1, receiver occupancy 2
            a.send(1, tags::CTRL, vec![0.0; 4]);
        });
        b.recv_from(0, tags::CTRL);
        h.join().unwrap();
        let t = b.now();
        assert!(t >= 5.0, "receiver clock {t} should be >= 5.0");
        assert!(t < 5.5, "receiver clock {t} should not include wall noise");
    }

    #[test]
    fn eval_plane_is_free() {
        let (mut eps, stats) = build(2, SimParams { latency: 1.0, per_msg: 1.0, sec_per_scalar: 1.0 });
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let h = thread::spawn(move || {
            a.send_eval(1, tags::EVAL, vec![0.0; 100]);
        });
        b.recv_eval_from(0, tags::EVAL);
        h.join().unwrap();
        assert_eq!(stats.total_scalars(), 0);
        assert!(b.now() < 0.5);
    }

    #[test]
    fn selective_receive_reorders() {
        let (mut eps, _) = build(2, SimParams::free());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let h = thread::spawn(move || {
            a.send(1, tags::PUSH, vec![1.0]);
            a.send(1, tags::REDUCE, vec![2.0]);
        });
        // ask for the REDUCE first even though PUSH arrives first
        let m2 = b.recv_from(0, tags::REDUCE);
        let m1 = b.recv_from(0, tags::PUSH);
        h.join().unwrap();
        assert_eq!(m2.data, vec![2.0]);
        assert_eq!(m1.data, vec![1.0]);
    }

    #[test]
    fn clock_happens_before_chain() {
        // a -> b -> c: c's clock must reflect both hops' latency
        let params = SimParams { latency: 1.0, per_msg: 0.0, sec_per_scalar: 0.0 };
        let (eps, _) = build(3, params);
        let mut it = eps.into_iter();
        let mut a = it.next().unwrap();
        let mut b = it.next().unwrap();
        let mut c = it.next().unwrap();
        let ha = thread::spawn(move || a.send(1, tags::CTRL, vec![1.0]));
        let hb = thread::spawn(move || {
            let m = b.recv_from(0, tags::CTRL);
            b.send(2, tags::CTRL, m.data);
        });
        let m = c.recv_from(1, tags::CTRL);
        ha.join().unwrap();
        hb.join().unwrap();
        assert_eq!(m.data, vec![1.0]);
        assert!(c.now() >= 2.0, "two hops of 1s latency");
    }

    #[test]
    fn busiest_node_tracking() {
        let (mut eps, stats) = build(3, SimParams::free());
        let mut c = eps.pop().unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let h1 = thread::spawn(move || {
            a.send(2, tags::CTRL, vec![0.0; 10]);
            a.send(2, tags::CTRL, vec![0.0; 10]);
        });
        let h2 = thread::spawn(move || b.send(2, tags::CTRL, vec![0.0; 5]));
        for _ in 0..3 {
            c.recv_tag(tags::CTRL);
        }
        h1.join().unwrap();
        h2.join().unwrap();
        assert_eq!(stats.busiest_node_scalars(), 20);
        assert_eq!(stats.total_scalars(), 25);
    }
}
