//! Pluggable network timing models — the scenario layer of the simulator.
//!
//! The wire layer ([`crate::net::payload`]) decides *what* a message costs
//! in bytes; this module decides *how long* those bytes take. A
//! [`NetModel`] describes the whole cluster's timing plane and hands every
//! node a [`LinkView`] — the per-node charging rules the [`super::Endpoint`]
//! routes all time accounting through (compute ticks, sender-NIC
//! serialization, wire latency, receiver-NIC serialization). Four models
//! ship:
//!
//! | model | scenario | parameters |
//! |-------|----------|------------|
//! | [`NetModel::Uniform`] | the legacy single-[`SimParams`] network; **bit-exact** with the pre-model charging (the equivalence/comm suites pin it) | base `SimParams` |
//! | [`NetModel::Heterogeneous`] | rack-structured clusters: rack-local links vs slower cross-rack links | local `SimParams`, cross [`LinkProfile`], `rack_size` |
//! | [`NetModel::Straggler`] | `slow` designated slow nodes (the highest node ids — workers in every topology) running compute *and* NIC at `factor×` the time | base `SimParams`, `slow`, `factor` |
//! | [`NetModel::Jitter`] | per-message wire-latency noise, drawn from a dedicated seeded PCG stream per sender — fully deterministic under a seed, checkpoint/resumable | base `SimParams`, `amp`, `seed` |
//!
//! Configuration flows as a [`NetSpec`] — a base-free scenario overlay
//! carried by [`crate::algs::RunParams`] (CLI `--net`, config table
//! `net.*`) and resolved against the run's base `SimParams` by
//! [`NetSpec::resolve`], so every existing `RunParams { sim, .. }` call
//! site keeps meaning what it meant (the default overlay is `Uniform`).
//!
//! **Bit-exactness of `Uniform`.** The charging formulas below are the
//! legacy `Endpoint` formulas with a multiplicative NIC/compute scale and
//! an additive jitter term. Under `Uniform` the scales are exactly `1.0`
//! and the jitter is exactly `+0.0`; IEEE-754 guarantees `x * 1.0 == x`
//! and `x + 0.0 == x` bit-for-bit for every non-negative finite `x`, so
//! the refactor cannot perturb a single clock bit
//! (`rust/tests/net_model.rs` pins this against a reference
//! implementation of the legacy formulas).

use super::{ClockState, NodeId, SimParams};
use crate::util::Pcg64;

/// One link's LogP cost parameters — the same three axes as [`SimParams`]
/// (wire latency, per-message endpoint overhead, seconds per payload
/// byte), but scoped to a single node pair instead of the whole cluster.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkProfile {
    /// Wire/switch latency in seconds (parallel across links).
    pub latency: f64,
    /// Per-message endpoint processing (serializes at each NIC).
    pub per_msg: f64,
    /// Transfer seconds per payload byte.
    pub sec_per_byte: f64,
}

impl LinkProfile {
    /// Endpoint occupancy of one message over this link.
    #[inline]
    pub fn occupancy(&self, bytes: usize) -> f64 {
        self.per_msg + bytes as f64 * self.sec_per_byte
    }

    /// A zero-cost link.
    pub fn free() -> LinkProfile {
        LinkProfile { latency: 0.0, per_msg: 0.0, sec_per_byte: 0.0 }
    }
}

impl From<SimParams> for LinkProfile {
    fn from(sp: SimParams) -> LinkProfile {
        LinkProfile { latency: sp.latency, per_msg: sp.per_msg, sec_per_byte: sp.sec_per_byte }
    }
}

/// Seeded per-message latency-noise stream (one per sender node). Draws
/// are uniform in `[0, amp)` from a dedicated PCG stream, so a run is a
/// pure function of the seed, and the stream's state words join the
/// checkpoint's per-node records so a mid-run resume replays the exact
/// same noise tail.
#[derive(Clone, Debug)]
pub struct JitterStream {
    rng: Pcg64,
    amp: f64,
}

impl JitterStream {
    /// Draw the next message's extra wire latency.
    #[inline]
    pub fn draw(&mut self) -> f64 {
        self.amp * self.rng.next_f64()
    }
}

/// Derive the per-node jitter stream from the scenario seed: splitmix-style
/// spread of the node id so streams don't correlate across nodes.
fn node_stream(seed: u64, id: NodeId) -> Pcg64 {
    Pcg64::seed_from_u64(seed ^ (id as u64).wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// A whole cluster's network timing model. Build one per run (usually via
/// [`NetSpec::resolve`]) and hand each endpoint its charging rules with
/// [`NetModel::node_view`].
#[derive(Clone, Debug, PartialEq)]
pub enum NetModel {
    /// Identical LogP parameters on every link — re-expresses the legacy
    /// flat `SimParams` network bit-exactly.
    Uniform(SimParams),
    /// Rack-structured heterogeneity: nodes are grouped into racks of
    /// `rack_size` consecutive ids; links within a rack use `local`,
    /// links across racks use `cross` (typically higher latency, lower
    /// bandwidth).
    Heterogeneous {
        local: SimParams,
        cross: LinkProfile,
        rack_size: usize,
    },
    /// `slow` designated slow nodes — the **highest** node ids, which are
    /// workers in every topology this crate ships (node 0 is always the
    /// coordinator/monitor; `slow` is clamped to `n_nodes − 1` so the
    /// monitor never straggles) — run both compute and NIC occupancy at
    /// `factor×` the time.
    Straggler { base: SimParams, slow: usize, factor: f64 },
    /// Uniform links plus seeded per-message wire-latency noise in
    /// `[0, amp)`, drawn sender-side from a per-node PCG stream. Fully
    /// deterministic under `seed`, including across checkpoint/resume.
    Jitter { base: SimParams, amp: f64, seed: u64 },
}

impl NetModel {
    /// Scenario name (`uniform`/`hetero`/`straggler`/`jitter`).
    pub fn name(&self) -> &'static str {
        match self {
            NetModel::Uniform(_) => "uniform",
            NetModel::Heterogeneous { .. } => "hetero",
            NetModel::Straggler { .. } => "straggler",
            NetModel::Jitter { .. } => "jitter",
        }
    }

    /// The base link parameters (what [`super::Endpoint::params`] reports).
    pub fn base(&self) -> SimParams {
        match self {
            NetModel::Uniform(sp) => *sp,
            NetModel::Heterogeneous { local, .. } => *local,
            NetModel::Straggler { base, .. } | NetModel::Jitter { base, .. } => *base,
        }
    }

    /// The charging view of node `id` in an `n_nodes` cluster: its link
    /// profile to every peer, its compute/NIC scale, and (under `Jitter`)
    /// its seeded noise stream.
    pub fn node_view(&self, id: NodeId, n_nodes: usize) -> LinkView {
        let base = self.base();
        match self {
            NetModel::Uniform(sp) => LinkView {
                base,
                links: vec![LinkProfile::from(*sp); n_nodes],
                compute_scale: 1.0,
                nic_scale: 1.0,
                jitter: None,
            },
            NetModel::Heterogeneous { local, cross, rack_size } => {
                let rs = (*rack_size).max(1);
                let links = (0..n_nodes)
                    .map(|peer| {
                        if peer / rs == id / rs {
                            LinkProfile::from(*local)
                        } else {
                            *cross
                        }
                    })
                    .collect();
                LinkView { base, links, compute_scale: 1.0, nic_scale: 1.0, jitter: None }
            }
            NetModel::Straggler { base: sp, slow, factor } => {
                // clamp to n_nodes − 1: stragglers are always workers, the
                // monitor (node 0) never slows down
                let k = (*slow).min(n_nodes.saturating_sub(1));
                let scale = if id >= n_nodes - k { *factor } else { 1.0 };
                LinkView {
                    base,
                    links: vec![LinkProfile::from(*sp); n_nodes],
                    compute_scale: scale,
                    nic_scale: scale,
                    jitter: None,
                }
            }
            NetModel::Jitter { base: sp, amp, seed } => LinkView {
                base,
                links: vec![LinkProfile::from(*sp); n_nodes],
                compute_scale: 1.0,
                nic_scale: 1.0,
                jitter: Some(JitterStream { rng: node_stream(*seed, id), amp: *amp }),
            },
        }
    }
}

/// Config-level scenario selector (`--net uniform|hetero|straggler|jitter`
/// plus the `net.*` scenario table): a *base-free* overlay carried by
/// [`crate::algs::RunParams`] and resolved against the run's base
/// `SimParams`, so the legacy `sim` field keeps its meaning under every
/// scenario (it is the rack-local / non-straggler / noise-free link).
#[derive(Clone, Debug, Default, PartialEq)]
pub enum NetSpec {
    /// The legacy single-`SimParams` network (default; bit-exact).
    #[default]
    Uniform,
    /// Rack-local links use the base `SimParams`; cross-rack links use
    /// `cross`.
    Hetero { cross: LinkProfile, rack_size: usize },
    /// The `slow` highest-id nodes run compute and NIC at `factor×`.
    Straggler { slow: usize, factor: f64 },
    /// Seeded per-message latency noise in `[0, amp)`.
    Jitter { amp: f64, seed: u64 },
}

impl NetSpec {
    /// Every scenario kind, for CLI parsing and error listings.
    pub const KINDS: [&'static str; 4] = ["uniform", "hetero", "straggler", "jitter"];

    /// Scenario name of this spec.
    pub fn name(&self) -> &'static str {
        match self {
            NetSpec::Uniform => "uniform",
            NetSpec::Hetero { .. } => "hetero",
            NetSpec::Straggler { .. } => "straggler",
            NetSpec::Jitter { .. } => "jitter",
        }
    }

    /// Resolve the overlay against the run's base link parameters.
    pub fn resolve(&self, base: SimParams) -> NetModel {
        match self {
            NetSpec::Uniform => NetModel::Uniform(base),
            NetSpec::Hetero { cross, rack_size } => NetModel::Heterogeneous {
                local: base,
                cross: *cross,
                rack_size: *rack_size,
            },
            NetSpec::Straggler { slow, factor } => {
                NetModel::Straggler { base, slow: *slow, factor: *factor }
            }
            NetSpec::Jitter { amp, seed } => NetModel::Jitter { base, amp: *amp, seed: *seed },
        }
    }
}

/// One node's charging rules — everything the [`super::Endpoint`] needs to
/// turn an event (compute lap, send, receive) into simulated time. All
/// time-charging formulas of the simulator live in the three `charge_*`
/// methods; the endpoint owns the [`ClockState`] and routes every event
/// through here.
#[derive(Clone, Debug)]
pub struct LinkView {
    base: SimParams,
    /// This node's link profile to each peer (symmetric; own entry unused).
    links: Vec<LinkProfile>,
    /// Multiplier on measured compute time (stragglers run slow).
    compute_scale: f64,
    /// Multiplier on this node's NIC occupancy, send and receive side.
    nic_scale: f64,
    jitter: Option<JitterStream>,
}

impl LinkView {
    /// The base (`SimParams`) link parameters of the model.
    pub fn base(&self) -> SimParams {
        self.base
    }

    /// This node's link profile to `peer`.
    pub fn link(&self, peer: NodeId) -> LinkProfile {
        self.links[peer]
    }

    /// This node's compute-time multiplier (1.0 unless it is a straggler).
    pub fn compute_scale(&self) -> f64 {
        self.compute_scale
    }

    /// Charge `cpu` seconds of measured compute to the clock.
    #[inline]
    pub fn charge_compute(&self, cs: &mut ClockState, cpu: f64) {
        cs.clock += cpu * self.compute_scale;
    }

    /// Sender-side charge of one counted message to `to`: serializes on
    /// the outgoing NIC and returns `(wire timestamp, wire jitter)` — the
    /// jitter is drawn here (sender side) so the noise sequence is a pure
    /// function of this node's send sequence, and travels with the message
    /// to be applied as extra wire latency at the receiver.
    #[inline]
    pub fn charge_send(&mut self, cs: &mut ClockState, to: NodeId, bytes: usize) -> (f64, f64) {
        let occ = self.links[to].occupancy(bytes) * self.nic_scale;
        let wire_time = cs.clock.max(cs.nic_out) + occ;
        cs.nic_out = wire_time;
        let jitter = match &mut self.jitter {
            Some(j) => j.draw(),
            None => 0.0,
        };
        (wire_time, jitter)
    }

    /// Receiver-side charge of one counted message from `from`: wire
    /// latency (+ the sender-drawn jitter), then serialization on the
    /// incoming NIC; advances the clock per the happens-before rule.
    #[inline]
    pub fn charge_recv(
        &self,
        cs: &mut ClockState,
        from: NodeId,
        bytes: usize,
        send_time: f64,
        jitter: f64,
    ) {
        let link = &self.links[from];
        let at_nic = send_time + link.latency + jitter;
        let done = at_nic.max(cs.nic_in) + link.occupancy(bytes) * self.nic_scale;
        cs.nic_in = done;
        if done > cs.clock {
            cs.clock = done;
        }
    }

    /// The jitter stream's PCG state words (None unless this is a
    /// [`NetModel::Jitter`] view) — joins the checkpoint's per-node
    /// records so a resume continues the exact noise sequence.
    pub fn jitter_words(&self) -> Option<[u64; 4]> {
        self.jitter.as_ref().map(|j| j.rng.state_words())
    }

    /// Restore a checkpointed jitter stream. A `None` (checkpoint taken
    /// under a jitter-free model) leaves the freshly-seeded stream in
    /// place; restoring onto a jitter-free view is a no-op.
    pub fn restore_jitter(&mut self, words: Option<[u64; 4]>) {
        if let (Some(j), Some(w)) = (self.jitter.as_mut(), words) {
            j.rng = Pcg64::from_state_words(w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> SimParams {
        SimParams { latency: 1e-3, per_msg: 1e-4, sec_per_byte: 1e-8 }
    }

    #[test]
    fn uniform_view_has_identity_scales_and_equal_links() {
        let model = NetModel::Uniform(base());
        for id in 0..4 {
            let v = model.node_view(id, 4);
            assert_eq!(v.compute_scale(), 1.0);
            assert_eq!(v.nic_scale, 1.0);
            assert!(v.jitter.is_none());
            for peer in 0..4 {
                assert_eq!(v.link(peer), LinkProfile::from(base()));
            }
        }
    }

    #[test]
    fn straggler_marks_the_highest_ids() {
        let model = NetModel::Straggler { base: base(), slow: 2, factor: 8.0 };
        let scales: Vec<f64> = (0..5).map(|id| model.node_view(id, 5).compute_scale()).collect();
        assert_eq!(scales, vec![1.0, 1.0, 1.0, 8.0, 8.0]);
        // slow count clamps to n_nodes − 1: the monitor (node 0) never slows
        let all = NetModel::Straggler { base: base(), slow: 99, factor: 2.0 };
        let scales: Vec<f64> = (0..3).map(|id| all.node_view(id, 3).compute_scale()).collect();
        assert_eq!(scales, vec![1.0, 2.0, 2.0]);
    }

    #[test]
    fn hetero_links_split_by_rack() {
        let cross = LinkProfile { latency: 0.5, per_msg: 0.0, sec_per_byte: 0.0 };
        let model = NetModel::Heterogeneous { local: base(), cross, rack_size: 2 };
        // nodes 0,1 are rack 0; nodes 2,3 rack 1
        let v = model.node_view(0, 4);
        assert_eq!(v.link(1), LinkProfile::from(base()), "rack-local link");
        assert_eq!(v.link(2), cross, "cross-rack link");
        assert_eq!(v.link(3), cross);
        let v3 = model.node_view(3, 4);
        assert_eq!(v3.link(2), LinkProfile::from(base()));
        assert_eq!(v3.link(0), cross);
    }

    #[test]
    fn jitter_streams_are_per_node_and_seed_deterministic() {
        let model = NetModel::Jitter { base: base(), amp: 2.0, seed: 7 };
        let draw5 = |id: NodeId| -> Vec<f64> {
            let mut v = model.node_view(id, 3);
            let mut cs = ClockState::default();
            (0..5).map(|_| v.charge_send(&mut cs, (id + 1) % 3, 8).1).collect()
        };
        let a = draw5(0);
        assert_eq!(a, draw5(0), "same seed + node must replay the sequence");
        assert_ne!(a, draw5(1), "nodes must not share a stream");
        assert!(a.iter().all(|&j| (0.0..2.0).contains(&j)));
        assert!(a.iter().any(|&j| j > 0.0));
        let other = NetModel::Jitter { base: base(), amp: 2.0, seed: 8 };
        let mut v = other.node_view(0, 3);
        let mut cs = ClockState::default();
        let b: Vec<f64> = (0..5).map(|_| v.charge_send(&mut cs, 1, 8).1).collect();
        assert_ne!(a, b, "different seeds must differ");
    }

    #[test]
    fn jitter_words_round_trip_continues_the_stream() {
        let model = NetModel::Jitter { base: base(), amp: 1.0, seed: 11 };
        let mut v = model.node_view(2, 4);
        let mut cs = ClockState::default();
        for _ in 0..7 {
            v.charge_send(&mut cs, 0, 100);
        }
        let words = v.jitter_words().expect("jitter view exports its stream");
        let mut fresh = model.node_view(2, 4);
        fresh.restore_jitter(Some(words));
        let mut cs2 = ClockState::default();
        for _ in 0..10 {
            let a = v.charge_send(&mut cs, 0, 8).1;
            let b = fresh.charge_send(&mut cs2, 0, 8).1;
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // restoring onto a jitter-free view is a no-op; None leaves the
        // fresh stream in place
        let mut uni = NetModel::Uniform(base()).node_view(0, 2);
        uni.restore_jitter(Some(words));
        assert!(uni.jitter_words().is_none());
    }

    #[test]
    fn netspec_resolves_against_the_base_params() {
        let sp = base();
        assert_eq!(NetSpec::Uniform.resolve(sp), NetModel::Uniform(sp));
        let spec = NetSpec::Straggler { slow: 1, factor: 3.0 };
        assert_eq!(spec.resolve(sp), NetModel::Straggler { base: sp, slow: 1, factor: 3.0 });
        assert_eq!(spec.name(), "straggler");
        assert_eq!(NetSpec::default(), NetSpec::Uniform);
        for kind in NetSpec::KINDS {
            assert!(!kind.is_empty());
        }
    }

    #[test]
    fn charge_math_reproduces_the_documented_example() {
        // 4 f64 scalars = 32 bytes at 0.0625 s/B ⇒ 2 s occupancy/side,
        // 1 s latency (the example from the net module docs)
        let sp = SimParams { latency: 1.0, per_msg: 0.0, sec_per_byte: 0.0625 };
        let model = NetModel::Uniform(sp);
        let mut tx = model.node_view(0, 2);
        let rx = model.node_view(1, 2);
        let mut cs0 = ClockState::default();
        let mut cs1 = ClockState::default();
        let (wire, jit) = tx.charge_send(&mut cs0, 1, 32);
        assert_eq!(wire, 2.0);
        assert_eq!(jit, 0.0);
        rx.charge_recv(&mut cs1, 0, 32, wire, jit);
        assert_eq!(cs1.clock, 5.0); // 2 (send occ) + 1 (latency) + 2 (recv occ)
        assert_eq!(cs1.nic_in, 5.0);
    }

    #[test]
    fn straggler_scales_both_compute_and_nic() {
        let sp = SimParams { latency: 0.0, per_msg: 1.0, sec_per_byte: 0.0 };
        let model = NetModel::Straggler { base: sp, slow: 1, factor: 4.0 };
        let mut slow = model.node_view(1, 2);
        let mut cs = ClockState::default();
        slow.charge_compute(&mut cs, 1.0);
        assert_eq!(cs.clock, 4.0, "compute runs 4x slow");
        let (wire, _) = slow.charge_send(&mut cs, 0, 0);
        assert_eq!(wire, 8.0, "NIC occupancy 4x on top of the 4s clock");
    }
}
