//! Topology helpers and exact-`f64` views of the collectives.
//!
//! The collective *implementations* (binomial tree reduce/broadcast and
//! the star ablation, generic over the wire codec) live in
//! [`crate::net::collectives`]; algorithms reach them through
//! [`crate::net::collectives::Comm`]. The free functions here are the
//! historical raw-`Vec<f64>` entry points, pinned to the bit-exact
//! [`WireFmt::F64`] format — tests and benches use them to assert the
//! paper's Fig.-5 properties (for one reduced+broadcast vector of length
//! `L` over `q` workers the total traffic is exactly `2·q·L` scalars in
//! `2·⌈log₂(q+1)⌉` latency rounds instead of the naive star's `2q`).
//!
//! Node ids: the *cluster* numbering used by every algorithm is
//! `0 = coordinator, 1..=q = workers`. The binomial tree is built over all
//! `q+1` nodes with the coordinator as root.

use super::collectives;
use super::{Endpoint, NodeId, WireFmt};

/// Exact-`f64` tree reduce to `group[0]` (see
/// [`collectives::tree_reduce`]).
pub fn tree_reduce(ep: &mut Endpoint, group: &[NodeId], data: &mut [f64]) {
    collectives::tree_reduce(ep, group, data, WireFmt::F64);
}

/// Exact-`f64` reverse-tree broadcast from `group[0]` (see
/// [`collectives::tree_broadcast`]).
pub fn tree_broadcast(ep: &mut Endpoint, group: &[NodeId], data: &mut Vec<f64>) {
    collectives::tree_broadcast(ep, group, data, WireFmt::F64);
}

/// Exact-`f64` allreduce: tree reduce + reverse-tree broadcast.
pub fn tree_allreduce(ep: &mut Endpoint, group: &[NodeId], data: &mut Vec<f64>) {
    collectives::tree_allreduce(ep, group, data, WireFmt::F64);
}

/// Exact-`f64` naive star allreduce (ablation baseline; see
/// [`collectives::star_allreduce`]).
pub fn star_allreduce(ep: &mut Endpoint, group: &[NodeId], data: &mut Vec<f64>) {
    collectives::star_allreduce(ep, group, data, WireFmt::F64);
}

/// Ring neighbors for DSVRG's decentralized layout over `n` workers.
pub fn ring_next(id: NodeId, n: usize) -> NodeId {
    (id + 1) % n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{build, SimParams};
    use std::thread;

    /// Run `f(endpoint, rank)` on `n` nodes, return per-rank results.
    fn run_group<T: Send + 'static>(
        n: usize,
        params: SimParams,
        f: impl Fn(&mut Endpoint, usize) -> T + Send + Sync + Copy + 'static,
    ) -> (Vec<T>, std::sync::Arc<crate::net::CommStats>) {
        let (eps, stats) = build(n, params);
        let mut handles = Vec::new();
        for (rank, mut ep) in eps.into_iter().enumerate() {
            handles.push(thread::spawn(move || f(&mut ep, rank)));
        }
        (handles.into_iter().map(|h| h.join().unwrap()).collect(), stats)
    }

    #[test]
    fn allreduce_sums_for_many_group_sizes() {
        for n in [1usize, 2, 3, 4, 5, 8, 9, 16, 17] {
            let group: Vec<NodeId> = (0..n).collect();
            let (results, _) = run_group(n, SimParams::free(), move |ep, rank| {
                let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
                let mut data = vec![rank as f64, 1.0];
                tree_allreduce(ep, &group, &mut data);
                data
            });
            let want = vec![(0..n).sum::<usize>() as f64, n as f64];
            for (rank, r) in results.iter().enumerate() {
                assert_eq!(r, &want, "n={n} rank={rank}");
            }
            let _ = group;
        }
    }

    #[test]
    fn allreduce_traffic_is_2q_scalars() {
        // paper Fig. 5: coordinator + q workers, one scalar => 2q scalars
        // total — and, under the f64 wire, exactly 8× that in bytes.
        for q in [1usize, 2, 3, 4, 7, 8, 15, 16] {
            let n = q + 1;
            let (_, stats) = run_group(n, SimParams::free(), |ep, rank| {
                let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
                let mut data = vec![rank as f64];
                tree_allreduce(ep, &group, &mut data);
            });
            assert_eq!(
                stats.total_scalars(),
                2 * q as u64,
                "q={q}: tree allreduce of 1 scalar must cost 2q"
            );
            assert_eq!(
                stats.total_bytes(),
                8 * 2 * q as u64,
                "q={q}: f64 wire bytes must be 8× the scalar count"
            );
        }
    }

    #[test]
    fn star_same_volume_more_hub_load() {
        let q = 8usize;
        let (_, tree_stats) = run_group(q + 1, SimParams::free(), |ep, _| {
            let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
            let mut data = vec![1.0];
            tree_allreduce(ep, &group, &mut data);
        });
        let (_, star_stats) = run_group(q + 1, SimParams::free(), |ep, _| {
            let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
            let mut data = vec![1.0];
            star_allreduce(ep, &group, &mut data);
        });
        assert_eq!(star_stats.total_scalars(), tree_stats.total_scalars());
        assert_eq!(star_stats.total_bytes(), tree_stats.total_bytes());
        assert!(star_stats.node_scalars(0) > tree_stats.node_scalars(0));
    }

    #[test]
    fn tree_latency_beats_star() {
        // With per-message endpoint cost 1 and 16+1 nodes, the star hub
        // must serialize 16 receives + 16 sends (≥32 time units); the tree
        // hub handles only ⌈log₂ 17⌉ messages per direction. This is the
        // paper's Fig.-5 argument.
        let n = 17usize;
        let params = SimParams { latency: 0.0, per_msg: 1.0, sec_per_byte: 0.0 };
        let (results, _) = run_group(n, params, |ep, _| {
            let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
            let mut data = vec![1.0];
            tree_allreduce(ep, &group, &mut data);
            ep.now()
        });
        let t_tree = results.iter().cloned().fold(0.0, f64::max);

        let (results, _) = run_group(n, params, |ep, _| {
            let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
            let mut data = vec![1.0];
            star_allreduce(ep, &group, &mut data);
            ep.now()
        });
        let t_star = results.iter().cloned().fold(0.0, f64::max);
        assert!(t_star >= 32.0, "star hub must serialize 2q messages, got {t_star}");
        assert!(
            t_star > 1.5 * t_tree,
            "star ({t_star}) should be well beyond tree ({t_tree})"
        );
    }

    #[test]
    fn broadcast_delivers_root_value() {
        for n in [2usize, 3, 5, 8, 13] {
            let (results, _) = run_group(n, SimParams::free(), |ep, rank| {
                let group: Vec<NodeId> = (0..ep.n_nodes()).collect();
                let mut data = if rank == 0 { vec![42.0, 7.0] } else { vec![0.0, 0.0] };
                tree_broadcast(ep, &group, &mut data);
                data
            });
            for r in &results {
                assert_eq!(r, &vec![42.0, 7.0], "n={n}");
            }
        }
    }

    #[test]
    fn subgroup_allreduce_ignores_outsiders() {
        // nodes 1..=3 allreduce while node 0 stays idle
        let (eps, _) = build(4, SimParams::free());
        let mut handles = Vec::new();
        for (rank, mut ep) in eps.into_iter().enumerate() {
            handles.push(thread::spawn(move || {
                if rank == 0 {
                    return vec![];
                }
                let group = vec![1, 2, 3];
                let mut data = vec![rank as f64];
                tree_allreduce(ep_ref(&mut ep), &group, &mut data);
                data
            }));
        }
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for r in &results[1..] {
            assert_eq!(r, &vec![6.0]);
        }
    }

    fn ep_ref(ep: &mut Endpoint) -> &mut Endpoint {
        ep
    }

    #[test]
    fn ring_next_wraps() {
        assert_eq!(ring_next(0, 4), 1);
        assert_eq!(ring_next(3, 4), 0);
    }
}
