//! Gradient sparsification for the counted collectives (`--compress`).
//!
//! A [`Compression`] policy turns a dense `f64` vector into a
//! [`Payload::Sparse`] carrying only the *selected* coordinates as
//! `(u32 index, f32 value)` pairs — 8 wire bytes per survivor — before the
//! payload enters a counted send. Two selectors:
//!
//! * `topk:<k>` — keep the `k` coordinates of largest magnitude
//!   (deterministic tie-break: the lower index wins), the classic top-k
//!   gradient sparsification of distributed SGD/SAGA;
//! * `thresh:<t>` — keep every coordinate with `|v| ≥ t`, the
//!   magnitude-threshold variant (data-dependent payload size).
//!
//! Zeros are never selected (they carry no information and a
//! [`Payload::Sparse`] scatter restores them for free), indices are
//! emitted strictly ascending (the `Sparse` codec's invariant), and the
//! whole pipe rides the existing byte-accurate accounting: the simulator
//! charges `8·selected` bytes because that is exactly what the payload
//! serializes to — nothing about [`crate::net::CommStats`] changes.
//!
//! Compression is lossy twice over (dropped coordinates *and* the `f32`
//! value quantization of the sparse codec), so it is strictly opt-in:
//! [`Compression::None`] is the default everywhere and leaves every
//! counted send byte-identical to the pre-compression wire.

use super::payload::Payload;

/// Sparsification policy for counted payloads (`--compress
/// none|topk:<k>|thresh:<t>`, config `run.compress`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum Compression {
    /// No sparsification: payloads go through the run's [`super::WireFmt`]
    /// codec untouched (the bit-exact default).
    #[default]
    None,
    /// Keep the `k` largest-magnitude coordinates.
    TopK(usize),
    /// Keep every coordinate with `|v| ≥ t`.
    Threshold(f64),
}

impl Compression {
    /// Spec names listed by parse errors.
    pub const NAMES: [&'static str; 3] = ["none", "topk:<k>", "thresh:<t>"];

    /// Parse a compression spec: `none`, `topk:<k>` or `thresh:<t>`
    /// (case-insensitive; `top-k:`/`top_k:` also accepted via the usual
    /// `_` → `-` folding done by hand here since the value part is free-form).
    pub fn parse(s: &str) -> Option<Compression> {
        let s = s.trim().to_ascii_lowercase().replace('_', "-");
        if s == "none" || s.is_empty() {
            return Some(Compression::None);
        }
        if let Some(k) = s.strip_prefix("topk:").or_else(|| s.strip_prefix("top-k:")) {
            let k: usize = k.trim().parse().ok()?;
            return if k == 0 { None } else { Some(Compression::TopK(k)) };
        }
        if let Some(t) = s.strip_prefix("thresh:").or_else(|| s.strip_prefix("threshold:")) {
            let t: f64 = t.trim().parse().ok()?;
            return if t > 0.0 && t.is_finite() { Some(Compression::Threshold(t)) } else { None };
        }
        None
    }

    /// [`Compression::parse`] with a CLI-grade error listing the valid
    /// spec shapes.
    pub fn parse_or_err(s: &str) -> Result<Compression, String> {
        Compression::parse(s).ok_or_else(|| {
            format!(
                "unknown compression {s:?}; valid specs (case-insensitive): {} \
                 (k ≥ 1, t > 0)",
                Self::NAMES.join(", ")
            )
        })
    }

    /// The canonical spec string — round-trips through [`Compression::parse`]
    /// (the tcp worker spec serializes this).
    pub fn spec(&self) -> String {
        match self {
            Compression::None => "none".into(),
            Compression::TopK(k) => format!("topk:{k}"),
            Compression::Threshold(t) => format!("thresh:{t}"),
        }
    }

    pub fn is_none(&self) -> bool {
        *self == Compression::None
    }

    /// Sparsify `data` into a [`Payload::Sparse`]. [`Compression::None`]
    /// keeps every nonzero (identical to the sparse wire codec); the
    /// selectors drop coordinates as documented above. Indices come out
    /// strictly ascending and duplicate-free in every case.
    pub fn encode(&self, data: &[f64]) -> Payload {
        let keep: Vec<u32> = match *self {
            Compression::None => {
                (0..data.len()).filter(|&i| data[i] != 0.0).map(|i| i as u32).collect()
            }
            Compression::Threshold(t) => (0..data.len())
                .filter(|&i| data[i] != 0.0 && data[i].abs() >= t)
                .map(|i| i as u32)
                .collect(),
            Compression::TopK(k) => {
                let mut nz: Vec<u32> =
                    (0..data.len()).filter(|&i| data[i] != 0.0).map(|i| i as u32).collect();
                if nz.len() > k {
                    // largest magnitude first; ties broken toward the lower
                    // index so the selection is deterministic across nodes
                    nz.sort_unstable_by(|&a, &b| {
                        data[b as usize]
                            .abs()
                            .total_cmp(&data[a as usize].abs())
                            .then(a.cmp(&b))
                    });
                    nz.truncate(k);
                    nz.sort_unstable();
                }
                nz
            }
        };
        let val: Vec<f32> = keep.iter().map(|&i| data[i as usize] as f32).collect();
        Payload::Sparse { idx: keep.into(), val: val.into() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decoded(c: Compression, data: &[f64]) -> Vec<f64> {
        c.encode(data).to_vec(data.len())
    }

    #[test]
    fn parse_round_trips_and_rejects_garbage() {
        for spec in ["none", "topk:64", "thresh:0.001"] {
            let c = Compression::parse(spec).unwrap();
            assert_eq!(Compression::parse(&c.spec()), Some(c), "{spec}");
        }
        assert_eq!(Compression::parse("TOPK:8"), Some(Compression::TopK(8)));
        assert_eq!(Compression::parse("Top_K:8"), Some(Compression::TopK(8)));
        assert_eq!(Compression::parse("threshold:1e-3"), Some(Compression::Threshold(1e-3)));
        for bad in ["topk:0", "topk:x", "thresh:0", "thresh:-1", "thresh:nan", "gzip"] {
            assert_eq!(Compression::parse(bad), None, "{bad}");
        }
        let err = Compression::parse_or_err("gzip").unwrap_err();
        for name in Compression::NAMES {
            assert!(err.contains(name), "error must list {name:?}: {err}");
        }
    }

    #[test]
    fn topk_keeps_largest_magnitudes_with_ascending_indices() {
        let data = [0.5, -3.0, 0.0, 2.0, -0.25, 1.0];
        let p = Compression::TopK(2).encode(&data);
        match &p {
            Payload::Sparse { idx, val } => {
                assert_eq!(idx.as_ref(), &[1, 3]);
                assert_eq!(val.as_ref(), &[-3.0f32, 2.0]);
            }
            other => panic!("expected sparse payload, got {other:?}"),
        }
        assert_eq!(p.wire_bytes(), 16, "8 bytes per kept coordinate");
        assert_eq!(decoded(Compression::TopK(2), &data), vec![0.0, -3.0, 0.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn topk_larger_than_nnz_keeps_all_nonzeros() {
        let data = [0.0, 1.0, 0.0, -2.0];
        assert_eq!(decoded(Compression::TopK(100), &data), data.to_vec());
        assert_eq!(Compression::TopK(100).encode(&data).scalars(), 2);
    }

    #[test]
    fn topk_breaks_magnitude_ties_toward_low_indices() {
        let data = [1.0, -1.0, 1.0, -1.0];
        let p = Compression::TopK(2).encode(&data);
        match &p {
            Payload::Sparse { idx, .. } => assert_eq!(idx.as_ref(), &[0, 1]),
            other => panic!("expected sparse payload, got {other:?}"),
        }
    }

    #[test]
    fn threshold_drops_small_coordinates_only() {
        let data = [1e-6, 0.5, -1e-4, 0.0, -2.0];
        assert_eq!(
            decoded(Compression::Threshold(1e-3), &data),
            vec![0.0, 0.5, 0.0, 0.0, -2.0]
        );
        // at the boundary |v| == t the coordinate survives
        assert_eq!(decoded(Compression::Threshold(0.5), &data), vec![0.0, 0.5, 0.0, 0.0, -2.0]);
    }

    #[test]
    fn none_matches_sparse_codec_selection() {
        use crate::net::WireFmt;
        let data = [0.0, 2.5, 0.0, -1.25, 0.0];
        let a = Compression::None.encode(&data);
        let b = WireFmt::Sparse.encode(&data);
        assert_eq!(a.to_vec(5), b.to_vec(5));
        assert_eq!(a.wire_bytes(), b.wire_bytes());
    }

    #[test]
    fn empty_selection_encodes_an_empty_payload() {
        let data = [1e-9, -1e-9, 0.0];
        let p = Compression::Threshold(1.0).encode(&data);
        assert_eq!(p.scalars(), 0);
        assert_eq!(p.wire_bytes(), 0);
        assert_eq!(p.to_vec(3), vec![0.0; 3]);
    }
}
