//! The transport seam: where a [`Msg`] actually travels.
//!
//! [`crate::net::Endpoint`] owns all simulator semantics — clock charging,
//! byte/scalar accounting, selective receive, the stash — and delegates
//! *moving* messages to a [`Transport`]:
//!
//! * [`SimTransport`] — the in-memory mailboxes the simulator has always
//!   used: one mpsc channel per node, every peer holds a sender clone.
//!   Bit-exact with the pre-seam message plane (the equivalence, resume
//!   and exactness suites pin it).
//! * [`tcp::TcpTransport`] — length-prefixed frames over localhost
//!   sockets, one OS process per node (`--transport tcp`). The frame
//!   body reuses the [`Payload`] byte codecs, so the same [`WireFmt`]
//!   selection governs real socket bytes.
//!
//! Both transports deliver [`Arrival`]s: either a message or a
//! [`Arrival::Gone`] sentinel announcing that a peer's link closed.
//! `SimTransport` broadcasts `Gone` from its `Drop` impl — which runs
//! during unwinding, so a panicking or early-returning node notifies
//! every peer that is still blocked on it. Because mpsc channels are
//! FIFO per sender, `Gone(x)` always arrives *after* every message `x`
//! sent, so a receiver that observes `Gone(x)` while waiting on `x` can
//! fail fast: nothing from `x` can still be in flight. The TCP reader
//! threads emit the same sentinel on EOF or a broken stream.

pub mod tcp;

use std::sync::mpsc::{channel, Receiver, Sender};

use super::payload::Payload;
use super::{Msg, NodeId, Tag};

/// Marker error: the destination's link is down (peer thread or process
/// gone). The endpoint owns the panic message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkDown;

/// What a blocking transport receive can yield.
pub enum Arrival {
    /// A delivered message.
    Msg(Msg),
    /// The link to this peer closed; nothing more from it is in flight.
    Gone(NodeId),
}

/// Moves messages between nodes. Implementations carry no simulator
/// semantics: no clock, no counters, no selective receive — the
/// [`crate::net::Endpoint`] layers those on top, identically for every
/// transport.
pub trait Transport: Send {
    /// Deliver `msg` to node `to`; errors iff the link is down.
    fn send(&mut self, to: NodeId, msg: Msg) -> Result<(), LinkDown>;

    /// Block for the next arrival; `None` once every peer's link has
    /// closed (after each closure was reported as [`Arrival::Gone`]).
    fn recv(&mut self) -> Option<Arrival>;

    /// Real bytes this node has written to sockets for *counted* frames,
    /// including framing overhead (0 for in-memory transports).
    fn socket_bytes(&self) -> u64 {
        0
    }

    /// True when peers live in other OS processes (the TCP path) — the
    /// session layer ships comm counters over the wire in that case.
    fn is_remote(&self) -> bool {
        false
    }
}

/// Which transport backs the message plane (`--transport sim|tcp`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// In-memory mailboxes, one thread per node (the default).
    #[default]
    Sim,
    /// Localhost TCP sockets, one OS process per node.
    Tcp,
}

impl TransportKind {
    pub const NAMES: [&'static str; 2] = ["sim", "tcp"];

    const TABLE: [(&'static str, TransportKind); 2] =
        [("sim", TransportKind::Sim), ("tcp", TransportKind::Tcp)];

    /// Parse a transport name, case-insensitively.
    pub fn parse(s: &str) -> Option<TransportKind> {
        crate::util::parse_enum(s, &Self::TABLE)
    }

    /// [`TransportKind::parse`] with a CLI-grade error listing the valid
    /// transports.
    pub fn parse_or_err(s: &str) -> Result<TransportKind, String> {
        crate::util::parse_enum_or_err(
            s,
            "transport",
            "transports (case-insensitive)",
            &Self::NAMES,
            &Self::TABLE,
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Sim => "sim",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// The in-memory transport: node `i`'s mailbox is an mpsc channel whose
/// sender every peer clones. Dropping a `SimTransport` broadcasts
/// [`Arrival::Gone`] to every peer (best-effort) *before* the sender
/// clones it holds are released, so waiters fail fast instead of
/// deadlocking on a vanished node.
pub struct SimTransport {
    id: NodeId,
    /// `peers[p]` is the sender into `p`'s mailbox; `None` at `p == id`
    /// (nodes never send to themselves, and holding a live self-sender
    /// would keep this node's own mailbox open forever).
    peers: Vec<Option<Sender<Arrival>>>,
    rx: Receiver<Arrival>,
}

impl SimTransport {
    /// Build the fully-connected mesh of `n_nodes` transports.
    pub fn mesh(n_nodes: usize) -> Vec<SimTransport> {
        let mut txs = Vec::with_capacity(n_nodes);
        let mut rxs = Vec::with_capacity(n_nodes);
        for _ in 0..n_nodes {
            let (tx, rx) = channel::<Arrival>();
            txs.push(tx);
            rxs.push(rx);
        }
        rxs.into_iter()
            .enumerate()
            .map(|(id, rx)| {
                let peers = txs
                    .iter()
                    .enumerate()
                    .map(|(p, tx)| if p == id { None } else { Some(tx.clone()) })
                    .collect();
                SimTransport { id, peers, rx }
            })
            .collect()
    }
}

impl Transport for SimTransport {
    fn send(&mut self, to: NodeId, msg: Msg) -> Result<(), LinkDown> {
        match &self.peers[to] {
            Some(tx) => tx.send(Arrival::Msg(msg)).map_err(|_| LinkDown),
            None => Err(LinkDown),
        }
    }

    fn recv(&mut self) -> Option<Arrival> {
        self.rx.recv().ok()
    }
}

impl Drop for SimTransport {
    fn drop(&mut self) {
        for tx in self.peers.iter().flatten() {
            let _ = tx.send(Arrival::Gone(self.id));
        }
    }
}

/// Frame a message for a socket: a little-endian `u32` body length, then
/// `[from u32] [tag u32] [counted u8] [send_time f64] [jitter f64]`
/// followed by the payload's [`Payload::write_bytes`] encoding.
pub(crate) fn encode_frame(msg: &Msg) -> Vec<u8> {
    let mut body = Vec::with_capacity(25 + 5 + msg.payload.wire_bytes());
    body.extend_from_slice(&(msg.from as u32).to_le_bytes());
    body.extend_from_slice(&msg.tag.to_le_bytes());
    body.push(msg.counted as u8);
    body.extend_from_slice(&msg.send_time.to_le_bytes());
    body.extend_from_slice(&msg.jitter.to_le_bytes());
    msg.payload.write_bytes(&mut body);
    let mut frame = Vec::with_capacity(4 + body.len());
    frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
    frame.extend_from_slice(&body);
    frame
}

/// Decode a frame *body* (the length prefix already stripped). Errors on
/// anything malformed — truncated header, bad flag byte, payload decode
/// failure, or trailing garbage.
pub(crate) fn decode_frame(body: &[u8]) -> Result<Msg, String> {
    if body.len() < 25 {
        return Err(format!("frame header truncated: {} bytes, need 25", body.len()));
    }
    let from = u32::from_le_bytes(body[0..4].try_into().unwrap()) as NodeId;
    let tag = u32::from_le_bytes(body[4..8].try_into().unwrap()) as Tag;
    let counted = match body[8] {
        0 => false,
        1 => true,
        b => return Err(format!("bad counted flag {b}")),
    };
    let send_time = f64::from_le_bytes(body[9..17].try_into().unwrap());
    let jitter = f64::from_le_bytes(body[17..25].try_into().unwrap());
    let (payload, used) = Payload::read_bytes(&body[25..])?;
    if 25 + used != body.len() {
        return Err(format!("{} trailing bytes after payload", body.len() - 25 - used));
    }
    Ok(Msg { from, tag, payload, send_time, jitter, counted })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::tags;
    use crate::net::WireFmt;

    fn msg(from: NodeId, tag: Tag, data: &[f64], fmt: WireFmt, counted: bool) -> Msg {
        Msg { from, tag, payload: fmt.encode(data), send_time: 1.25, jitter: 0.5, counted }
    }

    #[test]
    fn frame_round_trips_every_wire_format() {
        for fmt in WireFmt::ALL {
            for counted in [true, false] {
                let m = msg(3, tags::REDUCE, &[1.0, 0.0, -2.5], fmt, counted);
                let frame = encode_frame(&m);
                let len = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
                assert_eq!(len + 4, frame.len());
                let back = decode_frame(&frame[4..]).unwrap();
                assert_eq!(back.from, 3);
                assert_eq!(back.tag, tags::REDUCE);
                assert_eq!(back.send_time, 1.25);
                assert_eq!(back.wire_jitter(), 0.5);
                assert_eq!(back.counted, counted);
                assert_eq!(back.to_vec(3), m.to_vec(3), "{}", fmt.name());
            }
        }
    }

    #[test]
    fn truncated_frames_error() {
        let frame = encode_frame(&msg(1, tags::BCAST, &[4.0, 5.0], WireFmt::F64, true));
        for cut in 0..frame.len() - 4 {
            assert!(decode_frame(&frame[4..4 + cut]).is_err(), "cut at {cut}");
        }
        // trailing garbage is rejected too
        let mut long = frame[4..].to_vec();
        long.push(0);
        assert!(decode_frame(&long).is_err());
    }

    #[test]
    fn transport_parse_and_names() {
        assert_eq!(TransportKind::parse("sim"), Some(TransportKind::Sim));
        assert_eq!(TransportKind::parse(" TCP "), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("udp"), None);
        assert_eq!(TransportKind::default(), TransportKind::Sim);
        let err = TransportKind::parse_or_err("udp").unwrap_err();
        assert!(err.contains("sim") && err.contains("tcp"), "{err}");
        for k in [TransportKind::Sim, TransportKind::Tcp] {
            assert_eq!(TransportKind::parse(k.name()), Some(k));
        }
    }

    #[test]
    fn sim_gone_arrives_after_the_peers_messages() {
        let mut mesh = SimTransport::mesh(2);
        let mut b = mesh.pop().unwrap();
        let mut a = mesh.pop().unwrap();
        a.send(1, msg(0, tags::PUSH, &[7.0], WireFmt::F64, true)).unwrap();
        drop(a); // broadcasts Gone(0) after the message, per-sender FIFO
        match b.recv() {
            Some(Arrival::Msg(m)) => assert_eq!(m.to_vec(1), vec![7.0]),
            _ => panic!("message must precede the Gone sentinel"),
        }
        match b.recv() {
            Some(Arrival::Gone(0)) => {}
            _ => panic!("peer 0's drop must deliver Gone(0)"),
        }
        assert!(b.recv().is_none(), "all senders gone: mailbox must close");
    }

    #[test]
    fn sim_self_send_is_link_down() {
        let mut mesh = SimTransport::mesh(2);
        let m = msg(0, tags::CTRL, &[1.0], WireFmt::F64, true);
        assert_eq!(mesh[0].send(0, m).unwrap_err(), LinkDown);
    }
}
