//! Localhost TCP transport: one OS process per node.
//!
//! ## Frame format
//!
//! Every message is one frame: a little-endian `u32` body length followed
//! by the body ([`super::encode_frame`]): `[from u32] [tag u32]
//! [counted u8] [send_time f64] [jitter f64] [payload bytes]`, the
//! payload in [`crate::net::Payload::write_bytes`] encoding. Decoding
//! treats the bytes as untrusted: bad lengths, bad flags and truncation
//! close the link instead of panicking.
//!
//! ## Rendezvous
//!
//! The monitor process (node 0) binds a loopback listener and spawns one
//! worker process per node via its own executable (`fdsvrg worker`, an
//! internal entrypoint), passing the experiment spec and the rendezvous
//! port through `FDSVRG_WORKER_*` environment variables. Each worker
//! binds its own mesh listener, dials the monitor, and sends `HELLO
//! [id u32] [mesh_port u32]`. Once all q workers have checked in, the
//! monitor replies on every control stream with the port map (`u32` mesh
//! ports for nodes `1..=q`); workers then dial every lower-id worker
//! (announcing `[id u32]`) and accept every higher-id worker. The
//! control stream doubles as the node-0 ↔ worker data link. Every wait
//! in the protocol is bounded: the monitor polls `accept` while checking
//! child processes for early exits, so a worker that dies during
//! rendezvous surfaces as an error naming the node, never a hang.
//!
//! ## Reading
//!
//! Each established stream gets a detached reader thread that decodes
//! frames into the transport's mailbox and emits [`Arrival::Gone`] on
//! EOF or any malformed frame. Dropping the transport shuts the sockets
//! down, which unblocks and retires the readers.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::process::Child;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::{decode_frame, encode_frame, Arrival, LinkDown, Transport};
use crate::net::{Msg, NodeId};

/// Experiment spec (config text) handed to worker processes.
pub const ENV_SPEC: &str = "FDSVRG_WORKER_SPEC";
/// The worker's node id (`1..=q`).
pub const ENV_ID: &str = "FDSVRG_WORKER_ID";
/// Total node count (q workers + the monitor).
pub const ENV_NODES: &str = "FDSVRG_WORKER_NODES";
/// The monitor's rendezvous port on 127.0.0.1.
pub const ENV_PORT: &str = "FDSVRG_WORKER_PORT";
/// Test hook: the worker with this node id exits(0) right after
/// rendezvous, so teardown paths can be exercised deterministically.
pub const ENV_TEST_EXIT: &str = "FDSVRG_TEST_WORKER_EXIT";

/// Default rendezvous deadline, seconds (`--rendezvous-timeout`): every
/// wait in the rendezvous protocol gives up after this long unless the
/// caller passes its own budget.
pub const DEFAULT_RENDEZVOUS_SECS: f64 = 30.0;

/// First dial-retry backoff; doubles per attempt up to [`MAX_BACKOFF`].
const FIRST_BACKOFF: Duration = Duration::from_millis(50);
const MAX_BACKOFF: Duration = Duration::from_millis(800);

/// Clamp a caller-supplied deadline into a usable `Duration` (guards the
/// `from_secs_f64` panics on non-finite/negative input).
fn budget(secs: f64) -> Duration {
    if secs.is_finite() && secs > 0.0 {
        Duration::from_secs_f64(secs)
    } else {
        Duration::from_millis(1)
    }
}

/// Dial `127.0.0.1:port` with bounded retry-with-backoff: a refused or
/// reset connection (the peer's listener not up yet) retries with
/// doubling sleeps until `deadline_secs` is spent, then fails with the
/// attempt count, elapsed time and last error.
fn dial_with_retry(port: u16, what: &str, deadline_secs: f64) -> Result<TcpStream> {
    let start = Instant::now();
    let deadline = start + budget(deadline_secs);
    let mut backoff = FIRST_BACKOFF;
    let mut attempts = 0u32;
    loop {
        attempts += 1;
        match TcpStream::connect(("127.0.0.1", port)) {
            Ok(stream) => return Ok(stream),
            Err(e) => {
                if Instant::now() + backoff > deadline {
                    bail!(
                        "dial {what} (127.0.0.1:{port}) failed after {attempts} attempt(s) \
                         over {:.1}s: {e}",
                        start.elapsed().as_secs_f64()
                    );
                }
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(MAX_BACKOFF);
            }
        }
    }
}

/// Frames above this are treated as stream corruption.
const MAX_FRAME: usize = 1 << 30;

/// The socket-backed [`Transport`]: per-peer writer streams plus one
/// reader thread per peer feeding a shared mailbox.
pub struct TcpTransport {
    /// `writers[p]` is the stream to peer `p`; `None` at our own slot.
    writers: Vec<Option<TcpStream>>,
    rx: Receiver<Arrival>,
    /// Counted-frame bytes written, including framing overhead.
    socket_bytes: u64,
}

impl TcpTransport {
    /// Wrap established per-peer streams: spawn one reader per stream.
    fn assemble(n_nodes: usize, streams: Vec<Option<TcpStream>>) -> Result<TcpTransport> {
        let (tx, rx) = channel::<Arrival>();
        let mut writers = Vec::with_capacity(n_nodes);
        for (peer, stream) in streams.into_iter().enumerate() {
            let Some(stream) = stream else {
                writers.push(None);
                continue;
            };
            stream.set_nodelay(true).ok();
            let reader = stream.try_clone().context("clone stream for reader")?;
            let tx = tx.clone();
            std::thread::Builder::new()
                .name(format!("tcp-reader-{peer}"))
                .spawn(move || reader_loop(peer, reader, tx))
                .context("spawn reader thread")?;
            writers.push(Some(stream));
        }
        // `tx` drops here: the mailbox closes exactly when every reader
        // has exited (each sends its Gone sentinel first).
        Ok(TcpTransport { writers, rx, socket_bytes: 0 })
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, to: NodeId, msg: Msg) -> Result<(), LinkDown> {
        let frame = encode_frame(&msg);
        let writer = self.writers[to].as_mut().ok_or(LinkDown)?;
        if writer.write_all(&frame).is_err() {
            return Err(LinkDown);
        }
        if msg.counted {
            self.socket_bytes += frame.len() as u64;
        }
        Ok(())
    }

    fn recv(&mut self) -> Option<Arrival> {
        self.rx.recv().ok()
    }

    fn socket_bytes(&self) -> u64 {
        self.socket_bytes
    }

    fn is_remote(&self) -> bool {
        true
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        for writer in self.writers.iter().flatten() {
            let _ = writer.shutdown(Shutdown::Both);
        }
    }
}

fn reader_loop(peer: NodeId, mut stream: TcpStream, tx: Sender<Arrival>) {
    loop {
        let mut len4 = [0u8; 4];
        if stream.read_exact(&mut len4).is_err() {
            break;
        }
        let len = u32::from_le_bytes(len4) as usize;
        if len > MAX_FRAME {
            break;
        }
        let mut body = vec![0u8; len];
        if stream.read_exact(&mut body).is_err() {
            break;
        }
        let Ok(msg) = decode_frame(&body) else {
            break;
        };
        if tx.send(Arrival::Msg(msg)).is_err() {
            break;
        }
    }
    let _ = tx.send(Arrival::Gone(peer));
}

/// Bind the monitor's rendezvous listener (port 0 = OS-assigned).
pub fn listen() -> Result<(TcpListener, u16)> {
    let listener = TcpListener::bind("127.0.0.1:0").context("bind rendezvous listener")?;
    let port = listener.local_addr().context("read rendezvous port")?.port();
    Ok((listener, port))
}

/// Monitor side of the rendezvous: accept `n_nodes - 1` worker HELLOs,
/// send the port map, and assemble node 0's transport. `poll` runs each
/// time `accept` would block — the process launcher uses it to detect
/// workers that died before checking in. `deadline_secs` bounds every
/// wait (`--rendezvous-timeout`; [`DEFAULT_RENDEZVOUS_SECS`]).
pub fn accept_workers(
    listener: &TcpListener,
    n_nodes: usize,
    deadline_secs: f64,
    mut poll: impl FnMut(&[Option<TcpStream>]) -> Result<()>,
) -> Result<TcpTransport> {
    listener.set_nonblocking(true).context("rendezvous listener nonblocking")?;
    let wait = budget(deadline_secs);
    let deadline = Instant::now() + wait;
    let mut streams: Vec<Option<TcpStream>> = (0..n_nodes).map(|_| None).collect();
    let mut ports = vec![0u16; n_nodes];
    let mut pending = n_nodes - 1;
    while pending > 0 {
        match listener.accept() {
            Ok((mut stream, _addr)) => {
                stream.set_nonblocking(false).context("worker stream blocking")?;
                stream.set_read_timeout(Some(wait)).context("worker stream timeout")?;
                let mut hello = [0u8; 8];
                stream.read_exact(&mut hello).context("read worker hello")?;
                let id = u32::from_le_bytes(hello[0..4].try_into().unwrap()) as usize;
                let mesh_port = u32::from_le_bytes(hello[4..8].try_into().unwrap()) as u16;
                if id == 0 || id >= n_nodes {
                    bail!("worker hello announced bogus node id {id}");
                }
                if streams[id].is_some() {
                    bail!("two workers announced node id {id}");
                }
                stream.set_read_timeout(None).context("worker stream timeout")?;
                ports[id] = mesh_port;
                streams[id] = Some(stream);
                pending -= 1;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                poll(&streams)?;
                if Instant::now() > deadline {
                    bail!(
                        "rendezvous timed out after {deadline_secs}s waiting for \
                         {pending} worker(s) (raise --rendezvous-timeout?)"
                    );
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e).context("accept worker connection"),
        }
    }
    let mut map = Vec::with_capacity(4 * (n_nodes - 1));
    for p in ports.iter().skip(1) {
        map.extend_from_slice(&(*p as u32).to_le_bytes());
    }
    for stream in streams.iter_mut().flatten() {
        stream.write_all(&map).context("send port map")?;
    }
    TcpTransport::assemble(n_nodes, streams)
}

/// Monitor-side `poll` hook for [`accept_workers`]: error out (naming
/// the node) if any worker process exited before completing rendezvous.
pub fn check_children(
    children: &mut [(NodeId, Child)],
    streams: &[Option<TcpStream>],
) -> Result<()> {
    for (id, child) in children.iter_mut() {
        if streams[*id].is_none() {
            if let Some(status) = child.try_wait().context("poll worker process")? {
                bail!("worker process for node {id} exited during rendezvous ({status})");
            }
        }
    }
    Ok(())
}

/// Worker side of the rendezvous: dial the monitor, exchange
/// HELLO/port-map, then mesh with the other workers (dial lower ids,
/// accept higher ids). Returns this node's assembled transport. Dials
/// retry with bounded backoff (a racing peer's listener may not be up
/// yet); every wait honours `deadline_secs`.
pub fn worker_connect(
    id: NodeId,
    n_nodes: usize,
    parent_port: u16,
    deadline_secs: f64,
) -> Result<TcpTransport> {
    let wait = budget(deadline_secs);
    let mesh = TcpListener::bind("127.0.0.1:0").context("bind mesh listener")?;
    let mesh_port = mesh.local_addr().context("read mesh port")?.port();
    let mut ctrl = dial_with_retry(parent_port, "monitor", deadline_secs)?;
    let mut hello = Vec::with_capacity(8);
    hello.extend_from_slice(&(id as u32).to_le_bytes());
    hello.extend_from_slice(&(mesh_port as u32).to_le_bytes());
    ctrl.write_all(&hello).context("send hello")?;
    ctrl.set_read_timeout(Some(wait)).context("control stream timeout")?;
    let mut map = vec![0u8; 4 * (n_nodes - 1)];
    ctrl.read_exact(&mut map).context("read port map")?;
    ctrl.set_read_timeout(None).context("control stream timeout")?;
    let mut ports = vec![0u16; n_nodes];
    for (off, chunk) in map.chunks_exact(4).enumerate() {
        ports[off + 1] = u32::from_le_bytes(chunk.try_into().unwrap()) as u16;
    }
    let mut streams: Vec<Option<TcpStream>> = (0..n_nodes).map(|_| None).collect();
    streams[0] = Some(ctrl);
    // Dial every lower-id worker, announcing our id …
    for peer in 1..id {
        let mut stream =
            dial_with_retry(ports[peer], &format!("worker {peer}"), deadline_secs)?;
        stream.write_all(&(id as u32).to_le_bytes()).context("send mesh announce")?;
        streams[peer] = Some(stream);
    }
    // … and accept every higher-id worker (each announces itself).
    mesh.set_nonblocking(true).context("mesh listener nonblocking")?;
    let deadline = Instant::now() + wait;
    let mut pending = n_nodes - 1 - id;
    while pending > 0 {
        match mesh.accept() {
            Ok((mut stream, _addr)) => {
                stream.set_nonblocking(false).context("mesh stream blocking")?;
                stream.set_read_timeout(Some(wait)).context("mesh stream timeout")?;
                let mut ann = [0u8; 4];
                stream.read_exact(&mut ann).context("read mesh announce")?;
                let peer = u32::from_le_bytes(ann) as usize;
                if peer <= id || peer >= n_nodes || streams[peer].is_some() {
                    bail!("bogus mesh announce from node {peer}");
                }
                stream.set_read_timeout(None).context("mesh stream timeout")?;
                streams[peer] = Some(stream);
                pending -= 1;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() > deadline {
                    bail!(
                        "node {id}: mesh rendezvous timed out after {deadline_secs}s \
                         waiting for {pending} peer(s) (raise --rendezvous-timeout?)"
                    );
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e).context("accept mesh connection"),
        }
    }
    TcpTransport::assemble(n_nodes, streams)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{tags, WireFmt};
    use std::thread;

    fn msg(from: NodeId, tag: u32, data: &[f64], counted: bool) -> Msg {
        Msg {
            from,
            tag,
            payload: WireFmt::F64.encode(data),
            send_time: 0.25,
            jitter: 0.0,
            counted,
        }
    }

    /// Full 3-node rendezvous on loopback, inside one process: the
    /// monitor half runs [`accept_workers`] on this thread while two
    /// "worker" threads run [`worker_connect`].
    fn loopback_mesh() -> (TcpTransport, TcpTransport, TcpTransport) {
        let (listener, port) = listen().unwrap();
        let h1 =
            thread::spawn(move || worker_connect(1, 3, port, DEFAULT_RENDEZVOUS_SECS).unwrap());
        let h2 =
            thread::spawn(move || worker_connect(2, 3, port, DEFAULT_RENDEZVOUS_SECS).unwrap());
        let t0 = accept_workers(&listener, 3, DEFAULT_RENDEZVOUS_SECS, |_| Ok(())).unwrap();
        (t0, h1.join().unwrap(), h2.join().unwrap())
    }

    #[test]
    fn loopback_mesh_round_trips_messages() {
        let (mut t0, mut t1, mut t2) = loopback_mesh();
        t0.send(1, msg(0, tags::BCAST, &[1.0, 2.0], true)).unwrap();
        t1.send(2, msg(1, tags::RING, &[3.0], true)).unwrap();
        t2.send(0, msg(2, tags::REDUCE, &[4.0, 5.0, 6.0], true)).unwrap();
        for (t, from, tag, want) in [
            (&mut t1, 0, tags::BCAST, vec![1.0, 2.0]),
            (&mut t2, 1, tags::RING, vec![3.0]),
            (&mut t0, 2, tags::REDUCE, vec![4.0, 5.0, 6.0]),
        ] {
            match t.recv() {
                Some(Arrival::Msg(m)) => {
                    assert_eq!(m.from, from);
                    assert_eq!(m.tag, tag);
                    assert_eq!(m.to_vec(want.len()), want);
                    assert_eq!(m.send_time, 0.25, "clock stamp must survive the wire");
                }
                _ => panic!("expected a message from {from}"),
            }
        }
    }

    #[test]
    fn socket_bytes_count_counted_frames_only() {
        let (mut t0, mut t1, _t2) = loopback_mesh();
        assert_eq!(t0.socket_bytes(), 0);
        t0.send(1, msg(0, tags::BCAST, &[1.0, 2.0], true)).unwrap();
        let counted = t0.socket_bytes();
        // frame = 4 (len) + 25 (header) + 5 + 16 (payload) bytes
        assert_eq!(counted, 50);
        t0.send(1, msg(0, tags::EVAL, &[9.0; 8], false)).unwrap();
        assert_eq!(t0.socket_bytes(), counted, "eval frames are not counted");
        // …but the eval frame still arrives
        for _ in 0..2 {
            match t1.recv() {
                Some(Arrival::Msg(_)) => {}
                _ => panic!("both frames must arrive"),
            }
        }
        assert!(t0.is_remote());
    }

    #[test]
    fn configurable_deadline_bounds_the_monitor_wait() {
        // nobody ever dials in: a short budget must fail fast, naming
        // the missing workers and the knob that raises the budget
        let (listener, _port) = listen().unwrap();
        let start = Instant::now();
        let err = accept_workers(&listener, 3, 0.2, |_| Ok(())).unwrap_err();
        assert!(start.elapsed() < Duration::from_secs(5), "must honour the 0.2s budget");
        let text = format!("{err:#}");
        assert!(text.contains("timed out"), "got: {text}");
        assert!(text.contains("2 worker(s)"), "got: {text}");
        assert!(text.contains("--rendezvous-timeout"), "got: {text}");
    }

    #[test]
    fn dial_retry_fails_loudly_within_its_budget() {
        // grab a port and close the listener so the dial is refused
        let port = {
            let (listener, port) = listen().unwrap();
            drop(listener);
            port
        };
        let start = Instant::now();
        let err = dial_with_retry(port, "monitor", 0.3).unwrap_err();
        assert!(start.elapsed() < Duration::from_secs(5), "must honour the 0.3s budget");
        let text = format!("{err:#}");
        assert!(text.contains("dial monitor"), "got: {text}");
        assert!(text.contains("attempt"), "got: {text}");
    }

    #[test]
    fn dial_retry_survives_a_late_listener() {
        // the listener comes up ~100ms after the first dial — the backoff
        // loop must absorb the race that a bare connect() would lose
        let (listener, port) = listen().unwrap();
        drop(listener);
        let accept = thread::spawn(move || {
            thread::sleep(Duration::from_millis(100));
            let listener = TcpListener::bind(("127.0.0.1", port)).unwrap();
            listener.accept().map(|_| ()).unwrap()
        });
        dial_with_retry(port, "worker 1", DEFAULT_RENDEZVOUS_SECS).unwrap();
        accept.join().unwrap();
    }

    #[test]
    fn dropped_peer_delivers_gone_sentinel() {
        let (t0, mut t1, _t2) = loopback_mesh();
        drop(t0);
        match t1.recv() {
            Some(Arrival::Gone(0)) => {}
            Some(Arrival::Gone(p)) => panic!("expected Gone(0), got Gone({p})"),
            Some(Arrival::Msg(_)) => panic!("expected Gone(0), got a message"),
            None => panic!("expected Gone(0) before the mailbox closes"),
        }
    }
}
