//! Multi-class linear classification via one-vs-rest (OvR) — the
//! adaptation the paper's §2 mentions ("the techniques in this paper can
//! also be adapted for multi-class problems").
//!
//! OvR trains `K` independent binary problems — class `k` vs the rest —
//! each of which is exactly the paper's formulation (1), so every
//! distributed algorithm in [`crate::algs`] applies unchanged; prediction
//! is `argmax_k w_kᵀx`. Because the `K` binary problems share the same
//! feature partition, a feature-distributed deployment trains them with
//! the same slabs and `K`-fold batched scalar traffic (the per-instance
//! allreduce carries `K` scalars instead of 1 — still independent of `d`).

use crate::algs::{Algorithm, Problem, RunParams};
use crate::loss::{LossKind, Regularizer};
use crate::sparse::libsvm::Dataset;
use crate::sparse::CscMatrix;
use crate::util::Pcg64;

/// A labelled multi-class dataset: `x` is `d × N`, `labels[i] ∈ 0..k`.
#[derive(Clone, Debug)]
pub struct MulticlassDataset {
    pub name: String,
    pub x: CscMatrix,
    pub labels: Vec<usize>,
    pub k: usize,
}

impl MulticlassDataset {
    pub fn d(&self) -> usize {
        self.x.rows()
    }

    pub fn n(&self) -> usize {
        self.x.cols()
    }

    /// The binary view for class `k`: `y_i = +1` iff `labels[i] == k`.
    pub fn binarize(&self, k: usize) -> Dataset {
        assert!(k < self.k);
        Dataset {
            name: format!("{}_ovr{k}", self.name),
            x: self.x.clone(),
            y: self.labels.iter().map(|&l| if l == k { 1.0 } else { -1.0 }).collect(),
        }
    }
}

/// Synthetic multi-class generator: reuses the binary power-law generator
/// and relabels by the argmax of `k` random sparse separators.
pub fn generate_multiclass(d: usize, n: usize, nnz: usize, k: usize, seed: u64) -> MulticlassDataset {
    assert!(k >= 2);
    let base = crate::data::generate(&crate::data::GenSpec::new("mc", d, n, nnz).with_seed(seed));
    let mut rng = Pcg64::seed_from_u64(seed ^ 0x6c6c);
    let n_signal = (d / 20).max(8).min(d);
    let separators: Vec<Vec<f64>> = (0..k)
        .map(|_| {
            let mut w = vec![0.0; d];
            for wi in w.iter_mut().take(n_signal) {
                *wi = rng.normal();
            }
            w
        })
        .collect();
    let labels: Vec<usize> = (0..n)
        .map(|i| {
            let mut best = (f64::NEG_INFINITY, 0usize);
            for (c, w) in separators.iter().enumerate() {
                let s = base.x.col_dot(i, w);
                if s > best.0 {
                    best = (s, c);
                }
            }
            if rng.next_f64() < 0.03 {
                rng.below(k) // label noise
            } else {
                best.1
            }
        })
        .collect();
    MulticlassDataset { name: format!("mc{k}-{d}x{n}"), x: base.x, labels, k }
}

/// A trained one-vs-rest model: one parameter vector per class.
#[derive(Clone, Debug)]
pub struct OvrModel {
    pub ws: Vec<Vec<f64>>,
}

impl OvrModel {
    /// Train `K` binary problems with the given algorithm. Each class runs
    /// the same `RunParams` (and hence the same sampling stream — the
    /// feature-distributed deployment batches their scalars together).
    pub fn train(
        ds: &MulticlassDataset,
        lambda: f64,
        algo: Algorithm,
        params: &RunParams,
    ) -> OvrModel {
        let ws = (0..ds.k)
            .map(|k| {
                let problem = Problem::new(
                    ds.binarize(k),
                    LossKind::Logistic,
                    Regularizer::L2 { lambda },
                );
                algo.run(&problem, params).w
            })
            .collect();
        OvrModel { ws }
    }

    /// `argmax_k w_kᵀx_i` over the columns of `x`.
    pub fn predict(&self, x: &CscMatrix, i: usize) -> usize {
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (k, w) in self.ws.iter().enumerate() {
            let s = x.col_dot(i, w);
            if s > best.0 {
                best = (s, k);
            }
        }
        best.1
    }

    pub fn accuracy(&self, ds: &MulticlassDataset) -> f64 {
        let correct = (0..ds.n()).filter(|&i| self.predict(&ds.x, i) == ds.labels[i]).count();
        correct as f64 / ds.n() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::SimParams;

    fn tiny_mc() -> MulticlassDataset {
        generate_multiclass(300, 240, 20, 4, 7)
    }

    #[test]
    fn generator_shapes_and_label_range() {
        let ds = tiny_mc();
        assert_eq!(ds.d(), 300);
        assert_eq!(ds.n(), 240);
        assert_eq!(ds.labels.len(), 240);
        assert!(ds.labels.iter().all(|&l| l < 4));
        // every class should appear
        for k in 0..4 {
            assert!(ds.labels.iter().any(|&l| l == k), "class {k} empty");
        }
    }

    #[test]
    fn binarize_is_consistent() {
        let ds = tiny_mc();
        let b2 = ds.binarize(2);
        assert_eq!(b2.n(), ds.n());
        for i in 0..ds.n() {
            assert_eq!(b2.y[i] > 0.0, ds.labels[i] == 2);
        }
    }

    #[test]
    fn ovr_with_fdsvrg_beats_chance_strongly() {
        let ds = tiny_mc();
        let params = RunParams { q: 4, outer: 10, sim: SimParams::free(), ..Default::default() };
        let model = OvrModel::train(&ds, 1e-3, Algorithm::FdSvrg, &params);
        let acc = model.accuracy(&ds);
        assert!(acc > 0.7, "OvR accuracy {acc} (chance = 0.25)");
    }

    #[test]
    fn ovr_serial_and_distributed_agree() {
        let ds = tiny_mc();
        let params = RunParams { q: 3, outer: 3, sim: SimParams::free(), ..Default::default() };
        let fd = OvrModel::train(&ds, 1e-3, Algorithm::FdSvrg, &params);
        let serial = OvrModel::train(&ds, 1e-3, Algorithm::SerialSvrg, &params);
        for (a, b) in fd.ws.iter().zip(serial.ws.iter()) {
            assert!(crate::linalg::dist2(a, b) < 1e-12);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate_multiclass(100, 80, 10, 3, 5);
        let b = generate_multiclass(100, 80, 10, 3, 5);
        assert_eq!(a.labels, b.labels);
    }
}
