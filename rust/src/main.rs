//! `fdsvrg` — launcher for the FD-SVRG reproduction.
//!
//! ```text
//! fdsvrg train --algo fdsvrg --dataset webspam-sim --q 16 [--lambda 1e-4]
//!              [--eta 0.x] [--outer 30] [--batch u] [--servers p]
//!              [--config exp.toml] [--out results] [--star] [--transport sim|tcp]
//! fdsvrg serve --ckpt file-or-dir --dataset news20-sim --q 8 [--serve-batch 32]
//!              [--queries 10000] [--mode closed|open] [--wire f64|f32]
//!              [--replicas 2] [--faults crash:1@0.002] [--hedge 200e-6]
//!              [--serve-deadline 5e-3] [--queue-cap 64]
//! fdsvrg exp   <fig6|fig7|fig8|fig9|table1|table2|table3|wire|netmodel|compress|calibrate|faults|serving|serving-faults|all> [--out results] [--quick]
//! fdsvrg data  <stats|gen> [--profile news20-sim] [--out file.libsvm]
//! fdsvrg check-engine      # smoke the blocked compute engine (alias: check-artifacts)
//! ```

use anyhow::{bail, Context, Result};
use fdsvrg::algs::{Algorithm, Problem, RunParams};
use fdsvrg::cli::Args;
use fdsvrg::config::{Config, ExperimentConfig};
use fdsvrg::data::profiles;
use fdsvrg::exp;
use fdsvrg::metrics::TextTable;
use std::path::Path;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    fdsvrg::util::logger::init();
    let args = Args::parse();
    match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("predict") => cmd_predict(&args),
        Some("serve") => cmd_serve(&args),
        Some("exp") => cmd_exp(&args),
        Some("data") => cmd_data(&args),
        Some("check-engine") | Some("check-artifacts") => cmd_check_engine(&args),
        // hidden: re-exec entrypoint for `--transport tcp` worker processes
        Some("worker") => cmd_worker(),
        Some(other) => bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "usage:
  fdsvrg train --algo <fdsvrg|dsvrg|synsvrg|asysvrg|pslite-sgd|serial-svrg|serial-sgd>
               --dataset <profile|path.libsvm> [--q N] [--servers P] [--lambda L]
               [--eta E] [--outer T] [--batch U] [--seed S] [--config file.toml]
               [--out dir] [--star] [--lazy] [--gap-target G]
               [--threads K]   (host threads per node for the sparse
               kernels; w/traces/counters are bit-identical at every K
               and the simulated clock still charges the serial compute,
               so K changes host wall-clock only; default 1)
               [--wire f64|f32|sparse]   (payload codec for counted traffic:
               f64 = bit-exact default, f32 = half the wire bytes,
               sparse = (u32,f32) pairs for the nonzeros only)
               [--compress none|topk:<k>|thresh:<t>]   (gradient
               sparsification on counted vector sends: keep the k
               largest-magnitude coordinates, or those with |v| >= t,
               as (u32,f32) pairs — 8 wire bytes each; lossy, off by
               default)
               [--simd]   (vectorized sparse kernels: multi-lane
               accumulators on the Dᵀw/Dc reductions; faster per core
               but reassociates FP sums, so trajectories match the
               serial default to tolerance rather than bit-exactly)
               [--net uniform|hetero|straggler|jitter]   (network timing
               model: uniform = the legacy flat SimParams (default,
               bit-exact), hetero = rack-local vs cross-rack links,
               straggler = slow nodes, jitter = seeded per-message
               latency noise; scenario knobs come from the config [net]
               table or --net-slow/--net-factor/--net-rack/
               --net-jitter-amp/--net-jitter-seed)
               [--engine native|block|mixed|xla]   (native = sparse CSC
               path, block = dense blocked trainer on the pure-Rust f32
               engine, mixed = the same f32 kernels against f64 master
               weights — f32 speed, f64-accumulated updates,
               xla = dense blocked trainer on PJRT, needs --features xla)
               [--transport sim|tcp]   (message plane: sim = in-memory
               mailboxes, one thread per node — the default, bit-exact
               with every pinned trajectory; tcp = localhost sockets with
               one OS process per node, same algorithms and wire codecs,
               real socket bytes and wall-clock reported next to the
               model's predictions; native engine only, no --resume/--ckpt)
               [--rendezvous-timeout S]   (tcp only: seconds the monitor
               waits for worker processes to dial in, and the budget each
               worker's bounded dial-retry loop honours; default 30)
               [--faults SPEC]   (seeded fault plan for the sim transport:
               comma-separated clauses  crash:<node>@<t>  drop:<p>
               dup:<p>  reorder:<p>  partition:<a>+<b>@<t1>-<t2>
               seed:<u64>.
               Link faults reshape simulated time only (drop = retransmit
               after an RTO, dup = extra NIC charge, reorder = extra link
               latency, partition = cross-cut traffic deferred to heal
               time) so the trajectory stays bit-identical; crash kills
               the node at sim-time t and the session respawns the
               cluster from its last checkpoint (give --ckpt to get
               durable snapshots; otherwise recovery replays from the
               last epoch boundary). Decisions derive from seed:<u64>
               (default: the run seed), so reruns are bit-identical;
               node 0 is the monitor and cannot be crashed)
               [--ckpt file --save-every K]   (write a v2 session checkpoint
               every K epochs; resumable mid-run snapshot)
               [--resume file]   (continue a run from a v2 session
               checkpoint; --outer counts total epochs incl. pre-resume)
               [--save file]     (write final weights as a v1 checkpoint)
  fdsvrg predict --ckpt <file|dir> [--dataset profile|path.libsvm]
               (inference from a checkpoint of either version: v1 final
               weights or a v2 session snapshot; a directory means a
               rotating checkpoint store from `train --ckpt X --save-every K`
               — the newest valid snapshot wins, corrupt ones are skipped)
  fdsvrg serve --ckpt <file|dir> [--dataset profile|path.libsvm] [--q N]
               [--queries N] [--serve-batch B] [--serve-delay S]
               [--mode closed|open] [--concurrency C] [--rate R]
               [--replicas R] [--serve-deadline S] [--hedge S] [--queue-cap K]
               [--faults spec] [--wire f64|f32|sparse]
               [--net uniform|hetero|straggler|jitter]
               [--seed S] [--out file.json]
               (sharded margin-merge serving: the checkpoint's weights are
               split over q feature shards — served from f32-quantized
               read slabs under --wire f32, exact f64 otherwise — and a
               router node batches seeded traffic drawn from the dataset's
               instances, fans each batch to the shards and merges the
               partial margins shard-by-shard. closed mode keeps
               --concurrency clients in flight; open mode draws Poisson
               arrivals at --rate qps. Batches close when full
               (--serve-batch) or --serve-delay seconds after their oldest
               query. --replicas r runs r copies of each shard (cluster is
               q*r+1 nodes; the router fails over when a primary dies) and
               composes with the same --faults grammar as train (node 0 is
               the router and cannot be crashed). --serve-deadline marks
               batches late, --hedge mirrors each shard request to a second
               replica after that delay, and --queue-cap sheds open-mode
               arrivals past the admission queue bound. Reports p50/p90/p99
               latency, throughput, availability %, shed/failover/hedge
               counters and wire bytes under the --net scenario; everything
               is simulated time, so reports are bit-stable across reruns
               and --threads. --ckpt accepts the same file-or-directory
               forms as predict)
  fdsvrg exp <fig6|fig7|fig8|fig9|table1|table2|table3|wire|netmodel|compress|calibrate|faults|serving|serving-faults|all> [--out dir] [--quick]
               (compress: gap vs wire bytes vs sim time for the top-k /
               threshold gradient sparsifiers across the distributed
               algorithms; calibrate: run the distributed algorithms under
               the sim transport and again over real localhost sockets, and
               report predicted vs measured bytes and time per algorithm;
               faults: run the distributed algorithms across fault
               scenarios — link faults, a mid-run crash with automatic
               recovery, a healing partition — and report recovery counts
               and sim-time overhead vs the failure-free baseline;
               serving: latency/throughput ablation of the sharded
               inference plane over batch size × wire format × network
               scenario × shard count, written to BENCH_serving.json;
               serving-faults: availability/latency/goodput of the robust
               serving plane across replication × fault scenarios vs the
               failure-free baseline, written to BENCH_serving_faults.json)
  fdsvrg data <stats|gen> [--profile name] [--out file]
  fdsvrg check-engine [--dir artifacts] [--engine block|mixed|xla]
               (default: the build's own backend — xla when compiled in,
               the pure-Rust block engine otherwise)";

fn build_experiment_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_config(&Config::load(path)?),
        None => ExperimentConfig::default(),
    };
    if let Some(v) = args.get("dataset") {
        cfg.dataset = v.to_string();
    }
    if let Some(v) = args.get("algo") {
        cfg.algo = v.to_string();
    }
    cfg.lambda = args.get_or("lambda", cfg.lambda);
    cfg.eta = args.get_or("eta", cfg.eta);
    cfg.outer = args.get_or("outer", cfg.outer);
    cfg.q = args.get_or("q", cfg.q);
    cfg.servers = args.get_or("servers", cfg.servers);
    cfg.batch = args.get_or("batch", cfg.batch);
    cfg.seed = args.get_or("seed", cfg.seed);
    cfg.gap_target = args.get_or("gap-target", cfg.gap_target);
    cfg.threads = args.get_or("threads", cfg.threads).max(1);
    if let Some(v) = args.get("wire") {
        cfg.wire = fdsvrg::net::WireFmt::parse_or_err(v).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(v) = args.get("compress") {
        cfg.compress = fdsvrg::net::Compression::parse_or_err(v).map_err(|e| anyhow::anyhow!(e))?;
    }
    cfg.simd = cfg.simd || args.flag("simd");
    if let Some(v) = args.get("net") {
        cfg.net_model = v.to_string();
    }
    if let Some(v) = args.get("transport") {
        cfg.transport = v.to_string();
    }
    // validate up front so the CLI error lists every valid value
    fdsvrg::net::TransportKind::parse_or_err(&cfg.transport).map_err(|e| anyhow::anyhow!(e))?;
    if let Some(v) = args.get("faults") {
        cfg.faults = v.to_string();
    }
    // validate the fault spec up front so a typo fails with the grammar
    // instead of panicking deep inside run_params()
    fdsvrg::net::fault::FaultPlan::parse(&cfg.faults, cfg.seed).map_err(|e| anyhow::anyhow!(e))?;
    cfg.rendezvous_timeout = args.get_or("rendezvous-timeout", cfg.rendezvous_timeout);
    anyhow::ensure!(
        cfg.rendezvous_timeout > 0.0 && cfg.rendezvous_timeout.is_finite(),
        "--rendezvous-timeout must be a positive number of seconds (got {})",
        cfg.rendezvous_timeout
    );
    cfg.slow = args.get_or("net-slow", cfg.slow);
    cfg.slow_factor = args.get_or("net-factor", cfg.slow_factor);
    cfg.rack_size = args.get_or("net-rack", cfg.rack_size);
    cfg.jitter_amp = args.get_or("net-jitter-amp", cfg.jitter_amp);
    cfg.jitter_seed = args.get_or("net-jitter-seed", cfg.jitter_seed);
    cfg.serve_batch = args.get_or("serve-batch", cfg.serve_batch).max(1);
    cfg.serve_delay = args.get_or("serve-delay", cfg.serve_delay);
    cfg.serve_queries = args.get_or("queries", cfg.serve_queries);
    cfg.serve_concurrency = args.get_or("concurrency", cfg.serve_concurrency).max(1);
    if let Some(v) = args.get("mode") {
        cfg.serve_mode = v.to_string();
    }
    cfg.serve_rate = args.get_or("rate", cfg.serve_rate);
    cfg.serve_replicas = args.get_or("replicas", cfg.serve_replicas).max(1);
    cfg.serve_deadline = args.get_or("serve-deadline", cfg.serve_deadline);
    cfg.serve_hedge = args.get_or("hedge", cfg.serve_hedge);
    cfg.serve_queue_cap = args.get_or("queue-cap", cfg.serve_queue_cap);
    // validate the arrival mode up front so the CLI error lists both modes
    cfg.serve_arrival_mode().map_err(|e| anyhow::anyhow!(e))?;
    // validate the scenario kind up front so the CLI error lists every
    // valid value instead of panicking deep inside run_params()
    cfg.net_spec().map_err(|e| anyhow::anyhow!(e))?;
    Ok(cfg)
}

fn load_dataset(name: &str) -> Result<fdsvrg::sparse::libsvm::Dataset> {
    if let Some(ds) = profiles::load(name) {
        return Ok(ds);
    }
    if Path::new(name).exists() {
        return fdsvrg::sparse::libsvm::read_file(name, 0);
    }
    bail!("dataset {name:?} is neither a profile ({:?}, tiny, small, dense-xla) nor a file",
          profiles::PROFILE_NAMES)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = build_experiment_config(args)?;
    let algo = Algorithm::parse_or_err(&cfg.algo).map_err(|e| anyhow::anyhow!(e))?;
    let ds = load_dataset(&cfg.dataset)?;
    // optional held-out split (--test-frac 0.2)
    let test_frac: f64 = args.get_or("test-frac", 0.0);
    let (ds, test_ds) = if test_frac > 0.0 {
        let (train, test) = fdsvrg::eval::train_test_split(&ds, test_frac, cfg.seed);
        (train, Some(test))
    } else {
        (ds, None)
    };
    let problem = Problem::logistic_l2(ds, cfg.lambda);
    let mut params: RunParams = cfg.run_params();
    params.star_reduce = args.flag("star");
    params.lazy = params.lazy || args.flag("lazy");
    let engine_kind = args.get("engine").unwrap_or("native");
    if params.faults.is_some() {
        anyhow::ensure!(
            params.transport == fdsvrg::net::TransportKind::Sim,
            "--faults requires the sim transport (fault injection over tcp is not wired yet)"
        );
        anyhow::ensure!(
            algo.is_distributed(),
            "--faults injects failures into a cluster's message plane; {} is a serial algorithm",
            algo.name()
        );
        anyhow::ensure!(
            engine_kind == "native",
            "--faults is available on the native sparse engine only (got --engine {engine_kind})"
        );
    }
    if params.transport == fdsvrg::net::TransportKind::Tcp {
        anyhow::ensure!(
            algo.is_distributed(),
            "--transport tcp runs one OS process per cluster node; {} is a serial algorithm — \
             drop the flag (the default sim transport runs it in-process)",
            algo.name()
        );
        anyhow::ensure!(
            engine_kind == "native",
            "--transport tcp is available on the native sparse engine only (got --engine {engine_kind})"
        );
        anyhow::ensure!(
            args.get("resume").is_none()
                && args.get("ckpt").is_none()
                && args.get("save-every").is_none(),
            "checkpoint/resume is not available over --transport tcp \
             (worker state lives in other processes)"
        );
        // everything a worker process needs to rebuild this run, including
        // the CLI extras applied after run_params() above
        params.worker_spec = Some(std::sync::Arc::new(cfg.worker_spec(
            test_frac,
            params.star_reduce,
            params.lazy,
        )));
    }

    println!(
        "training {} on {} (d={}, N={}, q={}, λ={:.0e}, η={}, wire={}, compress={}, net={}, threads={}{}, engine={engine_kind})",
        algo.name(),
        cfg.dataset,
        problem.d(),
        problem.n(),
        params.q,
        cfg.lambda,
        if cfg.eta > 0.0 { format!("{}", cfg.eta) } else { format!("auto={:.3}", problem.default_eta()) },
        params.wire.name(),
        params.compress.spec(),
        params.net.name(),
        params.threads,
        if params.simd { "+simd" } else { "" },
    );
    let res = match engine_kind {
        // "native" keeps its historical meaning: the sparse CSC algorithms,
        // now driven through the session layer so runs can be observed,
        // checkpointed mid-flight, and resumed.
        "native" => {
            let mut builder = fdsvrg::session::SessionBuilder::new(algo, &problem, params.clone());
            if let Some(path) = args.get("resume") {
                match fdsvrg::checkpoint::load_any(path)? {
                    fdsvrg::checkpoint::Loaded::Session(sc) => {
                        let st = sc.state;
                        println!(
                            "resuming from {path}: epoch {} ({} trace points)",
                            st.resume.epoch,
                            st.trace.points.len()
                        );
                        builder = builder.resume(st);
                    }
                    fdsvrg::checkpoint::Loaded::Weights(_) => bail!(
                        "{path} is a version-1 final-weights checkpoint (inference-only); \
                         use `fdsvrg predict --ckpt {path}` instead, or train fresh"
                    ),
                }
            }
            let ckpt_path = args.get("ckpt").map(|s| s.to_string());
            if let Some(ckpt) = &ckpt_path {
                let every: usize = args.get_or("save-every", 1usize);
                builder =
                    builder.observe(fdsvrg::session::CheckpointObserver::new(ckpt.clone(), every));
                // Fault plane + durable snapshots: rotate the last few
                // epoch snapshots into <ckpt>.d/ and attach the store to
                // the plan, so an injected crash recovers from the newest
                // on-disk snapshot instead of replaying from the latest
                // in-memory boundary.
                if let Some(plan) = &params.faults {
                    if !plan.crashes().is_empty() {
                        let store = std::sync::Arc::new(fdsvrg::checkpoint::CheckpointStore::new(
                            format!("{ckpt}.d"),
                            3,
                        )?);
                        plan.attach_store(store.clone());
                        builder =
                            builder.observe(fdsvrg::session::CheckpointObserver::rotating(
                                store, every,
                            ));
                    }
                }
            } else if args.get("save-every").is_some() {
                bail!("--save-every needs --ckpt <path> to say where checkpoints go");
            }
            let mut session = builder.build()?;
            while !session.should_stop() {
                session.step();
            }
            // Final flush: the observer only fires on multiples of
            // --save-every, so write the end-of-run state unconditionally
            // (the checkpoint on disk always matches the finished run).
            if let Some(ckpt) = &ckpt_path {
                fdsvrg::checkpoint::SessionCheckpoint::new(session.state()).save(ckpt)?;
                println!("session checkpoint written to {ckpt}");
            }
            session.finish()
        }
        other => {
            anyhow::ensure!(
                args.get("resume").is_none() && args.get("ckpt").is_none(),
                "--resume/--ckpt session checkpointing is available on the native engine only"
            );
            let kind =
                fdsvrg::runtime::EngineKind::parse_or_err(other).map_err(|e| anyhow::anyhow!(e))?;
            let engine = fdsvrg::runtime::build_engine(
                kind,
                Path::new(args.get("artifacts").unwrap_or("artifacts")),
            )?;
            algo.run_blocked(&problem, &params, engine.as_ref())?
        }
    };

    let mut table =
        TextTable::new(vec!["epoch", "objective", "sim time (s)", "scalars", "accuracy"]);
    for p in &res.trace.points {
        table.row(vec![
            format!("{}", p.outer),
            format!("{:.8}", p.objective),
            format!("{:.4}", p.sim_time),
            format!("{}", p.scalars),
            String::new(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "final objective {:.8} | train accuracy {:.2}% | sim {:.3}s | wall {:.3}s | {} bytes on the wire in {} messages ({} scalars; busiest node {} bytes)",
        res.final_objective(),
        100.0 * problem.accuracy(&res.w),
        res.total_sim_time,
        res.total_wall_time,
        res.total_bytes,
        res.total_messages,
        res.total_scalars,
        res.busiest_node_bytes,
    );
    if let Some(test) = &test_ds {
        let m = fdsvrg::eval::evaluate(test, &res.w);
        println!(
            "held-out ({} instances): accuracy {:.2}%  precision {:.3}  recall {:.3}  F1 {:.3}  AUC {:.4}",
            m.n,
            100.0 * m.accuracy,
            m.precision,
            m.recall,
            m.f1,
            m.auc
        );
    }
    if let Some(out) = args.get("out") {
        let path = Path::new(out).join(format!("train_{}_{}.csv", algo.name(), cfg.dataset));
        let f_opt = 0.0; // raw objective column is authoritative here
        res.trace.write_csv(&path, f_opt)?;
        println!("trace written to {}", path.display());
        let jpath = Path::new(out).join(format!("train_{}_{}.json", algo.name(), cfg.dataset));
        fdsvrg::metrics::json::write_json(&res, None, &jpath)?;
        println!("json written to {}", jpath.display());
    }
    if let Some(ckpt) = args.get("save") {
        fdsvrg::checkpoint::Checkpoint::new(algo.name(), &cfg.dataset, cfg.lambda, res.w.clone())
            .save(ckpt)?;
        println!("checkpoint written to {ckpt}");
    }
    Ok(())
}

/// Resolve a checkpoint argument — a v1/v2 file, or a rotating
/// `CheckpointStore` directory where the newest valid snapshot wins — to
/// `(version, algorithm, dataset, lambda, w)`.
fn load_weights(path: &str) -> Result<(u32, String, String, f64, Vec<f64>)> {
    Ok(match fdsvrg::checkpoint::load_newest(path)? {
        fdsvrg::checkpoint::Loaded::Weights(c) => (1, c.algorithm, c.dataset, c.lambda, c.w),
        fdsvrg::checkpoint::Loaded::Session(sc) => {
            let st = sc.state;
            // freshly loaded ⇒ the Arc is uniquely held; unwrap without a copy
            let w = std::sync::Arc::try_unwrap(st.resume.w).unwrap_or_else(|a| (*a).clone());
            (2, st.algorithm, st.dataset, st.lambda, w)
        }
    })
}

/// Inference from a saved checkpoint — v1 final weights or a v2 session
/// snapshot (whose assembled `w` serves equally well). Exercises the
/// backward-compat guarantee: v1 files keep loading after the v2 cut.
/// The margin pass runs once through a reused [`fdsvrg::algs::Workspace`]
/// buffer (no per-instance allocation) and both metrics derive from it.
fn cmd_predict(args: &Args) -> Result<()> {
    let path = args.get("ckpt").context("predict needs --ckpt <file-or-dir>")?;
    let (version, algorithm, dataset, lambda, w) = load_weights(path)?;
    let ds_name = args.get("dataset").map(|s| s.to_string()).unwrap_or_else(|| dataset.clone());
    let ds = load_dataset(&ds_name)?;
    let problem = Problem::logistic_l2(ds, lambda);
    anyhow::ensure!(
        w.len() == problem.d(),
        "checkpoint dim {} does not match dataset {ds_name:?} dim {}",
        w.len(),
        problem.d()
    );
    let mut buf = Vec::new();
    let margins = fdsvrg::serve::dense_margins(&problem.ds.x, &w, &mut buf);
    let (objective, accuracy) = problem.eval_margins(margins, &w);
    println!(
        "checkpoint {path} (v{version}, {algorithm} on {dataset}, λ={lambda:.0e}): \
         objective {objective:.8}, accuracy {:.2}% on {ds_name} ({} instances)",
        100.0 * accuracy,
        problem.n()
    );
    Ok(())
}

/// Sharded margin-merge serving from a checkpoint: split the weights over
/// `--q` feature shards (mirroring the training partition), batch seeded
/// traffic at a router node under the `--serve-batch`/`--serve-delay`
/// policy, and report the latency/throughput profile under the selected
/// network scenario. Entirely simulated time — reports are bit-stable
/// across reruns and `--threads`.
fn cmd_serve(args: &Args) -> Result<()> {
    use fdsvrg::serve::{simulate, BatchPolicy, QuerySource, RobustSpec, ServeSpec};
    let cfg = build_experiment_config(args)?;
    let path = args.get("ckpt").context("serve needs --ckpt <file-or-dir>")?;
    let (version, algorithm, dataset, lambda, w) = load_weights(path)?;
    let ds_name = args.get("dataset").map(|s| s.to_string()).unwrap_or_else(|| dataset.clone());
    let ds = load_dataset(&ds_name)?;
    anyhow::ensure!(
        w.len() == ds.d(),
        "checkpoint dim {} does not match dataset {ds_name:?} dim {}",
        w.len(),
        ds.d()
    );
    // serve the training layout: same balanced-nnz feature partition
    let bounds: Vec<(usize, usize)> = fdsvrg::sparse::partition::by_features(&ds.x, cfg.q)
        .iter()
        .map(|s| (s.row_lo, s.row_hi))
        .collect();
    let model = cfg.net_spec().map_err(|e| anyhow::anyhow!(e))?.resolve(cfg.sim_params());
    let mode = cfg.serve_arrival_mode().map_err(|e| anyhow::anyhow!(e))?;
    let spec = ServeSpec {
        w: &w,
        bounds,
        model,
        wire: cfg.wire,
        policy: BatchPolicy { max_batch: cfg.serve_batch, max_delay: cfg.serve_delay },
        queries: cfg.serve_queries,
        mode,
        seed: cfg.seed,
        source: QuerySource::Columns(std::sync::Arc::new(ds.x)),
        collect_margins: false,
        robust: RobustSpec {
            replicas: cfg.serve_replicas,
            deadline: cfg.serve_deadline,
            hedge: cfg.serve_hedge,
            queue_cap: cfg.serve_queue_cap,
            faults: fdsvrg::net::fault::FaultPlan::parse(&cfg.faults, cfg.seed)
                .map_err(|e| anyhow::anyhow!(e))?,
        },
    };
    let r = simulate(&spec).map_err(|e| anyhow::anyhow!(e))?.report;
    println!(
        "serve {path} (v{version}, {algorithm} on {dataset}, λ={lambda:.0e}): \
         q={}×{} replicas, wire={}, scenario={}, faults={}, mode={}, batch≤{} \
         ({} batches, mean {:.1} queries/batch)",
        r.q, r.replicas, r.wire, r.scenario, r.faults, r.mode, r.max_batch, r.batches, r.mean_batch
    );
    println!(
        "  {} queries in {:.4}s sim: {:.0} qps, p50 {:.1}µs p90 {:.1}µs \
         p99 {:.1}µs max {:.1}µs, {} wire bytes ({:.1} B/query)",
        r.queries,
        r.sim_time_s,
        r.qps,
        r.p50_us,
        r.p90_us,
        r.p99_us,
        r.max_us,
        r.wire_bytes,
        r.bytes_per_query
    );
    println!(
        "  availability {:.2}% ({} ok / {} degraded / {} late / {} shed of {} offered), \
         goodput {:.0} qps, {} failovers, {} retries, {} hedged ({} wins), {} crashes",
        r.availability_pct,
        r.ok,
        r.degraded,
        r.late,
        r.shed,
        r.answered + r.shed,
        r.goodput_qps,
        r.failovers,
        r.retries,
        r.hedged,
        r.hedge_wins,
        r.crashes
    );
    if let Some(out) = args.get("out") {
        std::fs::write(out, format!("{}\n", r.to_json_row()))
            .with_context(|| format!("writing {out}"))?;
        println!("report written to {out}");
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let out = args.get("out").unwrap_or("results");
    std::fs::create_dir_all(out).ok();
    let mut ctx =
        if args.flag("quick") { exp::Ctx::quick(Path::new(out)) } else { exp::Ctx::new(Path::new(out)) };
    ctx.cfg = build_experiment_config(args)?;
    match args.positional().first().map(|s| s.as_str()) {
        Some("fig6") | Some("fig7") => exp::fig6_fig7(&ctx, &exp::paper_grid()),
        Some("fig8") => exp::fig8(&ctx),
        Some("fig9") => exp::fig9(&ctx).map(|_| ()),
        Some("table1") => exp::table1(),
        Some("table2") => exp::table2(&ctx).map(|_| ()),
        Some("table3") => exp::table3(&ctx).map(|_| ()),
        Some("wire") => exp::wire_ablation(&ctx).map(|_| ()),
        Some("netmodel") => exp::netmodel_ablation(&ctx).map(|_| ()),
        Some("compress") => exp::compress_ablation(&ctx).map(|_| ()),
        Some("calibrate") => exp::calibrate(&ctx).map(|_| ()),
        Some("faults") => exp::faults(&ctx).map(|_| ()),
        Some("serving") => exp::serving(&ctx).map(|_| ()),
        Some("serving-faults") => exp::serving_faults(&ctx).map(|_| ()),
        Some("all") | None => exp::all(&ctx),
        Some(other) => bail!("unknown experiment {other:?}"),
    }
}

/// Hidden entrypoint: one `--transport tcp` cluster node, re-exec'd by the
/// monitor process. The run spec and rendezvous coordinates arrive in
/// environment variables; the node rebuilds the identical problem and
/// parameters from the spec (same profile generators, same seeds), joins
/// the socket mesh, and runs its node closure to completion.
fn cmd_worker() -> Result<()> {
    use fdsvrg::net::transport::tcp;
    let spec = std::env::var(tcp::ENV_SPEC).context(tcp::ENV_SPEC)?;
    let id: usize = std::env::var(tcp::ENV_ID).context(tcp::ENV_ID)?.parse()?;
    let n_nodes: usize = std::env::var(tcp::ENV_NODES).context(tcp::ENV_NODES)?.parse()?;
    let port: u16 = std::env::var(tcp::ENV_PORT).context(tcp::ENV_PORT)?.parse()?;
    let doc = Config::parse(&spec).context("worker: malformed spec")?;
    let cfg = ExperimentConfig::from_config(&doc);
    let algo = Algorithm::parse_or_err(&cfg.algo).map_err(|e| anyhow::anyhow!(e))?;
    let ds = load_dataset(&cfg.dataset)?;
    // mirror the monitor's held-out split exactly (same frac, same seed)
    let test_frac = doc.f64_or("run.test_frac", 0.0);
    let ds = if test_frac > 0.0 {
        fdsvrg::eval::train_test_split(&ds, test_frac, cfg.seed).0
    } else {
        ds
    };
    let problem = Problem::logistic_l2(ds, cfg.lambda);
    let mut params: RunParams = cfg.run_params();
    params.star_reduce = doc.bool_or("run.star", false);
    let driver = algo.make_cluster_driver(&problem, &params, None)?;
    let transport = tcp::worker_connect(id, n_nodes, port, cfg.rendezvous_timeout)
        .with_context(|| format!("worker node {id}: rendezvous"))?;
    // test hook: this node dies right after rendezvous, so teardown tests
    // can assert the monitor names it instead of hanging
    if std::env::var(tcp::ENV_TEST_EXIT).ok().as_deref() == Some(id.to_string().as_str()) {
        return Ok(());
    }
    driver.run_node(id, Box::new(transport));
    Ok(())
}

fn cmd_data(args: &Args) -> Result<()> {
    match args.positional().first().map(|s| s.as_str()) {
        Some("stats") | None => exp::table1(),
        Some("gen") => {
            let profile = args.get("profile").unwrap_or("tiny");
            let ds = profiles::load(profile)
                .with_context(|| format!("unknown profile {profile:?}"))?;
            let out = args.get("out").map(|s| s.to_string()).unwrap_or(format!("{profile}.libsvm"));
            fdsvrg::sparse::libsvm::write_file(&ds, &out)?;
            println!("wrote {} ({} instances, {} features, {} nnz)", out, ds.n(), ds.d(), ds.nnz());
            Ok(())
        }
        Some(other) => bail!("unknown data command {other:?}"),
    }
}

fn cmd_check_engine(args: &Args) -> Result<()> {
    let dir = args.get("dir").unwrap_or("artifacts");
    // default backend of this build: xla when compiled in, else native.
    // (Unlike `train`, there is no sparse path here — "block" is the
    // canonical name for the pure-Rust backend.)
    let kind = match args.get("engine") {
        Some(s) => fdsvrg::runtime::EngineKind::parse_or_err(s).map_err(|e| anyhow::anyhow!(e))?,
        None => fdsvrg::runtime::EngineKind::default_for_build(),
    };
    let engine = fdsvrg::runtime::build_engine(kind, Path::new(dir))?;
    // smoke: run a partial-products call on a simple pattern
    use fdsvrg::runtime::{BLOCK_D, BLOCK_N};
    let w = vec![1f32; BLOCK_D];
    let mut d_block = vec![0f32; BLOCK_D * BLOCK_N];
    d_block[0] = 2.0; // instance 0 has one feature with value 2
    let s = engine.partial_products(&w, &d_block)?;
    anyhow::ensure!((s[0] - 2.0).abs() < 1e-6, "partial_products smoke failed: {}", s[0]);
    anyhow::ensure!(s[1].abs() < 1e-6, "padding must contribute zero");
    println!(
        "engine `{}` OK: {} kernels responding",
        engine.name(),
        fdsvrg::runtime::ARTIFACTS.len()
    );
    Ok(())
}
