//! # FD-SVRG — Feature-Distributed SVRG for High-Dimensional Linear Classification
//!
//! A full reproduction of Zhang, Zhao, Gao & Li (2018). The library is the
//! Layer-3 coordinator of a three-layer rust + JAX + Pallas stack:
//!
//! * [`algs::fdsvrg`] — the paper's contribution: a feature-distributed SVRG
//!   coordinator where workers hold feature *slabs* of the data matrix and
//!   exchange only scalars through a tree-structured reduce/broadcast.
//! * [`algs`] — every baseline the paper evaluates against, built on the same
//!   substrate: serial SVRG/SGD, DSVRG (decentralized ring), a
//!   Parameter-Server framework hosting SynSVRG, AsySVRG and PS-Lite-style
//!   asynchronous SGD.
//! * [`net`] / [`cluster`] — an in-process multi-node cluster simulator with
//!   a typed wire layer ([`net::payload`]: `f64`/`f32`/sparse codecs over
//!   `Arc` buffers, shared zero-copy collectives in [`net::collectives`]),
//!   byte-accurate per-sender communication accounting (scalars kept as
//!   the derived §4.5 view) and a latency/bandwidth simulated clock,
//!   standing in for the paper's 16-node 10GbE testbed.
//! * [`runtime`] — the blocked dense trainer behind the backend-agnostic
//!   [`runtime::ComputeEngine`] trait: a pure-Rust f32 backend (the
//!   default; fully offline) and a PJRT backend (`--features xla`) that
//!   loads the AOT-compiled HLO artifacts produced by the JAX/Pallas
//!   build layer (`python/compile/`); python never runs at training time.
//! * [`sparse`] / [`linalg`] / [`loss`] / [`data`] — the data-plane
//!   substrates: CSC/CSR sparse matrices, the LibSVM text format, dense
//!   kernels, the paper's loss functions, and synthetic dataset generators
//!   matched to the paper's four benchmark datasets.
//!
//! See `DESIGN.md` for the three-layer architecture, the module
//! inventory, the engine feature matrix, and how to run the tier-1
//! checks.

pub mod algs;
pub mod bench;
pub mod checkpoint;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod data;
pub mod eval;
pub mod exp;
pub mod linalg;
pub mod loss;
pub mod metrics;
pub mod multiclass;
pub mod net;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod sparse;
pub mod testkit;
pub mod util;
