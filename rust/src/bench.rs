//! Benchmark harness (criterion-lite; `criterion` is unavailable offline).
//!
//! Cargo benches (`benches/*.rs`, `harness = false`) build a [`Bench`],
//! register closures, and get warmup + repeated timing with mean / median /
//! stddev reporting. End-to-end paper-table benches use [`Bench::once`]
//! (long-running convergence runs are measured once and reported as-is;
//! their interesting output is the table itself, not nanosecond noise).
//!
//! `cargo bench -- <filter>` runs only matching entries, like criterion.
//! `cargo bench -- --json <path>` redirects a suite's JSON report to
//! `<path>` (suites that persist a repo baseline keep their default file
//! when the flag is absent, and still refuse to overwrite it when a filter
//! is active — see [`Bench::is_filtered`]).

use crate::util::time::Stopwatch;
use crate::util::{mean, median, stddev};

/// Measured timings of one benchmark entry.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
}

pub struct Bench {
    suite: String,
    filter: Option<String>,
    json_path: Option<String>,
    warmup_iters: usize,
    measure_iters: usize,
    samples: Vec<Sample>,
}

/// Split bench argv into (filter, json path): the filter is the first
/// non-dash token that is not the value of `--json`.
fn parse_argv(argv: &[String]) -> (Option<String>, Option<String>) {
    let mut filter = None;
    let mut json_path = None;
    let mut i = 0;
    while i < argv.len() {
        if argv[i] == "--json" {
            if let Some(v) = argv.get(i + 1) {
                json_path = Some(v.clone());
                i += 2;
                continue;
            }
        } else if !argv[i].starts_with('-') && filter.is_none() {
            filter = Some(argv[i].clone());
        }
        i += 1;
    }
    (filter, json_path)
}

impl Bench {
    /// Build from process args (`cargo bench -- [filter] [--json path]`).
    pub fn from_args(suite: &str) -> Bench {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let (filter, json_path) = parse_argv(&argv);
        Bench {
            suite: suite.to_string(),
            filter,
            json_path,
            warmup_iters: 2,
            measure_iters: 5,
            samples: Vec::new(),
        }
    }

    /// The `--json <path>` override, when given on the bench command line.
    pub fn json_path(&self) -> Option<&str> {
        self.json_path.as_deref()
    }

    pub fn with_iters(mut self, warmup: usize, measure: usize) -> Bench {
        self.warmup_iters = warmup;
        self.measure_iters = measure;
        self
    }

    /// Whether an entry named `name` would run under the active filter
    /// (benches use this to skip expensive setup for filtered-out cases).
    pub fn enabled(&self, name: &str) -> bool {
        self.filter.as_deref().map(|f| name.contains(f)).unwrap_or(true)
    }

    /// Timed micro-benchmark: warmup + N measured iterations.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) {
        if !self.enabled(name) {
            return;
        }
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut times = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let sw = Stopwatch::start();
            f();
            times.push(sw.seconds());
        }
        let s = Sample {
            name: name.to_string(),
            iters: self.measure_iters,
            mean_s: mean(&times),
            median_s: median(&times),
            stddev_s: stddev(&times),
        };
        println!(
            "{:<44} {:>12.6}s mean  {:>12.6}s median  ±{:>10.6}s  (n={})",
            s.name, s.mean_s, s.median_s, s.stddev_s, s.iters
        );
        self.samples.push(s);
    }

    /// One-shot measurement for long end-to-end runs (paper tables).
    pub fn once<F: FnOnce()>(&mut self, name: &str, f: F) {
        if !self.enabled(name) {
            return;
        }
        let sw = Stopwatch::start();
        f();
        let t = sw.seconds();
        println!("{:<44} {:>12.3}s (single run)", name, t);
        self.samples.push(Sample {
            name: name.to_string(),
            iters: 1,
            mean_s: t,
            median_s: t,
            stddev_s: 0.0,
        });
    }

    /// All samples recorded so far (benches that persist a baseline file
    /// read these back out before `finish`).
    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    /// Whether a `cargo bench -- <filter>` filter is active. Baseline
    /// writers skip persisting under a filter — a partial run must never
    /// overwrite a full baseline.
    pub fn is_filtered(&self) -> bool {
        self.filter.is_some()
    }

    /// Serialize the recorded samples as a small JSON document (no
    /// `serde` offline — the format is flat enough to emit by hand).
    pub fn to_json(&self, note: &str) -> String {
        let esc = crate::metrics::json::esc;
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"suite\": \"{}\",\n", esc(&self.suite)));
        out.push_str(&format!("  \"note\": \"{}\",\n", esc(note)));
        out.push_str("  \"entries\": [\n");
        for (i, s) in self.samples.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"iters\": {}, \"mean_s\": {:.6e}, \"median_s\": {:.6e}, \"stddev_s\": {:.6e}}}{}\n",
                esc(&s.name),
                s.iters,
                s.mean_s,
                s.median_s,
                s.stddev_s,
                if i + 1 < self.samples.len() { "," } else { "" },
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the JSON document to `path`.
    pub fn write_json(&self, path: &str, note: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json(note))
    }

    /// Print the suite footer; call at the end of main().
    pub fn finish(self) {
        println!("── {} : {} entries ──", self.suite, self.samples.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples() {
        let mut b = Bench {
            suite: "t".into(),
            filter: None,
            json_path: None,
            warmup_iters: 1,
            measure_iters: 3,
            samples: vec![],
        };
        let mut count = 0;
        b.bench("noop", || count += 1);
        assert_eq!(count, 4); // 1 warmup + 3 measured
        assert_eq!(b.samples.len(), 1);
        assert_eq!(b.samples[0].iters, 3);
    }

    #[test]
    fn filter_skips_nonmatching() {
        let mut b = Bench {
            suite: "t".into(),
            filter: Some("keep".into()),
            json_path: None,
            warmup_iters: 0,
            measure_iters: 1,
            samples: vec![],
        };
        let mut ran = false;
        b.bench("skip_this", || ran = true);
        assert!(!ran);
        b.bench("keep_this", || ran = true);
        assert!(ran);
    }

    #[test]
    fn once_runs_exactly_once() {
        let mut b = Bench {
            suite: "t".into(),
            filter: None,
            json_path: None,
            warmup_iters: 5,
            measure_iters: 5,
            samples: vec![],
        };
        let mut count = 0;
        b.once("single", || count += 1);
        assert_eq!(count, 1);
    }

    #[test]
    fn parse_argv_splits_filter_and_json() {
        let to = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        assert_eq!(parse_argv(&to(&[])), (None, None));
        assert_eq!(parse_argv(&to(&["simd"])), (Some("simd".into()), None));
        assert_eq!(
            parse_argv(&to(&["--json", "out.json"])),
            (None, Some("out.json".into())),
        );
        // the --json value must not be mistaken for the filter, in either order
        assert_eq!(
            parse_argv(&to(&["--json", "out.json", "simd"])),
            (Some("simd".into()), Some("out.json".into())),
        );
        assert_eq!(
            parse_argv(&to(&["simd", "--json", "out.json"])),
            (Some("simd".into()), Some("out.json".into())),
        );
        // cargo's own --bench-ish dashed args are ignored; bare --json too
        assert_eq!(parse_argv(&to(&["--bench", "--json"])), (None, None));
    }
}
