//! Experiment drivers — one per paper table/figure (see DESIGN.md §4).
//!
//! Each driver runs the relevant algorithm grid, writes per-run CSV traces
//! under `results/`, and prints the paper's table/series to stdout. The
//! bench targets in `benches/` call straight into these.

use crate::algs::{serial, Algorithm, Problem, RunParams};
use crate::config::ExperimentConfig;
use crate::data::profiles;
use crate::metrics::plot::{AsciiPlot, Series};
use crate::metrics::{RunResult, TextTable};
use crate::session::{SessionBuilder, StopPolicy};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Shared driver context.
pub struct Ctx {
    pub out_dir: PathBuf,
    /// Scale factor on epoch budgets (quick CI runs use < 1).
    pub scale: f64,
    /// Extra scale on the parameter-server SVRG baselines (SynSVRG /
    /// AsySVRG). Their per-epoch traffic is Θ(N·d) scalars of actual
    /// memcpy in the simulator, so bench runs shrink *their* budgets
    /// while keeping FD-SVRG/DSVRG at full fidelity — the PS methods'
    /// ">cap" shape is unchanged, only the drawn curve is shorter.
    pub ps_scale: f64,
    pub cfg: ExperimentConfig,
}

impl Ctx {
    pub fn new(out_dir: &Path) -> Ctx {
        Ctx {
            out_dir: out_dir.to_path_buf(),
            scale: 1.0,
            ps_scale: 1.0,
            cfg: ExperimentConfig::default(),
        }
    }

    pub fn quick(out_dir: &Path) -> Ctx {
        let mut c = Ctx::new(out_dir);
        c.scale = 0.25;
        c.ps_scale = 0.25;
        c
    }

    /// Bench-mode context: full budgets for the cheap algorithms, scaled
    /// PS baselines (set `FDSVRG_BENCH_FULL=1` for the paper-budget run,
    /// `FDSVRG_BENCH_QUICK=1` for a CI-speed smoke of every table/figure).
    pub fn bench(out_dir: &Path) -> Ctx {
        let mut c = Ctx::new(out_dir);
        if std::env::var("FDSVRG_BENCH_QUICK").as_deref() == Ok("1") {
            c.scale = 0.5;
            c.ps_scale = 0.1;
        } else if std::env::var("FDSVRG_BENCH_FULL").as_deref() != Ok("1") {
            c.ps_scale = 0.2;
        }
        c
    }

    fn epochs(&self, base: usize) -> usize {
        ((base as f64 * self.scale).round() as usize).max(2)
    }

    /// Load a dataset profile + build the experiment problem.
    pub fn problem(&self, profile: &str, lambda: f64) -> Result<Problem> {
        let ds = profiles::load(profile)
            .with_context(|| format!("unknown dataset profile {profile:?}"))?;
        Ok(Problem::logistic_l2(ds, lambda))
    }

    /// Reference optimum, cached under `artifacts/optima/`.
    pub fn optimum(&self, problem: &Problem) -> (Vec<f64>, f64) {
        serial::cached_optimum(problem, Path::new("artifacts/optima"), 60)
    }

    fn base_params(&self, q: usize) -> RunParams {
        let mut p = self.cfg.run_params();
        p.q = q;
        p
    }
}

/// The four dataset profiles in paper (Table 1) order with their paper
/// worker counts.
pub fn paper_grid() -> Vec<(&'static str, usize)> {
    profiles::PROFILE_NAMES
        .iter()
        .map(|&p| (p, profiles::paper_worker_count(p)))
        .collect()
}

/// Run one (algorithm, params) cell through the session layer with the
/// driver's stop policies spelled out explicitly (rather than smuggled in
/// through `RunParams` fields), then persist the trace.
fn run_and_save(
    ctx: &Ctx,
    problem: &Problem,
    algo: Algorithm,
    params: &RunParams,
    policies: &[StopPolicy],
    f_opt: f64,
    tag: &str,
) -> RunResult {
    let mut builder = SessionBuilder::new(algo, problem, params.clone());
    for &p in policies {
        builder = builder.stop_when(p);
    }
    let res = builder.build().expect("fresh experiment session").run_to_completion();
    let csv = ctx.out_dir.join(format!("{tag}_{}.csv", algo.name()));
    if let Err(e) = res.trace.write_csv(&csv, f_opt) {
        crate::util::logger::log(
            crate::util::logger::Level::Warn,
            format_args!("csv write failed: {e:#}"),
        );
    }
    res
}

/// Figures 6 & 7: gap-vs-time and gap-vs-communication on the four
/// datasets for {FD-SVRG, DSVRG, SynSVRG, AsySVRG}, λ=1e-4. One run per
/// (dataset, algorithm) produces both figures' series (the trace carries
/// both axes).
pub fn fig6_fig7(ctx: &Ctx, datasets: &[(&str, usize)]) -> Result<()> {
    for &(profile, q) in datasets {
        let problem = ctx.problem(profile, ctx.cfg.lambda)?;
        let (_, f_opt) = ctx.optimum(&problem);
        let mut table = TextTable::new(vec![
            "algorithm",
            "epochs",
            "final gap",
            "sim time (s)",
            "scalars",
            "bytes",
            "time to 1e-4 (s)",
            "bytes to 1e-4",
        ]);
        println!("== Fig 6/7 :: {profile} (q={q}, λ={:.0e}) ==", ctx.cfg.lambda);
        let mut plot_t = AsciiPlot::new(
            &format!("Fig 6 :: {profile} — objective gap vs simulated time (s)"),
            "time (s)",
        );
        let mut plot_c = AsciiPlot::new(
            &format!("Fig 7 :: {profile} — objective gap vs bytes on the wire"),
            "bytes on the wire",
        );
        for algo in Algorithm::ALL_DISTRIBUTED {
            let mut params = ctx.base_params(q);
            let ps = matches!(algo, Algorithm::SynSvrg | Algorithm::AsySvrg);
            let budget = if ps {
                ((default_epochs(algo) as f64) * ctx.ps_scale).round() as usize
            } else {
                default_epochs(algo)
            };
            params.outer = ctx.epochs(budget);
            let gap = StopPolicy::GapReached { f_opt, target: ctx.cfg.gap_target / 10.0 };
            let tag = format!("fig6_{profile}");
            let res = run_and_save(ctx, &problem, algo, &params, &[gap], f_opt, &tag);
            let tt = res.trace.time_to_gap(f_opt, ctx.cfg.gap_target);
            // bytes, to match the Fig-7 plot axis (comm_to_gap keeps the
            // scalar view for callers that want the §4.5 unit)
            let cc = res.trace.bytes_to_gap(f_opt, ctx.cfg.gap_target);
            plot_t.add(Series::gap_vs_time(algo.name(), &res.trace, f_opt));
            plot_c.add(Series::gap_vs_comm(algo.name(), &res.trace, f_opt));
            table.row(vec![
                algo.name().to_string(),
                format!("{}", res.trace.points.len() - 1),
                format!("{:.3e}", res.final_objective() - f_opt),
                format!("{:.4}", res.total_sim_time),
                format!("{}", res.total_scalars),
                format!("{}", res.total_bytes),
                tt.map(|t| format!("{t:.4}")).unwrap_or_else(|| ">cap".into()),
                cc.map(|c| format!("{c}")).unwrap_or_else(|| ">cap".into()),
            ]);
        }
        println!("{}", table.render());
        println!("{}", plot_t.render());
        println!("{}", plot_c.render());
    }
    Ok(())
}

/// Figure 8: webspam with λ ∈ {1e-3, 1e-5}.
pub fn fig8(ctx: &Ctx) -> Result<()> {
    for lambda in [1e-3, 1e-5] {
        let mut sub = Ctx {
            out_dir: ctx.out_dir.clone(),
            scale: ctx.scale,
            ps_scale: ctx.ps_scale,
            cfg: ExperimentConfig { lambda, ..ctx.cfg.clone() },
        };
        // smaller λ ⇒ worse conditioning ⇒ longer runs
        if lambda < 1e-4 {
            sub.scale = ctx.scale * 2.0;
        }
        println!("-- Fig 8: λ = {lambda:.0e} --");
        fig6_fig7(&sub, &[("webspam-sim", 16)])?;
    }
    Ok(())
}

/// Figure 9: FD-SVRG speedup vs q on webspam-sim.
///
/// speedup(q) = sim time with 1 worker / sim time with q workers, measured
/// at the paper's gap target.
pub fn fig9(ctx: &Ctx) -> Result<Vec<(usize, f64)>> {
    let problem = ctx.problem("webspam-sim", ctx.cfg.lambda)?;
    let (_, f_opt) = ctx.optimum(&problem);
    let mut times = Vec::new();
    for q in [1usize, 4, 8, 16] {
        let mut params = ctx.base_params(q);
        params.outer = ctx.epochs(default_epochs(Algorithm::FdSvrg));
        let gap = StopPolicy::GapReached { f_opt, target: ctx.cfg.gap_target / 10.0 };
        let tag = format!("fig9_q{q}");
        let res = run_and_save(ctx, &problem, Algorithm::FdSvrg, &params, &[gap], f_opt, &tag);
        let t = res
            .trace
            .time_to_gap(f_opt, ctx.cfg.gap_target)
            .unwrap_or(res.total_sim_time);
        times.push((q, t));
    }
    let t1 = times[0].1;
    let mut table = TextTable::new(vec!["q", "time to gap (s)", "speedup", "ideal"]);
    let mut out = Vec::new();
    for &(q, t) in &times {
        let s = t1 / t;
        table.row(vec![
            format!("{q}"),
            format!("{t:.4}"),
            format!("{s:.2}"),
            format!("{q}"),
        ]);
        out.push((q, s));
    }
    println!("== Fig 9 :: FD-SVRG speedup on webspam-sim ==");
    println!("{}", table.render());
    Ok(out)
}

/// Table 2: time-to-gap≤1e-4, DSVRG vs FD-SVRG, and the speedup row.
pub fn table2(ctx: &Ctx) -> Result<Vec<(String, f64, f64)>> {
    let mut rows = Vec::new();
    let mut table = TextTable::new(vec!["dataset", "DSVRG (s)", "FD-SVRG (s)", "speedup"]);
    for (profile, q) in paper_grid() {
        let problem = ctx.problem(profile, ctx.cfg.lambda)?;
        let (_, f_opt) = ctx.optimum(&problem);
        let time_of = |algo: Algorithm| -> f64 {
            let mut params = ctx.base_params(q);
            params.outer = ctx.epochs(default_epochs(algo));
            let gap = StopPolicy::GapReached { f_opt, target: ctx.cfg.gap_target / 10.0 };
            let tag = format!("table2_{profile}");
            let res = run_and_save(ctx, &problem, algo, &params, &[gap], f_opt, &tag);
            res.trace
                .time_to_gap(f_opt, ctx.cfg.gap_target)
                .unwrap_or(res.total_sim_time)
        };
        let t_dsvrg = time_of(Algorithm::Dsvrg);
        let t_fd = time_of(Algorithm::FdSvrg);
        table.row(vec![
            profile.to_string(),
            format!("{t_dsvrg:.4}"),
            format!("{t_fd:.4}"),
            format!("{:.2}", t_dsvrg / t_fd),
        ]);
        rows.push((profile.to_string(), t_dsvrg, t_fd));
    }
    println!("== Table 2 :: speedup to DSVRG ==");
    println!("{}", table.render());
    Ok(rows)
}

/// Table 3: time-to-gap≤1e-4, PS-Lite(SGD) vs FD-SVRG, with the paper's
/// ">cap" semantics when SGD fails to reach the target.
pub fn table3(ctx: &Ctx) -> Result<Vec<(String, Option<f64>, f64)>> {
    let mut rows = Vec::new();
    let mut table =
        TextTable::new(vec!["dataset", "PS-Lite(SGD) (s)", "FD-SVRG (s)", "speedup"]);
    for (profile, q) in paper_grid() {
        let problem = ctx.problem(profile, ctx.cfg.lambda)?;
        let (_, f_opt) = ctx.optimum(&problem);
        // FD-SVRG side
        let mut params = ctx.base_params(q);
        params.outer = ctx.epochs(default_epochs(Algorithm::FdSvrg));
        let gap = StopPolicy::GapReached { f_opt, target: ctx.cfg.gap_target / 10.0 };
        let res_fd = run_and_save(
            ctx,
            &problem,
            Algorithm::FdSvrg,
            &params,
            &[gap],
            f_opt,
            &format!("table3_{profile}"),
        );
        let t_fd = res_fd
            .trace
            .time_to_gap(f_opt, ctx.cfg.gap_target)
            .unwrap_or(res_fd.total_sim_time);
        // PS-Lite(SGD) side, capped at 100× the FD time (the paper reports
        // ">1000s"-style rows when SGD never reaches the target)
        let cap = (t_fd * 100.0).max(1.0);
        let mut sgd_params = ctx.base_params(q);
        sgd_params.servers = 8; // paper §5.2
        sgd_params.outer = ctx.epochs(default_epochs(Algorithm::PsLiteSgd));
        let res_sgd = run_and_save(
            ctx,
            &problem,
            Algorithm::PsLiteSgd,
            &sgd_params,
            &[
                StopPolicy::GapReached { f_opt, target: ctx.cfg.gap_target },
                StopPolicy::SimTimeCap(cap),
            ],
            f_opt,
            &format!("table3_{profile}"),
        );
        let t_sgd = res_sgd.trace.time_to_gap(f_opt, ctx.cfg.gap_target);
        let (sgd_cell, speedup_cell) = match t_sgd {
            Some(t) => (format!("{t:.4}"), format!("{:.0}", t / t_fd)),
            None => (format!(">{:.1}", res_sgd.total_sim_time), format!(">{:.0}", res_sgd.total_sim_time / t_fd)),
        };
        table.row(vec![profile.to_string(), sgd_cell, format!("{t_fd:.4}"), speedup_cell]);
        rows.push((profile.to_string(), t_sgd, t_fd));
    }
    println!("== Table 3 :: speedup to PS-Lite (SGD) ==");
    println!("{}", table.render());
    Ok(rows)
}

/// Wire-format ablation: FD-SVRG under `f64`/`f32`/`sparse` payload
/// codecs on the `url-sim` and `news20-sim` profiles — objective gap vs
/// bytes on the wire. `f32` halves the bytes of the same trajectory (up
/// to rounding); `sparse` pays 8 B per nonzero, which loses on the dense
/// margin payloads and quantifies why the codec choice matters.
/// Returns `(profile, wire, total_bytes, final_gap)` rows.
pub fn wire_ablation(ctx: &Ctx) -> Result<Vec<(String, &'static str, u64, f64)>> {
    use crate::net::WireFmt;
    let mut rows = Vec::new();
    for profile in ["url-sim", "news20-sim"] {
        let q = profiles::paper_worker_count(profile);
        let problem = ctx.problem(profile, ctx.cfg.lambda)?;
        let (_, f_opt) = ctx.optimum(&problem);
        let mut table = TextTable::new(vec![
            "wire",
            "final gap",
            "total bytes",
            "busiest node bytes",
            "messages",
            "sim time (s)",
        ]);
        let mut plot = AsciiPlot::new(
            &format!("Wire ablation :: {profile} — objective gap vs bytes on the wire"),
            "bytes on the wire",
        );
        println!("== Wire ablation :: {profile} (q={q}, λ={:.0e}) ==", ctx.cfg.lambda);
        for wire in WireFmt::ALL {
            let mut params = ctx.base_params(q);
            params.outer = ctx.epochs(default_epochs(Algorithm::FdSvrg) / 3);
            params.wire = wire;
            let res = run_and_save(
                ctx,
                &problem,
                Algorithm::FdSvrg,
                &params,
                &[StopPolicy::GapReached { f_opt, target: ctx.cfg.gap_target / 10.0 }],
                f_opt,
                &format!("wire_{profile}_{}", wire.name()),
            );
            let gap = res.final_objective() - f_opt;
            plot.add(Series::gap_vs_comm(wire.name(), &res.trace, f_opt));
            table.row(vec![
                wire.name().to_string(),
                format!("{gap:.3e}"),
                format!("{}", res.total_bytes),
                format!("{}", res.busiest_node_bytes),
                format!("{}", res.total_messages),
                format!("{:.4}", res.total_sim_time),
            ]);
            rows.push((profile.to_string(), wire.name(), res.total_bytes, gap));
        }
        println!("{}", table.render());
        println!("{}", plot.render());
    }
    Ok(rows)
}

/// Network-model ablation (`exp netmodel`): FD-SVRG vs the PS baselines
/// (SynSVRG, PS-Lite SGD) under the four `net::model` scenarios on
/// `url-sim`/`news20-sim` — objective gap vs *simulated time*. This is
/// the stress test of the paper's Fig.-7 wall-clock claim: FD-SVRG's
/// advantage comes from moving fewer bytes, so it should widen (not
/// vanish) on degraded networks — cross-rack bottlenecks, designated
/// stragglers, noisy switches. The per-node clock-skew column shows how
/// unevenly each scenario loads the cluster. Returns
/// `(profile, scenario, algorithm, sim_time, final_gap, clock_skew)`
/// rows.
#[allow(clippy::type_complexity)]
pub fn netmodel_ablation(
    ctx: &Ctx,
) -> Result<Vec<(String, &'static str, &'static str, f64, f64, f64)>> {
    let mut rows = Vec::new();
    let scenarios = ["uniform", "hetero", "straggler", "jitter"];
    for profile in ["url-sim", "news20-sim"] {
        let q = profiles::paper_worker_count(profile);
        let problem = ctx.problem(profile, ctx.cfg.lambda)?;
        let (_, f_opt) = ctx.optimum(&problem);
        for scenario in scenarios {
            let spec = ctx
                .cfg
                .net_spec_for(scenario)
                .expect("built-in scenario kinds always parse");
            let mut table = TextTable::new(vec![
                "algorithm",
                "epochs",
                "final gap",
                "sim time (s)",
                "clock skew (s)",
                "time to 1e-4 (s)",
            ]);
            let mut plot = AsciiPlot::new(
                &format!(
                    "Net-model ablation :: {profile} / {scenario} — objective gap vs simulated time (s)"
                ),
                "time (s)",
            );
            println!(
                "== Net-model ablation :: {profile} / {scenario} (q={q}, λ={:.0e}) ==",
                ctx.cfg.lambda
            );
            for algo in [Algorithm::FdSvrg, Algorithm::SynSvrg, Algorithm::PsLiteSgd] {
                let mut params = ctx.base_params(q);
                params.net = spec.clone();
                let ps = !matches!(algo, Algorithm::FdSvrg);
                let budget = if ps {
                    ((default_epochs(algo) as f64) * ctx.ps_scale).round() as usize
                } else {
                    default_epochs(algo)
                };
                params.outer = ctx.epochs(budget);
                let gap = StopPolicy::GapReached { f_opt, target: ctx.cfg.gap_target / 10.0 };
                let res = run_and_save(
                    ctx,
                    &problem,
                    algo,
                    &params,
                    &[gap],
                    f_opt,
                    &format!("netmodel_{profile}_{scenario}"),
                );
                let final_gap = res.final_objective() - f_opt;
                let tt = res.trace.time_to_gap(f_opt, ctx.cfg.gap_target);
                plot.add(Series::gap_vs_time(algo.name(), &res.trace, f_opt));
                table.row(vec![
                    algo.name().to_string(),
                    format!("{}", res.trace.points.len() - 1),
                    format!("{final_gap:.3e}"),
                    format!("{:.4}", res.total_sim_time),
                    format!("{:.6}", res.clock_skew),
                    tt.map(|t| format!("{t:.4}")).unwrap_or_else(|| ">cap".into()),
                ]);
                rows.push((
                    profile.to_string(),
                    scenario,
                    algo.name(),
                    res.total_sim_time,
                    final_gap,
                    res.clock_skew,
                ));
            }
            println!("{}", table.render());
            println!("{}", plot.render());
        }
    }
    Ok(rows)
}

/// Compression ablation (`exp compress`): the distributed algorithms
/// under gradient sparsification — objective gap vs bytes on the wire vs
/// simulated time on `url-sim`/`news20-sim`. Three modes per profile:
/// the exact baseline, `topk:<k>` with `k = N/16` (every counted
/// N-vector sheds ≥ 15/16 of its coordinates), and a magnitude
/// threshold. This is the comm-side twin of the paper's low-communication
/// claim: FD-SVRG already moves the fewest bytes, and sparsification
/// should cut its wire total further at a matched gap. Returns
/// `(profile, compress, algorithm, total_bytes, final_gap, sim_time)`
/// rows.
#[allow(clippy::type_complexity)]
pub fn compress_ablation(
    ctx: &Ctx,
) -> Result<Vec<(String, String, &'static str, u64, f64, f64)>> {
    use crate::net::Compression;
    let mut rows = Vec::new();
    for profile in ["url-sim", "news20-sim"] {
        let q = profiles::paper_worker_count(profile);
        let problem = ctx.problem(profile, ctx.cfg.lambda)?;
        let (_, f_opt) = ctx.optimum(&problem);
        let k = (problem.n() / 16).max(16);
        let modes =
            [Compression::None, Compression::TopK(k), Compression::Threshold(1e-3)];
        for compress in modes {
            let spec = compress.spec();
            let mut table = TextTable::new(vec![
                "algorithm",
                "epochs",
                "final gap",
                "total bytes",
                "busiest node bytes",
                "sim time (s)",
            ]);
            let mut plot = AsciiPlot::new(
                &format!(
                    "Compression ablation :: {profile} / {spec} — objective gap vs bytes on the wire"
                ),
                "bytes on the wire",
            );
            println!(
                "== Compression ablation :: {profile} / {spec} (q={q}, λ={:.0e}) ==",
                ctx.cfg.lambda
            );
            for algo in Algorithm::ALL_DISTRIBUTED {
                let mut params = ctx.base_params(q);
                params.compress = compress;
                let ps = matches!(algo, Algorithm::SynSvrg | Algorithm::AsySvrg);
                let budget = if ps {
                    ((default_epochs(algo) as f64) * ctx.ps_scale).round() as usize
                } else {
                    default_epochs(algo) / 3
                };
                params.outer = ctx.epochs(budget);
                let gap = StopPolicy::GapReached { f_opt, target: ctx.cfg.gap_target / 10.0 };
                let res = run_and_save(
                    ctx,
                    &problem,
                    algo,
                    &params,
                    &[gap],
                    f_opt,
                    &format!("compress_{profile}_{spec}"),
                );
                let final_gap = res.final_objective() - f_opt;
                plot.add(Series::gap_vs_comm(algo.name(), &res.trace, f_opt));
                table.row(vec![
                    algo.name().to_string(),
                    format!("{}", res.trace.points.len() - 1),
                    format!("{final_gap:.3e}"),
                    format!("{}", res.total_bytes),
                    format!("{}", res.busiest_node_bytes),
                    format!("{:.4}", res.total_sim_time),
                ]);
                rows.push((
                    profile.to_string(),
                    spec.clone(),
                    algo.name(),
                    res.total_bytes,
                    final_gap,
                    res.total_sim_time,
                ));
            }
            println!("{}", table.render());
            println!("{}", plot.render());
        }
    }
    Ok(rows)
}

/// `exp calibrate`: hold the network model's predictions against real
/// sockets. Each distributed algorithm runs the same tiny workload twice
/// — once on the in-memory sim transport (the model's *prediction*) and
/// once over `--transport tcp` with one OS process per cluster node (the
/// *measurement*) — and the report lines up predicted simulated seconds /
/// modeled payload bytes against measured wall-clock seconds / bytes that
/// actually crossed the loopback sockets. Both runs share one seed and
/// one spec, so the trajectories must agree bit for bit — AsySVRG
/// excepted, whose pull/push loop races by design on either plane — and
/// the `bit-exact` column proves the transport swap changed timing and
/// framing only.
/// Returns `(algorithm, sim_time, wall_time, model_bytes, socket_bytes)`
/// rows.
pub fn calibrate(ctx: &Ctx) -> Result<Vec<(String, f64, f64, u64, u64)>> {
    use crate::net::TransportKind;
    use std::sync::Arc;
    let q = 2;
    let cfg_base = ExperimentConfig {
        dataset: "tiny".into(),
        q,
        servers: 2,
        outer: ctx.epochs(6),
        transport: "sim".into(),
        ..ctx.cfg.clone()
    };
    let problem = ctx.problem("tiny", cfg_base.lambda)?;
    let mut table = TextTable::new(vec![
        "algorithm",
        "sim time (s)",
        "wall time (s)",
        "wall/sim",
        "model bytes",
        "socket bytes",
        "socket/model",
        "bit-exact",
    ]);
    let mut rows = Vec::new();
    println!("== Calibrate :: network model vs tcp sockets (tiny, q={q}) ==");
    for algo in Algorithm::ALL_DISTRIBUTED {
        let cfg = ExperimentConfig { algo: algo.name().into(), ..cfg_base.clone() };
        let run = |params: RunParams| -> Result<RunResult> {
            Ok(SessionBuilder::new(algo, &problem, params)
                .build()
                .with_context(|| format!("calibrate: {} session", algo.name()))?
                .run_to_completion())
        };
        let sim = run(cfg.run_params())?;
        let mut tcp_params = cfg.run_params();
        tcp_params.transport = TransportKind::Tcp;
        tcp_params.worker_spec = Some(Arc::new(cfg.worker_spec(0.0, false, false)));
        let tcp = run(tcp_params)?;
        let exact = sim.final_objective().to_bits() == tcp.final_objective().to_bits()
            && sim.total_bytes == tcp.total_bytes
            && sim.total_sim_time.to_bits() == tcp.total_sim_time.to_bits();
        // AsySVRG's pull/push loop races by design on either plane, so
        // trajectory equality is not a transport property there
        let exact_cell = if matches!(algo, Algorithm::AsySvrg) {
            "races"
        } else if exact {
            "yes"
        } else {
            "NO"
        };
        table.row(vec![
            algo.name().to_string(),
            format!("{:.4}", sim.total_sim_time),
            format!("{:.4}", tcp.total_wall_time),
            format!("{:.2}", tcp.total_wall_time / sim.total_sim_time.max(1e-12)),
            format!("{}", sim.total_bytes),
            format!("{}", tcp.total_socket_bytes),
            format!("{:.2}", tcp.total_socket_bytes as f64 / sim.total_bytes.max(1) as f64),
            exact_cell.to_string(),
        ]);
        rows.push((
            algo.name().to_string(),
            sim.total_sim_time,
            tcp.total_wall_time,
            sim.total_bytes,
            tcp.total_socket_bytes,
        ));
    }
    let report = table.render();
    println!("{report}");
    std::fs::create_dir_all(&ctx.out_dir).ok();
    let path = ctx.out_dir.join("calibrate.txt");
    std::fs::write(&path, &report).with_context(|| format!("write {}", path.display()))?;
    Ok(rows)
}

/// One `exp faults` measurement: a (profile, algorithm, scenario) cell.
#[derive(Clone, Debug)]
pub struct FaultRow {
    pub profile: String,
    pub algorithm: &'static str,
    pub scenario: &'static str,
    pub spec: String,
    pub sim_time: f64,
    pub baseline_sim_time: f64,
    /// Relative sim-time overhead vs the failure-free baseline.
    pub overhead: f64,
    pub final_gap: f64,
    pub stats: crate::net::fault::FaultStats,
    /// Final `w` bit-identical to the failure-free run (sync algorithms:
    /// link faults reshape time only, and crash recovery replays to the
    /// same state; AsySVRG races by design, so `false` is expected there).
    pub bit_exact: bool,
    /// `(epoch, objective, sim_time)` per reported boundary. A recovered
    /// run repeats the replayed epoch numbers — the restart penalty is
    /// visible in the trajectory, by design.
    pub trajectory: Vec<(usize, f64, f64)>,
}

/// `exp faults`: the fault-tolerance measurement of DESIGN.md's fault
/// plane. Every distributed algorithm runs on `url-sim`/`news20-sim`
/// under a failure-free baseline and four seeded fault scenarios — lossy
/// links, a composite link-noise mix, a mid-run worker crash with
/// automatic recovery, and a healing partition — and the report holds the
/// fault runs against the baseline: recovery counts, rolled-back sim
/// time, sim-time overhead, and whether the final iterate stayed
/// bit-identical (it must, for the synchronous algorithms). The crash
/// column is the paper-relevant contrast: the synchronous algorithms
/// (FD-SVRG, DSVRG, SynSVRG) barrier-and-restart from the last epoch
/// boundary, while AsySVRG absorbs the loss and keeps going. Everything
/// lands in `BENCH_faults.json` (trajectories included) next to the
/// printed tables.
pub fn faults(ctx: &Ctx) -> Result<Vec<FaultRow>> {
    use crate::net::fault::FaultPlan;
    let mut rows: Vec<FaultRow> = Vec::new();
    // `--quick` (CI) smokes the whole matrix on the tiny profile; the
    // full run measures the paper profiles at their paper worker counts.
    let quick = ctx.scale < 1.0;
    let profile_list: &[&str] = if quick { &["tiny"] } else { &["url-sim", "news20-sim"] };
    for &profile in profile_list {
        let q = if quick { 4 } else { profiles::paper_worker_count(profile) };
        let problem = ctx.problem(profile, ctx.cfg.lambda)?;
        let (_, f_opt) = ctx.optimum(&problem);
        let mut table = TextTable::new(vec![
            "algorithm",
            "scenario",
            "sim time (s)",
            "overhead",
            "recoveries",
            "lost sim (s)",
            "drops",
            "holds",
            "final gap",
            "bit-exact",
        ]);
        println!("== Faults :: {profile} (q={q}, λ={:.0e}) ==", ctx.cfg.lambda);
        for algo in Algorithm::ALL_DISTRIBUTED {
            let mut params = ctx.base_params(q);
            let ps = matches!(algo, Algorithm::SynSvrg | Algorithm::AsySvrg);
            let budget = if ps {
                ((default_epochs(algo) as f64) * ctx.ps_scale).round() as usize
            } else {
                default_epochs(algo) / 3
            };
            params.outer = ctx.epochs(budget);
            // Failure-free baseline: no stop policies beyond the epoch
            // budget, so every scenario runs the identical workload and
            // the sim-time ratio is meaningful.
            let base = run_and_save(
                ctx,
                &problem,
                algo,
                &params,
                &[],
                f_opt,
                &format!("faults_{profile}_none"),
            );
            let t_base = base.total_sim_time;
            rows.push(FaultRow {
                profile: profile.to_string(),
                algorithm: algo.name(),
                scenario: "none",
                spec: String::new(),
                sim_time: t_base,
                baseline_sim_time: t_base,
                overhead: 0.0,
                final_gap: base.final_objective() - f_opt,
                stats: Default::default(),
                bit_exact: true,
                trajectory: base
                    .trace
                    .points
                    .iter()
                    .map(|p| (p.outer, p.objective, p.sim_time))
                    .collect(),
            });
            // Scenario schedule derived from the baseline's sim time, so
            // the crash lands mid-run and the partition window is inside
            // the run on every (profile, algorithm) cell.
            let scenarios: [(&'static str, String); 4] = [
                ("drop", "drop:0.05".to_string()),
                ("linknoise", "drop:0.03,dup:0.03,reorder:0.2".to_string()),
                ("crash", format!("crash:2@{}", 0.5 * t_base)),
                (
                    "partition",
                    format!("partition:1+2@{}-{}", 0.2 * t_base, 0.45 * t_base),
                ),
            ];
            for (scenario, spec) in &scenarios {
                let plan = FaultPlan::parse(spec, params.seed)
                    .map_err(anyhow::Error::msg)?
                    .expect("non-empty fault spec");
                let mut fp = params.clone();
                fp.faults = Some(plan.clone());
                let res = run_and_save(
                    ctx,
                    &problem,
                    algo,
                    &fp,
                    &[],
                    f_opt,
                    &format!("faults_{profile}_{scenario}"),
                );
                let stats = plan.stats();
                let bit_exact = res.w.len() == base.w.len()
                    && res
                        .w
                        .iter()
                        .zip(base.w.iter())
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                rows.push(FaultRow {
                    profile: profile.to_string(),
                    algorithm: algo.name(),
                    scenario: *scenario,
                    spec: spec.clone(),
                    sim_time: res.total_sim_time,
                    baseline_sim_time: t_base,
                    overhead: res.total_sim_time / t_base.max(1e-12) - 1.0,
                    final_gap: res.final_objective() - f_opt,
                    stats,
                    bit_exact,
                    trajectory: res
                        .trace
                        .points
                        .iter()
                        .map(|p| (p.outer, p.objective, p.sim_time))
                        .collect(),
                });
            }
            for row in rows.iter().rev().take(scenarios.len() + 1).collect::<Vec<_>>().into_iter().rev()
            {
                let exact_cell = if matches!(algo, Algorithm::AsySvrg) && row.scenario != "none"
                {
                    if row.bit_exact { "yes" } else { "races" }
                } else if row.bit_exact {
                    "yes"
                } else {
                    "NO"
                };
                table.row(vec![
                    row.algorithm.to_string(),
                    row.scenario.to_string(),
                    format!("{:.4}", row.sim_time),
                    format!("{:+.1}%", 100.0 * row.overhead),
                    format!("{}", row.stats.recoveries),
                    format!("{:.4}", row.stats.lost_sim_time),
                    format!("{}", row.stats.drops),
                    format!("{}", row.stats.partition_holds),
                    format!("{:.3e}", row.final_gap),
                    exact_cell.to_string(),
                ]);
            }
        }
        println!("{}", table.render());
    }
    write_faults_json(ctx, &rows)?;
    Ok(rows)
}

/// Hand-rolled JSON for `BENCH_faults.json` — deliberately separate from
/// [`crate::metrics::json::run_result_to_json`], whose byte layout is
/// pinned by a golden test.
fn write_faults_json(ctx: &Ctx, rows: &[FaultRow]) -> Result<()> {
    fn esc(s: &str) -> String {
        s.replace('\\', "\\\\").replace('"', "\\\"")
    }
    let mut out = String::from("{\n  \"experiment\": \"faults\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let trajectory: Vec<String> = r
            .trajectory
            .iter()
            .map(|(e, obj, t)| format!("[{e}, {obj}, {t}]"))
            .collect();
        out.push_str(&format!(
            "    {{\"profile\": \"{}\", \"algorithm\": \"{}\", \"scenario\": \"{}\", \
             \"spec\": \"{}\", \"sim_time\": {}, \"baseline_sim_time\": {}, \
             \"overhead\": {}, \"final_gap\": {}, \"recoveries\": {}, \
             \"lost_sim_time\": {}, \"drops\": {}, \"dups\": {}, \"reorders\": {}, \
             \"partition_holds\": {}, \"crashes\": {}, \"bit_exact\": {}, \
             \"trajectory\": [{}]}}{}\n",
            esc(&r.profile),
            r.algorithm,
            r.scenario,
            esc(&r.spec),
            r.sim_time,
            r.baseline_sim_time,
            r.overhead,
            r.final_gap,
            r.stats.recoveries,
            r.stats.lost_sim_time,
            r.stats.drops,
            r.stats.dups,
            r.stats.reorders,
            r.stats.partition_holds,
            r.stats.crashes,
            r.bit_exact,
            trajectory.join(", "),
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::create_dir_all(&ctx.out_dir).ok();
    let path = ctx.out_dir.join("BENCH_faults.json");
    std::fs::write(&path, &out).with_context(|| format!("write {}", path.display()))?;
    println!("fault report written to {}", path.display());
    Ok(())
}

/// The serving-plane ablation (`exp serving`): drive the sharded
/// inference plane (see [`crate::serve`]) across network scenario ×
/// shard count × wire format × batch size, closed-loop, plus one
/// open-loop (Poisson) row per scenario/shard cell. Serving timing is
/// independent of the weight *values*, so the model is a seeded synthetic
/// w — the driver never touches the training path. Quick mode smokes the
/// whole grid on the tiny profile; the full run measures news20-sim with
/// 50k queries per cell (millions of simulated queries total). Everything
/// lands in `BENCH_serving.json` next to the printed tables; the sim is
/// entirely modeled time, so the report is bit-stable across reruns and
/// `--threads`.
pub fn serving(ctx: &Ctx) -> Result<Vec<crate::serve::ServeReport>> {
    use crate::serve::{simulate, ArrivalMode, BatchPolicy, QuerySource, ServeSpec};
    use crate::util::Pcg64;
    let quick = ctx.scale < 1.0;
    let profile = if quick { "tiny" } else { "news20-sim" };
    let queries = if quick { 1_500 } else { 50_000 };
    let concurrency = ctx.cfg.serve_concurrency;
    let q_list: &[usize] = if quick { &[2, 4] } else { &[4, 8] };
    let batch_list = [1usize, 8, 32];
    let wires = [crate::net::WireFmt::F64, crate::net::WireFmt::F32];
    let scenarios = ["uniform", "hetero", "straggler", "jitter"];
    let ds = profiles::load(profile).context("profile")?;
    let d = ds.d();
    // per-q feature partitions, computed before the matrix moves into the
    // shared query source
    let bounds_for: Vec<Vec<(usize, usize)>> = q_list
        .iter()
        .map(|&q| {
            crate::sparse::partition::by_features(&ds.x, q)
                .iter()
                .map(|s| (s.row_lo, s.row_hi))
                .collect()
        })
        .collect();
    let source = QuerySource::Columns(std::sync::Arc::new(ds.x));
    let mut rng = Pcg64::seed_from_u64(ctx.cfg.seed ^ 0x7e57);
    let inv = 1.0 / (d as f64).sqrt();
    let w: Vec<f64> = (0..d).map(|_| rng.normal() * inv).collect();
    let mut rows: Vec<crate::serve::ServeReport> = Vec::new();
    for scenario in scenarios {
        let model = ctx
            .cfg
            .net_spec_for(scenario)
            .expect("built-in scenario kinds always parse")
            .resolve(ctx.cfg.sim_params());
        let mut table = TextTable::new(vec![
            "q",
            "wire",
            "mode",
            "batch",
            "p50 (us)",
            "p99 (us)",
            "qps",
            "B/query",
        ]);
        println!("== Serving :: {profile} / {scenario} ({queries} queries/run) ==");
        for (qi, &q) in q_list.iter().enumerate() {
            for wire in wires {
                for &max_batch in &batch_list {
                    let spec = ServeSpec {
                        w: &w,
                        bounds: bounds_for[qi].clone(),
                        model: model.clone(),
                        wire,
                        policy: BatchPolicy { max_batch, max_delay: ctx.cfg.serve_delay },
                        queries,
                        mode: ArrivalMode::Closed { concurrency },
                        seed: ctx.cfg.seed,
                        source: source.clone(),
                        collect_margins: false,
                        robust: Default::default(),
                    };
                    let r = simulate(&spec).map_err(|e| anyhow::anyhow!(e))?.report;
                    table.row(vec![
                        format!("{q}"),
                        r.wire.to_string(),
                        r.mode.to_string(),
                        format!("{max_batch}"),
                        format!("{:.1}", r.p50_us),
                        format!("{:.1}", r.p99_us),
                        format!("{:.0}", r.qps),
                        format!("{:.1}", r.bytes_per_query),
                    ]);
                    rows.push(r);
                }
            }
            // one open-loop row per (scenario, q): Poisson arrivals at the
            // configured --rate against the full-batch f64 configuration
            let spec = ServeSpec {
                w: &w,
                bounds: bounds_for[qi].clone(),
                model: model.clone(),
                wire: crate::net::WireFmt::F64,
                policy: BatchPolicy { max_batch: 32, max_delay: ctx.cfg.serve_delay },
                queries,
                mode: ArrivalMode::Open { rate: ctx.cfg.serve_rate },
                seed: ctx.cfg.seed,
                source: source.clone(),
                collect_margins: false,
                robust: Default::default(),
            };
            let r = simulate(&spec).map_err(|e| anyhow::anyhow!(e))?.report;
            table.row(vec![
                format!("{q}"),
                r.wire.to_string(),
                format!("{}@{:.0}/s", r.mode, r.rate),
                "32".to_string(),
                format!("{:.1}", r.p50_us),
                format!("{:.1}", r.p99_us),
                format!("{:.0}", r.qps),
                format!("{:.1}", r.bytes_per_query),
            ]);
            rows.push(r);
        }
        println!("{}", table.render());
    }
    write_serving_json(ctx, &rows)?;
    Ok(rows)
}

/// Hand-rolled JSON for `BENCH_serving.json` — one row per simulated
/// configuration, via [`crate::serve::ServeReport::to_json_row`].
/// Deliberately separate from the golden-pinned
/// [`crate::metrics::json::run_result_to_json`] layout.
fn write_serving_json(ctx: &Ctx, rows: &[crate::serve::ServeReport]) -> Result<()> {
    let mut out = String::from("{\n  \"experiment\": \"serving\",\n");
    out.push_str(
        "  \"note\": \"regenerate from the repo root with \
         `cargo run --release -- exp serving --out .` \
         (add --quick for the CI-sized tiny-profile grid)\",\n",
    );
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str("    ");
        out.push_str(&r.to_json_row());
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    std::fs::create_dir_all(&ctx.out_dir).ok();
    let path = ctx.out_dir.join("BENCH_serving.json");
    std::fs::write(&path, &out).with_context(|| format!("write {}", path.display()))?;
    println!("serving report written to {}", path.display());
    Ok(())
}

/// The robust-serving ablation (`exp serving-faults`): measure
/// availability, tail latency, and goodput of the sharded inference plane
/// under injected faults, across replication levels, against the
/// failure-free baseline of the same (scenario, replicas) cell. Fault
/// times are fractions of the measured failure-free sim time, so every
/// scenario places its crash/partition at the same relative point in the
/// run; all decisions are seeded, so the whole grid is bit-stable across
/// reruns and `--threads`. Quick mode smokes the grid on the tiny
/// profile; the full run measures news20-sim. Lands in
/// `BENCH_serving_faults.json`.
pub fn serving_faults(ctx: &Ctx) -> Result<Vec<String>> {
    use crate::serve::{simulate, ArrivalMode, BatchPolicy, QuerySource, RobustSpec, ServeSpec};
    use crate::util::Pcg64;
    let quick = ctx.scale < 1.0;
    let profile = if quick { "tiny" } else { "news20-sim" };
    let queries = if quick { 1_200 } else { 50_000 };
    let q = if quick { 4 } else { 8 };
    let scenarios = ["uniform", "straggler"];
    let ds = profiles::load(profile).context("profile")?;
    let d = ds.d();
    let bounds: Vec<(usize, usize)> = crate::sparse::partition::by_features(&ds.x, q)
        .iter()
        .map(|s| (s.row_lo, s.row_hi))
        .collect();
    let source = QuerySource::Columns(std::sync::Arc::new(ds.x));
    // serving timing is independent of the weight values (same rule as
    // `exp serving`): a seeded synthetic model, never the training path
    let mut rng = Pcg64::seed_from_u64(ctx.cfg.seed ^ 0x7e57);
    let inv = 1.0 / (d as f64).sqrt();
    let w: Vec<f64> = (0..d).map(|_| rng.normal() * inv).collect();
    let seed = ctx.cfg.seed;
    let mut rows: Vec<String> = Vec::new();
    for scenario in scenarios {
        let model = ctx
            .cfg
            .net_spec_for(scenario)
            .expect("built-in scenario kinds always parse")
            .resolve(ctx.cfg.sim_params());
        let mut table = TextTable::new(vec![
            "replicas",
            "faults",
            "hedge (us)",
            "avail %",
            "p99 (us)",
            "qps",
            "goodput",
            "failovers",
            "degraded",
        ]);
        println!("== Serving faults :: {profile} / {scenario} ({queries} queries/run, q={q}) ==");
        for replicas in [1usize, 2] {
            let run = |fault_spec: &str, hedge: f64| -> Result<crate::serve::ServeReport> {
                let spec = ServeSpec {
                    w: &w,
                    bounds: bounds.clone(),
                    model: model.clone(),
                    wire: crate::net::WireFmt::F64,
                    policy: BatchPolicy { max_batch: 16, max_delay: ctx.cfg.serve_delay },
                    queries,
                    mode: ArrivalMode::Closed { concurrency: ctx.cfg.serve_concurrency },
                    seed,
                    source: source.clone(),
                    collect_margins: false,
                    robust: RobustSpec {
                        replicas,
                        deadline: 0.0,
                        hedge,
                        queue_cap: 0,
                        faults: crate::net::fault::FaultPlan::parse(fault_spec, seed)
                            .map_err(|e| anyhow::anyhow!(e))?,
                    },
                };
                Ok(simulate(&spec).map_err(|e| anyhow::anyhow!(e))?.report)
            };
            // failure-free baseline for this (scenario, replicas) cell;
            // fault times are fractions of its measured sim time
            let base = run("none", -1.0)?;
            let t = base.sim_time_s;
            let crash = format!("crash:1@{:.6}", 0.35 * t);
            let part = format!("partition:1@{:.6}-{:.6}", 0.30 * t, 0.50 * t);
            let mut cell: Vec<(&str, String, f64)> = vec![
                ("none", "none".to_string(), -1.0),
                ("crash", crash.clone(), -1.0),
                ("partition", part, -1.0),
                ("drop2pct", "drop:0.02".to_string(), -1.0),
            ];
            if replicas >= 2 {
                // one hedged row: mirror each dispatch to the second
                // replica, hedge budget = one straggler-ish delay
                cell.push(("crash+hedge", crash, 200e-6));
            }
            for (name, fault_spec, hedge) in cell.drain(..) {
                let r = if name == "none" { base.clone() } else { run(&fault_spec, hedge)? };
                table.row(vec![
                    format!("{replicas}"),
                    name.to_string(),
                    if hedge >= 0.0 { format!("{:.0}", 1e6 * hedge) } else { "-".to_string() },
                    format!("{:.2}", r.availability_pct),
                    format!("{:.1}", r.p99_us),
                    format!("{:.0}", r.qps),
                    format!("{:.0}", r.goodput_qps),
                    format!("{}", r.failovers),
                    format!("{}", r.degraded),
                ]);
                // splice the grid label and this cell's baseline next to
                // the report's own fields
                let row = r.to_json_row();
                rows.push(format!(
                    "{{\"label\": \"{scenario}/r{replicas}/{name}\", \
                     \"baseline_p99_us\": {:.3}, \"baseline_qps\": {:.3}, {}",
                    base.p99_us,
                    base.qps,
                    row.trim_start().trim_start_matches('{').trim_start()
                ));
            }
        }
        println!("{}", table.render());
    }
    write_serving_faults_json(ctx, &rows)?;
    Ok(rows)
}

/// Hand-rolled JSON for `BENCH_serving_faults.json` — one row per
/// simulated (scenario × replicas × fault) cell, each a
/// [`crate::serve::ServeReport::to_json_row`] object prefixed with the
/// grid label and its cell's failure-free baseline.
fn write_serving_faults_json(ctx: &Ctx, rows: &[String]) -> Result<()> {
    let mut out = String::from("{\n  \"experiment\": \"serving-faults\",\n");
    out.push_str(
        "  \"note\": \"regenerate from the repo root with \
         `cargo run --release -- exp serving-faults --out .` \
         (add --quick for the CI-sized tiny-profile grid)\",\n",
    );
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str("    ");
        out.push_str(r);
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    std::fs::create_dir_all(&ctx.out_dir).ok();
    let path = ctx.out_dir.join("BENCH_serving_faults.json");
    std::fs::write(&path, &out).with_context(|| format!("write {}", path.display()))?;
    println!("serving-faults report written to {}", path.display());
    Ok(())
}

/// Table 1: dataset statistics of the `-sim` profiles.
pub fn table1() -> Result<()> {
    let mut table =
        TextTable::new(vec!["dataset", "features (d)", "instances (N)", "nnz/inst", "d/N"]);
    for name in profiles::PROFILE_NAMES {
        let ds = profiles::load(name).context("profile")?;
        let s = crate::data::stats(&ds);
        table.row(vec![
            s.name,
            format!("{}", s.d),
            format!("{}", s.n),
            format!("{:.1}", s.nnz_per_instance),
            format!("{:.2}", s.aspect),
        ]);
    }
    println!("== Table 1 :: datasets (simulated profiles) ==");
    println!("{}", table.render());
    Ok(())
}

/// Default epoch budgets per algorithm (how many outer loops each method
/// typically needs to pass the 1e-4 gap on the -sim profiles).
pub fn default_epochs(algo: Algorithm) -> usize {
    match algo {
        Algorithm::FdSvrg | Algorithm::FdSaga | Algorithm::SerialSvrg => 30,
        // DSVRG runs M = N/q inner steps per outer iteration (one machine
        // at a time), so it needs ~q× the epochs of FD-SVRG to make the
        // same optimization progress; gap_stop halts it as soon as the
        // target is reached, so the large cap only pays when needed.
        Algorithm::Dsvrg => 600,
        Algorithm::SynSvrg => 80,
        Algorithm::AsySvrg => 40,
        Algorithm::PsLiteSgd => 200,
        Algorithm::FdSgd | Algorithm::DPsgd | Algorithm::SerialSgd => 200,
    }
}

/// Run the whole suite (CLI `exp all`).
pub fn all(ctx: &Ctx) -> Result<()> {
    table1()?;
    fig6_fig7(ctx, &paper_grid())?;
    fig8(ctx)?;
    fig9(ctx)?;
    table2(ctx)?;
    table3(ctx)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_ctx() -> Ctx {
        let dir = std::env::temp_dir().join("fdsvrg_exp_test");
        let mut ctx = Ctx::new(&dir);
        ctx.scale = 0.1;
        ctx
    }

    #[test]
    fn paper_grid_matches_paper() {
        let g = paper_grid();
        assert_eq!(g.len(), 4);
        assert_eq!(g[0], ("news20-sim", 8));
        assert_eq!(g[2], ("webspam-sim", 16));
    }

    #[test]
    fn ctx_problem_unknown_profile_errors() {
        let ctx = tiny_ctx();
        assert!(ctx.problem("no-such-profile", 1e-4).is_err());
    }

    #[test]
    fn epochs_scaling_floors_at_two() {
        let mut ctx = tiny_ctx();
        ctx.scale = 1e-9;
        assert_eq!(ctx.epochs(100), 2);
    }
}
