//! CSR view — row-major companion of [`CscMatrix`], used where per-feature
//! iteration is needed (feature statistics, the generators' frequency
//! accounting) and by format round-trip tests.

use super::csc::CscMatrix;

#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from a CSC matrix (O(nnz) counting transpose).
    pub fn from_csc(m: &CscMatrix) -> Self {
        let t = m.transpose(); // cols×rows CSC == rows×cols CSR of m
        let mut row_ptr = Vec::with_capacity(m.rows() + 1);
        let mut col_idx = Vec::with_capacity(m.nnz());
        let mut values = Vec::with_capacity(m.nnz());
        row_ptr.push(0);
        for r in 0..m.rows() {
            for (c, v) in t.col_iter(r) {
                col_idx.push(c);
                values.push(v);
            }
            row_ptr.push(col_idx.len());
        }
        CsrMatrix { rows: m.rows(), cols: m.cols(), row_ptr, col_idx, values }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    pub fn row_nnz(&self, row: usize) -> usize {
        self.row_ptr[row + 1] - self.row_ptr[row]
    }

    pub fn row_iter(&self, row: usize) -> impl Iterator<Item = (u32, f64)> + '_ {
        let (s, e) = (self.row_ptr[row], self.row_ptr[row + 1]);
        self.col_idx[s..e].iter().copied().zip(self.values[s..e].iter().copied())
    }

    /// `out = A x` (dense x).
    pub fn matvec(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for r in 0..self.rows {
            let mut acc = 0.0;
            for (c, v) in self.row_iter(r) {
                acc += x[c as usize] * v;
            }
            out[r] = acc;
        }
    }

    /// Round-trip back to CSC.
    pub fn to_csc(&self) -> CscMatrix {
        let mut b = super::CooBuilder::new(self.rows, self.cols);
        for r in 0..self.rows {
            for (c, v) in self.row_iter(r) {
                b.push(r, c as usize, v);
            }
        }
        b.to_csc()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooBuilder;

    fn sample_csc() -> CscMatrix {
        let mut b = CooBuilder::new(3, 4);
        b.push(0, 0, 1.0);
        b.push(1, 1, 2.0);
        b.push(2, 2, 3.0);
        b.push(0, 3, 4.0);
        b.push(2, 3, 5.0);
        b.to_csc()
    }

    #[test]
    fn csr_round_trip() {
        let csc = sample_csc();
        let csr = CsrMatrix::from_csc(&csc);
        assert_eq!(csr.nnz(), csc.nnz());
        assert_eq!(csr.to_csc(), csc);
    }

    #[test]
    fn matvec_agrees_with_csc_transpose_matvec() {
        let csc = sample_csc();
        let csr = CsrMatrix::from_csc(&csc);
        // CSR matvec computes D x over columns; CSC transpose_matvec computes
        // Dᵀ w. Check CSR(D) · x == dense D · x.
        let x = [1.0, -2.0, 0.5, 3.0];
        let mut out = vec![0.0; 3];
        csr.matvec(&x, &mut out);
        let d = csc.to_dense();
        for r in 0..3 {
            let want: f64 = (0..4).map(|c| d[r][c] * x[c]).sum();
            assert!((out[r] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn row_iter_sorted() {
        let csr = CsrMatrix::from_csc(&sample_csc());
        let cols: Vec<u32> = csr.row_iter(0).map(|(c, _)| c).collect();
        assert_eq!(cols, vec![0, 3]);
    }
}
