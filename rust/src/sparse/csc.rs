//! CSC matrix — the canonical storage for the data matrix `D ∈ R^{d×N}`
//! (features × instances, instance `i` = column `i`).
//!
//! The two kernels that dominate every algorithm's wall-clock — the
//! full-gradient partial products `Dᵀw` ([`CscMatrix::transpose_matvec`])
//! and the aggregation `Dc` ([`CscMatrix::matvec_accumulate`]) — have
//! pool-parallel variants (`*_pool`) that are **bit-exact** with the
//! serial kernels at every thread count:
//!
//! * `Dᵀw` is column-parallel: each output margin `s_c = x_cᵀw` is an
//!   independent [`CscMatrix::col_dot`], so chunking the output changes
//!   nothing about any element's arithmetic.
//! * `Dc` is row-parallel over a lazily-built, cached **CSR mirror** of
//!   the same matrix: the serial scatter-add visits columns in ascending
//!   order, so the additions landing on row `r` arrive in ascending-column
//!   order — exactly the order the mirror's row `r` stores them. The
//!   per-row gather replays that sum term for term (including the
//!   serial path's `c == 0` skip), so the result is bit-identical.
//!
//! The mirror costs `+4 B/nnz` (u32 column ids) `+8 B/nnz` (values) plus
//! `8·(rows+1)` bytes of row pointers; it is built on first use (or via
//! [`CscMatrix::ensure_mirror`]) and is *not* part of the matrix's value:
//! equality ignores it.

use crate::linalg;
use crate::util::pool::Pool;
use std::sync::OnceLock;

/// Row-major companion arrays of a [`CscMatrix`] — the row-parallel `Dc`
/// kernel's view. Column ids within each row are ascending (the building
/// pass visits columns in order), which is what makes the per-row gather
/// reproduce the serial scatter-add's summation order bit for bit.
#[derive(Clone, Debug, Default)]
struct CsrMirror {
    row_ptr: Vec<usize>, // len rows+1
    col_idx: Vec<u32>,   // len nnz, ascending within each row
    values: Vec<f64>,    // len nnz
}

impl CsrMirror {
    /// Row `row`'s share of `D·(scale·c)` starting from `init` — the same
    /// FP operations, in the same order, as the serial column scatter:
    /// terms in ascending-column order, coefficient formed as `c·scale`
    /// first, columns with `c == 0` skipped entirely (the serial path
    /// never touches them, and `x + 0.0` is not always a bit-level no-op).
    #[inline]
    fn row_gather(&self, row: usize, c: &[f64], scale: f64, init: f64) -> f64 {
        let (s, e) = (self.row_ptr[row], self.row_ptr[row + 1]);
        let mut acc = init;
        for p in s..e {
            let cv = c[self.col_idx[p] as usize];
            if cv != 0.0 {
                acc += (cv * scale) * self.values[p];
            }
        }
        acc
    }

    /// Multi-lane variant of [`CsrMirror::row_gather`] (`--simd`): four
    /// independent accumulators hide the gather-load latency behind the FP
    /// adds instead of serializing on one chain. The lanes reassociate the
    /// sum and the serial path's `c == 0` skip is dropped (a zero
    /// coefficient contributes an exact `±0.0` product to its lane), so
    /// the result matches [`CsrMirror::row_gather`] to summation-order
    /// roundoff only — callers opt in and pin the tolerance.
    #[inline]
    fn row_gather_simd(&self, row: usize, c: &[f64], scale: f64, init: f64) -> f64 {
        let (s, e) = (self.row_ptr[row], self.row_ptr[row + 1]);
        let cols = &self.col_idx[s..e];
        let vals = &self.values[s..e];
        let chunks = cols.len() / 4;
        let mut acc = [0.0f64; 4];
        for ch in 0..chunks {
            let i = 4 * ch;
            acc[0] += (c[cols[i] as usize] * scale) * vals[i];
            acc[1] += (c[cols[i + 1] as usize] * scale) * vals[i + 1];
            acc[2] += (c[cols[i + 2] as usize] * scale) * vals[i + 2];
            acc[3] += (c[cols[i + 3] as usize] * scale) * vals[i + 3];
        }
        let mut tail = init;
        for i in 4 * chunks..cols.len() {
            tail += (c[cols[i] as usize] * scale) * vals[i];
        }
        tail + (acc[0] + acc[1]) + (acc[2] + acc[3])
    }
}

/// Compressed sparse column matrix over `f64` values with `u32` row indices
/// (the paper's largest dataset has d ≈ 3·10⁷ features, well within u32).
#[derive(Clone, Debug)]
pub struct CscMatrix {
    rows: usize,
    cols: usize,
    col_ptr: Vec<usize>, // len cols+1
    row_idx: Vec<u32>,   // len nnz, sorted within each column
    values: Vec<f64>,    // len nnz
    /// Lazily-built CSR companion for the row-parallel `Dc` kernel.
    /// Cache only — excluded from equality, rebuilt on demand.
    mirror: OnceLock<CsrMirror>,
}

/// Equality is over the matrix *value* (shape + nonzeros); the CSR-mirror
/// cache is ignored so `a == b` cannot depend on which kernels ran.
impl PartialEq for CscMatrix {
    fn eq(&self, other: &Self) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.col_ptr == other.col_ptr
            && self.row_idx == other.row_idx
            && self.values == other.values
    }
}

impl CscMatrix {
    /// Assemble from raw parts, validating the CSC invariants.
    ///
    /// Cheap shape checks (lengths, `col_ptr` monotonicity — O(cols)) run
    /// in every profile. The O(nnz) content checks (per-column strict row
    /// sorting, row bounds) run under `debug_assertions` only: every slab
    /// build in the partitioners funnels through here, and re-scanning all
    /// nonzeros on each release-mode bench run is pure overhead for inputs
    /// our own builders already produce sorted.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        col_ptr: Vec<usize>,
        row_idx: Vec<u32>,
        values: Vec<f64>,
    ) -> Self {
        assert_eq!(col_ptr.len(), cols + 1, "col_ptr length");
        assert_eq!(col_ptr[0], 0, "col_ptr[0]");
        assert_eq!(*col_ptr.last().unwrap(), row_idx.len(), "col_ptr[-1] != nnz");
        assert_eq!(row_idx.len(), values.len(), "row_idx/values length");
        for c in 0..cols {
            assert!(col_ptr[c] <= col_ptr[c + 1], "col_ptr not monotone at {c}");
        }
        #[cfg(debug_assertions)]
        for c in 0..cols {
            let seg = &row_idx[col_ptr[c]..col_ptr[c + 1]];
            for w in seg.windows(2) {
                assert!(w[0] < w[1], "row indices not strictly sorted in column {c}");
            }
            if let Some(&last) = seg.last() {
                assert!((last as usize) < rows, "row index out of bounds in column {c}");
            }
        }
        CscMatrix { rows, cols, col_ptr, row_idx, values, mirror: OnceLock::new() }
    }

    pub fn zero(rows: usize, cols: usize) -> Self {
        CscMatrix {
            rows,
            cols,
            col_ptr: vec![0; cols + 1],
            row_idx: vec![],
            values: vec![],
            mirror: OnceLock::new(),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.row_idx.len()
    }

    pub fn col_nnz(&self, col: usize) -> usize {
        self.col_ptr[col + 1] - self.col_ptr[col]
    }

    /// Iterate the nonzeros of a column as `(row, value)` pairs.
    pub fn col_iter(&self, col: usize) -> impl Iterator<Item = (u32, f64)> + '_ {
        let (s, e) = (self.col_ptr[col], self.col_ptr[col + 1]);
        self.row_idx[s..e].iter().copied().zip(self.values[s..e].iter().copied())
    }

    /// Raw slices of a column's nonzeros (hot-path access, no iterator).
    #[inline]
    pub fn col(&self, col: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.col_ptr[col], self.col_ptr[col + 1]);
        (&self.row_idx[s..e], &self.values[s..e])
    }

    /// Random access (O(log nnz_col)); for tests and small tools only.
    pub fn get(&self, row: usize, col: usize) -> f64 {
        let (rows, vals) = self.col(col);
        match rows.binary_search(&(row as u32)) {
            Ok(p) => vals[p],
            Err(_) => 0.0,
        }
    }

    /// Sparse dot of column `col` against a dense vector: `x_colᵀ w`.
    ///
    /// This is the per-instance hot operation of the FD-SVRG inner loop
    /// (paper Alg. 1 line 9). The gather is 4-way unrolled — the four
    /// indexed loads and multiplies of each block are independent and can
    /// issue in parallel — while the accumulator keeps the exact
    /// left-to-right summation order of the scalar loop, because every
    /// pinned trajectory (equivalence suites, golden files) depends on
    /// these bits.
    #[inline]
    pub fn col_dot(&self, col: usize, w: &[f64]) -> f64 {
        debug_assert_eq!(w.len(), self.rows);
        let (rows, vals) = self.col(col);
        let chunks = rows.len() / 4;
        let mut acc = 0.0;
        for ch in 0..chunks {
            let i = 4 * ch;
            let p0 = w[rows[i] as usize] * vals[i];
            let p1 = w[rows[i + 1] as usize] * vals[i + 1];
            let p2 = w[rows[i + 2] as usize] * vals[i + 2];
            let p3 = w[rows[i + 3] as usize] * vals[i + 3];
            // left-associated: ((((acc+p0)+p1)+p2)+p3), the scalar order
            acc = acc + p0 + p1 + p2 + p3;
        }
        for i in 4 * chunks..rows.len() {
            acc += w[rows[i] as usize] * vals[i];
        }
        acc
    }

    /// Multi-lane variant of [`CscMatrix::col_dot`] (`--simd`): the same
    /// 4-way unrolled gather, but with four *independent* accumulator
    /// lanes so the adds pipeline instead of serializing on one FP chain —
    /// the latency win explicit vectorization buys on an indexed gather
    /// (AVX2 has no efficient f64 gather-multiply chain that beats this on
    /// sparse index streams, so the lanes are portable scalar code the
    /// compiler maps onto vector registers). Reassociates the sum: equal
    /// to [`CscMatrix::col_dot`] only up to summation-order roundoff,
    /// which is why it is opt-in behind `RunParams::simd` and pinned by
    /// the kernel-exactness tolerance suite rather than bit-for-bit.
    #[inline]
    pub fn col_dot_simd(&self, col: usize, w: &[f64]) -> f64 {
        debug_assert_eq!(w.len(), self.rows);
        let (rows, vals) = self.col(col);
        let chunks = rows.len() / 4;
        let mut acc = [0.0f64; 4];
        for ch in 0..chunks {
            let i = 4 * ch;
            acc[0] += w[rows[i] as usize] * vals[i];
            acc[1] += w[rows[i + 1] as usize] * vals[i + 1];
            acc[2] += w[rows[i + 2] as usize] * vals[i + 2];
            acc[3] += w[rows[i + 3] as usize] * vals[i + 3];
        }
        let mut tail = 0.0;
        for i in 4 * chunks..rows.len() {
            tail += w[rows[i] as usize] * vals[i];
        }
        // pairwise lane fold: one more reassociation, two fewer serial adds
        tail + (acc[0] + acc[1]) + (acc[2] + acc[3])
    }

    /// `out += alpha * x_col` (scatter-add of one instance), 4-way
    /// unrolled: row indices are strictly sorted within a column, so the
    /// four stores of a block target distinct slots and issue
    /// independently; each `out[r]` sees exactly one add, so unrolling
    /// cannot change any bit.
    ///
    /// This is also the `--simd` form: a scatter-add has no accumulator
    /// chain to split (every `out[r]` receives exactly one add) and x86
    /// has no f64 scatter store short of AVX-512, so the unrolled
    /// independent-store body *is* the vector-width-friendly shape — the
    /// SIMD path reuses it unchanged, bit for bit.
    #[inline]
    pub fn col_axpy(&self, col: usize, alpha: f64, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.rows);
        let (rows, vals) = self.col(col);
        let chunks = rows.len() / 4;
        for ch in 0..chunks {
            let i = 4 * ch;
            out[rows[i] as usize] += alpha * vals[i];
            out[rows[i + 1] as usize] += alpha * vals[i + 1];
            out[rows[i + 2] as usize] += alpha * vals[i + 2];
            out[rows[i + 3] as usize] += alpha * vals[i + 3];
        }
        for i in 4 * chunks..rows.len() {
            out[rows[i] as usize] += alpha * vals[i];
        }
    }

    /// `Dᵀ w` — the partial-products vector `s` with `s_i = x_iᵀ w`.
    ///
    /// This is the full-gradient-phase hot operation (paper Alg. 1 line 3).
    pub fn transpose_matvec(&self, w: &[f64], out: &mut [f64]) {
        self.transpose_matvec_pool(w, out, &Pool::serial());
    }

    /// Pool-parallel `Dᵀ w`: the output margins are chunked contiguously
    /// and each is an independent [`CscMatrix::col_dot`] — bit-exact with
    /// the serial kernel at any thread count.
    pub fn transpose_matvec_pool(&self, w: &[f64], out: &mut [f64], pool: &Pool) {
        assert_eq!(w.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        pool.for_each_chunk(out, |start, chunk| {
            for (j, o) in chunk.iter_mut().enumerate() {
                *o = self.col_dot(start + j, w);
            }
        });
    }

    /// `D c` — accumulate `Σ_i c_i x_i` into `out` (caller zeroes `out`).
    pub fn matvec_accumulate(&self, c: &[f64], out: &mut [f64]) {
        self.matvec_accumulate_scaled(c, 1.0, out);
    }

    /// `D (scale·c)` — accumulate `Σ_i (c_i·scale) x_i` into `out`,
    /// skipping `c_i == 0` columns (the gradient-aggregation form: `c` is
    /// the loss-derivative vector, `scale` the `1/N` normalization). The
    /// coefficient is formed as `c_i·scale` *before* the scatter so the
    /// row-parallel mirror kernel can replay the identical products.
    pub fn matvec_accumulate_scaled(&self, c: &[f64], scale: f64, out: &mut [f64]) {
        assert_eq!(c.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for col in 0..self.cols {
            let ci = c[col];
            if ci != 0.0 {
                self.col_axpy(col, ci * scale, out);
            }
        }
    }

    /// Pool-parallel `D c` over the CSR mirror (see
    /// [`CscMatrix::matvec_accumulate_scaled_pool`]).
    pub fn matvec_accumulate_pool(&self, c: &[f64], out: &mut [f64], pool: &Pool) {
        self.matvec_accumulate_scaled_pool(c, 1.0, out, pool);
    }

    /// Pool-parallel `D (scale·c)`: output rows are chunked contiguously
    /// and each row is gathered from the CSR mirror. Bit-exact with the
    /// serial scatter at any thread count — the mirror stores each row's
    /// terms in ascending-column order, which is exactly the order the
    /// column-major scatter adds them, and the gather replays the same
    /// `c == 0` skip and `c·scale` product (see `CsrMirror::row_gather`).
    pub fn matvec_accumulate_scaled_pool(
        &self,
        c: &[f64],
        scale: f64,
        out: &mut [f64],
        pool: &Pool,
    ) {
        if pool.threads() <= 1 {
            return self.matvec_accumulate_scaled(c, scale, out);
        }
        assert_eq!(c.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        let m = self.mirror();
        pool.for_each_chunk(out, |start, chunk| {
            for (j, o) in chunk.iter_mut().enumerate() {
                *o = m.row_gather(start + j, c, scale, *o);
            }
        });
    }

    /// CSR-mirror dot of row `row` against a column-indexed vector:
    /// `Σ_c c[col]·D[row,col]` with the serial scatter's `c == 0` skip —
    /// equal to the CSC scatter's contribution to `out[row]` bit for bit.
    pub fn row_dot(&self, row: usize, c: &[f64]) -> f64 {
        assert_eq!(c.len(), self.cols);
        assert!(row < self.rows);
        self.mirror().row_gather(row, c, 1.0, 0.0)
    }

    /// Multi-lane [`CscMatrix::row_dot`] (`--simd`): four accumulator
    /// lanes over the mirror row; reassociates the sum (tolerance, not
    /// bits — see [`CscMatrix::col_dot_simd`]).
    pub fn row_dot_simd(&self, row: usize, c: &[f64]) -> f64 {
        assert_eq!(c.len(), self.cols);
        assert!(row < self.rows);
        self.mirror().row_gather_simd(row, c, 1.0, 0.0)
    }

    /// Pool-parallel multi-lane `Dᵀ w` (`--simd`): chunked like
    /// [`CscMatrix::transpose_matvec_pool`] but each margin is a
    /// [`CscMatrix::col_dot_simd`]. Same value at every thread count
    /// (chunking never splits a column); differs from the serial kernel by
    /// summation-order roundoff only.
    pub fn transpose_matvec_pool_simd(&self, w: &[f64], out: &mut [f64], pool: &Pool) {
        assert_eq!(w.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        pool.for_each_chunk(out, |start, chunk| {
            for (j, o) in chunk.iter_mut().enumerate() {
                *o = self.col_dot_simd(start + j, w);
            }
        });
    }

    /// Pool-parallel multi-lane `D (scale·c)` (`--simd`): row-parallel
    /// over the CSR mirror like
    /// [`CscMatrix::matvec_accumulate_scaled_pool`], but gathering with
    /// [`CsrMirror::row_gather_simd`] — and unlike the bit-exact kernel it
    /// uses the mirror even at one thread, because the row gather is where
    /// the lanes pay (the column scatter has no accumulator chain to
    /// split). Same value at every thread count; tolerance vs serial.
    pub fn matvec_accumulate_scaled_pool_simd(
        &self,
        c: &[f64],
        scale: f64,
        out: &mut [f64],
        pool: &Pool,
    ) {
        assert_eq!(c.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        let m = self.mirror();
        pool.for_each_chunk(out, |start, chunk| {
            for (j, o) in chunk.iter_mut().enumerate() {
                *o = m.row_gather_simd(start + j, c, scale, *o);
            }
        });
    }

    /// Build (and cache) the CSR mirror now — drivers call this at setup
    /// when `threads > 1` so the one-time O(nnz) transpose does not land
    /// inside the first timed epoch. Idempotent; a no-op cost-wise once
    /// built.
    pub fn ensure_mirror(&self) {
        let _ = self.mirror();
    }

    /// Bytes held by the CSR mirror (0 until built): `+4 B/nnz` column
    /// ids, `+8 B/nnz` values, `8·(rows+1)` row pointers.
    pub fn mirror_bytes(&self) -> usize {
        match self.mirror.get() {
            Some(m) => m.row_ptr.len() * 8 + m.col_idx.len() * 4 + m.values.len() * 8,
            None => 0,
        }
    }

    fn mirror(&self) -> &CsrMirror {
        self.mirror.get_or_init(|| {
            let mut row_ptr = vec![0usize; self.rows + 1];
            for &r in &self.row_idx {
                row_ptr[r as usize + 1] += 1;
            }
            for i in 0..self.rows {
                row_ptr[i + 1] += row_ptr[i];
            }
            let mut cursor = row_ptr.clone();
            let mut col_idx = vec![0u32; self.nnz()];
            let mut values = vec![0f64; self.nnz()];
            for c in 0..self.cols {
                let (rs, vs) = self.col(c);
                for (r, v) in rs.iter().zip(vs.iter()) {
                    let p = cursor[*r as usize];
                    col_idx[p] = c as u32;
                    values[p] = *v;
                    cursor[*r as usize] += 1;
                }
            }
            // columns visited in ascending order ⇒ ascending within rows
            CsrMirror { row_ptr, col_idx, values }
        })
    }

    /// Squared Euclidean norm of column `col`.
    pub fn col_nrm2_sq(&self, col: usize) -> f64 {
        let (_, vals) = self.col(col);
        linalg::dot(vals, vals)
    }

    /// Dense `rows × cols` copy (tests / tiny matrices only).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut dense = vec![vec![0.0; self.cols]; self.rows];
        for c in 0..self.cols {
            for (r, v) in self.col_iter(c) {
                dense[r as usize][c] = v;
            }
        }
        dense
    }

    /// Dense column-major flattening of a *row slab* `[row_lo, row_hi)` of
    /// this matrix, in f32 — the layout the XLA dense engine consumes.
    /// Each column's `[row_lo, row_hi)` window is binary-searched (rows
    /// are sorted within columns, as in [`CscMatrix::slice_rows`]) instead
    /// of range-testing every nonzero of every column.
    pub fn dense_slab_f32(&self, row_lo: usize, row_hi: usize) -> Vec<f32> {
        assert!(row_lo <= row_hi && row_hi <= self.rows);
        let dl = row_hi - row_lo;
        let mut out = vec![0f32; dl * self.cols];
        for c in 0..self.cols {
            let (rs, vs) = self.col(c);
            let lo = rs.partition_point(|&r| (r as usize) < row_lo);
            let hi = rs.partition_point(|&r| (r as usize) < row_hi);
            for p in lo..hi {
                out[c * dl + (rs[p] as usize - row_lo)] = vs[p] as f32;
            }
        }
        out
    }

    /// Select a subset of columns (instance partition). Row dimension is
    /// kept; `cols` become `idx.len()` in the given order. Index/value
    /// storage is reserved up front (the summed nnz of the selected
    /// columns) so the build never regrows mid-copy.
    pub fn select_columns(&self, idx: &[usize]) -> CscMatrix {
        let nnz: usize = idx
            .iter()
            .map(|&c| {
                assert!(c < self.cols);
                self.col_nnz(c)
            })
            .sum();
        let mut col_ptr = Vec::with_capacity(idx.len() + 1);
        col_ptr.push(0usize);
        let mut row_idx = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        for &c in idx {
            let (rs, vs) = self.col(c);
            row_idx.extend_from_slice(rs);
            values.extend_from_slice(vs);
            col_ptr.push(row_idx.len());
        }
        CscMatrix {
            rows: self.rows,
            cols: idx.len(),
            col_ptr,
            row_idx,
            values,
            mirror: OnceLock::new(),
        }
    }

    /// Extract the row slab `[row_lo, row_hi)` with row indices remapped to
    /// the slab-local range — the feature-partition primitive (paper Fig. 3,
    /// upper right). Rows within each column stay sorted, so the result is a
    /// valid CSC.
    pub fn slice_rows(&self, row_lo: usize, row_hi: usize) -> CscMatrix {
        assert!(row_lo <= row_hi && row_hi <= self.rows);
        let mut col_ptr = Vec::with_capacity(self.cols + 1);
        col_ptr.push(0usize);
        let mut row_idx = Vec::new();
        let mut values = Vec::new();
        for c in 0..self.cols {
            let (rs, vs) = self.col(c);
            // binary-search the [row_lo, row_hi) window inside the sorted rows
            let lo = rs.partition_point(|&r| (r as usize) < row_lo);
            let hi = rs.partition_point(|&r| (r as usize) < row_hi);
            for p in lo..hi {
                row_idx.push(rs[p] - row_lo as u32);
                values.push(vs[p]);
            }
            col_ptr.push(row_idx.len());
        }
        CscMatrix {
            rows: row_hi - row_lo,
            cols: self.cols,
            col_ptr,
            row_idx,
            values,
            mirror: OnceLock::new(),
        }
    }

    /// Transpose into CSR-of-the-same-matrix, i.e. a `cols × rows` CSC.
    pub fn transpose(&self) -> CscMatrix {
        let mut row_counts = vec![0usize; self.rows + 1];
        for &r in &self.row_idx {
            row_counts[r as usize + 1] += 1;
        }
        for i in 0..self.rows {
            row_counts[i + 1] += row_counts[i];
        }
        let mut cursor = row_counts.clone();
        let mut t_rows = vec![0u32; self.nnz()];
        let mut t_vals = vec![0f64; self.nnz()];
        for c in 0..self.cols {
            for (r, v) in self.col_iter(c) {
                let p = cursor[r as usize];
                t_rows[p] = c as u32;
                t_vals[p] = v;
                cursor[r as usize] += 1;
            }
        }
        // columns were visited in increasing order, so each new column
        // (= old row) has sorted indices already
        CscMatrix {
            rows: self.cols,
            cols: self.rows,
            col_ptr: row_counts,
            row_idx: t_rows,
            values: t_vals,
            mirror: OnceLock::new(),
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        linalg::dot(&self.values, &self.values).sqrt()
    }

    /// Total bytes of the raw arrays (capacity planning / stats).
    pub fn storage_bytes(&self) -> usize {
        self.col_ptr.len() * 8 + self.row_idx.len() * 4 + self.values.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooBuilder;

    fn sample() -> CscMatrix {
        // 4x3:
        // [1 0 4]
        // [0 2 0]
        // [3 0 5]
        // [0 0 6]
        let mut b = CooBuilder::new(4, 3);
        b.push(0, 0, 1.0);
        b.push(2, 0, 3.0);
        b.push(1, 1, 2.0);
        b.push(0, 2, 4.0);
        b.push(2, 2, 5.0);
        b.push(3, 2, 6.0);
        b.to_csc()
    }

    #[test]
    fn col_dot_matches_dense() {
        let m = sample();
        let w = [1.0, 10.0, 100.0, 1000.0];
        assert_eq!(m.col_dot(0, &w), 301.0);
        assert_eq!(m.col_dot(1, &w), 20.0);
        assert_eq!(m.col_dot(2, &w), 4.0 + 500.0 + 6000.0);
    }

    #[test]
    fn transpose_matvec_matches_per_column() {
        let m = sample();
        let w = [1.0, -1.0, 2.0, 0.5];
        let mut s = vec![0.0; 3];
        m.transpose_matvec(&w, &mut s);
        for c in 0..3 {
            assert_eq!(s[c], m.col_dot(c, &w));
        }
    }

    #[test]
    fn matvec_accumulate_matches_dense() {
        let m = sample();
        let c = [2.0, -1.0, 0.5];
        let mut out = vec![0.0; 4];
        m.matvec_accumulate(&c, &mut out);
        // dense: D c
        let d = m.to_dense();
        for r in 0..4 {
            let want: f64 = (0..3).map(|j| d[r][j] * c[j]).sum();
            assert!((out[r] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn slice_rows_remaps() {
        let m = sample();
        let s = m.slice_rows(1, 3); // rows 1..3 => [[0 2 0],[3 0 5]]
        assert_eq!(s.rows(), 2);
        assert_eq!(s.cols(), 3);
        assert_eq!(s.to_dense(), vec![vec![0.0, 2.0, 0.0], vec![3.0, 0.0, 5.0]]);
    }

    #[test]
    fn slice_rows_partition_reassembles() {
        let m = sample();
        let a = m.slice_rows(0, 2);
        let b = m.slice_rows(2, 4);
        assert_eq!(a.nnz() + b.nnz(), m.nnz());
        let w = [1.0, 2.0, 3.0, 4.0];
        for c in 0..3 {
            let partial = a.col_dot(c, &w[0..2]) + b.col_dot(c, &w[2..4]);
            assert!((partial - m.col_dot(c, &w)).abs() < 1e-12);
        }
    }

    #[test]
    fn select_columns_subset() {
        let m = sample();
        let s = m.select_columns(&[2, 0]);
        assert_eq!(s.cols(), 2);
        assert_eq!(s.get(3, 0), 6.0); // old col 2
        assert_eq!(s.get(0, 1), 1.0); // old col 0
    }

    #[test]
    fn transpose_involution() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t.cols(), 4);
        assert_eq!(t.get(2, 3), 6.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn dense_slab_layout() {
        let m = sample();
        let slab = m.dense_slab_f32(2, 4); // rows 2..4, col-major dl=2
        // col0: rows2..4 = [3,0]; col1: [0,0]; col2: [5,6]
        assert_eq!(slab, vec![3.0, 0.0, 0.0, 0.0, 5.0, 6.0]);
    }

    #[test]
    fn zero_matrix() {
        let z = CscMatrix::zero(5, 4);
        assert_eq!(z.nnz(), 0);
        let mut out = vec![1.0; 4];
        z.transpose_matvec(&[0.0; 5], &mut out);
        assert_eq!(out, vec![0.0; 4]);
    }

    // The O(nnz) content checks are compiled out in release, so these two
    // pins run in debug only (which is what `cargo test` builds).
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "not strictly sorted")]
    fn from_parts_validates_sorted_rows_in_debug() {
        CscMatrix::from_parts(3, 1, vec![0, 2], vec![2, 1], vec![1.0, 2.0]);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "out of bounds")]
    fn from_parts_validates_row_bounds_in_debug() {
        CscMatrix::from_parts(3, 1, vec![0, 1], vec![7], vec![1.0]);
    }

    #[test]
    #[should_panic(expected = "col_ptr")]
    fn from_parts_shape_checks_run_in_every_profile() {
        // monotonicity is a cheap shape check: always validated
        CscMatrix::from_parts(3, 2, vec![0, 2, 1], vec![0], vec![1.0]);
    }

    #[test]
    fn col_nrm2_sq_sample() {
        let m = sample();
        assert!((m.col_nrm2_sq(2) - (16.0 + 25.0 + 36.0)).abs() < 1e-12);
    }

    #[test]
    fn pool_kernels_match_serial_bits() {
        let m = sample();
        let w = [1.0, -1.0, 2.0, 0.5];
        let c = [0.25, 0.0, -1.5]; // includes a zero coefficient (skip path)
        let mut s_serial = vec![0.0; 3];
        m.transpose_matvec(&w, &mut s_serial);
        let mut z_serial = vec![0.5, -0.25, 0.0, 1.0]; // nonzero initial out
        m.matvec_accumulate(&c, &mut z_serial);
        for threads in [2usize, 3, 8] {
            let pool = Pool::new(threads);
            let mut s = vec![0.0; 3];
            m.transpose_matvec_pool(&w, &mut s, &pool);
            assert_eq!(s, s_serial, "Dᵀw at k={threads}");
            let mut z = vec![0.5, -0.25, 0.0, 1.0];
            m.matvec_accumulate_pool(&c, &mut z, &pool);
            assert_eq!(z, z_serial, "Dc at k={threads}");
        }
    }

    #[test]
    fn row_dot_matches_scatter_contribution() {
        let m = sample();
        let c = [2.0, -1.0, 0.5];
        let mut out = vec![0.0; 4];
        m.matvec_accumulate(&c, &mut out);
        for r in 0..4 {
            assert_eq!(m.row_dot(r, &c), out[r], "row {r}");
        }
    }

    #[test]
    fn mirror_is_cached_and_excluded_from_equality() {
        let a = sample();
        let b = sample();
        assert_eq!(a.mirror_bytes(), 0, "mirror must be lazy");
        a.ensure_mirror();
        assert!(a.mirror_bytes() > 0);
        // +4 B/nnz col ids, +8 B/nnz values, 8·(rows+1) row pointers
        assert_eq!(a.mirror_bytes(), 12 * a.nnz() + 8 * (a.rows() + 1));
        assert_eq!(a, b, "the cache must not affect equality");
        assert_eq!(b, a);
    }

    #[test]
    fn dense_slab_binary_search_matches_full_scan() {
        // pin: the windowed build must reproduce the old range-test-every-
        // nonzero output exactly (reimplemented here as the oracle)
        let mut rng = crate::util::Pcg64::seed_from_u64(99);
        let mut b = CooBuilder::new(60, 17);
        for _ in 0..300 {
            b.push(rng.below(60), rng.below(17), rng.range_f64(-2.0, 2.0));
        }
        let m = b.to_csc();
        for (lo, hi) in [(0usize, 60usize), (10, 45), (0, 1), (59, 60), (20, 20)] {
            let dl = hi - lo;
            let mut want = vec![0f32; dl * m.cols()];
            for c in 0..m.cols() {
                for (r, v) in m.col_iter(c) {
                    let r = r as usize;
                    if r >= lo && r < hi {
                        want[c * dl + (r - lo)] = v as f32;
                    }
                }
            }
            assert_eq!(m.dense_slab_f32(lo, hi), want, "slab [{lo}, {hi})");
        }
    }

    #[test]
    fn simd_reductions_match_serial_within_tolerance() {
        // The `_simd` kernels reassociate sums, so they are pinned by
        // tolerance rather than bits: |simd − serial| ≤ 1e-12·(1 + |serial|)
        // is generous for the ~40-term sums here (the end-to-end contract
        // lives in tests/kernel_exactness.rs).
        let mut rng = crate::util::Pcg64::seed_from_u64(23);
        let mut b = CooBuilder::new(80, 13);
        for _ in 0..500 {
            b.push(rng.below(80), rng.below(13), rng.range_f64(-1.0, 1.0));
        }
        let m = b.to_csc();
        let w: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
        let mut c: Vec<f64> = (0..13).map(|_| rng.normal()).collect();
        // zero coefficients exercise the skip the simd row gather drops
        c[3] = 0.0;
        c[7] = 0.0;
        let close = |got: f64, want: f64| (got - want).abs() <= 1e-12 * (1.0 + want.abs());
        for col in 0..13 {
            assert!(close(m.col_dot_simd(col, &w), m.col_dot(col, &w)), "col {col}");
        }
        let mut dc_serial = vec![0.25; 80];
        m.matvec_accumulate_scaled(&c, -0.5, &mut dc_serial);
        for row in 0..80 {
            assert!(close(m.row_dot_simd(row, &c), m.row_dot(row, &c)), "row {row}");
        }
        let mut dtw_serial = vec![0.0; 13];
        m.transpose_matvec(&w, &mut dtw_serial);
        for threads in [1usize, 2, 5] {
            let pool = Pool::new(threads);
            let mut dtw = vec![0.0; 13];
            m.transpose_matvec_pool_simd(&w, &mut dtw, &pool);
            for col in 0..13 {
                assert!(close(dtw[col], dtw_serial[col]), "Dᵀw col {col} at k={threads}");
            }
            let mut dc = vec![0.25; 80];
            m.matvec_accumulate_scaled_pool_simd(&c, -0.5, &mut dc, &pool);
            for row in 0..80 {
                assert!(close(dc[row], dc_serial[row]), "Dc row {row} at k={threads}");
            }
        }
    }

    #[test]
    fn simd_pool_kernels_are_thread_count_invariant() {
        // chunking never splits a column/row, so the simd pool kernels must
        // return the same bits at every thread count (only the serial-vs-
        // simd delta is a tolerance; k is not a degree of freedom)
        let mut rng = crate::util::Pcg64::seed_from_u64(24);
        let mut b = CooBuilder::new(40, 11);
        for _ in 0..200 {
            b.push(rng.below(40), rng.below(11), rng.range_f64(-1.0, 1.0));
        }
        let m = b.to_csc();
        let w: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let c: Vec<f64> = (0..11).map(|_| rng.normal()).collect();
        let one = Pool::new(1);
        let mut dtw1 = vec![0.0; 11];
        m.transpose_matvec_pool_simd(&w, &mut dtw1, &one);
        let mut dc1 = vec![0.0; 40];
        m.matvec_accumulate_scaled_pool_simd(&c, 1.0, &mut dc1, &one);
        for threads in [3usize, 7] {
            let pool = Pool::new(threads);
            let mut dtw = vec![0.0; 11];
            m.transpose_matvec_pool_simd(&w, &mut dtw, &pool);
            assert_eq!(dtw, dtw1, "Dᵀw simd at k={threads}");
            let mut dc = vec![0.0; 40];
            m.matvec_accumulate_scaled_pool_simd(&c, 1.0, &mut dc, &pool);
            assert_eq!(dc, dc1, "Dc simd at k={threads}");
        }
    }

    #[test]
    fn unrolled_gather_matches_naive_loops() {
        // columns with ≥ 4 nonzeros exercise the unrolled body + tail
        let mut rng = crate::util::Pcg64::seed_from_u64(7);
        let mut b = CooBuilder::new(50, 9);
        for _ in 0..260 {
            b.push(rng.below(50), rng.below(9), rng.range_f64(-1.0, 1.0));
        }
        let m = b.to_csc();
        let w: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        for c in 0..9 {
            let (rows, vals) = m.col(c);
            let mut naive = 0.0;
            for (r, v) in rows.iter().zip(vals.iter()) {
                naive += w[*r as usize] * *v;
            }
            assert_eq!(m.col_dot(c, &w), naive, "col_dot order must be unchanged");
            let mut got = vec![0.1f64; 50];
            let mut want = got.clone();
            m.col_axpy(c, -0.3, &mut got);
            for (r, v) in rows.iter().zip(vals.iter()) {
                want[*r as usize] += -0.3 * *v;
            }
            assert_eq!(got, want, "col_axpy must be element-identical");
        }
    }
}
