//! CSC matrix — the canonical storage for the data matrix `D ∈ R^{d×N}`
//! (features × instances, instance `i` = column `i`).

use crate::linalg;

/// Compressed sparse column matrix over `f64` values with `u32` row indices
/// (the paper's largest dataset has d ≈ 3·10⁷ features, well within u32).
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    rows: usize,
    cols: usize,
    col_ptr: Vec<usize>, // len cols+1
    row_idx: Vec<u32>,   // len nnz, sorted within each column
    values: Vec<f64>,    // len nnz
}

impl CscMatrix {
    /// Assemble from raw parts, validating the CSC invariants.
    ///
    /// Cheap shape checks (lengths, `col_ptr` monotonicity — O(cols)) run
    /// in every profile. The O(nnz) content checks (per-column strict row
    /// sorting, row bounds) run under `debug_assertions` only: every slab
    /// build in the partitioners funnels through here, and re-scanning all
    /// nonzeros on each release-mode bench run is pure overhead for inputs
    /// our own builders already produce sorted.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        col_ptr: Vec<usize>,
        row_idx: Vec<u32>,
        values: Vec<f64>,
    ) -> Self {
        assert_eq!(col_ptr.len(), cols + 1, "col_ptr length");
        assert_eq!(col_ptr[0], 0, "col_ptr[0]");
        assert_eq!(*col_ptr.last().unwrap(), row_idx.len(), "col_ptr[-1] != nnz");
        assert_eq!(row_idx.len(), values.len(), "row_idx/values length");
        for c in 0..cols {
            assert!(col_ptr[c] <= col_ptr[c + 1], "col_ptr not monotone at {c}");
        }
        #[cfg(debug_assertions)]
        for c in 0..cols {
            let seg = &row_idx[col_ptr[c]..col_ptr[c + 1]];
            for w in seg.windows(2) {
                assert!(w[0] < w[1], "row indices not strictly sorted in column {c}");
            }
            if let Some(&last) = seg.last() {
                assert!((last as usize) < rows, "row index out of bounds in column {c}");
            }
        }
        CscMatrix { rows, cols, col_ptr, row_idx, values }
    }

    pub fn zero(rows: usize, cols: usize) -> Self {
        CscMatrix { rows, cols, col_ptr: vec![0; cols + 1], row_idx: vec![], values: vec![] }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.row_idx.len()
    }

    pub fn col_nnz(&self, col: usize) -> usize {
        self.col_ptr[col + 1] - self.col_ptr[col]
    }

    /// Iterate the nonzeros of a column as `(row, value)` pairs.
    pub fn col_iter(&self, col: usize) -> impl Iterator<Item = (u32, f64)> + '_ {
        let (s, e) = (self.col_ptr[col], self.col_ptr[col + 1]);
        self.row_idx[s..e].iter().copied().zip(self.values[s..e].iter().copied())
    }

    /// Raw slices of a column's nonzeros (hot-path access, no iterator).
    #[inline]
    pub fn col(&self, col: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.col_ptr[col], self.col_ptr[col + 1]);
        (&self.row_idx[s..e], &self.values[s..e])
    }

    /// Random access (O(log nnz_col)); for tests and small tools only.
    pub fn get(&self, row: usize, col: usize) -> f64 {
        let (rows, vals) = self.col(col);
        match rows.binary_search(&(row as u32)) {
            Ok(p) => vals[p],
            Err(_) => 0.0,
        }
    }

    /// Sparse dot of column `col` against a dense vector: `x_colᵀ w`.
    ///
    /// This is the per-instance hot operation of the FD-SVRG inner loop
    /// (paper Alg. 1 line 9).
    #[inline]
    pub fn col_dot(&self, col: usize, w: &[f64]) -> f64 {
        debug_assert_eq!(w.len(), self.rows);
        let (rows, vals) = self.col(col);
        let mut acc = 0.0;
        for (r, v) in rows.iter().zip(vals.iter()) {
            acc += w[*r as usize] * *v;
        }
        acc
    }

    /// `out += alpha * x_col` (scatter-add of one instance).
    #[inline]
    pub fn col_axpy(&self, col: usize, alpha: f64, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.rows);
        let (rows, vals) = self.col(col);
        for (r, v) in rows.iter().zip(vals.iter()) {
            out[*r as usize] += alpha * *v;
        }
    }

    /// `Dᵀ w` — the partial-products vector `s` with `s_i = x_iᵀ w`.
    ///
    /// This is the full-gradient-phase hot operation (paper Alg. 1 line 3).
    pub fn transpose_matvec(&self, w: &[f64], out: &mut [f64]) {
        assert_eq!(w.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        for c in 0..self.cols {
            out[c] = self.col_dot(c, w);
        }
    }

    /// `D c` — accumulate `Σ_i c_i x_i` into `out` (caller zeroes `out`).
    pub fn matvec_accumulate(&self, c: &[f64], out: &mut [f64]) {
        assert_eq!(c.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for col in 0..self.cols {
            let ci = c[col];
            if ci != 0.0 {
                self.col_axpy(col, ci, out);
            }
        }
    }

    /// Squared Euclidean norm of column `col`.
    pub fn col_nrm2_sq(&self, col: usize) -> f64 {
        let (_, vals) = self.col(col);
        linalg::dot(vals, vals)
    }

    /// Dense `rows × cols` copy (tests / tiny matrices only).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut dense = vec![vec![0.0; self.cols]; self.rows];
        for c in 0..self.cols {
            for (r, v) in self.col_iter(c) {
                dense[r as usize][c] = v;
            }
        }
        dense
    }

    /// Dense column-major flattening of a *row slab* `[row_lo, row_hi)` of
    /// this matrix, in f32 — the layout the XLA dense engine consumes.
    pub fn dense_slab_f32(&self, row_lo: usize, row_hi: usize) -> Vec<f32> {
        assert!(row_lo <= row_hi && row_hi <= self.rows);
        let dl = row_hi - row_lo;
        let mut out = vec![0f32; dl * self.cols];
        for c in 0..self.cols {
            for (r, v) in self.col_iter(c) {
                let r = r as usize;
                if r >= row_lo && r < row_hi {
                    out[c * dl + (r - row_lo)] = v as f32;
                }
            }
        }
        out
    }

    /// Select a subset of columns (instance partition). Row dimension is
    /// kept; `cols` become `idx.len()` in the given order.
    pub fn select_columns(&self, idx: &[usize]) -> CscMatrix {
        let mut col_ptr = Vec::with_capacity(idx.len() + 1);
        col_ptr.push(0usize);
        let mut row_idx = Vec::new();
        let mut values = Vec::new();
        for &c in idx {
            assert!(c < self.cols);
            let (rs, vs) = self.col(c);
            row_idx.extend_from_slice(rs);
            values.extend_from_slice(vs);
            col_ptr.push(row_idx.len());
        }
        CscMatrix { rows: self.rows, cols: idx.len(), col_ptr, row_idx, values }
    }

    /// Extract the row slab `[row_lo, row_hi)` with row indices remapped to
    /// the slab-local range — the feature-partition primitive (paper Fig. 3,
    /// upper right). Rows within each column stay sorted, so the result is a
    /// valid CSC.
    pub fn slice_rows(&self, row_lo: usize, row_hi: usize) -> CscMatrix {
        assert!(row_lo <= row_hi && row_hi <= self.rows);
        let mut col_ptr = Vec::with_capacity(self.cols + 1);
        col_ptr.push(0usize);
        let mut row_idx = Vec::new();
        let mut values = Vec::new();
        for c in 0..self.cols {
            let (rs, vs) = self.col(c);
            // binary-search the [row_lo, row_hi) window inside the sorted rows
            let lo = rs.partition_point(|&r| (r as usize) < row_lo);
            let hi = rs.partition_point(|&r| (r as usize) < row_hi);
            for p in lo..hi {
                row_idx.push(rs[p] - row_lo as u32);
                values.push(vs[p]);
            }
            col_ptr.push(row_idx.len());
        }
        CscMatrix { rows: row_hi - row_lo, cols: self.cols, col_ptr, row_idx, values }
    }

    /// Transpose into CSR-of-the-same-matrix, i.e. a `cols × rows` CSC.
    pub fn transpose(&self) -> CscMatrix {
        let mut row_counts = vec![0usize; self.rows + 1];
        for &r in &self.row_idx {
            row_counts[r as usize + 1] += 1;
        }
        for i in 0..self.rows {
            row_counts[i + 1] += row_counts[i];
        }
        let mut cursor = row_counts.clone();
        let mut t_rows = vec![0u32; self.nnz()];
        let mut t_vals = vec![0f64; self.nnz()];
        for c in 0..self.cols {
            for (r, v) in self.col_iter(c) {
                let p = cursor[r as usize];
                t_rows[p] = c as u32;
                t_vals[p] = v;
                cursor[r as usize] += 1;
            }
        }
        // columns were visited in increasing order, so each new column
        // (= old row) has sorted indices already
        CscMatrix {
            rows: self.cols,
            cols: self.rows,
            col_ptr: row_counts,
            row_idx: t_rows,
            values: t_vals,
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        linalg::dot(&self.values, &self.values).sqrt()
    }

    /// Total bytes of the raw arrays (capacity planning / stats).
    pub fn storage_bytes(&self) -> usize {
        self.col_ptr.len() * 8 + self.row_idx.len() * 4 + self.values.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooBuilder;

    fn sample() -> CscMatrix {
        // 4x3:
        // [1 0 4]
        // [0 2 0]
        // [3 0 5]
        // [0 0 6]
        let mut b = CooBuilder::new(4, 3);
        b.push(0, 0, 1.0);
        b.push(2, 0, 3.0);
        b.push(1, 1, 2.0);
        b.push(0, 2, 4.0);
        b.push(2, 2, 5.0);
        b.push(3, 2, 6.0);
        b.to_csc()
    }

    #[test]
    fn col_dot_matches_dense() {
        let m = sample();
        let w = [1.0, 10.0, 100.0, 1000.0];
        assert_eq!(m.col_dot(0, &w), 301.0);
        assert_eq!(m.col_dot(1, &w), 20.0);
        assert_eq!(m.col_dot(2, &w), 4.0 + 500.0 + 6000.0);
    }

    #[test]
    fn transpose_matvec_matches_per_column() {
        let m = sample();
        let w = [1.0, -1.0, 2.0, 0.5];
        let mut s = vec![0.0; 3];
        m.transpose_matvec(&w, &mut s);
        for c in 0..3 {
            assert_eq!(s[c], m.col_dot(c, &w));
        }
    }

    #[test]
    fn matvec_accumulate_matches_dense() {
        let m = sample();
        let c = [2.0, -1.0, 0.5];
        let mut out = vec![0.0; 4];
        m.matvec_accumulate(&c, &mut out);
        // dense: D c
        let d = m.to_dense();
        for r in 0..4 {
            let want: f64 = (0..3).map(|j| d[r][j] * c[j]).sum();
            assert!((out[r] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn slice_rows_remaps() {
        let m = sample();
        let s = m.slice_rows(1, 3); // rows 1..3 => [[0 2 0],[3 0 5]]
        assert_eq!(s.rows(), 2);
        assert_eq!(s.cols(), 3);
        assert_eq!(s.to_dense(), vec![vec![0.0, 2.0, 0.0], vec![3.0, 0.0, 5.0]]);
    }

    #[test]
    fn slice_rows_partition_reassembles() {
        let m = sample();
        let a = m.slice_rows(0, 2);
        let b = m.slice_rows(2, 4);
        assert_eq!(a.nnz() + b.nnz(), m.nnz());
        let w = [1.0, 2.0, 3.0, 4.0];
        for c in 0..3 {
            let partial = a.col_dot(c, &w[0..2]) + b.col_dot(c, &w[2..4]);
            assert!((partial - m.col_dot(c, &w)).abs() < 1e-12);
        }
    }

    #[test]
    fn select_columns_subset() {
        let m = sample();
        let s = m.select_columns(&[2, 0]);
        assert_eq!(s.cols(), 2);
        assert_eq!(s.get(3, 0), 6.0); // old col 2
        assert_eq!(s.get(0, 1), 1.0); // old col 0
    }

    #[test]
    fn transpose_involution() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t.cols(), 4);
        assert_eq!(t.get(2, 3), 6.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn dense_slab_layout() {
        let m = sample();
        let slab = m.dense_slab_f32(2, 4); // rows 2..4, col-major dl=2
        // col0: rows2..4 = [3,0]; col1: [0,0]; col2: [5,6]
        assert_eq!(slab, vec![3.0, 0.0, 0.0, 0.0, 5.0, 6.0]);
    }

    #[test]
    fn zero_matrix() {
        let z = CscMatrix::zero(5, 4);
        assert_eq!(z.nnz(), 0);
        let mut out = vec![1.0; 4];
        z.transpose_matvec(&[0.0; 5], &mut out);
        assert_eq!(out, vec![0.0; 4]);
    }

    // The O(nnz) content checks are compiled out in release, so these two
    // pins run in debug only (which is what `cargo test` builds).
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "not strictly sorted")]
    fn from_parts_validates_sorted_rows_in_debug() {
        CscMatrix::from_parts(3, 1, vec![0, 2], vec![2, 1], vec![1.0, 2.0]);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "out of bounds")]
    fn from_parts_validates_row_bounds_in_debug() {
        CscMatrix::from_parts(3, 1, vec![0, 1], vec![7], vec![1.0]);
    }

    #[test]
    #[should_panic(expected = "col_ptr")]
    fn from_parts_shape_checks_run_in_every_profile() {
        // monotonicity is a cheap shape check: always validated
        CscMatrix::from_parts(3, 2, vec![0, 2, 1], vec![0], vec![1.0]);
    }

    #[test]
    fn col_nrm2_sq_sample() {
        let m = sample();
        assert!((m.col_nrm2_sq(2) - (16.0 + 25.0 + 36.0)).abs() < 1e-12);
    }
}
