//! Sparse-matrix substrate.
//!
//! The paper's data matrix is `D ∈ R^{d×N}` with **instances as columns**
//! (`x_i` is column `i`). Every algorithm in this crate touches data through
//! one of two access patterns:
//!
//! * per-instance column access (`x_i` given `i`) — sampling in the inner
//!   loop, full-gradient scatter;
//! * column-wise matvecs (`D^T w` and `D c`).
//!
//! Both favour **CSC** (compressed sparse column), so [`CscMatrix`] is the
//! canonical storage. [`CsrMatrix`] and dense conversions exist for tests
//! and for the CSR-oriented kernels in the XLA path. [`CooBuilder`] is the
//! mutable assembly format used by the generators and the LibSVM reader.
//!
//! Partitioners implement the paper's two data layouts (Fig. 3):
//! [`partition::by_features`] (horizontal slabs — FD-SVRG) and
//! [`partition::by_instances`] (vertical slices — every baseline).

pub mod coo;
pub mod csc;
pub mod csr;
pub mod hashing;
pub mod libsvm;
pub mod partition;

pub use coo::CooBuilder;
pub use csc::CscMatrix;
pub use csr::CsrMatrix;
