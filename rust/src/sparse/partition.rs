//! Data partitioners — paper Fig. 3.
//!
//! * [`by_features`] splits `D` **horizontally** into `q` row slabs
//!   `D^(1) … D^(q)` with `Σ d_l = d` — the FD-SVRG layout. The split is
//!   balanced by *nonzeros*, not raw rows, so workers get even compute even
//!   when feature frequencies are power-law (they are, for text data).
//! * [`by_instances`] splits `D` **vertically** into `q` column shards —
//!   the layout of every instance-distributed baseline.

use super::csc::CscMatrix;
use super::csr::CsrMatrix;

/// A feature slab: rows `[row_lo, row_hi)` of the global matrix, with the
/// slab-local CSC and the global offset needed to reassemble `w`.
#[derive(Clone, Debug)]
pub struct FeatureSlab {
    pub row_lo: usize,
    pub row_hi: usize,
    pub data: CscMatrix,
}

impl FeatureSlab {
    pub fn dim(&self) -> usize {
        self.row_hi - self.row_lo
    }

    /// Build the slab's CSR mirror now when the run is multi-threaded, so
    /// the one-time O(nnz) transpose happens at partition time instead of
    /// inside the first timed epoch. A no-op at `threads <= 1` (the serial
    /// kernels never touch the mirror).
    pub fn prewarm(&self, threads: usize) {
        if threads > 1 {
            self.data.ensure_mirror();
        }
    }
}

/// An instance shard: global column indices + the shard CSC.
#[derive(Clone, Debug)]
pub struct InstanceShard {
    pub col_idx: Vec<usize>,
    pub data: CscMatrix,
}

impl InstanceShard {
    /// See [`FeatureSlab::prewarm`].
    pub fn prewarm(&self, threads: usize) {
        if threads > 1 {
            self.data.ensure_mirror();
        }
    }
}

/// Split by features into `q` contiguous row slabs, balancing nonzeros.
///
/// Returns exactly `q` slabs covering `[0, d)` disjointly, some possibly
/// empty when `q > d`.
pub fn by_features(m: &CscMatrix, q: usize) -> Vec<FeatureSlab> {
    assert!(q > 0);
    // nonzeros per row
    let csr = CsrMatrix::from_csc(m);
    let d = m.rows();
    let total = m.nnz();
    let target = (total as f64 / q as f64).max(1.0);
    let mut cuts = Vec::with_capacity(q + 1);
    cuts.push(0usize);
    let mut acc = 0usize;
    let mut next_target = target;
    for r in 0..d {
        acc += csr.row_nnz(r);
        if cuts.len() < q && acc as f64 >= next_target {
            cuts.push(r + 1);
            next_target += target;
        }
    }
    while cuts.len() < q {
        cuts.push(d);
    }
    cuts.push(d);
    (0..q)
        .map(|l| FeatureSlab {
            row_lo: cuts[l],
            row_hi: cuts[l + 1],
            data: m.slice_rows(cuts[l], cuts[l + 1]),
        })
        .collect()
}

/// Split by features into `q` contiguous slabs of (near-)equal **row
/// count**. The naive FD-SVRG inner loop does `O(d_l)` dense work per
/// step, which dominates its per-epoch cost (≈ `2M` flops per row vs ~4
/// per nonzero), so its critical path is `max_l d_l` — and on power-law
/// data the nnz-balanced cut of [`by_features`] gives the tail worker
/// almost all of `d`. The lazy inner loop (`RunParams::lazy`) does
/// `O(nnz)` work and wants the nnz-balanced cut instead.
pub fn by_features_rows(m: &CscMatrix, q: usize) -> Vec<FeatureSlab> {
    assert!(q > 0);
    let d = m.rows();
    (0..q)
        .map(|l| {
            let row_lo = l * d / q;
            let row_hi = (l + 1) * d / q;
            FeatureSlab { row_lo, row_hi, data: m.slice_rows(row_lo, row_hi) }
        })
        .collect()
}

/// Split by instances into `q` round-robin column shards (round-robin keeps
/// label balance without needing the labels).
pub fn by_instances(m: &CscMatrix, q: usize) -> Vec<InstanceShard> {
    assert!(q > 0);
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); q];
    for c in 0..m.cols() {
        shards[c % q].push(c);
    }
    shards
        .into_iter()
        .map(|col_idx| InstanceShard { data: m.select_columns(&col_idx), col_idx })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooBuilder;
    use crate::util::Pcg64;

    fn random_matrix(rows: usize, cols: usize, nnz: usize, seed: u64) -> CscMatrix {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut b = CooBuilder::new(rows, cols);
        for _ in 0..nnz {
            b.push(rng.below(rows), rng.below(cols), rng.range_f64(-1.0, 1.0));
        }
        b.to_csc()
    }

    #[test]
    fn feature_slabs_cover_disjointly() {
        let m = random_matrix(100, 40, 600, 1);
        for q in [1, 2, 3, 7, 16] {
            let slabs = by_features(&m, q);
            assert_eq!(slabs.len(), q);
            assert_eq!(slabs[0].row_lo, 0);
            assert_eq!(slabs.last().unwrap().row_hi, 100);
            for w in slabs.windows(2) {
                assert_eq!(w[0].row_hi, w[1].row_lo);
            }
            let nnz_sum: usize = slabs.iter().map(|s| s.data.nnz()).sum();
            assert_eq!(nnz_sum, m.nnz());
        }
    }

    #[test]
    fn feature_slabs_balance_nnz() {
        let m = random_matrix(1000, 50, 20_000, 2);
        let slabs = by_features(&m, 4);
        let avg = m.nnz() as f64 / 4.0;
        for s in &slabs {
            assert!(
                (s.data.nnz() as f64) < 1.6 * avg && (s.data.nnz() as f64) > 0.4 * avg,
                "slab nnz {} vs avg {avg}",
                s.data.nnz()
            );
        }
    }

    #[test]
    fn partial_dots_sum_to_full_dot() {
        // THE invariant that makes FD-SVRG work: Σ_l w^(l)ᵀ x_i^(l) = wᵀ x_i.
        let m = random_matrix(200, 30, 1500, 3);
        let mut rng = Pcg64::seed_from_u64(4);
        let w: Vec<f64> = (0..200).map(|_| rng.normal()).collect();
        let slabs = by_features(&m, 5);
        for i in 0..30 {
            let full = m.col_dot(i, &w);
            let partial: f64 =
                slabs.iter().map(|s| s.data.col_dot(i, &w[s.row_lo..s.row_hi])).sum();
            assert!((full - partial).abs() < 1e-10, "col {i}: {full} vs {partial}");
        }
    }

    #[test]
    fn instance_shards_cover() {
        let m = random_matrix(50, 23, 300, 5);
        let shards = by_instances(&m, 4);
        assert_eq!(shards.len(), 4);
        let mut all: Vec<usize> = shards.iter().flat_map(|s| s.col_idx.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..23).collect::<Vec<_>>());
        let nnz_sum: usize = shards.iter().map(|s| s.data.nnz()).sum();
        assert_eq!(nnz_sum, m.nnz());
    }

    #[test]
    fn more_workers_than_rows() {
        let m = random_matrix(3, 5, 10, 6);
        let slabs = by_features(&m, 8);
        assert_eq!(slabs.len(), 8);
        let nnz_sum: usize = slabs.iter().map(|s| s.data.nnz()).sum();
        assert_eq!(nnz_sum, m.nnz());
    }

    #[test]
    fn single_worker_identity() {
        let m = random_matrix(40, 10, 100, 7);
        let slabs = by_features(&m, 1);
        assert_eq!(slabs[0].data, m);
        let shards = by_instances(&m, 1);
        assert_eq!(shards[0].data, m);
    }
}
