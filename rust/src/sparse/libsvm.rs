//! LibSVM text format (`label idx:val idx:val ...`, 1-based indices).
//!
//! The paper's four datasets (news20.binary, url, webspam, kdd2010) ship in
//! this format on the LibSVM site. The reader accepts those files unchanged;
//! the writer is used by the synthetic generators so the `-sim` datasets are
//! byte-compatible with external tools.

use crate::sparse::{CooBuilder, CscMatrix};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// A labelled sparse dataset: `x` is `d × N` (instances as columns),
/// `y ∈ {-1, +1}^N`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub x: CscMatrix,
    pub y: Vec<f64>,
}

impl Dataset {
    pub fn d(&self) -> usize {
        self.x.rows()
    }

    pub fn n(&self) -> usize {
        self.x.cols()
    }

    pub fn nnz(&self) -> usize {
        self.x.nnz()
    }

    /// Mean nonzeros per instance.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / self.n() as f64
    }
}

/// Parse LibSVM text. `min_dim` lets callers force the paper's published
/// feature count even if the tail features never occur in the sample.
pub fn read<R: BufRead>(reader: R, name: &str, min_dim: usize) -> Result<Dataset> {
    let mut labels = Vec::new();
    let mut triples: Vec<(u32, u32, f64)> = Vec::new();
    let mut max_feat = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("read line")?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label_tok = parts.next().context("missing label")?;
        let label: f64 = label_tok
            .parse()
            .with_context(|| format!("line {}: bad label {label_tok:?}", lineno + 1))?;
        // normalize {0,1}, {1,2}, {-1,+1} labelings to {-1,+1}
        let y = if label > 0.0 && label < 1.5 { 1.0 } else if label > 1.5 { -1.0 } else { -1.0 };
        let col = labels.len() as u32;
        labels.push(y);
        for tok in parts {
            let (idx_s, val_s) = tok
                .split_once(':')
                .with_context(|| format!("line {}: bad token {tok:?}", lineno + 1))?;
            let idx: usize = idx_s
                .parse()
                .with_context(|| format!("line {}: bad index {idx_s:?}", lineno + 1))?;
            // Validate BEFORE the 0-based conversion: `(idx - 1) as u32`
            // on a malformed `0:val` token would underflow (wrapping to
            // u32::MAX in release, panicking in debug), and an index past
            // u32::MAX would silently truncate the row id.
            if idx == 0 {
                bail!(
                    "line {}: LibSVM indices are 1-based, got 0 in token {tok:?}",
                    lineno + 1
                );
            }
            if idx > u32::MAX as usize {
                bail!(
                    "line {}: feature index {idx} exceeds the u32 row-index range",
                    lineno + 1
                );
            }
            let val: f64 = val_s
                .parse()
                .with_context(|| format!("line {}: bad value {val_s:?}", lineno + 1))?;
            max_feat = max_feat.max(idx);
            triples.push(((idx - 1) as u32, col, val));
        }
    }
    let d = max_feat.max(min_dim);
    let n = labels.len();
    let mut b = CooBuilder::new(d, n);
    for (r, c, v) in triples {
        b.push(r as usize, c as usize, v);
    }
    Ok(Dataset { name: name.to_string(), x: b.to_csc(), y: labels })
}

pub fn read_file<P: AsRef<Path>>(path: P, min_dim: usize) -> Result<Dataset> {
    let path = path.as_ref();
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let name = path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
    read(BufReader::new(f), &name, min_dim)
}

/// Write in LibSVM text format (1-based indices, `%.6g`-style values).
pub fn write<W: Write>(ds: &Dataset, out: W) -> Result<()> {
    let mut w = BufWriter::new(out);
    for i in 0..ds.n() {
        if ds.y[i] > 0.0 {
            write!(w, "+1")?;
        } else {
            write!(w, "-1")?;
        }
        for (r, v) in ds.x.col_iter(i) {
            write!(w, " {}:{}", r + 1, v)?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

pub fn write_file<P: AsRef<Path>>(ds: &Dataset, path: P) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    write(ds, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const SAMPLE: &str = "\
+1 1:0.5 3:1.5
-1 2:2.0
+1 1:1.0 2:1.0 3:1.0
";

    #[test]
    fn parse_sample() {
        let ds = read(Cursor::new(SAMPLE), "sample", 0).unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.x.get(0, 0), 0.5);
        assert_eq!(ds.x.get(2, 0), 1.5);
        assert_eq!(ds.x.get(1, 1), 2.0);
        assert_eq!(ds.nnz(), 6);
    }

    #[test]
    fn min_dim_pads_features() {
        let ds = read(Cursor::new(SAMPLE), "s", 10).unwrap();
        assert_eq!(ds.d(), 10);
    }

    #[test]
    fn zero_one_labels_normalized() {
        let ds = read(Cursor::new("1 1:1\n0 2:1\n"), "s", 0).unwrap();
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn round_trip() {
        let ds = read(Cursor::new(SAMPLE), "rt", 0).unwrap();
        let mut buf = Vec::new();
        write(&ds, &mut buf).unwrap();
        let ds2 = read(Cursor::new(buf), "rt", 0).unwrap();
        assert_eq!(ds.x, ds2.x);
        assert_eq!(ds.y, ds2.y);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(read(Cursor::new("+1 0:1.0\n"), "s", 0).is_err());
    }

    #[test]
    fn rejects_zero_index_with_line_context() {
        let err = read(Cursor::new("+1 1:1\n+1 0:1.0\n"), "s", 0).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("1-based"), "{msg}");
        assert!(msg.contains("0:1.0"), "{msg}");
    }

    #[test]
    fn rejects_index_beyond_u32_range() {
        // u32::MAX itself is the largest representable 1-based index
        assert!(read(Cursor::new("+1 4294967295:1.0\n"), "s", 0).is_ok());
        let err = read(Cursor::new("+1 4294967296:1.0\n"), "s", 0).unwrap_err();
        assert!(format!("{err:#}").contains("u32"), "{err:#}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(read(Cursor::new("+1 abc\n"), "s", 0).is_err());
        assert!(read(Cursor::new("xyz 1:1\n"), "s", 0).is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let ds = read(Cursor::new("# hi\n\n+1 1:1\n"), "s", 0).unwrap();
        assert_eq!(ds.n(), 1);
    }
}
