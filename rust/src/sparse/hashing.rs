//! Feature hashing (the "hashing trick", Weinberger et al. 2009) — the
//! standard dimensionality-reduction preprocessing for the text-scale
//! feature spaces this paper targets (news20: 1.4M features, kdd2010:
//! 30M). Hashing to `d' < d` buckets with a sign hash preserves inner
//! products in expectation, so a practitioner can trade the paper's
//! `d > N` regime against memory — and the FD-SVRG communication model
//! (scalars only) is *unchanged* by the transform, which is worth testing.

use super::{CooBuilder, CscMatrix};

/// SplitMix64-style avalanche over (feature, salt).
#[inline]
fn mix(feature: u64, salt: u64) -> u64 {
    let mut z = feature.wrapping_add(salt).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Hash the rows (features) of `m` into `buckets` rows with ±1 signs.
/// Collisions add; the sign hash makes collision noise zero-mean so
/// `E[⟨h(x), h(x')⟩] = ⟨x, x'⟩`.
pub fn hash_features(m: &CscMatrix, buckets: usize, seed: u64) -> CscMatrix {
    assert!(buckets > 0);
    let mut b = CooBuilder::new(buckets, m.cols());
    for c in 0..m.cols() {
        for (r, v) in m.col_iter(c) {
            let h = mix(r as u64, seed);
            let bucket = (h % buckets as u64) as usize;
            let sign = if h >> 63 == 0 { 1.0 } else { -1.0 };
            b.push(bucket, c, sign * v);
        }
    }
    b.to_csc()
}

/// Hash a whole dataset (features only; labels pass through).
pub fn hash_dataset(
    ds: &crate::sparse::libsvm::Dataset,
    buckets: usize,
    seed: u64,
) -> crate::sparse::libsvm::Dataset {
    crate::sparse::libsvm::Dataset {
        name: format!("{}_h{buckets}", ds.name),
        x: hash_features(&ds.x, buckets, seed),
        y: ds.y.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, GenSpec};

    fn ds() -> crate::sparse::libsvm::Dataset {
        generate(&GenSpec::new("hash", 5_000, 300, 40).with_seed(19))
    }

    #[test]
    fn shapes_and_nnz_bound() {
        let d = ds();
        let h = hash_features(&d.x, 512, 1);
        assert_eq!(h.rows(), 512);
        assert_eq!(h.cols(), d.n());
        // collisions within a column can merge (or cancel) entries
        assert!(h.nnz() <= d.x.nnz());
    }

    #[test]
    fn inner_products_preserved_in_expectation() {
        let d = ds();
        let h = hash_features(&d.x, 2048, 7);
        // instance norms: E⟨h(x),h(x)⟩ = ‖x‖² = 1 (generator normalizes)
        let mean_sq: f64 =
            (0..d.n()).map(|i| h.col_nrm2_sq(i)).sum::<f64>() / d.n() as f64;
        assert!(
            (mean_sq - 1.0).abs() < 0.05,
            "mean hashed norm² {mean_sq} should be ≈ 1"
        );
    }

    #[test]
    fn deterministic_per_seed_different_across_seeds() {
        let d = ds();
        let a = hash_features(&d.x, 256, 3);
        let b = hash_features(&d.x, 256, 3);
        assert_eq!(a, b);
        let c = hash_features(&d.x, 256, 4);
        assert_ne!(a, c);
    }

    #[test]
    fn hashed_problem_still_learnable() {
        // train FD-SVRG on the hashed dataset; signal must survive
        let d = hash_dataset(&ds(), 1024, 11);
        let p = crate::algs::Problem::logistic_l2(d, 1e-3);
        let params = crate::algs::RunParams {
            q: 4,
            outer: 8,
            sim: crate::net::SimParams::free(),
            ..Default::default()
        };
        let res = crate::algs::Algorithm::FdSvrg.run(&p, &params);
        assert!(p.accuracy(&res.w) > 0.8, "hashed accuracy {}", p.accuracy(&res.w));
    }

    #[test]
    fn comm_model_unchanged_by_hashing() {
        // FD-SVRG scalars depend on (q, N) only — hashing d must not
        // change the counters (the paper's cost model is d-free)
        let original = ds();
        let hashed = hash_dataset(&original, 512, 2);
        let params = crate::algs::RunParams {
            q: 4,
            outer: 2,
            sim: crate::net::SimParams::free(),
            ..Default::default()
        };
        let a = crate::algs::Algorithm::FdSvrg
            .run(&crate::algs::Problem::logistic_l2(original, 1e-3), &params);
        let b = crate::algs::Algorithm::FdSvrg
            .run(&crate::algs::Problem::logistic_l2(hashed, 1e-3), &params);
        assert_eq!(a.total_scalars, b.total_scalars);
    }
}
