//! COO (triplet) assembly format.

use super::csc::CscMatrix;

/// Mutable triplet builder; the generators and parsers accumulate entries
/// here and finish with [`CooBuilder::to_csc`].
#[derive(Clone, Debug, Default)]
pub struct CooBuilder {
    pub rows: usize,
    pub cols: usize,
    entries: Vec<(u32, u32, f64)>, // (row, col, value)
}

impl CooBuilder {
    pub fn new(rows: usize, cols: usize) -> Self {
        CooBuilder { rows, cols, entries: Vec::new() }
    }

    /// Add a triplet. Duplicate (row, col) entries are *summed* at
    /// conversion time (standard COO semantics).
    pub fn push(&mut self, row: usize, col: usize, value: f64) {
        assert!(row < self.rows, "row {row} out of bounds {}", self.rows);
        assert!(col < self.cols, "col {col} out of bounds {}", self.cols);
        if value != 0.0 {
            self.entries.push((row as u32, col as u32, value));
        }
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Convert to CSC: counting sort by column, then per-column sort by row
    /// with duplicate coalescing.
    pub fn to_csc(&self) -> CscMatrix {
        let mut col_counts = vec![0usize; self.cols + 1];
        for &(_, c, _) in &self.entries {
            col_counts[c as usize + 1] += 1;
        }
        for i in 0..self.cols {
            col_counts[i + 1] += col_counts[i];
        }
        let col_ptr_raw = col_counts.clone();
        let mut row_idx = vec![0u32; self.entries.len()];
        let mut values = vec![0f64; self.entries.len()];
        let mut cursor = col_counts;
        for &(r, c, v) in &self.entries {
            let p = cursor[c as usize];
            row_idx[p] = r;
            values[p] = v;
            cursor[c as usize] += 1;
        }
        // per-column: sort by row, coalesce duplicates
        let mut out_ptr = vec![0usize; self.cols + 1];
        let mut out_rows: Vec<u32> = Vec::with_capacity(row_idx.len());
        let mut out_vals: Vec<f64> = Vec::with_capacity(values.len());
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for c in 0..self.cols {
            let (s, e) = (col_ptr_raw[c], col_ptr_raw[c + 1]);
            scratch.clear();
            scratch.extend(row_idx[s..e].iter().copied().zip(values[s..e].iter().copied()));
            scratch.sort_unstable_by_key(|&(r, _)| r);
            let mut i = 0;
            while i < scratch.len() {
                let (r, mut v) = scratch[i];
                let mut j = i + 1;
                while j < scratch.len() && scratch[j].0 == r {
                    v += scratch[j].1;
                    j += 1;
                }
                if v != 0.0 {
                    out_rows.push(r);
                    out_vals.push(v);
                }
                i = j;
            }
            out_ptr[c + 1] = out_rows.len();
        }
        CscMatrix::from_parts(self.rows, self.cols, out_ptr, out_rows, out_vals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_small() {
        let mut b = CooBuilder::new(3, 2);
        b.push(0, 0, 1.0);
        b.push(2, 0, 2.0);
        b.push(1, 1, 3.0);
        let m = b.to_csc();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.to_dense(), vec![vec![1.0, 0.0], vec![0.0, 3.0], vec![2.0, 0.0]]);
    }

    #[test]
    fn duplicates_are_summed() {
        let mut b = CooBuilder::new(2, 2);
        b.push(0, 1, 1.5);
        b.push(0, 1, 2.5);
        let m = b.to_csc();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.get(0, 1), 4.0);
    }

    #[test]
    fn zeros_are_dropped() {
        let mut b = CooBuilder::new(2, 2);
        b.push(0, 0, 0.0);
        b.push(1, 1, 1.0);
        b.push(1, 1, -1.0); // cancels to zero
        let m = b.to_csc();
        assert_eq!(m.nnz(), 0);
    }

    #[test]
    fn unsorted_insertion_order_ok() {
        let mut b = CooBuilder::new(4, 1);
        b.push(3, 0, 3.0);
        b.push(0, 0, 1.0);
        b.push(2, 0, 2.0);
        let m = b.to_csc();
        let col: Vec<(u32, f64)> = m.col_iter(0).map(|(r, v)| (r, v)).collect();
        assert_eq!(col, vec![(0, 1.0), (2, 2.0), (3, 3.0)]);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_panics() {
        let mut b = CooBuilder::new(2, 2);
        b.push(2, 0, 1.0);
    }
}
