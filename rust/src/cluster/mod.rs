//! Cluster runtime: spawns one OS thread per simulated node and wires the
//! endpoints. Owns process topology and deterministic teardown; algorithms
//! only see their [`Endpoint`] plus whatever state the launcher hands them.
//!
//! Node closures may *return early* (cooperative injected crashes in the
//! robust serving plane — see [`crate::serve`]): a returned closure drops
//! its endpoint, surviving peers observe the closed link as
//! `Arrival::Gone`, and teardown still joins every thread, so a partial
//! cluster winds down cleanly instead of deadlocking.

use crate::net::{build, build_with_model, CommStats, Endpoint, NetModel, SimParams};
use std::sync::{Arc, Condvar, Mutex};

/// Clock-synchronizing barrier: all participants wait, and every clock is
/// advanced to the maximum over the group (plus nothing — barrier traffic
/// is negligible next to the collectives and the paper does not count it).
pub struct SimBarrier {
    n: usize,
    state: Mutex<BarrierState>,
    cv: Condvar,
}

struct BarrierState {
    waiting: usize,
    generation: u64,
    max_clock: f64,
    release_clock: f64,
}

impl SimBarrier {
    pub fn new(n: usize) -> Arc<Self> {
        Arc::new(SimBarrier {
            n,
            state: Mutex::new(BarrierState {
                waiting: 0,
                generation: 0,
                max_clock: 0.0,
                release_clock: 0.0,
            }),
            cv: Condvar::new(),
        })
    }

    /// Wait for all `n` nodes; returns the synchronized (max) clock.
    pub fn wait(&self, ep: &mut Endpoint) -> f64 {
        let my_clock = ep.now();
        let mut st = self.state.lock().unwrap();
        let gen = st.generation;
        st.max_clock = st.max_clock.max(my_clock);
        st.waiting += 1;
        if st.waiting == self.n {
            st.waiting = 0;
            st.generation += 1;
            st.release_clock = st.max_clock;
            st.max_clock = 0.0;
            self.cv.notify_all();
        } else {
            while st.generation == gen {
                st = self.cv.wait(st).unwrap();
            }
        }
        let release = st.release_clock;
        drop(st);
        ep.discard_cpu(); // waiting time is not compute
        ep.advance_to(release);
        release
    }
}

/// Outcome of a cluster run: per-node return values plus the comm counters.
pub struct ClusterRun<T> {
    pub results: Vec<T>,
    pub stats: Arc<CommStats>,
}

/// Run `f(endpoint)` on `n_nodes` threads. Node 0 is the coordinator by
/// convention; `f` receives each node's endpoint (id = index). A panic in
/// any node fails the whole run loudly (rather than deadlocking the
/// others): the panicking node's channel drops, peers blocked on it panic
/// on `recv`, and the launcher re-raises.
pub fn run_cluster<T, F>(n_nodes: usize, params: SimParams, f: F) -> ClusterRun<T>
where
    T: Send,
    F: Fn(Endpoint) -> T + Send + Sync,
{
    let (eps, stats) = build(n_nodes, params);
    ClusterRun { results: run_endpoints(eps, f), stats }
}

/// [`run_cluster`] under an explicit [`NetModel`] — scenario runs
/// (heterogeneous racks, stragglers, seeded jitter) where each endpoint
/// gets its own link view instead of a flat `SimParams`.
pub fn run_cluster_model<T, F>(n_nodes: usize, model: &NetModel, f: F) -> ClusterRun<T>
where
    T: Send,
    F: Fn(Endpoint) -> T + Send + Sync,
{
    let (eps, stats) = build_with_model(n_nodes, model);
    ClusterRun { results: run_endpoints(eps, f), stats }
}

/// Run `f(endpoint)` on one thread per pre-built endpoint. This is the
/// spawning/teardown half of [`run_cluster`], split out so launchers that
/// need to prepare the endpoints first (the session layer preloads comm
/// counters and restores clock states when resuming from a checkpoint)
/// share the same panic-propagation semantics.
pub fn run_endpoints<T, F>(eps: Vec<Endpoint>, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Endpoint) -> T + Send + Sync,
{
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = eps.into_iter().map(|ep| scope.spawn(move || f(ep))).collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(v) => v,
                Err(e) => {
                    let msg = e
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| e.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "<non-string payload>".into());
                    panic!("node panicked: {msg}");
                }
            })
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_collects_in_order() {
        let out = run_cluster(4, SimParams::free(), |ep| ep.id() * 10);
        assert_eq!(out.results, vec![0, 10, 20, 30]);
    }

    #[test]
    fn barrier_syncs_clocks() {
        let barrier = SimBarrier::new(3);
        let out = run_cluster(3, SimParams { latency: 1.0, per_msg: 0.0, sec_per_byte: 0.0 }, {
            let barrier = barrier.clone();
            move |mut ep| {
                if ep.id() == 2 {
                    // node 2 is "slow": pretend it received a late message
                    ep.advance_to(5.0);
                }
                barrier.wait(&mut ep)
            }
        });
        for t in out.results {
            assert!(t >= 5.0, "barrier must release at the max clock, got {t}");
        }
    }

    #[test]
    fn run_cluster_model_hands_each_node_its_link_view() {
        let model = NetModel::Straggler { base: SimParams::free(), slow: 1, factor: 3.0 };
        let out = run_cluster_model(3, &model, |ep| ep.net().compute_scale());
        assert_eq!(out.results, vec![1.0, 1.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "node panicked")]
    fn node_panic_propagates() {
        run_cluster(2, SimParams::free(), |ep| {
            if ep.id() == 1 {
                panic!("boom");
            }
        });
    }
}
