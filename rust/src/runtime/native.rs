//! Pure-Rust f32 backend of the [`ComputeEngine`] contract — the default
//! engine, available offline with no PJRT toolchain.
//!
//! Implements the same padded-block semantics as the AOT Pallas kernels:
//! column-major `(BLOCK_D × BLOCK_N)` tiles, f32 arithmetic throughout,
//! loss derivatives matching [`crate::loss`] evaluated in f32. Padding is
//! inert by construction: padded instances are zero columns with `y = 0`,
//! for which both derivative kernels return exactly `0.0`, and zero tile
//! entries contribute exactly nothing to every dot/scatter.
//!
//! The integration suite (`rust/tests/xla_runtime.rs`) checks every kernel
//! of this engine against the f64 CSC reference path to f32 tolerance; the
//! same tests run against the PJRT engine under `--features xla`.

use super::contract::{ComputeEngine, BLOCK_D, BLOCK_N, BLOCK_U};
use anyhow::{ensure, Result};

/// f32 logistic derivative `φ'(z, y) = −y·σ(−yz)`, the single-precision
/// mirror of [`crate::loss::Logistic::derivative`] (same stable form).
#[inline]
fn logistic_deriv(z: f32, y: f32) -> f32 {
    let m = y * z;
    let s = if m > 0.0 {
        let e = (-m).exp();
        e / (1.0 + e)
    } else {
        1.0 / (1.0 + m.exp())
    };
    -y * s
}

/// f32 smoothed-hinge derivative, mirroring
/// [`crate::loss::SmoothedHinge::derivative`].
#[inline]
fn hinge_deriv(z: f32, y: f32, gamma: f32) -> f32 {
    let m = y * z;
    if m >= 1.0 {
        0.0
    } else if m > 1.0 - gamma {
        -y * (1.0 - m) / gamma
    } else {
        -y
    }
}

/// Dot of `w` against tile column `j` (instance `j` of the block) —
/// 4-way unrolled with a single left-to-right accumulation chain, so the
/// result is bit-identical to the scalar loop (the xla_runtime suite
/// compares this engine's kernels against the f64 reference).
#[inline]
fn col_dot(w: &[f32], d_block: &[f32], j: usize) -> f32 {
    let col = &d_block[j * BLOCK_D..(j + 1) * BLOCK_D];
    let n = w.len().min(col.len());
    let chunks = n / 4;
    let mut acc = 0f32;
    for c in 0..chunks {
        let i = 4 * c;
        let p0 = w[i] * col[i];
        let p1 = w[i + 1] * col[i + 1];
        let p2 = w[i + 2] * col[i + 2];
        let p3 = w[i + 3] * col[i + 3];
        acc = acc + p0 + p1 + p2 + p3;
    }
    for i in 4 * chunks..n {
        acc += w[i] * col[i];
    }
    acc
}

/// The pure-Rust compute engine. Stateless; construction never fails.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeEngine;

impl NativeEngine {
    pub fn new() -> NativeEngine {
        NativeEngine
    }
}

impl ComputeEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn partial_products(&self, w: &[f32], d_block: &[f32]) -> Result<Vec<f32>> {
        ensure!(w.len() == BLOCK_D, "partial_products: w len {}", w.len());
        ensure!(d_block.len() == BLOCK_D * BLOCK_N, "partial_products: tile len {}", d_block.len());
        let mut s = vec![0f32; BLOCK_N];
        for (j, sv) in s.iter_mut().enumerate() {
            *sv = col_dot(w, d_block, j);
        }
        Ok(s)
    }

    fn logistic_coef(&self, s: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        ensure!(s.len() == BLOCK_N && y.len() == BLOCK_N, "logistic_coef: bad lengths");
        Ok(s.iter().zip(y.iter()).map(|(&z, &yi)| logistic_deriv(z, yi)).collect())
    }

    fn hinge_coef(&self, s: &[f32], y: &[f32], gamma: f32) -> Result<Vec<f32>> {
        ensure!(s.len() == BLOCK_N && y.len() == BLOCK_N, "hinge_coef: bad lengths");
        ensure!(gamma > 0.0, "hinge_coef: gamma must be positive, got {gamma}");
        Ok(s.iter().zip(y.iter()).map(|(&z, &yi)| hinge_deriv(z, yi, gamma)).collect())
    }

    fn coef_matvec(&self, d_block: &[f32], c: &[f32]) -> Result<Vec<f32>> {
        ensure!(d_block.len() == BLOCK_D * BLOCK_N, "coef_matvec: tile len {}", d_block.len());
        ensure!(c.len() == BLOCK_N, "coef_matvec: c len {}", c.len());
        let mut z = vec![0f32; BLOCK_D];
        for (j, &cj) in c.iter().enumerate() {
            if cj != 0.0 {
                let col = &d_block[j * BLOCK_D..(j + 1) * BLOCK_D];
                for (zv, &dv) in z.iter_mut().zip(col.iter()) {
                    *zv += cj * dv;
                }
            }
        }
        Ok(z)
    }

    fn batch_dots(&self, w: &[f32], d_block: &[f32], idx: &[i32]) -> Result<Vec<f32>> {
        ensure!(w.len() == BLOCK_D, "batch_dots: w len {}", w.len());
        ensure!(d_block.len() == BLOCK_D * BLOCK_N, "batch_dots: tile len {}", d_block.len());
        ensure!(idx.len() == BLOCK_U, "batch_dots: idx len {}", idx.len());
        let mut p = vec![0f32; BLOCK_U];
        for (pv, &i) in p.iter_mut().zip(idx.iter()) {
            let j = i as usize;
            ensure!(j < BLOCK_N, "batch_dots: index {i} out of block");
            *pv = col_dot(w, d_block, j);
        }
        Ok(p)
    }

    fn batch_update(
        &self,
        w: &[f32],
        z: &[f32],
        d_block: &[f32],
        idx: &[i32],
        margins: &[f32],
        y: &[f32],
        c0: &[f32],
        eta: f32,
        lambda: f32,
    ) -> Result<Vec<f32>> {
        ensure!(w.len() == BLOCK_D && z.len() == BLOCK_D, "batch_update: w/z lengths");
        ensure!(d_block.len() == BLOCK_D * BLOCK_N, "batch_update: tile len {}", d_block.len());
        ensure!(
            idx.len() == BLOCK_U && margins.len() == BLOCK_U && y.len() == BLOCK_U && c0.len() == BLOCK_U,
            "batch_update: batch lengths"
        );
        let shrink = 1.0 - eta * lambda;
        let mut out = w.to_vec();
        for (k, &ik) in idx.iter().enumerate() {
            let j = ik as usize;
            ensure!(j < BLOCK_N, "batch_update: index {ik} out of block");
            // variance-reduced coefficient from the *pre-batch* margin
            let delta = logistic_deriv(margins[k], y[k]) - c0[k];
            // dense part: w ← (1−ηλ)·w − η·z
            for (wv, &zv) in out.iter_mut().zip(z.iter()) {
                *wv = shrink * *wv - eta * zv;
            }
            // sparse part: w ← w − ηδ·x_j (dense column; zero padding inert)
            let col = &d_block[j * BLOCK_D..(j + 1) * BLOCK_D];
            let step = eta * delta;
            for (wv, &dv) in out.iter_mut().zip(col.iter()) {
                *wv -= step * dv;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::super::contract::pad_vec;
    use super::*;
    use crate::loss::{Logistic, Loss, SmoothedHinge};

    #[test]
    fn logistic_deriv_matches_f64_loss() {
        let loss = Logistic;
        for &z in &[-30.0f32, -2.0, -0.1, 0.0, 0.1, 2.0, 30.0] {
            for &y in &[-1.0f32, 1.0] {
                let want = loss.derivative(z as f64, y as f64);
                let got = logistic_deriv(z, y) as f64;
                assert!((got - want).abs() < 1e-6, "z={z} y={y}: {got} vs {want}");
            }
        }
        // padded instances (y = 0) must produce exactly zero
        assert_eq!(logistic_deriv(0.0, 0.0), 0.0);
    }

    #[test]
    fn hinge_deriv_matches_f64_loss() {
        for gamma in [0.25f32, 1.0] {
            let loss = SmoothedHinge { gamma: gamma as f64 };
            for &z in &[-2.0f32, 0.2, 0.74, 0.9, 1.5] {
                for &y in &[-1.0f32, 1.0] {
                    let want = loss.derivative(z as f64, y as f64);
                    let got = hinge_deriv(z, y, gamma) as f64;
                    assert!((got - want).abs() < 1e-6, "γ={gamma} z={z} y={y}");
                }
            }
            assert_eq!(hinge_deriv(0.0, 0.0, gamma), 0.0, "padding must be inert");
        }
    }

    #[test]
    fn partial_products_padding_reads_zero() {
        let e = NativeEngine::new();
        let w = pad_vec(&[1.0, -2.0], BLOCK_D);
        let mut tile = vec![0f32; BLOCK_D * BLOCK_N];
        tile[0] = 3.0; // instance 0, feature 0
        tile[1] = 0.5; // instance 0, feature 1
        let s = e.partial_products(&w, &tile).unwrap();
        assert_eq!(s[0], 3.0 - 1.0);
        assert!(s[1..].iter().all(|&v| v == 0.0), "padding leaked");
    }

    #[test]
    fn coef_matvec_is_transpose_of_partial_products() {
        // z = D c and s = Dᵀ w satisfy ⟨w, Dc⟩ = ⟨Dᵀw, c⟩
        let e = NativeEngine::new();
        let mut rng = crate::util::Pcg64::seed_from_u64(12);
        let w: Vec<f32> = (0..BLOCK_D).map(|_| rng.normal() as f32).collect();
        let c: Vec<f32> = (0..BLOCK_N).map(|_| rng.normal() as f32 * 0.01).collect();
        let tile: Vec<f32> =
            (0..BLOCK_D * BLOCK_N).map(|_| if rng.next_f64() < 0.05 { rng.normal() as f32 } else { 0.0 }).collect();
        let s = e.partial_products(&w, &tile).unwrap();
        let z = e.coef_matvec(&tile, &c).unwrap();
        let lhs: f64 = w.iter().zip(z.iter()).map(|(&a, &b)| a as f64 * b as f64).sum();
        let rhs: f64 = s.iter().zip(c.iter()).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
    }

    #[test]
    fn batch_update_zero_delta_is_pure_shrink() {
        // margins chosen so φ'(m, y) == c0 → δ = 0 → w' = (1−ηλ)w − ηz
        let e = NativeEngine::new();
        let w = vec![1.0f32; BLOCK_D];
        let z = vec![0.5f32; BLOCK_D];
        let tile = vec![0f32; BLOCK_D * BLOCK_N];
        let idx = vec![0i32; BLOCK_U];
        let margins = vec![0.3f32; BLOCK_U];
        let y = vec![1.0f32; BLOCK_U];
        let c0: Vec<f32> = margins.iter().map(|&m| logistic_deriv(m, 1.0)).collect();
        let (eta, lambda) = (0.1f32, 0.01f32);
        let got = e.batch_update(&w, &z, &tile, &idx, &margins, &y, &c0, eta, lambda).unwrap();
        let mut want = 1.0f32;
        for _ in 0..BLOCK_U {
            want = (1.0 - eta * lambda) * want - eta * 0.5;
        }
        for &v in &got {
            assert!((v - want).abs() < 1e-6, "{v} vs {want}");
        }
    }

    #[test]
    fn out_of_block_index_is_rejected() {
        let e = NativeEngine::new();
        let w = vec![0f32; BLOCK_D];
        let tile = vec![0f32; BLOCK_D * BLOCK_N];
        let mut idx = vec![0i32; BLOCK_U];
        idx[3] = BLOCK_N as i32; // one past the end
        assert!(e.batch_dots(&w, &tile, &idx).is_err());
    }
}
