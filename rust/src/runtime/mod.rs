//! PJRT runtime — the L3↔L2 bridge.
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`
//! (`make artifacts`), compiles each once on the PJRT CPU client, and
//! exposes typed wrappers the coordinator's hot path calls. Python never
//! runs at training time; after `make artifacts` the rust binary is
//! self-contained.
//!
//! ## Artifact contract (shapes are AOT-fixed; rust pads)
//!
//! | artifact | signature | role |
//! |----------|-----------|------|
//! | `partial_products.hlo.txt` | `(w[DL], D[DL,NB]) → s[NB]`  | `D^(l)ᵀ w^(l)` (Alg. 1 line 3) |
//! | `logistic_coef.hlo.txt`    | `(s[NB], y[NB]) → c[NB]`     | `φ'(s_i, y_i)` (logistic) |
//! | `hinge_coef.hlo.txt`       | `(s[NB], y[NB], γ[1]) → c[NB]` | `φ'(s_i, y_i)` (smoothed hinge) |
//! | `coef_matvec.hlo.txt`      | `(D[DL,NB], c[NB]) → z[DL]`  | `D^(l) c` (full gradient, line 5) |
//! | `batch_dots.hlo.txt`       | `(w[DL], D[DL,NB], idx[U]) → p[U]` | inner-batch partial products (line 9) |
//! | `batch_update.hlo.txt`     | `(w[DL], z[DL], D[DL,NB], idx[U], m[U], y[U], c0[U], η, λ) → w'[DL]` | fused inner-batch update (line 11) |
//!
//! `DL`=[`BLOCK_D`], `NB`=[`BLOCK_N`], `U`=[`BLOCK_U`]; all tensors f32
//! except `idx` (i32). The matmul hot spots inside these graphs are Pallas
//! kernels (interpret-mode) — see `python/compile/kernels/`.

pub mod trainer;

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Feature-block length every worker slab is padded to.
pub const BLOCK_D: usize = 256;
/// Instance-block length the dense engine pads N to.
pub const BLOCK_N: usize = 512;
/// Inner mini-batch size of the fused update artifact.
pub const BLOCK_U: usize = 16;

/// Names of all artifacts the runtime expects (and `aot.py` emits).
pub const ARTIFACTS: [&str; 6] = [
    "partial_products",
    "logistic_coef",
    "hinge_coef",
    "coef_matvec",
    "batch_dots",
    "batch_update",
];

/// A compiled PJRT executable with its artifact name.
pub struct Kernel {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Kernel {
    fn execute(&self, args: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("execute {}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("sync {}", self.name))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple
        Ok(tuple.to_tuple1().with_context(|| format!("untuple {}", self.name))?)
    }
}

/// The PJRT client plus the compiled kernel set.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    kernels: HashMap<String, Kernel>,
}

fn f32_input(values: &[f32], shape: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(values).reshape(shape)?)
}

fn i32_input(values: &[i32], shape: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(values).reshape(shape)?)
}

impl Engine {
    /// Load and compile every artifact under `dir` (typically `artifacts/`).
    pub fn load(dir: &Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut kernels = HashMap::new();
        for name in ARTIFACTS {
            let path: PathBuf = dir.join(format!("{name}.hlo.txt"));
            if !path.exists() {
                bail!(
                    "missing artifact {} — run `make artifacts` first",
                    path.display()
                );
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compile {name}"))?;
            kernels.insert(name.to_string(), Kernel { name: name.to_string(), exe });
        }
        Ok(Engine { client, kernels })
    }

    fn kernel(&self, name: &str) -> &Kernel {
        self.kernels.get(name).unwrap_or_else(|| panic!("kernel {name} not loaded"))
    }

    /// `s = Dᵀ w` over one padded block.
    pub fn partial_products(&self, w: &[f32], d_block: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(w.len(), BLOCK_D);
        assert_eq!(d_block.len(), BLOCK_D * BLOCK_N);
        let out = self.kernel("partial_products").execute(&[
            f32_input(w, &[BLOCK_D as i64])?,
            f32_input(d_block, &[BLOCK_N as i64, BLOCK_D as i64])?,
        ])?;
        Ok(out.to_vec::<f32>()?)
    }

    /// `c_i = φ'(s_i, y_i)` (logistic).
    pub fn logistic_coef(&self, s: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(s.len(), BLOCK_N);
        assert_eq!(y.len(), BLOCK_N);
        let out = self.kernel("logistic_coef").execute(&[
            f32_input(s, &[BLOCK_N as i64])?,
            f32_input(y, &[BLOCK_N as i64])?,
        ])?;
        Ok(out.to_vec::<f32>()?)
    }

    /// `c_i = φ'(s_i, y_i)` (smoothed hinge, linear SVM).
    pub fn hinge_coef(&self, s: &[f32], y: &[f32], gamma: f32) -> Result<Vec<f32>> {
        assert_eq!(s.len(), BLOCK_N);
        assert_eq!(y.len(), BLOCK_N);
        let out = self.kernel("hinge_coef").execute(&[
            f32_input(s, &[BLOCK_N as i64])?,
            f32_input(y, &[BLOCK_N as i64])?,
            f32_input(&[gamma], &[1])?,
        ])?;
        Ok(out.to_vec::<f32>()?)
    }

    /// `z = D c` over one padded block.
    pub fn coef_matvec(&self, d_block: &[f32], c: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(d_block.len(), BLOCK_D * BLOCK_N);
        assert_eq!(c.len(), BLOCK_N);
        let out = self.kernel("coef_matvec").execute(&[
            f32_input(d_block, &[BLOCK_N as i64, BLOCK_D as i64])?,
            f32_input(c, &[BLOCK_N as i64])?,
        ])?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Partial inner products for one sampled mini-batch.
    pub fn batch_dots(&self, w: &[f32], d_block: &[f32], idx: &[i32]) -> Result<Vec<f32>> {
        assert_eq!(idx.len(), BLOCK_U);
        let out = self.kernel("batch_dots").execute(&[
            f32_input(w, &[BLOCK_D as i64])?,
            f32_input(d_block, &[BLOCK_N as i64, BLOCK_D as i64])?,
            i32_input(idx, &[BLOCK_U as i64])?,
        ])?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Fused inner-batch SVRG update (Alg. 1 line 11, scanned over the batch).
    #[allow(clippy::too_many_arguments)]
    pub fn batch_update(
        &self,
        w: &[f32],
        z: &[f32],
        d_block: &[f32],
        idx: &[i32],
        margins: &[f32],
        y: &[f32],
        c0: &[f32],
        eta: f32,
        lambda: f32,
    ) -> Result<Vec<f32>> {
        let out = self.kernel("batch_update").execute(&[
            f32_input(w, &[BLOCK_D as i64])?,
            f32_input(z, &[BLOCK_D as i64])?,
            f32_input(d_block, &[BLOCK_N as i64, BLOCK_D as i64])?,
            i32_input(idx, &[BLOCK_U as i64])?,
            f32_input(margins, &[BLOCK_U as i64])?,
            f32_input(y, &[BLOCK_U as i64])?,
            f32_input(c0, &[BLOCK_U as i64])?,
            xla::Literal::from(eta),
            xla::Literal::from(lambda),
        ])?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Pad a dense column-major slab `(dl × n)` to `(BLOCK_D × BLOCK_N)`.
pub fn pad_slab(slab: &[f32], dl: usize, n: usize) -> Vec<f32> {
    assert!(dl <= BLOCK_D && n <= BLOCK_N, "slab {dl}x{n} exceeds block");
    assert_eq!(slab.len(), dl * n);
    let mut out = vec![0f32; BLOCK_D * BLOCK_N];
    for c in 0..n {
        out[c * BLOCK_D..c * BLOCK_D + dl].copy_from_slice(&slab[c * dl..(c + 1) * dl]);
    }
    out
}

/// Pad a vector with zeros to `len`.
pub fn pad_vec(v: &[f32], len: usize) -> Vec<f32> {
    assert!(v.len() <= len);
    let mut out = vec![0f32; len];
    out[..v.len()].copy_from_slice(v);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_slab_layout() {
        // 2x2 slab [[1,3],[2,4]] col-major = [1,2,3,4]
        let padded = pad_slab(&[1.0, 2.0, 3.0, 4.0], 2, 2);
        assert_eq!(padded.len(), BLOCK_D * BLOCK_N);
        assert_eq!(padded[0], 1.0);
        assert_eq!(padded[1], 2.0);
        assert_eq!(padded[BLOCK_D], 3.0);
        assert_eq!(padded[BLOCK_D + 1], 4.0);
        assert_eq!(padded[2], 0.0);
    }

    #[test]
    fn pad_vec_zero_fills() {
        let v = pad_vec(&[1.0, 2.0], 5);
        assert_eq!(v, vec![1.0, 2.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn pad_slab_rejects_oversize() {
        pad_slab(&vec![0f32; (BLOCK_D + 1) * 2], BLOCK_D + 1, 2);
    }

    // Engine-level tests live in rust/tests/xla_runtime.rs (they need the
    // artifacts built by `make artifacts`).
}
