//! Blocked dense compute runtime — the L3↔L2 bridge.
//!
//! [`trainer`] runs the full FD-SVRG loop (Algorithm 1) on an AOT-fixed
//! grid of zero-padded dense tiles. All FLOPs go through the
//! [`ComputeEngine`] trait ([`contract`]), so the algorithm layer is
//! independent of the execution substrate:
//!
//! | backend | module | availability |
//! |---------|--------|--------------|
//! | `native` | [`native`] — pure-Rust f32 | always (default build, offline) |
//! | `mixed`  | [`mixed`] — f32 compute, f64 master weights | always |
//! | `xla`    | [`xla_engine`] — PJRT + AOT HLO artifacts | `--features xla` |
//!
//! The artifact contract (block shapes, kernel signatures, padding rules)
//! lives in [`contract`]; both backends implement it and are validated by
//! the same integration suite (`rust/tests/xla_runtime.rs`).

pub mod contract;
pub mod mixed;
pub mod native;
pub mod trainer;
#[cfg(feature = "xla")]
pub mod xla_engine;

pub use contract::{
    pad_slab, pad_vec, ComputeEngine, Kernel, ARTIFACTS, BLOCK_D, BLOCK_N, BLOCK_U,
};
pub use mixed::MixedEngine;
pub use native::NativeEngine;
#[cfg(feature = "xla")]
pub use xla_engine::XlaEngine;

use anyhow::Result;
use std::path::Path;

/// Which backend the blocked trainer should run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust f32 backend (always available).
    Native,
    /// f32 compute with f64 master weights (always available).
    Mixed,
    /// PJRT + AOT artifacts (requires the `xla` cargo feature).
    Xla,
}

impl EngineKind {
    /// Every accepted engine name (canonical names + aliases), the source
    /// of truth for [`EngineKind::parse`] error listings.
    pub const NAMES: [&'static str; 5] = ["native", "block", "mixed", "xla", "pjrt"];

    const TABLE: [(&'static str, EngineKind); 5] = [
        ("native", EngineKind::Native),
        ("block", EngineKind::Native),
        ("mixed", EngineKind::Mixed),
        ("xla", EngineKind::Xla),
        ("pjrt", EngineKind::Xla),
    ];

    /// Parse an engine name, case-insensitively (`Native`, `XLA`, …).
    pub fn parse(s: &str) -> Option<EngineKind> {
        crate::util::parse_enum(s, &Self::TABLE)
    }

    /// [`EngineKind::parse`] with a CLI-grade error: the failure message
    /// lists every valid name instead of a bare "unknown engine".
    pub fn parse_or_err(s: &str) -> Result<EngineKind, String> {
        crate::util::parse_enum_or_err(
            s,
            "engine",
            "engines (case-insensitive)",
            &Self::NAMES,
            &Self::TABLE,
        )
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Native => "native",
            EngineKind::Mixed => "mixed",
            EngineKind::Xla => "xla",
        }
    }

    /// The backend this build executes by default: XLA when the feature is
    /// compiled in (it is the accelerated path), native otherwise.
    pub fn default_for_build() -> EngineKind {
        if cfg!(feature = "xla") {
            EngineKind::Xla
        } else {
            EngineKind::Native
        }
    }
}

/// Construct a compute engine. `artifacts_dir` is only read by the XLA
/// backend (the native engine needs no artifacts).
pub fn build_engine(kind: EngineKind, artifacts_dir: &Path) -> Result<Box<dyn ComputeEngine>> {
    match kind {
        EngineKind::Native => Ok(Box::new(NativeEngine::new())),
        EngineKind::Mixed => Ok(Box::new(MixedEngine::new())),
        #[cfg(feature = "xla")]
        EngineKind::Xla => Ok(Box::new(XlaEngine::load(artifacts_dir)?)),
        #[cfg(not(feature = "xla"))]
        EngineKind::Xla => {
            let _ = artifacts_dir;
            anyhow::bail!(
                "this binary was built without the `xla` feature; rebuild with \
                 `cargo build --features xla` (and provide the PJRT toolchain) \
                 or use `--engine native`"
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parses_cli_names() {
        assert_eq!(EngineKind::parse("native"), Some(EngineKind::Native));
        assert_eq!(EngineKind::parse("block"), Some(EngineKind::Native));
        assert_eq!(EngineKind::parse("mixed"), Some(EngineKind::Mixed));
        assert_eq!(EngineKind::parse("xla"), Some(EngineKind::Xla));
        assert_eq!(EngineKind::parse("gpu"), None);
    }

    #[test]
    fn mixed_engine_always_builds() {
        let e = build_engine(EngineKind::Mixed, Path::new("unused")).unwrap();
        assert_eq!(e.name(), "mixed");
        assert!(e.master_weights());
    }

    #[test]
    fn engine_kind_parse_is_case_insensitive() {
        assert_eq!(EngineKind::parse("Native"), Some(EngineKind::Native));
        assert_eq!(EngineKind::parse("XLA"), Some(EngineKind::Xla));
        assert_eq!(EngineKind::parse(" Block "), Some(EngineKind::Native));
    }

    #[test]
    fn engine_kind_parse_error_lists_valid_names() {
        let err = EngineKind::parse_or_err("gpu").unwrap_err();
        for name in EngineKind::NAMES {
            assert!(err.contains(name), "error must list {name:?}: {err}");
            assert!(EngineKind::parse(name).is_some(), "{name:?} must actually parse");
        }
        assert_eq!(EngineKind::parse_or_err("PJRT"), Ok(EngineKind::Xla));
    }

    #[test]
    fn native_engine_always_builds() {
        let e = build_engine(EngineKind::Native, Path::new("unused")).unwrap();
        assert_eq!(e.name(), "native");
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn xla_engine_unavailable_without_feature() {
        let err = build_engine(EngineKind::Xla, Path::new("artifacts")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("--features xla"), "{msg}");
    }
}
