//! Engine-agnostic artifact contract of the blocked dense trainer.
//!
//! The trainer ([`super::trainer`]) executes FD-SVRG on an AOT-fixed grid
//! of zero-padded dense tiles; *which substrate* evaluates each kernel is
//! behind [`ComputeEngine`]. Two implementations exist:
//!
//! * [`super::native`] — pure-Rust f32 (the default; fully offline);
//! * [`super::xla_engine`] — PJRT executables compiled from the HLO-text
//!   artifacts `python/compile/aot.py` emits (`--features xla`).
//!
//! ## Artifact contract (shapes are AOT-fixed; rust pads)
//!
//! | artifact | signature | role |
//! |----------|-----------|------|
//! | `partial_products` | `(w[DL], D[DL,NB]) → s[NB]`  | `D^(l)ᵀ w^(l)` (Alg. 1 line 3) |
//! | `logistic_coef`    | `(s[NB], y[NB]) → c[NB]`     | `φ'(s_i, y_i)` (logistic) |
//! | `hinge_coef`       | `(s[NB], y[NB], γ[1]) → c[NB]` | `φ'(s_i, y_i)` (smoothed hinge) |
//! | `coef_matvec`      | `(D[DL,NB], c[NB]) → z[DL]`  | `D^(l) c` (full gradient, line 5) |
//! | `batch_dots`       | `(w[DL], D[DL,NB], idx[U]) → p[U]` | inner-batch partial products (line 9) |
//! | `batch_update`     | `(w[DL], z[DL], D[DL,NB], idx[U], m[U], y[U], c0[U], η, λ) → w'[DL]` | fused inner-batch update (line 11) |
//!
//! `DL`=[`BLOCK_D`], `NB`=[`BLOCK_N`], `U`=[`BLOCK_U`]; all tensors f32
//! except `idx` (i32). Tiles are column-major: instance `j` of a tile
//! occupies `tile[j·BLOCK_D .. (j+1)·BLOCK_D]`. Padding is provably inert:
//! padded instances are all-zero columns with `y = 0` (for which both loss
//! derivatives vanish), and padded feature rows never mix into real ones.

use anyhow::Result;

/// Feature-block length every worker slab is padded to.
pub const BLOCK_D: usize = 256;
/// Instance-block length the dense engine pads N to.
pub const BLOCK_N: usize = 512;
/// Inner mini-batch size of the fused update artifact.
pub const BLOCK_U: usize = 16;

/// One kernel of the AOT artifact set: its name (also the `<name>.hlo.txt`
/// file stem `aot.py` emits) and its shape signature, for diagnostics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Kernel {
    pub name: &'static str,
    pub signature: &'static str,
}

/// All kernels the contract comprises (and `aot.py` emits).
pub const ARTIFACTS: [Kernel; 6] = [
    Kernel { name: "partial_products", signature: "(w[DL], D[DL,NB]) -> s[NB]" },
    Kernel { name: "logistic_coef", signature: "(s[NB], y[NB]) -> c[NB]" },
    Kernel { name: "hinge_coef", signature: "(s[NB], y[NB], gamma[1]) -> c[NB]" },
    Kernel { name: "coef_matvec", signature: "(D[DL,NB], c[NB]) -> z[DL]" },
    Kernel { name: "batch_dots", signature: "(w[DL], D[DL,NB], idx[U]) -> p[U]" },
    Kernel {
        name: "batch_update",
        signature: "(w[DL], z[DL], D[DL,NB], idx[U], m[U], y[U], c0[U], eta, lambda) -> w'[DL]",
    },
];

/// The six typed kernel entry points of the blocked trainer. Every
/// implementation must honour the padded-block shapes above and keep
/// padding inert (zero contributions from padded rows/instances).
pub trait ComputeEngine {
    /// Short backend identifier (`"native"`, `"xla"`), used in run labels.
    fn name(&self) -> &'static str;

    /// Whether the trainer should keep f64 master copies of the parameter
    /// slabs and fold each kernel's f32 update into them as a delta
    /// (`w64 += new32 − old32`, then `w32 = w64 as f32`). The kernels
    /// themselves stay all-f32 — this only changes where the *state*
    /// accumulates, so rounding errors stop compounding across epochs.
    /// Default `false`: the f32 slabs are the state (pure-f32 engines).
    fn master_weights(&self) -> bool {
        false
    }

    /// `s = Dᵀ w` over one padded block.
    fn partial_products(&self, w: &[f32], d_block: &[f32]) -> Result<Vec<f32>>;

    /// `c_i = φ'(s_i, y_i)` (logistic).
    fn logistic_coef(&self, s: &[f32], y: &[f32]) -> Result<Vec<f32>>;

    /// `c_i = φ'(s_i, y_i)` (smoothed hinge, linear SVM).
    fn hinge_coef(&self, s: &[f32], y: &[f32], gamma: f32) -> Result<Vec<f32>>;

    /// `z = D c` over one padded block.
    fn coef_matvec(&self, d_block: &[f32], c: &[f32]) -> Result<Vec<f32>>;

    /// Partial inner products for one sampled mini-batch.
    fn batch_dots(&self, w: &[f32], d_block: &[f32], idx: &[i32]) -> Result<Vec<f32>>;

    /// Fused inner-batch SVRG update (Alg. 1 line 11, scanned over the
    /// batch): for each k, `w ← (1−ηλ)w − ηz − η(φ'(m_k, y_k) − c0_k)·x_k`.
    #[allow(clippy::too_many_arguments)]
    fn batch_update(
        &self,
        w: &[f32],
        z: &[f32],
        d_block: &[f32],
        idx: &[i32],
        margins: &[f32],
        y: &[f32],
        c0: &[f32],
        eta: f32,
        lambda: f32,
    ) -> Result<Vec<f32>>;
}

/// Pad a dense column-major slab `(dl × n)` to `(BLOCK_D × BLOCK_N)`.
pub fn pad_slab(slab: &[f32], dl: usize, n: usize) -> Vec<f32> {
    assert!(dl <= BLOCK_D && n <= BLOCK_N, "slab {dl}x{n} exceeds block");
    assert_eq!(slab.len(), dl * n);
    let mut out = vec![0f32; BLOCK_D * BLOCK_N];
    for c in 0..n {
        out[c * BLOCK_D..c * BLOCK_D + dl].copy_from_slice(&slab[c * dl..(c + 1) * dl]);
    }
    out
}

/// Pad a vector with zeros to `len`.
pub fn pad_vec(v: &[f32], len: usize) -> Vec<f32> {
    assert!(v.len() <= len);
    let mut out = vec![0f32; len];
    out[..v.len()].copy_from_slice(v);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_slab_layout() {
        // 2x2 slab [[1,3],[2,4]] col-major = [1,2,3,4]
        let padded = pad_slab(&[1.0, 2.0, 3.0, 4.0], 2, 2);
        assert_eq!(padded.len(), BLOCK_D * BLOCK_N);
        assert_eq!(padded[0], 1.0);
        assert_eq!(padded[1], 2.0);
        assert_eq!(padded[BLOCK_D], 3.0);
        assert_eq!(padded[BLOCK_D + 1], 4.0);
        assert_eq!(padded[2], 0.0);
    }

    #[test]
    fn pad_vec_zero_fills() {
        let v = pad_vec(&[1.0, 2.0], 5);
        assert_eq!(v, vec![1.0, 2.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn pad_slab_rejects_oversize() {
        pad_slab(&vec![0f32; (BLOCK_D + 1) * 2], BLOCK_D + 1, 2);
    }

    #[test]
    fn artifact_names_are_unique() {
        for (i, a) in ARTIFACTS.iter().enumerate() {
            for b in &ARTIFACTS[i + 1..] {
                assert_ne!(a.name, b.name);
            }
        }
        assert_eq!(ARTIFACTS.len(), 6);
    }
}
