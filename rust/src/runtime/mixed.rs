//! Mixed-precision backend (`--engine mixed`): f32 compute, f64 state.
//!
//! Every kernel delegates to [`NativeEngine`] unchanged — same tiles, same
//! f32 arithmetic, same padded-block contract — but
//! [`ComputeEngine::master_weights`] returns `true`, which tells the
//! blocked trainer to keep f64 master copies of the parameter slabs and
//! fold each batch update into them as a delta:
//!
//! ```text
//! w64[j] += new32[j] − old32[j];   w32[j] = w64[j] as f32
//! ```
//!
//! The FLOP-heavy work (dots, scatters, the fused update) stays in f32 and
//! runs at f32 speed/bandwidth; only the O(d) state fold is f64. What that
//! buys: a pure-f32 state loses low-order update bits every time
//! `|Δw| ≪ |w|` (the common case late in training, when steps shrink), and
//! those losses compound over the `M·outer` inner steps. The f64 master
//! absorbs each delta exactly, so the only rounding left is the final
//! `as f32` cast the *next* kernel input sees — errors stop accumulating.
//! The cost model is unchanged (same counted traffic as `native`); the
//! accuracy-vs-speed tradeoff is measured per-kernel in `bench_kernels`
//! and end-to-end in `tests/kernel_exactness.rs`.

use super::contract::ComputeEngine;
use super::native::NativeEngine;
use anyhow::Result;

/// f32-compute / f64-state engine. Stateless; construction never fails.
#[derive(Clone, Copy, Debug, Default)]
pub struct MixedEngine {
    inner: NativeEngine,
}

impl MixedEngine {
    pub fn new() -> MixedEngine {
        MixedEngine { inner: NativeEngine::new() }
    }
}

impl ComputeEngine for MixedEngine {
    fn name(&self) -> &'static str {
        "mixed"
    }

    fn master_weights(&self) -> bool {
        true
    }

    fn partial_products(&self, w: &[f32], d_block: &[f32]) -> Result<Vec<f32>> {
        self.inner.partial_products(w, d_block)
    }

    fn logistic_coef(&self, s: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        self.inner.logistic_coef(s, y)
    }

    fn hinge_coef(&self, s: &[f32], y: &[f32], gamma: f32) -> Result<Vec<f32>> {
        self.inner.hinge_coef(s, y, gamma)
    }

    fn coef_matvec(&self, d_block: &[f32], c: &[f32]) -> Result<Vec<f32>> {
        self.inner.coef_matvec(d_block, c)
    }

    fn batch_dots(&self, w: &[f32], d_block: &[f32], idx: &[i32]) -> Result<Vec<f32>> {
        self.inner.batch_dots(w, d_block, idx)
    }

    fn batch_update(
        &self,
        w: &[f32],
        z: &[f32],
        d_block: &[f32],
        idx: &[i32],
        margins: &[f32],
        y: &[f32],
        c0: &[f32],
        eta: f32,
        lambda: f32,
    ) -> Result<Vec<f32>> {
        self.inner.batch_update(w, z, d_block, idx, margins, y, c0, eta, lambda)
    }
}

#[cfg(test)]
mod tests {
    use super::super::contract::{BLOCK_D, BLOCK_N, BLOCK_U};
    use super::super::trainer;
    use super::*;
    use crate::algs::{Problem, RunParams};
    use crate::data::{generate, GenSpec};
    use crate::net::SimParams;

    #[test]
    fn kernels_delegate_to_native_bitwise() {
        let native = NativeEngine::new();
        let mixed = MixedEngine::new();
        assert_eq!(mixed.name(), "mixed");
        assert!(mixed.master_weights() && !native.master_weights());
        let mut rng = crate::util::Pcg64::seed_from_u64(31);
        let w: Vec<f32> = (0..BLOCK_D).map(|_| rng.normal() as f32).collect();
        let tile: Vec<f32> = (0..BLOCK_D * BLOCK_N)
            .map(|_| if rng.next_f64() < 0.05 { rng.normal() as f32 } else { 0.0 })
            .collect();
        assert_eq!(
            native.partial_products(&w, &tile).unwrap(),
            mixed.partial_products(&w, &tile).unwrap(),
        );
        let idx: Vec<i32> = (0..BLOCK_U).map(|_| rng.below(BLOCK_N) as i32).collect();
        assert_eq!(
            native.batch_dots(&w, &tile, &idx).unwrap(),
            mixed.batch_dots(&w, &tile, &idx).unwrap(),
        );
    }

    #[test]
    fn mixed_run_tracks_native_and_converges() {
        let ds = generate(&GenSpec::new("mx", 120, 400, 10).with_seed(5));
        let p = Problem::logistic_l2(ds, 1e-2);
        let params = RunParams { outer: 4, sim: SimParams::free(), ..Default::default() };
        let rn = trainer::run(&p, &params, &NativeEngine::new()).unwrap();
        let rm = trainer::run(&p, &params, &MixedEngine::new()).unwrap();
        // identical schedule and cost model — only the state precision moves
        assert_eq!(rn.total_scalars, rm.total_scalars);
        assert_eq!(rn.total_bytes, rm.total_bytes);
        // the f64 masters can only keep the trajectory at f32-rounding
        // distance from the pure-f32 run over 4 epochs
        let rel = crate::linalg::dist2(&rn.w, &rm.w)
            / (1.0 + crate::linalg::nrm2(&rn.w).powi(2));
        assert!(rel < 1e-3, "mixed vs native relative dist2 {rel:.3e}");
        let f0 = p.objective(&vec![0.0; p.d()]);
        assert!(rm.final_objective() < f0 - 1e-2, "mixed engine failed to train");
    }
}
