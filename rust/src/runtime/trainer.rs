//! Blocked dense FD-SVRG: the full Algorithm-1 loop executed through a
//! [`ComputeEngine`] backend (`--engine block|xla` on the CLI).
//!
//! Every FLOP of the training loop — partial products, logistic
//! coefficients, gradient scatter, the fused inner-batch update — runs
//! inside the engine's kernels (pure-Rust f32 by default, PJRT/Pallas
//! executables under `--features xla`); rust only orchestrates buffers
//! and does the (free) scalar reductions a real multi-node deployment
//! would tree-allreduce.
//!
//! ## Blocking
//!
//! The kernel contract is shape-monomorphic (PJRT executables are AOT
//! compiled), so the data is laid out on a fixed grid: features in
//! `⌈d / BLOCK_D⌉` slabs (the "workers" of the paper's Fig. 4), instances
//! in `⌈N / BLOCK_N⌉` column blocks, inner mini-batches of exactly
//! `BLOCK_U` (the §4.4.1 variant with `u = 16`). Everything is
//! zero-padded to block shape; padding is provably inert (`coef` is
//! zeroed on padded instances, padded feature rows never mix into real
//! ones).
//!
//! ## Accounting
//!
//! A single process executes all slabs, so the *communication counters*
//! are computed from the paper's closed form (§4.5: `2q` scalars per
//! tree-allreduced scalar, `q` = slab count) rather than measured off a
//! socket — the numbers a q-worker deployment of this engine would move.
//! `sim_time` is the measured wall time of the engine loop.

use super::{ComputeEngine, BLOCK_D, BLOCK_N, BLOCK_U};
use crate::algs::{Problem, RunParams};
use crate::loss::Regularizer;
use crate::metrics::{RunResult, Trace, TracePoint};
use crate::util::time::Stopwatch;
use crate::util::Pcg64;
use anyhow::{ensure, Context, Result};

/// Blocked dense mirror of one dataset: `blocks[l][b]` is the
/// `(BLOCK_D × BLOCK_N)` zero-padded dense tile of feature slab `l`,
/// instance block `b`.
pub struct BlockedData {
    pub d: usize,
    pub n: usize,
    pub n_slabs: usize,
    pub n_blocks: usize,
    pub blocks: Vec<Vec<Vec<f32>>>,
    /// Per-block padded labels (`BLOCK_N`, zeros on padding).
    pub y_blocks: Vec<Vec<f32>>,
}

impl BlockedData {
    /// Densify + block a (small) sparse dataset. Memory is
    /// `n_slabs · n_blocks · BLOCK_D · BLOCK_N · 4` bytes — callers guard
    /// against paper-scale `d`; this path is for dense/AOT workloads.
    pub fn build(problem: &Problem) -> Result<BlockedData> {
        let d = problem.d();
        let n = problem.n();
        let n_slabs = d.div_ceil(BLOCK_D);
        let n_blocks = n.div_ceil(BLOCK_N);
        let bytes = n_slabs * n_blocks * BLOCK_D * BLOCK_N * 4;
        ensure!(
            bytes <= 2 << 30,
            "blocked dense engine would need {bytes} bytes; use the sparse CSC path"
        );
        let mut blocks = Vec::with_capacity(n_slabs);
        for l in 0..n_slabs {
            let row_lo = l * BLOCK_D;
            let row_hi = (row_lo + BLOCK_D).min(d);
            let dl = row_hi - row_lo;
            let slab = problem.ds.x.dense_slab_f32(row_lo, row_hi); // col-major dl × n
            let mut col_blocks = Vec::with_capacity(n_blocks);
            for b in 0..n_blocks {
                let col_lo = b * BLOCK_N;
                let col_hi = (col_lo + BLOCK_N).min(n);
                let mut tile = vec![0f32; BLOCK_D * BLOCK_N];
                for (j, c) in (col_lo..col_hi).enumerate() {
                    tile[j * BLOCK_D..j * BLOCK_D + dl]
                        .copy_from_slice(&slab[c * dl..c * dl + dl]);
                }
                col_blocks.push(tile);
            }
            blocks.push(col_blocks);
        }
        let mut y_blocks = Vec::with_capacity(n_blocks);
        for b in 0..n_blocks {
            let col_lo = b * BLOCK_N;
            let col_hi = (col_lo + BLOCK_N).min(n);
            let mut yb = vec![0f32; BLOCK_N];
            for (j, c) in (col_lo..col_hi).enumerate() {
                yb[j] = problem.ds.y[c] as f32;
            }
            y_blocks.push(yb);
        }
        Ok(BlockedData { d, n, n_slabs, n_blocks, blocks, y_blocks })
    }
}

/// Run FD-SVRG through a blocked compute engine. Mini-batch size is
/// pinned to the contract's `BLOCK_U`; `params.batch` is ignored.
pub fn run(problem: &Problem, params: &RunParams, engine: &dyn ComputeEngine) -> Result<RunResult> {
    let lambda = match problem.reg {
        Regularizer::L2 { lambda } => lambda as f32,
        _ => anyhow::bail!("the blocked engine supports L2 regularization only"),
    };
    ensure!(
        problem.loss == crate::loss::LossKind::Logistic,
        "the blocked engine kernels implement the logistic loss"
    );
    let data = BlockedData::build(problem).context("blocking dataset for the dense engine")?;
    let (d, n) = (data.d, data.n);
    let q = data.n_slabs; // the "workers" of the accounting
    let eta = params.effective_eta(problem) as f32;
    let m_inner = if params.m_inner == 0 { n } else { params.m_inner };
    let wall = Stopwatch::start();

    // parameter + full-gradient slabs, padded to BLOCK_D
    let mut w: Vec<Vec<f32>> = vec![vec![0f32; BLOCK_D]; q];
    let mut z: Vec<Vec<f32>> = vec![vec![0f32; BLOCK_D]; q];

    let mut trace = Trace::default();
    let mut grads = 0u64;
    let mut scalars = 0u64;
    // closed-form wire accounting: the modeled payloads (margins, batch
    // dots) are dense, so bytes = scalars × the codec's dense rate, and
    // every modeled tree allreduce moves 2q messages
    let bytes_per_scalar = params.wire.dense_bytes_per_scalar();
    let mut messages = 0u64;
    let assemble = |w: &[Vec<f32>]| -> Vec<f64> {
        let mut out = vec![0f64; d];
        for (l, wl) in w.iter().enumerate() {
            let lo = l * BLOCK_D;
            let hi = (lo + BLOCK_D).min(d);
            for (j, o) in out[lo..hi].iter_mut().enumerate() {
                *o = wl[j] as f64;
            }
        }
        out
    };
    trace.push(TracePoint {
        outer: 0,
        sim_time: 0.0,
        wall_time: 0.0,
        scalars: 0,
        bytes: 0,
        grads: 0,
        objective: problem.objective(&assemble(&w)),
    });

    let mut rng = Pcg64::seed_from_u64(params.seed);
    let mut margins = vec![0f32; data.n_blocks * BLOCK_N];
    let mut c0 = vec![0f32; data.n_blocks * BLOCK_N];

    for t in 0..params.outer {
        // ---- full-gradient phase (Alg. 1 lines 3–5) ----
        margins.iter_mut().for_each(|v| *v = 0.0);
        for (l, wl) in w.iter().enumerate() {
            for b in 0..data.n_blocks {
                let s = engine.partial_products(wl, &data.blocks[l][b])?;
                for (j, sv) in s.iter().enumerate() {
                    margins[b * BLOCK_N + j] += sv;
                }
            }
        }
        scalars += 2 * q as u64 * n as u64; // one tree allreduce of N scalars
        messages += 2 * q as u64;
        let inv_n = 1.0 / n as f32;
        for zl in z.iter_mut() {
            zl.iter_mut().for_each(|v| *v = 0.0);
        }
        for b in 0..data.n_blocks {
            let mb = &margins[b * BLOCK_N..(b + 1) * BLOCK_N];
            let coef = engine.logistic_coef(mb, &data.y_blocks[b])?;
            let lo = b * BLOCK_N;
            let valid = (n - lo).min(BLOCK_N);
            let c_scaled: Vec<f32> = coef
                .iter()
                .enumerate()
                .map(|(j, &v)| if j < valid { v * inv_n } else { 0.0 })
                .collect();
            c0[lo..lo + BLOCK_N].copy_from_slice(&coef);
            for (l, zl) in z.iter_mut().enumerate() {
                let zb = engine.coef_matvec(&data.blocks[l][b], &c_scaled)?;
                for (zv, nv) in zl.iter_mut().zip(zb.iter()) {
                    *zv += nv;
                }
            }
        }
        grads += n as u64;

        // ---- inner loop (lines 7–12), batches of BLOCK_U ----
        let mut m = 0usize;
        while m < m_inner {
            // uniform over instances: block ∝ size, then uniform within
            let gi = rng.below(n);
            let b = gi / BLOCK_N;
            let valid = (n - b * BLOCK_N).min(BLOCK_N);
            let idx: Vec<i32> = (0..BLOCK_U).map(|_| rng.below(valid) as i32).collect();

            // batch partial products, summed across slabs ("tree allreduce")
            let mut dots = vec![0f32; BLOCK_U];
            for (l, wl) in w.iter().enumerate() {
                let part = engine.batch_dots(wl, &data.blocks[l][b], &idx)?;
                for (dv, pv) in dots.iter_mut().zip(part.iter()) {
                    *dv += pv;
                }
            }
            scalars += 2 * q as u64 * BLOCK_U as u64;
            messages += 2 * q as u64;

            let yb: Vec<f32> =
                idx.iter().map(|&i| data.y_blocks[b][i as usize]).collect();
            let c0b: Vec<f32> =
                idx.iter().map(|&i| c0[b * BLOCK_N + i as usize]).collect();
            for (l, wl) in w.iter_mut().enumerate() {
                *wl = engine.batch_update(
                    wl,
                    &z[l],
                    &data.blocks[l][b],
                    &idx,
                    &dots,
                    &yb,
                    &c0b,
                    eta,
                    lambda,
                )?;
            }
            grads += BLOCK_U as u64;
            m += BLOCK_U;
        }

        let objective = problem.objective(&assemble(&w));
        trace.push(TracePoint {
            outer: t + 1,
            sim_time: wall.seconds(),
            wall_time: wall.seconds(),
            scalars,
            bytes: bytes_per_scalar * scalars,
            grads,
            objective,
        });
        if let Some((f_opt, target)) = params.gap_stop {
            if objective - f_opt <= target {
                break;
            }
        }
    }

    let w_final = assemble(&w);
    let total_sim_time = trace.points.last().map(|p| p.sim_time).unwrap_or(0.0);
    Ok(RunResult {
        algorithm: format!("fdsvrg-{}", engine.name()),
        dataset: problem.ds.name.clone(),
        w: w_final,
        trace,
        total_sim_time,
        total_wall_time: wall.seconds(),
        total_scalars: scalars,
        busiest_node_scalars: scalars / q.max(1) as u64,
        total_bytes: bytes_per_scalar * scalars,
        busiest_node_bytes: bytes_per_scalar * (scalars / q.max(1) as u64),
        total_messages: messages,
        node_comm: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, GenSpec};

    #[test]
    fn blocked_data_pads_and_covers() {
        let ds = generate(&GenSpec::new("blk", 300, 600, 20).with_seed(8));
        let p = Problem::logistic_l2(ds, 1e-3);
        let b = BlockedData::build(&p).unwrap();
        assert_eq!(b.n_slabs, 2); // 300 → 2×256
        assert_eq!(b.n_blocks, 2); // 600 → 2×512
        assert_eq!(b.blocks.len(), 2);
        assert_eq!(b.blocks[0].len(), 2);
        // nnz preserved: sum of |tile| entries equals the dense sum
        let tile_sum: f32 = b
            .blocks
            .iter()
            .flatten()
            .flat_map(|t| t.iter())
            .map(|v| v.abs())
            .sum();
        let direct: f64 = (0..p.n())
            .map(|i| p.ds.x.col_iter(i).map(|(_, v)| v.abs()).sum::<f64>())
            .sum();
        // f32 tile entries + f32 accumulation: compare to relative tolerance
        assert!(
            ((tile_sum as f64 - direct) / direct).abs() < 1e-5,
            "{tile_sum} vs {direct}"
        );
        // labels preserved (last real instance), padding zero beyond it
        assert_eq!(b.y_blocks[1][599 - 512], p.ds.y[599] as f32);
        assert_eq!(b.y_blocks[1][600 - 512], 0.0);
    }

    #[test]
    fn blocked_data_rejects_huge_dense() {
        let ds = generate(&GenSpec::new("huge", 300_000, 6_000, 5).with_seed(9));
        let p = Problem::logistic_l2(ds, 1e-3);
        assert!(BlockedData::build(&p).is_err());
    }
}
