//! Blocked dense FD-SVRG: the full Algorithm-1 loop executed through a
//! [`ComputeEngine`] backend (`--engine block|mixed|xla` on the CLI).
//!
//! Every FLOP of the training loop — partial products, logistic
//! coefficients, gradient scatter, the fused inner-batch update — runs
//! inside the engine's kernels (pure-Rust f32 by default, PJRT/Pallas
//! executables under `--features xla`); rust only orchestrates buffers
//! and does the (free) scalar reductions a real multi-node deployment
//! would tree-allreduce.
//!
//! ## Blocking
//!
//! The kernel contract is shape-monomorphic (PJRT executables are AOT
//! compiled), so the data is laid out on a fixed grid: features in
//! `⌈d / BLOCK_D⌉` slabs (the "workers" of the paper's Fig. 4), instances
//! in `⌈N / BLOCK_N⌉` column blocks, inner mini-batches of exactly
//! `BLOCK_U` (the §4.4.1 variant with `u = 16`). Everything is
//! zero-padded to block shape; padding is provably inert (`coef` is
//! zeroed on padded instances, padded feature rows never mix into real
//! ones).
//!
//! ## Accounting
//!
//! A single process executes all slabs, so the *communication counters*
//! are computed from the paper's closed form (§4.5: `2q` scalars per
//! tree-allreduced scalar, `q` = slab count) rather than measured off a
//! socket — the numbers a q-worker deployment of this engine would move.
//! `sim_time` is the measured wall time of the engine loop.

use super::{ComputeEngine, BLOCK_D, BLOCK_N, BLOCK_U};
use crate::algs::{Problem, RunParams};
use crate::loss::Regularizer;
use crate::metrics::{CommTotals, RunResult};
use crate::session::{
    Driver, EpochReport, FinishOut, NodeState, ResumeState, SessionBuilder,
};
use crate::util::time::Stopwatch;
use crate::util::Pcg64;
use anyhow::{ensure, Context, Result};
use std::sync::Arc;

/// Blocked dense mirror of one dataset: `blocks[l][b]` is the
/// `(BLOCK_D × BLOCK_N)` zero-padded dense tile of feature slab `l`,
/// instance block `b`.
pub struct BlockedData {
    pub d: usize,
    pub n: usize,
    pub n_slabs: usize,
    pub n_blocks: usize,
    pub blocks: Vec<Vec<Vec<f32>>>,
    /// Per-block padded labels (`BLOCK_N`, zeros on padding).
    pub y_blocks: Vec<Vec<f32>>,
}

impl BlockedData {
    /// Densify + block a (small) sparse dataset. Memory is
    /// `n_slabs · n_blocks · BLOCK_D · BLOCK_N · 4` bytes — callers guard
    /// against paper-scale `d`; this path is for dense/AOT workloads.
    pub fn build(problem: &Problem) -> Result<BlockedData> {
        let d = problem.d();
        let n = problem.n();
        let n_slabs = d.div_ceil(BLOCK_D);
        let n_blocks = n.div_ceil(BLOCK_N);
        let bytes = n_slabs * n_blocks * BLOCK_D * BLOCK_N * 4;
        ensure!(
            bytes <= 2 << 30,
            "blocked dense engine would need {bytes} bytes; use the sparse CSC path"
        );
        let mut blocks = Vec::with_capacity(n_slabs);
        for l in 0..n_slabs {
            let row_lo = l * BLOCK_D;
            let row_hi = (row_lo + BLOCK_D).min(d);
            let dl = row_hi - row_lo;
            let slab = problem.ds.x.dense_slab_f32(row_lo, row_hi); // col-major dl × n
            let mut col_blocks = Vec::with_capacity(n_blocks);
            for b in 0..n_blocks {
                let col_lo = b * BLOCK_N;
                let col_hi = (col_lo + BLOCK_N).min(n);
                let mut tile = vec![0f32; BLOCK_D * BLOCK_N];
                for (j, c) in (col_lo..col_hi).enumerate() {
                    tile[j * BLOCK_D..j * BLOCK_D + dl]
                        .copy_from_slice(&slab[c * dl..c * dl + dl]);
                }
                col_blocks.push(tile);
            }
            blocks.push(col_blocks);
        }
        let mut y_blocks = Vec::with_capacity(n_blocks);
        for b in 0..n_blocks {
            let col_lo = b * BLOCK_N;
            let col_hi = (col_lo + BLOCK_N).min(n);
            let mut yb = vec![0f32; BLOCK_N];
            for (j, c) in (col_lo..col_hi).enumerate() {
                yb[j] = problem.ds.y[c] as f32;
            }
            y_blocks.push(yb);
        }
        Ok(BlockedData { d, n, n_slabs, n_blocks, blocks, y_blocks })
    }
}

/// Steppable blocked FD-SVRG: one outer iteration per [`Driver::step`],
/// every FLOP through the [`ComputeEngine`] kernels. Construct with
/// [`BlockedDriver::new`]; the [`run`] wrapper rides it through the shared
/// session runner.
pub struct BlockedDriver<'e> {
    name: String,
    problem: Problem,
    engine: &'e dyn ComputeEngine,
    data: BlockedData,
    eta: f32,
    lambda: f32,
    m_inner: usize,
    bytes_per_scalar: u64,
    /// parameter + full-gradient slabs, padded to BLOCK_D
    w: Vec<Vec<f32>>,
    /// f64 master copies of the parameter slabs — present iff the engine
    /// asks for them ([`ComputeEngine::master_weights`], `--engine mixed`).
    /// Kernels still consume `w` (f32); each batch update is folded into
    /// the masters as a delta and `w` re-derived by rounding, so state
    /// error stops compounding across inner steps.
    w64: Option<Vec<Vec<f64>>>,
    z: Vec<Vec<f32>>,
    margins: Vec<f32>,
    c0: Vec<f32>,
    /// per-batch scratch (inner loop runs allocation-free)
    idx: Vec<i32>,
    dots: Vec<f32>,
    yb: Vec<f32>,
    c0b: Vec<f32>,
    c_scaled: Vec<f32>,
    rng: Pcg64,
    epoch: usize,
    grads: u64,
    scalars: u64,
    messages: u64,
    wall: Stopwatch,
}

impl<'e> BlockedDriver<'e> {
    /// Mini-batch size is pinned to the contract's `BLOCK_U`;
    /// `params.batch` is ignored.
    pub fn new(
        problem: &Problem,
        params: &RunParams,
        engine: &'e dyn ComputeEngine,
        resume: Option<ResumeState>,
    ) -> Result<BlockedDriver<'e>> {
        let lambda = match problem.reg {
            Regularizer::L2 { lambda } => lambda as f32,
            _ => anyhow::bail!("the blocked engine supports L2 regularization only"),
        };
        ensure!(
            problem.loss == crate::loss::LossKind::Logistic,
            "the blocked engine kernels implement the logistic loss"
        );
        let data = BlockedData::build(problem).context("blocking dataset for the dense engine")?;
        let n = data.n;
        let q = data.n_slabs; // the "workers" of the accounting
        let eta = params.effective_eta(problem) as f32;
        let m_inner = if params.m_inner == 0 { n } else { params.m_inner };

        let mut driver = BlockedDriver {
            name: format!("fdsvrg-{}", engine.name()),
            problem: problem.clone(),
            engine,
            eta,
            lambda,
            m_inner,
            // closed-form wire accounting: the modeled payloads (margins,
            // batch dots) are dense, so bytes = scalars × the codec's
            // dense rate, and every modeled tree allreduce moves 2q
            // messages
            bytes_per_scalar: params.wire.dense_bytes_per_scalar(),
            w: vec![vec![0f32; BLOCK_D]; q],
            w64: engine.master_weights().then(|| vec![vec![0f64; BLOCK_D]; q]),
            z: vec![vec![0f32; BLOCK_D]; q],
            margins: vec![0f32; data.n_blocks * BLOCK_N],
            c0: vec![0f32; data.n_blocks * BLOCK_N],
            idx: Vec::with_capacity(BLOCK_U),
            dots: Vec::with_capacity(BLOCK_U),
            yb: Vec::with_capacity(BLOCK_U),
            c0b: Vec::with_capacity(BLOCK_U),
            c_scaled: Vec::with_capacity(BLOCK_N),
            rng: Pcg64::seed_from_u64(params.seed),
            epoch: 0,
            grads: 0,
            scalars: 0,
            messages: 0,
            wall: Stopwatch::start(),
            data,
        };
        if let Some(r) = resume {
            if !r.is_fresh() {
                ensure!(r.nodes.len() == 1, "blocked checkpoint carries exactly one node");
                ensure!(r.w.len() == driver.data.d, "checkpoint dim mismatch");
                let node = &r.nodes[0];
                ensure!(node.extra.len() == 2, "blocked node extra = [scalars, messages]");
                // f32 → f64 is exact, so the f64 checkpoint restores the
                // f32 slabs bit-for-bit; with master weights the checkpoint
                // *is* the f64 state, restored verbatim
                for (l, wl) in driver.w.iter_mut().enumerate() {
                    let lo = l * BLOCK_D;
                    let hi = (lo + BLOCK_D).min(driver.data.d);
                    for (j, src) in r.w[lo..hi].iter().enumerate() {
                        wl[j] = *src as f32;
                        if let Some(masters) = driver.w64.as_mut() {
                            masters[l][j] = *src;
                        }
                    }
                }
                driver.rng = Pcg64::from_state_words(
                    node.rng.ok_or_else(|| anyhow::anyhow!("missing RNG state"))?,
                );
                driver.epoch = r.epoch;
                driver.grads = r.grads;
                driver.scalars = node.extra[0].to_bits();
                driver.messages = node.extra[1].to_bits();
            }
        }
        Ok(driver)
    }

    fn assemble(&self) -> Vec<f64> {
        let d = self.data.d;
        let mut out = vec![0f64; d];
        for l in 0..self.w.len() {
            let lo = l * BLOCK_D;
            let hi = (lo + BLOCK_D).min(d);
            for (j, o) in out[lo..hi].iter_mut().enumerate() {
                // reports and checkpoints carry the most precise state we
                // hold: the f64 masters when present, else the f32 slabs
                *o = match &self.w64 {
                    Some(masters) => masters[l][j],
                    None => self.w[l][j] as f64,
                };
            }
        }
        out
    }

    fn node_state(&self) -> NodeState {
        NodeState {
            rng: Some(self.rng.state_words()),
            jitter: None,
            clock: Default::default(),
            extra: vec![f64::from_bits(self.scalars), f64::from_bits(self.messages)],
        }
    }

    /// One outer iteration (full-gradient phase + inner loop in batches of
    /// `BLOCK_U`). Engine kernels are assumed healthy mid-run; a kernel
    /// failure here is a broken backend and panics with context.
    fn epoch_body(&mut self) -> Result<()> {
        let n = self.data.n;
        let q = self.data.n_slabs;

        // ---- full-gradient phase (Alg. 1 lines 3–5) ----
        self.margins.iter_mut().for_each(|v| *v = 0.0);
        for (l, wl) in self.w.iter().enumerate() {
            for b in 0..self.data.n_blocks {
                let s = self.engine.partial_products(wl, &self.data.blocks[l][b])?;
                for (j, sv) in s.iter().enumerate() {
                    self.margins[b * BLOCK_N + j] += sv;
                }
            }
        }
        self.scalars += 2 * q as u64 * n as u64; // one tree allreduce of N scalars
        self.messages += 2 * q as u64;
        let inv_n = 1.0 / n as f32;
        for zl in self.z.iter_mut() {
            zl.iter_mut().for_each(|v| *v = 0.0);
        }
        for b in 0..self.data.n_blocks {
            let mb = &self.margins[b * BLOCK_N..(b + 1) * BLOCK_N];
            let coef = self.engine.logistic_coef(mb, &self.data.y_blocks[b])?;
            let lo = b * BLOCK_N;
            let valid = (n - lo).min(BLOCK_N);
            self.c_scaled.clear();
            for (j, &v) in coef.iter().enumerate() {
                self.c_scaled.push(if j < valid { v * inv_n } else { 0.0 });
            }
            self.c0[lo..lo + BLOCK_N].copy_from_slice(&coef);
            for (l, zl) in self.z.iter_mut().enumerate() {
                let zb = self.engine.coef_matvec(&self.data.blocks[l][b], &self.c_scaled)?;
                for (zv, nv) in zl.iter_mut().zip(zb.iter()) {
                    *zv += nv;
                }
            }
        }
        self.grads += n as u64;

        // ---- inner loop (lines 7–12), batches of BLOCK_U ----
        let mut m = 0usize;
        while m < self.m_inner {
            // uniform over instances: block ∝ size, then uniform within
            let gi = self.rng.below(n);
            let b = gi / BLOCK_N;
            let valid = (n - b * BLOCK_N).min(BLOCK_N);
            self.idx.clear();
            self.idx.extend((0..BLOCK_U).map(|_| self.rng.below(valid) as i32));

            // batch partial products, summed across slabs ("tree allreduce")
            self.dots.clear();
            self.dots.resize(BLOCK_U, 0.0);
            for (l, wl) in self.w.iter().enumerate() {
                let part = self.engine.batch_dots(wl, &self.data.blocks[l][b], &self.idx)?;
                for (dv, pv) in self.dots.iter_mut().zip(part.iter()) {
                    *dv += pv;
                }
            }
            self.scalars += 2 * q as u64 * BLOCK_U as u64;
            self.messages += 2 * q as u64;

            self.yb.clear();
            self.yb.extend(self.idx.iter().map(|&i| self.data.y_blocks[b][i as usize]));
            self.c0b.clear();
            self.c0b.extend(self.idx.iter().map(|&i| self.c0[b * BLOCK_N + i as usize]));
            for (l, wl) in self.w.iter_mut().enumerate() {
                let new = self.engine.batch_update(
                    wl,
                    &self.z[l],
                    &self.data.blocks[l][b],
                    &self.idx,
                    &self.dots,
                    &self.yb,
                    &self.c0b,
                    self.eta,
                    self.lambda,
                )?;
                match self.w64.as_mut() {
                    // mixed precision: fold the f32 update into the f64
                    // master as an exact delta, then round the master back
                    // down for the next kernel input
                    Some(masters) => {
                        let ml = &mut masters[l];
                        for (j, (mv, wv)) in ml.iter_mut().zip(wl.iter_mut()).enumerate() {
                            *mv += new[j] as f64 - *wv as f64;
                            *wv = *mv as f32;
                        }
                    }
                    None => *wl = new,
                }
            }
            self.grads += BLOCK_U as u64;
            m += BLOCK_U;
        }
        Ok(())
    }
}

impl Driver for BlockedDriver<'_> {
    fn name(&self) -> &str {
        &self.name
    }

    fn dataset(&self) -> &str {
        &self.problem.ds.name
    }

    fn step(&mut self) -> EpochReport {
        self.epoch_body().expect("compute engine failed mid-run");
        self.epoch += 1;
        EpochReport {
            epoch: self.epoch,
            w: Arc::new(self.assemble()),
            grads: self.grads,
            sim_time: self.wall.seconds(),
            scalars: self.scalars,
            bytes: self.bytes_per_scalar * self.scalars,
            comm: Vec::new(),
            nodes: vec![self.node_state()],
        }
    }

    fn state(&self) -> ResumeState {
        ResumeState {
            epoch: self.epoch,
            grads: self.grads,
            w: Arc::new(self.assemble()),
            comm: Vec::new(),
            nodes: vec![self.node_state()],
        }
    }

    fn finish(self: Box<Self>) -> FinishOut {
        let q = self.data.n_slabs.max(1) as u64;
        let totals = CommTotals {
            total_scalars: self.scalars,
            busiest_node_scalars: self.scalars / q,
            total_bytes: self.bytes_per_scalar * self.scalars,
            busiest_node_bytes: self.bytes_per_scalar * (self.scalars / q),
            total_messages: self.messages,
            total_socket_bytes: 0,
            node_comm: Vec::new(),
        };
        FinishOut { w: self.assemble(), totals }
    }
}

/// Run FD-SVRG through a blocked compute engine — a thin wrapper riding
/// [`BlockedDriver`] through the shared session runner (stop policies
/// derived from `params`). Mini-batch size is pinned to the contract's
/// `BLOCK_U`; `params.batch` is ignored.
pub fn run(problem: &Problem, params: &RunParams, engine: &dyn ComputeEngine) -> Result<RunResult> {
    let driver = BlockedDriver::new(problem, params, engine, None)?;
    Ok(SessionBuilder::from_driver(Box::new(driver), problem, params.clone())
        .build()?
        .run_to_completion())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, GenSpec};

    #[test]
    fn blocked_data_pads_and_covers() {
        let ds = generate(&GenSpec::new("blk", 300, 600, 20).with_seed(8));
        let p = Problem::logistic_l2(ds, 1e-3);
        let b = BlockedData::build(&p).unwrap();
        assert_eq!(b.n_slabs, 2); // 300 → 2×256
        assert_eq!(b.n_blocks, 2); // 600 → 2×512
        assert_eq!(b.blocks.len(), 2);
        assert_eq!(b.blocks[0].len(), 2);
        // nnz preserved: sum of |tile| entries equals the dense sum
        let tile_sum: f32 = b
            .blocks
            .iter()
            .flatten()
            .flat_map(|t| t.iter())
            .map(|v| v.abs())
            .sum();
        let direct: f64 = (0..p.n())
            .map(|i| p.ds.x.col_iter(i).map(|(_, v)| v.abs()).sum::<f64>())
            .sum();
        // f32 tile entries + f32 accumulation: compare to relative tolerance
        assert!(
            ((tile_sum as f64 - direct) / direct).abs() < 1e-5,
            "{tile_sum} vs {direct}"
        );
        // labels preserved (last real instance), padding zero beyond it
        assert_eq!(b.y_blocks[1][599 - 512], p.ds.y[599] as f32);
        assert_eq!(b.y_blocks[1][600 - 512], 0.0);
    }

    #[test]
    fn blocked_data_rejects_huge_dense() {
        let ds = generate(&GenSpec::new("huge", 300_000, 6_000, 5).with_seed(9));
        let p = Problem::logistic_l2(ds, 1e-3);
        assert!(BlockedData::build(&p).is_err());
    }
}
