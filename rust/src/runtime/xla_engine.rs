//! PJRT backend of the [`ComputeEngine`] contract (`--features xla`).
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`
//! (`make artifacts`), compiles each once on the PJRT CPU client, and
//! serves the typed kernel wrappers to the blocked trainer. Python never
//! runs at training time; after `make artifacts` the rust binary is
//! self-contained. The matmul hot spots inside these graphs are Pallas
//! kernels (interpret-mode) — see `python/compile/kernels/`.
//!
//! Offline builds resolve the `xla` dependency to the vendored type-stub
//! (`third_party/xla-stub`), which keeps this module compiling but makes
//! [`XlaEngine::load`] return an error; swap in the real `xla` crate to
//! execute artifacts.

use super::contract::{ComputeEngine, ARTIFACTS, BLOCK_D, BLOCK_N, BLOCK_U};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled PJRT executable with its artifact name.
struct CompiledKernel {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl CompiledKernel {
    fn execute(&self, args: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("execute {}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("sync {}", self.name))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple
        tuple.to_tuple1().with_context(|| format!("untuple {}", self.name))
    }
}

/// The PJRT client plus the compiled kernel set.
pub struct XlaEngine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    kernels: HashMap<String, CompiledKernel>,
}

fn f32_input(values: &[f32], shape: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(values).reshape(shape)?)
}

fn i32_input(values: &[i32], shape: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(values).reshape(shape)?)
}

impl XlaEngine {
    /// Load and compile every artifact under `dir` (typically `artifacts/`).
    pub fn load(dir: &Path) -> Result<XlaEngine> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut kernels = HashMap::new();
        for k in ARTIFACTS {
            let path: PathBuf = dir.join(format!("{}.hlo.txt", k.name));
            if !path.exists() {
                bail!(
                    "missing artifact {} — run `make artifacts` first",
                    path.display()
                );
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile {}", k.name))?;
            kernels.insert(
                k.name.to_string(),
                CompiledKernel { name: k.name.to_string(), exe },
            );
        }
        Ok(XlaEngine { client, kernels })
    }

    fn kernel(&self, name: &str) -> &CompiledKernel {
        self.kernels.get(name).unwrap_or_else(|| panic!("kernel {name} not loaded"))
    }
}

impl ComputeEngine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn partial_products(&self, w: &[f32], d_block: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(w.len(), BLOCK_D);
        assert_eq!(d_block.len(), BLOCK_D * BLOCK_N);
        let out = self.kernel("partial_products").execute(&[
            f32_input(w, &[BLOCK_D as i64])?,
            f32_input(d_block, &[BLOCK_N as i64, BLOCK_D as i64])?,
        ])?;
        Ok(out.to_vec::<f32>()?)
    }

    fn logistic_coef(&self, s: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(s.len(), BLOCK_N);
        assert_eq!(y.len(), BLOCK_N);
        let out = self.kernel("logistic_coef").execute(&[
            f32_input(s, &[BLOCK_N as i64])?,
            f32_input(y, &[BLOCK_N as i64])?,
        ])?;
        Ok(out.to_vec::<f32>()?)
    }

    fn hinge_coef(&self, s: &[f32], y: &[f32], gamma: f32) -> Result<Vec<f32>> {
        assert_eq!(s.len(), BLOCK_N);
        assert_eq!(y.len(), BLOCK_N);
        let out = self.kernel("hinge_coef").execute(&[
            f32_input(s, &[BLOCK_N as i64])?,
            f32_input(y, &[BLOCK_N as i64])?,
            f32_input(&[gamma], &[1])?,
        ])?;
        Ok(out.to_vec::<f32>()?)
    }

    fn coef_matvec(&self, d_block: &[f32], c: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(d_block.len(), BLOCK_D * BLOCK_N);
        assert_eq!(c.len(), BLOCK_N);
        let out = self.kernel("coef_matvec").execute(&[
            f32_input(d_block, &[BLOCK_N as i64, BLOCK_D as i64])?,
            f32_input(c, &[BLOCK_N as i64])?,
        ])?;
        Ok(out.to_vec::<f32>()?)
    }

    fn batch_dots(&self, w: &[f32], d_block: &[f32], idx: &[i32]) -> Result<Vec<f32>> {
        assert_eq!(idx.len(), BLOCK_U);
        let out = self.kernel("batch_dots").execute(&[
            f32_input(w, &[BLOCK_D as i64])?,
            f32_input(d_block, &[BLOCK_N as i64, BLOCK_D as i64])?,
            i32_input(idx, &[BLOCK_U as i64])?,
        ])?;
        Ok(out.to_vec::<f32>()?)
    }

    fn batch_update(
        &self,
        w: &[f32],
        z: &[f32],
        d_block: &[f32],
        idx: &[i32],
        margins: &[f32],
        y: &[f32],
        c0: &[f32],
        eta: f32,
        lambda: f32,
    ) -> Result<Vec<f32>> {
        let out = self.kernel("batch_update").execute(&[
            f32_input(w, &[BLOCK_D as i64])?,
            f32_input(z, &[BLOCK_D as i64])?,
            f32_input(d_block, &[BLOCK_N as i64, BLOCK_D as i64])?,
            i32_input(idx, &[BLOCK_U as i64])?,
            f32_input(margins, &[BLOCK_U as i64])?,
            f32_input(y, &[BLOCK_U as i64])?,
            f32_input(c0, &[BLOCK_U as i64])?,
            xla::Literal::from(eta),
            xla::Literal::from(lambda),
        ])?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine-level tests live in rust/tests/xla_runtime.rs; against the
    // offline stub the only testable behaviour is the load-failure path.
    #[test]
    fn load_missing_dir_errors_cleanly() {
        let msg = match XlaEngine::load(Path::new("/nonexistent-artifacts-dir")) {
            Ok(_) => panic!("load must fail on a missing dir"),
            Err(e) => format!("{e:#}"),
        };
        // stub build: PJRT client creation fails first; real build: the
        // missing-artifact message. Both must name an actionable fix.
        assert!(
            msg.contains("make artifacts") || msg.contains("stub"),
            "unhelpful error: {msg}"
        );
    }
}
