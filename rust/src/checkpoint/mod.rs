//! Model checkpointing: save/restore trained parameters (version 1) and
//! full mid-run session snapshots (version 2).
//!
//! ## Version 1 — final weights (inference-only), little-endian
//!
//! ```text
//! magic   8 B  "FDSVRGCK"
//! version u32  = 1
//! d       u64          parameter dimension
//! algo    u32 + bytes  algorithm name
//! dataset u32 + bytes  dataset name
//! lambda  f64
//! w       d × f64
//! crc     u64          FNV-1a over everything above
//! ```
//!
//! ## Version 2 — session snapshot (mid-run resume), little-endian
//!
//! Shares the v1 header layout (so inference-only consumers read the
//! weights out of either version), then appends the session section:
//!
//! ```text
//! magic   8 B  "FDSVRGCK"
//! version u32  = 2
//! d       u64
//! algo    u32 + bytes
//! dataset u32 + bytes
//! lambda  f64
//! w       d × f64               assembled parameter at the epoch boundary
//! wire    u32                   0 = f64, 1 = f32, 2 = sparse
//! epoch   u64                   completed outer epochs
//! grads   u64                   cumulative gradient evaluations
//! trace   u64 count × point     point = outer u64, sim_time f64,
//!                               skew f64 (per-node clock skew),
//!                               wall_time f64, scalars u64, bytes u64,
//!                               grads u64, objective f64
//! comm    u64 count × sender    sender = scalars u64, bytes u64,
//!                               messages u64   (per-node counters)
//! nodes   u64 count × node      node = has_rng u8, rng 4 × u64,
//!                               has_jitter u8, jitter 4 × u64,
//!                               clock f64, nic_out f64, nic_in f64,
//!                               extra u64 count × f64
//! crc     u64                   FNV-1a over everything above
//! ```
//!
//! `nodes[i].extra` is algorithm-owned (SAGA's coefficient table, D-PSGD's
//! local parameter copy, PS-Lite's step counter, ...). The `jitter` words
//! are the node's net-model noise stream (PCG state of the
//! `--net jitter` scenario; `has_jitter = 0` on jitter-free models):
//! restoring them replays the exact per-message latency noise the
//! uninterrupted run would have drawn, so jittered runs resume bit-exactly
//! too. A run restored from a v2 checkpoint continues on the identical
//! trajectory: same `w`, same trace points, same per-sender byte counters
//! (for the deterministic algorithms; the asynchronous ones race by
//! design).

use crate::metrics::Trace;
use crate::net::{ClockState, NodeComm, WireFmt};
use crate::session::{NodeState, ResumeState, SessionState};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"FDSVRGCK";
const VERSION: u32 = 1;
const VERSION_SESSION: u32 = 2;

/// A saved model.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub algorithm: String,
    pub dataset: String,
    pub lambda: f64,
    pub w: Vec<f64>,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Check magic + CRC; returns the CRC-covered body slice.
fn verify_envelope(bytes: &[u8]) -> Result<&[u8]> {
    if bytes.len() < MAGIC.len() + 12 + 8 {
        bail!("checkpoint too short ({} bytes)", bytes.len());
    }
    if &bytes[..8] != MAGIC {
        bail!("bad checkpoint magic");
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(crc_bytes.try_into().unwrap());
    if want != fnv1a(body) {
        bail!("checkpoint CRC mismatch (corrupted file)");
    }
    Ok(body)
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

fn get_u32(bytes: &[u8], at: &mut usize) -> Result<u32> {
    let end = *at + 4;
    if end > bytes.len() {
        bail!("truncated checkpoint");
    }
    let v = u32::from_le_bytes(bytes[*at..end].try_into().unwrap());
    *at = end;
    Ok(v)
}

fn get_u64(bytes: &[u8], at: &mut usize) -> Result<u64> {
    let end = *at + 8;
    if end > bytes.len() {
        bail!("truncated checkpoint");
    }
    let v = u64::from_le_bytes(bytes[*at..end].try_into().unwrap());
    *at = end;
    Ok(v)
}

fn get_str(bytes: &[u8], at: &mut usize) -> Result<String> {
    let len = get_u32(bytes, at)? as usize;
    let end = *at + len;
    if end > bytes.len() {
        bail!("truncated checkpoint string");
    }
    let s = std::str::from_utf8(&bytes[*at..end]).context("checkpoint string not utf-8")?;
    *at = end;
    Ok(s.to_string())
}

fn get_f64(bytes: &[u8], at: &mut usize) -> Result<f64> {
    Ok(f64::from_bits(get_u64(bytes, at)?))
}

fn get_u8(bytes: &[u8], at: &mut usize) -> Result<u8> {
    if *at >= bytes.len() {
        bail!("truncated checkpoint");
    }
    let v = bytes[*at];
    *at += 1;
    Ok(v)
}

fn get_f64_vec(bytes: &[u8], at: &mut usize, len: usize) -> Result<Vec<f64>> {
    let end = *at + 8 * len;
    if end > bytes.len() {
        bail!("truncated checkpoint vector");
    }
    let v = bytes[*at..end]
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    *at = end;
    Ok(v)
}

fn put_f64_vec(buf: &mut Vec<u8>, v: &[f64]) {
    for x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn wire_code(wire: WireFmt) -> u32 {
    match wire {
        WireFmt::F64 => 0,
        WireFmt::F32 => 1,
        WireFmt::Sparse => 2,
    }
}

fn wire_from_code(code: u32) -> Result<WireFmt> {
    match code {
        0 => Ok(WireFmt::F64),
        1 => Ok(WireFmt::F32),
        2 => Ok(WireFmt::Sparse),
        other => bail!("unknown wire-format code {other} in checkpoint"),
    }
}

impl Checkpoint {
    pub fn new(algorithm: &str, dataset: &str, lambda: f64, w: Vec<f64>) -> Checkpoint {
        Checkpoint { algorithm: algorithm.into(), dataset: dataset.into(), lambda, w }
    }

    /// Serialize to the version-1 binary format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(64 + 8 * self.w.len());
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&(self.w.len() as u64).to_le_bytes());
        put_str(&mut buf, &self.algorithm);
        put_str(&mut buf, &self.dataset);
        buf.extend_from_slice(&self.lambda.to_le_bytes());
        for v in &self.w {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        let crc = fnv1a(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    /// Parse + verify a checkpoint, reading the inference view (header +
    /// weights). Accepts version 1 files and the shared header of
    /// version 2 session snapshots, so old consumers keep working on
    /// both.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        let body = verify_envelope(bytes)?;
        let mut at = 8usize;
        let version = get_u32(bytes, &mut at)?;
        if version != VERSION && version != VERSION_SESSION {
            bail!("unsupported checkpoint version {version}");
        }
        let d = get_u64(bytes, &mut at)? as usize;
        let algorithm = get_str(bytes, &mut at)?;
        let dataset = get_str(bytes, &mut at)?;
        let lambda = f64::from_bits(get_u64(bytes, &mut at)?);
        if version == VERSION && body.len() - at != 8 * d {
            bail!("checkpoint dim {d} disagrees with payload");
        }
        let w = get_f64_vec(bytes, &mut at, d)?;
        Ok(Checkpoint { algorithm, dataset, lambda, w })
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Checkpoint> {
        let mut bytes = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {}", path.as_ref().display()))?
            .read_to_end(&mut bytes)?;
        Checkpoint::from_bytes(&bytes).with_context(|| format!("parse {}", path.as_ref().display()))
    }

    /// Validate against a problem before warm-starting it.
    pub fn check_compatible(&self, d: usize) -> Result<()> {
        if self.w.len() != d {
            bail!(
                "checkpoint dim {} does not match problem dim {d}",
                self.w.len()
            );
        }
        Ok(())
    }
}

/// A version-2 checkpoint: the full mid-run [`SessionState`]. Saving one
/// and resuming through [`crate::session::SessionBuilder::resume`]
/// reproduces the uninterrupted run's trajectory.
#[derive(Clone, Debug)]
pub struct SessionCheckpoint {
    pub state: SessionState,
}

/// Either checkpoint version, as loaded from disk.
pub enum Loaded {
    /// v1: final weights only (inference / warm start).
    Weights(Checkpoint),
    /// v2: full session snapshot (mid-run resume; also usable for
    /// inference via its `w`).
    Session(Box<SessionCheckpoint>),
}

/// Load a checkpoint of either version, dispatching on the version field.
pub fn load_any<P: AsRef<Path>>(path: P) -> Result<Loaded> {
    let mut bytes = Vec::new();
    std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?
        .read_to_end(&mut bytes)?;
    verify_envelope(&bytes)?;
    let mut at = 8usize;
    let version = get_u32(&bytes, &mut at)?;
    match version {
        VERSION => Ok(Loaded::Weights(
            Checkpoint::from_bytes(&bytes)
                .with_context(|| format!("parse {}", path.as_ref().display()))?,
        )),
        VERSION_SESSION => Ok(Loaded::Session(Box::new(
            SessionCheckpoint::from_bytes(&bytes)
                .with_context(|| format!("parse {}", path.as_ref().display()))?,
        ))),
        other => bail!("unsupported checkpoint version {other}"),
    }
}

impl SessionCheckpoint {
    pub fn new(state: SessionState) -> SessionCheckpoint {
        SessionCheckpoint { state }
    }

    /// Serialize to the version-2 binary format (see the module docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let st = &self.state;
        let r = &st.resume;
        let mut buf = Vec::with_capacity(128 + 8 * r.w.len());
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION_SESSION.to_le_bytes());
        buf.extend_from_slice(&(r.w.len() as u64).to_le_bytes());
        put_str(&mut buf, &st.algorithm);
        put_str(&mut buf, &st.dataset);
        buf.extend_from_slice(&st.lambda.to_le_bytes());
        put_f64_vec(&mut buf, &r.w);
        buf.extend_from_slice(&wire_code(st.wire).to_le_bytes());
        buf.extend_from_slice(&(r.epoch as u64).to_le_bytes());
        buf.extend_from_slice(&r.grads.to_le_bytes());
        buf.extend_from_slice(&(st.trace.points.len() as u64).to_le_bytes());
        for p in &st.trace.points {
            buf.extend_from_slice(&(p.outer as u64).to_le_bytes());
            buf.extend_from_slice(&p.sim_time.to_le_bytes());
            buf.extend_from_slice(&p.skew.to_le_bytes());
            buf.extend_from_slice(&p.wall_time.to_le_bytes());
            buf.extend_from_slice(&p.scalars.to_le_bytes());
            buf.extend_from_slice(&p.bytes.to_le_bytes());
            buf.extend_from_slice(&p.grads.to_le_bytes());
            buf.extend_from_slice(&p.objective.to_le_bytes());
        }
        buf.extend_from_slice(&(r.comm.len() as u64).to_le_bytes());
        for nc in &r.comm {
            buf.extend_from_slice(&nc.scalars.to_le_bytes());
            buf.extend_from_slice(&nc.bytes.to_le_bytes());
            buf.extend_from_slice(&nc.messages.to_le_bytes());
        }
        buf.extend_from_slice(&(r.nodes.len() as u64).to_le_bytes());
        for node in &r.nodes {
            for words in [node.rng, node.jitter] {
                match words {
                    Some(w) => {
                        buf.push(1);
                        for wdr in w {
                            buf.extend_from_slice(&wdr.to_le_bytes());
                        }
                    }
                    None => {
                        buf.push(0);
                        buf.extend_from_slice(&[0u8; 32]);
                    }
                }
            }
            buf.extend_from_slice(&node.clock.clock.to_le_bytes());
            buf.extend_from_slice(&node.clock.nic_out.to_le_bytes());
            buf.extend_from_slice(&node.clock.nic_in.to_le_bytes());
            buf.extend_from_slice(&(node.extra.len() as u64).to_le_bytes());
            put_f64_vec(&mut buf, &node.extra);
        }
        let crc = fnv1a(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    /// Parse + verify a version-2 checkpoint.
    pub fn from_bytes(bytes: &[u8]) -> Result<SessionCheckpoint> {
        let body = verify_envelope(bytes)?;
        let mut at = 8usize;
        let version = get_u32(bytes, &mut at)?;
        if version != VERSION_SESSION {
            bail!("not a session checkpoint (version {version}; use Checkpoint for v1)");
        }
        let d = get_u64(bytes, &mut at)? as usize;
        let algorithm = get_str(bytes, &mut at)?;
        let dataset = get_str(bytes, &mut at)?;
        let lambda = get_f64(bytes, &mut at)?;
        let w = get_f64_vec(bytes, &mut at, d)?;
        let wire = wire_from_code(get_u32(bytes, &mut at)?)?;
        let epoch = get_u64(bytes, &mut at)? as usize;
        let grads = get_u64(bytes, &mut at)?;
        let npoints = get_u64(bytes, &mut at)? as usize;
        let mut trace = Trace::default();
        for _ in 0..npoints {
            trace.push(crate::metrics::TracePoint {
                outer: get_u64(bytes, &mut at)? as usize,
                sim_time: get_f64(bytes, &mut at)?,
                skew: get_f64(bytes, &mut at)?,
                wall_time: get_f64(bytes, &mut at)?,
                scalars: get_u64(bytes, &mut at)?,
                bytes: get_u64(bytes, &mut at)?,
                grads: get_u64(bytes, &mut at)?,
                objective: get_f64(bytes, &mut at)?,
            });
        }
        let ncomm = get_u64(bytes, &mut at)? as usize;
        let mut comm = Vec::with_capacity(ncomm);
        for _ in 0..ncomm {
            comm.push(NodeComm {
                scalars: get_u64(bytes, &mut at)?,
                bytes: get_u64(bytes, &mut at)?,
                messages: get_u64(bytes, &mut at)?,
            });
        }
        let nnodes = get_u64(bytes, &mut at)? as usize;
        let mut nodes = Vec::with_capacity(nnodes);
        for _ in 0..nnodes {
            let read_words = |at: &mut usize| -> Result<Option<[u64; 4]>> {
                let present = get_u8(bytes, at)? != 0;
                let mut words = [0u64; 4];
                for wdr in words.iter_mut() {
                    *wdr = get_u64(bytes, at)?;
                }
                Ok(present.then_some(words))
            };
            let rng = read_words(&mut at)?;
            let jitter = read_words(&mut at)?;
            let clock = ClockState {
                clock: get_f64(bytes, &mut at)?,
                nic_out: get_f64(bytes, &mut at)?,
                nic_in: get_f64(bytes, &mut at)?,
            };
            let nextra = get_u64(bytes, &mut at)? as usize;
            let extra = get_f64_vec(bytes, &mut at, nextra)?;
            nodes.push(NodeState { rng, jitter, clock, extra });
        }
        if at != body.len() {
            bail!("session checkpoint has {} trailing bytes", body.len() - at);
        }
        Ok(SessionCheckpoint {
            state: SessionState {
                algorithm,
                dataset,
                lambda,
                wire,
                trace,
                resume: ResumeState { epoch, grads, w: std::sync::Arc::new(w), comm, nodes },
            },
        })
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<SessionCheckpoint> {
        let mut bytes = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {}", path.as_ref().display()))?
            .read_to_end(&mut bytes)?;
        SessionCheckpoint::from_bytes(&bytes)
            .with_context(|| format!("parse {}", path.as_ref().display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Checkpoint {
        Checkpoint::new("fdsvrg", "webspam-sim", 1e-4, vec![0.5, -1.5, 0.0, 3.25])
    }

    #[test]
    fn round_trip() {
        let c = demo();
        let back = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("fdsvrg_ckpt_test");
        let path = dir.join("m.ckpt");
        demo().save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, demo());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corruption_detected() {
        let mut bytes = demo().to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err}").contains("CRC"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let bytes = demo().to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 9]).is_err());
        assert!(Checkpoint::from_bytes(&bytes[..4]).is_err());
    }

    #[test]
    fn bad_magic_detected() {
        let mut bytes = demo().to_bytes();
        bytes[0] = b'X';
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn dim_check() {
        let c = demo();
        assert!(c.check_compatible(4).is_ok());
        assert!(c.check_compatible(5).is_err());
    }

    #[test]
    fn empty_w_round_trips() {
        let c = Checkpoint::new("a", "b", 0.0, vec![]);
        assert_eq!(Checkpoint::from_bytes(&c.to_bytes()).unwrap(), c);
    }

    fn demo_session() -> SessionCheckpoint {
        let mut trace = Trace::default();
        trace.push(crate::metrics::TracePoint {
            outer: 0,
            sim_time: 0.0,
            skew: 0.0,
            wall_time: 0.0,
            scalars: 0,
            bytes: 0,
            grads: 0,
            objective: 0.693,
        });
        trace.push(crate::metrics::TracePoint {
            outer: 1,
            sim_time: 1.5,
            skew: 0.3,
            wall_time: 0.1,
            scalars: 100,
            bytes: 800,
            grads: 60,
            objective: 0.5,
        });
        SessionCheckpoint::new(SessionState {
            algorithm: "fdsvrg".into(),
            dataset: "tiny".into(),
            lambda: 1e-3,
            wire: WireFmt::F64,
            trace,
            resume: ResumeState {
                epoch: 1,
                grads: 60,
                w: std::sync::Arc::new(vec![0.25, -1.0, 3.5]),
                comm: vec![
                    NodeComm { scalars: 40, bytes: 320, messages: 4 },
                    NodeComm { scalars: 60, bytes: 480, messages: 6 },
                ],
                nodes: vec![
                    NodeState {
                        rng: None,
                        jitter: Some([11, 22, 33, u64::MAX]),
                        clock: ClockState { clock: 1.5, nic_out: 1.4, nic_in: 1.45 },
                        extra: vec![],
                    },
                    NodeState {
                        rng: Some([u64::MAX, 1, 2, 3]),
                        jitter: None,
                        clock: ClockState { clock: 1.2, nic_out: 0.0, nic_in: 1.1 },
                        extra: vec![9.0, -0.5],
                    },
                ],
            },
        })
    }

    #[test]
    fn session_checkpoint_round_trips() {
        let c = demo_session();
        let back = SessionCheckpoint::from_bytes(&c.to_bytes()).unwrap();
        let (a, b) = (&c.state, &back.state);
        assert_eq!(a.algorithm, b.algorithm);
        assert_eq!(a.dataset, b.dataset);
        assert_eq!(a.lambda, b.lambda);
        assert_eq!(a.wire, b.wire);
        assert_eq!(a.trace.points, b.trace.points);
        assert_eq!(a.resume.epoch, b.resume.epoch);
        assert_eq!(a.resume.grads, b.resume.grads);
        assert_eq!(a.resume.w, b.resume.w);
        assert_eq!(a.resume.comm, b.resume.comm);
        assert_eq!(a.resume.nodes, b.resume.nodes);
    }

    #[test]
    fn v1_reader_extracts_weights_from_v2() {
        // inference-only consumers read the shared header of either version
        let c = demo_session();
        let weights = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(weights.algorithm, "fdsvrg");
        assert_eq!(weights.w, vec![0.25, -1.0, 3.5]);
        assert_eq!(weights.lambda, 1e-3);
    }

    #[test]
    fn load_any_dispatches_on_version() {
        let dir = std::env::temp_dir().join("fdsvrg_ckpt_any_test");
        let v1 = dir.join("v1.ckpt");
        let v2 = dir.join("v2.ckpt");
        demo().save(&v1).unwrap();
        demo_session().save(&v2).unwrap();
        assert!(matches!(load_any(&v1).unwrap(), Loaded::Weights(_)));
        assert!(matches!(load_any(&v2).unwrap(), Loaded::Session(_)));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn session_checkpoint_corruption_detected() {
        let mut bytes = demo_session().to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        let err = SessionCheckpoint::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err}").contains("CRC"), "{err}");
    }

    #[test]
    fn v1_loader_rejects_session_parser() {
        // a v1 file is not a session snapshot
        let err = SessionCheckpoint::from_bytes(&demo().to_bytes()).unwrap_err();
        assert!(format!("{err}").contains("version 1"), "{err}");
    }
}
