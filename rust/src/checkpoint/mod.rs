//! Model checkpointing: save/restore a trained parameter vector with
//! enough metadata to validate it against the problem it is loaded into.
//!
//! Format (version 1, little-endian):
//!
//! ```text
//! magic   8 B  "FDSVRGCK"
//! version u32
//! d       u64          parameter dimension
//! algo    u32 + bytes  algorithm name
//! dataset u32 + bytes  dataset name
//! lambda  f64
//! w       d × f64
//! crc     u64          FNV-1a over everything above
//! ```

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"FDSVRGCK";
const VERSION: u32 = 1;

/// A saved model.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub algorithm: String,
    pub dataset: String,
    pub lambda: f64,
    pub w: Vec<f64>,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

fn get_u32(bytes: &[u8], at: &mut usize) -> Result<u32> {
    let end = *at + 4;
    if end > bytes.len() {
        bail!("truncated checkpoint");
    }
    let v = u32::from_le_bytes(bytes[*at..end].try_into().unwrap());
    *at = end;
    Ok(v)
}

fn get_u64(bytes: &[u8], at: &mut usize) -> Result<u64> {
    let end = *at + 8;
    if end > bytes.len() {
        bail!("truncated checkpoint");
    }
    let v = u64::from_le_bytes(bytes[*at..end].try_into().unwrap());
    *at = end;
    Ok(v)
}

fn get_str(bytes: &[u8], at: &mut usize) -> Result<String> {
    let len = get_u32(bytes, at)? as usize;
    let end = *at + len;
    if end > bytes.len() {
        bail!("truncated checkpoint string");
    }
    let s = std::str::from_utf8(&bytes[*at..end]).context("checkpoint string not utf-8")?;
    *at = end;
    Ok(s.to_string())
}

impl Checkpoint {
    pub fn new(algorithm: &str, dataset: &str, lambda: f64, w: Vec<f64>) -> Checkpoint {
        Checkpoint { algorithm: algorithm.into(), dataset: dataset.into(), lambda, w }
    }

    /// Serialize to the version-1 binary format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(64 + 8 * self.w.len());
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&(self.w.len() as u64).to_le_bytes());
        put_str(&mut buf, &self.algorithm);
        put_str(&mut buf, &self.dataset);
        buf.extend_from_slice(&self.lambda.to_le_bytes());
        for v in &self.w {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        let crc = fnv1a(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    /// Parse + verify a version-1 checkpoint.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        if bytes.len() < MAGIC.len() + 12 + 8 {
            bail!("checkpoint too short ({} bytes)", bytes.len());
        }
        if &bytes[..8] != MAGIC {
            bail!("bad checkpoint magic");
        }
        let (body, crc_bytes) = bytes.split_at(bytes.len() - 8);
        let want = u64::from_le_bytes(crc_bytes.try_into().unwrap());
        let got = fnv1a(body);
        if want != got {
            bail!("checkpoint CRC mismatch (corrupted file)");
        }
        let mut at = 8usize;
        let version = get_u32(bytes, &mut at)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let d = get_u64(bytes, &mut at)? as usize;
        let algorithm = get_str(bytes, &mut at)?;
        let dataset = get_str(bytes, &mut at)?;
        let lambda = f64::from_bits(get_u64(bytes, &mut at)?);
        if body.len() - at != 8 * d {
            bail!("checkpoint dim {d} disagrees with payload");
        }
        let w = bytes[at..at + 8 * d]
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Checkpoint { algorithm, dataset, lambda, w })
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Checkpoint> {
        let mut bytes = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {}", path.as_ref().display()))?
            .read_to_end(&mut bytes)?;
        Checkpoint::from_bytes(&bytes).with_context(|| format!("parse {}", path.as_ref().display()))
    }

    /// Validate against a problem before warm-starting it.
    pub fn check_compatible(&self, d: usize) -> Result<()> {
        if self.w.len() != d {
            bail!(
                "checkpoint dim {} does not match problem dim {d}",
                self.w.len()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Checkpoint {
        Checkpoint::new("fdsvrg", "webspam-sim", 1e-4, vec![0.5, -1.5, 0.0, 3.25])
    }

    #[test]
    fn round_trip() {
        let c = demo();
        let back = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("fdsvrg_ckpt_test");
        let path = dir.join("m.ckpt");
        demo().save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, demo());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corruption_detected() {
        let mut bytes = demo().to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err}").contains("CRC"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let bytes = demo().to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 9]).is_err());
        assert!(Checkpoint::from_bytes(&bytes[..4]).is_err());
    }

    #[test]
    fn bad_magic_detected() {
        let mut bytes = demo().to_bytes();
        bytes[0] = b'X';
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn dim_check() {
        let c = demo();
        assert!(c.check_compatible(4).is_ok());
        assert!(c.check_compatible(5).is_err());
    }

    #[test]
    fn empty_w_round_trips() {
        let c = Checkpoint::new("a", "b", 0.0, vec![]);
        assert_eq!(Checkpoint::from_bytes(&c.to_bytes()).unwrap(), c);
    }
}
