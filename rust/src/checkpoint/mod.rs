//! Model checkpointing: save/restore trained parameters (version 1) and
//! full mid-run session snapshots (version 2).
//!
//! ## Version 1 — final weights (inference-only), little-endian
//!
//! ```text
//! magic   8 B  "FDSVRGCK"
//! version u32  = 1
//! d       u64          parameter dimension
//! algo    u32 + bytes  algorithm name
//! dataset u32 + bytes  dataset name
//! lambda  f64
//! w       d × f64
//! crc     u64          FNV-1a over everything above
//! ```
//!
//! ## Version 2 — session snapshot (mid-run resume), little-endian
//!
//! Shares the v1 header layout (so inference-only consumers read the
//! weights out of either version), then appends the session section:
//!
//! ```text
//! magic   8 B  "FDSVRGCK"
//! version u32  = 2
//! d       u64
//! algo    u32 + bytes
//! dataset u32 + bytes
//! lambda  f64
//! w       d × f64               assembled parameter at the epoch boundary
//! wire    u32                   0 = f64, 1 = f32, 2 = sparse
//! epoch   u64                   completed outer epochs
//! grads   u64                   cumulative gradient evaluations
//! trace   u64 count × point     point = outer u64, sim_time f64,
//!                               skew f64 (per-node clock skew),
//!                               wall_time f64, scalars u64, bytes u64,
//!                               grads u64, objective f64
//! comm    u64 count × sender    sender = scalars u64, bytes u64,
//!                               messages u64   (per-node counters)
//! nodes   u64 count × node      node = has_rng u8, rng 4 × u64,
//!                               has_jitter u8, jitter 4 × u64,
//!                               clock f64, nic_out f64, nic_in f64,
//!                               extra u64 count × f64
//! crc     u64                   FNV-1a over everything above
//! ```
//!
//! `nodes[i].extra` is algorithm-owned (SAGA's coefficient table, D-PSGD's
//! local parameter copy, PS-Lite's step counter, ...). The `jitter` words
//! are the node's net-model noise stream (PCG state of the
//! `--net jitter` scenario; `has_jitter = 0` on jitter-free models):
//! restoring them replays the exact per-message latency noise the
//! uninterrupted run would have drawn, so jittered runs resume bit-exactly
//! too. A run restored from a v2 checkpoint continues on the identical
//! trajectory: same `w`, same trace points, same per-sender byte counters
//! (for the deterministic algorithms; the asynchronous ones race by
//! design).

use crate::metrics::Trace;
use crate::net::{ClockState, NodeComm, WireFmt};
use crate::session::{NodeState, ResumeState, SessionState};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"FDSVRGCK";
const VERSION: u32 = 1;
const VERSION_SESSION: u32 = 2;

/// A saved model.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub algorithm: String,
    pub dataset: String,
    pub lambda: f64,
    pub w: Vec<f64>,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Check magic + CRC; returns the CRC-covered body slice. Every failure
/// names the offset and the expected-vs-got bytes so a corrupted file is
/// diagnosable from the error alone.
fn verify_envelope(bytes: &[u8]) -> Result<&[u8]> {
    if bytes.len() < MAGIC.len() + 12 + 8 {
        bail!(
            "checkpoint truncated: {} bytes, but even an empty checkpoint needs {} \
             (8-byte magic + version + dim + 8-byte CRC trailer)",
            bytes.len(),
            MAGIC.len() + 12 + 8
        );
    }
    if &bytes[..8] != MAGIC {
        bail!(
            "bad checkpoint magic at offset 0: expected {:02x?} ({:?}), got {:02x?}",
            MAGIC,
            std::str::from_utf8(MAGIC).unwrap(),
            &bytes[..8]
        );
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(crc_bytes.try_into().unwrap());
    let got = fnv1a(body);
    if want != got {
        bail!(
            "checkpoint CRC mismatch: trailer at offset {} says {want:#018x}, \
             body hashes to {got:#018x} — the file is corrupted",
            body.len()
        );
    }
    Ok(body)
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

/// Checked cursor advance: `need` bytes at `*at`, or a loud error naming
/// the offset, the field and the expected-vs-got byte counts. All reader
/// arithmetic goes through here so an adversarial length field can
/// neither wrap the cursor nor trigger an allocation/slice panic.
fn take<'a>(bytes: &'a [u8], at: &mut usize, need: usize, what: &str) -> Result<&'a [u8]> {
    let end = at
        .checked_add(need)
        .filter(|&end| end <= bytes.len())
        .ok_or_else(|| {
            anyhow::anyhow!(
                "truncated checkpoint: {what} at offset {} needs {need} bytes, \
                 but only {} of {} remain",
                *at,
                bytes.len().saturating_sub(*at),
                bytes.len()
            )
        })?;
    let slice = &bytes[*at..end];
    *at = end;
    Ok(slice)
}

fn get_u32(bytes: &[u8], at: &mut usize) -> Result<u32> {
    Ok(u32::from_le_bytes(take(bytes, at, 4, "u32 field")?.try_into().unwrap()))
}

fn get_u64(bytes: &[u8], at: &mut usize) -> Result<u64> {
    Ok(u64::from_le_bytes(take(bytes, at, 8, "u64 field")?.try_into().unwrap()))
}

fn get_str(bytes: &[u8], at: &mut usize) -> Result<String> {
    let len = get_u32(bytes, at)? as usize;
    let start = *at;
    let raw = take(bytes, at, len, "string")?;
    let s = std::str::from_utf8(raw)
        .with_context(|| format!("checkpoint string at offset {start} is not utf-8"))?;
    Ok(s.to_string())
}

fn get_f64(bytes: &[u8], at: &mut usize) -> Result<f64> {
    Ok(f64::from_bits(get_u64(bytes, at)?))
}

fn get_u8(bytes: &[u8], at: &mut usize) -> Result<u8> {
    Ok(take(bytes, at, 1, "u8 field")?[0])
}

fn get_f64_vec(bytes: &[u8], at: &mut usize, len: usize) -> Result<Vec<f64>> {
    let need = len.checked_mul(8).ok_or_else(|| {
        anyhow::anyhow!(
            "corrupt checkpoint: vector length {len} at offset {} overflows the file size",
            *at
        )
    })?;
    let v = take(bytes, at, need, "f64 vector")?
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(v)
}

fn put_f64_vec(buf: &mut Vec<u8>, v: &[f64]) {
    for x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn wire_code(wire: WireFmt) -> u32 {
    match wire {
        WireFmt::F64 => 0,
        WireFmt::F32 => 1,
        WireFmt::Sparse => 2,
    }
}

fn wire_from_code(code: u32) -> Result<WireFmt> {
    match code {
        0 => Ok(WireFmt::F64),
        1 => Ok(WireFmt::F32),
        2 => Ok(WireFmt::Sparse),
        other => bail!("unknown wire-format code {other} in checkpoint"),
    }
}

impl Checkpoint {
    pub fn new(algorithm: &str, dataset: &str, lambda: f64, w: Vec<f64>) -> Checkpoint {
        Checkpoint { algorithm: algorithm.into(), dataset: dataset.into(), lambda, w }
    }

    /// Serialize to the version-1 binary format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(64 + 8 * self.w.len());
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&(self.w.len() as u64).to_le_bytes());
        put_str(&mut buf, &self.algorithm);
        put_str(&mut buf, &self.dataset);
        buf.extend_from_slice(&self.lambda.to_le_bytes());
        for v in &self.w {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        let crc = fnv1a(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    /// Parse + verify a checkpoint, reading the inference view (header +
    /// weights). Accepts version 1 files and the shared header of
    /// version 2 session snapshots, so old consumers keep working on
    /// both.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        let body = verify_envelope(bytes)?;
        let mut at = 8usize;
        let version = get_u32(bytes, &mut at)?;
        if version != VERSION && version != VERSION_SESSION {
            bail!("unsupported checkpoint version {version}");
        }
        let d = get_u64(bytes, &mut at)? as usize;
        let algorithm = get_str(bytes, &mut at)?;
        let dataset = get_str(bytes, &mut at)?;
        let lambda = f64::from_bits(get_u64(bytes, &mut at)?);
        if version == VERSION && d.checked_mul(8).and_then(|n| at.checked_add(n)) != Some(body.len())
        {
            bail!(
                "checkpoint dim {d} disagrees with payload: {} bytes follow the header at \
                 offset {at}, expected {}",
                body.len().saturating_sub(at),
                d.saturating_mul(8)
            );
        }
        let w = get_f64_vec(bytes, &mut at, d)?;
        Ok(Checkpoint { algorithm, dataset, lambda, w })
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Checkpoint> {
        let mut bytes = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {}", path.as_ref().display()))?
            .read_to_end(&mut bytes)?;
        Checkpoint::from_bytes(&bytes).with_context(|| format!("parse {}", path.as_ref().display()))
    }

    /// Validate against a problem before warm-starting it.
    pub fn check_compatible(&self, d: usize) -> Result<()> {
        if self.w.len() != d {
            bail!(
                "checkpoint dim {} does not match problem dim {d}",
                self.w.len()
            );
        }
        Ok(())
    }
}

/// A version-2 checkpoint: the full mid-run [`SessionState`]. Saving one
/// and resuming through [`crate::session::SessionBuilder::resume`]
/// reproduces the uninterrupted run's trajectory.
#[derive(Clone, Debug)]
pub struct SessionCheckpoint {
    pub state: SessionState,
}

/// Either checkpoint version, as loaded from disk.
pub enum Loaded {
    /// v1: final weights only (inference / warm start).
    Weights(Checkpoint),
    /// v2: full session snapshot (mid-run resume; also usable for
    /// inference via its `w`).
    Session(Box<SessionCheckpoint>),
}

/// Load a checkpoint of either version, dispatching on the version field.
pub fn load_any<P: AsRef<Path>>(path: P) -> Result<Loaded> {
    let mut bytes = Vec::new();
    std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?
        .read_to_end(&mut bytes)?;
    verify_envelope(&bytes)?;
    let mut at = 8usize;
    let version = get_u32(&bytes, &mut at)?;
    match version {
        VERSION => Ok(Loaded::Weights(
            Checkpoint::from_bytes(&bytes)
                .with_context(|| format!("parse {}", path.as_ref().display()))?,
        )),
        VERSION_SESSION => Ok(Loaded::Session(Box::new(
            SessionCheckpoint::from_bytes(&bytes)
                .with_context(|| format!("parse {}", path.as_ref().display()))?,
        ))),
        other => bail!("unsupported checkpoint version {other}"),
    }
}

/// Load from a single checkpoint file *or* a rotating store directory
/// (`CheckpointObserver::rotating`'s `<path>.d/`): directories resolve to
/// the newest snapshot whose envelope verifies — corrupt or truncated
/// files are skipped with a logged warning ([`CheckpointStore::latest`]'s
/// contract) — so `predict`/`serve --ckpt` can point straight at a live
/// training run's store.
pub fn load_newest<P: AsRef<Path>>(path: P) -> Result<Loaded> {
    let p = path.as_ref();
    if p.is_dir() {
        let store = CheckpointStore::new(p, usize::MAX)?;
        let sc = store
            .latest()
            .with_context(|| format!("no valid checkpoint snapshot in {}", p.display()))?;
        Ok(Loaded::Session(Box::new(sc)))
    } else {
        load_any(p)
    }
}

impl SessionCheckpoint {
    pub fn new(state: SessionState) -> SessionCheckpoint {
        SessionCheckpoint { state }
    }

    /// Serialize to the version-2 binary format (see the module docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let st = &self.state;
        let r = &st.resume;
        let mut buf = Vec::with_capacity(128 + 8 * r.w.len());
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION_SESSION.to_le_bytes());
        buf.extend_from_slice(&(r.w.len() as u64).to_le_bytes());
        put_str(&mut buf, &st.algorithm);
        put_str(&mut buf, &st.dataset);
        buf.extend_from_slice(&st.lambda.to_le_bytes());
        put_f64_vec(&mut buf, &r.w);
        buf.extend_from_slice(&wire_code(st.wire).to_le_bytes());
        buf.extend_from_slice(&(r.epoch as u64).to_le_bytes());
        buf.extend_from_slice(&r.grads.to_le_bytes());
        buf.extend_from_slice(&(st.trace.points.len() as u64).to_le_bytes());
        for p in &st.trace.points {
            buf.extend_from_slice(&(p.outer as u64).to_le_bytes());
            buf.extend_from_slice(&p.sim_time.to_le_bytes());
            buf.extend_from_slice(&p.skew.to_le_bytes());
            buf.extend_from_slice(&p.wall_time.to_le_bytes());
            buf.extend_from_slice(&p.scalars.to_le_bytes());
            buf.extend_from_slice(&p.bytes.to_le_bytes());
            buf.extend_from_slice(&p.grads.to_le_bytes());
            buf.extend_from_slice(&p.objective.to_le_bytes());
        }
        buf.extend_from_slice(&(r.comm.len() as u64).to_le_bytes());
        for nc in &r.comm {
            buf.extend_from_slice(&nc.scalars.to_le_bytes());
            buf.extend_from_slice(&nc.bytes.to_le_bytes());
            buf.extend_from_slice(&nc.messages.to_le_bytes());
        }
        buf.extend_from_slice(&(r.nodes.len() as u64).to_le_bytes());
        for node in &r.nodes {
            for words in [node.rng, node.jitter] {
                match words {
                    Some(w) => {
                        buf.push(1);
                        for wdr in w {
                            buf.extend_from_slice(&wdr.to_le_bytes());
                        }
                    }
                    None => {
                        buf.push(0);
                        buf.extend_from_slice(&[0u8; 32]);
                    }
                }
            }
            buf.extend_from_slice(&node.clock.clock.to_le_bytes());
            buf.extend_from_slice(&node.clock.nic_out.to_le_bytes());
            buf.extend_from_slice(&node.clock.nic_in.to_le_bytes());
            buf.extend_from_slice(&(node.extra.len() as u64).to_le_bytes());
            put_f64_vec(&mut buf, &node.extra);
        }
        let crc = fnv1a(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    /// Parse + verify a version-2 checkpoint.
    pub fn from_bytes(bytes: &[u8]) -> Result<SessionCheckpoint> {
        let body = verify_envelope(bytes)?;
        let mut at = 8usize;
        let version = get_u32(bytes, &mut at)?;
        if version != VERSION_SESSION {
            bail!("not a session checkpoint (version {version}; use Checkpoint for v1)");
        }
        let d = get_u64(bytes, &mut at)? as usize;
        let algorithm = get_str(bytes, &mut at)?;
        let dataset = get_str(bytes, &mut at)?;
        let lambda = get_f64(bytes, &mut at)?;
        let w = get_f64_vec(bytes, &mut at, d)?;
        let wire = wire_from_code(get_u32(bytes, &mut at)?)?;
        let epoch = get_u64(bytes, &mut at)? as usize;
        let grads = get_u64(bytes, &mut at)?;
        let npoints = get_u64(bytes, &mut at)? as usize;
        let mut trace = Trace::default();
        for _ in 0..npoints {
            trace.push(crate::metrics::TracePoint {
                outer: get_u64(bytes, &mut at)? as usize,
                sim_time: get_f64(bytes, &mut at)?,
                skew: get_f64(bytes, &mut at)?,
                wall_time: get_f64(bytes, &mut at)?,
                scalars: get_u64(bytes, &mut at)?,
                bytes: get_u64(bytes, &mut at)?,
                grads: get_u64(bytes, &mut at)?,
                objective: get_f64(bytes, &mut at)?,
            });
        }
        let ncomm = get_u64(bytes, &mut at)? as usize;
        let mut comm = Vec::with_capacity(ncomm);
        for _ in 0..ncomm {
            comm.push(NodeComm {
                scalars: get_u64(bytes, &mut at)?,
                bytes: get_u64(bytes, &mut at)?,
                messages: get_u64(bytes, &mut at)?,
            });
        }
        let nnodes = get_u64(bytes, &mut at)? as usize;
        let mut nodes = Vec::with_capacity(nnodes);
        for _ in 0..nnodes {
            let read_words = |at: &mut usize| -> Result<Option<[u64; 4]>> {
                let present = get_u8(bytes, at)? != 0;
                let mut words = [0u64; 4];
                for wdr in words.iter_mut() {
                    *wdr = get_u64(bytes, at)?;
                }
                Ok(present.then_some(words))
            };
            let rng = read_words(&mut at)?;
            let jitter = read_words(&mut at)?;
            let clock = ClockState {
                clock: get_f64(bytes, &mut at)?,
                nic_out: get_f64(bytes, &mut at)?,
                nic_in: get_f64(bytes, &mut at)?,
            };
            let nextra = get_u64(bytes, &mut at)? as usize;
            let extra = get_f64_vec(bytes, &mut at, nextra)?;
            nodes.push(NodeState { rng, jitter, clock, extra });
        }
        if at != body.len() {
            bail!(
                "session checkpoint layout error: parser stopped at offset {at}, but the \
                 CRC-covered body ends at offset {} ({} bytes unaccounted for)",
                body.len(),
                body.len().abs_diff(at)
            );
        }
        Ok(SessionCheckpoint {
            state: SessionState {
                algorithm,
                dataset,
                lambda,
                wire,
                trace,
                resume: ResumeState { epoch, grads, w: std::sync::Arc::new(w), comm, nodes },
            },
        })
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<SessionCheckpoint> {
        let mut bytes = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {}", path.as_ref().display()))?
            .read_to_end(&mut bytes)?;
        SessionCheckpoint::from_bytes(&bytes)
            .with_context(|| format!("parse {}", path.as_ref().display()))
    }
}

/// Directory-backed rolling store of the last-k session snapshots
/// (`ck-<epoch>.ckpt`, v2 format). This is what crash recovery respawns
/// from: the session layer appends a snapshot per epoch (or every n-th),
/// old snapshots are pruned, and [`CheckpointStore::latest`] hands back
/// the newest snapshot that still *verifies* — a corrupted or truncated
/// file is skipped with a warning, never trusted and never a panic, so a
/// torn write during a crash costs one epoch of rollback, not the run.
#[derive(Debug)]
pub struct CheckpointStore {
    dir: PathBuf,
    keep: usize,
}

impl CheckpointStore {
    /// Open (creating if needed) a snapshot directory keeping the last
    /// `keep` snapshots.
    pub fn new<P: AsRef<Path>>(dir: P, keep: usize) -> Result<CheckpointStore> {
        if keep == 0 {
            bail!("checkpoint store must keep at least 1 snapshot");
        }
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create checkpoint store {}", dir.display()))?;
        Ok(CheckpointStore { dir, keep })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn snapshot_path(&self, epoch: usize) -> PathBuf {
        self.dir.join(format!("ck-{epoch:08}.ckpt"))
    }

    /// Epochs with a snapshot on disk, ascending (existence only — a
    /// listed snapshot may still fail verification when loaded).
    pub fn epochs(&self) -> Vec<usize> {
        let mut out = Vec::new();
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return out;
        };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(num) = name.strip_prefix("ck-").and_then(|s| s.strip_suffix(".ckpt")) {
                if let Ok(epoch) = num.parse::<usize>() {
                    out.push(epoch);
                }
            }
        }
        out.sort_unstable();
        out
    }

    /// Persist one snapshot and prune beyond the last `keep`.
    pub fn save(&self, ck: &SessionCheckpoint) -> Result<PathBuf> {
        let path = self.snapshot_path(ck.state.resume.epoch);
        ck.save(&path)?;
        let epochs = self.epochs();
        if epochs.len() > self.keep {
            for &old in &epochs[..epochs.len() - self.keep] {
                std::fs::remove_file(self.snapshot_path(old)).ok();
            }
        }
        Ok(path)
    }

    /// The newest snapshot that verifies (magic + CRC + full parse),
    /// newest-first. Corrupt snapshots are skipped with a warning on
    /// stderr; `None` when nothing on disk verifies.
    pub fn latest(&self) -> Option<SessionCheckpoint> {
        for epoch in self.epochs().into_iter().rev() {
            let path = self.snapshot_path(epoch);
            match SessionCheckpoint::load(&path) {
                Ok(ck) => return Some(ck),
                Err(e) => {
                    crate::util::logger::log(
                        crate::util::logger::Level::Warn,
                        format_args!("skipping unreadable snapshot {}: {e:#}", path.display()),
                    );
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Checkpoint {
        Checkpoint::new("fdsvrg", "webspam-sim", 1e-4, vec![0.5, -1.5, 0.0, 3.25])
    }

    #[test]
    fn round_trip() {
        let c = demo();
        let back = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("fdsvrg_ckpt_test");
        let path = dir.join("m.ckpt");
        demo().save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, demo());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corruption_detected() {
        let mut bytes = demo().to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err}").contains("CRC"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let bytes = demo().to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 9]).is_err());
        assert!(Checkpoint::from_bytes(&bytes[..4]).is_err());
    }

    #[test]
    fn bad_magic_detected() {
        let mut bytes = demo().to_bytes();
        bytes[0] = b'X';
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn dim_check() {
        let c = demo();
        assert!(c.check_compatible(4).is_ok());
        assert!(c.check_compatible(5).is_err());
    }

    #[test]
    fn empty_w_round_trips() {
        let c = Checkpoint::new("a", "b", 0.0, vec![]);
        assert_eq!(Checkpoint::from_bytes(&c.to_bytes()).unwrap(), c);
    }

    fn demo_session() -> SessionCheckpoint {
        let mut trace = Trace::default();
        trace.push(crate::metrics::TracePoint {
            outer: 0,
            sim_time: 0.0,
            skew: 0.0,
            wall_time: 0.0,
            scalars: 0,
            bytes: 0,
            grads: 0,
            objective: 0.693,
        });
        trace.push(crate::metrics::TracePoint {
            outer: 1,
            sim_time: 1.5,
            skew: 0.3,
            wall_time: 0.1,
            scalars: 100,
            bytes: 800,
            grads: 60,
            objective: 0.5,
        });
        SessionCheckpoint::new(SessionState {
            algorithm: "fdsvrg".into(),
            dataset: "tiny".into(),
            lambda: 1e-3,
            wire: WireFmt::F64,
            trace,
            resume: ResumeState {
                epoch: 1,
                grads: 60,
                w: std::sync::Arc::new(vec![0.25, -1.0, 3.5]),
                comm: vec![
                    NodeComm { scalars: 40, bytes: 320, messages: 4 },
                    NodeComm { scalars: 60, bytes: 480, messages: 6 },
                ],
                nodes: vec![
                    NodeState {
                        rng: None,
                        jitter: Some([11, 22, 33, u64::MAX]),
                        clock: ClockState { clock: 1.5, nic_out: 1.4, nic_in: 1.45 },
                        extra: vec![],
                    },
                    NodeState {
                        rng: Some([u64::MAX, 1, 2, 3]),
                        jitter: None,
                        clock: ClockState { clock: 1.2, nic_out: 0.0, nic_in: 1.1 },
                        extra: vec![9.0, -0.5],
                    },
                ],
            },
        })
    }

    #[test]
    fn session_checkpoint_round_trips() {
        let c = demo_session();
        let back = SessionCheckpoint::from_bytes(&c.to_bytes()).unwrap();
        let (a, b) = (&c.state, &back.state);
        assert_eq!(a.algorithm, b.algorithm);
        assert_eq!(a.dataset, b.dataset);
        assert_eq!(a.lambda, b.lambda);
        assert_eq!(a.wire, b.wire);
        assert_eq!(a.trace.points, b.trace.points);
        assert_eq!(a.resume.epoch, b.resume.epoch);
        assert_eq!(a.resume.grads, b.resume.grads);
        assert_eq!(a.resume.w, b.resume.w);
        assert_eq!(a.resume.comm, b.resume.comm);
        assert_eq!(a.resume.nodes, b.resume.nodes);
    }

    #[test]
    fn v1_reader_extracts_weights_from_v2() {
        // inference-only consumers read the shared header of either version
        let c = demo_session();
        let weights = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(weights.algorithm, "fdsvrg");
        assert_eq!(weights.w, vec![0.25, -1.0, 3.5]);
        assert_eq!(weights.lambda, 1e-3);
    }

    #[test]
    fn load_any_dispatches_on_version() {
        let dir = std::env::temp_dir().join("fdsvrg_ckpt_any_test");
        let v1 = dir.join("v1.ckpt");
        let v2 = dir.join("v2.ckpt");
        demo().save(&v1).unwrap();
        demo_session().save(&v2).unwrap();
        assert!(matches!(load_any(&v1).unwrap(), Loaded::Weights(_)));
        assert!(matches!(load_any(&v2).unwrap(), Loaded::Session(_)));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn session_checkpoint_corruption_detected() {
        let mut bytes = demo_session().to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        let err = SessionCheckpoint::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err}").contains("CRC"), "{err}");
    }

    #[test]
    fn v1_loader_rejects_session_parser() {
        // a v1 file is not a session snapshot
        let err = SessionCheckpoint::from_bytes(&demo().to_bytes()).unwrap_err();
        assert!(format!("{err}").contains("version 1"), "{err}");
    }

    // ---- adversarial-bytes hardening ------------------------------------
    //
    // Corrupt files must fail with a contextual error (offset, expected vs
    // got), never a panic — even when the CRC trailer has been recomputed
    // to match the damaged body.

    /// Re-seal a tampered body with a fresh CRC so corruption survives
    /// `verify_envelope` and exercises the field parsers themselves.
    fn reseal(mut bytes: Vec<u8>) -> Vec<u8> {
        let body_len = bytes.len() - 8;
        let crc = fnv1a(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&crc.to_le_bytes());
        bytes
    }

    #[test]
    fn crc_error_reports_expected_and_got() {
        let mut bytes = demo().to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01; // single bit flip
        let err = format!("{}", Checkpoint::from_bytes(&bytes).unwrap_err());
        assert!(err.contains("CRC mismatch"), "{err}");
        assert!(err.contains("0x"), "must show both checksums: {err}");
        assert!(err.contains("offset"), "must locate the trailer: {err}");
    }

    #[test]
    fn truncation_error_names_offset_and_counts() {
        let bytes = demo().to_bytes();
        // cut mid-weights, then reseal so the envelope verifies and the
        // truncation is caught by the field readers
        let cut = reseal(bytes[..bytes.len() - 17].to_vec());
        let err = format!("{}", Checkpoint::from_bytes(&cut).unwrap_err());
        assert!(err.contains("offset"), "must name the failing offset: {err}");
        assert!(err.contains("needs") || err.contains("disagrees"), "{err}");
    }

    #[test]
    fn absurd_vector_length_fails_without_allocating() {
        // overwrite the v2 dim field (offset 12) with u64::MAX: the parser
        // must error on the length, not attempt a 2^64-element allocation
        // or wrap the cursor
        let mut bytes = demo_session().to_bytes();
        bytes[12..20].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = format!("{}", SessionCheckpoint::from_bytes(&reseal(bytes)).unwrap_err());
        assert!(
            err.contains("overflows") || err.contains("needs"),
            "huge length must fail loudly: {err}"
        );
    }

    #[test]
    fn oversized_string_length_is_an_error_not_a_panic() {
        // the algo-string length field sits right after the dim (offset 20
        // in a v2 file); make it claim more bytes than the file holds
        let mut bytes = demo_session().to_bytes();
        bytes[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = format!("{}", SessionCheckpoint::from_bytes(&reseal(bytes)).unwrap_err());
        assert!(err.contains("offset") && err.contains("needs"), "{err}");
    }

    #[test]
    fn every_single_byte_truncation_errors_cleanly() {
        // no prefix of a valid file may panic, whatever the cut point
        let bytes = demo_session().to_bytes();
        for len in 0..bytes.len() {
            assert!(
                SessionCheckpoint::from_bytes(&bytes[..len]).is_err(),
                "prefix of {len} bytes must be rejected"
            );
        }
    }

    #[test]
    fn every_byte_flip_is_detected_or_roundtrips() {
        // flipping any single body byte must either fail loudly (CRC) or —
        // never — be silently accepted; step 7 keeps the test fast
        let bytes = demo_session().to_bytes();
        for i in (0..bytes.len() - 8).step_by(7) {
            let mut bad = bytes.clone();
            bad[i] ^= 0xff;
            assert!(
                SessionCheckpoint::from_bytes(&bad).is_err(),
                "flip at byte {i} must be caught by the CRC"
            );
        }
    }

    // ---- checkpoint store -----------------------------------------------

    fn session_at_epoch(epoch: usize) -> SessionCheckpoint {
        let mut ck = demo_session();
        ck.state.resume.epoch = epoch;
        ck
    }

    #[test]
    fn store_keeps_last_k_and_serves_newest() {
        let dir = std::env::temp_dir().join("fdsvrg_ckpt_store_rotation");
        std::fs::remove_dir_all(&dir).ok();
        let store = CheckpointStore::new(&dir, 3).unwrap();
        for epoch in 1..=6 {
            store.save(&session_at_epoch(epoch)).unwrap();
        }
        assert_eq!(store.epochs(), vec![4, 5, 6], "last-3 rotation");
        assert_eq!(store.latest().unwrap().state.resume.epoch, 6);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn store_latest_skips_corrupt_snapshots() {
        let dir = std::env::temp_dir().join("fdsvrg_ckpt_store_corrupt");
        std::fs::remove_dir_all(&dir).ok();
        let store = CheckpointStore::new(&dir, 4).unwrap();
        store.save(&session_at_epoch(1)).unwrap();
        let newest = store.save(&session_at_epoch(2)).unwrap();
        // damage the newest snapshot (torn write during a crash)
        let mut bytes = std::fs::read(&newest).unwrap();
        bytes.truncate(bytes.len() / 2);
        std::fs::write(&newest, bytes).unwrap();
        let got = store.latest().expect("older snapshot must still verify");
        assert_eq!(got.state.resume.epoch, 1, "corrupt newest is skipped");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn store_rejects_keep_zero() {
        let dir = std::env::temp_dir().join("fdsvrg_ckpt_store_zero");
        assert!(CheckpointStore::new(&dir, 0).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
