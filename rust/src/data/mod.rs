//! Synthetic dataset generation matched to the paper's benchmarks.
//!
//! The paper evaluates on four LibSVM datasets (Table 1): news20
//! (d=1,355,191 / N=19,954), url (3,231,961 / 2,396,130), webspam
//! (16,609,143 / 350,000) and kdd2010 (29,890,095 / 19,264,097). Those
//! files are multi-gigabyte downloads that are unavailable in this
//! environment, so we substitute generators that preserve the properties
//! the paper's claims actually depend on (see DESIGN.md §5):
//!
//! * the **aspect ratio** `d/N` (drives the FD-SVRG vs instance-distributed
//!   communication comparison: FD wins iff `d > N`);
//! * **sparsity** (nonzeros per instance) with **power-law feature
//!   frequencies**, as in bag-of-words text data;
//! * **linear separability with label noise**, so logistic regression is
//!   the right model and the optimum is informative;
//! * unit-normalized instances, giving a clean smoothness constant
//!   `L ≤ 0.25·max‖x_i‖² + λ = 0.25 + λ`.
//!
//! The real files still load through [`crate::sparse::libsvm::read_file`]
//! if the user provides them.

pub mod profiles;

use crate::sparse::libsvm::Dataset;
use crate::sparse::CooBuilder;
use crate::util::Pcg64;

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct GenSpec {
    pub name: String,
    /// Number of features (rows of D).
    pub d: usize,
    /// Number of instances (columns of D).
    pub n: usize,
    /// Mean nonzeros per instance.
    pub nnz_per_instance: usize,
    /// Zipf exponent for feature frequency (≈1.1 for text).
    pub zipf_exponent: f64,
    /// Fraction of labels flipped after the linear rule.
    pub label_noise: f64,
    /// Fraction of features carrying true signal.
    pub signal_density: f64,
    pub seed: u64,
}

impl GenSpec {
    pub fn new(name: &str, d: usize, n: usize, nnz: usize) -> Self {
        GenSpec {
            name: name.to_string(),
            d,
            n,
            nnz_per_instance: nnz,
            zipf_exponent: 1.1,
            label_noise: 0.05,
            signal_density: 0.05,
            seed: 2018,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Generate a sparse, power-law, linearly-separable-with-noise dataset.
///
/// Instances are L2-normalized columns; labels come from a sparse ground
/// truth separator `w★` with `label_noise` flips. The returned labels are
/// in `{-1, +1}` and every instance has ≥1 nonzero.
pub fn generate(spec: &GenSpec) -> Dataset {
    let mut rng = Pcg64::seed_from_u64(spec.seed);
    // sparse ground-truth separator on the most frequent features so the
    // signal is actually observable through the power-law sampling
    let n_signal = ((spec.d as f64 * spec.signal_density) as usize).max(8).min(spec.d);
    let mut w_star = vec![0.0f64; spec.d];
    for ws in w_star.iter_mut().take(n_signal) {
        *ws = rng.normal();
    }

    let mut b = CooBuilder::new(spec.d, spec.n);
    let mut y = Vec::with_capacity(spec.n);
    let mut feat_scratch: Vec<usize> = Vec::new();
    for col in 0..spec.n {
        // draw distinct features via zipf with rejection
        feat_scratch.clear();
        let want = (spec.nnz_per_instance / 2
            + rng.below(spec.nnz_per_instance.max(1)))
        .clamp(1, spec.d);
        let mut guard = 0;
        while feat_scratch.len() < want && guard < want * 20 {
            let f = rng.zipf(spec.d, spec.zipf_exponent);
            if !feat_scratch.contains(&f) {
                feat_scratch.push(f);
            }
            guard += 1;
        }
        // tf-like positive values, then L2-normalize the instance
        let vals: Vec<f64> =
            feat_scratch.iter().map(|_| 1.0 + rng.next_f64().powi(2) * 3.0).collect();
        let norm = crate::linalg::dot(&vals, &vals).sqrt();
        let mut margin = 0.0;
        for (f, v) in feat_scratch.iter().zip(vals.iter()) {
            let v = v / norm;
            b.push(*f, col, v);
            margin += w_star[*f] * v;
        }
        let mut label = if margin >= 0.0 { 1.0 } else { -1.0 };
        if rng.next_f64() < spec.label_noise {
            label = -label;
        }
        y.push(label);
    }
    Dataset { name: spec.name.clone(), x: b.to_csc(), y }
}

/// Dataset summary row (the `fdsvrg data stats` command prints Table 1 for
/// the `-sim` profiles with these).
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub d: usize,
    pub n: usize,
    pub nnz: usize,
    pub nnz_per_instance: f64,
    pub aspect: f64,
    pub pos_fraction: f64,
}

pub fn stats(ds: &Dataset) -> Stats {
    Stats {
        name: ds.name.clone(),
        d: ds.d(),
        n: ds.n(),
        nnz: ds.nnz(),
        nnz_per_instance: ds.nnz() as f64 / ds.n() as f64,
        aspect: ds.d() as f64 / ds.n() as f64,
        pos_fraction: ds.y.iter().filter(|&&v| v > 0.0).count() as f64 / ds.n() as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> GenSpec {
        GenSpec::new("tiny", 500, 200, 20).with_seed(7)
    }

    #[test]
    fn generate_shapes() {
        let ds = generate(&tiny_spec());
        assert_eq!(ds.d(), 500);
        assert_eq!(ds.n(), 200);
        assert!(ds.nnz() > 0);
        for i in 0..ds.n() {
            assert!(ds.x.col_nnz(i) >= 1, "instance {i} empty");
        }
    }

    #[test]
    fn instances_unit_normalized() {
        let ds = generate(&tiny_spec());
        for i in 0..ds.n() {
            let nrm = ds.x.col_nrm2_sq(i);
            assert!((nrm - 1.0).abs() < 1e-9, "col {i} norm² {nrm}");
        }
    }

    #[test]
    fn labels_are_pm_one_and_balancedish() {
        let ds = generate(&tiny_spec());
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
        let s = stats(&ds);
        assert!(s.pos_fraction > 0.10 && s.pos_fraction < 0.90, "pos frac {}", s.pos_fraction);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&tiny_spec());
        let b = generate(&tiny_spec());
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = generate(&tiny_spec().with_seed(8));
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn power_law_feature_usage() {
        let ds = generate(&GenSpec::new("pl", 2000, 500, 40).with_seed(3));
        let csr = crate::sparse::CsrMatrix::from_csc(&ds.x);
        let head: usize = (0..20).map(|r| csr.row_nnz(r)).sum();
        let mid: usize = (1000..1020).map(|r| csr.row_nnz(r)).sum();
        assert!(head > mid * 3, "head {head} vs mid {mid}");
    }

    #[test]
    fn signal_is_learnable() {
        // a few epochs of plain SGD should beat chance accuracy easily
        let ds = generate(&tiny_spec());
        let mut w = vec![0.0f64; ds.d()];
        let loss = crate::loss::Logistic;
        use crate::loss::Loss;
        let mut rng = Pcg64::seed_from_u64(1);
        for _ in 0..5 * ds.n() {
            let i = rng.below(ds.n());
            let z = ds.x.col_dot(i, &w);
            let g = loss.derivative(z, ds.y[i]);
            ds.x.col_axpy(i, -0.5 * g, &mut w);
        }
        let correct = (0..ds.n())
            .filter(|&i| (ds.x.col_dot(i, &w) >= 0.0) == (ds.y[i] > 0.0))
            .count();
        let acc = correct as f64 / ds.n() as f64;
        assert!(acc > 0.7, "accuracy {acc}");
    }
}
