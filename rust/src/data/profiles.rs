//! Paper-matched dataset profiles (scaled — see DESIGN.md §5).
//!
//! | profile      | paper d     | paper N     | d/N   | sim d    | sim N  | sim d/N |
//! |--------------|-------------|-------------|-------|----------|--------|---------|
//! | news20-sim   | 1,355,191   | 19,954      | 67.9  | 200,000  | 3,000  | 66.7    |
//! | url-sim      | 3,231,961   | 2,396,130   | 1.35  | 40,000   | 30,000 | 1.33    |
//! | webspam-sim  | 16,609,143  | 350,000     | 47.5  | 280,000  | 6,000  | 46.7    |
//! | kdd2010-sim  | 29,890,095  | 19,264,097  | 1.55  | 46,000   | 30,000 | 1.53    |
//!
//! Scale factor ≈ 7–600× in d, chosen so the whole experiment suite runs
//! in minutes on one machine. The aspect ratio d/N — the quantity the
//! paper's communication analysis (§4.5) is parameterized by — matches the
//! original within 2%.

use super::{generate, GenSpec};
use crate::sparse::libsvm::Dataset;

/// Named profiles, matching the paper's Table 1 order.
pub const PROFILE_NAMES: [&str; 4] = ["news20-sim", "url-sim", "webspam-sim", "kdd2010-sim"];

/// Worker count the paper used for each dataset (§5.1: 8 for news20,
/// 16 elsewhere).
pub fn paper_worker_count(profile: &str) -> usize {
    if profile.starts_with("news20") {
        8
    } else {
        16
    }
}

/// Build the [`GenSpec`] for a named profile (also accepts `tiny`/`small`
/// used by tests and the quickstart, and `dense-xla` for the XLA engine
/// demo).
pub fn spec(profile: &str) -> Option<GenSpec> {
    let s = match profile {
        "news20-sim" => {
            let mut s = GenSpec::new("news20-sim", 200_000, 3_000, 150);
            s.seed = 0x2e20;
            s
        }
        "url-sim" => {
            let mut s = GenSpec::new("url-sim", 40_000, 30_000, 60);
            s.zipf_exponent = 0.9; // url features are less head-heavy
            s.seed = 0x0521;
            s
        }
        "webspam-sim" => {
            let mut s = GenSpec::new("webspam-sim", 280_000, 6_000, 220);
            s.seed = 0x3eb5;
            s
        }
        "kdd2010-sim" => {
            let mut s = GenSpec::new("kdd2010-sim", 46_000, 30_000, 25);
            s.seed = 0xdd10;
            s
        }
        "tiny" => GenSpec::new("tiny", 400, 160, 16).with_seed(11),
        "small" => GenSpec::new("small", 5_000, 800, 40).with_seed(12),
        "dense-xla" => {
            // small + dense enough that padding to the AOT block shapes is
            // cheap; used by the XLA-engine example and integration tests
            let mut s = GenSpec::new("dense-xla", 1_024, 512, 64);
            s.seed = 0xd73a;
            s
        }
        _ => return None,
    };
    Some(s)
}

/// Generate a profile dataset by name.
pub fn load(profile: &str) -> Option<Dataset> {
    spec(profile).map(|s| generate(&s))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_profiles_resolve() {
        for p in PROFILE_NAMES {
            assert!(spec(p).is_some(), "{p}");
        }
        assert!(spec("tiny").is_some());
        assert!(spec("nonexistent").is_none());
    }

    #[test]
    fn aspect_ratios_match_paper() {
        // (profile, paper aspect)
        for (p, paper) in
            [("news20-sim", 67.9), ("url-sim", 1.35), ("webspam-sim", 47.5), ("kdd2010-sim", 1.55)]
        {
            let s = spec(p).unwrap();
            let sim = s.d as f64 / s.n as f64;
            assert!(
                (sim / paper - 1.0).abs() < 0.05,
                "{p}: sim aspect {sim} vs paper {paper}"
            );
        }
    }

    #[test]
    fn d_exceeds_n_where_paper_says_so() {
        for p in ["news20-sim", "webspam-sim"] {
            let s = spec(p).unwrap();
            assert!(s.d > 10 * s.n, "{p} should be strongly d>N");
        }
        for p in ["url-sim", "kdd2010-sim"] {
            let s = spec(p).unwrap();
            assert!(s.d > s.n && s.d < 2 * s.n, "{p} should be mildly d>N");
        }
    }

    #[test]
    fn worker_counts_match_paper() {
        assert_eq!(paper_worker_count("news20-sim"), 8);
        assert_eq!(paper_worker_count("webspam-sim"), 16);
    }

    #[test]
    fn tiny_generates_quickly() {
        let ds = load("tiny").unwrap();
        assert_eq!(ds.d(), 400);
        assert_eq!(ds.n(), 160);
    }
}
