//! SynSVRG — synchronous distributed SVRG on the Parameter-Server framework
//! (paper Appendix B, Algorithms 3–4).
//!
//! `p` servers own key ranges of `w`; `q` workers own instance shards.
//! Every inner round moves **dense** `d`-vectors both ways (`w̃_m` down,
//! averaged stochastic gradients up), which is exactly the `O(N + d)`-scale
//! traffic the paper's §4.5 complexity comparison charges against PS-based
//! SVRG: per outer iteration `2qd` for the full gradient plus `2qd` per
//! inner round.

use super::ps::PsTopology;
use super::{Problem, RunParams, Workspace};
use crate::linalg;
use crate::metrics::RunResult;
use crate::net::{tags, Endpoint};
use crate::session::cluster::{
    collect_node_states, comm_snapshot, net_node_state, send_node_state, ClusterCtx,
    ClusterDriver, Directive, EpochGate,
};
use crate::session::{EpochReport, ResumeState};
use crate::sparse::partition::{by_instances, InstanceShard};
use crate::util::Pcg64;
use std::sync::Arc;

/// Run SynSVRG (the fire-and-forget path: one session driven to
/// completion).
pub fn run(problem: &Problem, params: &RunParams) -> RunResult {
    super::Algorithm::SynSvrg.run(problem, params)
}

/// Build the steppable SynSVRG driver: servers 0..p (server 0 is the
/// monitor), workers p..p+q. Server parameter blocks restore from the
/// checkpointed full `w` via the deterministic key ranges; worker RNG
/// streams restore from their checkpointed words.
pub(crate) fn driver(
    problem: &Problem,
    params: &RunParams,
    resume: Option<ResumeState>,
) -> anyhow::Result<ClusterDriver> {
    let q = params.q.max(1);
    let p = params.servers.max(1);
    let d = problem.d();
    let n = problem.n();
    let eta = params.effective_eta(problem);
    // paper §5.2: inner loops = instances per worker; each SynSVRG round
    // consumes one instance per worker in parallel
    let m_rounds = if params.m_inner == 0 { (n / q).max(1) } else { params.m_inner };
    let topo = PsTopology::new(p, q, d);
    let shards: Vec<InstanceShard> = by_instances(&problem.ds.x, q);
    for shard in &shards {
        shard.prewarm(params.threads);
    }
    let shards: Arc<Vec<InstanceShard>> = Arc::new(shards);
    let y: Arc<Vec<f64>> = Arc::new(problem.ds.y.clone());
    let dataset = problem.ds.name.clone();
    let model = params.net_model();
    let problem = problem.clone();
    let params = params.clone();

    let node_fn = Arc::new(move |mut ep: Endpoint, cx: &ClusterCtx| {
        if topo.is_server(ep.id()) {
            let gate = if ep.id() == 0 { Some(cx.take_gate()) } else { None };
            server(&mut ep, &problem, &params, topo, eta, m_rounds, gate.as_ref(), cx);
        } else {
            worker(&mut ep, &problem, &params, topo, m_rounds, &shards, &y, cx);
        }
    });
    ClusterDriver::new("synsvrg", &dataset, topo.n_nodes(), d, model, resume, node_fn)
}

/// Server `k` (Algorithm 3). Server 0 additionally assembles evaluation
/// snapshots and runs the session gate.
#[allow(clippy::too_many_arguments)]
fn server(
    ep: &mut Endpoint,
    problem: &Problem,
    params: &RunParams,
    topo: PsTopology,
    eta: f64,
    m_rounds: usize,
    gate: Option<&EpochGate>,
    cx: &ClusterCtx,
) {
    let k = ep.id();
    let (lo, hi) = topo.key_range(k);
    let dk = hi - lo;
    let n = problem.n();
    let q = topo.q;
    let comm = params.comm();
    let lambda = problem.reg.lambda();
    let resume = cx.resume.as_deref();
    let mut w_k =
        resume.map(|r| r.w[lo..hi].to_vec()).unwrap_or_else(|| vec![0.0f64; dk]);
    let mut grads = resume.map(|r| r.grads).unwrap_or(0);
    let mut epoch = resume.map(|r| r.epoch).unwrap_or(0);
    let mut ws = Workspace::new(params.threads);

    loop {
        // full-gradient phase: fan w_t^(k) out to all workers (one
        // encode, Arc clones), sum their z_l^(k)
        comm.send_all(ep, (0..q).map(|l| topo.worker_node(l)), tags::BCAST, &w_k);
        Workspace::reset(&mut ws.zx, dk);
        for l in 0..q {
            let msg = ep.recv_from(topo.worker_node(l), tags::REDUCE);
            msg.add_into(&mut ws.zx);
        }
        linalg::scale(1.0 / n as f64, &mut ws.zx);
        grads += n as u64;

        // inner rounds (Algorithm 3 lines 7–12)
        for _ in 0..m_rounds {
            comm.send_all(ep, (0..q).map(|l| topo.worker_node(l)), tags::PULL_RESP, &w_k);
            Workspace::reset(&mut ws.grad, dk);
            for l in 0..q {
                let msg = ep.recv_from(topo.worker_node(l), tags::PUSH);
                msg.add_into(&mut ws.grad);
            }
            linalg::scale(1.0 / q as f64, &mut ws.grad);
            // w̃ ← w̃ − η(∇̄ + z + ∇g(w̃))
            for i in 0..dk {
                w_k[i] -= eta * (ws.grad[i] + ws.zx[i] + lambda * w_k[i]);
            }
            grads += q as u64;
        }

        // evaluation plane: monitor assembles w (into a fresh buffer whose
        // ownership moves into the report's Arc), reports the boundary
        epoch += 1;
        let stop = if let Some(gate) = gate {
            let mut full_w = vec![0.0f64; topo.d];
            full_w[lo..hi].copy_from_slice(&w_k);
            for s in 1..topo.p {
                let msg = ep.recv_eval_from(topo.server_node(s), tags::EVAL);
                let (slo, shi) = topo.key_range(s);
                msg.decode_into(&mut full_w[slo..shi]);
            }
            let sim_time = ep.now();
            let own = net_node_state(ep, None, vec![]);
            let nodes = collect_node_states(ep, 0, own, 1..topo.n_nodes(), topo.n_nodes());
            let (scalars, bytes, per_node) = comm_snapshot(ep);
            let directive = gate.exchange(EpochReport {
                epoch,
                w: Arc::new(full_w),
                grads,
                sim_time,
                scalars,
                bytes,
                comm: per_node,
                nodes,
            });
            let stop = directive == Directive::Stop;
            for node in 0..topo.n_nodes() {
                if node != 0 {
                    ep.send_eval(node, tags::CTRL, vec![if stop { 1.0 } else { 0.0 }]);
                }
            }
            stop
        } else {
            ep.send_eval(0, tags::EVAL, w_k.clone());
            let st = net_node_state(ep, None, vec![]);
            send_node_state(ep, 0, &st);
            let ctrl = ep.recv_eval_from(0, tags::CTRL);
            ctrl.value(0) != 0.0
        };
        if stop {
            break;
        }
    }
}

/// Worker `l` (Algorithm 4).
#[allow(clippy::too_many_arguments)]
fn worker(
    ep: &mut Endpoint,
    problem: &Problem,
    params: &RunParams,
    topo: PsTopology,
    m_rounds: usize,
    shards: &[InstanceShard],
    y: &[f64],
    cx: &ClusterCtx,
) {
    let l = ep.id() - topo.p;
    let shard = &shards[l];
    let n_local = shard.data.cols();
    let comm = params.comm();
    let loss = problem.build_loss();
    let mut rng = match cx.node_state(ep.id()) {
        Some(st) if cx.resume.is_some() => {
            Pcg64::from_state_words(st.rng.expect("synsvrg worker state carries the RNG"))
        }
        _ => Pcg64::seed_from_u64(params.seed ^ (0x517 + l as u64)),
    };
    let mut w_t = vec![0.0f64; topo.d];
    let mut w_m = vec![0.0f64; topo.d];
    let mut ws = Workspace::new(params.threads);
    // reusable sparse-gradient staging: only instance i's nonzero rows are
    // ever touched, so re-zeroing those O(nnz_i) slots after each send
    // restores the all-zero state without an O(d) pass
    let mut grad = vec![0.0f64; topo.d];

    loop {
        // assemble w_t from all servers
        for k in 0..topo.p {
            let (lo, hi) = topo.key_range(k);
            comm.recv_into(ep, topo.server_node(k), tags::BCAST, &mut w_t[lo..hi]);
        }
        // local loss-gradient sum, split to servers (Dᵀw and Dc on the
        // workspace pool — bit-exact at any --threads width)
        Workspace::reset(&mut ws.margins, n_local);
        shard.data.transpose_matvec_pool(&w_t, &mut ws.margins, &ws.pool);
        Workspace::reset(&mut ws.c0, n_local);
        for i in 0..n_local {
            ws.c0[i] = loss.derivative(ws.margins[i], y[shard.col_idx[i]]);
        }
        Workspace::reset(&mut ws.grad, topo.d);
        shard.data.matvec_accumulate_pool(&ws.c0, &mut ws.grad, &ws.pool);
        for k in 0..topo.p {
            let (lo, hi) = topo.key_range(k);
            comm.send(ep, topo.server_node(k), tags::REDUCE, &ws.grad[lo..hi]);
        }

        // inner rounds (Algorithm 4 lines 5–10)
        for _ in 0..m_rounds {
            for k in 0..topo.p {
                let (lo, hi) = topo.key_range(k);
                comm.recv_into(ep, topo.server_node(k), tags::PULL_RESP, &mut w_m[lo..hi]);
            }
            let i = rng.below(n_local);
            let yi = y[shard.col_idx[i]];
            let delta = loss.derivative(shard.data.col_dot(i, &w_m), yi)
                - loss.derivative(ws.margins[i], yi);
            shard.data.col_axpy(i, delta, &mut grad);
            for k in 0..topo.p {
                let (lo, hi) = topo.key_range(k);
                comm.send(ep, topo.server_node(k), tags::PUSH, &grad[lo..hi]);
            }
            for (r, _) in shard.data.col_iter(i) {
                grad[r as usize] = 0.0;
            }
        }

        let st = net_node_state(ep, Some(rng.state_words()), vec![]);
        send_node_state(ep, 0, &st);
        let ctrl = ep.recv_eval_from(0, tags::CTRL);
        if ctrl.value(0) != 0.0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, GenSpec};
    use crate::net::SimParams;

    fn tiny() -> Problem {
        let ds = generate(&GenSpec::new("t", 120, 64, 10).with_seed(29));
        Problem::logistic_l2(ds, 1e-2)
    }

    fn fast_params(q: usize, p: usize, outer: usize) -> RunParams {
        RunParams { q, servers: p, outer, sim: SimParams::free(), ..Default::default() }
    }

    #[test]
    fn converges_on_tiny_problem() {
        let p = tiny();
        let (_, f_opt) = crate::algs::serial::solve_optimum(&p, 40);
        let res = run(&p, &fast_params(4, 2, 30));
        let gap = res.final_objective() - f_opt;
        assert!(gap < 1e-3, "gap {gap:.3e}");
    }

    #[test]
    fn comm_counters_match_formula() {
        // per outer: full grad 2qd + M rounds × 2qd
        let p = tiny();
        let (q, srv, outer) = (4u64, 2u64, 2u64);
        let res = run(&p, &fast_params(q as usize, srv as usize, outer as usize));
        let d = p.d() as u64;
        let m = (p.n() as u64) / q;
        assert_eq!(res.total_scalars, outer * (2 * q * d + m * 2 * q * d));
    }

    #[test]
    fn single_server_works() {
        let p = tiny();
        let res = run(&p, &fast_params(3, 1, 3));
        assert!(res.final_objective().is_finite());
    }

    #[test]
    fn more_servers_reduce_per_server_load_not_volume() {
        let p = tiny();
        let r2 = run(&p, &fast_params(4, 2, 2));
        let r4 = run(&p, &fast_params(4, 4, 2));
        assert_eq!(r2.total_scalars, r4.total_scalars, "server count must not change volume");
        assert!(r4.busiest_node_scalars <= r2.busiest_node_scalars);
    }
}
