//! Non-distributed (serial) SVRG — paper Appendix A, Algorithm 2 — plus
//! serial SGD and the reference-optimum solver used for gap plots.
//!
//! The serial inner update is written in the *same algebraic form* as
//! FD-SVRG Algorithm 1 line 11:
//!
//! ```text
//! w̃_{m+1} = w̃_m − η( (φ'(w̃_mᵀx) − φ'(w̃_0ᵀx))·x + z_φ + ∇g(w̃_m) )
//! ```
//!
//! where `z_φ = (1/N) Σ φ'(w̃_0ᵀx_i)·x_i` is the *loss part* of the full
//! gradient. This equals textbook SVRG because the `∇g(w̃_0)` terms of
//! `∇f_i(w̃_m) − ∇f_i(w̃_0) + ∇f(w̃_0)` cancel. Keeping both codebases in
//! this form makes the FD-SVRG ≡ serial-SVRG equivalence exact (it is the
//! same floating-point computation, merely partitioned by feature blocks).

use super::Problem;
use crate::linalg;
use crate::metrics::{Trace, TracePoint};
use crate::util::pool::Pool;
use crate::util::time::Stopwatch;
use crate::util::Pcg64;

/// Which `w_{t+1}` rule to use (paper Algorithm 2, line 9–10).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SvrgOption {
    /// `w_{t+1} = w̃_M` — what FD-SVRG uses; convergence proved by Theorem 1.
    I,
    /// `w_{t+1} = w̃_m`, random `m` — the Johnson & Zhang analyzed variant.
    II,
}

/// Steppable serial-SVRG state: one [`svrg_epoch`] call per outer
/// iteration. This is the single copy of the epoch body; both the
/// [`svrg`] reference wrapper and the session layer's
/// [`crate::session::serial::SerialSvrgDriver`] drive it.
pub struct SvrgState {
    pub w: Vec<f64>,
    pub sample_rng: Pcg64,
    pub option_rng: Pcg64,
    margins: Vec<f64>,
    c0: Vec<f64>,
    z: Vec<f64>,
    w_snapshot_m: Vec<f64>,
    /// Compute pool for the full-gradient kernels (`Dᵀw`, `Dc`); width 1
    /// by default. The parallel kernels are bit-exact with the serial
    /// ones, so widening the pool never perturbs a trajectory.
    pool: Pool,
}

impl SvrgState {
    /// Fresh state at `w = 0` with the shared sampling-stream layout (one
    /// `below(n)` per inner step; option-II snapshot draws come from a
    /// separate stream so both options consume identical sampling
    /// sequences — shared with FD-SVRG, paper §4.3).
    pub fn fresh(problem: &Problem, seed: u64) -> SvrgState {
        SvrgState {
            w: vec![0.0f64; problem.d()],
            sample_rng: Pcg64::seed_from_u64(seed),
            option_rng: Pcg64::seed_from_u64(seed ^ 0x5eed_0011),
            margins: vec![0.0f64; problem.n()],
            c0: vec![0.0f64; problem.n()],
            z: vec![0.0f64; problem.d()],
            w_snapshot_m: Vec::new(),
            pool: Pool::serial(),
        }
    }

    /// Rebuild mid-run state from checkpointed `w` + RNG words.
    pub fn restore(
        problem: &Problem,
        w: Vec<f64>,
        sample_rng: [u64; 4],
        option_rng: [u64; 4],
    ) -> SvrgState {
        SvrgState {
            w,
            sample_rng: Pcg64::from_state_words(sample_rng),
            option_rng: Pcg64::from_state_words(option_rng),
            margins: vec![0.0f64; problem.n()],
            c0: vec![0.0f64; problem.n()],
            z: vec![0.0f64; problem.d()],
            w_snapshot_m: Vec::new(),
            pool: Pool::serial(),
        }
    }

    /// Widen the compute pool to `threads` (see [`super::RunParams::threads`]).
    pub fn with_threads(mut self, threads: usize) -> SvrgState {
        self.pool = Pool::new(threads);
        self
    }
}

/// One serial-SVRG outer iteration (full-gradient pass + `m_inner`
/// variance-reduced steps); returns the gradient evaluations consumed.
///
/// The arithmetic is kept operation-for-operation identical to the
/// FD-SVRG worker (store φ' undivided, scale by 1/N inside the scatter)
/// so the q=1 equivalence test can demand bitwise equality.
pub fn svrg_epoch(
    problem: &Problem,
    eta: f64,
    m_inner: usize,
    option: SvrgOption,
    st: &mut SvrgState,
) -> u64 {
    let n = problem.n();
    let loss = problem.build_loss();
    let x = &problem.ds.x;
    let y = &problem.ds.y;
    let m_inner = if m_inner == 0 { n } else { m_inner };
    let mut grads = 0u64;

    // full (loss-part) gradient at w_t: Dᵀw then D(c0/N), both through
    // the state's pool (bit-exact with the serial kernels at any width)
    x.transpose_matvec_pool(&st.w, &mut st.margins, &st.pool);
    for i in 0..n {
        st.c0[i] = loss.derivative(st.margins[i], y[i]);
    }
    st.z.iter_mut().for_each(|v| *v = 0.0);
    let inv_n = 1.0 / n as f64;
    x.matvec_accumulate_scaled_pool(&st.c0, inv_n, &mut st.z, &st.pool);
    grads += n as u64;

    // inner loop on w̃ (= w, updated in place)
    let snapshot_at = match option {
        SvrgOption::I => m_inner, // never triggers
        SvrgOption::II => 1 + st.option_rng.below(m_inner),
    };
    for m in 0..m_inner {
        let i = st.sample_rng.below(n);
        let zi = x.col_dot(i, &st.w);
        let delta = loss.derivative(zi, y[i]) - st.c0[i];
        // dense part: w̃ −= η (z + ∇g(w̃))
        match problem.reg {
            crate::loss::Regularizer::L2 { lambda } => {
                linalg::axpby(-eta, &st.z, 1.0 - eta * lambda, &mut st.w);
            }
            _ => {
                for (wi, zi) in st.w.iter_mut().zip(st.z.iter()) {
                    let g = problem.reg.grad_coord(*wi);
                    *wi -= eta * (*zi + g);
                }
            }
        }
        // sparse part: w̃ −= η Δφ x_i
        x.col_axpy(i, -eta * delta, &mut st.w);
        grads += 1;
        if m + 1 == snapshot_at {
            st.w_snapshot_m = st.w.clone();
        }
    }
    if option == SvrgOption::II {
        st.w = st.w_snapshot_m.clone();
    }
    grads
}

/// Serial SVRG. Returns final `w` and, when `snapshots` is non-null, pushes
/// a copy of `w_t` after every outer iteration (equivalence tests).
pub fn svrg(
    problem: &Problem,
    eta: f64,
    outer: usize,
    m_inner: usize,
    seed: u64,
    option: SvrgOption,
    mut snapshots: Option<&mut Vec<Vec<f64>>>,
) -> (Vec<f64>, Trace) {
    let mut st = SvrgState::fresh(problem, seed);
    let mut trace = Trace::default();
    let wall = Stopwatch::start();
    let mut grads = 0u64;
    trace.push(TracePoint {
        outer: 0,
        sim_time: 0.0,
        skew: 0.0,
        wall_time: 0.0,
        scalars: 0,
        bytes: 0,
        grads: 0,
        objective: problem.objective(&st.w),
    });

    for t in 0..outer {
        grads += svrg_epoch(problem, eta, m_inner, option, &mut st);
        let objective = problem.objective(&st.w);
        trace.push(TracePoint {
            outer: t + 1,
            sim_time: 0.0,
            skew: 0.0,
            wall_time: wall.seconds(),
            scalars: 0,
            bytes: 0,
            grads,
            objective,
        });
        if let Some(s) = snapshots.as_deref_mut() {
            s.push(st.w.clone());
        }
    }
    (st.w, trace)
}

/// Steppable serial-SGD state: one [`sgd_epoch`] call per epoch of `N`
/// sampled instances.
pub struct SgdState {
    pub w: Vec<f64>,
    pub rng: Pcg64,
    /// Global step counter (drives the `1/(1 + step·decay)` decay).
    pub step: u64,
}

impl SgdState {
    pub fn fresh(problem: &Problem, seed: u64) -> SgdState {
        SgdState { w: vec![0.0f64; problem.d()], rng: Pcg64::seed_from_u64(seed), step: 0 }
    }

    pub fn restore(w: Vec<f64>, rng: [u64; 4], step: u64) -> SgdState {
        SgdState { w, rng: Pcg64::from_state_words(rng), step }
    }
}

/// One serial-SGD epoch (`N` steps with `1/(1 + step·decay)` decay,
/// `decay=0` = fixed step); returns the gradient evaluations consumed.
pub fn sgd_epoch(problem: &Problem, eta0: f64, decay: f64, st: &mut SgdState) -> u64 {
    let n = problem.n();
    let loss = problem.build_loss();
    let x = &problem.ds.x;
    let y = &problem.ds.y;
    for _ in 0..n {
        let i = st.rng.below(n);
        let zi = x.col_dot(i, &st.w);
        let g = loss.derivative(zi, y[i]);
        let eta = eta0 / (1.0 + st.step as f64 * decay);
        match problem.reg {
            crate::loss::Regularizer::L2 { lambda } => {
                linalg::scale(1.0 - eta * lambda, &mut st.w);
            }
            _ => {
                for wi in st.w.iter_mut() {
                    let gr = problem.reg.grad_coord(*wi);
                    *wi -= eta * gr;
                }
            }
        }
        x.col_axpy(i, -eta * g, &mut st.w);
        st.step += 1;
    }
    n as u64
}

/// Serial SGD with `1/(1 + t·decay)` step decay (`decay=0` = fixed step).
pub fn sgd(
    problem: &Problem,
    eta0: f64,
    epochs: usize,
    decay: f64,
    seed: u64,
) -> (Vec<f64>, Trace) {
    let mut st = SgdState::fresh(problem, seed);
    let mut trace = Trace::default();
    let wall = Stopwatch::start();
    trace.push(TracePoint {
        outer: 0,
        sim_time: 0.0,
        skew: 0.0,
        wall_time: 0.0,
        scalars: 0,
        bytes: 0,
        grads: 0,
        objective: problem.objective(&st.w),
    });
    for t in 0..epochs {
        sgd_epoch(problem, eta0, decay, &mut st);
        trace.push(TracePoint {
            outer: t + 1,
            sim_time: 0.0,
            skew: 0.0,
            wall_time: wall.seconds(),
            scalars: 0,
            bytes: 0,
            grads: st.step,
            objective: problem.objective(&st.w),
        });
    }
    (st.w, trace)
}

/// Lazy-update serial SVRG for **L2-regularized** problems: algebraically
/// identical to [`svrg`] with Option I, but each inner step costs
/// `O(nnz(x_i))` instead of `O(d)`.
///
/// The dense part of the update, `w̃ ← (1−ηλ)w̃ − ηz`, is tracked in closed
/// form through the representation `w̃ = α·v + γ·z`:
///
/// ```text
/// α ← (1−ηλ)·α          γ ← (1−ηλ)·γ − η          v ← v − (ηΔ/α)·x_i
/// ```
///
/// and the needed margins come from `w̃ᵀx_i = α·(vᵀx_i) + γ·(zᵀx_i)` with
/// `zᵀx_i` precomputed once per outer loop. This is the §Perf optimization
/// of EXPERIMENTS.md; `lazy_matches_naive_svrg` pins the equivalence.
pub fn svrg_lazy(
    problem: &Problem,
    eta: f64,
    outer: usize,
    m_inner: usize,
    seed: u64,
) -> (Vec<f64>, Trace) {
    let lambda = match problem.reg {
        crate::loss::Regularizer::L2 { lambda } => lambda,
        _ => panic!("svrg_lazy requires an L2 regularizer"),
    };
    let d = problem.d();
    let n = problem.n();
    let loss = problem.build_loss();
    let x = &problem.ds.x;
    let y = &problem.ds.y;
    let m_inner = if m_inner == 0 { n } else { m_inner };
    let mut sample_rng = Pcg64::seed_from_u64(seed);

    let mut w = vec![0.0f64; d];
    let mut trace = Trace::default();
    let wall = Stopwatch::start();
    let mut grads = 0u64;
    trace.push(TracePoint {
        outer: 0,
        sim_time: 0.0,
        skew: 0.0,
        wall_time: 0.0,
        scalars: 0,
        bytes: 0,
        grads: 0,
        objective: problem.objective(&w),
    });

    let mut margins = vec![0.0f64; n];
    let mut zx = vec![0.0f64; n];
    let mut c0 = vec![0.0f64; n];
    let mut z = vec![0.0f64; d];
    let beta = 1.0 - eta * lambda;

    for t in 0..outer {
        x.transpose_matvec(&w, &mut margins);
        for i in 0..n {
            c0[i] = loss.derivative(margins[i], y[i]);
        }
        z.iter_mut().for_each(|v| *v = 0.0);
        let inv_n = 1.0 / n as f64;
        for i in 0..n {
            if c0[i] != 0.0 {
                x.col_axpy(i, c0[i] * inv_n, &mut z);
            }
        }
        grads += n as u64;
        // precompute zᵀx_i (one extra sparse pass, O(nnz))
        x.transpose_matvec(&z, &mut zx);

        // lazy representation w̃ = α·v + γ·z ; v aliases w (updated sparsely)
        let mut alpha = 1.0f64;
        let mut gamma = 0.0f64;
        for _ in 0..m_inner {
            let i = sample_rng.below(n);
            let vx = x.col_dot(i, &w);
            let zi = alpha * vx + gamma * zx[i];
            let delta = loss.derivative(zi, y[i]) - c0[i];
            alpha *= beta;
            gamma = beta * gamma - eta;
            // Renormalize (v ← α·v, α ← 1) BEFORE the division: at the old
            // 1e-150 threshold a large η could push −ηδ/α past f64::MAX to
            // ±inf before the guard fired, and at ηλ = 1 exactly (β = 0 ⇒
            // α = 0) the division is NaN however late the guard runs (see
            // the FD-SVRG lazy path, which shares this representation).
            if alpha < 1e-100 {
                linalg::scale(alpha, &mut w);
                alpha = 1.0;
            }
            x.col_axpy(i, -eta * delta / alpha, &mut w);
            grads += 1;
        }
        // materialize w = α·v + γ·z
        for j in 0..d {
            w[j] = alpha * w[j] + gamma * z[j];
        }

        trace.push(TracePoint {
            outer: t + 1,
            sim_time: 0.0,
            skew: 0.0,
            wall_time: wall.seconds(),
            scalars: 0,
            bytes: 0,
            grads,
            objective: problem.objective(&w),
        });
    }
    (w, trace)
}

/// Reference optimum: run lazy SVRG far past the experiment horizon and
/// return `(w*, f(w*))`. Converges linearly (Theorem 1), so 60–100 outer
/// epochs reach machine-precision neighborhoods on the experiment problems.
pub fn solve_optimum(problem: &Problem, outer: usize) -> (Vec<f64>, f64) {
    let eta = problem.default_eta();
    let (w, _) = if matches!(problem.reg, crate::loss::Regularizer::L2 { .. }) {
        svrg_lazy(problem, eta, outer, 2 * problem.n(), 0xF00D)
    } else {
        svrg(problem, eta, outer, 2 * problem.n(), 0xF00D, SvrgOption::I, None)
    };
    let f = problem.objective(&w);
    (w, f)
}

/// Disk cache for reference optima (`artifacts/optima/<name>.f64`): the
/// experiment drivers share one `w*` per (dataset, λ) pair. Format: raw
/// little-endian f64s, `[f_opt, w...]`.
pub fn cached_optimum(problem: &Problem, cache_dir: &std::path::Path, outer: usize) -> (Vec<f64>, f64) {
    let key = format!(
        "{}_{}_{:.0e}.f64",
        problem.ds.name,
        problem.loss.build().name(),
        problem.reg.lambda()
    );
    let path = cache_dir.join(key);
    if let Ok(bytes) = std::fs::read(&path) {
        if bytes.len() == 8 * (problem.d() + 1) {
            let vals: Vec<f64> = bytes
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            return (vals[1..].to_vec(), vals[0]);
        }
    }
    let (w, f) = solve_optimum(problem, outer);
    let mut bytes = Vec::with_capacity(8 * (w.len() + 1));
    bytes.extend_from_slice(&f.to_le_bytes());
    for v in &w {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::create_dir_all(cache_dir).ok();
    std::fs::write(&path, bytes).ok();
    (w, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, GenSpec};

    fn tiny() -> Problem {
        let ds = generate(&GenSpec::new("t", 120, 60, 8).with_seed(21));
        Problem::logistic_l2(ds, 1e-2)
    }

    #[test]
    fn svrg_decreases_objective() {
        let p = tiny();
        let f0 = p.objective(&vec![0.0; p.d()]);
        let (_, trace) = svrg(&p, p.default_eta(), 8, 0, 1, SvrgOption::I, None);
        let f_end = trace.last_objective().unwrap();
        assert!(f_end < f0 - 1e-3, "f0={f0} f_end={f_end}");
    }

    #[test]
    fn svrg_linear_convergence_toward_optimum() {
        let p = tiny();
        let (_, f_opt) = solve_optimum(&p, 40);
        let (_, trace) = svrg(&p, p.default_eta(), 20, 0, 1, SvrgOption::I, None);
        let g5 = trace.points[5].objective - f_opt;
        let g20 = trace.points[20].objective - f_opt;
        assert!(g20 < g5 * 0.01, "gap at 20 ({g20:.3e}) should crush gap at 5 ({g5:.3e})");
        assert!(g20 >= -1e-10, "objective below reference optimum: {g20:.3e}");
    }

    #[test]
    fn option_ii_also_converges() {
        // Option II returns a uniformly random inner iterate, so it carries
        // more per-epoch variance than Option I — test at a looser target.
        let p = tiny();
        let (_, f_opt) = solve_optimum(&p, 40);
        let (_, trace) = svrg(&p, p.default_eta(), 25, 0, 2, SvrgOption::II, None);
        let g = trace.last_objective().unwrap() - f_opt;
        assert!(g < 1e-3, "option II gap {g:.3e}");
    }

    #[test]
    fn theorem1_contraction_bound_holds() {
        // Theorem 1: E‖w̃_M − w*‖² ≤ (a^M + b/(1−a)) ‖w̃_0 − w*‖²,
        // a = 1 − μη + 2L²η², b = 2L²η². Check the *measured* per-epoch
        // contraction of ‖w_t − w*‖² stays below the bound (generously,
        // since we observe one sample path, not the expectation).
        let p = tiny();
        let (w_star, _) = solve_optimum(&p, 60);
        let mu = p.strong_convexity();
        let l = p.smoothness();
        let eta = 0.05 / l; // small enough that a^M + b/(1-a) < 1
        let m = 4 * p.n();
        let a = 1.0 - mu * eta + 2.0 * l * l * eta * eta;
        let b = 2.0 * l * l * eta * eta;
        let rho = a.powi(m as i32) + b / (1.0 - a);
        assert!(rho < 1.0, "test setup: rho={rho} must contract");
        let mut snaps = Vec::new();
        let (_, _) = svrg(&p, eta, 6, m, 3, SvrgOption::I, Some(&mut snaps));
        let d0 = {
            let zero = vec![0.0; p.d()];
            crate::linalg::dist2(&zero, &w_star).powi(2)
        };
        let mut prev = d0;
        for (t, w) in snaps.iter().enumerate() {
            let dist = crate::linalg::dist2(w, &w_star).powi(2);
            // single sample path: allow 3x slack over the expectation bound
            assert!(
                dist <= 3.0 * rho * prev + 1e-12,
                "epoch {t}: ‖w−w*‖²={dist:.3e} vs bound {:.3e}",
                rho * prev
            );
            prev = dist;
        }
    }

    #[test]
    fn sgd_converges_slower_than_svrg() {
        let p = tiny();
        let (_, f_opt) = solve_optimum(&p, 40);
        let epochs = 12;
        let (_, sgd_trace) = sgd(&p, 1.0, epochs, 1.0 / p.n() as f64, 1);
        let (_, svrg_trace) = svrg(&p, p.default_eta(), epochs, 0, 1, SvrgOption::I, None);
        let g_sgd = sgd_trace.last_objective().unwrap() - f_opt;
        let g_svrg = svrg_trace.last_objective().unwrap() - f_opt;
        assert!(
            g_svrg < g_sgd,
            "SVRG gap {g_svrg:.3e} should beat SGD gap {g_sgd:.3e} at equal epochs"
        );
    }

    #[test]
    fn snapshots_are_one_per_outer() {
        let p = tiny();
        let mut snaps = Vec::new();
        let _ = svrg(&p, p.default_eta(), 5, 0, 1, SvrgOption::I, Some(&mut snaps));
        assert_eq!(snaps.len(), 5);
    }

    #[test]
    fn lazy_matches_naive_svrg() {
        let p = tiny();
        let eta = p.default_eta();
        let (w_naive, _) = svrg(&p, eta, 5, 0, 7, SvrgOption::I, None);
        let (w_lazy, _) = svrg_lazy(&p, eta, 5, 0, 7);
        let dist = crate::linalg::dist2(&w_naive, &w_lazy);
        assert!(dist < 1e-9, "lazy vs naive distance {dist:.3e}");
    }

    #[test]
    fn cached_optimum_round_trips() {
        let p = tiny();
        let dir = std::env::temp_dir().join("fdsvrg_optima_test");
        std::fs::remove_dir_all(&dir).ok();
        let (w1, f1) = cached_optimum(&p, &dir, 30);
        let (w2, f2) = cached_optimum(&p, &dir, 30); // second call hits disk
        assert_eq!(f1, f2);
        assert_eq!(w1, w2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn deterministic_given_seed() {
        let p = tiny();
        let (w1, _) = svrg(&p, 0.1, 3, 0, 9, SvrgOption::I, None);
        let (w2, _) = svrg(&p, 0.1, 3, 0, 9, SvrgOption::I, None);
        assert_eq!(w1, w2);
        let (w3, _) = svrg(&p, 0.1, 3, 0, 10, SvrgOption::I, None);
        assert_ne!(w1, w3);
    }
}
