//! D-PSGD (Lian et al., 2017) — the decentralized-parallel-SGD baseline
//! the paper's §3.2 discusses: no central node, every worker holds a full
//! copy of `w` and an instance shard, and each iteration
//!
//! 1. averages its parameter with its ring neighbours
//!    (`w_i ← (w_{i−1} + w_i + w_{i+1})/3`, the uniform-ring mixing
//!    matrix), then
//! 2. takes a local stochastic gradient step.
//!
//! The point the paper makes — and this implementation's counters show —
//! is that decentralization balances load but still moves **dense
//! d-vectors** every iteration (`2qd` scalars per round), so on `d > N`
//! data it loses to FD-SVRG's scalar-only traffic by orders of magnitude.
//!
//! Node layout: `q` workers, no coordinator. Per outer iteration each
//! worker runs `M = ⌈m_inner/q⌉` rounds (one round = one mixing exchange +
//! one mini-batch gradient step), so an epoch touches ~`m_inner` samples
//! across the cluster like the other baselines. The trace evaluates the
//! *consensus average* `w̄ = (1/q) Σ w_i`, the quantity D-PSGD's analysis
//! bounds.

use super::{Problem, RunParams};
use crate::linalg;
use crate::metrics::RunResult;
use crate::net::{tags, Endpoint};
use crate::session::cluster::{
    collect_node_states, comm_snapshot, net_node_state, send_node_state, ClusterCtx,
    ClusterDriver, Directive, EpochGate,
};
use crate::session::{EpochReport, ResumeState};
use crate::sparse::partition::{by_instances, InstanceShard};
use crate::util::Pcg64;
use std::sync::Arc;

/// Step decay matching [`super::fdsgd`]: `η_t = η₀ / (1 + 0.1·t)`.
const DECAY: f64 = 0.1;

/// Run D-PSGD (the fire-and-forget path: one session driven to completion).
pub fn run(problem: &Problem, params: &RunParams) -> RunResult {
    super::Algorithm::DPsgd.run(problem, params)
}

/// Build the steppable D-PSGD driver: `q` ring workers, no coordinator;
/// node 0 doubles as the session monitor and reports the *consensus
/// average* `w̄` (the quantity D-PSGD's analysis bounds). Every node's
/// full local parameter copy rides in its resume `extra`, so a restored
/// ring continues bit-exactly.
pub(crate) fn driver(
    problem: &Problem,
    params: &RunParams,
    resume: Option<ResumeState>,
) -> anyhow::Result<ClusterDriver> {
    let q = params.q.max(2); // a ring needs at least 2 nodes
    let d = problem.d();
    let n = problem.n();
    let eta0 = params.effective_eta(problem);
    let m_inner = if params.m_inner == 0 { n } else { params.m_inner };
    let rounds = m_inner.div_ceil(q);
    let shards: Arc<Vec<InstanceShard>> = Arc::new(by_instances(&problem.ds.x, q));
    let y: Arc<Vec<f64>> = Arc::new(problem.ds.y.clone());
    let dataset = problem.ds.name.clone();
    let model = params.net_model();
    let problem = problem.clone();
    let params = params.clone();

    let node_fn = Arc::new(move |mut ep: Endpoint, cx: &ClusterCtx| {
        let gate = if ep.id() == 0 { Some(cx.take_gate()) } else { None };
        worker(&mut ep, &problem, &params, q, d, eta0, rounds, &shards, &y, gate.as_ref(), cx);
    });
    ClusterDriver::new("dpsgd", &dataset, q, d, model, resume, node_fn)
}

#[allow(clippy::too_many_arguments)]
fn worker(
    ep: &mut Endpoint,
    problem: &Problem,
    params: &RunParams,
    q: usize,
    d: usize,
    eta0: f64,
    rounds: usize,
    shards: &[InstanceShard],
    y: &[f64],
    gate: Option<&EpochGate>,
    cx: &ClusterCtx,
) {
    let id = ep.id();
    let next = (id + 1) % q;
    let prev = (id + q - 1) % q;
    let shard = &shards[id];
    let local_n = shard.data.cols();
    let comm = params.comm();
    let loss = problem.build_loss();
    let (mut w, mut rng, mut t, mut grads) =
        match (cx.resume.as_deref(), cx.node_state(id)) {
            (Some(r), Some(st)) => {
                assert_eq!(st.extra.len(), d, "dpsgd node extra = local parameter copy");
                (
                    st.extra.clone(),
                    Pcg64::from_state_words(st.rng.expect("dpsgd node state carries the RNG")),
                    r.epoch,
                    // the leader reports grads × q (all workers step in
                    // parallel); recover the per-node count
                    r.grads / q as u64,
                )
            }
            _ => (
                vec![0.0f64; d],
                Pcg64::seed_from_u64(params.seed ^ (id as u64).wrapping_mul(0x9E37)),
                0usize,
                0u64,
            ),
        };
    // reusable decode buffers for the ring exchange (no per-round allocs)
    let mut wp = vec![0.0f64; d];
    let mut wn = vec![0.0f64; d];

    loop {
        let eta = eta0 / (1.0 + DECAY * t as f64);
        for _ in 0..rounds {
            // 1. ring mixing: exchange dense w with both neighbours —
            //    one encode, two Arc sends (send both first; channels are
            //    buffered, no deadlock)
            comm.send_all(ep, [next, prev], tags::RING, &w);
            ep.recv_from(prev, tags::RING).decode_into(&mut wp);
            ep.recv_from(next, tags::RING).decode_into(&mut wn);
            for ((wi, a), b) in w.iter_mut().zip(wp.iter()).zip(wn.iter()) {
                *wi = (*wi + a + b) / 3.0;
            }
            // 2. local stochastic gradient step on the shard
            if local_n > 0 {
                let j = rng.below(local_n);
                let gi = shard.col_idx[j];
                let z = shard.data.col_dot(j, &w);
                let c = loss.derivative(z, y[gi]);
                match problem.reg {
                    crate::loss::Regularizer::L2 { lambda } if lambda != 0.0 => {
                        linalg::scale(1.0 - eta * lambda, &mut w);
                    }
                    _ => {
                        for wi in w.iter_mut() {
                            *wi -= eta * problem.reg.grad_coord(*wi);
                        }
                    }
                }
                shard.data.col_axpy(j, -eta * c, &mut w);
                grads += 1;
            }
        }

        // evaluation plane: leader gathers everyone's w, reports consensus
        t += 1;
        if let Some(gate) = gate {
            let mut avg = w.clone();
            for peer in 1..q {
                let msg = ep.recv_eval_from(peer, tags::EVAL);
                msg.add_into(&mut avg);
            }
            let inv_q = 1.0 / q as f64;
            avg.iter_mut().for_each(|v| *v *= inv_q);
            let sim_time = ep.now();
            let own = net_node_state(ep, Some(rng.state_words()), w.clone());
            let nodes = collect_node_states(ep, 0, own, 1..q, q);
            let (scalars, bytes, per_node) = comm_snapshot(ep);
            let directive = gate.exchange(EpochReport {
                epoch: t,
                w: Arc::new(avg),
                grads: grads * q as u64, // all workers step in parallel
                sim_time,
                scalars,
                bytes,
                comm: per_node,
                nodes,
            });
            let stop = directive == Directive::Stop;
            for peer in 1..q {
                ep.send_eval(peer, tags::CTRL, vec![if stop { 1.0 } else { 0.0 }]);
            }
            if stop {
                return;
            }
        } else {
            ep.send_eval(0, tags::EVAL, w.clone());
            let st = net_node_state(ep, Some(rng.state_words()), w.clone());
            send_node_state(ep, 0, &st);
            let ctrl = ep.recv_eval_from(0, tags::CTRL);
            if ctrl.value(0) != 0.0 {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, GenSpec};
    use crate::net::SimParams;

    fn tiny() -> Problem {
        let ds = generate(&GenSpec::new("t", 150, 60, 10).with_seed(17));
        Problem::logistic_l2(ds, 1e-2)
    }

    fn fast_params(q: usize, outer: usize) -> RunParams {
        RunParams { q, outer, sim: SimParams::free(), ..Default::default() }
    }

    #[test]
    fn converges_on_tiny_problem() {
        let p = tiny();
        let res = run(&p, &fast_params(4, 20));
        let f0 = p.objective(&vec![0.0; p.d()]);
        assert!(res.final_objective() < f0 - 1e-2, "obj {}", res.final_objective());
    }

    #[test]
    fn traffic_is_dense_vectors_per_round() {
        // each round every worker ships w to both neighbours: 2qd per round
        let p = tiny();
        let q = 4;
        let outer = 2;
        let res = run(&p, &fast_params(q, outer));
        let rounds_per_epoch = p.n().div_ceil(q);
        let expect = (outer * rounds_per_epoch * 2 * q * p.d()) as u64;
        assert_eq!(res.total_scalars, expect);
    }

    #[test]
    fn loses_to_fdsvrg_on_comm_when_d_gt_n() {
        let p = tiny(); // d=150 > N=60
        let dp = run(&p, &fast_params(4, 2)).total_scalars;
        let fd = crate::algs::fdsvrg::run(&p, &fast_params(4, 2)).total_scalars;
        assert!(
            fd * 10 < dp,
            "FD-SVRG {fd} scalars must be ≪ D-PSGD {dp} on d>N"
        );
    }

    #[test]
    fn load_is_balanced_no_hub() {
        let p = tiny();
        let res = run(&p, &fast_params(4, 2));
        // decentralized: the busiest node carries ~1/q of total (±ring edge)
        let per_node = res.total_scalars / 4;
        assert!(
            res.busiest_node_scalars < per_node + per_node / 2,
            "busiest {} vs per-node {per_node}",
            res.busiest_node_scalars
        );
    }

    #[test]
    fn ring_of_two_works() {
        let p = tiny();
        let res = run(&p, &fast_params(2, 2));
        assert!(res.final_objective().is_finite());
    }
}
