//! **FD-SVRG** — the paper's contribution (Algorithm 1 + Fig. 4/5).
//!
//! Layout: node 0 is the coordinator, nodes 1..=q are workers. The data
//! matrix is partitioned **by features** into row slabs `D^(l) ∈ R^{d_l×N}`
//! (`sparse::partition::by_features`); worker `l` owns `D^(l)` and the
//! matching parameter slab `w^(l)`. The full parameter vector never travels:
//! the only counted traffic is
//!
//! * one allreduce of the N-vector of partial products `w^(l)ᵀD^(l)` per
//!   outer iteration (full-gradient phase, Alg. 1 lines 3–4): `2qN` scalars;
//! * one allreduce of `u` scalars per inner mini-batch (lines 9–10):
//!   `2q` scalars per sampled instance, `M·2q` per outer iteration.
//!
//! Both use the Fig.-5 binomial tree rooted at the coordinator
//! ([`crate::net::collectives`], reached through the run's
//! [`crate::net::collectives::Comm`] handle so the payloads go through the
//! wire codec), so the counters reproduce the §4.5 accounting *exactly* —
//! `comm_counters_match_paper_formula` below pins this.
//!
//! All workers draw the sampled index `i_m` from the same seeded PRNG
//! stream, which makes the distributed update *exactly* the serial SVRG
//! update (paper §4.3): bit-identical at q=1, and identical up to the FP
//! reassociation of the cross-block margin sum `Σ_l w^(l)ᵀx^(l)` for q>1
//! (parameter blocks are disjoint, so no other source of drift exists) —
//! see `rust/tests/equivalence.rs`.

use super::{Problem, RunParams, Workspace};
use crate::linalg;
use crate::metrics::RunResult;
use crate::net::{tags, Endpoint, NodeId};
use crate::session::cluster::{
    collect_node_states, comm_snapshot, net_node_state, send_node_state, ClusterCtx,
    ClusterDriver, Directive, EpochGate,
};
use crate::session::{EpochReport, ResumeState};
use crate::sparse::partition::{by_features, by_features_rows, FeatureSlab};
use crate::util::Pcg64;
use std::sync::Arc;

/// Run FD-SVRG on a simulated cluster of `params.q` workers + coordinator
/// (the fire-and-forget path: one session driven to completion).
pub fn run(problem: &Problem, params: &RunParams) -> RunResult {
    super::Algorithm::FdSvrg.run(problem, params)
}

/// Build the steppable FD-SVRG driver: node 0 is the coordinator (and the
/// session's monitor), nodes 1..=q are workers.
pub(crate) fn driver(
    problem: &Problem,
    params: &RunParams,
    resume: Option<ResumeState>,
) -> anyhow::Result<ClusterDriver> {
    let q = params.q.max(1);
    let n = problem.n();
    let d = problem.d();
    let eta = params.effective_eta(problem);
    let m_inner = if params.m_inner == 0 { n } else { params.m_inner };
    let u = params.batch.max(1);
    // Partition to balance the inner loop's dominant cost: the lazy path
    // does O(nnz) work per step (nnz-balanced cut); the naive path does
    // O(d_l) dense work per step (row-balanced cut) — see by_features_rows.
    let slabs: Vec<FeatureSlab> = if params.lazy {
        by_features(&problem.ds.x, q)
    } else {
        by_features_rows(&problem.ds.x, q)
    };
    // multi-threaded runs build the CSR mirrors once here, outside every
    // node's simulated clock and ahead of the first timed epoch; the simd
    // Dc kernel rides the mirror at every thread count, so --simd forces
    // the build even single-threaded
    for slab in &slabs {
        slab.prewarm(params.threads);
        if params.simd {
            slab.data.ensure_mirror();
        }
    }
    let slabs: Arc<Vec<FeatureSlab>> = Arc::new(slabs);
    let y: Arc<Vec<f64>> = Arc::new(problem.ds.y.clone());
    let group: Vec<NodeId> = (0..=q).collect();
    let dataset = problem.ds.name.clone();
    let model = params.net_model();
    let problem = problem.clone();
    let params = params.clone();

    let node_fn = Arc::new(move |mut ep: Endpoint, cx: &ClusterCtx| {
        if ep.id() == 0 {
            let gate = cx.take_gate();
            coordinator(&mut ep, &params, &group, n, m_inner, u, &slabs, &gate, cx);
        } else {
            worker(&mut ep, &problem, &params, &group, eta, m_inner, u, &slabs, &y, cx);
        }
    });
    ClusterDriver::new("fdsvrg", &dataset, q + 1, d, model, resume, node_fn)
}

#[allow(clippy::too_many_arguments)]
fn coordinator(
    ep: &mut Endpoint,
    params: &RunParams,
    group: &[NodeId],
    n: usize,
    m_inner: usize,
    u: usize,
    slabs: &[FeatureSlab],
    gate: &EpochGate,
    cx: &ClusterCtx,
) {
    let q = group.len() - 1;
    let comm = params.comm();
    let resume = cx.resume.as_deref();
    let mut grads = resume.map(|r| r.grads).unwrap_or(0);
    let mut epoch = resume.map(|r| r.epoch).unwrap_or(0);
    let d_total = slabs.last().unwrap().row_hi;
    let mut ws = Workspace::new(params.threads);

    loop {
        // --- full-gradient phase: allreduce of partial products (root) ---
        comm.allreduce(ep, group, Workspace::reset(&mut ws.margins, n));
        grads += n as u64;

        // --- inner loop: one scalar-batch allreduce per mini-batch ---
        let mut m = 0usize;
        while m < m_inner {
            let b = u.min(m_inner - m);
            comm.allreduce(ep, group, Workspace::reset(&mut ws.partial, b));
            grads += b as u64;
            m += b;
        }

        // --- evaluation plane: collect w slabs + worker states, report ---
        // assembled into a fresh buffer whose ownership moves into the
        // report's Arc — the session and resume state share it, no clone
        let mut w = vec![0.0f64; d_total];
        for (l, slab) in slabs.iter().enumerate() {
            let msg = ep.recv_eval_from(l + 1, tags::EVAL);
            msg.decode_into(&mut w[slab.row_lo..slab.row_hi]);
        }
        let sim_time = ep.now();
        let own = net_node_state(ep, None, vec![]);
        let nodes = collect_node_states(ep, 0, own, 1..=q, q + 1);
        let (scalars, bytes, per_node) = comm_snapshot(ep);
        epoch += 1;
        let directive = gate.exchange(EpochReport {
            epoch,
            w: Arc::new(w),
            grads,
            sim_time,
            scalars,
            bytes,
            comm: per_node,
            nodes,
        });
        let stop = directive == Directive::Stop;
        for l in 1..=q {
            ep.send_eval(l, tags::CTRL, vec![if stop { 1.0 } else { 0.0 }]);
        }
        if stop {
            break;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker(
    ep: &mut Endpoint,
    problem: &Problem,
    params: &RunParams,
    group: &[NodeId],
    eta: f64,
    m_inner: usize,
    u: usize,
    slabs: &[FeatureSlab],
    y: &[f64],
    cx: &ClusterCtx,
) {
    let l = ep.id() - 1;
    let slab = &slabs[l];
    let dl = slab.dim();
    let n = problem.n();
    let comm = params.comm();
    let loss = problem.build_loss();
    let lambda = match problem.reg {
        crate::loss::Regularizer::L2 { lambda } => lambda,
        _ => 0.0,
    };
    let use_l2_fast_path = matches!(problem.reg, crate::loss::Regularizer::L2 { .. });

    // worker state: parameter slab + reusable buffers; on resume the slab
    // comes out of the checkpointed full `w` (exact bits — the eval plane
    // ships uncompressed f64) and the sampling stream continues from its
    // checkpointed words.
    let (mut w_l, mut sample_rng) = match (cx.resume.as_deref(), cx.node_state(ep.id())) {
        (Some(r), Some(st)) => (
            r.w[slab.row_lo..slab.row_hi].to_vec(),
            Pcg64::from_state_words(st.rng.expect("fdsvrg worker state carries the sampling RNG")),
        ),
        _ => (vec![0.0f64; dl], Pcg64::seed_from_u64(params.seed)),
    };
    let mut z_l = vec![0.0f64; dl];
    let mut ws = Workspace::new(params.threads);
    let mut batch_idx: Vec<usize> = Vec::with_capacity(u);
    // shared sampling stream — identical on every worker (paper §4.3:
    // "make the parameter identical for different machines")

    // --simd swaps every reduction kernel for its multi-lane variant
    // (tolerance vs the pinned serial chain — see tests/kernel_exactness.rs);
    // col_axpy scatters have no accumulator chain and stay as-is
    let simd = params.simd;

    loop {
        // --- full gradient phase (Alg. 1 lines 3–5): both sparse kernels
        // run on the workspace pool, bit-exact at any --threads width ---
        Workspace::reset(&mut ws.margins, n);
        if simd {
            slab.data.transpose_matvec_pool_simd(&w_l, &mut ws.margins, &ws.pool);
        } else {
            slab.data.transpose_matvec_pool(&w_l, &mut ws.margins, &ws.pool);
        }
        comm.allreduce(ep, group, &mut ws.margins);
        Workspace::reset(&mut ws.c0, n);
        for i in 0..n {
            ws.c0[i] = loss.derivative(ws.margins[i], y[i]);
        }
        z_l.iter_mut().for_each(|v| *v = 0.0);
        let inv_n = 1.0 / n as f64;
        if simd {
            slab.data.matvec_accumulate_scaled_pool_simd(&ws.c0, inv_n, &mut z_l, &ws.pool);
        } else {
            slab.data.matvec_accumulate_scaled_pool(&ws.c0, inv_n, &mut z_l, &ws.pool);
        }

        // --- inner loop (Alg. 1 lines 7–12) ---
        if params.lazy && use_l2_fast_path {
            // §Perf lazy path: w̃ = α·v + γ·z with v aliasing w_l updated
            // sparsely; per-step cost drops from O(d_l) to O(nnz_l(i)).
            // Partial margins come from α·(vᵀx) + γ·(zᵀx) with zᵀx
            // precomputed once per outer iteration (one O(nnz_l) pass).
            Workspace::reset(&mut ws.zx, n);
            if simd {
                slab.data.transpose_matvec_pool_simd(&z_l, &mut ws.zx, &ws.pool);
            } else {
                slab.data.transpose_matvec_pool(&z_l, &mut ws.zx, &ws.pool);
            }
            let beta = 1.0 - eta * lambda;
            let mut alpha = 1.0f64;
            let mut gamma = 0.0f64;
            let mut m = 0usize;
            while m < m_inner {
                let b = u.min(m_inner - m);
                batch_idx.clear();
                for _ in 0..b {
                    batch_idx.push(sample_rng.below(n));
                }
                Workspace::reset(&mut ws.partial, b);
                for (k, &i) in batch_idx.iter().enumerate() {
                    let wx = if simd {
                        slab.data.col_dot_simd(i, &w_l)
                    } else {
                        slab.data.col_dot(i, &w_l)
                    };
                    ws.partial[k] = alpha * wx + gamma * ws.zx[i];
                }
                comm.allreduce(ep, group, &mut ws.partial);
                for (k, &i) in batch_idx.iter().enumerate() {
                    let delta = loss.derivative(ws.partial[k], y[i]) - ws.c0[i];
                    alpha *= beta;
                    gamma = beta * gamma - eta;
                    // Renormalize (v ← α·v, α ← 1; preserves w̃ = α·v + γ·z)
                    // per *step* and BEFORE the division. The old per-batch
                    // guard at 1e-150 let −ηδ/α overflow to ±inf mid-batch
                    // under an aggressive η·λ, and at ηλ = 1 exactly
                    // (β = 0 ⇒ α = 0) the division is NaN however late the
                    // guard fires — folding the renorm in first makes even
                    // that boundary exact: v ← 0, then v ← −ηδ·x.
                    if alpha < 1e-100 {
                        linalg::scale(alpha, &mut w_l);
                        alpha = 1.0;
                    }
                    slab.data.col_axpy(i, -eta * delta / alpha, &mut w_l);
                }
                m += b;
            }
            // materialize w̃ = α·v + γ·z
            for (wi, zi) in w_l.iter_mut().zip(z_l.iter()) {
                *wi = alpha * *wi + gamma * zi;
            }
        } else {
            let mut m = 0usize;
            while m < m_inner {
                let b = u.min(m_inner - m);
                batch_idx.clear();
                for _ in 0..b {
                    batch_idx.push(sample_rng.below(n));
                }
                // u partial inner products, communicated together (§4.4.1)
                Workspace::reset(&mut ws.partial, b);
                for (k, &i) in batch_idx.iter().enumerate() {
                    ws.partial[k] = if simd {
                        slab.data.col_dot_simd(i, &w_l)
                    } else {
                        slab.data.col_dot(i, &w_l)
                    };
                }
                comm.allreduce(ep, group, &mut ws.partial);
                // apply the b variance-reduced updates (line 11), each using
                // the margin taken before this batch's updates
                for (k, &i) in batch_idx.iter().enumerate() {
                    let delta = loss.derivative(ws.partial[k], y[i]) - ws.c0[i];
                    if use_l2_fast_path {
                        linalg::axpby(-eta, &z_l, 1.0 - eta * lambda, &mut w_l);
                    } else {
                        for (wi, zi) in w_l.iter_mut().zip(z_l.iter()) {
                            let g = problem.reg.grad_coord(*wi);
                            *wi -= eta * (*zi + g);
                        }
                    }
                    slab.data.col_axpy(i, -eta * delta, &mut w_l);
                }
                m += b;
            }
        }

        // --- evaluation plane: ship the slab + session state, await
        // continue/stop ---
        ep.send_eval(0, tags::EVAL, w_l.clone());
        let st = net_node_state(ep, Some(sample_rng.state_words()), vec![]);
        send_node_state(ep, 0, &st);
        let ctrl = ep.recv_eval_from(0, tags::CTRL);
        if ctrl.value(0) != 0.0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, GenSpec};
    use crate::net::SimParams;

    fn tiny() -> Problem {
        let ds = generate(&GenSpec::new("t", 150, 60, 10).with_seed(17));
        Problem::logistic_l2(ds, 1e-2)
    }

    fn fast_params(q: usize, outer: usize) -> RunParams {
        RunParams { q, outer, sim: SimParams::free(), ..Default::default() }
    }

    #[test]
    fn converges_on_tiny_problem() {
        let p = tiny();
        let res = run(&p, &fast_params(4, 10));
        let f0 = p.objective(&vec![0.0; p.d()]);
        assert!(res.final_objective() < f0 - 1e-2);
    }

    #[test]
    fn comm_counters_match_paper_formula() {
        // per outer iteration: allreduce of N scalars (2qN) + M allreduces
        // of 1 scalar (2qM); with M = N (default) => 4qN per epoch.
        let p = tiny();
        let q = 4;
        let outer = 3;
        let res = run(&p, &fast_params(q, outer));
        let n = p.n() as u64;
        let expect = outer as u64 * (2 * q as u64 * n + 2 * q as u64 * n);
        assert_eq!(res.total_scalars, expect);
    }

    #[test]
    fn minibatch_same_scalars_fewer_messages() {
        let p = tiny();
        let mut a = fast_params(4, 2);
        a.batch = 1;
        let mut b = fast_params(4, 2);
        b.batch = 8;
        let ra = run(&p, &a);
        let rb = run(&p, &b);
        assert_eq!(ra.total_scalars, rb.total_scalars, "batching must not change volume");
    }

    #[test]
    fn star_ablation_same_result_same_volume() {
        let p = tiny();
        let mut params = fast_params(4, 3);
        let r_tree = run(&p, &params);
        params.star_reduce = true;
        let r_star = run(&p, &params);
        assert_eq!(r_tree.total_scalars, r_star.total_scalars);
        // identical numerics: same sampling stream, same arithmetic
        assert!(crate::linalg::dist2(&r_tree.w, &r_star.w) < 1e-12);
        // but the tree spreads load off the hub
        assert!(r_star.busiest_node_scalars >= r_tree.busiest_node_scalars);
    }

    #[test]
    fn q1_matches_serial_exactly() {
        let p = tiny();
        let params = fast_params(1, 4);
        let res = run(&p, &params);
        let (w_serial, _) = crate::algs::serial::svrg(
            &p,
            params.effective_eta(&p),
            4,
            0,
            params.seed,
            crate::algs::serial::SvrgOption::I,
            None,
        );
        assert!(
            crate::linalg::dist2(&res.w, &w_serial) < 1e-12,
            "q=1 FD-SVRG must equal serial SVRG bit-for-bit"
        );
    }

    #[test]
    fn gap_stop_halts_early() {
        let p = tiny();
        let f_opt = crate::algs::serial::solve_optimum(&p, 30).1;
        let mut params = fast_params(4, 50);
        params.gap_stop = Some((f_opt, 1e-3));
        let res = run(&p, &params);
        assert!(res.trace.points.len() < 50, "should stop well before 50 epochs");
        assert!(res.final_objective() - f_opt <= 1e-3);
    }

    #[test]
    fn lazy_matches_naive_to_roundoff() {
        let p = tiny();
        let naive = run(&p, &fast_params(4, 5));
        let lazy = run(&p, &RunParams { lazy: true, ..fast_params(4, 5) });
        let rel = crate::linalg::dist2(&naive.w, &lazy.w)
            / (1.0 + crate::linalg::nrm2(&naive.w).powi(2));
        assert!(rel < 1e-12, "lazy vs naive relative dist2 {rel:.3e}");
        // identical communication pattern
        assert_eq!(naive.total_scalars, lazy.total_scalars);
    }

    #[test]
    fn lazy_renormalization_survives_aggressive_step() {
        // Regression: η·λ = 0.99 ⇒ β = 0.01, so α decays 100× per inner
        // step and crosses any renorm threshold mid-batch. The old guard
        // (per-batch, 1e-150) let −ηδ/α blow up to ±inf before firing;
        // the per-step 1e-100 guard must keep every coordinate finite.
        let p = tiny(); // λ = 1e-2
        let mut params = fast_params(2, 2);
        params.lazy = true;
        params.eta = 99.0; // deliberately divergent step — only finiteness matters
        params.m_inner = 120; // α would reach 1e-240 unguarded within one epoch
        params.batch = 16; // threshold crossing happens inside a batch
        let res = run(&p, &params);
        assert!(
            res.w.iter().all(|v| v.is_finite()),
            "lazy renormalization produced non-finite coordinates"
        );
        assert!(res.final_objective().is_finite());
    }

    #[test]
    fn lazy_survives_eta_lambda_exactly_one() {
        // Boundary: η·λ = 1 exactly ⇒ β = 0 ⇒ α collapses to literal 0 on
        // the first decay. The guard must fold the renorm in before the
        // −ηδ/α division or every coordinate goes NaN (0/0).
        let ds = generate(&GenSpec::new("beta0", 150, 60, 10).with_seed(17));
        let p = Problem::logistic_l2(ds, 0.25);
        let mut params = fast_params(2, 2);
        params.lazy = true;
        params.eta = 4.0; // 4.0 * 0.25 == 1.0 exactly in f64
        params.m_inner = 40;
        let res = run(&p, &params);
        assert!(
            res.w.iter().all(|v| v.is_finite()),
            "β = 0 boundary produced non-finite coordinates"
        );
    }

    #[test]
    fn lazy_converges_with_minibatch() {
        let p = tiny();
        let (_, f_opt) = crate::algs::serial::solve_optimum(&p, 60);
        let mut params = fast_params(4, 25);
        params.lazy = true;
        params.batch = 4;
        let res = run(&p, &params);
        assert!(res.final_objective() - f_opt < 1e-3);
    }

    #[test]
    fn simd_kernels_track_the_default_trajectory() {
        // --simd reassociates the reduction sums only; on this tiny, well-
        // conditioned problem the trajectories must stay within roundoff
        // scale of each other while the counted traffic is untouched
        let p = tiny();
        let base = fast_params(4, 5);
        let r = run(&p, &base);
        let rs = run(&p, &RunParams { simd: true, ..base.clone() });
        assert_eq!(r.total_scalars, rs.total_scalars);
        assert_eq!(r.total_bytes, rs.total_bytes);
        let rel =
            crate::linalg::dist2(&r.w, &rs.w) / (1.0 + crate::linalg::nrm2(&r.w).powi(2));
        assert!(rel < 1e-10, "simd vs serial relative dist2 {rel:.3e}");
        // and the lazy path's simd col_dot/zx precompute agree too
        let rl = run(&p, &RunParams { lazy: true, ..base.clone() });
        let rls = run(&p, &RunParams { lazy: true, simd: true, ..base });
        let rel =
            crate::linalg::dist2(&rl.w, &rls.w) / (1.0 + crate::linalg::nrm2(&rl.w).powi(2));
        assert!(rel < 1e-10, "lazy simd vs serial relative dist2 {rel:.3e}");
    }

    #[test]
    fn compressed_allreduce_cuts_bytes_and_still_converges() {
        // top-k on the margin/batch-dot allreduces: fewer wire bytes at the
        // same logical schedule, and the tiny problem still trains
        let p = tiny();
        let base = fast_params(4, 8);
        let dense = run(&p, &base);
        let k = p.n() / 8;
        let topk =
            run(&p, &RunParams { compress: crate::net::Compression::TopK(k), ..base });
        // same logical schedule (every allreduce still happens), fewer
        // scalars on the wire (the counters see kept coordinates only)
        assert_eq!(dense.total_messages, topk.total_messages, "schedule unchanged");
        assert!(topk.total_scalars < dense.total_scalars, "top-k must drop coordinates");
        // only the N-vector margin allreduce compresses (the u-scalar batch
        // dots are dense at 8 B either way), so with M = N the margin phase
        // is half the bytes and top-k at N/8 shaves most of that half
        assert!(
            topk.total_bytes * 4 < dense.total_bytes * 3,
            "top-k kept {} of {} bytes",
            topk.total_bytes,
            dense.total_bytes
        );
        let f0 = p.objective(&vec![0.0; p.d()]);
        assert!(topk.final_objective() < f0 - 1e-2, "compressed run failed to train");
    }

    #[test]
    fn trace_sim_time_monotone() {
        let p = tiny();
        let res = run(&p, &fast_params(3, 4));
        for w in res.trace.points.windows(2) {
            assert!(w[1].sim_time >= w[0].sim_time);
            assert!(w[1].scalars >= w[0].scalars);
            assert!(w[1].bytes >= w[0].bytes);
        }
    }

    #[test]
    fn f32_wire_halves_bytes_and_stays_close() {
        let p = tiny();
        let base = fast_params(4, 6);
        let r64 = run(&p, &base);
        let r32 = run(&p, &RunParams { wire: crate::net::WireFmt::F32, ..base.clone() });
        // identical logical traffic, half the wire bytes
        assert_eq!(r64.total_scalars, r32.total_scalars);
        assert_eq!(r64.total_bytes, 8 * r64.total_scalars);
        assert_eq!(r32.total_bytes, 4 * r32.total_scalars);
        // f32 margins perturb the trajectory only at rounding scale
        let rel = crate::linalg::dist2(&r64.w, &r32.w)
            / (1.0 + crate::linalg::nrm2(&r64.w).powi(2));
        assert!(rel < 1e-4, "f32 wire drifted too far: rel {rel:.3e}");
        assert!(r32.final_objective() - r64.final_objective() < 1e-3);
    }

    #[test]
    fn sparse_wire_runs_end_to_end() {
        let p = tiny();
        let mut params = fast_params(3, 4);
        params.wire = crate::net::WireFmt::Sparse;
        let res = run(&p, &params);
        assert!(res.final_objective().is_finite());
        let f0 = p.objective(&vec![0.0; p.d()]);
        assert!(res.final_objective() < f0 - 1e-2);
        // dense margin payloads under the sparse codec: 8 bytes per nonzero
        assert!(res.total_bytes > 0 && res.total_bytes <= 8 * res.total_scalars);
    }
}
