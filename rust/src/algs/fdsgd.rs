//! **FD-SGD** — the feature-distributed framework of the paper applied to
//! plain SGD (the extension the paper's introduction explicitly claims:
//! "our feature-distributed framework is not only applicable to SVRG, it
//! can also be applied to SGD and other variants").
//!
//! Same substrate as [`super::fdsvrg`]: feature slabs, shared sampling
//! stream, tree-structured scalar allreduce per sampled instance. The
//! difference is the update — no snapshot/full-gradient phase, a plain
//! stochastic step with `η_t = η₀ / (1 + decay·t)` decay on the epoch
//! counter (fixed step when `decay = 0`, matching the paper's §5.2 setup
//! for the SVRG runs).
//!
//! Communication per "epoch" of N sampled instances is `2qN` scalars —
//! half of FD-SVRG's `4qN` (no full-gradient margin pass) — but SGD's
//! sublinear convergence means it loses badly on time-to-tight-gap, which
//! is exactly the SVRG-vs-SGD contrast the paper's Table 3 shows on the
//! instance-distributed side.

use super::{Problem, RunParams, Workspace};
use crate::metrics::RunResult;
use crate::net::{tags, Endpoint, NodeId};
use crate::session::cluster::{
    collect_node_states, comm_snapshot, net_node_state, send_node_state, ClusterCtx,
    ClusterDriver, Directive, EpochGate,
};
use crate::session::{EpochReport, ResumeState};
use crate::sparse::partition::{by_features, by_features_rows, FeatureSlab};
use crate::util::Pcg64;
use std::sync::Arc;

/// Per-epoch step decay: `η_t = η₀ / (1 + decay · t)`.
pub const DEFAULT_DECAY: f64 = 0.1;

/// Run FD-SGD on a simulated cluster of `params.q` workers + coordinator.
/// `params.outer` counts epochs of `M` sampled instances (`m_inner`,
/// default N) so traces are axis-compatible with the SVRG runs.
pub fn run(problem: &Problem, params: &RunParams) -> RunResult {
    super::Algorithm::FdSgd.run(problem, params)
}

/// Build the steppable FD-SGD driver.
pub(crate) fn driver(
    problem: &Problem,
    params: &RunParams,
    resume: Option<ResumeState>,
) -> anyhow::Result<ClusterDriver> {
    let q = params.q.max(1);
    let n = problem.n();
    let d = problem.d();
    let eta0 = params.effective_eta(problem);
    let m_inner = if params.m_inner == 0 { n } else { params.m_inner };
    let u = params.batch.max(1);
    // naive dense O(d_l)-per-step update ⇒ row-balanced cut (see partition)
    // (no mirror prewarm: this algorithm has no full-gradient Dᵀw/Dc
    // pass, so the pool kernels — and the CSR mirror — are never used)
    let slabs: Arc<Vec<FeatureSlab>> = Arc::new(by_features_rows(&problem.ds.x, q));
    let _ = by_features; // nnz-balanced variant kept for the lazy path
    let y: Arc<Vec<f64>> = Arc::new(problem.ds.y.clone());
    let group: Vec<NodeId> = (0..=q).collect();
    let dataset = problem.ds.name.clone();
    let model = params.net_model();
    let problem = problem.clone();
    let params = params.clone();

    let node_fn = Arc::new(move |mut ep: Endpoint, cx: &ClusterCtx| {
        if ep.id() == 0 {
            let gate = cx.take_gate();
            coordinator(&mut ep, &params, &group, d, m_inner, u, &slabs, &gate, cx);
        } else {
            worker(&mut ep, &problem, &params, &group, eta0, m_inner, u, &slabs, &y, cx);
        }
    });
    ClusterDriver::new("fdsgd", &dataset, q + 1, d, model, resume, node_fn)
}

#[allow(clippy::too_many_arguments)]
fn coordinator(
    ep: &mut Endpoint,
    params: &RunParams,
    group: &[NodeId],
    d: usize,
    m_inner: usize,
    u: usize,
    slabs: &[FeatureSlab],
    gate: &EpochGate,
    cx: &ClusterCtx,
) {
    let q = group.len() - 1;
    let comm = params.comm();
    let resume = cx.resume.as_deref();
    let mut grads = resume.map(|r| r.grads).unwrap_or(0);
    let mut epoch = resume.map(|r| r.epoch).unwrap_or(0);
    let mut ws = Workspace::new(params.threads);

    loop {
        let mut m = 0usize;
        while m < m_inner {
            let b = u.min(m_inner - m);
            comm.allreduce(ep, group, Workspace::reset(&mut ws.partial, b));
            grads += b as u64;
            m += b;
        }
        // fresh buffer per epoch: ownership moves into the report's Arc
        let mut w = vec![0.0f64; d];
        for (l, slab) in slabs.iter().enumerate() {
            let msg = ep.recv_eval_from(l + 1, tags::EVAL);
            msg.decode_into(&mut w[slab.row_lo..slab.row_hi]);
        }
        let sim_time = ep.now();
        let own = net_node_state(ep, None, vec![]);
        let nodes = collect_node_states(ep, 0, own, 1..=q, q + 1);
        let (scalars, bytes, per_node) = comm_snapshot(ep);
        epoch += 1;
        let directive = gate.exchange(EpochReport {
            epoch,
            w: Arc::new(w),
            grads,
            sim_time,
            scalars,
            bytes,
            comm: per_node,
            nodes,
        });
        let stop = directive == Directive::Stop;
        for l in 1..=q {
            ep.send_eval(l, tags::CTRL, vec![if stop { 1.0 } else { 0.0 }]);
        }
        if stop {
            break;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker(
    ep: &mut Endpoint,
    problem: &Problem,
    params: &RunParams,
    group: &[NodeId],
    eta0: f64,
    m_inner: usize,
    u: usize,
    slabs: &[FeatureSlab],
    y: &[f64],
    cx: &ClusterCtx,
) {
    let l = ep.id() - 1;
    let slab = &slabs[l];
    let dl = slab.dim();
    let n = problem.n();
    let comm = params.comm();
    let loss = problem.build_loss();
    // the per-epoch step decay runs on the absolute epoch counter, so a
    // resumed run continues the same schedule
    let (mut w_l, mut sample_rng, mut epoch) =
        match (cx.resume.as_deref(), cx.node_state(ep.id())) {
            (Some(r), Some(st)) => (
                r.w[slab.row_lo..slab.row_hi].to_vec(),
                Pcg64::from_state_words(st.rng.expect("fdsgd worker state carries the RNG")),
                r.epoch,
            ),
            _ => (vec![0.0f64; dl], Pcg64::seed_from_u64(params.seed), 0usize),
        };

    let mut ws = Workspace::new(params.threads);
    let mut batch_idx: Vec<usize> = Vec::with_capacity(u);

    loop {
        let eta = eta0 / (1.0 + DEFAULT_DECAY * epoch as f64);
        let mut m = 0usize;
        while m < m_inner {
            let b = u.min(m_inner - m);
            batch_idx.clear();
            for _ in 0..b {
                batch_idx.push(sample_rng.below(n));
            }
            Workspace::reset(&mut ws.partial, b);
            for (k, &i) in batch_idx.iter().enumerate() {
                ws.partial[k] = slab.data.col_dot(i, &w_l);
            }
            comm.allreduce(ep, group, &mut ws.partial);
            for (k, &i) in batch_idx.iter().enumerate() {
                let c = loss.derivative(ws.partial[k], y[i]);
                // dense part: regularizer gradient on the local slab
                match problem.reg {
                    crate::loss::Regularizer::L2 { lambda } => {
                        if lambda != 0.0 {
                            crate::linalg::scale(1.0 - eta * lambda, &mut w_l);
                        }
                    }
                    _ => {
                        for wi in w_l.iter_mut() {
                            let g = problem.reg.grad_coord(*wi);
                            *wi -= eta * g;
                        }
                    }
                }
                // sparse part: stochastic loss gradient
                slab.data.col_axpy(i, -eta * c, &mut w_l);
            }
            m += b;
        }
        epoch += 1;

        ep.send_eval(0, tags::EVAL, w_l.clone());
        let st = net_node_state(ep, Some(sample_rng.state_words()), vec![]);
        send_node_state(ep, 0, &st);
        let ctrl = ep.recv_eval_from(0, tags::CTRL);
        if ctrl.value(0) != 0.0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, GenSpec};
    use crate::net::SimParams;

    fn tiny() -> Problem {
        let ds = generate(&GenSpec::new("t", 150, 60, 10).with_seed(17));
        Problem::logistic_l2(ds, 1e-2)
    }

    fn fast_params(q: usize, outer: usize) -> RunParams {
        RunParams { q, outer, sim: SimParams::free(), ..Default::default() }
    }

    #[test]
    fn converges_on_tiny_problem() {
        let p = tiny();
        let res = run(&p, &fast_params(4, 15));
        let f0 = p.objective(&vec![0.0; p.d()]);
        assert!(res.final_objective() < f0 - 1e-2, "obj {}", res.final_objective());
    }

    #[test]
    fn comm_is_half_of_fdsvrg() {
        // no full-gradient margin pass: 2qN vs FD-SVRG's 4qN per epoch
        let p = tiny();
        let params = fast_params(4, 3);
        let sgd = run(&p, &params).total_scalars;
        let svrg = crate::algs::fdsvrg::run(&p, &params).total_scalars;
        assert_eq!(2 * sgd, svrg);
    }

    #[test]
    fn svrg_dominates_sgd_on_tight_gap() {
        let p = tiny();
        let (_, f_opt) = crate::algs::serial::solve_optimum(&p, 60);
        let params = fast_params(4, 20);
        let gap_sgd = run(&p, &params).final_objective() - f_opt;
        let gap_svrg = crate::algs::fdsvrg::run(&p, &params).final_objective() - f_opt;
        assert!(
            gap_svrg < gap_sgd / 5.0,
            "FD-SVRG gap {gap_svrg:.2e} must beat FD-SGD {gap_sgd:.2e}"
        );
    }

    #[test]
    fn workers_stay_consistent_across_epochs() {
        // identical sampling stream ⇒ the assembled w must descend smoothly
        let p = tiny();
        let res = run(&p, &fast_params(3, 6));
        let objs: Vec<f64> = res.trace.points.iter().map(|p| p.objective).collect();
        assert!(objs.windows(2).filter(|w| w[1] > w[0] + 1e-3).count() <= 1, "{objs:?}");
    }
}
