//! **FD-SGD** — the feature-distributed framework of the paper applied to
//! plain SGD (the extension the paper's introduction explicitly claims:
//! "our feature-distributed framework is not only applicable to SVRG, it
//! can also be applied to SGD and other variants").
//!
//! Same substrate as [`super::fdsvrg`]: feature slabs, shared sampling
//! stream, tree-structured scalar allreduce per sampled instance. The
//! difference is the update — no snapshot/full-gradient phase, a plain
//! stochastic step with `η_t = η₀ / (1 + decay·t)` decay on the epoch
//! counter (fixed step when `decay = 0`, matching the paper's §5.2 setup
//! for the SVRG runs).
//!
//! Communication per "epoch" of N sampled instances is `2qN` scalars —
//! half of FD-SVRG's `4qN` (no full-gradient margin pass) — but SGD's
//! sublinear convergence means it loses badly on time-to-tight-gap, which
//! is exactly the SVRG-vs-SGD contrast the paper's Table 3 shows on the
//! instance-distributed side.

use super::{Problem, RunParams};
use crate::cluster::run_cluster;
use crate::metrics::{RunResult, Trace, TracePoint};
use crate::net::{tags, Endpoint, NodeId};
use crate::sparse::partition::{by_features, by_features_rows, FeatureSlab};
use crate::util::time::Stopwatch;
use crate::util::Pcg64;
use std::sync::Arc;

/// Per-epoch step decay: `η_t = η₀ / (1 + decay · t)`.
pub const DEFAULT_DECAY: f64 = 0.1;

struct CoordOut {
    trace: Trace,
    w: Vec<f64>,
}

enum NodeOut {
    Coord(Box<CoordOut>),
    Worker,
}

/// Run FD-SGD on a simulated cluster of `params.q` workers + coordinator.
/// `params.outer` counts epochs of `M` sampled instances (`m_inner`,
/// default N) so traces are axis-compatible with the SVRG runs.
pub fn run(problem: &Problem, params: &RunParams) -> RunResult {
    let q = params.q.max(1);
    let n = problem.n();
    let eta0 = params.effective_eta(problem);
    let m_inner = if params.m_inner == 0 { n } else { params.m_inner };
    let u = params.batch.max(1);
    // naive dense O(d_l)-per-step update ⇒ row-balanced cut (see partition)
    let slabs: Arc<Vec<FeatureSlab>> = Arc::new(by_features_rows(&problem.ds.x, q));
    let _ = by_features; // nnz-balanced variant kept for the lazy path
    let y: Arc<Vec<f64>> = Arc::new(problem.ds.y.clone());
    let group: Vec<NodeId> = (0..=q).collect();
    let wall = Stopwatch::start();

    let cluster = run_cluster(q + 1, params.sim, |mut ep| {
        if ep.id() == 0 {
            NodeOut::Coord(Box::new(coordinator(&mut ep, problem, params, &group, m_inner, u, &slabs, &wall)))
        } else {
            worker(&mut ep, problem, params, &group, eta0, m_inner, u, &slabs, &y);
            NodeOut::Worker
        }
    });

    let coord = cluster
        .results
        .into_iter()
        .find_map(|r| match r {
            NodeOut::Coord(c) => Some(*c),
            NodeOut::Worker => None,
        })
        .expect("coordinator result");
    RunResult::from_cluster(
        "fdsgd",
        &problem.ds.name,
        coord.w,
        coord.trace,
        wall.seconds(),
        &cluster.stats,
    )
}

#[allow(clippy::too_many_arguments)]
fn coordinator(
    ep: &mut Endpoint,
    problem: &Problem,
    params: &RunParams,
    group: &[NodeId],
    m_inner: usize,
    u: usize,
    slabs: &[FeatureSlab],
    wall: &Stopwatch,
) -> CoordOut {
    let q = group.len() - 1;
    let d = problem.d();
    let comm = params.comm();
    let mut trace = Trace::default();
    let mut grads = 0u64;
    let mut w = vec![0.0f64; d];
    trace.push(TracePoint {
        outer: 0,
        sim_time: 0.0,
        wall_time: wall.seconds(),
        scalars: 0,
        bytes: 0,
        grads: 0,
        objective: problem.objective(&w),
    });
    ep.discard_cpu();

    for t in 0..params.outer {
        let mut m = 0usize;
        while m < m_inner {
            let b = u.min(m_inner - m);
            let mut partial = vec![0.0f64; b];
            comm.allreduce(ep, group, &mut partial);
            grads += b as u64;
            m += b;
        }
        for (l, slab) in slabs.iter().enumerate() {
            let msg = ep.recv_eval_from(l + 1, tags::EVAL);
            msg.decode_into(&mut w[slab.row_lo..slab.row_hi]);
        }
        let objective = problem.objective(&w);
        ep.discard_cpu();
        let sim_time = ep.now();
        trace.push(TracePoint {
            outer: t + 1,
            sim_time,
            wall_time: wall.seconds(),
            scalars: ep.stats().total_scalars(),
            bytes: ep.stats().total_bytes(),
            grads,
            objective,
        });
        let gap_hit = match params.gap_stop {
            Some((f_opt, target)) => objective - f_opt <= target,
            None => false,
        };
        let time_hit = params.sim_time_cap.map(|cap| sim_time >= cap).unwrap_or(false);
        let stop = gap_hit || time_hit || t + 1 == params.outer;
        for l in 1..=q {
            ep.send_eval(l, tags::CTRL, vec![if stop { 1.0 } else { 0.0 }]);
        }
        if stop {
            break;
        }
    }
    CoordOut { trace, w }
}

#[allow(clippy::too_many_arguments)]
fn worker(
    ep: &mut Endpoint,
    problem: &Problem,
    params: &RunParams,
    group: &[NodeId],
    eta0: f64,
    m_inner: usize,
    u: usize,
    slabs: &[FeatureSlab],
    y: &[f64],
) {
    let l = ep.id() - 1;
    let slab = &slabs[l];
    let dl = slab.dim();
    let n = problem.n();
    let comm = params.comm();
    let loss = problem.build_loss();
    let mut w_l = vec![0.0f64; dl];
    let mut sample_rng = Pcg64::seed_from_u64(params.seed);
    let mut epoch = 0usize;

    loop {
        let eta = eta0 / (1.0 + DEFAULT_DECAY * epoch as f64);
        let mut m = 0usize;
        let mut batch_idx = Vec::with_capacity(u);
        while m < m_inner {
            let b = u.min(m_inner - m);
            batch_idx.clear();
            for _ in 0..b {
                batch_idx.push(sample_rng.below(n));
            }
            let mut partial: Vec<f64> =
                batch_idx.iter().map(|&i| slab.data.col_dot(i, &w_l)).collect();
            comm.allreduce(ep, group, &mut partial);
            for (k, &i) in batch_idx.iter().enumerate() {
                let c = loss.derivative(partial[k], y[i]);
                // dense part: regularizer gradient on the local slab
                match problem.reg {
                    crate::loss::Regularizer::L2 { lambda } => {
                        if lambda != 0.0 {
                            crate::linalg::scale(1.0 - eta * lambda, &mut w_l);
                        }
                    }
                    _ => {
                        for wi in w_l.iter_mut() {
                            let g = problem.reg.grad_coord(*wi);
                            *wi -= eta * g;
                        }
                    }
                }
                // sparse part: stochastic loss gradient
                slab.data.col_axpy(i, -eta * c, &mut w_l);
            }
            m += b;
        }
        epoch += 1;

        ep.send_eval(0, tags::EVAL, w_l.clone());
        let ctrl = ep.recv_eval_from(0, tags::CTRL);
        if ctrl.value(0) != 0.0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, GenSpec};
    use crate::net::SimParams;

    fn tiny() -> Problem {
        let ds = generate(&GenSpec::new("t", 150, 60, 10).with_seed(17));
        Problem::logistic_l2(ds, 1e-2)
    }

    fn fast_params(q: usize, outer: usize) -> RunParams {
        RunParams { q, outer, sim: SimParams::free(), ..Default::default() }
    }

    #[test]
    fn converges_on_tiny_problem() {
        let p = tiny();
        let res = run(&p, &fast_params(4, 15));
        let f0 = p.objective(&vec![0.0; p.d()]);
        assert!(res.final_objective() < f0 - 1e-2, "obj {}", res.final_objective());
    }

    #[test]
    fn comm_is_half_of_fdsvrg() {
        // no full-gradient margin pass: 2qN vs FD-SVRG's 4qN per epoch
        let p = tiny();
        let params = fast_params(4, 3);
        let sgd = run(&p, &params).total_scalars;
        let svrg = crate::algs::fdsvrg::run(&p, &params).total_scalars;
        assert_eq!(2 * sgd, svrg);
    }

    #[test]
    fn svrg_dominates_sgd_on_tight_gap() {
        let p = tiny();
        let (_, f_opt) = crate::algs::serial::solve_optimum(&p, 60);
        let params = fast_params(4, 20);
        let gap_sgd = run(&p, &params).final_objective() - f_opt;
        let gap_svrg = crate::algs::fdsvrg::run(&p, &params).final_objective() - f_opt;
        assert!(
            gap_svrg < gap_sgd / 5.0,
            "FD-SVRG gap {gap_svrg:.2e} must beat FD-SGD {gap_sgd:.2e}"
        );
    }

    #[test]
    fn workers_stay_consistent_across_epochs() {
        // identical sampling stream ⇒ the assembled w must descend smoothly
        let p = tiny();
        let res = run(&p, &fast_params(3, 6));
        let objs: Vec<f64> = res.trace.points.iter().map(|p| p.objective).collect();
        assert!(objs.windows(2).filter(|w| w[1] > w[0] + 1e-3).count() <= 1, "{objs:?}");
    }
}
