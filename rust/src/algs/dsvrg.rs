//! DSVRG (Lee et al., 2017) — the paper's strongest baseline (§4.5).
//!
//! Instance-distributed, decentralized-with-a-center as the paper costs it:
//! node 0 is the center, nodes 1..=q hold instance shards. Per outer
//! iteration:
//!
//! 1. the center sends `w_t` (a dense d-vector) to every worker and
//!    receives the local loss-gradient sums back — `2qd` scalars;
//! 2. the center sends the full gradient `z` to the single on-duty machine
//!    `J` (round-robin along the ring), which runs `M = N/q` local inner
//!    SVRG steps and returns the updated parameter — `2d` scalars.
//!
//! Total `2qd + 2d` per outer iteration, exactly the §4.5 accounting
//! (`comm_counters_match_paper_formula` pins it). Only one machine works
//! during the inner loop — the serial fraction the paper contrasts with
//! FD-SVRG's fully-parallel inner loop.

use super::{Problem, RunParams, Workspace};
use crate::linalg;
use crate::metrics::RunResult;
use crate::net::{tags, Endpoint};
use crate::session::cluster::{
    collect_node_states, comm_snapshot, net_node_state, send_node_state, ClusterCtx,
    ClusterDriver, Directive, EpochGate,
};
use crate::session::{EpochReport, ResumeState};
use crate::sparse::partition::{by_instances, InstanceShard};
use crate::util::Pcg64;
use std::sync::Arc;

/// Run DSVRG (the fire-and-forget path: one session driven to completion).
pub fn run(problem: &Problem, params: &RunParams) -> RunResult {
    super::Algorithm::Dsvrg.run(problem, params)
}

/// Build the steppable DSVRG driver: node 0 is the center (monitor), nodes
/// 1..=q hold instance shards. The round-robin duty rotation runs on the
/// absolute epoch counter, so resumed runs continue the same schedule.
pub(crate) fn driver(
    problem: &Problem,
    params: &RunParams,
    resume: Option<ResumeState>,
) -> anyhow::Result<ClusterDriver> {
    let q = params.q.max(1);
    let d = problem.d();
    let n = problem.n();
    let eta = params.effective_eta(problem);
    let m_inner = if params.m_inner == 0 { (n / q).max(1) } else { params.m_inner };
    let shards: Vec<InstanceShard> = by_instances(&problem.ds.x, q);
    for shard in &shards {
        shard.prewarm(params.threads);
    }
    let shards: Arc<Vec<InstanceShard>> = Arc::new(shards);
    let y: Arc<Vec<f64>> = Arc::new(problem.ds.y.clone());
    let dataset = problem.ds.name.clone();
    let model = params.net_model();
    let problem = problem.clone();
    let params = params.clone();

    let node_fn = Arc::new(move |mut ep: Endpoint, cx: &ClusterCtx| {
        if ep.id() == 0 {
            let gate = cx.take_gate();
            center(&mut ep, &problem, &params, q, d, m_inner, &gate, cx);
        } else {
            worker(&mut ep, &problem, &params, eta, m_inner, &shards, &y, cx);
        }
    });
    ClusterDriver::new("dsvrg", &dataset, q + 1, d, model, resume, node_fn)
}

#[allow(clippy::too_many_arguments)]
fn center(
    ep: &mut Endpoint,
    problem: &Problem,
    params: &RunParams,
    q: usize,
    d: usize,
    m_inner: usize,
    gate: &EpochGate,
    cx: &ClusterCtx,
) {
    let n = problem.n();
    let comm = params.comm();
    let resume = cx.resume.as_deref();
    let mut grads = resume.map(|r| r.grads).unwrap_or(0);
    let mut epoch = resume.map(|r| r.epoch).unwrap_or(0);
    // the center's w is replaced wholesale each epoch, so it lives behind
    // the same Arc the report carries — no per-epoch clone
    let mut w: Arc<Vec<f64>> =
        resume.map(|r| r.w.clone()).unwrap_or_else(|| Arc::new(vec![0.0f64; d]));
    let mut ws = Workspace::new(params.threads);

    loop {
        // (1) broadcast w_t (one encode, Arc fan-out), gather gradient sums
        comm.send_all(ep, 1..=q, tags::BCAST, &w);
        Workspace::reset(&mut ws.grad, d);
        for l in 1..=q {
            let msg = ep.recv_from(l, tags::REDUCE);
            msg.add_into(&mut ws.grad);
        }
        let inv_n = 1.0 / n as f64;
        linalg::scale(inv_n, &mut ws.grad);
        grads += n as u64;

        // (2) on-duty machine J runs the inner loop
        let j = 1 + (epoch % q);
        comm.send(ep, j, tags::RING, &ws.grad);
        let msg = ep.recv_from(j, tags::RING);
        w = Arc::new(msg.to_vec(d));
        grads += m_inner as u64;

        // evaluation plane: collect states, report the boundary
        let sim_time = ep.now();
        let own = net_node_state(ep, None, vec![]);
        let nodes = collect_node_states(ep, 0, own, 1..=q, q + 1);
        let (scalars, bytes, per_node) = comm_snapshot(ep);
        epoch += 1;
        let directive = gate.exchange(EpochReport {
            epoch,
            w: w.clone(), // Arc clone — the buffer is shared, not copied
            grads,
            sim_time,
            scalars,
            bytes,
            comm: per_node,
            nodes,
        });
        let stop = directive == Directive::Stop;
        for l in 1..=q {
            ep.send_eval(l, tags::CTRL, vec![if stop { 1.0 } else { 0.0 }]);
        }
        if stop {
            break;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker(
    ep: &mut Endpoint,
    problem: &Problem,
    params: &RunParams,
    eta: f64,
    m_inner: usize,
    shards: &[InstanceShard],
    y: &[f64],
    cx: &ClusterCtx,
) {
    let l = ep.id() - 1;
    let q = shards.len();
    let shard = &shards[l];
    let n_local = shard.data.cols();
    let d = problem.d();
    let comm = params.comm();
    let loss = problem.build_loss();
    let lambda = problem.reg.lambda();
    let use_l2 = matches!(problem.reg, crate::loss::Regularizer::L2 { .. });
    let (mut rng, mut t) = match (cx.resume.as_deref(), cx.node_state(ep.id())) {
        (Some(r), Some(st)) => (
            Pcg64::from_state_words(st.rng.expect("dsvrg worker state carries the RNG")),
            r.epoch,
        ),
        _ => (Pcg64::seed_from_u64(params.seed ^ (0xD5 + l as u64)), 0usize),
    };

    let mut ws = Workspace::new(params.threads);
    let mut w_t = vec![0.0f64; d];

    loop {
        // (1) receive w_t, return local loss-gradient sum (the Dᵀw and Dc
        // kernels run on the workspace pool, bit-exact at any width)
        comm.recv_into(ep, 0, tags::BCAST, &mut w_t);
        Workspace::reset(&mut ws.margins, n_local);
        shard.data.transpose_matvec_pool(&w_t, &mut ws.margins, &ws.pool);
        Workspace::reset(&mut ws.c0, n_local);
        for i in 0..n_local {
            ws.c0[i] = loss.derivative(ws.margins[i], y[shard.col_idx[i]]);
        }
        Workspace::reset(&mut ws.grad, d);
        shard.data.matvec_accumulate_pool(&ws.c0, &mut ws.grad, &ws.pool);
        comm.send(ep, 0, tags::REDUCE, &ws.grad);

        // (2) if on duty this epoch, run the inner loop and return w
        if l == t % q {
            let z = comm.recv_vec(ep, 0, tags::RING, d);
            let mut w = w_t.clone();
            for _ in 0..m_inner {
                let i = rng.below(n_local);
                let yi = y[shard.col_idx[i]];
                let zi = shard.data.col_dot(i, &w);
                let delta = loss.derivative(zi, yi) - loss.derivative(ws.margins[i], yi);
                if use_l2 {
                    linalg::axpby(-eta, &z, 1.0 - eta * lambda, &mut w);
                } else {
                    for (wi, zi) in w.iter_mut().zip(z.iter()) {
                        let g = problem.reg.grad_coord(*wi);
                        *wi -= eta * (*zi + g);
                    }
                }
                shard.data.col_axpy(i, -eta * delta, &mut w);
            }
            comm.send(ep, 0, tags::RING, &w);
        }

        let st = net_node_state(ep, Some(rng.state_words()), vec![]);
        send_node_state(ep, 0, &st);
        let ctrl = ep.recv_eval_from(0, tags::CTRL);
        if ctrl.value(0) != 0.0 {
            break;
        }
        t += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, GenSpec};
    use crate::net::SimParams;

    fn tiny() -> Problem {
        let ds = generate(&GenSpec::new("t", 150, 64, 10).with_seed(19));
        Problem::logistic_l2(ds, 1e-2)
    }

    fn fast_params(q: usize, outer: usize) -> RunParams {
        RunParams { q, outer, sim: SimParams::free(), ..Default::default() }
    }

    #[test]
    fn converges_on_tiny_problem() {
        let p = tiny();
        let (_, f_opt) = crate::algs::serial::solve_optimum(&p, 40);
        let res = run(&p, &fast_params(4, 40));
        let gap = res.final_objective() - f_opt;
        assert!(gap < 1e-3, "gap {gap:.3e}");
    }

    #[test]
    fn comm_counters_match_paper_formula() {
        // per outer: 2qd (full gradient) + 2d (inner loop hand-off)
        let p = tiny();
        let q = 4u64;
        let outer = 3u64;
        let res = run(&p, &fast_params(q as usize, outer as usize));
        let d = p.d() as u64;
        assert_eq!(res.total_scalars, outer * (2 * q * d + 2 * d));
    }

    #[test]
    fn comm_is_dimension_bound_not_instance_bound() {
        // DSVRG cost scales with d; FD-SVRG with N. On a d >> N problem the
        // FD-SVRG total must be smaller — the paper's core claim.
        let ds = generate(&GenSpec::new("wide", 4000, 100, 12).with_seed(23));
        let p = Problem::logistic_l2(ds, 1e-2);
        let params = fast_params(4, 2);
        let r_d = run(&p, &params);
        let r_f = crate::algs::fdsvrg::run(&p, &params);
        assert!(
            r_f.total_scalars < r_d.total_scalars,
            "FD {} should beat DSVRG {} when d>N",
            r_f.total_scalars,
            r_d.total_scalars
        );
    }

    #[test]
    fn center_holds_assembled_parameter() {
        let p = tiny();
        let res = run(&p, &fast_params(3, 5));
        assert_eq!(res.w.len(), p.d());
        assert!(res.final_objective().is_finite());
    }
}
