//! Parameter-Server framework substrate (paper §3.1, Fig. 1).
//!
//! Stands in for PS-Lite/Petuum: `p` servers each own a contiguous key
//! (feature) range of the parameter vector; `q` workers own instance
//! shards and talk to servers with pull/push. SynSVRG, AsySVRG and
//! PS-Lite(SGD) are built on this module.
//!
//! Node numbering: servers are nodes `0..p`, workers are nodes `p..p+q`.
//! Server 0 doubles as the monitor that assembles evaluation snapshots
//! (evaluation uses the uncounted plane, so this does not distort the
//! counters the paper's figures read).

use crate::net::NodeId;

/// Static cluster shape for a parameter-server run.
#[derive(Clone, Copy, Debug)]
pub struct PsTopology {
    /// Number of servers `p`.
    pub p: usize,
    /// Number of workers `q`.
    pub q: usize,
    /// Parameter dimension `d`.
    pub d: usize,
}

impl PsTopology {
    pub fn new(p: usize, q: usize, d: usize) -> Self {
        assert!(p > 0 && q > 0);
        PsTopology { p, q, d }
    }

    pub fn n_nodes(&self) -> usize {
        self.p + self.q
    }

    pub fn server_node(&self, k: usize) -> NodeId {
        debug_assert!(k < self.p);
        k
    }

    pub fn worker_node(&self, l: usize) -> NodeId {
        debug_assert!(l < self.q);
        self.p + l
    }

    pub fn is_server(&self, node: NodeId) -> bool {
        node < self.p
    }

    /// Key range `[lo, hi)` owned by server `k` (contiguous blocks, the
    /// PS-Lite default for dense parameters).
    pub fn key_range(&self, k: usize) -> (usize, usize) {
        let base = self.d / self.p;
        let rem = self.d % self.p;
        let lo = k * base + k.min(rem);
        let hi = lo + base + usize::from(k < rem);
        (lo, hi)
    }

    /// Which server owns key (feature) `key`.
    pub fn server_of_key(&self, key: usize) -> usize {
        debug_assert!(key < self.d);
        let base = self.d / self.p;
        let rem = self.d % self.p;
        let boundary = rem * (base + 1);
        if key < boundary {
            key / (base + 1)
        } else {
            rem + (key - boundary) / base.max(1)
        }
    }

    /// Split a dense d-vector into per-server blocks.
    pub fn split_dense(&self, v: &[f64]) -> Vec<Vec<f64>> {
        assert_eq!(v.len(), self.d);
        (0..self.p)
            .map(|k| {
                let (lo, hi) = self.key_range(k);
                v[lo..hi].to_vec()
            })
            .collect()
    }

    /// Assemble per-server blocks back into a dense vector.
    pub fn join_dense(&self, blocks: &[Vec<f64>]) -> Vec<f64> {
        assert_eq!(blocks.len(), self.p);
        let mut out = vec![0.0; self.d];
        for (k, b) in blocks.iter().enumerate() {
            let (lo, hi) = self.key_range(k);
            assert_eq!(b.len(), hi - lo, "block {k} size");
            out[lo..hi].copy_from_slice(b);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_ranges_cover_disjointly() {
        for (p, d) in [(1usize, 10usize), (3, 10), (4, 7), (7, 7), (5, 23)] {
            let t = PsTopology::new(p, 2, d);
            let mut covered = 0usize;
            for k in 0..p {
                let (lo, hi) = t.key_range(k);
                assert_eq!(lo, covered, "p={p} d={d} k={k}");
                covered = hi;
            }
            assert_eq!(covered, d);
        }
    }

    #[test]
    fn server_of_key_matches_ranges() {
        for (p, d) in [(1usize, 10usize), (3, 10), (4, 7), (5, 23), (2, 1000)] {
            let t = PsTopology::new(p, 2, d);
            for key in 0..d {
                let k = t.server_of_key(key);
                let (lo, hi) = t.key_range(k);
                assert!(key >= lo && key < hi, "p={p} d={d} key={key} -> server {k}");
            }
        }
    }

    #[test]
    fn split_join_round_trip() {
        let t = PsTopology::new(3, 2, 11);
        let v: Vec<f64> = (0..11).map(|i| i as f64).collect();
        let blocks = t.split_dense(&v);
        assert_eq!(blocks.len(), 3);
        assert_eq!(t.join_dense(&blocks), v);
    }

    #[test]
    fn node_numbering() {
        let t = PsTopology::new(2, 3, 10);
        assert_eq!(t.n_nodes(), 5);
        assert_eq!(t.server_node(1), 1);
        assert_eq!(t.worker_node(0), 2);
        assert!(t.is_server(0) && t.is_server(1) && !t.is_server(2));
    }
}
