//! Optimization algorithms: the paper's FD-SVRG plus every baseline it is
//! evaluated against, all built on the same [`crate::net`]/[`crate::cluster`]
//! substrate so their communication counters and simulated clocks are
//! directly comparable.
//!
//! | module | algorithm | framework | paper reference |
//! |--------|-----------|-----------|-----------------|
//! | [`serial`] | SVRG (Options I & II), SGD | single node | Appendix A |
//! | [`fdsvrg`] | **FD-SVRG** (+ mini-batch) | coordinator + q workers, feature-distributed | Algorithm 1 |
//! | [`fdsgd`]  | FD-SGD (framework extension) | coordinator + q workers, feature-distributed | §1 ("also applicable to SGD") |
//! | [`fdsaga`] | FD-SAGA (framework extension) | coordinator + q workers, feature-distributed | §1 ("and other variants") |
//! | [`dsvrg`]  | DSVRG | decentralized ring, instance-distributed | Lee et al. 2017, §4.5 |
//! | [`dpsgd`]  | D-PSGD | decentralized ring, instance-distributed | Lian et al. 2017, §3.2 |
//! | [`ps`]     | Parameter-Server framework | p servers + q workers | §3.1 |
//! | [`synsvrg`]| SynSVRG on PS | PS | Algorithms 3–4 |
//! | [`asysvrg`]| AsySVRG on PS | PS | Algorithms 5–6 |
//! | [`pslite_sgd`] | asynchronous SGD on PS | PS | §5.3, Table 3 |

pub mod asysvrg;
pub mod dpsgd;
pub mod dsvrg;
pub mod fdsaga;
pub mod fdsgd;
pub mod fdsvrg;
pub mod ps;
pub mod pslite_sgd;
pub mod serial;
pub mod synsvrg;

use crate::loss::{Loss, LossKind, Regularizer};
use crate::net::collectives::Comm;
use crate::net::{Compression, NetModel, NetSpec, SimParams, TransportKind, WireFmt};
use crate::sparse::libsvm::Dataset;
use crate::util::pool::Pool;
use std::sync::Arc;

/// The optimization problem (paper eq. 1): dataset + loss + regularizer.
#[derive(Clone)]
pub struct Problem {
    pub ds: Arc<Dataset>,
    pub loss: LossKind,
    pub reg: Regularizer,
}

impl Problem {
    pub fn new(ds: Dataset, loss: LossKind, reg: Regularizer) -> Self {
        Problem { ds: Arc::new(ds), loss, reg }
    }

    /// Standard experimental setup of the paper: logistic loss + L2.
    pub fn logistic_l2(ds: Dataset, lambda: f64) -> Self {
        Problem::new(ds, LossKind::Logistic, Regularizer::L2 { lambda })
    }

    pub fn d(&self) -> usize {
        self.ds.d()
    }

    pub fn n(&self) -> usize {
        self.ds.n()
    }

    /// Objective `f(w) = (1/N) Σ φ(wᵀx_i, y_i) + g(w)`.
    pub fn objective(&self, w: &[f64]) -> f64 {
        let loss = self.loss.build();
        let n = self.n();
        let mut acc = 0.0;
        for i in 0..n {
            let z = self.ds.x.col_dot(i, w);
            acc += loss.value(z, self.ds.y[i]);
        }
        acc / n as f64 + self.reg.value(w)
    }

    /// Full gradient `∇f(w)` written into `out`.
    pub fn full_gradient(&self, w: &[f64], out: &mut [f64]) {
        let loss = self.loss.build();
        let n = self.n();
        out.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..n {
            let z = self.ds.x.col_dot(i, w);
            let c = loss.derivative(z, self.ds.y[i]) / n as f64;
            self.ds.x.col_axpy(i, c, out);
        }
        self.reg.add_grad(w, out);
    }

    /// Classification accuracy of `sign(wᵀx)` on this dataset.
    pub fn accuracy(&self, w: &[f64]) -> f64 {
        let n = self.n();
        let correct = (0..n)
            .filter(|&i| (self.ds.x.col_dot(i, w) >= 0.0) == (self.ds.y[i] > 0.0))
            .count();
        correct as f64 / n as f64
    }

    /// `(objective, accuracy)` from precomputed margins `wᵀx_i` — one pass
    /// over the data instead of two, with the margins coming from reused
    /// scratch ([`crate::serve::dense_margins`]): the allocation-free
    /// batch-predict path. Agrees with [`Problem::objective`] /
    /// [`Problem::accuracy`] bit-exactly (same `col_dot` margins, same
    /// summation order).
    pub fn eval_margins(&self, margins: &[f64], w: &[f64]) -> (f64, f64) {
        assert_eq!(margins.len(), self.n(), "need one margin per instance");
        let loss = self.loss.build();
        let n = self.n();
        let mut acc = 0.0;
        let mut correct = 0usize;
        for (i, &z) in margins.iter().enumerate() {
            acc += loss.value(z, self.ds.y[i]);
            if (z >= 0.0) == (self.ds.y[i] > 0.0) {
                correct += 1;
            }
        }
        (acc / n as f64 + self.reg.value(w), correct as f64 / n as f64)
    }

    /// Smoothness constant `L ≤ φ''_max · max_i ‖x_i‖² + λ` (instances are
    /// unit-normalized by the generators, but compute the max anyway).
    pub fn smoothness(&self) -> f64 {
        let loss = self.loss.build();
        let max_sq = (0..self.n())
            .map(|i| self.ds.x.col_nrm2_sq(i))
            .fold(0.0f64, f64::max);
        loss.curvature_bound() * max_sq + self.reg.lambda()
    }

    /// Strong-convexity modulus `μ` (the L2 coefficient).
    pub fn strong_convexity(&self) -> f64 {
        self.reg.strong_convexity()
    }

    /// Step size heuristic `η = c/L` with the paper-standard `c = 0.1`.
    pub fn default_eta(&self) -> f64 {
        0.1 / self.smoothness()
    }

    pub fn build_loss(&self) -> Box<dyn Loss> {
        self.loss.build()
    }
}

/// Parameters shared by all distributed runs.
#[derive(Clone, Debug)]
pub struct RunParams {
    /// Step size η (fixed during training, as in the paper §5.2).
    pub eta: f64,
    /// Number of outer iterations (epochs).
    pub outer: usize,
    /// Inner-loop length M; `0` = each algorithm's paper default
    /// (FD-SVRG: N, DSVRG: N/q, SynSVRG: N/q rounds, AsySVRG: N updates).
    pub m_inner: usize,
    /// Mini-batch size `u` (paper §4.4.1); 1 = the plain algorithm.
    pub batch: usize,
    /// Worker count q.
    pub q: usize,
    /// Server count p (parameter-server algorithms only).
    pub servers: usize,
    /// Shared RNG seed (drives the instance-sampling sequence).
    pub seed: u64,
    /// Base network link parameters (the uniform / rack-local /
    /// non-straggler link).
    pub sim: SimParams,
    /// Network scenario overlay (`--net uniform|hetero|straggler|jitter`),
    /// resolved against `sim` into the run's [`NetModel`] by
    /// [`RunParams::net_model`]. `Uniform` (the default) is bit-exact with
    /// the historical flat-`SimParams` charging.
    pub net: NetSpec,
    /// Early stop once `objective − f_opt ≤ target`: `(f_opt, target)`.
    pub gap_stop: Option<(f64, f64)>,
    /// Give up once the simulated clock passes this many seconds (the
    /// ">1000s" rows of the paper's Table 3).
    pub sim_time_cap: Option<f64>,
    /// Ablation: replace the Fig.-5 tree with a naive star reduce.
    pub star_reduce: bool,
    /// Wire format for counted payloads (`--wire f64|f32|sparse`): `f64`
    /// is bit-exact (the equivalence-suite default), `f32` halves wire
    /// bytes, `sparse` sends only nonzeros as `(u32, f32)` pairs.
    pub wire: WireFmt,
    /// Opt-in gradient sparsification on counted vector sends
    /// (`--compress none|topk:<k>|thresh:<t>`, `run.compress`). Off by
    /// default — every counted send stays byte-identical to the plain
    /// wire; when active, selected coordinates ride the sparse codec and
    /// both the byte counters and the simulated transfer times shrink in
    /// proportion.
    pub compress: Compression,
    /// FD-SVRG inner loop implementation: lazy `w̃ = α·v + γ·z`
    /// representation (O(nnz) per step, L2 only) instead of the naive
    /// O(d_l)-per-step dense update. Numerically equal up to roundoff;
    /// the §Perf optimization of EXPERIMENTS.md.
    pub lazy: bool,
    /// Host threads per node for the sparse compute kernels (`--threads`,
    /// `run.threads`; default 1 = today's serial loops). The parallel
    /// kernels are bit-exact at any width and the pool credits worker CPU
    /// back to the node's simulated clock, so `threads` changes host
    /// wall-clock only — `w`, traces and counters are invariant.
    pub threads: usize,
    /// Opt-in SIMD sparse kernels (`--simd`, `run.simd`; default false).
    /// Elementwise kernels vectorize bit-identically, but the reduction
    /// kernels (`col_dot`, row gathers) use multiple accumulator lanes
    /// that reassociate floating-point sums — trajectories agree with the
    /// serial chain only to documented tolerance, so this never turns on
    /// implicitly.
    pub simd: bool,
    /// Message-plane backing (`--transport sim|tcp`): in-memory mailboxes
    /// with one thread per node (default, bit-exact with the historical
    /// plane), or localhost sockets with one OS process per node.
    pub transport: TransportKind,
    /// Config-format spec the tcp monitor hands each worker process so it
    /// can rebuild the identical problem + params (`None` under sim; the
    /// CLI fills it in for `--transport tcp`).
    pub worker_spec: Option<Arc<String>>,
    /// Seeded fault plan (`--faults`, `run.faults`): installed on every
    /// sim endpoint at spawn, driving per-link drop/dup/reorder delays,
    /// scheduled crashes (with automatic recovery) and partitions. `None`
    /// (the default) keeps the message plane untouched — bit-exact with
    /// every pinned suite.
    pub faults: Option<Arc<crate::net::fault::FaultPlan>>,
    /// TCP rendezvous deadline, seconds (`--rendezvous-timeout`): how long
    /// the monitor waits for all workers to dial in, and the budget a
    /// worker's dial retry loop honours.
    pub rendezvous_secs: f64,
}

impl Default for RunParams {
    fn default() -> Self {
        RunParams {
            eta: 0.0, // 0 = problem.default_eta()
            outer: 10,
            m_inner: 0,
            batch: 1,
            q: 4,
            servers: 2,
            seed: 42,
            sim: SimParams::default(),
            net: NetSpec::Uniform,
            gap_stop: None,
            sim_time_cap: None,
            star_reduce: false,
            wire: WireFmt::F64,
            compress: Compression::None,
            lazy: false,
            threads: 1,
            simd: false,
            transport: TransportKind::Sim,
            worker_spec: None,
            faults: None,
            rendezvous_secs: crate::net::transport::tcp::DEFAULT_RENDEZVOUS_SECS,
        }
    }
}

impl RunParams {
    pub fn effective_eta(&self, p: &Problem) -> f64 {
        if self.eta > 0.0 {
            self.eta
        } else {
            p.default_eta()
        }
    }

    /// The run's communication policy: every counted send goes through
    /// this handle (codec + tree/star selection + optional sparsifier).
    pub fn comm(&self) -> Comm {
        Comm::new(self.wire, self.star_reduce).with_compress(self.compress)
    }

    /// The run's resolved network timing model: the scenario overlay
    /// (`net`) applied to the base link parameters (`sim`).
    pub fn net_model(&self) -> NetModel {
        self.net.resolve(self.sim)
    }
}

/// Reusable per-node scratch for the epoch loops: the margin / derivative
/// / partial-dot buffers every algorithm used to `vec!` afresh each epoch
/// (and each inner batch), plus the node's deterministic compute pool.
///
/// One `Workspace` lives on each simulated node's stack for the node's
/// whole lifetime; `Workspace::reset` re-lengths a buffer without giving
/// its capacity back, so after the first epoch the loops run
/// allocation-free. Fields are public (rather than accessor methods) so a
/// loop can hold disjoint buffers simultaneously under the borrow checker.
pub struct Workspace {
    /// Deterministic compute pool, [`RunParams::threads`] wide.
    pub pool: Pool,
    /// N-length margin scratch (`Dᵀw` partial products).
    pub margins: Vec<f64>,
    /// N-length loss-derivative scratch (`c0`).
    pub c0: Vec<f64>,
    /// N-length `zᵀx` scratch (the FD-SVRG lazy path).
    pub zx: Vec<f64>,
    /// Batch-length partial-dot scratch (inner-loop allreduce payload).
    pub partial: Vec<f64>,
    /// d-length gradient / reduce scratch.
    pub grad: Vec<f64>,
}

impl Workspace {
    pub fn new(threads: usize) -> Workspace {
        Workspace {
            pool: Pool::new(threads),
            margins: Vec::new(),
            c0: Vec::new(),
            zx: Vec::new(),
            partial: Vec::new(),
            grad: Vec::new(),
        }
    }

    /// Reset `buf` to `len` zeros, reusing its capacity. Returns the
    /// buffer for call-chaining into collectives
    /// (`comm.allreduce(ep, group, Workspace::reset(&mut ws.margins, n))`).
    ///
    /// An associated function on purpose: taking `&mut self` here would
    /// lock the whole workspace while a loop still reads its other
    /// buffers.
    pub fn reset(buf: &mut Vec<f64>, len: usize) -> &mut Vec<f64> {
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }
}

/// Algorithm selector used by the CLI and the experiment drivers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    FdSvrg,
    FdSgd,
    FdSaga,
    Dsvrg,
    DPsgd,
    SynSvrg,
    AsySvrg,
    PsLiteSgd,
    SerialSvrg,
    SerialSgd,
}

impl Algorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::FdSvrg => "fdsvrg",
            Algorithm::FdSgd => "fdsgd",
            Algorithm::FdSaga => "fdsaga",
            Algorithm::Dsvrg => "dsvrg",
            Algorithm::DPsgd => "dpsgd",
            Algorithm::SynSvrg => "synsvrg",
            Algorithm::AsySvrg => "asysvrg",
            Algorithm::PsLiteSgd => "pslite-sgd",
            Algorithm::SerialSvrg => "serial-svrg",
            Algorithm::SerialSgd => "serial-sgd",
        }
    }

    /// Canonical names and aliases, in dispatch order, as a
    /// [`crate::util::parse_enum`] table.
    const TABLE: [(&'static str, Algorithm); 21] = [
        ("fdsvrg", Algorithm::FdSvrg),
        ("fd-svrg", Algorithm::FdSvrg),
        ("fdsgd", Algorithm::FdSgd),
        ("fd-sgd", Algorithm::FdSgd),
        ("fdsaga", Algorithm::FdSaga),
        ("fd-saga", Algorithm::FdSaga),
        ("dsvrg", Algorithm::Dsvrg),
        ("d-svrg", Algorithm::Dsvrg),
        ("dpsgd", Algorithm::DPsgd),
        ("d-psgd", Algorithm::DPsgd),
        ("synsvrg", Algorithm::SynSvrg),
        ("syn-svrg", Algorithm::SynSvrg),
        ("asysvrg", Algorithm::AsySvrg),
        ("asy-svrg", Algorithm::AsySvrg),
        ("pslite-sgd", Algorithm::PsLiteSgd),
        ("pslite", Algorithm::PsLiteSgd),
        ("ps-sgd", Algorithm::PsLiteSgd),
        ("serial-svrg", Algorithm::SerialSvrg),
        ("svrg", Algorithm::SerialSvrg),
        ("serial-sgd", Algorithm::SerialSgd),
        ("sgd", Algorithm::SerialSgd),
    ];

    /// Parse an algorithm name: case-insensitive and underscore-tolerant
    /// (`FD_SVRG`, `FdSvrg`, `fd-svrg` and `fdsvrg` all name
    /// [`Algorithm::FdSvrg`]).
    pub fn parse(s: &str) -> Option<Algorithm> {
        crate::util::parse_enum(s, &Self::TABLE)
    }

    /// [`Algorithm::parse`] with a CLI-grade error: the failure message
    /// lists every valid name instead of a bare "unknown algorithm".
    pub fn parse_or_err(s: &str) -> Result<Algorithm, String> {
        let names: Vec<&str> = Algorithm::ALL.iter().map(|a| a.name()).collect();
        crate::util::parse_enum_or_err(
            s,
            "algorithm",
            "names (case-insensitive, '_' ok)",
            &names,
            &Self::TABLE,
        )
    }

    /// Every algorithm, in dispatch order.
    pub const ALL: [Algorithm; 10] = [
        Algorithm::FdSvrg,
        Algorithm::FdSgd,
        Algorithm::FdSaga,
        Algorithm::Dsvrg,
        Algorithm::DPsgd,
        Algorithm::SynSvrg,
        Algorithm::AsySvrg,
        Algorithm::PsLiteSgd,
        Algorithm::SerialSvrg,
        Algorithm::SerialSgd,
    ];

    pub const ALL_DISTRIBUTED: [Algorithm; 4] =
        [Algorithm::FdSvrg, Algorithm::Dsvrg, Algorithm::SynSvrg, Algorithm::AsySvrg];

    /// Run through the blocked dense engine ([`crate::runtime::trainer`])
    /// instead of the sparse CSC path. Only FD-SVRG has a blocked trainer;
    /// the backend (native f32 or PJRT) is the caller's choice via
    /// [`crate::runtime::build_engine`].
    pub fn run_blocked(
        &self,
        problem: &Problem,
        params: &RunParams,
        engine: &dyn crate::runtime::ComputeEngine,
    ) -> anyhow::Result<crate::metrics::RunResult> {
        anyhow::ensure!(
            *self == Algorithm::FdSvrg,
            "the blocked engine implements FD-SVRG only (got {})",
            self.name()
        );
        crate::runtime::trainer::run(problem, params, engine)
    }

    /// True for the cluster algorithms ([`Algorithm::make_cluster_driver`]
    /// works); false for the two single-node serial baselines.
    pub fn is_distributed(&self) -> bool {
        !matches!(self, Algorithm::SerialSvrg | Algorithm::SerialSgd)
    }

    /// Build the *concrete* [`crate::session::cluster::ClusterDriver`] for
    /// a distributed algorithm. The tcp launch path needs the concrete
    /// type: the monitor injects the worker spec
    /// ([`crate::session::cluster::ClusterDriver::processes`]) and a worker
    /// process runs a single node
    /// ([`crate::session::cluster::ClusterDriver::run_node`]). Errors for
    /// the serial algorithms, which have no cluster.
    pub fn make_cluster_driver(
        &self,
        problem: &Problem,
        params: &RunParams,
        resume: Option<crate::session::ResumeState>,
    ) -> anyhow::Result<crate::session::cluster::ClusterDriver> {
        let driver = match self {
            Algorithm::FdSvrg => fdsvrg::driver(problem, params, resume),
            Algorithm::FdSgd => fdsgd::driver(problem, params, resume),
            Algorithm::FdSaga => fdsaga::driver(problem, params, resume),
            Algorithm::Dsvrg => dsvrg::driver(problem, params, resume),
            Algorithm::DPsgd => dpsgd::driver(problem, params, resume),
            Algorithm::SynSvrg => synsvrg::driver(problem, params, resume),
            Algorithm::AsySvrg => asysvrg::driver(problem, params, resume),
            Algorithm::PsLiteSgd => pslite_sgd::driver(problem, params, resume),
            Algorithm::SerialSvrg | Algorithm::SerialSgd => {
                anyhow::bail!("{} is a serial algorithm: no cluster driver", self.name())
            }
        }?;
        anyhow::ensure!(
            params.faults.is_none() || params.transport == TransportKind::Sim,
            "--faults requires the sim transport (fault injection over tcp is not wired yet)"
        );
        // Asynchronous algorithms absorb a crash from the latest epoch
        // boundary; the synchronous ones barrier-and-restart from the
        // newest durable snapshot.
        let async_recovery = matches!(self, Algorithm::AsySvrg | Algorithm::PsLiteSgd);
        driver.with_faults(params.faults.clone(), async_recovery)
    }

    /// Build the steppable [`crate::session::Driver`] for this algorithm
    /// (optionally resuming from a mid-run state). Callers normally go
    /// through [`crate::session::SessionBuilder`] instead. When
    /// `params.transport` is [`TransportKind::Tcp`], the cluster driver is
    /// switched to process launch mode using `params.worker_spec`.
    pub fn make_driver(
        &self,
        problem: &Problem,
        params: &RunParams,
        resume: Option<crate::session::ResumeState>,
    ) -> anyhow::Result<Box<dyn crate::session::Driver>> {
        Ok(match self {
            Algorithm::SerialSvrg => {
                Box::new(crate::session::serial::SerialSvrgDriver::new(problem, params, resume)?)
            }
            Algorithm::SerialSgd => {
                Box::new(crate::session::serial::SerialSgdDriver::new(problem, params, resume)?)
            }
            _ => {
                let driver = self.make_cluster_driver(problem, params, resume)?;
                match params.transport {
                    TransportKind::Sim => Box::new(driver),
                    TransportKind::Tcp => {
                        let spec = params.worker_spec.clone().ok_or_else(|| {
                            anyhow::anyhow!(
                                "--transport tcp requires a worker spec (the CLI builds one)"
                            )
                        })?;
                        Box::new(driver.processes(spec, params.rendezvous_secs))
                    }
                }
            }
        })
    }

    /// Dispatch a run — a thin compatibility wrapper over
    /// [`crate::session::Session::run_to_completion`]. The session derives
    /// its stop policies from `params` (`outer`, `gap_stop`,
    /// `sim_time_cap`), so the trajectory and stopping behaviour are
    /// identical to the historical fire-and-forget loops.
    pub fn run(&self, problem: &Problem, params: &RunParams) -> crate::metrics::RunResult {
        crate::session::SessionBuilder::new(*self, problem, params.clone())
            .build()
            .expect("fresh sessions cannot fail to build")
            .run_to_completion()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, GenSpec};

    fn tiny_problem() -> Problem {
        let ds = generate(&GenSpec::new("t", 200, 80, 10).with_seed(3));
        Problem::logistic_l2(ds, 1e-3)
    }

    #[test]
    fn objective_at_zero_is_ln2_plus_zero_reg() {
        let p = tiny_problem();
        let w = vec![0.0; p.d()];
        assert!((p.objective(&w) - std::f64::consts::LN_2).abs() < 1e-12);
    }

    #[test]
    fn full_gradient_matches_finite_difference() {
        let p = tiny_problem();
        let mut rng = crate::util::Pcg64::seed_from_u64(5);
        let w: Vec<f64> = (0..p.d()).map(|_| 0.1 * rng.normal()).collect();
        let mut g = vec![0.0; p.d()];
        p.full_gradient(&w, &mut g);
        let h = 1e-6;
        for &coord in &[0usize, 3, 17, 100] {
            let mut wp = w.clone();
            wp[coord] += h;
            let mut wm = w.clone();
            wm[coord] -= h;
            let num = (p.objective(&wp) - p.objective(&wm)) / (2.0 * h);
            assert!(
                (num - g[coord]).abs() < 1e-5,
                "coord {coord}: fd {num} vs analytic {}",
                g[coord]
            );
        }
    }

    #[test]
    fn gradient_near_zero_at_converged_point() {
        let p = tiny_problem();
        // run a crude gradient descent; gradient norm must shrink
        let mut w = vec![0.0; p.d()];
        let mut g = vec![0.0; p.d()];
        let eta = p.default_eta() * 5.0;
        for _ in 0..300 {
            p.full_gradient(&w, &mut g);
            crate::linalg::axpy(-eta, &g, &mut w);
        }
        p.full_gradient(&w, &mut g);
        assert!(crate::linalg::nrm2(&g) < 1e-2);
    }

    #[test]
    fn smoothness_sane_for_normalized_data() {
        let p = tiny_problem();
        let l = p.smoothness();
        assert!(l > 0.25 && l < 0.26, "L = {l}");
    }

    #[test]
    fn algorithm_parse_round_trip() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::parse(a.name()), Some(a), "{}", a.name());
        }
        assert_eq!(Algorithm::parse("nope"), None);
    }

    #[test]
    fn algorithm_parse_is_case_and_underscore_tolerant() {
        assert_eq!(Algorithm::parse("FD_SVRG"), Some(Algorithm::FdSvrg));
        assert_eq!(Algorithm::parse("FdSvrg"), Some(Algorithm::FdSvrg));
        assert_eq!(Algorithm::parse("  Fd-Svrg "), Some(Algorithm::FdSvrg));
        assert_eq!(Algorithm::parse("PSLITE_SGD"), Some(Algorithm::PsLiteSgd));
        assert_eq!(Algorithm::parse("Serial_SVRG"), Some(Algorithm::SerialSvrg));
        assert_eq!(Algorithm::parse("D_PSGD"), Some(Algorithm::DPsgd));
    }

    #[test]
    fn algorithm_parse_error_lists_valid_names() {
        let err = Algorithm::parse_or_err("no-such-algo").unwrap_err();
        for a in Algorithm::ALL {
            assert!(err.contains(a.name()), "error must list {:?}: {err}", a.name());
        }
    }
}
